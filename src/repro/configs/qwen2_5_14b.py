"""qwen2.5-14b — dense GQA with QKV bias [hf:Qwen/Qwen2.5 family].

48L d_model=5120 40H (GQA kv=8) d_ff=13824 vocab=152064.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-14b", family="dense",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
    d_ff=13824, vocab_size=152064, head_dim=128,
    qkv_bias=True, rope_theta=1e6, norm_eps=1e-6,
    scan_group=8, accum_steps=4,
)

SMOKE = ModelConfig(
    name="qwen2.5-14b-smoke", family="dense",
    n_layers=3, d_model=128, n_heads=8, n_kv_heads=4,
    d_ff=320, vocab_size=512, head_dim=16,
    qkv_bias=True, rope_theta=1e6, norm_eps=1e-6, remat=False,
)
