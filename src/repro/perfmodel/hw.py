"""Hardware profiles for the Appendix-A performance model.

PLASTICINE reproduces the paper's evaluation platform (§6.1/§6.2): U=64
PMU/PCU pairs, SIMD width L=16, 16 MB scratchpad, DDR3 at 49 GB/s, SSD
spill at 700 MB/s, 12.3 TFLOPS peak, 1 GHz, worst-case on-chip network
latency 24 cycles + 6-cycle PCU pipeline.

TPU_V5E maps the same roles onto one v5e chip for the beyond-paper
analysis: the PMU grid becomes VMEM tiles (128 MB), the PCU SIMD becomes
the 8×128 VPU lane grid, DRAM becomes HBM at 819 GB/s; "SSD spill"
becomes host DMA (~50 GB/s PCIe-class).  `scale(n)` models an n-chip pod
(joins scale linearly in both lanes and aggregate bandwidth; the ICI
collective term of the distributed join is measured separately by the
dry-run, not assumed here).

CPU_XEON models the paper's baseline (§6.1): single-threaded hash join on
a Xeon E5-2697v2 — one comparison chain per cycle-ish with a calibrated
per-probe cost, DDR3 DRAM, 251 GB RAM before SSD spill.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class HW:
    name: str
    freq: float                  # Hz
    u: int                       # parallel compute units (PMU/PCU pairs)
    simd: int                    # lanes per unit
    dram_bw: float               # bytes/s
    dram_resp_s: float           # per-request response time (latency)
    dram_burst: int              # bytes per efficient burst
    spill_bw: float              # bytes/s once DRAM capacity is exceeded
    dram_cap: float              # bytes of DRAM before spill
    sram: float                  # on-chip memory bytes (usable: /2 for
                                 # double buffering per §6.2)
    net_lat_cycles: int = 24     # worst-case diagonal network latency
    pipe_lat_cycles: int = 6     # PCU pipeline latency
    tuple_bytes: int = 8         # two 4-byte ints (paper Example 3)
    cpu_probe_s: float = 0.0     # CPU-only: seconds per compare/probe

    @property
    def lanes(self) -> int:
        return self.u * self.simd

    @property
    def m_tuples(self) -> float:
        """On-chip memory budget in tuples with double buffering (§6.2:
        'uses only half of the on-chip memory')."""
        return self.sram / 2 / self.tuple_bytes

    def scaled(self, n_chips: int) -> "HW":
        return dataclasses.replace(
            self, name=f"{self.name}x{n_chips}",
            u=self.u * n_chips, dram_bw=self.dram_bw * n_chips,
            sram=self.sram * n_chips, dram_cap=self.dram_cap * n_chips)


PLASTICINE = HW(
    name="plasticine", freq=1e9, u=64, simd=16,
    dram_bw=49e9, dram_resp_s=60e-9, dram_burst=64,
    spill_bw=0.7e9, dram_cap=251e9, sram=16e6)

TPU_V5E = HW(
    name="tpu-v5e", freq=0.94e9, u=8, simd=128,       # VPU lane grid
    dram_bw=819e9, dram_resp_s=120e-9, dram_burst=512,
    spill_bw=50e9, dram_cap=16e9, sram=128e6)

CPU_XEON = HW(
    name="cpu-xeon-e5", freq=2.7e9, u=1, simd=1,
    dram_bw=50e9, dram_resp_s=80e-9, dram_burst=64,
    spill_bw=0.7e9, dram_cap=251e9, sram=30e6,
    cpu_probe_s=3e-9)            # calibrated hash-probe cost (§6.3 note)
