"""JAX cross-version compatibility shims.

The repo targets the jax >= 0.4.37 line; a few APIs moved between 0.4.x and
0.5+/0.6+:

* ``shard_map`` graduated from ``jax.experimental.shard_map`` to ``jax``
  proper, renaming ``check_rep`` → ``check_vma`` on the way,
* ``jax.sharding.AxisType`` (explicit-sharding mesh axis types) only exists
  on newer jax; older versions are implicitly "auto" everywhere.

Everything here degrades gracefully so a single codebase runs on either
line (CI pins one, accelerator images may carry another).
"""

from __future__ import annotations

import jax


def shard_map(f, *, mesh, in_specs, out_specs):
    """`jax.shard_map` with replication checking off, on any jax line."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False)


def make_mesh(shape, axes):
    """`jax.make_mesh` with Auto axis types where the API supports them."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)
