"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (never a module-level constant) so
importing this module never touches jax device state.  The dry-run forces
512 host-platform devices before any jax import; real launches build the
same logical mesh from the actual fleet.

Mesh semantics (see DESIGN.md §5):
  single-pod: (16, 16)      axes ("data", "model")   = 256 chips (v5e pod)
  multi-pod:  (2, 16, 16)   axes ("pod", "data", "model") = 512 chips

"pod" is the slow-link (DCN) axis: the launcher keeps only data-parallel
gradient reduction on it.  Scaling to 1000+ nodes grows the "pod" axis; all
sharding rules are written against axis *names*, so no model code changes.
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh

from repro import compat
from repro.parallel import sharding as shd


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_host_mesh(max_devices: int | None = None) -> Mesh:
    """Best-effort mesh over whatever devices exist (tests / CPU drivers):
    a 1-D ("data",) mesh, optionally capped."""
    devs = jax.devices()
    if max_devices:
        devs = devs[:max_devices]
    import numpy as np
    return Mesh(np.asarray(devs), ("data",))


def activate(mesh: Mesh, rules_overrides: dict | None = None) -> Mesh:
    """Install `mesh` as the process sharding context (logical-axis rules
    from repro.parallel.sharding, with optional per-launch overrides)."""
    rules = dict(shd.DEFAULT_RULES)
    if rules_overrides:
        rules.update(rules_overrides)
    shd.set_context(mesh, rules)
    return mesh
