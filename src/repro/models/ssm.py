"""Mamba2 (SSD — state-space duality, arXiv:2405.21060) block.

Chunked SSD algorithm: within a chunk the recurrence is computed as a
[Q, Q] masked-decay matmul (quadratic *inside* the chunk only — MXU-shaped);
across chunks a scan carries the [heads, d_state, head_dim] state.  A decode
step is the bare recurrence (O(1) per token) plus a rolling conv window —
this bounded state is why the SSM/hybrid archs own the long_500k shape.

Layout: d_inner = expand·d_model = n_ssm_heads·headdim; B/C are shared
across heads within each of `ngroups` groups (we use ngroups=1 per config).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers


def init_ssm(key, cfg):
    d = cfg.d_model
    di = cfg.d_inner_ssm
    nh, st, g = cfg.n_ssm_heads, cfg.ssm_state, cfg.ssm_ngroups
    conv_dim = di + 2 * g * st
    k1, k2, k3, k4 = jax.random.split(key, 4)
    import math
    return {
        # z, x, B, C, dt in one fused projection
        "in_proj": {"w": layers.normal(
            k1, (d, 2 * di + 2 * g * st + nh), 1.0 / math.sqrt(d))},
        "conv": {"w": layers.normal(k2, (cfg.ssm_conv, conv_dim), 0.1),
                 "b": jnp.zeros((conv_dim,), jnp.float32)},
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, nh).astype(jnp.float32)),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "d_skip": jnp.ones((nh,), jnp.float32),
        "gate_norm": layers.init_rms_norm(di),
        "out_proj": {"w": layers.normal(k3, (di, d), 1.0 / math.sqrt(di))},
    }


def _split_proj(cfg, zxbcdt):
    di = cfg.d_inner_ssm
    g, st, nh = cfg.ssm_ngroups, cfg.ssm_state, cfg.n_ssm_heads
    z = zxbcdt[..., :di]
    xin = zxbcdt[..., di:2 * di]
    b = zxbcdt[..., 2 * di:2 * di + g * st]
    c = zxbcdt[..., 2 * di + g * st:2 * di + 2 * g * st]
    dt = zxbcdt[..., 2 * di + 2 * g * st:]
    return z, xin, b, c, dt


def _causal_conv(x, w, b, cache=None):
    """Depthwise causal conv along seq.  x: [B, S, C], w: [W, C].
    With `cache` [B, W-1, C]: continue from rolling state (decode)."""
    win = w.shape[0]
    if cache is None:
        pad = jnp.zeros((x.shape[0], win - 1, x.shape[2]), x.dtype)
    else:
        pad = cache.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1], :] * w[i].astype(x.dtype)
              for i in range(win))
    out = out + b.astype(x.dtype)
    new_cache = xp[:, -(win - 1):, :] if win > 1 else None
    return jax.nn.silu(out), new_cache


def ssd_forward(x, p, cfg, chunk: int = 128):
    """Chunked SSD over a full sequence.  x: [B, S, d] → [B, S, d]."""
    y, _, _ = _ssd_core(x, p, cfg, chunk, want_state=False)
    return y


def ssd_prefill(x, p, cfg, chunk: int = 128):
    """Like ssd_forward but also returns (final_state [B,nh,st,hd],
    conv_cache [B,W-1,conv_dim]) to prime decoding."""
    return _ssd_core(x, p, cfg, chunk, want_state=True)


def _ssd_core(x, p, cfg, chunk: int, want_state: bool):
    bsz, s, _ = x.shape
    nh, hd, st, g = (cfg.n_ssm_heads, cfg.ssm_headdim, cfg.ssm_state,
                     cfg.ssm_ngroups)
    di = cfg.d_inner_ssm

    zxbcdt = layers.linear(x, p["in_proj"]["w"])
    z, xin, bb, cc, dt = _split_proj(cfg, zxbcdt)
    conv_in = jnp.concatenate([xin, bb, cc], axis=-1)
    conv_cache = conv_in[:, -(cfg.ssm_conv - 1):, :].astype(jnp.float32) \
        if want_state else None
    conv_out, _ = _causal_conv(conv_in, p["conv"]["w"], p["conv"]["b"])
    xin = conv_out[..., :di]
    bb = conv_out[..., di:di + g * st]
    cc = conv_out[..., di + g * st:]

    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"][None, None])            # [B,S,nh]
    a = -jnp.exp(p["a_log"])                                    # [nh] < 0
    la = dt * a[None, None]                                     # log-decay

    xh = xin.reshape(bsz, s, nh, hd).astype(jnp.float32)
    bg = bb.reshape(bsz, s, g, st).astype(jnp.float32)
    cg = cc.reshape(bsz, s, g, st).astype(jnp.float32)
    hpg = nh // g
    # broadcast groups over their heads
    bh = jnp.repeat(bg, hpg, axis=2)                            # [B,S,nh,st]
    ch = jnp.repeat(cg, hpg, axis=2)

    # pad to chunk multiple
    q = chunk
    nc = -(-s // q)
    pad = nc * q - s

    def padz(t):
        return jnp.pad(t, [(0, 0), (0, pad)] + [(0, 0)] * (t.ndim - 2))

    xh, bh, ch, la, dtp = map(padz, (xh, bh, ch, la, dt))
    xc = xh.reshape(bsz, nc, q, nh, hd)
    bc = bh.reshape(bsz, nc, q, nh, st)
    cx = ch.reshape(bsz, nc, q, nh, st)
    lac = la.reshape(bsz, nc, q, nh)
    dtc = dtp.reshape(bsz, nc, q, nh)

    # scan over chunks: intra-chunk quadratic matmuls + state carry.
    ii = jnp.arange(q)
    causal = (ii[:, None] >= ii[None, :])[None, :, :, None]     # [1,Q,Q,1]

    def chunk_step(state, xs):
        xi, bi, ci, lai, dti = xs           # [B,Q,nh,(hd|st)] / [B,Q,nh]
        cum = jnp.cumsum(lai, axis=1)                           # [B,Q,nh]
        # intra-chunk: decay(i,j) = exp(cum_i - cum_j), j <= i
        dec = cum[:, :, None, :] - cum[:, None, :, :]           # [B,Q,Q,nh]
        # mask BEFORE exp: for j > i, dec > 0 can overflow to +inf; masking
        # after exp leaves `0 * inf = NaN` in the where-VJP.
        l_mat = jnp.exp(jnp.where(causal, dec, -jnp.inf))
        gmat = jnp.einsum("bihs,bjhs->bijh", ci, bi)            # C_i · B_j
        wmat = gmat * l_mat * dti[:, None, :, :]
        y_intra = jnp.einsum("bijh,bjhd->bihd", wmat, xi)
        # inter-chunk: contribution of the carried state
        y_inter = jnp.einsum("bqhs,bhsd->bqhd",
                             ci * jnp.exp(cum)[..., None], state)
        # state update to the end of this chunk
        decay_to_end = jnp.exp(cum[:, -1:, :] - cum)            # [B,Q,nh]
        sgrow = jnp.einsum("bqhs,bqh,bqhd->bhsd",
                           bi, decay_to_end * dti, xi)
        new_state = state * jnp.exp(cum[:, -1, :])[..., None, None] + sgrow
        return new_state, y_intra + y_inter

    s0 = jnp.zeros((bsz, nh, st, hd), jnp.float32)
    final_state, ys = jax.lax.scan(
        chunk_step, s0,
        (xc.transpose(1, 0, 2, 3, 4), bc.transpose(1, 0, 2, 3, 4),
         cx.transpose(1, 0, 2, 3, 4), lac.transpose(1, 0, 2, 3),
         dtc.transpose(1, 0, 2, 3)))                            # [nc,B,Q,h,hd]

    y = ys.transpose(1, 0, 2, 3, 4).reshape(bsz, nc * q, nh, hd)[:, :s]
    y = y + xh[:, :s].reshape(bsz, s, nh, hd) * p["d_skip"][None, None, :, None]
    y = y.reshape(bsz, s, di).astype(x.dtype)

    # gated RMSNorm then output projection
    y = layers.rms_norm(y * jax.nn.silu(z), p["gate_norm"]["scale"],
                        cfg.norm_eps)
    out = layers.linear(y, p["out_proj"]["w"])
    return out, final_state, conv_cache


# --------------------------------------------------------------------------
# decode (recurrent) path
# --------------------------------------------------------------------------

def init_ssm_cache(cfg, batch, n_layers, dtype=jnp.float32):
    di = cfg.d_inner_ssm
    conv_dim = di + 2 * cfg.ssm_ngroups * cfg.ssm_state
    return {
        "state": jnp.zeros((n_layers, batch, cfg.n_ssm_heads, cfg.ssm_state,
                            cfg.ssm_headdim), dtype),
        "conv": jnp.zeros((n_layers, batch, cfg.ssm_conv - 1, conv_dim),
                          dtype),
    }


def ssd_decode_step(x, p, cfg, state, conv_cache):
    """One-token recurrence.  x: [B, 1, d]; state: [B, nh, st, hd];
    conv_cache: [B, W-1, conv_dim].  Returns (y [B,1,d], state, conv_cache).
    """
    bsz = x.shape[0]
    nh, hd, st, g = (cfg.n_ssm_heads, cfg.ssm_headdim, cfg.ssm_state,
                     cfg.ssm_ngroups)
    di = cfg.d_inner_ssm

    zxbcdt = layers.linear(x, p["in_proj"]["w"])
    z, xin, bb, cc, dt = _split_proj(cfg, zxbcdt)
    conv_in = jnp.concatenate([xin, bb, cc], axis=-1)
    conv_out, new_conv = _causal_conv(conv_in, p["conv"]["w"], p["conv"]["b"],
                                      cache=conv_cache)
    xin = conv_out[..., :di]
    bb = conv_out[..., di:di + g * st]
    cc = conv_out[..., di + g * st:]

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])[:, 0]  # [B,nh]
    a = -jnp.exp(p["a_log"])
    decay = jnp.exp(dt * a[None])                               # [B,nh]

    xh = xin.reshape(bsz, nh, hd).astype(jnp.float32)
    hpg = nh // g
    bh = jnp.repeat(bb.reshape(bsz, g, st), hpg, axis=1)        # [B,nh,st]
    ch = jnp.repeat(cc.reshape(bsz, g, st), hpg, axis=1)

    state = (state * decay[..., None, None]
             + jnp.einsum("bhs,bh,bhd->bhsd", bh, dt, xh))
    y = jnp.einsum("bhs,bhsd->bhd", ch, state)
    y = y + xh * p["d_skip"][None, :, None]
    y = y.reshape(bsz, 1, di).astype(x.dtype)
    y = layers.rms_norm(y * jax.nn.silu(z), p["gate_norm"]["scale"],
                        cfg.norm_eps)
    return layers.linear(y, p["out_proj"]["w"]), state, new_conv
