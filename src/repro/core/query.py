"""Declarative query-graph API: the join *query*, not the physical plan.

The paper's pitch is that one hardware abstraction serves linear (§4),
cyclic (§5) and star (§6.5) multiway joins — but picking which is which was
the caller's job (`kind="linear"` strings plus a per-kind `rb=/sb=/sc=/tc=`
kwarg soup).  This module moves that decision into the engine, the way
graph-pattern systems plan from the join graph itself:

  * :class:`Query` — named relations (with schemas) plus equality join
    predicates, i.e. the join hypergraph.  Nothing physical.
  * :meth:`Query.classify` — analyzes the predicate graph: a 3-cycle is the
    cyclic (triangle) query; a path is either the linear chain or the star
    (hub) schema, disambiguated by cardinalities (a hub whose centre dwarfs
    both endpoints is a fact table with dimension tables — the paper's star
    case); anything disconnected or multi-predicate raises.
  * :meth:`Query.bind` — a schema-checked :class:`Binding` that replaces the
    per-kind column-kwarg soup with ONE object shared by the fused layouts,
    the recovery KindOps and the sharded (mesh) path.

`core.session.JoinSession` is the front door that takes a Query all the way
to an exact, skew-recovered answer (with plan caching); the retired legacy
entry points (``driver.engine_count`` / ``engine_per_r_counts``) were shims
over this module — see the README migration table.

A Query is NOT limited to three relations: any connected acyclic
equality-predicate hypergraph over N >= 2 named relations executes through
the session (``planner.plan_query`` decomposes it into a
``core.plan_ir.QueryPlan`` — a DAG of fused 3-way and binary join steps).
``classify``/``bind`` remain the 3-relation *engine-kind* analysis that
single fused steps are built from.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Mapping

from repro.core.relation import Relation

# A path-shaped (hub) query is classified as the paper's star schema when
# the centre relation is at least this many times larger than EACH endpoint
# (fact table vs dimension tables); otherwise it is the linear chain.  Ties
# and ambiguity resolve to linear — the conservative plan (star pins both
# endpoint relations on-chip).
STAR_FACT_RATIO = 4.0

# Engine column-kwarg names per kind, in role order.  These are exactly the
# ctor parameters of the recovery KindOps / the `**cols` of the fused
# layouts, which is what lets one Binding serve every layer.
_KIND_COL_KWARGS = {
    "linear": ("rb", "sb", "sc", "tc"),
    "star": ("rb", "sb", "sc", "tc"),
    "cyclic": ("ra", "rb", "sb", "sc", "tc", "ta"),
}

# Canonical column names used by the distributed (mesh) path, which routes
# by literal column name: role -> ((canonical name, col kwarg), ...).
_CANONICAL_COLS = {
    "linear": {"r": (("b", "rb"),), "s": (("b", "sb"), ("c", "sc")),
               "t": (("c", "tc"),)},
    "star": {"r": (("b", "rb"),), "s": (("b", "sb"), ("c", "sc")),
             "t": (("c", "tc"),)},
    "cyclic": {"r": (("a", "ra"), ("b", "rb")),
               "s": (("b", "sb"), ("c", "sc")),
               "t": (("c", "tc"), ("a", "ta"))},
}


class QueryError(ValueError):
    """Base class for declarative-query rejections."""


class QuerySchemaError(QueryError):
    """A predicate references a relation or column the query doesn't have."""


class QueryGraphError(QueryError):
    """The predicate graph doesn't match a supported join shape."""


def _parse_endpoint(ep) -> tuple[str, str]:
    """Accept ``"rel.col"`` strings or ``(rel, col)`` pairs."""
    if isinstance(ep, str):
        rel, dot, col = ep.partition(".")
        if not dot or not rel or not col:
            raise QuerySchemaError(
                f"predicate endpoint {ep!r} is not of the form 'rel.col'")
        return rel, col
    rel, col = ep
    return str(rel), str(col)


@dataclasses.dataclass(frozen=True)
class Predicate:
    """One equality join predicate between two relation columns."""

    left: tuple[str, str]     # (relation name, column)
    right: tuple[str, str]


@dataclasses.dataclass(frozen=True)
class Classification:
    """What the predicate graph analysis decided (no data bound yet)."""

    kind: str                            # "linear" | "cyclic" | "star"
    shape: str                           # "path" | "cycle"
    roles: tuple[tuple[str, str], ...]   # (engine role r/s/t, relation name)
    cols: tuple[tuple[str, str], ...]    # (engine col kwarg, column name)

    @property
    def role_map(self) -> dict[str, str]:
        return dict(self.roles)

    @property
    def col_map(self) -> dict[str, str]:
        return dict(self.cols)


@dataclasses.dataclass(frozen=True)
class Binding:
    """A classification bound to concrete relations: the ONE checked object
    every layer shares (fused layouts take ``**binding.col_kwargs()``,
    recovery takes ``binding.kind_ops()``, the mesh path takes
    ``binding.canonical()``)."""

    kind: str
    roles: tuple[tuple[str, str], ...]           # (role, relation name)
    cols: tuple[tuple[str, str], ...]            # (col kwarg, column name)
    rels: Mapping[str, Relation]                 # role -> Relation

    def col_kwargs(self) -> dict[str, str]:
        """The engine/recovery column kwargs (``rb=/sb=/...``), derived —
        not hand-threaded."""
        return dict(self.cols)

    def relations(self) -> tuple[Relation, Relation, Relation]:
        return self.rels["r"], self.rels["s"], self.rels["t"]

    def cardinalities(self) -> tuple[int, int, int]:
        return tuple(int(self.rels[k].n) for k in ("r", "s", "t"))

    def kind_ops(self, **kw):
        """The recovery KindOps for this query, built FROM the binding."""
        from repro.core import recovery
        return recovery.ops_from_binding(self, **kw)

    def canonical(self) -> tuple[Relation, Relation, Relation]:
        """Relations re-keyed to the canonical column names the distributed
        path routes by (linear/star: r.b, s.b/s.c, t.c; cyclic adds a).
        Pure dict re-keying — arrays (and their device placement) are
        untouched, so sharded inputs stay sharded."""
        colmap = self.col_kwargs()
        out = []
        for role in ("r", "s", "t"):
            rel = self.rels[role]
            cols = {canon: rel.columns[colmap[kwarg]]
                    for canon, kwarg in _CANONICAL_COLS[self.kind][role]}
            out.append(Relation(cols, rel.valid))
        return tuple(out)


class Query:
    """A declarative multiway join: named relations + equality predicates.

    >>> q = Query(
    ...     relations={"f1": friends, "f2": friends, "f3": friends},
    ...     predicates=[("f1.dst", "f2.src"), ("f2.dst", "f3.src")])
    >>> q.classify().kind
    'linear'

    The physical strategy (which relation drives, which columns are H/g
    hashed, 3-way vs cascade) is derived — there is no ``kind`` string.
    Self-joins are expressed by registering the same Relation under several
    names (as above).  Aggregates only, like the engine: COUNT everywhere,
    per-R counts where the classified kind supports them.
    """

    def __init__(self, relations: Mapping[str, Relation],
                 predicates: Iterable):
        self.relations: dict[str, Relation] = dict(relations)
        if not self.relations:
            raise QuerySchemaError("a query needs at least one relation")
        preds = []
        for p in predicates:
            if isinstance(p, Predicate):
                left, right = p.left, p.right
            else:
                left, right = p
            preds.append(Predicate(_parse_endpoint(left),
                                   _parse_endpoint(right)))
        self.predicates: tuple[Predicate, ...] = tuple(preds)
        if not self.predicates:
            raise QueryGraphError("a multiway query needs join predicates")
        for pred in self.predicates:
            for rel, col in (pred.left, pred.right):
                if rel not in self.relations:
                    raise QuerySchemaError(
                        f"predicate references unknown relation {rel!r} "
                        f"(have {sorted(self.relations)})")
                if col not in self.relations[rel].columns:
                    raise QuerySchemaError(
                        f"relation {rel!r} has no column {col!r} "
                        f"(schema: {sorted(self.relations[rel].columns)})")

    # -- structure ---------------------------------------------------------

    def schema(self) -> tuple:
        """Hashable structural signature: relation names + schemas +
        predicates.  Two queries with equal signatures classify and bind
        identically — this is the plan-cache key's structure component."""
        rels = tuple((name, tuple(sorted(rel.columns)))
                     for name, rel in self.relations.items())
        preds = tuple((p.left, p.right) for p in self.predicates)
        return rels, preds

    def edges(self) -> dict[frozenset, Predicate]:
        """The predicate graph's edge set: ``frozenset({rel_a, rel_b}) ->
        Predicate``.  Validates the per-edge rules (no self-referential
        predicates, no parallel predicates between one pair) for ANY
        relation count — the N-way decomposer in ``core.planner`` builds
        its join tree from this."""
        return self._edges()

    def _edges(self) -> dict[frozenset, Predicate]:
        edges: dict[frozenset, Predicate] = {}
        for pred in self.predicates:
            (lr, _), (rr, _) = pred.left, pred.right
            if lr == rr:
                raise QueryGraphError(
                    f"predicate joins {lr!r} with itself; register the "
                    "relation under two names for a self-join")
            key = frozenset((lr, rr))
            if key in edges:
                raise QueryGraphError(
                    f"multiple predicates between {sorted(key)} "
                    "(conjunctive multi-column joins are not supported)")
            edges[key] = pred
        return edges

    # -- classification ----------------------------------------------------

    def classify(self, cardinalities: Mapping[str, int] | None = None, *,
                 star_fact_ratio: float = STAR_FACT_RATIO) -> Classification:
        """Infer the join kind from the predicate graph.

        * three relations in a 3-cycle        → ``cyclic`` (triangles),
        * three relations in a path whose hub is ≥ ``star_fact_ratio`` ×
          each endpoint                        → ``star`` (fact + dims),
        * any other connected path             → ``linear``,
        * anything else (disconnected graph, unsupported arity, repeated
          predicates, self-referential predicates) → ``QueryGraphError``.

        ``cardinalities`` (name → live row count) feeds the star/linear
        disambiguation; when omitted it is read from the relations.
        """
        names = list(self.relations)
        if len(names) != 3:
            raise QueryGraphError(
                f"Query.classify infers the 3-relation engine kinds; got "
                f"{len(names)} relations ({names}).  N-way acyclic queries "
                "are supported: execute them through JoinSession.execute "
                "(or planner.plan_query), which decomposes the predicate "
                "graph into a multi-step plan of fused 3-way and binary "
                "join steps")
        edges = self._edges()
        degree = {n: 0 for n in names}
        for key in edges:
            for n in key:
                degree[n] += 1
        if min(degree.values()) == 0 or len(edges) < 2:
            isolated = sorted(n for n, d in degree.items() if d == 0)
            raise QueryGraphError(
                f"predicate graph is disconnected: relation(s) {isolated} "
                "join nothing")

        def pred_col(pred: Predicate, rel: str) -> str:
            return pred.left[1] if pred.left[0] == rel else pred.right[1]

        if len(edges) == 3:
            # 3-cycle: the triangle query.  R is the first-declared
            # relation (it drives recovery); S its first-declared
            # neighbour; T closes the cycle.
            r = names[0]
            nbrs = [n for n in names[1:]]
            s, t = nbrs[0], nbrs[1]
            e_rs = edges[frozenset((r, s))]
            e_st = edges[frozenset((s, t))]
            e_tr = edges[frozenset((t, r))]
            roles = (("r", r), ("s", s), ("t", t))
            cols = (("ra", pred_col(e_tr, r)), ("rb", pred_col(e_rs, r)),
                    ("sb", pred_col(e_rs, s)), ("sc", pred_col(e_st, s)),
                    ("tc", pred_col(e_st, t)), ("ta", pred_col(e_tr, t)))
            return Classification("cyclic", "cycle", roles, cols)

        # path: centre has degree 2, endpoints degree 1
        centre = next(n for n, d in degree.items() if d == 2)
        ends = [n for n in names if n != centre]
        r, t = ends[0], ends[1]
        e_rs = edges[frozenset((r, centre))]
        e_st = edges[frozenset((centre, t))]
        if cardinalities is None:
            cardinalities = {n: int(rel.n)
                             for n, rel in self.relations.items()}
        n_c = cardinalities[centre]
        hub = n_c >= star_fact_ratio * max(cardinalities[r],
                                           cardinalities[t], 1)
        kind = "star" if hub else "linear"
        roles = (("r", r), ("s", centre), ("t", t))
        cols = (("rb", pred_col(e_rs, r)), ("sb", pred_col(e_rs, centre)),
                ("sc", pred_col(e_st, centre)), ("tc", pred_col(e_st, t)))
        return Classification(kind, "path", roles, cols)

    # -- binding -----------------------------------------------------------

    def bind(self, classification: Classification | None = None, *,
             cardinalities: Mapping[str, int] | None = None,
             star_fact_ratio: float = STAR_FACT_RATIO) -> Binding:
        """Classify (unless given) and attach the relations: the checked
        Binding every execution layer consumes."""
        cls_ = classification or self.classify(
            cardinalities, star_fact_ratio=star_fact_ratio)
        rels = {role: self.relations[name] for role, name in cls_.roles}
        return Binding(kind=cls_.kind, roles=cls_.roles, cols=cls_.cols,
                       rels=rels)


def _legacy_query(kind: str, r: Relation, s: Relation, t: Relation,
                  cols: Mapping[str, str]) -> tuple[Query, Classification]:
    """Build the Query + forced Classification a legacy ``kind``-string
    entry point implies (the deprecation-shim path: same relations, same
    column kwargs, no inference)."""
    kwargs = _KIND_COL_KWARGS[kind]
    unknown = set(cols) - set(kwargs)
    if unknown:
        # the legacy entry points rejected misdirected column kwargs with
        # a TypeError from the KindOps ctor — keep that, don't execute a
        # plausible-but-wrong join on default columns
        raise TypeError(f"unexpected column kwargs for kind {kind!r}: "
                        f"{sorted(unknown)} (valid: {list(kwargs)})")
    defaults = {"ra": "a", "rb": "b", "sb": "b", "sc": "c", "tc": "c",
                "ta": "a"}
    colmap = {k: cols.get(k, defaults[k]) for k in kwargs}
    preds = [(("r", colmap["rb"]), ("s", colmap["sb"])),
             (("s", colmap["sc"]), ("t", colmap["tc"]))]
    if kind == "cyclic":
        preds.append((("t", colmap["ta"]), ("r", colmap["ra"])))
    q = Query({"r": r, "s": s, "t": t}, preds)
    cls_ = Classification(
        kind=kind, shape="cycle" if kind == "cyclic" else "path",
        roles=(("r", "r"), ("s", "s"), ("t", "t")),
        cols=tuple((k, colmap[k]) for k in kwargs))
    return q, cls_
