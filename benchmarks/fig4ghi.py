"""Fig 4 (g,h,i): star 3-way join — hyperparameters and speedup over the
cascaded binary star plan, across d (fact-key distincts) and K (dimension
size) at different DRAM bandwidths.  Paper claim: 11x."""

from __future__ import annotations

import dataclasses

from benchmarks.common import claim, write_csv
from repro.perfmodel import PLASTICINE, star3_binary_time, star3_time

N = 1e9               # fact relation


def main(results: dict | None = None):
    results = results if results is not None else {}
    print("fig4ghi: star 3-way join")

    rows_g = []
    for d in (1e6, 5e5, 2e5, 1e5):
        for h in (2, 4, 8, 16, 32):
            b = star3_time(1e6, N, 1e6, d, PLASTICINE, h_bkt=h)
            rows_g.append([d, h, b.total, b.bottleneck])
    write_csv("fig4g_star_hyper", ["d", "h_bkt", "total_s", "bottleneck"],
              rows_g)

    rows_hi = []
    sp_by_d = {}
    for bw in (24.5e9, 49e9):
        hw = dataclasses.replace(PLASTICINE, dram_bw=bw)
        for k in (1e6, 2e6):
            for d in (1e6, 5e5, 2e5, 1e5):
                s3 = star3_time(k, N, k, d, hw)
                sb = star3_binary_time(k, N, k, d, hw)
                sp = sb.total / s3.total
                rows_hi.append([bw, k, d, k / d, s3.total, sb.total, sp])
                if bw == 49e9 and k == 1e6:
                    sp_by_d[d] = sp
    write_csv("fig4hi_star_speedup",
              ["dram_bw", "k", "d", "dup", "star3_s", "cascade_s",
               "speedup"], rows_hi)

    claim(results, "fig4ghi_star_11x",
          any(8 <= sp <= 25 for sp in sp_by_d.values()),
          "speedups by d: " + ", ".join(
              f"d={d:.0e}: {sp:.1f}x" for d, sp in sp_by_d.items())
          + " (paper: 11x)")
    claim(results, "fig4ghi_lower_d_higher_speedup",
          sp_by_d[1e5] > sp_by_d[1e6],
          f"d=1e5: {sp_by_d[1e5]:.1f}x > d=1e6: {sp_by_d[1e6]:.1f}x "
          "(intermediate expansion drives the gap)")
    return results


if __name__ == "__main__":
    main()
