"""JoinSession: one front door for plan → decompose → execute → recover.

The session owns everything between a declarative :class:`~repro.core.query.
Query` — over ANY connected acyclic graph of N ≥ 2 relations (cyclic stays
supported at N = 3, the triangle query) — and an exact answer:

  * **decompose** — ``planner.plan_query`` turns the predicate graph into
    a ``core.plan_ir.QueryPlan``: 3-relation queries keep their single
    fused, recovery-wrapped step; larger trees become binary materialize
    steps feeding a fused 3-way (or binary) root, ordered by the cost
    model's per-step cardinality estimates,
  * **cache** — whole multi-step plans are cached by (query structure,
    log-bucketed cardinalities, m_budget, hardware, kernel flag, forced
    strategy).  Bucketing the cardinalities (``sketches.card_bucket``)
    makes the cache survive small data drift — a ±5% refresh still hits;
    a 4x resize re-plans,
  * **execute / recover** — ``plan_ir.execute_plan`` walks the DAG:
    intermediates materialize exactly (host-histogram sizing), every
    fused step runs the shared skew-recovery rounds with the session's
    ``base_salt``, and ``overflowed == False`` is a postcondition.  The
    returned :class:`QueryResult` aggregates count / tuples_read /
    recovery rounds / timings across steps (``step_stats`` has the
    per-step breakdown).

``execute_many`` batches queries over the shared plan cache (structurally
repeated queries plan once); ``execute_sharded`` runs a 3-relation query
on a device mesh through ``distributed.engine_count_sharded``.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Iterable

import numpy as np

from repro.core import plan_ir, planner, recovery, sketches
from repro.core.query import STAR_FACT_RATIO, Classification, Query
from repro.core.results import JoinResult
from repro.perfmodel import HW, PLASTICINE, Calibration


@dataclasses.dataclass(frozen=True, kw_only=True)
class QueryResult(JoinResult):
    """Uniform result for every kind, strategy and relation count: the
    :class:`~repro.core.results.JoinResult` core (count / overflowed /
    tuples_read / rounds / steps) plus the session's plan, cache and
    timing metadata.  ``JoinSession.execute``, ``execute_sharded`` and
    ``StandingQuery.snapshot`` all answer with this type."""

    kind: str                             # root frontier kind (or "binary")
    strategy: str                         # "3way" | "cascade" | "hybrid"
    cache_hit: bool                       # plan came from the session cache
    plan_s: float                         # decompose + sizing seconds
    exec_s: float                         # execution seconds, all steps
    plan: plan_ir.QueryPlan | None = None
    per_r: recovery.PerRResult | None = None   # per-R aggregates (linear)


class JoinSession:
    """Declarative query executor with a plan cache.

    >>> sess = JoinSession(m_budget=4096)
    >>> res = sess.execute(Query(relations={...}, predicates=[...]))
    >>> res.count, res.kind, res.strategy, res.cache_hit

    Parameters mirror the engine: ``use_kernel`` dispatches the fused
    Pallas kernels, ``max_rounds``/``growth`` shape skew recovery,
    ``base_salt`` seeds every round's hash salt (plumbed all the way into
    the recovery rounds of every fused step — a plan-level salt is never
    silently dropped), ``hw`` is the profile the 3-way vs cascade time
    decisions run on, and ``star_fact_ratio`` tunes the star/linear hub
    disambiguation.  ``calibration`` (``perfmodel.Calibration``, typically
    ``calibration_from_bench("BENCH_engine.json")``) re-anchors the time
    model's constants to measured per-root seconds on THIS machine; the
    default ``None`` keeps the paper's hand-set constants.
    """

    def __init__(self, *, m_budget: int | None = None, hw: HW = PLASTICINE,
                 use_kernel: bool = False, max_rounds: int = 3,
                 growth: float = 2.0, base_salt: int = 0,
                 star_fact_ratio: float | None = None,
                 calibration: Calibration | None = None):
        self.m_budget = m_budget
        self.hw = hw
        self.use_kernel = use_kernel
        self.max_rounds = max_rounds
        self.growth = growth
        self.base_salt = base_salt
        self.star_fact_ratio = (STAR_FACT_RATIO if star_fact_ratio is None
                                else star_fact_ratio)
        self.calibration = calibration
        self._plan_cache: dict[Any, plan_ir.QueryPlan] = {}
        self._hits = 0
        self._misses = 0

    # -- cache -------------------------------------------------------------

    @property
    def cache_info(self) -> dict[str, int]:
        return {"size": len(self._plan_cache), "hits": self._hits,
                "misses": self._misses}

    def clear_plan_cache(self) -> None:
        self._plan_cache.clear()

    def refresh_calibration(self, bench="BENCH_engine.json", *,
                            out_path=None, shape: str = "cascade_4way"
                            ) -> Calibration:
        """Re-derive the time-model calibration from a bench report,
        persist it to the committed calibration file
        (``perfmodel.CALIBRATION_FILE``), and adopt it for this session.
        The plan cache is cleared: cached plans embed 3-way/cascade
        decisions made under the old scales, and the calibration is part
        of the cache key anyway."""
        from repro.perfmodel import calibrate
        cal = calibrate.refresh_calibration_file(
            bench, calibrate.CALIBRATION_FILE if out_path is None
            else out_path, shape=shape)
        self.calibration = cal
        self.clear_plan_cache()
        return cal

    def _cache_key(self, query: Query, cards: dict[str, int],
                   m_budget: int | None, strategy: str | None,
                   forced: Classification | None,
                   per_r_name: str | None, per_r_key: str):
        # cardinalities enter the key LOG-BUCKETED (sketches.card_bucket):
        # plans are estimate-sized and recovery-correct, so a few percent
        # of data drift must not evict them — only scale changes re-plan
        buckets = tuple(sorted((name, sketches.card_bucket(n))
                               for name, n in cards.items()))
        cal = self.calibration
        return (query.schema(), buckets, m_budget, self.hw,
                self.use_kernel, strategy,
                None if forced is None else (forced.kind, forced.roles,
                                             forced.cols),
                None if per_r_name is None else (per_r_name, per_r_key),
                None if cal is None else (cal.fused3_scale,
                                          cal.cascade_scale))

    # -- planning ----------------------------------------------------------

    def _plan(self, query: Query, cards: dict[str, int],
              m_budget: int | None, strategy: str | None,
              forced: Classification | None,
              per_r_name: str | None = None, per_r_key: str = "a"
              ) -> tuple[plan_ir.QueryPlan, bool]:
        """Decompose + size, through the plan cache.  A hit skips the
        graph analysis, the decomposition and the shape/strategy sizing."""
        key = self._cache_key(query, cards, m_budget, strategy, forced,
                              per_r_name, per_r_key)
        hit = self._plan_cache.get(key)
        if hit is not None:
            self._hits += 1
            return hit, True
        self._misses += 1
        qp = planner.plan_query(
            query, cards, m_budget=m_budget, hw=self.hw,
            use_kernel=self.use_kernel, max_rounds=self.max_rounds,
            growth=self.growth, base_salt=self.base_salt,
            star_fact_ratio=self.star_fact_ratio, strategy=strategy,
            classification=forced, calibration=self.calibration,
            per_r_name=per_r_name, per_r_key=per_r_key)
        # every plan the session caches is statically verified: DAG shape,
        # schema propagation, refcounts, per-R pins, and the width bounds
        # of every composite-id space / accumulator at the estimated cards
        # (imports deferred: analysis sits above core in the import graph)
        from repro.analysis.verify_plan import verify_plan
        from repro.analysis.widths import check_widths
        verify_plan(qp, schemas={name: frozenset(rel.columns)
                                 for name, rel in query.relations.items()})
        check_widths(qp, cards)
        self._plan_cache[key] = qp
        return qp, False

    # -- execution ---------------------------------------------------------

    def _resolve_per_r(self, query: Query, cards: dict[str, int],
                       per_r: bool | str) -> str | None:
        """Turn the ``per_r`` argument into a pinned relation name:
        ``False`` → ``None``; a string names the relation; ``True`` picks
        the classified role-r endpoint (3 relations) or the first-declared
        leaf of the predicate tree (N ≥ 4)."""
        if not per_r:
            return None
        if isinstance(per_r, str):
            return per_r
        names = list(query.relations)
        if len(names) == 3:
            cls_ = query.classify(cards,
                                  star_fact_ratio=self.star_fact_ratio)
            return dict(cls_.roles)["r"]
        degree = {nm: 0 for nm in names}
        for key in query.edges():
            for nm in key:
                degree[nm] += 1
        for nm in names:           # a tree always has >= 2 leaves
            if degree[nm] == 1:
                return nm
        raise ValueError("per_r=True found no leaf relation; pin one by "
                         "name (per_r='<relation>')")

    def execute(self, query: Query, *, m_budget: int | None = None,
                per_r: bool | str = False, key_col: str = "a",
                plan=None, strategy: str | None = None,
                classification: Classification | None = None) -> QueryResult:
        """Decompose (or reuse a cached plan), walk the DAG, recover.

        ``plan`` overrides sizing with an explicit 3-relation shape plan
        (skipping the planner and the cache); ``strategy=None`` lets the
        time model pick per root, ``"3way"`` forces the fused engine at
        the root, ``"cascade"`` forces the all-binary cascade;
        ``classification`` bypasses 3-relation inference (the deprecation
        shims use it — new code should let the graph speak).

        ``per_r`` requests per-key group counts: ``True`` groups by the
        classified role-r endpoint (3 relations) or the first-declared
        leaf (N ≥ 4); a string pins a specific relation.  The planner
        routes the pinned relation to the fused linear root (its join
        edge is never contracted away) and the executor answers through
        the recovery engine's per-R rounds — ``QueryResult.per_r`` holds
        the (keys, counts, valid) aggregate, ``count`` its valid sum.
        """
        if strategy not in (None, "3way", "cascade"):
            raise ValueError(f"unknown strategy {strategy!r}: pass None "
                             "(planner decides), '3way' (force the fused "
                             "multiway engine) or 'cascade' (force the "
                             "binary cascade)")
        t0 = time.perf_counter()
        m_budget = self.m_budget if m_budget is None else m_budget
        cards = {name: int(rel.n) for name, rel in query.relations.items()}
        per_r_name = self._resolve_per_r(query, cards, per_r)
        if plan is not None:
            cls_ = classification or query.classify(
                cards, star_fact_ratio=self.star_fact_ratio)
            if per_r_name is not None:
                cls_ = planner.pin_per_r_classification(cls_, per_r_name)
            ep = planner.forced_3way_plan(
                cls_.kind, plan, m_budget=m_budget,
                use_kernel=self.use_kernel, max_rounds=self.max_rounds,
                growth=self.growth, base_salt=self.base_salt)
            qp = planner._single_fused_plan(
                query, cls_, ep,
                per_r_key=(key_col if per_r_name else None))
            from repro.analysis.verify_plan import verify_plan
            from repro.analysis.widths import check_widths
            verify_plan(qp, schemas={
                name: frozenset(rel.columns)
                for name, rel in query.relations.items()})
            check_widths(qp, cards)
            cache_hit = False
        else:
            qp, cache_hit = self._plan(query, cards, m_budget, strategy,
                                       classification, per_r_name,
                                       key_col)
        plan_s = time.perf_counter() - t0

        t1 = time.perf_counter()
        res = plan_ir.execute_plan(qp, dict(query.relations))
        exec_s = time.perf_counter() - t1
        return QueryResult(
            count=np.int64(res.count), overflowed=bool(res.overflowed),
            tuples_read=np.int64(res.tuples_read), rounds=int(res.rounds),
            kind=qp.kind, strategy=qp.strategy, cache_hit=cache_hit,
            plan_s=plan_s, exec_s=exec_s, plan=qp, per_r=res.per_r,
            steps=res.step_stats)

    # -- standing queries --------------------------------------------------

    def watch(self, query: Query, *, m_budget: int | None = None,
              strategy: str | None = None):
        """Register ``query`` as a standing query: execute it once keeping
        every binary step's materialized intermediate resident, then keep
        the count exact under ``Relation.append`` ingest by executing only
        the delta plan per append (``core.streaming.StandingQuery``).
        ``snapshot()`` on the returned handle answers with the same
        :class:`QueryResult` type as :meth:`execute`."""
        from repro.core.streaming import StandingQuery
        return StandingQuery(self, query, m_budget=m_budget,
                             strategy=strategy)

    # -- batched execution -------------------------------------------------

    def execute_many(self, queries: Iterable[Query], *,
                     m_budget: int | None = None,
                     strategy: str | None = None) -> list[QueryResult]:
        """Execute a batch of queries over the SHARED plan cache.

        Structurally repeated queries (the common serving pattern: one
        parametrized query over refreshed relations of similar size) pay
        decomposition + sizing once — every later execution is a
        plan-cache hit, including across ±small cardinality drift thanks
        to the log-bucketed cache key.  Returns one QueryResult per query,
        in input order.
        """
        return [self.execute(q, m_budget=m_budget, strategy=strategy)
                for q in queries]

    # -- distributed -------------------------------------------------------

    def execute_sharded(self, query: Query, mesh, row: str, col: str, *,
                        max_rounds: int = 2,
                        classification: Classification | None = None,
                        **kw) -> QueryResult:
        """The same declarative query on a device mesh: classify + bind,
        re-key the relations to the canonical routing columns, and run the
        cross-device recovery rounds of ``distributed.engine_count_sharded``
        (``overflowed == False`` on the mesh too).  Relations should enter
        sharded in arrival order (``distributed.shard_relation``); 3
        relations only for now (N-way mesh plans are a ROADMAP follow-up).
        """
        from repro.core import distributed
        t0 = time.perf_counter()
        cards = {name: int(rel.n) for name, rel in query.relations.items()}
        cls_ = classification or query.classify(
            cards, star_fact_ratio=self.star_fact_ratio)
        binding = query.bind(cls_)
        r, s, t = binding.canonical()
        plan_s = time.perf_counter() - t0
        t1 = time.perf_counter()
        fn = distributed.engine_count_sharded(
            mesh, row, col, binding.kind, max_rounds=max_rounds,
            growth=self.growth, use_kernel=self.use_kernel, **kw)
        res = fn(r, s, t)
        exec_s = time.perf_counter() - t1
        return QueryResult(
            count=np.int64(int(res.count)),
            overflowed=bool(res.overflowed), tuples_read=None,
            rounds=int(res.rounds), kind=binding.kind, strategy="3way",
            cache_hit=False, plan_s=plan_s, exec_s=exec_s)
