"""Data substrate: synthetic token streams, relation workload generators,
and the join-enriched pipeline (the paper's engine as a framework feature)."""

from repro.data.pipeline import JoinEnrichedPipeline  # noqa: F401
from repro.data.relations import RelGenConfig, gen_relation  # noqa: F401
from repro.data.synthetic import TokenGenConfig, token_batches  # noqa: F401
