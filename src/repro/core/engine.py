"""Unified multiway join engine: fused partition sweeps + skew recovery.

This is the execution layer the paper's numbers assume.  The per-algorithm
drivers in ``linear3.py`` / ``cyclic3.py`` / ``star3.py`` sweep the coarse
H(B)×g(C) partition grid with nested ``lax.scan`` loops, launching one
bucket-row kernel per step — the grid dimension (the paper's U-way PMU
parallelism, §4–§6) sits idle between launches.  The engine instead issues
ONE fused kernel per query (``kernels.ops.fused_*``): the Pallas grid spans
``(h_parts, u, g_parts)`` (resp. the cyclic/star equivalents), BlockSpec
index maps pick the partition row per program, and Pallas double-buffers the
HBM→VMEM operand streams across the whole sweep (§6.2 prefetching, now
spanning partitions rather than restarting per bucket row).

Skew recovery (paper §5's skew discussion, made correct-by-construction)
-----------------------------------------------------------------------
Fixed-capacity buckets overflow under key skew.  The scan drivers only
*flag* this; ``core.driver`` then re-runs the whole query with grown
capacities.  The engine recovers surgically instead, exploiting that the
fused kernels return **per-partition** partial counts:

1. Bucketize and read the true per-bucket histograms (``Buckets.counts``).
2. Coarse partitions whose buckets fit are *exact*: their partial counts are
   kept directly — no re-run, no wasted work.
3. Overflowed coarse partitions are split off: the rows they own are
   re-partitioned with a salted second-level hash (plus geometric capacity
   growth) and re-joined in the next round — only those shards re-run.
4. The final round sizes capacities from the exact residual histograms, so
   it cannot overflow and the loop terminates with ``overflowed == False``.

Exactness argument: every output triple contains exactly one R row (linear /
cyclic) or one S row (star), and that row lives in exactly one coarse
partition per round; partitions are disjointly split into "kept" and
"re-run", so each triple is counted exactly once across rounds.  A kept
partition only reads buckets that fit (for linear, T is pre-sized from its
exact histogram since it is shared by every H(B) partition), so kept partial
counts are exact.

The ``*_count_fused`` functions are single-pass and fully traceable (jit /
shard_map safe); ``MultiwayJoinEngine`` adds the host-side recovery loop.
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

from repro.core import cyclic3, linear3, partition, star3
from repro.core.relation import Relation
from repro.kernels import ops as kops


class EngineResult(NamedTuple):
    count: jnp.ndarray           # () int32 exact join cardinality
    overflowed: jnp.ndarray      # () bool — False after successful recovery
    tuples_read: jnp.ndarray     # () int32 tuples streamed, summed over rounds
    rounds: int                  # recovery rounds executed (1 = no skew)


class PerRResult(NamedTuple):
    keys: jnp.ndarray            # [N] int32 carried key column (flattened)
    counts: jnp.ndarray          # [N] int32 per-R-tuple counts
    valid: jnp.ndarray           # [N] bool
    overflowed: jnp.ndarray      # () bool
    rounds: int


def _align(n: int, align: int = 8) -> int:
    return max(align, int(math.ceil(n / align)) * align)


# ==========================================================================
# salted layouts (Fig 2 / Fig 3 data reorganization, re-randomizable)
# ==========================================================================

def linear3_layouts(r: Relation, s: Relation, t: Relation,
                    plan: linear3.Linear3Plan, *, salt: int = 0,
                    rb: str = "b", sb: str = "b", sc: str = "c",
                    tc: str = "c"):
    """R → [hp,u,cap], S → [hp,gp,u,cap], T → [gp,cap] (salted)."""
    hp, u, gp = plan.h_parts, plan.u, plan.g_parts
    r_ids, r_nb = partition.composite_ids(
        r, [(rb, hp, "H"), (rb, u, "h")], salt)
    rg = partition.bucketize_by_ids(r, r_ids, r_nb, plan.r_cap, (hp, u))
    s_ids, s_nb = partition.composite_ids(
        s, [(sb, hp, "H"), (sc, gp, "g"), (sb, u, "h")], salt)
    sg = partition.bucketize_by_ids(s, s_ids, s_nb, plan.s_cap, (hp, gp, u))
    tg = partition.bucketize(t, tc, gp, plan.t_cap, fn="g", salt=salt)
    return rg, sg, tg


def cyclic3_layouts(r: Relation, s: Relation, t: Relation,
                    plan: cyclic3.Cyclic3Plan, *, salt: int = 0,
                    ra: str = "a", rb: str = "b", sb: str = "b",
                    sc: str = "c", tc: str = "c", ta: str = "a"):
    """R → [hp,gp,uh,ug,cap], S → [gp,fp,ug,cap], T → [hp,fp,uh,cap]."""
    hp, gp, uh, ug, fp = (plan.h_parts, plan.g_parts, plan.uh, plan.ug,
                          plan.f_parts)
    r_ids, r_nb = partition.composite_ids(
        r, [(ra, hp, "H"), (rb, gp, "G"), (ra, uh, "h"), (rb, ug, "g")], salt)
    rg = partition.bucketize_by_ids(r, r_ids, r_nb, plan.r_cap,
                                    (hp, gp, uh, ug))
    s_ids, s_nb = partition.composite_ids(
        s, [(sb, gp, "G"), (sc, fp, "f"), (sb, ug, "g")], salt)
    sg = partition.bucketize_by_ids(s, s_ids, s_nb, plan.s_cap, (gp, fp, ug))
    t_ids, t_nb = partition.composite_ids(
        t, [(ta, hp, "H"), (tc, fp, "f"), (ta, uh, "h")], salt)
    tg = partition.bucketize_by_ids(t, t_ids, t_nb, plan.t_cap, (hp, fp, uh))
    return rg, sg, tg


def star3_layouts(r: Relation, s: Relation, t: Relation,
                  plan: star3.Star3Plan, *, salt: int = 0, rb: str = "b",
                  sb: str = "b", sc: str = "c", tc: str = "c"):
    """R → [uh,cap], S → [ch,uh,ug,cap], T → [ug,cap] (salted)."""
    uh, ug, ch = plan.uh, plan.ug, plan.chunks
    rg = partition.bucketize(r, rb, uh, plan.r_cap, fn="h", salt=salt)
    tg = partition.bucketize(t, tc, ug, plan.t_cap, fn="g", salt=salt)
    chunk_ids = jnp.where(
        s.valid,
        (jnp.arange(s.capacity, dtype=jnp.int32) * ch) // s.capacity, 0)
    hb = partition.bucket_ids_for(s, sb, uh, "h", salt)
    gc = partition.bucket_ids_for(s, sc, ug, "g", salt)
    flat = jnp.where(s.valid, (chunk_ids * uh + hb) * ug + gc,
                     jnp.int32(ch * uh * ug))
    sg = partition.bucketize_by_ids(s, flat, ch * uh * ug, plan.s_cap,
                                    (ch, uh, ug))
    return rg, sg, tg


# ==========================================================================
# single-pass fused counts (traceable: jit / shard_map safe)
# ==========================================================================

def linear3_count_fused(r: Relation, s: Relation, t: Relation,
                        plan: linear3.Linear3Plan, *,
                        use_kernel: bool = False, salt: int = 0,
                        rb: str = "b", sb: str = "b", sc: str = "c",
                        tc: str = "c") -> linear3.Linear3Result:
    """Algorithm 1 as ONE fused launch (overflow flagged, not recovered)."""
    rg, sg, tg = linear3_layouts(r, s, t, plan, salt=salt, rb=rb, sb=sb,
                                 sc=sc, tc=tc)
    c = kops.fused_count3_linear(rg.columns[rb], rg.valid, sg.columns[sb],
                                 sg.columns[sc], sg.valid, tg.columns[tc],
                                 tg.valid, use_kernel=use_kernel)
    overflow = rg.overflowed | sg.overflowed | tg.overflowed
    tuples = r.n + s.n + plan.h_parts * t.n
    return linear3.Linear3Result(jnp.sum(c), overflow,
                                 tuples.astype(jnp.int32))


def cyclic3_count_fused(r: Relation, s: Relation, t: Relation,
                        plan: cyclic3.Cyclic3Plan, *,
                        use_kernel: bool = False, salt: int = 0,
                        ra: str = "a", rb: str = "b", sb: str = "b",
                        sc: str = "c", tc: str = "c",
                        ta: str = "a") -> cyclic3.Cyclic3Result:
    """The §5 grid algorithm as ONE fused launch."""
    rg, sg, tg = cyclic3_layouts(r, s, t, plan, salt=salt, ra=ra, rb=rb,
                                 sb=sb, sc=sc, tc=tc, ta=ta)
    c = kops.fused_count3_cyclic(rg.columns[ra], rg.columns[rb], rg.valid,
                                 sg.columns[sb], sg.columns[sc], sg.valid,
                                 tg.columns[tc], tg.columns[ta], tg.valid,
                                 use_kernel=use_kernel)
    overflow = rg.overflowed | sg.overflowed | tg.overflowed
    tuples = r.n + plan.h_parts * s.n + plan.g_parts * t.n
    return cyclic3.Cyclic3Result(jnp.sum(c), overflow,
                                 tuples.astype(jnp.int32))


def star3_count_fused(r: Relation, s: Relation, t: Relation,
                      plan: star3.Star3Plan, *, use_kernel: bool = False,
                      salt: int = 0, rb: str = "b", sb: str = "b",
                      sc: str = "c", tc: str = "c") -> star3.Star3Result:
    """The §6.5 star join as ONE fused launch."""
    rg, sg, tg = star3_layouts(r, s, t, plan, salt=salt, rb=rb, sb=sb,
                               sc=sc, tc=tc)
    c = kops.fused_count3_star(rg.columns[rb], rg.valid, sg.columns[sb],
                               sg.columns[sc], sg.valid, tg.columns[tc],
                               tg.valid, use_kernel=use_kernel)
    overflow = rg.overflowed | sg.overflowed | tg.overflowed
    tuples = r.n + s.n + t.n
    return star3.Star3Result(jnp.sum(c), overflow, tuples.astype(jnp.int32))


# ==========================================================================
# the engine: fused sweeps + surgical skew recovery
# ==========================================================================

class MultiwayJoinEngine:
    """Executable multiway hash join with per-partition skew recovery.

    Parameters
    ----------
    kind:        "linear" | "cyclic" | "star" — which §4/§5/§6.5 plan.
    use_kernel:  dispatch the fused Pallas kernels (TPU) instead of the
                 fused jnp path (CPU/XLA).
    max_rounds:  recovery rounds before the exact-histogram final round.
    growth:      geometric per-round bucket-capacity growth for re-run
                 shards.

    ``count`` is host-side (it inspects overflow histograms between rounds);
    use the module-level ``*_count_fused`` functions inside jit/shard_map.
    """

    KINDS = ("linear", "cyclic", "star")

    def __init__(self, kind: str = "linear", *, use_kernel: bool = False,
                 max_rounds: int = 3, growth: float = 2.0):
        if kind not in self.KINDS:
            raise ValueError(f"unknown kind {kind!r}; choose from {self.KINDS}")
        self.kind = kind
        self.use_kernel = use_kernel
        self.max_rounds = max_rounds
        self.growth = growth

    # -- planning ----------------------------------------------------------

    def default_plan(self, n_r: int, n_s: int, n_t: int, *, m_budget: int,
                     **kw):
        if self.kind == "linear":
            return linear3.default_plan(n_r, n_s, n_t, m_budget=m_budget,
                                        **kw)
        if self.kind == "cyclic":
            return cyclic3.default_plan(n_r, n_s, n_t, m_budget=m_budget,
                                        **kw)
        return star3.default_plan(n_r, n_s, n_t, **kw)

    # -- execution ---------------------------------------------------------

    def count(self, r: Relation, s: Relation, t: Relation, plan=None, *,
              m_budget: int | None = None, **cols) -> EngineResult:
        if plan is None:
            if m_budget is None:
                raise ValueError("pass a plan or m_budget")
            plan = self.default_plan(int(r.n), int(s.n), int(t.n),
                                     m_budget=m_budget)
        if self.kind == "linear":
            return self._linear_count(r, s, t, plan, **cols)
        if self.kind == "cyclic":
            return self._cyclic_count(r, s, t, plan, **cols)
        return self._star_count(r, s, t, plan, **cols)

    def _grown(self, plan):
        # lazy import: driver imports engine at module load
        from repro.core import driver
        return driver._grown(plan, self.growth)

    # -- linear ------------------------------------------------------------

    def _linear_count(self, r, s, t, plan, *, rb="b", sb="b", sc="c",
                      tc="c") -> EngineResult:
        total, tuples = 0, 0
        for rnd in range(self.max_rounds + 1):
            final = rnd == self.max_rounds
            hp, u, gp = plan.h_parts, plan.u, plan.g_parts
            # T is shared by every H(B) partition: size it from its exact
            # g(C) histogram so T overflow (unrecoverable by H-splitting)
            # cannot occur.
            t_ids = partition.bucket_ids_for(t, tc, gp, "g", rnd)
            t_hist = np.bincount(np.asarray(t_ids), minlength=gp + 1)[:gp]
            t_cap = _align(max(int(t_hist.max(initial=0)), 1))
            plan = plan._replace(t_cap=max(plan.t_cap, t_cap))
            if final:
                # exact-histogram sizing: this round cannot overflow
                r_ids, r_nb = partition.composite_ids(
                    r, [(rb, hp, "H"), (rb, u, "h")], rnd)
                s_ids, s_nb = partition.composite_ids(
                    s, [(sb, hp, "H"), (sc, gp, "g"), (sb, u, "h")], rnd)
                r_hist = np.bincount(np.asarray(r_ids),
                                     minlength=r_nb + 1)[:r_nb]
                s_hist = np.bincount(np.asarray(s_ids),
                                     minlength=s_nb + 1)[:s_nb]
                plan = plan._replace(
                    r_cap=_align(max(int(r_hist.max(initial=0)), 1)),
                    s_cap=_align(max(int(s_hist.max(initial=0)), 1)))
            rg, sg, tg = linear3_layouts(r, s, t, plan, salt=rnd, rb=rb,
                                         sb=sb, sc=sc, tc=tc)
            counts = kops.fused_count3_linear(
                rg.columns[rb], rg.valid, sg.columns[sb], sg.columns[sc],
                sg.valid, tg.columns[tc], tg.valid,
                use_kernel=self.use_kernel)                       # [hp, u]
            bad = (np.asarray(rg.counts > plan.r_cap).any(axis=1)
                   | np.asarray(sg.counts > plan.s_cap).any(axis=(1, 2)))
            tuples += int(r.n) + int(s.n) + hp * int(t.n)
            if final or not bad.any():
                total += int(jnp.sum(counts))
                return EngineResult(jnp.int32(total), jnp.asarray(False),
                                    jnp.int32(tuples), rnd + 1)
            # keep exact partitions, split off the skewed ones
            good = jnp.asarray(~bad)
            total += int(jnp.sum(jnp.where(good[:, None], counts, 0)))
            bad_j = jnp.asarray(bad)
            r_h = partition.bucket_ids_for(r, rb, hp, "H", rnd)
            s_h = partition.bucket_ids_for(s, sb, hp, "H", rnd)
            r = r.mask_where(bad_j[jnp.clip(r_h, 0, hp - 1)])
            s = s.mask_where(bad_j[jnp.clip(s_h, 0, hp - 1)])
            plan = self._grown(plan)
        raise AssertionError("unreachable: final round is exact-sized")

    # -- cyclic ------------------------------------------------------------

    def _cyclic_count(self, r, s, t, plan, *, ra="a", rb="b", sb="b",
                      sc="c", tc="c", ta="a") -> EngineResult:
        total, tuples = 0, 0
        for rnd in range(self.max_rounds + 1):
            final = rnd == self.max_rounds
            hp, gp = plan.h_parts, plan.g_parts
            if final:
                r_ids, r_nb = partition.composite_ids(
                    r, [(ra, hp, "H"), (rb, gp, "G"), (ra, plan.uh, "h"),
                        (rb, plan.ug, "g")], rnd)
                s_ids, s_nb = partition.composite_ids(
                    s, [(sb, gp, "G"), (sc, plan.f_parts, "f"),
                        (sb, plan.ug, "g")], rnd)
                t_ids, t_nb = partition.composite_ids(
                    t, [(ta, hp, "H"), (tc, plan.f_parts, "f"),
                        (ta, plan.uh, "h")], rnd)
                caps = []
                for ids, nb in ((r_ids, r_nb), (s_ids, s_nb), (t_ids, t_nb)):
                    hist = np.bincount(np.asarray(ids), minlength=nb + 1)[:nb]
                    caps.append(_align(max(int(hist.max(initial=0)), 1)))
                plan = plan._replace(r_cap=caps[0], s_cap=caps[1],
                                     t_cap=caps[2])
            rg, sg, tg = cyclic3_layouts(r, s, t, plan, salt=rnd, ra=ra,
                                         rb=rb, sb=sb, sc=sc, tc=tc, ta=ta)
            counts = kops.fused_count3_cyclic(
                rg.columns[ra], rg.columns[rb], rg.valid, sg.columns[sb],
                sg.columns[sc], sg.valid, tg.columns[tc], tg.columns[ta],
                tg.valid, use_kernel=self.use_kernel)    # [hp, gp, uh, ug]
            r_bad = np.asarray(rg.counts > plan.r_cap).any(axis=(2, 3))
            s_bad = np.asarray(sg.counts > plan.s_cap).any(axis=(1, 2))
            t_bad = np.asarray(tg.counts > plan.t_cap).any(axis=(1, 2))
            # a cell is tainted if its R buckets, its S column partition, or
            # its T row partition overflowed anywhere
            bad = r_bad | s_bad[None, :] | t_bad[:, None]      # [hp, gp]
            tuples += int(r.n) + hp * int(s.n) + gp * int(t.n)
            if final or not bad.any():
                total += int(jnp.sum(counts))
                return EngineResult(jnp.int32(total), jnp.asarray(False),
                                    jnp.int32(tuples), rnd + 1)
            good = jnp.asarray(~bad)
            total += int(jnp.sum(
                jnp.where(good[:, :, None, None], counts, 0)))
            # the residual is defined by R rows (each triple has exactly one)
            bad_j = jnp.asarray(bad)
            r_hid = partition.bucket_ids_for(r, ra, hp, "H", rnd)
            r_gid = partition.bucket_ids_for(r, rb, gp, "G", rnd)
            cell_bad = bad_j[jnp.clip(r_hid, 0, hp - 1),
                             jnp.clip(r_gid, 0, gp - 1)]
            r = r.mask_where(cell_bad)
            plan = self._grown(plan)
        raise AssertionError("unreachable: final round is exact-sized")

    # -- star --------------------------------------------------------------

    def _star_count(self, r, s, t, plan, *, rb="b", sb="b", sc="c",
                    tc="c") -> EngineResult:
        total, tuples = 0, 0
        for rnd in range(self.max_rounds + 1):
            final = rnd == self.max_rounds
            uh, ug, ch = plan.uh, plan.ug, plan.chunks
            if final:
                r_ids = partition.bucket_ids_for(r, rb, uh, "h", rnd)
                t_ids = partition.bucket_ids_for(t, tc, ug, "g", rnd)
                r_hist = np.bincount(np.asarray(r_ids), minlength=uh + 1)[:uh]
                t_hist = np.bincount(np.asarray(t_ids), minlength=ug + 1)[:ug]
                chunk_ids = jnp.where(
                    s.valid,
                    (jnp.arange(s.capacity, dtype=jnp.int32) * ch)
                    // s.capacity, 0)
                s_hb = partition.bucket_ids_for(s, sb, uh, "h", rnd)
                s_gc = partition.bucket_ids_for(s, sc, ug, "g", rnd)
                s_nb = ch * uh * ug
                s_flat = jnp.where(s.valid,
                                   (chunk_ids * uh + s_hb) * ug + s_gc,
                                   jnp.int32(s_nb))
                s_hist = np.bincount(np.asarray(s_flat),
                                     minlength=s_nb + 1)[:s_nb]
                plan = plan._replace(
                    r_cap=_align(max(int(r_hist.max(initial=0)), 1)),
                    t_cap=_align(max(int(t_hist.max(initial=0)), 1)),
                    s_cap=_align(max(int(s_hist.max(initial=0)), 1)))
            rg, sg, tg = star3_layouts(r, s, t, plan, salt=rnd, rb=rb,
                                       sb=sb, sc=sc, tc=tc)
            counts = kops.fused_count3_star(
                rg.columns[rb], rg.valid, sg.columns[sb], sg.columns[sc],
                sg.valid, tg.columns[tc], tg.valid,
                use_kernel=self.use_kernel)                      # [uh, ug]
            r_bad = np.asarray(rg.counts > plan.r_cap)           # [uh]
            t_bad = np.asarray(tg.counts > plan.t_cap)           # [ug]
            s_bad = np.asarray(sg.counts > plan.s_cap).any(axis=0)  # [uh,ug]
            bad = r_bad[:, None] | t_bad[None, :] | s_bad
            tuples += int(r.n) + int(s.n) + int(t.n)
            if final or not bad.any():
                total += int(jnp.sum(counts))
                return EngineResult(jnp.int32(total), jnp.asarray(False),
                                    jnp.int32(tuples), rnd + 1)
            good = jnp.asarray(~bad)
            total += int(jnp.sum(jnp.where(good, counts, 0)))
            # the residual is defined by S rows (each triple has exactly one)
            bad_j = jnp.asarray(bad)
            s_hid = partition.bucket_ids_for(s, sb, uh, "h", rnd)
            s_gid = partition.bucket_ids_for(s, sc, ug, "g", rnd)
            cell_bad = bad_j[jnp.clip(s_hid, 0, uh - 1),
                             jnp.clip(s_gid, 0, ug - 1)]
            s = s.mask_where(cell_bad)
            plan = self._grown(plan)
        raise AssertionError("unreachable: final round is exact-sized")

    # -- per-R aggregates (linear only) ------------------------------------

    def per_r_counts(self, r: Relation, s: Relation, t: Relation, plan, *,
                     rb: str = "b", sb: str = "b", sc: str = "c",
                     tc: str = "c", key_col: str = "a") -> PerRResult:
        """Per-R-tuple counts (Example 1) with skew recovery.  Returns
        flattened (keys, counts, valid) concatenated across rounds."""
        if self.kind != "linear":
            raise ValueError("per_r_counts is a linear-join aggregate")
        keys_out, counts_out, valid_out = [], [], []
        rounds = 0
        for rnd in range(self.max_rounds + 1):
            final = rnd == self.max_rounds
            hp, u, gp = plan.h_parts, plan.u, plan.g_parts
            t_ids = partition.bucket_ids_for(t, tc, gp, "g", rnd)
            t_hist = np.bincount(np.asarray(t_ids), minlength=gp + 1)[:gp]
            plan = plan._replace(t_cap=max(
                plan.t_cap, _align(max(int(t_hist.max(initial=0)), 1))))
            if final:
                r_ids, r_nb = partition.composite_ids(
                    r, [(rb, hp, "H"), (rb, u, "h")], rnd)
                s_ids, s_nb = partition.composite_ids(
                    s, [(sb, hp, "H"), (sc, gp, "g"), (sb, u, "h")], rnd)
                r_hist = np.bincount(np.asarray(r_ids),
                                     minlength=r_nb + 1)[:r_nb]
                s_hist = np.bincount(np.asarray(s_ids),
                                     minlength=s_nb + 1)[:s_nb]
                plan = plan._replace(
                    r_cap=_align(max(int(r_hist.max(initial=0)), 1)),
                    s_cap=_align(max(int(s_hist.max(initial=0)), 1)))
            rg, sg, tg = linear3_layouts(r, s, t, plan, salt=rnd, rb=rb,
                                         sb=sb, sc=sc, tc=tc)
            counts = kops.fused_per_r_counts(
                rg.columns[rb], rg.valid, sg.columns[sb], sg.columns[sc],
                sg.valid, tg.columns[tc], tg.valid,
                use_kernel=self.use_kernel)                   # [hp, u, Cr]
            bad = (np.asarray(rg.counts > plan.r_cap).any(axis=1)
                   | np.asarray(sg.counts > plan.s_cap).any(axis=(1, 2)))
            key = key_col if key_col in rg.columns else rb
            keep = jnp.asarray(~bad) if bad.any() else None
            valid = rg.valid
            if keep is not None and not final:
                valid = valid & keep[:, None, None]
            keys_out.append(rg.columns[key].reshape(-1))
            counts_out.append(counts.reshape(-1))
            valid_out.append(valid.reshape(-1))
            rounds = rnd + 1
            if final or not bad.any():
                break
            bad_j = jnp.asarray(bad)
            r_h = partition.bucket_ids_for(r, rb, hp, "H", rnd)
            s_h = partition.bucket_ids_for(s, sb, hp, "H", rnd)
            r = r.mask_where(bad_j[jnp.clip(r_h, 0, hp - 1)])
            s = s.mask_where(bad_j[jnp.clip(s_h, 0, hp - 1)])
            plan = self._grown(plan)
        return PerRResult(jnp.concatenate(keys_out),
                          jnp.concatenate(counts_out),
                          jnp.concatenate(valid_out),
                          jnp.asarray(False), rounds)
