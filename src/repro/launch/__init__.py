"""Launchers: production mesh, multi-pod dry-run, training and serving
drivers.  ``dryrun.py`` must be run as a module entry point (it sets
XLA_FLAGS before importing jax); nothing here imports it."""
