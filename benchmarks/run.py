"""Benchmark harness entry point: one module per paper table/figure
(Fig 4 a-i), plus measured real-execution joins and the roofline
aggregation over dry-run artifacts.

    PYTHONPATH=src python -m benchmarks.run [--skip-measured]

Emits artifacts/bench/*.csv and a claim-validation summary; exits nonzero
if any validated paper claim fails.
"""

from __future__ import annotations

import argparse
import json
import sys
import time


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--skip-measured", action="store_true",
                    help="skip the real-execution joins (slow on CPU)")
    args = ap.parse_args(argv)

    from benchmarks import (fig4ab, fig4c, fig4d, fig4ef, fig4ghi,
                            measured_joins, roofline)

    results: dict = {}
    t0 = time.time()
    fig4ab.main(results)
    fig4c.main(results)
    fig4d.main(results)
    fig4ef.main(results)
    fig4ghi.main(results)
    if not args.skip_measured:
        measured_joins.main(results)
    roofline.main(results)

    n_ok = sum(1 for v in results.values() if v["ok"])
    print(f"\n=== benchmark claims: {n_ok}/{len(results)} validated "
          f"({time.time() - t0:.1f}s) ===")
    for name, v in results.items():
        print(f"  [{'PASS' if v['ok'] else 'FAIL'}] {name}")
    from benchmarks.common import OUTDIR
    OUTDIR.mkdir(parents=True, exist_ok=True)
    (OUTDIR / "claims.json").write_text(json.dumps(results, indent=2))
    return 0 if n_ok == len(results) else 1


if __name__ == "__main__":
    sys.exit(main())
