"""N-way plan IR: decomposer, DAG execution, cache drift, satellites.

Covers the multi-step front door: 4+-relation acyclic queries decompose
into binary materialize steps feeding a fused (recovery-wrapped) 3-way
root and match a brute-force oracle exactly — including under adversarial
skew; 3-relation queries keep their single-step fused plans and cache
behavior; 2-relation queries execute as one exact binary step; the plan
cache survives ±5% data drift (log-bucketed cardinality keys) but not a
4x resize; ``execute_many`` amortizes planning over the cache; and the
legacy ``core.driver`` shims are fully retired.
"""

from collections import defaultdict

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from conftest import make_rel, skewed_keys
from repro.core import plan_ir, planner
from repro.core.query import Query, QueryGraphError
from repro.core.relation import Relation
from repro.core.session import JoinSession


# --------------------------------------------------------------------------
# oracles
# --------------------------------------------------------------------------

def oracle_nway(columns, predicates):
    """Brute-force N-way join count: successive hash-join materialization
    with python dicts (rows = lists of (relation, row-index) bindings).
    ``columns``: name -> dict[col -> np.ndarray]; ``predicates``: list of
    ((rel, col), (rel, col)) equality pairs."""
    preds = [(tuple(left), tuple(right)) for left, right in predicates]
    joined = {preds[0][0][0]}
    n0 = len(next(iter(columns[preds[0][0][0]].values())))
    rows = [{preds[0][0][0]: i} for i in range(n0)]
    pending = list(preds)
    while pending:
        for p in pending:
            (lr, lc), (rr, rc) = p
            if (lr in joined) != (rr in joined):
                break
        else:
            raise AssertionError("disconnected predicate set")
        pending.remove(p)
        if lr in joined:
            (old_r, old_c), (new_r, new_c) = (lr, lc), (rr, rc)
        else:
            (old_r, old_c), (new_r, new_c) = (rr, rc), (lr, lc)
        if new_r in joined:        # both sides already joined: filter
            rows = [bind for bind in rows
                    if columns[old_r][old_c][bind[old_r]]
                    == columns[new_r][new_c][bind[new_r]]]
            continue
        by_val = defaultdict(list)
        for j, v in enumerate(columns[new_r][new_c].tolist()):
            by_val[v].append(j)
        out = []
        for bind in rows:
            v = int(columns[old_r][old_c][bind[old_r]])
            for j in by_val.get(v, ()):
                out.append({**bind, new_r: j})
        rows = out
        joined.add(new_r)
    return len(rows)


def _chain_query(rels):
    """r1.b=r2.b, r2.c=r3.c, ... over relations with columns (a, b),
    (b, c), (c, d), ..."""
    names = [f"r{i + 1}" for i in range(len(rels))]
    cols = "abcdefgh"
    preds = [(f"{names[i]}.{cols[i + 1]}", f"{names[i + 1]}.{cols[i + 1]}")
             for i in range(len(rels) - 1)]
    return Query(dict(zip(names, rels)), preds)


def _chain_oracle(rels, cols="abcdefgh"):
    """Exact chain count via weight backflow (independent of the IR)."""
    w = np.ones(int(rels[-1].capacity), np.int64)
    w[~np.asarray(rels[-1].valid)] = 0
    for i in range(len(rels) - 1, 0, -1):
        key = cols[i]
        cnt = defaultdict(int)
        right = np.asarray(rels[i].col(key)).tolist()
        for k, wv, ok in zip(right, w.tolist(),
                             np.asarray(rels[i].valid).tolist()):
            if ok:
                cnt[k] += wv
        left = np.asarray(rels[i - 1].col(key)).tolist()
        w = np.array([cnt.get(k, 0) for k in left], np.int64)
        w[~np.asarray(rels[i - 1].valid)] = 0
    return int(w.sum())


# --------------------------------------------------------------------------
# tentpole: 4+-relation queries end-to-end
# --------------------------------------------------------------------------

def test_4way_chain_executes_with_fused_root(rng):
    """Acceptance: a 4-relation acyclic Query runs end-to-end (no
    QueryGraphError), its plan has >= 2 steps with a fused 3-way step,
    the count matches the oracle and overflowed is False."""
    rels = [make_rel(rng, 1500, (c1, c2), 300)[0]
            for c1, c2 in (("a", "b"), ("b", "c"), ("c", "d"), ("d", "e"))]
    q = _chain_query(rels)
    res = JoinSession(m_budget=256).execute(q)
    assert int(res.count) == _chain_oracle(rels)
    assert not res.overflowed
    assert len(res.plan.steps) >= 2
    assert len(res.plan.fused3_steps) >= 1
    assert res.plan.fused3_steps[0].recovery
    assert res.strategy == "hybrid"
    assert res.plan.root.out == plan_ir.COUNT
    # per-step stats aggregate onto the result
    assert sum(s.tuples_read for s in res.step_stats) == int(res.tuples_read)
    assert sum(s.rounds for s in res.step_stats) == int(res.rounds)


def test_5way_star_schema_fact_plus_dims(rng):
    """The README example shape: one fact table, 4 dimension tables, all
    predicates fact-to-dim (a degree-4 star graph)."""
    fact, _ = make_rel(rng, 6000, ("k1", "k2", "k3", "k4"), 150)
    dims = [make_rel(rng, 300, (f"k{i + 1}", "x"), 150)[0]
            for i in range(4)]
    names = {"fact": fact, **{f"d{i + 1}": dims[i] for i in range(4)}}
    q = Query(names, [(f"fact.k{i + 1}", f"d{i + 1}.k{i + 1}")
                      for i in range(4)])
    res = JoinSession(m_budget=256).execute(q)
    # oracle: per-fact-row product of dimension match counts
    want = np.ones(6000, np.int64)
    for i in range(4):
        cnt = defaultdict(int)
        for v in np.asarray(dims[i].col(f"k{i + 1}")).tolist():
            cnt[v] += 1
        want *= np.array([cnt.get(v, 0) for v in
                          np.asarray(fact.col(f"k{i + 1}")).tolist()],
                         np.int64)
    assert int(res.count) == int(want.sum())
    assert not res.overflowed
    assert len(res.plan.steps) >= 2
    assert len(res.plan.fused3_steps) >= 1


def test_4way_skewed_recovery_exact(rng):
    """Adversarial heavy hitters in the ROOT join columns: the fused root
    step must recover (overflowed == False postcondition) and the count
    must stay exact."""
    n = 400
    r1 = Relation.from_arrays(a=rng.integers(0, 99, n).astype(np.int32),
                              b=skewed_keys(rng, n, 30, 0.4))
    r2 = Relation.from_arrays(b=skewed_keys(rng, n, 30, 0.4),
                              c=skewed_keys(rng, n, 30, 0.4, 2))
    r3 = Relation.from_arrays(c=skewed_keys(rng, n, 30, 0.4, 2),
                              d=rng.integers(0, 25, n).astype(np.int32))
    r4 = Relation.from_arrays(d=rng.integers(0, 25, n).astype(np.int32),
                              e=rng.integers(0, 99, n).astype(np.int32))
    rels = [r1, r2, r3, r4]
    q = _chain_query(rels)
    res = JoinSession(m_budget=64).execute(q, strategy="3way")
    assert int(res.count) == _chain_oracle(rels)
    assert not res.overflowed
    assert len(res.plan.fused3_steps) == 1


def test_2way_query_single_binary_step(rng):
    r, rd = make_rel(rng, 200, ("a", "b"), 25)
    s, sd = make_rel(rng, 240, ("b", "c"), 25)
    q = Query({"r": r, "s": s}, [("r.b", "s.b")])
    res = JoinSession().execute(q)
    cnt = defaultdict(int)
    for v in sd["b"].tolist():
        cnt[v] += 1
    want = sum(cnt.get(v, 0) for v in rd["b"].tolist())
    assert int(res.count) == want
    assert len(res.plan.steps) == 1 and res.strategy == "cascade"
    with pytest.raises(ValueError, match="3-way"):
        JoinSession().execute(q, strategy="3way")


def test_3rel_queries_keep_single_step_fused_plans(rng):
    """Acceptance: existing 3-relation queries still take the single-step
    fused path, with plan-cache hits intact."""
    r, _ = make_rel(rng, 2000, ("a", "b"), 300)
    s, _ = make_rel(rng, 2000, ("b", "c"), 300)
    t, _ = make_rel(rng, 2000, ("c", "d"), 300)
    sess = JoinSession(m_budget=256)
    q = Query({"r": r, "s": s, "t": t}, [("r.b", "s.b"), ("s.c", "t.c")])
    cold = sess.execute(q)
    assert cold.strategy == "3way" and len(cold.plan.steps) == 1
    assert cold.plan.steps[0].op == "fused3"
    assert cold.plan.steps[0].shape_plan is not None   # plan-time sized
    warm = sess.execute(q)
    assert warm.cache_hit and int(warm.count) == int(cold.count)


def test_3rel_cascade_runs_through_ir(rng):
    """The time model picks the cascade at small sizes; it must now
    execute as a 2-step IR plan (the EnginePlan.run ad-hoc branch is
    retired) and still match the fused count."""
    r, _ = make_rel(rng, 120, ("a", "b"), 20)
    s, _ = make_rel(rng, 130, ("b", "c"), 20)
    t, _ = make_rel(rng, 110, ("c", "d"), 20)
    q = Query({"r": r, "s": s, "t": t}, [("r.b", "s.b"), ("s.c", "t.c")])
    sess = JoinSession(m_budget=64)
    res = sess.execute(q, strategy="cascade")
    assert res.strategy == "cascade"
    assert [st.op for st in res.plan.steps] == ["binary", "binary"]
    fused = sess.execute(q, strategy="3way")
    assert int(res.count) == int(fused.count)
    # the legacy EnginePlan.run cascade delegates to the same executor
    ep = planner.plan_step("linear", 120, 130, 110, 20, m_budget=64)
    assert int(ep.run(r, s, t).count) == int(res.count)


def test_nway_cyclic_rejected_with_pointer(rng):
    r, _ = make_rel(rng, 50, ("a", "b"), 10)
    s, _ = make_rel(rng, 50, ("b", "c"), 10)
    t, _ = make_rel(rng, 50, ("c", "d"), 10)
    u, _ = make_rel(rng, 50, ("d", "a"), 10)
    q = Query({"r": r, "s": s, "t": t, "u": u},
              [("r.b", "s.b"), ("s.c", "t.c"), ("t.d", "u.d"),
               ("u.a", "r.a")])
    with pytest.raises(QueryGraphError, match="tree"):
        JoinSession(m_budget=64).execute(q)
    # the 3-relation classifier points 4+-relation users at the N-way API
    with pytest.raises(QueryGraphError, match="JoinSession"):
        q.classify()


def test_nway_disconnected_rejected(rng):
    r, _ = make_rel(rng, 50, ("a", "b"), 10)
    s, _ = make_rel(rng, 50, ("b", "c"), 10)
    t, _ = make_rel(rng, 50, ("c", "d"), 10)
    u, _ = make_rel(rng, 50, ("x", "y"), 10)
    v, _ = make_rel(rng, 50, ("y", "z"), 10)
    q = Query({"r": r, "s": s, "t": t, "u": u, "v": v},
              [("r.b", "s.b"), ("s.c", "t.c"), ("u.y", "v.y")])
    with pytest.raises(QueryGraphError, match="disconnected"):
        JoinSession(m_budget=64).execute(q)


# --------------------------------------------------------------------------
# hypothesis: random acyclic 4-6 relation trees vs the brute-force oracle
# --------------------------------------------------------------------------

@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n_rel=st.integers(4, 6),
       skew=st.booleans())
def test_random_tree_queries_match_oracle(seed, n_rel, skew):
    """Property: for random acyclic join trees over 4-6 relations (uniform
    AND heavy-hitter data), JoinSession.execute == brute force."""
    rng = np.random.default_rng(seed)
    d = 12
    parents = [int(rng.integers(0, i)) for i in range(1, n_rel)]
    names = [f"q{i}" for i in range(n_rel)]
    # relation i gets one column per incident tree edge (+ a payload)
    cols = {nm: {} for nm in names}
    preds = []
    for i, p in enumerate(parents, start=1):
        col = f"j{i}"
        n_child = int(rng.integers(20, 36))
        n_parent = len(next(iter(cols[names[p]].values()))) \
            if cols[names[p]] else int(rng.integers(20, 36))

        def keys(n):
            if skew:
                return skewed_keys(rng, n, d, 0.3)
            return rng.integers(0, d, n).astype(np.int32)

        cols[names[i]][col] = keys(n_child)
        cols[names[p]][col] = keys(n_parent)
        preds.append((f"{names[p]}.{col}", f"{names[i]}.{col}"))
    for nm in names:   # pad relations that ended up with one column
        n = len(next(iter(cols[nm].values())))
        for other in cols[nm].values():
            assert len(other) == n
        cols[nm]["pay"] = rng.integers(0, 5, n).astype(np.int32)
    rels = {nm: Relation.from_arrays(**cs) for nm, cs in cols.items()}
    q = Query(rels, preds)
    want = oracle_nway(
        cols, [(tuple(left.split(".")), tuple(right.split(".")))
               for left, right in preds])
    sess = JoinSession(m_budget=64)
    forced = sess.execute(q, strategy="3way")
    assert int(forced.count) == want
    assert not forced.overflowed
    assert len(forced.plan.fused3_steps) == 1
    default = sess.execute(q)
    assert int(default.count) == want
    assert not default.overflowed


def test_shared_join_column_across_edges(rng):
    """One column feeding two tree edges (r2.b joins r1.b AND r3.b): the
    projection/origin bookkeeping must carry it through intermediates."""
    r1, _ = make_rel(rng, 40, ("a", "b"), 8)
    r2 = Relation.from_arrays(b=rng.integers(0, 8, 40).astype(np.int32))
    r3, _ = make_rel(rng, 40, ("b", "c"), 8)
    r4, _ = make_rel(rng, 40, ("c", "e"), 8)
    q = Query({"r1": r1, "r2": r2, "r3": r3, "r4": r4},
              [("r1.b", "r2.b"), ("r2.b", "r3.b"), ("r3.c", "r4.c")])
    cols = {nm: {k: np.asarray(v) for k, v in rel.columns.items()}
            for nm, rel in q.relations.items()}
    want = oracle_nway(cols, [(("r1", "b"), ("r2", "b")),
                              (("r2", "b"), ("r3", "b")),
                              (("r3", "c"), ("r4", "c"))])
    for strat in (None, "3way", "cascade"):
        res = JoinSession(m_budget=64).execute(q, strategy=strat)
        assert int(res.count) == want and not res.overflowed


def test_nway_self_join_aliases(rng):
    """friends^4: one Relation under four aliases, a 4-chain."""
    f, _ = make_rel(rng, 60, ("src", "dst"), 12)
    q = Query({f"f{i}": f for i in (1, 2, 3, 4)},
              [("f1.dst", "f2.src"), ("f2.dst", "f3.src"),
               ("f3.dst", "f4.src")])
    cols = {f"f{i}": {k: np.asarray(v) for k, v in f.columns.items()}
            for i in (1, 2, 3, 4)}
    want = oracle_nway(cols, [(("f1", "dst"), ("f2", "src")),
                              (("f2", "dst"), ("f3", "src")),
                              (("f3", "dst"), ("f4", "src"))])
    for strat in (None, "3way", "cascade"):
        res = JoinSession(m_budget=64).execute(q, strategy=strat)
        assert int(res.count) == want and not res.overflowed


# --------------------------------------------------------------------------
# satellites: cache drift, execute_many, deprecation stacklevel
# --------------------------------------------------------------------------

def test_plan_cache_survives_small_drift_not_resize(rng):
    """±5% cardinality drift hits the log-bucketed cache; 4x misses."""
    def build(n):
        r, _ = make_rel(rng, n, ("a", "b"), 50)
        s, _ = make_rel(rng, n, ("b", "c"), 50)
        t, _ = make_rel(rng, n, ("c", "d"), 50)
        return Query({"r": r, "s": s, "t": t},
                     [("r.b", "s.b"), ("s.c", "t.c")])
    sess = JoinSession(m_budget=64)
    cold = sess.execute(build(1000))
    assert not cold.cache_hit
    drifted = sess.execute(build(1050))       # +5%: same log2 bucket
    assert drifted.cache_hit
    assert not drifted.overflowed             # stale sizing is recovered
    shrunk = sess.execute(build(953))         # -5%: same bucket
    assert shrunk.cache_hit
    resized = sess.execute(build(4000))       # 4x: always >= 2 buckets away
    assert not resized.cache_hit
    # counts stay exact regardless of hit/miss
    q = build(1050)
    hit = sess.execute(q)
    sess2 = JoinSession(m_budget=64)
    fresh = sess2.execute(q)
    assert hit.cache_hit and not fresh.cache_hit
    assert int(hit.count) == int(fresh.count)


def test_execute_many_amortizes_planning(rng):
    """Batched execution: one decomposition, K-1 cache hits, all exact."""
    rels = [make_rel(rng, 900, (c1, c2), 60)[0]
            for c1, c2 in (("a", "b"), ("b", "c"), ("c", "d"), ("d", "e"))]
    queries = [_chain_query(rels) for _ in range(5)]
    sess = JoinSession(m_budget=128)
    results = sess.execute_many(queries)
    assert len(results) == 5
    assert not results[0].cache_hit
    assert all(r.cache_hit for r in results[1:])
    want = _chain_oracle(rels)
    assert all(int(r.count) == want for r in results)
    assert sess.cache_info["misses"] == 1
    assert sess.cache_info["hits"] == 4


def test_driver_shims_fully_retired():
    """The deprecation cycle ended this release: core.driver is deleted
    (not merely warning), and nothing in the package still imports it —
    the scan baselines moved to core.reference."""
    with pytest.raises(ImportError):
        import repro.core.driver  # noqa: F401
    import repro.core as core
    assert not hasattr(core, "driver")
    assert hasattr(core, "reference")


def test_card_bucket_properties():
    from repro.core import sketches
    assert sketches.card_bucket(1000) == sketches.card_bucket(1050)
    assert sketches.card_bucket(1000) == sketches.card_bucket(953)
    assert abs(sketches.card_bucket(4000) - sketches.card_bucket(1000)) >= 2
    assert sketches.card_bucket(0) == -1


def test_plan_describe_is_stable(rng):
    rels = [make_rel(rng, 100, (c1, c2), 10)[0]
            for c1, c2 in (("a", "b"), ("b", "c"), ("c", "d"), ("d", "e"))]
    qp = planner.plan_query(_chain_query(rels), m_budget=64,
                            strategy="3way")
    text = qp.describe()
    assert "fused3" in text and "%count" in text and "%i0" in text
