"""Flajolet–Martin / PCSA distinct-count sketches (paper Example 1).

The paper's headline query (count of friends-of-friends-of-friends per user)
cannot materialize its output; it folds an FM sketch on the fly and unions
sketches across workers.  Union is an elementwise bitwise OR of register
bitmaps — associative and commutative, so sketches combine across PMUs,
chips and pods with plain reductions.

Faithful FM/PCSA: K register bitmaps; each key sets bit ρ(hash_k(key))-1 in
bitmap k, where ρ is the position of the lowest set bit of the hash.
Estimate = 2^(mean_k R_k) / φ with R_k = index of the lowest ZERO bit of
bitmap k and φ ≈ 0.77351 (Flajolet–Martin 1985).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.core import hashing

PHI = 0.77351


def card_bucket(n: int, *, per_octave: int = 1) -> int:
    """Log-bucketed cardinality estimate for plan-cache keys.

    Plans are estimate-sized and recovery-correct, so the session cache
    keys on the *scale* of each relation rather than its exact row count:
    ``round(log2(n) * per_octave)``.  Small data drift (a ±5% refresh of
    a served relation, away from a bucket boundary) maps to the same
    bucket and HITS; a 4x resize always moves ≥ ``2 * per_octave``
    buckets and re-plans.  This is the cheap stand-in for keying on an
    FM-sketch cardinality estimate (same idea: a coarse, drift-stable
    summary instead of the exact count).
    """
    n = int(n)
    if n <= 0:
        return -1
    return int(round(math.log2(n) * per_octave))


def empty(n_registers: int = 32) -> jnp.ndarray:
    """Zeroed register bitmaps, one int32 per register."""
    return jnp.zeros((n_registers,), jnp.int32)


def key_bits(keys: jnp.ndarray, reg: int) -> jnp.ndarray:
    """The bitmap contribution 1 << (ρ(hash_reg(key)) - 1) per key."""
    rho = hashing.hash_trailing_zeros(keys, reg)   # in [1, 33]
    shift = jnp.minimum(rho - 1, 31).astype(jnp.uint32)
    return (jnp.uint32(1) << shift).astype(jnp.int32)


def add(registers: jnp.ndarray, keys: jnp.ndarray,
        valid: jnp.ndarray) -> jnp.ndarray:
    """Fold a batch of keys into the sketch."""
    k = registers.shape[0]
    regs = []
    for i in range(k):
        bits = jnp.where(valid, key_bits(keys, i), 0)
        regs.append(jax.lax.reduce(bits, jnp.int32(0), jax.lax.bitwise_or,
                                   tuple(range(bits.ndim))))
    return registers | jnp.stack(regs)


def merge(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Sketch union (distributive over any sharding of the data)."""
    return a | b


def _lowest_zero_index(x: jnp.ndarray) -> jnp.ndarray:
    """Index of the lowest zero bit of each int32 (32 if none)."""
    y = (~x).astype(jnp.uint32)
    low = y & (jnp.uint32(0) - y)
    idx = hashing._popcount32(low - jnp.uint32(1))
    return jnp.where(y == 0, jnp.int32(32), idx.astype(jnp.int32))


def fm_estimate(registers: jnp.ndarray) -> jnp.ndarray:
    """Distinct-count estimate from register bitmaps."""
    r = _lowest_zero_index(registers).astype(jnp.float32)
    return jnp.exp2(jnp.mean(r)) / PHI
