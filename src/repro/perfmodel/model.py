"""Appendix-A performance model: loop-tree runtime estimation.

Implements the paper's Fig 5/6 semantics analytically:

  #par[P]        loop work divided over P units
  #pipeline      outer iterations overlap: per-iteration time is
                 max(stage times) (double-buffered prefetch, §6.2)
  #streaming     producer/consumer overlap: total time is
                 max(stream times) + latency
  branch p       data-dependent body weighted by hit probability
                 (e.g. the S·T match branch hits with p = g/d, App. A)

Compute semantics: joins are *bucket probes*.  A streamed tuple is compared
SIMD-wide against the bucket it hashes to; bucketing can divide work only
down to duplicate groups (|rel|/d tuples share one key, and every one is a
real match that must be touched).  This reproduces the paper's footnote-10
comparison counts |R||S|/h + |R||S||T|/(d·g) including their implicit
duplicate floor, and the Fig 4 bottleneck shifts (compute-bound at small
bucket counts → stream-bound at large; response-time cliff when buckets
shrink below a DRAM burst).

The cascade materializes I(ABC) = R⋈S to DRAM — and to SSD once it exceeds
DRAM capacity (the Fig 4 e/f step).  Everything else aggregates on the fly
(COUNT / FM sketch) per §6.
"""

from __future__ import annotations

import dataclasses
import math

from repro.perfmodel.hw import HW


# --------------------------------------------------------------------------
# primitives
# --------------------------------------------------------------------------

def dram_time(total_bytes: float, hw: HW, chunk_bytes: float | None = None,
              bw: float | None = None) -> float:
    """Bandwidth + per-chunk response; sub-burst chunks pay full bursts."""
    if total_bytes <= 0:
        return 0.0
    bw = bw or hw.dram_bw
    if chunk_bytes is None or chunk_bytes <= 0:
        return total_bytes / bw
    eff_chunk = max(chunk_bytes, 1.0)
    n_chunks = total_bytes / eff_chunk
    padded = max(eff_chunk, hw.dram_burst) * n_chunks
    return padded / bw + n_chunks * hw.dram_resp_s


def probe_time(n_probes: float, other_n: float, fanout: float, d: float,
               hw: HW) -> float:
    """Probe `other` (hash-bucketed `fanout` ways, floored at duplicate
    groups of other_n/d) once per streamed tuple, SIMD-wide scans, U
    probes in flight."""
    if n_probes <= 0 or other_n <= 0:
        return 0.0
    eff_fanout = min(max(fanout, 1.0), max(d, 1.0))
    bucket = other_n / eff_fanout
    cycles_per_probe = max(1.0, bucket / hw.simd)
    return n_probes * cycles_per_probe / (hw.u * hw.freq)


def sync_latency(iters: float, hw: HW) -> float:
    """Per-iteration barrier: all PCUs share the streamed records, so each
    bucket iteration ends with a network+pipeline sync (App. A)."""
    return iters * (hw.net_lat_cycles + hw.pipe_lat_cycles) / hw.freq


@dataclasses.dataclass
class Breakdown:
    """Seconds by phase + the dominant stage marker (Fig 4 annotations)."""
    partition: float
    join1: float
    join2: float
    stages: dict

    @property
    def total(self) -> float:
        return self.partition + self.join1 + self.join2

    @property
    def bottleneck(self) -> str:
        return max(self.stages, key=self.stages.get)

    def to_json(self):
        return {"partition_s": self.partition, "join1_s": self.join1,
                "join2_s": self.join2, "total_s": self.total,
                "bottleneck": self.bottleneck,
                "stages": dict(self.stages)}


def _partition_pass(n_tuples: float, hw: HW, bw: float | None = None
                    ) -> float:
    """One radix pass = stream in + scatter out (2× bytes over DRAM)."""
    return dram_time(2.0 * n_tuples * hw.tuple_bytes, hw, bw=bw)


# --------------------------------------------------------------------------
# cascaded binary join (§6.3, Fig 6 b/d)
# --------------------------------------------------------------------------

def binary_cascade_time(n_r: float, n_s: float, n_t: float, d: float,
                        hw: HW, h_bkt: float | None = None,
                        g_bkt: float | None = None) -> Breakdown:
    """R ⋈ S → I (materialized), then I ⋈ T → aggregate.

    `h_bkt`/`g_bkt` are the coarse partition counts the paper sweeps in
    Fig 4 a/b; the fine level is fixed at h = g = U (§6.3).  Defaults pick
    the best value (large enough that probes hit the duplicate floor).
    """
    tb = hw.tuple_bytes
    n_i = n_r * n_s / d                       # |I| (Swami–Schiefer)
    h_bkt = h_bkt if h_bkt is not None else max(1.0, d / hw.u)
    g_bkt = g_bkt if g_bkt is not None else max(1.0, d / hw.u)
    spill = n_i * tb > hw.dram_cap
    io_bw = hw.spill_bw if spill else hw.dram_bw

    # partition: R,S by B; T by C; I re-partitioned by C (round trip
    # included in join1 write / join2 read, so only one extra scatter pass)
    t_part = _partition_pass(n_r + n_s + n_t, hw)

    # --- join 1: R partitions pinned, S streamed, I written --------------
    t1_compute = probe_time(n_s, n_r, h_bkt * hw.u, d, hw)
    t1_read = dram_time((n_r + n_s) * tb, hw)
    t1_write = dram_time(n_i * tb, hw, bw=io_bw)
    if spill:   # SSD is a separate interface: overlaps with DRAM reads
        t1 = max(t1_read, t1_compute, t1_write)
    else:       # write contends with reads on the one DRAM interface
        t1 = max(dram_time((n_r + n_s + n_i) * tb, hw), t1_compute)
    b1 = {"j1_stream_RS": t1_read, "j1_comp": t1_compute,
          "j1_store_I": t1_write}

    # --- join 2: T partitions pinned, I streamed, COUNT on the fly -------
    t2_compute = probe_time(n_i, n_t, g_bkt * hw.u, d, hw)
    t2_read_i = dram_time(n_i * tb, hw, bw=io_bw)
    t2_load_t = dram_time(n_t * tb, hw, chunk_bytes=n_t / g_bkt * tb)
    t2 = max(t2_read_i, t2_compute) + t2_load_t + sync_latency(g_bkt, hw)
    b2 = {"j2_stream_I": t2_read_i, "j2_comp": t2_compute,
          "j2_load_T": t2_load_t}

    stages = {"partition": t_part, **b1, **b2}
    return Breakdown(t_part, t1, t2, stages)


def cpu_cascade_time(n_r: float, n_s: float, n_t: float, d: float,
                     hw: HW) -> Breakdown:
    """Single-threaded CPU (Postgres-class) hash join: one probe chain,
    `cpu_probe_s` per tuple touch (bucket locate + every duplicate match),
    intermediate spills past RAM."""
    n_i = n_r * n_s / d
    c = hw.cpu_probe_s
    dup_r = max(1.0, n_r / d)
    dup_t = max(1.0, n_t / d)
    spill = n_i * hw.tuple_bytes > hw.dram_cap
    io_bw = hw.spill_bw if spill else hw.dram_bw
    # join1: build R, probe each S tuple (touching its dup_r matches)
    t1 = (n_r + n_s * (1.0 + dup_r)) * c \
        + dram_time(n_i * hw.tuple_bytes, hw, bw=io_bw)
    # join2: build T, probe each I tuple (touching its dup_t matches)
    t2 = (n_t + n_i * (1.0 + dup_t)) * c \
        + dram_time(n_i * hw.tuple_bytes, hw, bw=io_bw)
    stages = {"cpu_j1": t1, "cpu_j2": t2}
    return Breakdown(0.0, t1, t2, stages)


# --------------------------------------------------------------------------
# linear 3-way self join (§4, Fig 6 a)
# --------------------------------------------------------------------------

def linear3_time(n_r: float, n_s: float, n_t: float, d: float, hw: HW,
                 h_bkt: float | None = None, g_bkt: float | None = None
                 ) -> Breakdown:
    """Algorithm 1 runtime.

    for H(B) partition of R (sized to fit on-chip): load R_i;
      for g(C) bucket: load S_ij (routed by h(B)), broadcast-stream T_j;
        compare each t against the PMU-local S_ij records sharing g(c)
        (all-pairs within the bucket, floored at the |S|/d duplicate
        group); on a hit (p = g/d) join against the R_i records with the
        matching B (|R|/d duplicates, SIMD-wide).
    """
    tb = hw.tuple_bytes
    m = hw.m_tuples
    min_h = max(1, int(math.ceil(n_r / m)))
    h_bkt = max(h_bkt or min_h, min_h)
    if g_bkt is None:    # "with best bucket sizes" (§6): line-search g
        best = None
        g = 16.0
        while g <= 4 * max(d, hw.u):
            t = linear3_time(n_r, n_s, n_t, d, hw, h_bkt=h_bkt, g_bkt=g)
            if best is None or t.total < best[0]:
                best = (t.total, g)
            g *= 4.0
        g_bkt = best[1]

    t_part = _partition_pass(n_r + n_s + n_t, hw)

    s_ij = n_s / (h_bkt * g_bkt)                  # S bucket per iteration
    t_j = n_t / g_bkt
    # S·T compare: each streamed t scans the per-PMU S_ij slice SIMD-wide
    # (all-pairs within the g(C) bucket, floored at duplicate groups)
    t_comp_st_iter = probe_time(t_j, s_ij * h_bkt * g_bkt,
                                h_bkt * g_bkt * hw.u, d, hw) \
        / (h_bkt * g_bkt)
    # branch hits join against R's B-duplicates
    hits_iter = s_ij * t_j * (min(g_bkt, d) / d) if d else 0.0
    t_comp_r_iter = hits_iter * max(1.0, (n_r / d) / hw.simd) \
        / (hw.u * hw.freq)
    t_comp_iter = t_comp_st_iter + t_comp_r_iter

    # DRAM per iteration: buckets stream contiguously (the on-chip network
    # does the h(B) routing — that is the point of the fabric); a bucket
    # below a DRAM burst still pays the response-time cliff (Fig 4d).
    t_dram_iter = dram_time(s_ij * tb, hw, chunk_bytes=s_ij * tb) \
        + dram_time(t_j * tb, hw, chunk_bytes=t_j * tb)
    t_iter = max(t_comp_iter, t_dram_iter)        # double-buffered
    t_load_r = dram_time((n_r / h_bkt) * tb, hw)
    t_join = h_bkt * (t_load_r + g_bkt * t_iter) \
        + sync_latency(h_bkt * g_bkt, hw)

    stages = {
        "partition": t_part,
        "comp": h_bkt * g_bkt * t_comp_iter,
        "stream_T": h_bkt * g_bkt * dram_time(t_j * tb, hw,
                                              chunk_bytes=t_j * tb),
        "load_S": h_bkt * g_bkt * dram_time(s_ij * tb, hw,
                                            chunk_bytes=s_ij * tb),
        "load_R": h_bkt * t_load_r,
        "sync": sync_latency(h_bkt * g_bkt, hw),
    }
    return Breakdown(t_part, t_join, 0.0, stages)


# --------------------------------------------------------------------------
# star 3-way join (§6.5, Fig 6 c/d): R,T small, S streamed once
# --------------------------------------------------------------------------

def star3_time(n_r: float, n_s: float, n_t: float, d: float, hw: HW,
               h_bkt: float | None = None) -> Breakdown:
    """3-way star: R,T pinned at PMU (h(b), g(c)) pairs (h·g = U), S
    streamed once; each fact tuple probes both dimension buckets (duplicate
    floor n_r/d — dimension keys are near-unique, d ≈ |R|)."""
    hg = hw.u
    h = h_bkt or int(math.sqrt(hg))
    g = max(1, hg // int(h))
    del g
    tb = hw.tuple_bytes

    t_load_dims = dram_time((n_r + n_t) * tb, hw)
    t_stream_s = dram_time(n_s * tb, hw)
    # PMU-resident dimension buckets are hash-organized at build time:
    # a fact probe touches O(1) + its duplicate group (n/d)
    t_comp = probe_time(n_s, n_r, d, d, hw) + probe_time(n_s, n_t, d, d, hw)
    t_join = max(t_stream_s, t_comp) + t_load_dims
    stages = {"load_dims": t_load_dims, "stream_S": t_stream_s,
              "comp": t_comp}
    return Breakdown(0.0, t_join, 0.0, stages)


def star3_binary_time(n_r: float, n_s: float, n_t: float, d: float,
                      hw: HW) -> Breakdown:
    """Cascaded binary plan for the star schema: (R ⋈ S) ⋈ T with
    h = g = U (one hash at a time, §6.5).  I = |S|·(|R|/d) — below-one
    selectivity only if facts miss dimensions; with duplicates |R|/d > 1
    the intermediate *expands*, which is what the 3-way avoids."""
    dup = n_r / d if d else 1.0
    n_i = n_s * dup
    tb = hw.tuple_bytes
    spill = n_i * tb > hw.dram_cap
    io_bw = hw.spill_bw if spill else hw.dram_bw

    t_load_r = dram_time(n_r * tb, hw)
    t1_comp = probe_time(n_s, n_r, d, d, hw)
    t1_io_in = dram_time(n_s * tb, hw)
    t1_write = dram_time(n_i * tb, hw, bw=io_bw)
    t1 = (max(t1_io_in, t1_comp, t1_write) if spill
          else max(dram_time((n_s + n_i) * tb, hw), t1_comp)) + t_load_r

    t_load_t = dram_time(n_t * tb, hw)
    t2_comp = probe_time(n_i, n_t, d, d, hw)
    t2_read = dram_time(n_i * tb, hw, bw=io_bw)
    t2 = max(t2_read, t2_comp) + t_load_t
    stages = {"sj1_io": t1_io_in + t1_write, "sj1_comp": t1_comp,
              "sj2_io": t2_read, "sj2_comp": t2_comp,
              "load_dims": t_load_r + t_load_t}
    return Breakdown(0.0, t1, t2, stages)
