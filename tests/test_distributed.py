"""Distributed joins on an 8-fake-device mesh (subprocess: the
--xla_force_host_platform_device_count flag must not leak into this
process, which the rest of the suite expects to see 1 device)."""

import pathlib
import subprocess
import sys

import pytest

HERE = pathlib.Path(__file__).resolve().parent


@pytest.mark.slow
def test_distributed_joins_exact():
    proc = subprocess.run(
        [sys.executable, str(HERE / "dist_runner.py")],
        capture_output=True, text=True, timeout=900, cwd=str(HERE))
    assert proc.returncode == 0, (proc.stdout or "") + (proc.stderr or "")
    assert "all exact" in proc.stdout
