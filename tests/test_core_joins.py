"""Core join engine vs python oracles (sorted path + bucketed path)."""

import numpy as np
from hypothesis import given, settings, strategies as st

from conftest import (make_rel, oracle_cyclic3_count, oracle_linear3_count,
                      oracle_linear3_per_r, oracle_pair_count)
from repro.core import (Relation, binary_join, cyclic3, linear3, reference,
                        star3)


# --------------------------------------------------------------------------
# sorted-path binary join
# --------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(n_a=st.integers(1, 200), n_b=st.integers(1, 200),
       d=st.integers(1, 100), seed=st.integers(0, 2**31 - 1))
def test_join_count_matches_oracle(n_a, n_b, d, seed):
    rng = np.random.default_rng(seed)
    a, ad = make_rel(rng, n_a, ("b",), d, cap_extra=seed % 5)
    b, bd = make_rel(rng, n_b, ("b",), d)
    got = int(binary_join.join_count(a, "b", b, "b"))
    assert got == oracle_pair_count(ad["b"], bd["b"])


@settings(max_examples=15, deadline=None)
@given(n_a=st.integers(1, 100), n_b=st.integers(1, 100),
       d=st.integers(1, 40), seed=st.integers(0, 2**31 - 1))
def test_join_materialize_matches_oracle(n_a, n_b, d, seed):
    rng = np.random.default_rng(seed)
    a, ad = make_rel(rng, n_a, ("a", "b"), d)
    b, bd = make_rel(rng, n_b, ("b", "c"), d)
    expect = oracle_pair_count(ad["b"], bd["b"])
    res = binary_join.join_materialize(a, "b", b, "b", out_capacity=expect + 16,
                                       build_prefix="l_", probe_prefix="r_")
    assert int(res.total) == expect
    assert not bool(res.overflowed)
    # every emitted pair actually joins
    lb = np.asarray(res.rel.col("l_b"))
    rb = np.asarray(res.rel.col("r_b"))
    v = np.asarray(res.rel.valid)
    assert int(v.sum()) == expect
    np.testing.assert_array_equal(lb[v], rb[v])
    # multiset of (l_a, r_c) matches the oracle join
    from collections import Counter, defaultdict
    want = Counter()
    by_b = defaultdict(list)
    for bb, cc in zip(bd["b"], bd["c"]):
        by_b[bb].append(cc)
    for aa, bb in zip(ad["a"], ad["b"]):
        for cc in by_b.get(bb, ()):
            want[(int(aa), int(cc))] += 1
    la = np.asarray(res.rel.col("l_a"))
    rc = np.asarray(res.rel.col("r_c"))
    got = Counter(zip(la[v].tolist(), rc[v].tolist()))
    assert got == want


def test_join_materialize_overflow_flag(rng):
    a, _ = make_rel(rng, 50, ("b",), 2)
    b, _ = make_rel(rng, 50, ("b",), 2)
    res = binary_join.join_materialize(a, "b", b, "b", out_capacity=8)
    assert bool(res.overflowed)
    assert int(res.total) > 8
    # valid entries are still correct joins, just truncated
    assert int(np.asarray(res.rel.valid).sum()) == 8


def test_bucketed_pair_count(rng):
    a, ad = make_rel(rng, 500, ("b",), 97)
    b, bd = make_rel(rng, 300, ("b",), 97)
    got, ovf = binary_join.bucketed_join_count(
        a, "b", b, "b", n_buckets=16, build_cap=128, probe_cap=128)
    assert not bool(ovf)
    assert int(got) == oracle_pair_count(ad["b"], bd["b"])


# --------------------------------------------------------------------------
# cascaded binary baseline
# --------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), d=st.integers(2, 60))
def test_cascade_count_matches_oracle(seed, d):
    rng = np.random.default_rng(seed)
    r, rd = make_rel(rng, 120, ("a", "b"), d)
    s, sd = make_rel(rng, 150, ("b", "c"), d)
    t, td = make_rel(rng, 130, ("c", "d"), d)
    expect = oracle_linear3_count(rd["b"], sd["b"], sd["c"], td["c"])
    inter = oracle_pair_count(rd["b"], sd["b"])
    res = binary_join.cascaded_binary_count(r, s, t,
                                            intermediate_capacity=inter + 32)
    assert int(res.count) == expect
    assert int(res.intermediate_total) == inter
    assert not bool(res.intermediate_overflowed)


def test_cascade_per_r_counts(rng):
    r, rd = make_rel(rng, 80, ("a", "b"), 30)
    s, sd = make_rel(rng, 90, ("b", "c"), 30)
    t, td = make_rel(rng, 70, ("c", "d"), 30)
    got = np.asarray(binary_join.cascaded_binary_per_r_counts(r, s, t))[:80]
    want = oracle_linear3_per_r(rd["b"], sd["b"], sd["c"], td["c"])
    np.testing.assert_array_equal(got, want)


# --------------------------------------------------------------------------
# linear 3-way (Algorithm 1)
# --------------------------------------------------------------------------

@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), d=st.integers(3, 80),
       u=st.sampled_from([2, 4, 8]))
def test_linear3_count_matches_oracle(seed, d, u):
    rng = np.random.default_rng(seed)
    r, rd = make_rel(rng, 150, ("a", "b"), d)
    s, sd = make_rel(rng, 180, ("b", "c"), d)
    t, td = make_rel(rng, 160, ("c", "d"), d)
    expect = oracle_linear3_count(rd["b"], sd["b"], sd["c"], td["c"])
    plan = linear3.default_plan(150, 180, 160, m_budget=64, u=u)
    res, _ = reference.linear3_count_auto(r, s, t, plan)
    assert int(res.count) == expect


def test_linear3_per_r_matches_oracle(rng):
    r, rd = make_rel(rng, 100, ("a", "b"), 40)
    s, sd = make_rel(rng, 120, ("b", "c"), 40)
    t, td = make_rel(rng, 110, ("c", "d"), 40)
    plan = linear3.default_plan(100, 120, 110, m_budget=48, u=4)
    (keys, counts, valid), _ = reference.linear3_per_r_counts_auto(r, s, t, plan)
    # group by a on both sides
    from collections import defaultdict
    want = defaultdict(int)
    per_r = oracle_linear3_per_r(rd["b"], sd["b"], sd["c"], td["c"])
    for a, c in zip(rd["a"], per_r):
        want[int(a)] += int(c)
    got = defaultdict(int)
    k = np.asarray(keys).ravel()
    c = np.asarray(counts).ravel()
    v = np.asarray(valid).ravel()
    for ki, ci, vi in zip(k, c, v):
        if vi:
            got[int(ki)] += int(ci)
    assert dict(got) == dict(want)


def test_linear3_zipf_skew_auto_recovers(rng):
    """Zipf-skewed keys overflow the uniform plan; the driver recovers and
    stays exact (paper §1.2 skew note)."""
    r, rd = make_rel(rng, 200, ("a", "b"), 50, zipf=1.4)
    s, sd = make_rel(rng, 220, ("b", "c"), 50, zipf=1.4)
    t, td = make_rel(rng, 210, ("c", "d"), 50, zipf=1.4)
    expect = oracle_linear3_count(rd["b"], sd["b"], sd["c"], td["c"])
    plan = linear3.default_plan(200, 220, 210, m_budget=64, u=4, slack=1.5)
    res, grown = reference.linear3_count_auto(r, s, t, plan)
    assert int(res.count) == expect


def test_linear3_tuples_read_matches_cost_model(rng):
    from repro.core import cost_model
    r, _ = make_rel(rng, 128, ("a", "b"), 40)
    s, _ = make_rel(rng, 128, ("b", "c"), 40)
    t, _ = make_rel(rng, 128, ("c", "d"), 40)
    plan = linear3.default_plan(128, 128, 128, m_budget=32, u=4)
    res, _ = reference.linear3_count_auto(r, s, t, plan)
    # realized tuples == |R| + |S| + h_parts * |T|, h_parts = ceil(|R|/M)
    assert int(res.tuples_read) == 128 + 128 + plan.h_parts * 128
    # and the cost model's continuous form agrees within the ceil rounding
    cm = cost_model.linear3_tuples(128, 128, 128, m=32)
    assert abs(int(res.tuples_read) - cm) / cm < 0.35


# --------------------------------------------------------------------------
# cyclic 3-way (triangles)
# --------------------------------------------------------------------------

@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), d=st.integers(3, 60),
       grid=st.sampled_from([(2, 2), (4, 2), (4, 4)]))
def test_cyclic3_count_matches_oracle(seed, d, grid):
    rng = np.random.default_rng(seed)
    uh, ug = grid
    r, rd = make_rel(rng, 140, ("a", "b"), d)
    s, sd = make_rel(rng, 150, ("b", "c"), d)
    t, td = make_rel(rng, 130, ("c", "a"), d)
    expect = oracle_cyclic3_count(rd["a"], rd["b"], sd["b"], sd["c"],
                                  td["c"], td["a"])
    plan = cyclic3.default_plan(140, 150, 130, m_budget=64, uh=uh, ug=ug)
    res, _ = reference.cyclic3_count_auto(r, s, t, plan)
    assert int(res.count) == expect


def test_cyclic3_self_join_triangles(rng):
    """Triangle counting on a random graph: R = S = T = edge list."""
    n_edges, n_nodes = 240, 40
    e, ed = make_rel(rng, n_edges, ("a", "b"), n_nodes)
    s = Relation.from_arrays(b=ed["a"], c=ed["b"])
    t = Relation.from_arrays(c=ed["a"], a=ed["b"])
    expect = oracle_cyclic3_count(ed["a"], ed["b"], ed["a"], ed["b"],
                                  ed["a"], ed["b"])
    plan = cyclic3.default_plan(n_edges, n_edges, n_edges, m_budget=96,
                                uh=4, ug=4)
    res, _ = reference.cyclic3_count_auto(e, s, t, plan)
    assert int(res.count) == expect


# --------------------------------------------------------------------------
# star 3-way
# --------------------------------------------------------------------------

@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), d=st.integers(3, 60),
       chunks=st.sampled_from([1, 2, 4]))
def test_star3_count_matches_oracle(seed, d, chunks):
    rng = np.random.default_rng(seed)
    r, rd = make_rel(rng, 60, ("a", "b"), d)      # small dimension
    s, sd = make_rel(rng, 400, ("b", "c"), d)     # big fact
    t, td = make_rel(rng, 70, ("c", "d"), d)      # small dimension
    expect = oracle_linear3_count(rd["b"], sd["b"], sd["c"], td["c"])
    plan = star3.default_plan(60, 400, 70, uh=4, ug=4, chunks=chunks)
    res, _ = reference.star3_count_auto(r, s, t, plan)
    assert int(res.count) == expect
    assert int(res.tuples_read) == 60 + 400 + 70  # every tuple read once
