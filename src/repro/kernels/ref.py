"""Pure-jnp oracles for every Pallas kernel in this package.

Each function mirrors one kernel's contract exactly (same shapes, same
sentinel conventions) and is used (a) as the correctness oracle in tests and
(b) as the CPU fallback path in ``ops.py``.

Shapes: buckets are laid out ``[n_buckets, capacity]`` (PMU grid layout from
``repro.core.partition.bucketize``).  Invalid slots are assumed already
masked to per-side sentinels by ``ops.py`` (so ``invalid != invalid`` across
sides), which keeps the inner loops branch-free — the same trick the kernels
use on TPU.
"""

from __future__ import annotations

import jax.numpy as jnp


def bucket_pair_count(ka: jnp.ndarray, kb: jnp.ndarray) -> jnp.ndarray:
    """Per-bucket count of equal (a, b) pairs.

    ka: [B, Ca] int32 (invalid = SENT_A), kb: [B, Cb] int32 (invalid = SENT_B)
    returns [B] int32.
    """
    m = ka[:, :, None] == kb[:, None, :]
    return jnp.sum(m, axis=(1, 2)).astype(jnp.int32)


def bucket_count3_linear(rb: jnp.ndarray, sb: jnp.ndarray, sc: jnp.ndarray,
                         tc: jnp.ndarray) -> jnp.ndarray:
    """Per-bucket linear 3-way count:  Σ_s (Σ_r [r.b=s.b]) · (Σ_t [s.c=t.c]).

    rb: [B, Cr], sb/sc: [B, Cs], tc: [B, Ct]; returns [B] int32.
    """
    wr = jnp.sum(sb[:, :, None] == rb[:, None, :], axis=2)   # [B, Cs]
    wt = jnp.sum(sc[:, :, None] == tc[:, None, :], axis=2)   # [B, Cs]
    return jnp.sum(wr * wt, axis=1).astype(jnp.int32)


def bucket_per_r_counts(rb: jnp.ndarray, sb: jnp.ndarray, sc: jnp.ndarray,
                        tc: jnp.ndarray) -> jnp.ndarray:
    """Per-R-slot 3-way counts:  c[r] = Σ_s [s.b=r.b] · w_s,
    w_s = Σ_t [s.c=t.c].  The Example-1 per-user aggregate.

    returns [B, Cr] int32 aligned with the bucketized R layout.
    """
    wt = jnp.sum(sc[:, :, None] == tc[:, None, :], axis=2)   # [B, Cs]
    m1 = (sb[:, :, None] == rb[:, None, :])                  # [B, Cs, Cr]
    return jnp.einsum("bsr,bs->br", m1.astype(jnp.int32), wt).astype(jnp.int32)


def bucket_count3_cyclic(ra: jnp.ndarray, rb: jnp.ndarray,
                         sb: jnp.ndarray, sc: jnp.ndarray,
                         tc: jnp.ndarray, ta: jnp.ndarray) -> jnp.ndarray:
    """Per-bucket triangle count: Σ_{r,s,t} [r.b=s.b][s.c=t.c][t.a=r.a].

    ra/rb: [B, Cr], sb/sc: [B, Cs], tc/ta: [B, Ct]; returns [B] int32.
    Computed as Σ_{r,t} (M1ᵀ M2)[r,t] · [t.a = r.a] — two MXU matmuls on TPU.
    """
    m1 = (sb[:, :, None] == rb[:, None, :]).astype(jnp.int32)  # [B, Cs, Cr]
    m2 = (sc[:, :, None] == tc[:, None, :]).astype(jnp.int32)  # [B, Cs, Ct]
    p = jnp.einsum("bsr,bst->brt", m1, m2)                     # [B, Cr, Ct]
    m3 = (ra[:, :, None] == ta[:, None, :])                    # [B, Cr, Ct]
    return jnp.sum(p * m3, axis=(1, 2)).astype(jnp.int32)


def radix_histogram(keys: jnp.ndarray, bucket_ids: jnp.ndarray,
                    n_buckets: int) -> jnp.ndarray:
    """Histogram of precomputed bucket ids (invalid rows carry id==n_buckets).

    returns [n_buckets] int32.
    """
    del keys  # signature parity with the kernel (which hashes in-kernel)
    onehot = (bucket_ids[:, None] == jnp.arange(n_buckets)[None, :])
    return jnp.sum(onehot, axis=0).astype(jnp.int32)


def fm_registers(ra: jnp.ndarray, rb: jnp.ndarray, sb: jnp.ndarray,
                 sc: jnp.ndarray, tc: jnp.ndarray, td: jnp.ndarray,
                 n_registers: int) -> jnp.ndarray:
    """FM/PCSA register-bitmap update over the *implicit* joined pairs.

    For every (r, t) pair connected through some s (∃s: s.b=r.b ∧ s.c=t.c),
    OR bit ρ(hash_k(a, d))-1 into bitmap k.  Returns [B, K] int32 bitmaps.
    Never materializes the join — the existence matrix is a matmul.
    """
    import jax

    from repro.core import hashing, sketches

    m1 = (sb[:, :, None] == rb[:, None, :]).astype(jnp.int32)  # [B, Cs, Cr]
    m2 = (sc[:, :, None] == tc[:, None, :]).astype(jnp.int32)  # [B, Cs, Ct]
    exists = jnp.einsum("bsr,bst->brt", m1, m2) > 0            # [B, Cr, Ct]
    # pair key: avalanche-mixed combination of (a, d)
    pair = (hashing.mix32(ra[:, :, None], 0x1B873593) ^ hashing.mix32(
        td[:, None, :], 0xE6546B64)).astype(jnp.int32)         # [B, Cr, Ct]
    regs = []
    for k in range(n_registers):
        bits = jnp.where(exists, sketches.key_bits(pair, k), 0)
        regs.append(jax.lax.reduce(bits, jnp.int32(0), jax.lax.bitwise_or,
                                   (1, 2)))
    return jnp.stack(regs, axis=-1)                            # [B, K]
