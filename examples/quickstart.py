"""Quickstart: the multiway-join engine in five minutes.

    PYTHONPATH=src python examples/quickstart.py

Declares the paper's three join shapes as query graphs (the engine
classifies linear/cyclic/star from the predicates — no kind strings),
executes them through one ``JoinSession``, checks the counts against a
brute-force oracle, shows the planner's 3-way vs cascaded-binary decision
on the paper's own workloads (Examples 3/4), and runs one Pallas kernel in
interpret mode.
"""

import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent
                       / "src"))

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import JoinSession, Query, cost_model  # noqa: E402
from repro.data.relations import RelGenConfig, gen_relation  # noqa: E402


def main():
    rng_n, d = 4000, 300
    r = gen_relation(RelGenConfig(n=rng_n, d=d, columns=("a", "b"), seed=1))
    s = gen_relation(RelGenConfig(n=rng_n, d=d, columns=("b", "c"), seed=2))
    t = gen_relation(RelGenConfig(n=rng_n, d=d, columns=("c", "d"), seed=3))
    sess = JoinSession(m_budget=1024)

    # --- linear 3-way: R(AB) ⋈ S(BC) ⋈ T(CD), COUNT aggregated ---------
    # a path-shaped predicate graph with balanced cardinalities
    q = Query(relations={"r": r, "s": s, "t": t},
              predicates=[("r.b", "s.b"), ("s.c", "t.c")])
    res = sess.execute(q)
    rb = np.asarray(r.col("b")); sb = np.asarray(s.col("b"))
    sc = np.asarray(s.col("c")); tc = np.asarray(t.col("c"))
    oracle = int(((rb[:, None] == sb[None, :]).sum(0).astype(np.int64)
                  * (sc[:, None] == tc[None, :]).sum(1)).sum())
    print(f"{res.kind} 3-way COUNT = {int(res.count)}  (oracle {oracle})  "
          f"strategy={res.strategy}  tuples read = {int(res.tuples_read)}")
    assert res.kind == "linear" and int(res.count) == oracle
    warm = sess.execute(q)       # same structure + sizes: plan-cache hit
    print(f"warm re-execute: cache_hit={warm.cache_hit} "
          f"(plan {warm.plan_s * 1e3:.2f} ms vs cold "
          f"{res.plan_s * 1e3:.2f} ms)")

    # --- cyclic 3-way (triangles): a 3-cycle in the predicate graph -----
    t_cyc = gen_relation(RelGenConfig(n=rng_n, d=d, columns=("c", "a"),
                                      seed=3))
    cres = sess.execute(Query(
        relations={"r": r, "s": s, "t": t_cyc},
        predicates=[("r.b", "s.b"), ("s.c", "t.c"), ("t.a", "r.a")]),
        m_budget=2048)
    # dict-based oracle (the einsum contraction is O(n^3) in int64 —
    # minutes on a small host; this is O(n * avg-degree))
    from collections import Counter, defaultdict
    ra = np.asarray(r.col("a"))
    ta_c = np.asarray(t_cyc.col("c")); ta_a = np.asarray(t_cyc.col("a"))
    s_by_b = defaultdict(list)
    for b, c in zip(sb.tolist(), sc.tolist()):
        s_by_b[b].append(c)
    t_by_ca = Counter(zip(ta_c.tolist(), ta_a.tolist()))
    tri = sum(t_by_ca.get((c, a), 0)
              for a, b in zip(ra.tolist(), rb.tolist())
              for c in s_by_b.get(b, ()))
    print(f"{cres.kind} 3-way (triangle) COUNT = {int(cres.count)}  "
          f"(oracle {tri})")
    assert cres.kind == "cyclic" and int(cres.count) == tri

    # --- star 3-way: same path graph, hub cardinality ≫ endpoints -------
    dim1 = gen_relation(RelGenConfig(n=500, d=d, columns=("a", "b"), seed=4))
    dim2 = gen_relation(RelGenConfig(n=500, d=d, columns=("c", "e"), seed=5))
    sres = sess.execute(Query(
        relations={"dim1": dim1, "fact": s, "dim2": dim2},
        predicates=[("dim1.b", "fact.b"), ("fact.c", "dim2.c")]))
    db = np.asarray(dim1.col("b")); dc = np.asarray(dim2.col("c"))
    s_oracle = int(((db[:, None] == sb[None, :]).sum(0).astype(np.int64)
                    * (sc[:, None] == dc[None, :]).sum(1)).sum())
    print(f"{sres.kind} 3-way COUNT = {int(sres.count)} "
          f"(oracle {s_oracle})")
    assert sres.kind == "star" and int(sres.count) == s_oracle

    # --- the paper's planner decisions (Examples 3 and 4) ----------------
    m3_thresh = cost_model.example3_threshold_m()
    m4_thresh = cost_model.example4_threshold_m()
    print(f"\nExample 3 (Facebook linear self-join): 3-way wins iff "
          f"M > {m3_thresh:.3e} tuples (paper: 1.003e9)")
    print(f"Example 4 (cyclic/triangles): M threshold ≈ {m4_thresh:.2e} "
          "tuples (paper: ~7e6)")
    pick = cost_model.choose_linear_strategy(2e8, 2e8, 2e8, m=1e6, d=7e5)
    print(f"planner @ N=2e8,d=7e5,M=1e6: {pick.strategy} "
          f"(traffic ratio {pick.speed_ratio:.1f}x)")

    # --- one Pallas kernel, interpret mode ------------------------------
    from repro.kernels import ops as kops
    from repro.core import partition
    b = partition.bucketize(r, "b", 8, 1024, fn="h")
    p2 = partition.bucketize(s, "b", 8, 1024, fn="h")
    counts = kops.bucket_pair_count(b.columns["b"], b.valid,
                                    p2.columns["b"], p2.valid,
                                    use_kernel=True)
    print(f"\nPallas bucket_pair_count (interpret): "
          f"R⋈S pairs = {int(jax.numpy.sum(counts))}")
    print("\nquickstart OK")


if __name__ == "__main__":
    main()
