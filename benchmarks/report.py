"""Render EXPERIMENTS.md tables from dry-run artifacts + bench CSVs.

    PYTHONPATH=src python benchmarks/report.py   # prints markdown sections
"""

from __future__ import annotations

import json
import pathlib
from collections import defaultdict

DRYRUN = pathlib.Path("artifacts/dryrun")
BENCH = pathlib.Path("artifacts/bench")


def _load_all():
    arts = defaultdict(dict)     # (arch, shape, mesh) -> {tag: art}
    for p in sorted(DRYRUN.glob("*.json")):
        parts = p.stem.split("__")
        arch, shape, pod = parts[0], parts[1], parts[2]
        tag = parts[3] if len(parts) > 3 else "baseline"
        arts[(arch, shape, pod)][tag] = json.loads(p.read_text())
    return arts


def fmt_s(x):
    if x is None:
        return "-"
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.2f}ms"
    return f"{x * 1e6:.1f}us"


def roofline_table(pod="pod1", tag="baseline"):
    arts = _load_all()
    lines = [
        "| arch | shape | kind | t_comp | t_mem | t_coll | bottleneck |"
        " useful | roofline_frac | peak GB | fits16G |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for (arch, shape, p), tags in sorted(arts.items()):
        if p != pod or tag not in tags:
            continue
        a = tags[tag]
        if not a.get("ok"):
            lines.append(f"| {arch} | {shape} | FAILED | | | | | | | |")
            continue
        r = a["roofline"]
        lines.append(
            f"| {arch} | {shape} | {a['kind']} | {fmt_s(r['t_compute_s'])}"
            f" | {fmt_s(r['t_memory_s'])} | {fmt_s(r['t_collective_s'])}"
            f" | {r['bottleneck']} | {r['useful_flops_fraction']:.2f}"
            f" | {r['roofline_fraction']:.4f}"
            f" | {a['per_device_peak_bytes_est'] / 1e9:.1f}"
            f" | {'Y' if a.get('fits_16gb') else 'N'} |")
    return "\n".join(lines)


def perf_table(cells):
    """Per-cell iteration ladders."""
    arts = _load_all()
    out = []
    for arch, shape in cells:
        tags = arts.get((arch, shape, "pod1"), {})
        out.append(f"\n**{arch} × {shape}**\n")
        out.append("| iter | overrides | t_comp | t_mem | t_coll |"
                   " bottleneck | roofline_frac | peak GB |")
        out.append("|---|---|---|---|---|---|---|---|")
        order = sorted(tags, key=lambda t: (t != "baseline", t))
        for tag in order:
            a = tags[tag]
            if tag == "dbg" or not a.get("ok"):
                continue
            r = a["roofline"]
            ov = " ".join(f"{k}={v}" for k, v in
                          (a.get("overrides") or {}).items()) or "-"
            out.append(
                f"| {tag} | {ov} | {fmt_s(r['t_compute_s'])}"
                f" | {fmt_s(r['t_memory_s'])} | {fmt_s(r['t_collective_s'])}"
                f" | {r['bottleneck']} | {r['roofline_fraction']:.4f}"
                f" | {a['per_device_peak_bytes_est'] / 1e9:.1f} |")
    return "\n".join(out)


def multipod_summary():
    arts = _load_all()
    n_ok = n_tot = 0
    for (arch, shape, p), tags in arts.items():
        if p != "pod2" or "baseline" not in tags:
            continue
        n_tot += 1
        n_ok += bool(tags["baseline"].get("ok"))
    return f"{n_ok}/{n_tot} multi-pod (2×16×16) cells lowered + compiled"


def join_summary():
    p = BENCH / "join_dryrun.json"
    if not p.exists():
        return "(join dry-run not yet generated)"
    d = json.loads(p.read_text())
    lines = ["| plan | wire bytes (total) | paper-predicted | ratio |",
             "|---|---|---|---|"]
    for name, r in d.items():
        lines.append(f"| {name} | {r['wire_bytes_total']:.3e}"
                     f" | {r['paper_predicted_bytes']:.3e}"
                     f" | {r['measured_over_predicted']:.2f} |")
    return "\n".join(lines)


if __name__ == "__main__":
    print("## §Roofline (single-pod 16×16, baseline)\n")
    print(roofline_table())
    print("\n## multi-pod\n")
    print(multipod_summary())
    print("\n## §Perf ladders\n")
    print(perf_table([
        ("qwen3-moe-30b-a3b", "train_4k"),
        ("moonshot-v1-16b-a3b", "train_4k"),
        ("moonshot-v1-16b-a3b", "decode_32k"),
        ("yi-34b", "train_4k"),
        ("qwen2-1.5b", "train_4k"),
    ]))
    print("\n## join collective validation\n")
    print(join_summary())
