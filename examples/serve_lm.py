"""Serving example: batched prefill + greedy decode over request waves
(the serve_step the decode_32k / long_500k dry-run cells lower), including
a long-context SSM serve with O(1) per-token state.

    PYTHONPATH=src python examples/serve_lm.py
"""

import pathlib
import sys
import time

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent
                       / "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro import configs  # noqa: E402
from repro.models import zoo  # noqa: E402
from repro.train import make_decode_step  # noqa: E402


def serve(arch: str, batch=2, prompt_len=24, gen=12):
    cfg = configs.smoke(arch)
    model = zoo.build(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size,
                           size=(batch, prompt_len)).astype(np.int32)
    memory = None
    if model.needs_memory and cfg.n_frontend_tokens:
        memory = jnp.asarray(rng.normal(0, 1, size=(
            batch, cfg.n_frontend_tokens, cfg.d_model)).astype(np.float32))

    cache = model.init_cache(batch, prompt_len + gen)
    decode = jax.jit(make_decode_step(model), donate_argnums=1)
    t0 = time.time()
    logits, cache = jax.jit(
        lambda p, t, c: model.prefill(p, t, c, memory=memory),
        donate_argnums=2)(params, jnp.asarray(prompts), cache)
    tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
    outs = [[] for _ in range(batch)]
    for _ in range(gen):
        tok, logits, cache = decode(params, cache, tok)
        for i in range(batch):
            outs[i].append(int(tok[i, 0]))
    dt = time.time() - t0
    print(f"{arch:22s} prefill {prompt_len} + decode {gen}: "
          f"{batch * gen / dt:6.1f} tok/s   sample: {outs[0][:6]}")
    return outs


def main():
    print("== dense / MoE / VLM / enc-dec serving (reduced configs) ==")
    serve("qwen2-1.5b")
    serve("qwen3-moe-30b-a3b")
    serve("llama-3.2-vision-11b")
    serve("seamless-m4t-medium")
    print("\n== long-context SSM serving (bounded state) ==")
    serve("mamba2-370m", prompt_len=48, gen=16)
    serve("zamba2-1.2b", prompt_len=48, gen=16)
    print("\nserve_lm OK")


if __name__ == "__main__":
    main()
