"""Fused engine vs scan-based driver on the Fig 4 workload shapes.

Measures the tentpole claim of the engine PR: sweeping the H(B)×g(C)
partition grid as ONE fused launch (``core.engine.*_count_fused``) beats the
nested-``lax.scan`` per-bucket-row drivers (``core.linear3`` etc.) — the
same partitioning, the same per-bucket math, only the launch structure
differs.  Shapes are the paper's Fig 4 workloads (e,f: linear self-join;
g,h,i: star; plus the §5 triangle query), scaled to CPU-benchable sizes with
the partition counts preserved (tens of coarse partitions, so the scan
driver pays hundreds of sequential steps).

Both sides run the compiled XLA path (``use_kernel=False``) so the
comparison is launch-structure vs launch-structure, not interpreter
overhead.  Results go to BENCH_engine.json (CI uploads it every run —
the perf trajectory record).

    PYTHONPATH=src python benchmarks/engine_bench.py [--quick] [--repeats N]
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent
                       / "src"))

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import cyclic3, engine, linear3, star3  # noqa: E402
from repro.core.query import Query  # noqa: E402
from repro.core.relation import Relation  # noqa: E402
from repro.core.session import JoinSession  # noqa: E402

OUT = pathlib.Path("BENCH_engine.json")


def _rel(rng, n, cols, d):
    return Relation.from_arrays(
        **{c: rng.integers(0, d, size=n).astype(np.int32) for c in cols})


def _time(fn, *args, repeats: int) -> float:
    """Best-of-N wall time in ms for an already-jitted callable."""
    jax.block_until_ready(fn(*args))          # compile + warm caches
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best * 1e3


def bench_linear(rng, n, d, m_budget, u, repeats):
    r = _rel(rng, n, ("a", "b"), d)
    s = _rel(rng, n, ("b", "c"), d)
    t = _rel(rng, n, ("c", "d"), d)
    plan = linear3.default_plan(n, n, n, m_budget=m_budget, u=u, slack=3.0)
    scan_fn = jax.jit(lambda a, b, c: linear3.linear3_count(a, b, c, plan))
    fused_fn = jax.jit(
        lambda a, b, c: engine.linear3_count_fused(a, b, c, plan))
    scan_ms = _time(scan_fn, r, s, t, repeats=repeats)
    fused_ms = _time(fused_fn, r, s, t, repeats=repeats)
    c0, c1 = int(scan_fn(r, s, t).count), int(fused_fn(r, s, t).count)
    return {"n": n, "d": d, "h_parts": plan.h_parts, "g_parts": plan.g_parts,
            "u": plan.u, "scan_ms": scan_ms, "fused_ms": fused_ms,
            "speedup": scan_ms / fused_ms, "count_scan": c0,
            "count_fused": c1, "match": c0 == c1}


def bench_cyclic(rng, n, d, m_budget, repeats):
    """Cyclic (triangle) query: the fused path now probes a sorted
    (c, a)-pair index of T (searchsorted range scans) instead of the
    all-pairs contraction — the backend that unsticks the ~1x cyclic CPU
    number.  Both the pair-index and the all-pairs fused variants are
    timed against the scan driver."""
    r = _rel(rng, n, ("a", "b"), d)
    s = _rel(rng, n, ("b", "c"), d)
    t = _rel(rng, n, ("c", "a"), d)
    plan = cyclic3.default_plan(n, n, n, m_budget=m_budget, uh=4, ug=4,
                                slack=3.0)
    scan_fn = jax.jit(lambda a, b, c: cyclic3.cyclic3_count(a, b, c, plan))
    fused_fn = jax.jit(
        lambda a, b, c: engine.cyclic3_count_fused(a, b, c, plan))
    allpairs_fn = jax.jit(
        lambda a, b, c: engine.cyclic3_count_fused(a, b, c, plan,
                                                   pair_index=False))
    scan_ms = _time(scan_fn, r, s, t, repeats=repeats)
    fused_ms = _time(fused_fn, r, s, t, repeats=repeats)
    allpairs_ms = _time(allpairs_fn, r, s, t, repeats=repeats)
    c0, c1 = int(scan_fn(r, s, t).count), int(fused_fn(r, s, t).count)
    c2 = int(allpairs_fn(r, s, t).count)
    return {"n": n, "d": d, "h_parts": plan.h_parts, "g_parts": plan.g_parts,
            "f_parts": plan.f_parts, "scan_ms": scan_ms,
            "fused_ms": fused_ms, "fused_allpairs_ms": allpairs_ms,
            "speedup": scan_ms / fused_ms,
            "count_scan": c0, "count_fused": c1,
            "match": c0 == c1 == c2}


def bench_star(rng, n_dim, n_fact, d, chunks, repeats):
    r = _rel(rng, n_dim, ("a", "b"), d)
    s = _rel(rng, n_fact, ("b", "c"), d)
    t = _rel(rng, n_dim, ("c", "d"), d)
    plan = star3.default_plan(n_dim, n_fact, n_dim, uh=8, ug=8,
                              chunks=chunks, slack=3.0)
    scan_fn = jax.jit(lambda a, b, c: star3.star3_count(a, b, c, plan))
    fused_fn = jax.jit(
        lambda a, b, c: engine.star3_count_fused(a, b, c, plan))
    scan_ms = _time(scan_fn, r, s, t, repeats=repeats)
    fused_ms = _time(fused_fn, r, s, t, repeats=repeats)
    c0, c1 = int(scan_fn(r, s, t).count), int(fused_fn(r, s, t).count)
    return {"n_dim": n_dim, "n_fact": n_fact, "d": d, "chunks": chunks,
            "scan_ms": scan_ms, "fused_ms": fused_ms,
            "speedup": scan_ms / fused_ms, "count_scan": c0,
            "count_fused": c1, "match": c0 == c1}


def bench_session_cache(rng, n, d, m_budget, repeats):
    """The declarative front door's plan cache: a cold ``execute`` pays
    classification + strategy/shape sizing (incl. a host-side distinct
    estimate), a warm one skips straight to the fused engine.  Gated on
    cached-plan behavior (warm must re-plan nothing), recorded as cold vs
    warm PLANNING milliseconds (execution time is identical by
    construction and noisy, so it is excluded from the gate)."""
    r = _rel(rng, n, ("a", "b"), d)
    s = _rel(rng, n, ("b", "c"), d)
    t = _rel(rng, n, ("c", "d"), d)
    q = Query(relations={"r": r, "s": s, "t": t},
              predicates=[("r.b", "s.b"), ("s.c", "t.c")])
    sess = JoinSession(m_budget=m_budget)
    cold = sess.execute(q)
    warm_plan_ms = float("inf")
    warm_hits = True
    for _ in range(max(repeats, 2)):
        w = sess.execute(q)
        warm_hits &= w.cache_hit
        warm_plan_ms = min(warm_plan_ms, w.plan_s * 1e3)
    return {"n": n, "d": d, "kind": cold.kind, "strategy": cold.strategy,
            "cold_plan_ms": cold.plan_s * 1e3,
            "warm_plan_ms": warm_plan_ms,
            "plan_speedup": cold.plan_s * 1e3 / max(warm_plan_ms, 1e-6),
            "count": int(cold.count), "warm_cache_hits": warm_hits,
            "match": warm_hits and int(w.count) == int(cold.count)}


def _chain4_query(rng, n, d):
    rels = {f"r{i + 1}": _rel(rng, n, cols, d)
            for i, cols in enumerate((("a", "b"), ("b", "c"), ("c", "d"),
                                      ("d", "e")))}
    preds = [("r1.b", "r2.b"), ("r2.c", "r3.c"), ("r3.d", "r4.d")]
    return Query(relations=rels, predicates=preds)


def bench_cascade_4way(rng, n, d, m_budget, repeats):
    """The N-way plan IR on a 4-relation chain: the decomposer's hybrid
    plan (binary materialize feeding a fused, recovery-wrapped 3-way
    root) vs the forced all-binary cascade.  Both run through the SAME
    plan-IR executor, so this tracks the multi-step walk itself.  Gated
    on exact count agreement (match) — the ir/binary ratio is recorded
    for the trajectory but not speedup-gated (the two plans read
    different amounts of data by design)."""
    q = _chain4_query(rng, n, d)
    sess = JoinSession(m_budget=m_budget)
    cold = sess.execute(q)                      # decompose + compile
    binary = sess.execute(q, strategy="cascade")
    ir_ms = binary_ms = float("inf")
    for _ in range(max(repeats, 2)):
        w = sess.execute(q)
        ir_ms = min(ir_ms, w.exec_s * 1e3)
        wb = sess.execute(q, strategy="cascade")
        binary_ms = min(binary_ms, wb.exec_s * 1e3)
    return {"n": n, "d": d, "n_relations": 4,
            "steps": len(cold.plan.steps),
            "fused3_steps": len(cold.plan.fused3_steps),
            "strategy": cold.strategy,
            "ir_ms": ir_ms, "allbinary_ms": binary_ms,
            "ir_vs_binary": binary_ms / max(ir_ms, 1e-9),
            "count": int(cold.count),
            "match": (int(cold.count) == int(binary.count)
                      and not cold.overflowed and not binary.overflowed
                      and len(cold.plan.steps) >= 2)}


def bench_execute_many(rng, n, d, m_budget, batch, repeats):
    """JoinSession.execute_many warm-cache amortization: a batch of
    structurally identical 4-way queries plans ONCE — every query after
    the first is a plan-cache hit (log-bucketed cardinality keys), so
    per-query planning cost collapses.  Gated on cache behavior + exact
    counts (match)."""
    q = _chain4_query(rng, n, d)
    sess = JoinSession(m_budget=m_budget)
    results = sess.execute_many([q] * batch)
    counts = {int(r.count) for r in results}
    cold_plan_ms = results[0].plan_s * 1e3
    warm_plan_ms = min(r.plan_s for r in results[1:]) * 1e3
    for _ in range(max(repeats - 1, 1)):
        again = sess.execute_many([q] * batch)
        warm_plan_ms = min(warm_plan_ms,
                           min(r.plan_s for r in again) * 1e3)
    return {"n": n, "d": d, "batch": batch,
            "cold_plan_ms": cold_plan_ms, "warm_plan_ms": warm_plan_ms,
            "plan_amortization": cold_plan_ms / max(warm_plan_ms, 1e-6),
            "warm_cache_hits": all(r.cache_hit for r in results[1:]),
            "count": int(results[0].count),
            "match": (len(counts) == 1
                      and all(r.cache_hit for r in results[1:]))}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI sizes (smaller relations, fewer repeats)")
    ap.add_argument("--repeats", type=int, default=None)
    args = ap.parse_args()

    repeats = args.repeats or (2 if args.quick else 4)
    scale = 1 if args.quick else 2
    rng = np.random.default_rng(20260726)

    shapes = {}
    print(f"engine_bench: backend={jax.default_backend()} "
          f"quick={args.quick}")
    # Fig 4(e,f): linear self-join, |R|=|S|=|T|, tens of coarse partitions
    shapes["fig4ef_linear"] = bench_linear(
        rng, n=24000 * scale, d=4096 * scale, m_budget=1024 * scale, u=16,
        repeats=repeats)
    # §5 triangle query on a random graph
    shapes["cyclic_triangles"] = bench_cyclic(
        rng, n=6000 * scale, d=512 * scale, m_budget=512 * scale,
        repeats=repeats)
    # Fig 4(h,i): star schema — small dimensions, streamed fact
    shapes["fig4hi_star"] = bench_star(
        rng, n_dim=2000 * scale, n_fact=120000 * scale, d=2048 * scale,
        chunks=8, repeats=repeats)
    # declarative session: cold vs warm plan-cache execute
    shapes["session_plan_cache"] = bench_session_cache(
        rng, n=24000 * scale, d=4096 * scale, m_budget=1024 * scale,
        repeats=repeats)
    # N-way plan IR: 4-relation chain, hybrid vs all-binary cascade
    shapes["cascade_4way"] = bench_cascade_4way(
        rng, n=12000 * scale, d=2048 * scale, m_budget=1024 * scale,
        repeats=repeats)
    # batched execution over the plan cache
    shapes["session_execute_many"] = bench_execute_many(
        rng, n=12000 * scale, d=2048 * scale, m_budget=1024 * scale,
        batch=6, repeats=repeats)

    for name, row in shapes.items():
        if "scan_ms" in row:
            print(f"  {name}: scan {row['scan_ms']:.1f} ms, "
                  f"fused {row['fused_ms']:.1f} ms, "
                  f"speedup {row['speedup']:.2f}x, match={row['match']}")
        elif "ir_ms" in row:
            print(f"  {name}: ir {row['ir_ms']:.1f} ms "
                  f"({row['steps']} steps, {row['fused3_steps']} fused), "
                  f"all-binary {row['allbinary_ms']:.1f} ms, "
                  f"match={row['match']}")
        else:
            print(f"  {name}: cold plan {row['cold_plan_ms']:.2f} ms, "
                  f"warm plan {row['warm_plan_ms']:.3f} ms, "
                  f"cache hits={row['warm_cache_hits']}")

    best = max(s["speedup"] for s in shapes.values() if "speedup" in s)
    cyc = shapes["cyclic_triangles"]["speedup"]
    cache = shapes["session_plan_cache"]
    ok = best >= 2.0 and all(s["match"] for s in shapes.values())
    # the exit gate uses a noise-tolerant 2x floor (shared CI runners
    # jitter); the measured value and the 3x claim go in the JSON record,
    # and check_bench_regression.py guards the trajectory against the
    # committed baseline ratio
    cyc_ok = cyc >= 2.0
    report = {
        "backend": jax.default_backend(),
        "quick": bool(args.quick),
        "repeats": repeats,
        "shapes": shapes,
        "claim_fused_ge_2x": {
            "ok": ok, "best_speedup": best,
            "detail": "fused engine >= 2x over scan driver on at least one "
                      "Fig 4 shape, counts exactly equal",
        },
        "claim_cyclic_pairidx_ge_3x": {
            "ok": cyc >= 3.0, "speedup": cyc,
            "detail": "cyclic fused path with the sorted (c,a)-pair-index "
                      "backend >= 3x over the cyclic scan driver",
        },
        "claim_session_plan_cache": {
            "ok": bool(cache["warm_cache_hits"]),
            "cold_plan_ms": cache["cold_plan_ms"],
            "warm_plan_ms": cache["warm_plan_ms"],
            "detail": "warm JoinSession.execute hits the plan cache "
                      "(skips classification + sizing entirely)",
        },
        "claim_nway_plan_ir": {
            "ok": bool(shapes["cascade_4way"]["match"]
                       and shapes["session_execute_many"]["match"]),
            "steps": shapes["cascade_4way"]["steps"],
            "fused3_steps": shapes["cascade_4way"]["fused3_steps"],
            "plan_amortization":
                shapes["session_execute_many"]["plan_amortization"],
            "detail": "a 4-relation chain decomposes into a multi-step "
                      "plan with a fused 3-way root whose count equals "
                      "the all-binary cascade exactly, and execute_many "
                      "amortizes planning over the cache",
        },
    }
    OUT.write_text(json.dumps(report, indent=2))
    cache_ok = bool(cache["warm_cache_hits"])
    nway_ok = bool(report["claim_nway_plan_ir"]["ok"])
    print(f"[{'PASS' if ok else 'FAIL'}] best fused speedup {best:.2f}x; "
          f"[{'PASS' if cyc_ok else 'FAIL'}] cyclic pair-index {cyc:.2f}x; "
          f"[{'PASS' if cache_ok else 'FAIL'}] session plan cache; "
          f"[{'PASS' if nway_ok else 'FAIL'}] N-way plan IR "
          f"-> {OUT}")
    return 0 if (ok and cyc_ok and cache_ok and nway_ok) else 1


if __name__ == "__main__":
    raise SystemExit(main())
