"""Declarative query-graph API: classification, binding, JoinSession.

Covers the front-door contract: the predicate graph (not a ``kind``
string) decides linear/cyclic/star; schema errors and unsupported graphs
raise; ``JoinSession.execute`` equals the legacy entry points for all
three kinds (including under adversarial skew); the plan cache skips
re-planning; and the plan-level ``base_salt`` reaches the recovery rounds.
"""


import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from conftest import make_rel, skewed_keys
from repro.core import engine, linear3, planner, recovery
from repro.core.query import (Query, QueryGraphError, QuerySchemaError,
                              _legacy_query)
from repro.core.relation import Relation
from repro.core.session import JoinSession


def _query3(r, s, t, preds):
    return Query(relations={"r": r, "s": s, "t": t}, predicates=preds)


def _linear_preds():
    return [("r.b", "s.b"), ("s.c", "t.c")]


def _cyclic_preds():
    return [("r.b", "s.b"), ("s.c", "t.c"), ("t.a", "r.a")]


# --------------------------------------------------------------------------
# classification: graph shapes and edge cases
# --------------------------------------------------------------------------

def test_classify_path_is_linear(rng):
    r, _ = make_rel(rng, 100, ("a", "b"), 20)
    s, _ = make_rel(rng, 100, ("b", "c"), 20)
    t, _ = make_rel(rng, 100, ("c", "d"), 20)
    cls_ = _query3(r, s, t, _linear_preds()).classify()
    assert cls_.kind == "linear" and cls_.shape == "path"
    assert cls_.role_map == {"r": "r", "s": "s", "t": "t"}
    assert cls_.col_map == {"rb": "b", "sb": "b", "sc": "c", "tc": "c"}


def test_classify_cycle_is_cyclic(rng):
    r, _ = make_rel(rng, 100, ("a", "b"), 20)
    s, _ = make_rel(rng, 100, ("b", "c"), 20)
    t, _ = make_rel(rng, 100, ("c", "a"), 20)
    cls_ = _query3(r, s, t, _cyclic_preds()).classify()
    assert cls_.kind == "cyclic" and cls_.shape == "cycle"
    assert cls_.col_map == {"ra": "a", "rb": "b", "sb": "b", "sc": "c",
                            "tc": "c", "ta": "a"}


def test_classify_hub_is_star_by_cardinality(rng):
    """A path whose centre dwarfs both endpoints is the star (fact +
    dimensions) schema; the SAME graph with balanced sizes is linear —
    the documented ambiguity tie-break."""
    dim1, _ = make_rel(rng, 80, ("a", "b"), 20)
    dim2, _ = make_rel(rng, 90, ("c", "d"), 20)
    fact, _ = make_rel(rng, 2000, ("b", "c"), 20)
    q = Query({"d1": dim1, "f": fact, "d2": dim2},
              [("d1.b", "f.b"), ("f.c", "d2.c")])
    assert q.classify().kind == "star"
    # explicit cardinalities override the data: balanced -> linear
    assert q.classify({"d1": 100, "f": 100, "d2": 100}).kind == "linear"
    # right at the ratio boundary the tie resolves to star (>=)
    assert q.classify({"d1": 25, "f": 100, "d2": 25}).kind == "star"
    assert q.classify({"d1": 26, "f": 100, "d2": 25}).kind == "linear"


def test_classify_self_join_three_aliases(rng):
    """Self-joins register one Relation under several names; roles follow
    declaration order and columns bind per-alias."""
    f, _ = make_rel(rng, 150, ("src", "dst"), 25)
    q = Query({"f1": f, "f2": f, "f3": f},
              [("f1.dst", "f2.src"), ("f2.dst", "f3.src")])
    cls_ = q.classify()
    assert cls_.kind == "linear"
    assert cls_.role_map == {"r": "f1", "s": "f2", "t": "f3"}
    assert cls_.col_map == {"rb": "dst", "sb": "src", "sc": "dst",
                            "tc": "src"}
    b = q.bind(cls_)
    assert b.rels["r"] is f and b.rels["s"] is f


def test_classify_disconnected_raises(rng):
    r, _ = make_rel(rng, 50, ("a", "b"), 10)
    s, _ = make_rel(rng, 50, ("b", "c"), 10)
    t, _ = make_rel(rng, 50, ("c", "d"), 10)
    with pytest.raises(QueryGraphError, match="disconnected"):
        _query3(r, s, t, [("r.b", "s.b")]).classify()


def test_classify_rejects_bad_graphs(rng):
    r, _ = make_rel(rng, 50, ("a", "b"), 10)
    s, _ = make_rel(rng, 50, ("b", "c"), 10)
    t, _ = make_rel(rng, 50, ("c", "d"), 10)
    # predicate joining a relation to itself (use aliases instead)
    with pytest.raises(QueryGraphError, match="self-join"):
        _query3(r, s, t,
                [("r.a", "r.b"), ("r.b", "s.b"), ("s.c", "t.c")]).classify()
    # two predicates between the same pair (conjunctive multi-column)
    with pytest.raises(QueryGraphError, match="multi-column"):
        _query3(r, s, t, [("r.a", "s.b"), ("r.b", "s.c"),
                          ("s.c", "t.c")]).classify()
    # wrong arity
    with pytest.raises(QueryGraphError, match="3-relation"):
        Query({"r": r, "s": s}, [("r.b", "s.b")]).classify()


def test_schema_validation_raises(rng):
    r, _ = make_rel(rng, 50, ("a", "b"), 10)
    s, _ = make_rel(rng, 50, ("b", "c"), 10)
    t, _ = make_rel(rng, 50, ("c", "d"), 10)
    with pytest.raises(QuerySchemaError, match="no column"):
        _query3(r, s, t, [("r.zz", "s.b"), ("s.c", "t.c")])
    with pytest.raises(QuerySchemaError, match="unknown relation"):
        _query3(r, s, t, [("x.b", "s.b"), ("s.c", "t.c")])
    with pytest.raises(QuerySchemaError, match="rel.col"):
        _query3(r, s, t, [("rb", "s.b"), ("s.c", "t.c")])


# --------------------------------------------------------------------------
# parity: JoinSession.execute == the legacy entry points, all three kinds
# --------------------------------------------------------------------------

@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), d=st.integers(4, 60),
       kind=st.sampled_from(["linear", "cyclic", "star"]))
def test_session_matches_legacy_entry_points(seed, d, kind):
    """Hypothesis parity: for every kind, the declarative path returns the
    same exact count as legacy ``engine_count`` AND ``plan_step().run()``
    (no kind string crosses the new API)."""
    rng = np.random.default_rng(seed)
    if kind == "star":
        r, _ = make_rel(rng, 60, ("a", "b"), d)
        s, _ = make_rel(rng, 900, ("b", "c"), d)
        t, _ = make_rel(rng, 70, ("c", "d"), d)
        preds = _linear_preds()
    elif kind == "cyclic":
        r, _ = make_rel(rng, 120, ("a", "b"), d)
        s, _ = make_rel(rng, 130, ("b", "c"), d)
        t, _ = make_rel(rng, 110, ("c", "a"), d)
        preds = _cyclic_preds()
    else:
        r, _ = make_rel(rng, 120, ("a", "b"), d)
        s, _ = make_rel(rng, 130, ("b", "c"), d)
        t, _ = make_rel(rng, 110, ("c", "d"), d)
        preds = _linear_preds()
    q = _query3(r, s, t, preds)
    cls_ = q.classify()
    assert cls_.kind == kind
    res = JoinSession(m_budget=64).execute(q)
    assert not res.overflowed
    legacy = engine.MultiwayJoinEngine(kind).count(r, s, t,
                                                   m_budget=64)
    assert int(res.count) == int(legacy.count)
    n_r, n_s, n_t = int(r.n), int(s.n), int(t.n)
    ep = planner.plan_step(kind, n_r, n_s, n_t, d, m_budget=64)
    assert int(ep.run(r, s, t).count) == int(res.count)


def test_session_skew_recovery_exact(rng):
    """Adversarial heavy-hitter keys through the declarative path: the
    session must recover exactly (overflowed == False) and agree with the
    single-bucket kernel reference."""
    from repro.kernels import ops as kops
    rb = skewed_keys(rng, 200, 30, 0.5)
    sb = skewed_keys(rng, 220, 30, 0.5)
    sc = skewed_keys(rng, 220, 30, 0.5, 2)
    tc = skewed_keys(rng, 210, 30, 0.5, 2)
    r = Relation.from_arrays(a=rng.integers(0, 99, 200).astype(np.int32),
                             b=rb)
    s = Relation.from_arrays(b=sb, c=sc)
    t = Relation.from_arrays(c=tc,
                             d=rng.integers(0, 99, 210).astype(np.int32))
    want = int(kops.bucket_count3_linear(
        jnp.asarray(rb)[None, :], jnp.ones((1, len(rb)), bool),
        jnp.asarray(sb)[None, :], jnp.asarray(sc)[None, :],
        jnp.ones((1, len(sb)), bool),
        jnp.asarray(tc)[None, :], jnp.ones((1, len(tc)), bool))[0])
    plan = linear3.default_plan(200, 220, 210, m_budget=64, u=4, slack=1.2)
    res = JoinSession().execute(_query3(r, s, t, _linear_preds()),
                                plan=plan)
    assert int(res.count) == want
    assert not res.overflowed
    assert res.rounds > 1          # the skew actually exercised recovery


def test_session_per_r_matches_legacy(rng):
    r, _ = make_rel(rng, 120, ("a", "b"), 25)
    s, _ = make_rel(rng, 140, ("b", "c"), 25)
    t, _ = make_rel(rng, 130, ("c", "d"), 25)
    plan = linear3.default_plan(120, 140, 130, m_budget=48, u=4)
    res = JoinSession().execute(_query3(r, s, t, _linear_preds()),
                                plan=plan, per_r=True)
    # per_r executes the engine ONCE: COUNT is the valid per-R sum, and
    # the per-R rounds report their own int64 traffic
    assert int(res.count) == int(
        res.per_r.counts[np.asarray(res.per_r.valid)].sum())
    assert res.per_r.tuples_read > 0
    assert np.asarray(res.tuples_read).dtype == np.int64
    legacy = engine.MultiwayJoinEngine("linear").per_r_counts(r, s, t,
                                                              plan)
    np.testing.assert_array_equal(np.asarray(res.per_r.counts),
                                  np.asarray(legacy.counts))
    np.testing.assert_array_equal(np.asarray(res.per_r.keys),
                                  np.asarray(legacy.keys))
    with pytest.raises(ValueError, match="linear"):
        t2, _ = make_rel(rng, 130, ("c", "a"), 25)
        JoinSession(m_budget=64).execute(
            _query3(r, s, t2, _cyclic_preds()), per_r=True)


# --------------------------------------------------------------------------
# plan cache: repeated queries skip classification and sizing
# --------------------------------------------------------------------------

def test_plan_cache_hits_and_invalidates(rng, monkeypatch):
    r, _ = make_rel(rng, 150, ("a", "b"), 30)
    s, _ = make_rel(rng, 160, ("b", "c"), 30)
    t, _ = make_rel(rng, 140, ("c", "d"), 30)
    sess = JoinSession(m_budget=64)
    q = _query3(r, s, t, _linear_preds())
    cold = sess.execute(q)
    assert not cold.cache_hit and sess.cache_info["misses"] == 1

    # a warm execute must not re-classify or re-size
    calls = {"classify": 0, "plan_query": 0}
    orig_classify = Query.classify
    orig_plan_query = planner.plan_query

    def probe_classify(self, *a, **kw):
        calls["classify"] += 1
        return orig_classify(self, *a, **kw)

    def probe_plan_query(*a, **kw):
        calls["plan_query"] += 1
        return orig_plan_query(*a, **kw)

    monkeypatch.setattr(Query, "classify", probe_classify)
    monkeypatch.setattr(planner, "plan_query", probe_plan_query)
    warm = sess.execute(q)
    assert warm.cache_hit and calls == {"classify": 0, "plan_query": 0}
    assert int(warm.count) == int(cold.count)

    # changed cardinalities miss the cache (plans are size-dependent)
    r2, _ = make_rel(rng, 220, ("a", "b"), 30)
    again = sess.execute(_query3(r2, s, t, _linear_preds()))
    assert not again.cache_hit and calls["plan_query"] == 1


# --------------------------------------------------------------------------
# satellite regressions: base_salt plumbing + int64 fused traffic
# --------------------------------------------------------------------------

def test_engine_plan_build_keeps_base_salt(rng):
    """Regression: EnginePlan.build() used to drop base_salt, silently
    de-randomizing every recovery round on the planner path."""
    ep = planner.plan_step("linear", 100, 100, 100, 10, m_budget=64,
                           base_salt=7)
    assert ep.base_salt == 7
    assert ep.build().base_salt == 7
    # the session plumbs its base_salt into the recovery rounds
    seen = {}
    orig = recovery.run_count_rounds

    def probe(ops, r, s, t, plan, **kw):
        seen["base_salt"] = kw.get("base_salt")
        return orig(ops, r, s, t, plan, **kw)

    r, _ = make_rel(rng, 100, ("a", "b"), 20)
    s, _ = make_rel(rng, 100, ("b", "c"), 20)
    t, _ = make_rel(rng, 100, ("c", "d"), 20)
    import repro.core.recovery as rec_mod
    try:
        rec_mod.run_count_rounds = probe
        JoinSession(m_budget=64, base_salt=11).execute(
            _query3(r, s, t, _linear_preds()), strategy="3way")
    finally:
        rec_mod.run_count_rounds = orig
    assert seen["base_salt"] == 11
    # salted and unsalted sessions agree on the exact count
    q = _query3(r, s, t, _linear_preds())
    a = JoinSession(m_budget=64, base_salt=0).execute(q)
    b = JoinSession(m_budget=64, base_salt=123).execute(q)
    assert int(a.count) == int(b.count)


def test_fused_traffic_is_int64_exact(rng):
    """The fused tuples counters must not wrap at 2^31: h_parts * t.n is
    computed limb-wise (Traffic64) and must agree with the recovery path's
    host-side int64 totals."""
    # unit: the limb arithmetic is exact where int32 wraps
    big = engine.traffic64([(1024, jnp.int32(2**22)), (1, jnp.int32(5))])
    assert int(big) == 1024 * 2**22 + 5        # 2^32 + 5: wraps in int32
    assert int(engine.traffic64([(2**20, jnp.int32(2**30 + 12345))])
               ) == 2**20 * (2**30 + 12345)
    # end-to-end: fused one-shot traffic == recovery EngineResult traffic
    r, _ = make_rel(rng, 150, ("a", "b"), 40)
    s, _ = make_rel(rng, 160, ("b", "c"), 40)
    t, _ = make_rel(rng, 140, ("c", "d"), 40)
    plan = linear3.default_plan(150, 160, 140, m_budget=64, u=4, slack=4.0)
    fused = engine.linear3_count_fused(r, s, t, plan)
    assert not bool(fused.overflowed)
    res = engine.MultiwayJoinEngine("linear").count(r, s, t, plan)
    assert res.rounds == 1
    assert int(fused.tuples_read) == int(res.tuples_read)
    assert np.asarray(res.tuples_read).dtype == np.int64


def test_fused_traffic_consistent_all_kinds(rng):
    """cyclic/star fused traffic matches the recovery formulas too."""
    from repro.core import cyclic3, star3
    r, _ = make_rel(rng, 140, ("a", "b"), 30)
    s, _ = make_rel(rng, 150, ("b", "c"), 30)
    tc_, _ = make_rel(rng, 130, ("c", "a"), 30)
    cp = cyclic3.default_plan(140, 150, 130, m_budget=64, uh=2, ug=2,
                              slack=4.0)
    fused = engine.cyclic3_count_fused(r, s, tc_, cp)
    want = (int(r.n) + cp.h_parts * int(s.n) + cp.g_parts * int(tc_.n))
    assert int(fused.tuples_read) == want
    td, _ = make_rel(rng, 130, ("c", "d"), 30)
    sp = star3.default_plan(140, 150, 130, uh=4, ug=4, slack=4.0)
    fused_star = engine.star3_count_fused(r, s, td, sp)
    assert int(fused_star.tuples_read) == (int(r.n) + int(s.n) + int(td.n))


# --------------------------------------------------------------------------
# the deprecation shims are GONE; the engine front door took their place
# --------------------------------------------------------------------------

def test_legacy_shims_removed(rng):
    """driver.engine_count / engine_per_r_counts completed their
    deprecation cycle: the module is deleted outright (see the README
    migration table), the scan baselines live on in core.reference, and
    the _legacy_query bridge still constructs the equivalent Query for
    the engine front door."""
    with pytest.raises(ImportError):
        from repro.core import driver  # noqa: F401
    from repro.core import reference
    for fn in ("linear3_count_auto", "linear3_per_r_counts_auto",
               "cyclic3_count_auto", "star3_count_auto"):
        assert callable(getattr(reference, fn))
    r, _ = make_rel(rng, 100, ("a", "b"), 20)
    s, _ = make_rel(rng, 110, ("b", "c"), 20)
    t, _ = make_rel(rng, 105, ("c", "d"), 20)
    res = engine.MultiwayJoinEngine("linear").count(r, s, t, m_budget=64)
    assert not bool(res.overflowed)
    q, cls_ = _legacy_query("linear", r, s, t, {})
    assert cls_.kind == "linear"
    assert int(JoinSession(m_budget=64).execute(
        q, classification=cls_, strategy="3way").count) == int(res.count)
