"""Training launcher: mesh + sharded state + fault-tolerant loop.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b \
        --smoke --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

On this CPU container the driver runs reduced configs on a host mesh; on a
fleet the same code path takes --production to build the (pod, data, model)
mesh.  Features exercised here and asserted by tests/examples:
  * resumable data pipeline (pure function of step)
  * checkpoint/restart (atomic, committed-only resume)
  * straggler monitor
  * optional int8 error-feedback gradient compression across "pod"
  * XLA latency-hiding flags for compute/comm overlap (--overlap)
"""

from __future__ import annotations

import argparse
import os
import time


OVERLAP_FLAGS = (
    " --xla_tpu_enable_latency_hiding_scheduler=true"
    " --xla_tpu_enable_async_collective_fusion=true"
)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config")
    ap.add_argument("--production", action="store_true",
                    help="build the (16,16) or (2,16,16) production mesh")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--fail-at", type=int, default=None,
                    help="simulate a node failure at this step (tests)")
    ap.add_argument("--overlap", action="store_true",
                    help="enable XLA latency-hiding scheduler flags")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    if args.overlap:
        os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                                   + OVERLAP_FLAGS)

    import jax
    from repro import configs
    from repro.checkpoint import CheckpointManager
    from repro.data.synthetic import TokenGenConfig, batch_at
    from repro.launch import mesh as mesh_lib
    from repro.models import zoo
    from repro.optim import AdamWConfig
    from repro.runtime import RestartableLoop, StragglerMonitor
    from repro.train import init_train_state, make_train_step

    cfg = configs.smoke(args.arch) if args.smoke else configs.get(args.arch)
    if args.production:
        mesh = mesh_lib.make_production_mesh(multi_pod=args.multi_pod)
    else:
        mesh = mesh_lib.make_host_mesh()
    mesh_lib.activate(mesh)

    model = zoo.build(cfg)
    gen = TokenGenConfig(vocab_size=cfg.vocab_size, batch=args.batch,
                         seq_len=args.seq, seed=args.seed,
                         n_frontend_tokens=cfg.n_frontend_tokens,
                         d_model=cfg.d_model)

    opt_cfg = AdamWConfig(lr=args.lr, total_steps=args.steps,
                          warmup_steps=max(args.steps // 20, 5))
    step_fn = jax.jit(make_train_step(model, opt_cfg), donate_argnums=0)

    manager = CheckpointManager(args.ckpt_dir or "/tmp/repro_ckpt",
                                every=args.ckpt_every if args.ckpt_dir
                                else 0)
    loop = RestartableLoop(manager, monitor=StragglerMonitor())

    state = init_train_state(model, jax.random.key(args.seed))
    start = 0
    if args.ckpt_dir:
        restored, start = loop.resume_step(state)
        if restored is not None:
            state = restored

    import jax.numpy as jnp
    def batch_for_step(step):
        return {k: jnp.asarray(v) for k, v in batch_at(gen, step).items()}

    losses = []

    def metrics_cb(step, metrics, stats):
        losses.append(float(metrics["loss"]))
        if step % args.log_every == 0:
            print(f"step {step:5d}  loss {float(metrics['loss']):.4f}  "
                  f"lr {float(metrics['lr']):.2e}  "
                  f"gnorm {float(metrics['grad_norm']):.3f}  "
                  f"dt {stats.last:.3f}s", flush=True)

    t0 = time.time()
    state, end_step = loop.run(state, step_fn, batch_for_step, args.steps,
                               start_step=start, fail_at=args.fail_at,
                               metrics_cb=metrics_cb)
    dt = time.time() - t0
    if args.ckpt_dir and end_step > start:
        manager.save(state, end_step)
    if losses:
        print(f"done: steps [{start},{end_step}) in {dt:.1f}s  "
              f"first loss {losses[0]:.4f}  last loss {losses[-1]:.4f}")
    return state, losses


if __name__ == "__main__":
    main()
