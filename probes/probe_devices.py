import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding
print("n devices:", len(jax.devices()))
mesh = jax.make_mesh((2, 16, 16), ("pod", "data", "model"))
print("mesh ok:", mesh.shape)

# uneven sharding probe: 56 heads over 16 model shards
mesh2 = jax.make_mesh((16, 16), ("data", "model"))
x = jax.ShapeDtypeStruct((8, 56, 128, 64), jnp.bfloat16)  # b, heads, s, hd
w = jax.ShapeDtypeStruct((64, 56, 128), jnp.bfloat16)
def f(x, w):
    return jnp.einsum("bhsd,dhe->bhse", x, w)
try:
    lowered = jax.jit(
        f,
        in_shardings=(NamedSharding(mesh2, P("data", "model", None, None)),
                      NamedSharding(mesh2, P(None, "model", None))),
        out_shardings=NamedSharding(mesh2, P("data", "model", None, None)),
    ).lower(x, w)
    c = lowered.compile()
    print("UNEVEN SHARDING OK")
    ma = c.memory_analysis()
    print("memory_analysis:", type(ma), getattr(ma, "temp_size_in_bytes", None), getattr(ma, "argument_size_in_bytes", None))
    ca = c.cost_analysis()
    ca = ca[0] if isinstance(ca, (list, tuple)) else ca
    print("cost keys sample:", {k: v for k, v in list(ca.items())[:8]})
    print("flops:", ca.get("flops"), "bytes:", ca.get("bytes accessed"))
except Exception as e:
    print("UNEVEN SHARDING FAILED:", type(e).__name__, str(e)[:500])
