"""Join planner: N-way query decomposition + the 3-way vs cascade call.

Three decision layers:
  * traffic  — the paper's closed-form tuple-traffic comparison
    (re-exported from cost_model: Examples 3/4 thresholds),
  * time     — the Appendix-A cycle model on a concrete hardware profile
    (captures the compute/DRAM/SSD terms traffic alone misses, e.g. the
    v5e case where fast host DMA shrinks the 3-way win to 2.1×),
  * execution — :func:`plan_query` is the **decomposer**: it takes a
    declarative ``core.query.Query`` over any connected acyclic graph of
    N ≥ 2 relations (cyclic allowed at N = 3, the triangle query) and
    returns an executable ``core.plan_ir.QueryPlan``.  The predicate tree
    is greedily contracted along its smallest estimated joins
    (Swami–Schiefer ``|A ⋈ B| ≈ |A||B| / max(d_A, d_B)``) into binary
    materialize steps until three relations remain; the 3-relation
    frontier is classified (linear / star by hub-cardinality ratio) and
    the Appendix-A time model picks the root: one fused, recovery-wrapped
    3-way step or two more binary steps.  3-relation queries therefore
    keep their single-step fused plans, and every cascade — including the
    legacy ``EnginePlan.run`` cascade — executes through the one plan-IR
    walker.

:func:`plan_step` is the former ``plan_query``: the 3-relation step
planner that sizes one shape plan and times one 3-way/cascade choice.
"""

from __future__ import annotations

import dataclasses

from repro.analysis.errors import PlanPerRError
from repro.core import binary_join, cyclic3, engine, linear3, plan_ir, star3
from repro.core.cost_model import (  # noqa: F401  (traffic layer)
    PlanChoice, cascaded_binary_tuples, choose_cyclic_strategy,
    choose_linear_strategy, cyclic3_tuples, linear3_tuples)
from repro.core.query import (STAR_FACT_RATIO, Classification, Predicate,
                              Query, QueryGraphError)
from repro.core.relation import Relation
from repro.perfmodel import (HW, PLASTICINE, Calibration,
                             binary_cascade_time, linear3_time,
                             star3_binary_time, star3_time)


@dataclasses.dataclass(frozen=True)
class TimedChoice:
    strategy: str            # "3way" | "cascade"
    t_3way_s: float          # calibrated when a Calibration was applied
    t_cascade_s: float
    speedup: float           # cascade / 3way (>1 favors the 3-way)
    bottleneck_3way: str
    bottleneck_cascade: str
    calibration: str = "identity"   # Calibration.source that scaled this


def _timed(t3, tc, cal: Calibration | None) -> TimedChoice:
    """Compare two Breakdowns, optionally re-anchored by measured bench
    constants (``perfmodel.calibrate``) — the decision uses the CALIBRATED
    totals, and the choice records which calibration spoke."""
    t3s, tcs = t3.total, tc.total
    src = "identity"
    if cal is not None:
        t3s, tcs = cal.scaled(t3s, tcs)
        src = cal.source
    return TimedChoice("3way" if t3s < tcs else "cascade",
                       t3s, tcs, tcs / t3s,
                       t3.bottleneck, tc.bottleneck, calibration=src)


def choose_linear_timed(n_r: float, n_s: float, n_t: float, d: float,
                        hw: HW = PLASTICINE, *,
                        calibration: Calibration | None = None
                        ) -> TimedChoice:
    """Self/linear 3-way vs cascade on a hardware profile (Fig 4 e/f)."""
    return _timed(linear3_time(n_r, n_s, n_t, d, hw),
                  binary_cascade_time(n_r, n_s, n_t, d, hw), calibration)


def choose_star_timed(n_r: float, n_s: float, n_t: float, d: float,
                      hw: HW = PLASTICINE, *,
                      calibration: Calibration | None = None) -> TimedChoice:
    """Star 3-way vs cascade (Fig 4 g/h/i)."""
    return _timed(star3_time(n_r, n_s, n_t, d, hw),
                  star3_binary_time(n_r, n_s, n_t, d, hw), calibration)


# --------------------------------------------------------------------------
# executable engine plans (one 3-relation step)
# --------------------------------------------------------------------------

# the "no time model ran" marker: strategy forced to 3-way, time fields
# explicitly n/a rather than a wrong estimate
FORCED_3WAY_CHOICE = TimedChoice("3way", float("nan"), float("nan"),
                                 float("inf"), "n/a", "n/a")

# legacy default column names per engine kwarg (the pre-declarative API)
_DEFAULT_COLS = {"ra": "a", "rb": "b", "sb": "b", "sc": "c", "tc": "c",
                 "ta": "a"}


@dataclasses.dataclass(frozen=True)
class EnginePlan:
    """A sized, executable 3-relation step: the timed 3-way/cascade
    decision plus the shape plan the fused engine runs with.  ``run``
    executes the chosen strategy and returns an exact count — the 3-way
    path through the recovery engine, the cascade path through the SAME
    plan-IR executor that runs multi-step query plans (the old ad-hoc
    cascade branch is retired)."""

    kind: str                                   # "linear"|"cyclic"|"star"
    strategy: str                               # "3way" | "cascade"
    shape_plan: object                          # Linear3Plan | Cyclic3Plan | Star3Plan
    choice: TimedChoice
    m_budget: int | None
    use_kernel: bool = False
    max_rounds: int = 3
    growth: float = 2.0
    base_salt: int = 0

    def build(self) -> engine.MultiwayJoinEngine:
        # base_salt MUST flow through: a plan-level salt that build()
        # drops would silently de-randomize every recovery round
        return engine.MultiwayJoinEngine(
            self.kind, use_kernel=self.use_kernel,
            max_rounds=self.max_rounds, growth=self.growth,
            base_salt=self.base_salt)

    def run(self, r, s, t, *, binding=None, **cols) -> engine.EngineResult:
        """Execute the chosen strategy.  Column names come from ``binding``
        (a ``query.Binding``, the declarative path) or the legacy
        ``rb=/sb=/...`` kwargs."""
        if binding is not None:
            cols = binding.col_kwargs()
        if self.strategy == "3way" or self.kind == "cyclic":
            return self.build().count(r, s, t, self.shape_plan,
                                      binding=binding, **cols)
        # cascade: build the 2-step plan (materialize R ⋈ S, aggregate
        # with T) and walk it through the plan-IR executor
        colmap = {k: cols.get(k, _DEFAULT_COLS[k])
                  for k in ("rb", "sb", "sc", "tc")}
        qp = plan_ir.QueryPlan(
            steps=_cascade3_steps({"r": "r", "s": "s", "t": "t"}, colmap),
            n_relations=3, kind=self.kind, strategy="cascade",
            m_budget=self.m_budget, use_kernel=self.use_kernel,
            max_rounds=self.max_rounds, growth=self.growth,
            base_salt=self.base_salt)
        res = plan_ir.execute_plan(qp, {"r": r, "s": s, "t": t})
        return plan_ir.result_as_engine(res)


def forced_3way_plan(kind: str, shape_plan, *, m_budget: int | None = None,
                     use_kernel: bool = False, max_rounds: int = 3,
                     growth: float = 2.0, base_salt: int = 0) -> EnginePlan:
    """An EnginePlan that always runs the fused 3-way engine with the
    given shape plan — no time model (the cyclic query has no 2-join
    cascade; callers with an explicit shape plan skip the planner)."""
    return EnginePlan(kind=kind, strategy="3way", shape_plan=shape_plan,
                      choice=FORCED_3WAY_CHOICE, m_budget=m_budget,
                      use_kernel=use_kernel, max_rounds=max_rounds,
                      growth=growth, base_salt=base_salt)


def plan_step(kind: str, n_r: int, n_s: int, n_t: int, d: float, *,
              m_budget: int | None = None, hw: HW = PLASTICINE,
              use_kernel: bool = False, max_rounds: int = 3,
              growth: float = 2.0, base_salt: int = 0,
              calibration: Calibration | None = None,
              **plan_kw) -> EnginePlan:
    """Size one 3-relation shape plan from the paper's partitioning rules
    AND pick its 3-way vs cascade strategy from the Appendix-A time model
    — returning an executable step rather than a recommendation.  (This
    was ``plan_query`` before the N-way decomposer took that name.)"""
    if kind in ("linear", "cyclic") and m_budget is None:
        raise ValueError(f"{kind} plans need m_budget (on-chip partition "
                         "size in tuples)")
    if kind == "linear":
        choice = choose_linear_timed(n_r, n_s, n_t, d, hw,
                                     calibration=calibration)
        shape = linear3.default_plan(n_r, n_s, n_t, m_budget=m_budget,
                                     **plan_kw)
    elif kind == "cyclic":
        # the cyclic (triangle) query has no 2-join cascade, so the
        # strategy is forced; no cyclic cycle model exists yet either
        choice = FORCED_3WAY_CHOICE
        shape = cyclic3.default_plan(n_r, n_s, n_t, m_budget=m_budget,
                                     **plan_kw)
    elif kind == "star":
        choice = choose_star_timed(n_r, n_s, n_t, d, hw,
                                   calibration=calibration)
        shape = star3.default_plan(n_r, n_s, n_t, **plan_kw)
    else:
        raise ValueError(f"unknown kind {kind!r}")
    return EnginePlan(kind=kind, strategy=choice.strategy, shape_plan=shape,
                      choice=choice, m_budget=m_budget,
                      use_kernel=use_kernel, max_rounds=max_rounds,
                      growth=growth, base_salt=base_salt)


# --------------------------------------------------------------------------
# the N-way decomposer: Query -> plan_ir.QueryPlan
# --------------------------------------------------------------------------

def _distinct_est(rel: Relation, col: str) -> int:
    """FM-sketch distinct estimate of a join column (the plan-time seed
    for Swami–Schiefer estimates).  Device-side: the sketch is built once
    per (relation, column) and cached on the Relation, so planning never
    runs a host ``np.unique`` pass over the data."""
    return rel.distinct_estimate(col)


def estimate_d(binding) -> int:
    """Distinct-value estimate for the time model: the hub relation's
    R-side join column (one sketch build, amortized by the plan cache
    and the Relation's own sketch cache)."""
    return _distinct_est(binding.rels["s"], binding.col_kwargs()["sb"])


def _cascade3_steps(role_names, colmap) -> tuple:
    """The 2-step binary cascade over a 3-relation frontier: materialize
    I = R ⋈ S exactly, aggregate I ⋈ T host-side.  ``role_names`` maps
    engine role -> input name; ``colmap`` the rb/sb/sc/tc column keys."""
    rn, sn, tn = role_names["r"], role_names["s"], role_names["t"]
    rb, sb, sc, tc = colmap["rb"], colmap["sb"], colmap["sc"], colmap["tc"]
    i0 = "%i0"
    proj_r = ((rb, f"{rn}.{rb}"),)
    proj_s = tuple({sb: f"{sn}.{sb}", sc: f"{sn}.{sc}"}.items())
    step1 = plan_ir.PlanStep(
        op="binary", out=i0, inputs=(rn, sn),
        preds=(Predicate((rn, f"{rn}.{rb}"), (sn, f"{sn}.{sb}")),),
        aggregate=False, project=(proj_r, proj_s))
    step2 = plan_ir.PlanStep(
        op="binary", out=plan_ir.COUNT, inputs=(i0, tn),
        preds=(Predicate((i0, f"{sn}.{sc}"), (tn, tc)),), aggregate=True)
    return (step1, step2)


def _swap_linear_rt(cls_: Classification) -> Classification:
    """Swap the r/t endpoint roles of a linear classification (the path
    is symmetric, so this is free) — used to land a pinned per-R
    relation on role r, where the recovery engine's per-R rounds live."""
    cm, rm = cls_.col_map, cls_.role_map
    return Classification(
        kind=cls_.kind, shape=cls_.shape,
        roles=(("r", rm["t"]), ("s", rm["s"]), ("t", rm["r"])),
        cols=(("rb", cm["tc"]), ("sb", cm["sc"]),
              ("sc", cm["sb"]), ("tc", cm["rb"])))


def pin_per_r_classification(cls_: Classification,
                             per_r_name: str) -> Classification:
    """Validate + adjust a 3-relation classification so a pinned per-R
    relation lands on engine role r, where the recovery engine's per-R
    rounds live.  Star relaxes to the linear layout (per-R rounds are
    linear-engine ops, and every star is also a valid path); cyclic and
    centre pins are errors."""
    if cls_.kind == "cyclic":
        raise PlanPerRError(
            "per-R counts are defined for linear (path) queries; this "
            "query classified as 'cyclic'")
    if cls_.kind == "star":
        cls_ = Classification(kind="linear", shape=cls_.shape,
                              roles=cls_.roles, cols=cls_.cols)
    role_map = cls_.role_map
    if per_r_name == role_map["s"]:
        raise PlanPerRError(
            f"per-R relation {per_r_name!r} is the path centre; per-R "
            "counts group by a path endpoint")
    if per_r_name == role_map["t"]:
        cls_ = _swap_linear_rt(cls_)
    return cls_


def _single_fused_plan(query: Query, cls_: Classification, ep: EnginePlan,
                       per_r_key: str | None = None) -> plan_ir.QueryPlan:
    """Wrap a sized 3-relation EnginePlan as a one-step QueryPlan (the
    path every 3-relation fused query takes — plan-cache compatible)."""
    role_map = dict(cls_.roles)
    step = plan_ir.PlanStep(
        op="fused3", out=plan_ir.COUNT,
        inputs=tuple(role_map[r] for r in ("r", "s", "t")),
        preds=(), aggregate=True, kind=cls_.kind, roles=cls_.roles,
        cols=cls_.cols, shape_plan=ep.shape_plan, choice=ep.choice,
        per_r_key=per_r_key)
    return plan_ir.QueryPlan(
        steps=(step,), n_relations=len(query.relations), kind=cls_.kind,
        strategy="3way", m_budget=ep.m_budget, use_kernel=ep.use_kernel,
        max_rounds=ep.max_rounds, growth=ep.growth, base_salt=ep.base_salt)


class _Node:
    """One vertex of the contraction graph: a base relation or a planned
    intermediate.  ``colmap`` maps origin ``(relation, column)`` pairs to
    the vertex's CURRENT column keys (base columns keep their names,
    intermediate columns are ``"rel.col"``); ``d`` carries per-origin
    distinct estimates, capped by the vertex's estimated cardinality."""

    __slots__ = ("name", "order", "card", "colmap", "d")

    def __init__(self, name, order, card, colmap, d):
        self.name, self.order, self.card = name, order, max(1, int(card))
        self.colmap, self.d = colmap, d


def _edge_est(nodes, e) -> float:
    """Swami–Schiefer estimated join size of a live edge."""
    na, nb = nodes[e["ends"][0]], nodes[e["ends"][1]]
    d = 1
    for o in (e["pred"].left, e["pred"].right):
        for node in (na, nb):
            if o in node.colmap:
                d = max(d, node.d.get(o, 1))
    return max(1.0, (float(na.card) * float(nb.card)) / d)


def _contract(nodes, live, e, steps, k) -> str:
    """Contract live edge ``e`` into a binary materialize step; returns
    the new intermediate's name.  Projections keep exactly the origins
    the remaining edges still reference (plus this step's join keys)."""
    na_name, nb_name = e["ends"]
    na, nb = nodes[na_name], nodes[nb_name]
    out = f"%i{k}"
    down = set()
    for e2 in live:
        if e2 is e:
            continue
        for o in (e2["pred"].left, e2["pred"].right):
            if o in na.colmap or o in nb.colmap:
                down.add(o)
    jl, jr = e["pred"].left, e["pred"].right

    def side(node):
        origins = sorted({o for o in down if o in node.colmap}
                         | {o for o in (jl, jr) if o in node.colmap})
        proj = tuple((node.colmap[o], f"{o[0]}.{o[1]}") for o in origins)
        return origins, proj

    _, proj_a = side(na)
    _, proj_b = side(nb)
    key_l = jl if jl in na.colmap else jr
    key_r = jr if key_l is jl else jl
    pred = Predicate((na_name, f"{key_l[0]}.{key_l[1]}"),
                     (nb_name, f"{key_r[0]}.{key_r[1]}"))
    est_out = int(_edge_est(nodes, e))
    steps.append(plan_ir.PlanStep(
        op="binary", out=out, inputs=(na_name, nb_name), preds=(pred,),
        aggregate=False, project=(proj_a, proj_b),
        est_rows=(na.card, nb.card), est_out=est_out))
    colmap, d = {}, {}
    for o in down:
        owner = na if o in na.colmap else nb
        colmap[o] = f"{o[0]}.{o[1]}"
        d[o] = min(owner.d.get(o, owner.card), max(1, est_out))
    nodes[out] = _Node(out, min(na.order, nb.order), est_out, colmap, d)
    del nodes[na_name], nodes[nb_name]
    live.remove(e)
    for e2 in live:
        e2["ends"] = [out if x in (na_name, nb_name) else x
                      for x in e2["ends"]]
    return out


def _node_key(nodes, node_name, pred) -> str:
    node = nodes[node_name]
    for o in (pred.left, pred.right):
        if o in node.colmap:
            return node.colmap[o]
    raise AssertionError(f"predicate {pred} has no endpoint in {node_name}")


def plan_query(query: Query, cards=None, *, m_budget: int | None = None,
               hw: HW = PLASTICINE, use_kernel: bool = False,
               max_rounds: int = 3, growth: float = 2.0, base_salt: int = 0,
               star_fact_ratio: float | None = None,
               strategy: str | None = None,
               classification: Classification | None = None,
               calibration: Calibration | None = None,
               per_r_name: str | None = None, per_r_key: str = "a",
               **plan_kw) -> plan_ir.QueryPlan:
    """Decompose a declarative Query into an executable multi-step plan.

    * 3 relations — classify (triangle / star / linear) and either emit
      the single fused, recovery-wrapped 3-way step or (when the time
      model or ``strategy="cascade"`` says so) the 2-step binary cascade.
    * 2 relations — one binary aggregate step.
    * N ≥ 4, acyclic — greedily contract the predicate tree along its
      smallest estimated joins into binary materialize steps until three
      vertices remain, then plan the frontier like a 3-relation query
      (fused root sized at execute time from the live intermediates).

    ``strategy``: ``None`` lets the Appendix-A time model decide per
    root; ``"3way"`` forces the fused engine at the root; ``"cascade"``
    forces all-binary.  ``cards`` overrides the live cardinalities.
    ``calibration`` re-anchors the time model's constants from measured
    bench data (``perfmodel.calibrate``); ``None`` keeps the hand-set
    Appendix-A constants.

    ``per_r_name`` pins one relation for per-key group counts: the plan
    gets a fused linear root with that relation in role r and the
    declarative ``per_r_key`` stamped on the root step, which the
    executor answers via the recovery engine's per-R rounds.  The pinned
    relation must be a path endpoint (3 relations) or a leaf of the
    predicate tree (N ≥ 4) — its join edge is excluded from contraction
    so it survives to the root.
    """
    if isinstance(query, str):
        raise TypeError(
            "plan_query now takes a core.query.Query (it is the N-way "
            "decomposer); the 3-relation step planner is plan_step(kind, "
            "n_r, n_s, n_t, d, ...)")
    if strategy not in (None, "3way", "cascade"):
        raise ValueError(f"unknown strategy {strategy!r}: pass None "
                         "(planner decides), '3way' (force the fused "
                         "multiway engine) or 'cascade' (force the "
                         "binary cascade)")
    ratio = STAR_FACT_RATIO if star_fact_ratio is None else star_fact_ratio
    rels = query.relations
    names = list(rels)
    n = len(names)
    if per_r_name is not None:
        if per_r_name not in rels:
            raise PlanPerRError(f"per-R relation {per_r_name!r} is not one "
                                f"of the query's relations {sorted(rels)}")
        if per_r_key not in rels[per_r_name].columns:
            raise PlanPerRError(f"per-R key column {per_r_key!r} is not a "
                                f"column of relation {per_r_name!r}")
        if strategy == "cascade":
            raise PlanPerRError("per-R counts need the fused multiway root "
                                "(recovery per-R rounds); they have no "
                                "binary-cascade form")
        if n == 2:
            raise PlanPerRError("per-R counts need a fused 3-way root; a "
                                "2-relation query has none")
        # the fused root IS the per-R implementation — pin it
        strategy = "3way"
    if cards is None:
        cards = {nm: int(rel.n) for nm, rel in rels.items()}
    edges = query.edges()

    # connectivity over ALL N relations (classify only checks 3)
    adj: dict[str, list[str]] = {nm: [] for nm in names}
    for key in edges:
        a, b = tuple(key)
        adj[a].append(b)
        adj[b].append(a)
    seen, frontier = {names[0]}, [names[0]]
    while frontier:
        for nxt in adj[frontier.pop()]:
            if nxt not in seen:
                seen.add(nxt)
                frontier.append(nxt)
    if seen != set(names):
        missing = sorted(set(names) - seen)
        raise QueryGraphError(
            f"predicate graph is disconnected: relation(s) {missing} "
            "join nothing reachable from the rest of the query")

    cfg = dict(m_budget=m_budget, use_kernel=use_kernel,
               max_rounds=max_rounds, growth=growth, base_salt=base_salt)

    if n == 2:
        if strategy == "3way":
            raise ValueError("a 2-relation query is a single binary hash "
                             "join; it has no 3-way plan")
        (pred,) = edges.values()
        step = plan_ir.PlanStep(op="binary", out=plan_ir.COUNT,
                                inputs=(pred.left[0], pred.right[0]),
                                preds=(pred,), aggregate=True)
        return plan_ir.QueryPlan(steps=(step,), n_relations=2,
                                 kind="binary", strategy="cascade", **cfg)

    if n == 3:
        cls_ = classification or query.classify(cards,
                                                star_fact_ratio=ratio)
        if per_r_name is not None:
            cls_ = pin_per_r_classification(cls_, per_r_name)
        role_map = dict(cls_.roles)
        n_r, n_s, n_t = (cards[role_map[k]] for k in ("r", "s", "t"))
        if strategy == "cascade":
            if cls_.kind == "cyclic":
                raise ValueError("the cyclic (triangle) query has no "
                                 "2-join binary cascade")
            return plan_ir.QueryPlan(
                steps=_cascade3_steps(role_map, dict(cls_.cols)),
                n_relations=3, kind=cls_.kind, strategy="cascade", **cfg)
        if strategy == "3way":
            if cls_.kind != "star" and m_budget is None:
                raise ValueError(f"{cls_.kind} plans need m_budget")
            shape = engine.MultiwayJoinEngine(cls_.kind).default_plan(
                n_r, n_s, n_t, m_budget=m_budget, **plan_kw)
            ep = forced_3way_plan(cls_.kind, shape, **cfg)
        else:
            ep = plan_step(cls_.kind, n_r, n_s, n_t,
                           estimate_d(query.bind(cls_)), hw=hw,
                           calibration=calibration, **cfg, **plan_kw)
        if ep.strategy == "3way":
            return _single_fused_plan(query, cls_, ep,
                                      per_r_key=(per_r_key if per_r_name
                                                 else None))
        return plan_ir.QueryPlan(
            steps=_cascade3_steps(role_map, dict(cls_.cols)),
            n_relations=3, kind=cls_.kind, strategy="cascade", **cfg)

    # ---- N >= 4: acyclic (tree) decomposition ---------------------------
    if classification is not None:
        raise ValueError("forced classifications only apply to "
                         "3-relation queries")
    if len(edges) != n - 1:
        raise QueryGraphError(
            f"cyclic predicate graphs are only supported at 3 relations "
            f"(the triangle query); this {n}-relation query has "
            f"{len(edges)} predicates — N-way queries must form a tree "
            "(connected and acyclic)")
    if per_r_name is not None and len(adj[per_r_name]) != 1:
        raise PlanPerRError(
            f"per-R relation {per_r_name!r} joins "
            f"{len(adj[per_r_name])} relations; N-way per-R counts need "
            "the pinned relation to be a leaf of the predicate tree (so "
            "it can survive contraction to the fused root)")

    nodes: dict[str, _Node] = {}
    for i, nm in enumerate(names):
        refs = sorted({col for p in query.predicates
                       for rn2, col in (p.left, p.right) if rn2 == nm})
        nodes[nm] = _Node(
            nm, i, cards[nm], {(nm, c): c for c in refs},
            {(nm, c): min(_distinct_est(rels[nm], c), max(1, cards[nm]))
             for c in refs})
    live = [{"ends": [p.left[0], p.right[0]], "pred": p}
            for p in edges.values()]

    steps: list = []
    k = 0
    while len(nodes) > 3:
        # a pinned per-R leaf's edge is never contracted, so the pinned
        # relation survives to the 3-vertex frontier as an endpoint
        cands = [ie for ie in enumerate(live)
                 if per_r_name not in ie[1]["ends"]]
        e = min(cands, key=lambda ie: (_edge_est(nodes, ie[1]), ie[0]))[1]
        _contract(nodes, live, e, steps, k)
        k += 1

    # frontier: 3 vertices, 2 edges — a path; classify like a 3-rel query
    e1, e2 = live
    (centre,) = set(e1["ends"]) & set(e2["ends"])
    order = sorted(nodes.values(), key=lambda nd: nd.order)
    ends = [nd.name for nd in order if nd.name != centre]
    rn_, tn = ends[0], ends[1]
    if per_r_name is not None and tn == per_r_name:
        rn_, tn = tn, rn_     # per-R rounds live on role r
    e_rc = e1 if rn_ in e1["ends"] else e2
    e_ct = e2 if e_rc is e1 else e1
    n_r, n_s, n_t = nodes[rn_].card, nodes[centre].card, nodes[tn].card
    kind = "star" if n_s >= ratio * max(n_r, n_t, 1) else "linear"
    if per_r_name is not None:
        # per-R rounds are linear-engine ops; the linear root is correct
        # for any path frontier (star is only a layout optimization)
        kind = "linear"
    cols = (("rb", _node_key(nodes, rn_, e_rc["pred"])),
            ("sb", _node_key(nodes, centre, e_rc["pred"])),
            ("sc", _node_key(nodes, centre, e_ct["pred"])),
            ("tc", _node_key(nodes, tn, e_ct["pred"])))
    sb_origin = next(o for o in (e_rc["pred"].left, e_rc["pred"].right)
                     if o in nodes[centre].colmap)
    d_est = nodes[centre].d.get(sb_origin, n_s)
    if strategy is None:
        timed = (choose_star_timed if kind == "star"
                 else choose_linear_timed)
        choice = timed(n_r, n_s, n_t, d_est, hw, calibration=calibration)
    else:
        choice = FORCED_3WAY_CHOICE if strategy == "3way" else None
    root_3way = (strategy == "3way"
                 or (strategy is None and choice.strategy == "3way"))
    if root_3way:
        if kind != "star" and m_budget is None:
            raise ValueError(f"{kind} plans need m_budget (on-chip "
                             "partition size in tuples)")

        def frontier_pred(e):
            p, (a, b) = e["pred"], e["ends"]
            return Predicate((a, _node_key(nodes, a, p)),
                             (b, _node_key(nodes, b, p)))
        steps.append(plan_ir.PlanStep(
            op="fused3", out=plan_ir.COUNT, inputs=(rn_, centre, tn),
            preds=(frontier_pred(e_rc), frontier_pred(e_ct)),
            aggregate=True, kind=kind,
            roles=(("r", rn_), ("s", centre), ("t", tn)), cols=cols,
            shape_plan=None, choice=choice,
            est_rows=(n_r, n_s, n_t),
            per_r_key=(per_r_key if per_r_name else None)))
        label = "hybrid" if len(steps) > 1 else "3way"
    else:
        # all-binary tail: contract (R, centre), aggregate with T
        i_name = _contract(nodes, live, e_rc, steps, k)
        (e_last,) = live
        a, b = e_last["ends"]
        steps.append(plan_ir.PlanStep(
            op="binary", out=plan_ir.COUNT, inputs=(a, b),
            preds=(Predicate((a, _node_key(nodes, a, e_last["pred"])),
                             (b, _node_key(nodes, b, e_last["pred"]))),),
            aggregate=True, choice=choice,
            est_rows=(nodes[a].card, nodes[b].card)))
        assert i_name in (a, b)
        label = "cascade"
    return plan_ir.QueryPlan(steps=tuple(steps), n_relations=n, kind=kind,
                             strategy=label, **cfg)


# re-export for callers that sized intermediates via the old helper name
exact_join_count = binary_join.exact_join_count
