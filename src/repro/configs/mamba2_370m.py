"""mamba2-370m — pure SSD (state-space duality) stack, attention-free
[arXiv:2405.21060; unverified].

48L d_model=1024 ssm_state=128 vocab=50280 (d_ff=0: no MLP — Mamba2 blocks
interleave nothing).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-370m", family="ssm",
    n_layers=48, d_model=1024, n_heads=1, n_kv_heads=1,
    d_ff=0, vocab_size=50280, head_dim=64,
    ssm_state=128, ssm_expand=2, ssm_headdim=64, ssm_conv=4, ssm_ngroups=1,
    tie_embeddings=True, norm_eps=1e-5,
    accum_steps=2,
)

SMOKE = ModelConfig(
    name="mamba2-370m-smoke", family="ssm",
    n_layers=4, d_model=64, n_heads=1, n_kv_heads=1,
    d_ff=0, vocab_size=512, head_dim=16,
    ssm_state=16, ssm_expand=2, ssm_headdim=16, ssm_conv=4, ssm_ngroups=1,
    tie_embeddings=True, norm_eps=1e-5, remat=False,
)
