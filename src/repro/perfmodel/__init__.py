from repro.perfmodel.calibrate import (  # noqa: F401
    CALIBRATION_FILE, IDENTITY, Calibration, calibration_from_bench,
    calibration_from_file, refresh_calibration_file)
from repro.perfmodel.hw import CPU_XEON, HW, PLASTICINE, TPU_V5E  # noqa: F401
from repro.perfmodel.model import (  # noqa: F401
    Breakdown, binary_cascade_time, cpu_cascade_time, linear3_time,
    star3_binary_time, star3_time)
