"""Static analysis over the plan IR and the repo's exactness invariants.

Four entry points (see README "Static analysis & invariants"):

  verify_plan      — pure static checker over ``QueryPlan`` DAGs (topo
                     order, def-use, schema propagation, refcounts, per-R
                     pins); always-on at session plan time, re-checked per
                     execute under ``REPRO_VERIFY_PLANS=1``
  widths           — integer-width dataflow analysis: bound every
                     composite-id space, flat slot index, fused
                     accumulator cell and Traffic64 limb from plan-time
                     estimates (or live cardinalities) and flag int32 /
                     f32-exactness hazards before any kernel runs
  lint_invariants  — AST lint over ``src/repro`` enforcing the repo-wide
                     rules (one mutation point, oracle-only np.unique,
                     SENTINEL-derived sentinels, integer count
                     accumulation, dispatch-gated interpret-only kernels);
                     ``tools/check_invariants.py`` is the CI runner
  arena_sanitizer  — opt-in dynamic shadow of ``execute_plan``'s
                     refcounting arena and the streaming residents
                     (``REPRO_SANITIZE_ARENA=1``)

Submodules import lazily: ``analysis.errors`` sits below ``core.plan_ir``
in the import graph (the executor raises the shared typed errors), so this
package must be importable without touching ``repro.core``.
"""

from __future__ import annotations

import importlib

from repro.analysis.errors import (  # noqa: F401
    PlanPerRError, PlanRefcountError, PlanSchemaError, PlanStructureError,
    PlanValidationError, PlanWidthError)

_SUBMODULES = ("arena_sanitizer", "errors", "lint_invariants", "verify_plan",
               "widths")


def __getattr__(name: str):
    if name in _SUBMODULES:
        return importlib.import_module(f"repro.analysis.{name}")
    raise AttributeError(f"module 'repro.analysis' has no attribute {name!r}")


def __dir__():
    return sorted(list(globals()) + list(_SUBMODULES))
