"""End-to-end training driver: train a ~100M-param qwen2-family model for
a few hundred steps with checkpoint/restart, straggler monitoring, and
microbatch gradient accumulation.

    PYTHONPATH=src python examples/train_lm.py [--steps 300] [--d-model 256]

On this CPU container the default is a ~20M config for wall-clock sanity
(--full-100m selects the true ~100M layout; same code path).  Loss is
expected to fall from ~ln(V) as the model memorizes the synthetic stream.
A mid-run simulated crash + resume demonstrates the fault-tolerance path
(disable with --no-crash).
"""

import argparse
import dataclasses
import pathlib
import sys
import tempfile

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent
                       / "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.checkpoint import CheckpointManager  # noqa: E402
from repro.data.synthetic import TokenGenConfig, batch_at  # noqa: E402
from repro.models import zoo  # noqa: E402
from repro.models.config import ModelConfig  # noqa: E402
from repro.optim import AdamWConfig  # noqa: E402
from repro.runtime import RestartableLoop  # noqa: E402
from repro.train import init_train_state, make_train_step  # noqa: E402


def small_cfg(d_model: int, n_layers: int, vocab: int) -> ModelConfig:
    return ModelConfig(
        name=f"qwen2-train-demo-{d_model}", family="dense",
        n_layers=n_layers, d_model=d_model, n_heads=max(d_model // 64, 2),
        n_kv_heads=max(d_model // 128, 1), d_ff=d_model * 4,
        vocab_size=vocab, qkv_bias=True, tie_embeddings=True,
        remat=False, accum_steps=2)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--vocab", type=int, default=4096)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--full-100m", action="store_true",
                    help="d_model=768, 12 layers, 32k vocab (~100M params)")
    ap.add_argument("--no-crash", action="store_true")
    ap.add_argument("--ckpt-dir", default="")
    args = ap.parse_args()

    if args.full_100m:
        cfg = small_cfg(768, 12, 32768)
    else:
        cfg = small_cfg(args.d_model, args.layers, args.vocab)
    model = zoo.build(cfg)
    n_params = cfg.param_count()
    print(f"model {cfg.name}: {n_params / 1e6:.1f}M params, "
          f"accum_steps={cfg.accum_steps}")

    gen = TokenGenConfig(vocab_size=cfg.vocab_size, batch=args.batch,
                         seq_len=args.seq, seed=0)
    opt = AdamWConfig(lr=args.lr, total_steps=args.steps,
                      warmup_steps=max(args.steps // 20, 10))
    step_fn = jax.jit(make_train_step(model, opt), donate_argnums=0)
    batch_for = lambda s: {k: jnp.asarray(v)            # noqa: E731
                           for k, v in batch_at(gen, s).items()}

    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="repro_train_")
    manager = CheckpointManager(ckpt_dir, every=50, keep=2)
    loop = RestartableLoop(manager)

    def metrics_cb(step, metrics, stats):
        if step % 20 == 0:
            print(f"step {step:4d}  loss {float(metrics['loss']):.4f}  "
                  f"gnorm {float(metrics['grad_norm']):.3f}  "
                  f"dt {stats.last:.2f}s", flush=True)

    state = init_train_state(model, jax.random.key(0))
    first_loss = float(step_fn(state, batch_for(0))[1]["loss"])
    state = init_train_state(model, jax.random.key(0))

    crash_at = None if args.no_crash else min(args.steps // 2, 120)
    try:
        state, end = loop.run(state, step_fn, batch_for, args.steps,
                              fail_at=crash_at, metrics_cb=metrics_cb)
    except RuntimeError as e:
        print(f"!! {e} — restarting from the newest committed checkpoint")
        template = jax.eval_shape(
            lambda: init_train_state(model, jax.random.key(0)))
        resumed, start = loop.resume_step(template)
        state, end = loop.run(resumed, step_fn, batch_for, args.steps,
                              start_step=start, metrics_cb=metrics_cb)

    final_loss = float(
        make_train_step(model, opt)(state, batch_for(end))[1]["loss"])
    print(f"\ndone @ step {end}: loss {first_loss:.3f} -> "
          f"{final_loss:.3f} (ckpts in {ckpt_dir})")
    assert final_loss < first_loss, "training did not reduce loss"


if __name__ == "__main__":
    main()
