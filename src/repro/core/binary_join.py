"""Binary hash join and the cascaded-binary baseline (paper §6.3).

Two execution paths:

* **sorted path** (`join_count`, `join_materialize`, `probe_weight_sum`) —
  exact joins via sort + searchsorted range probes.  O((n+m) log n), static
  shapes, used as the in-framework oracle and for fast aggregates.

* **bucketed path** (`bucketed_join_count`) — the accelerator-shaped
  execution: hash-partition both sides into `[n_buckets, capacity]` grids
  (PMU layout) and run the per-bucket compare kernel from
  ``repro.kernels.ops``.  This is the structure Algorithm 1 builds on and is
  exact as long as no bucket overflows (overflow is returned, never hidden).

The cascade (first join materialized, second join aggregated) reproduces the
paper's binary baseline, including the bounded intermediate buffer whose
overflow models the DRAM/SSD spill cliff.

Device-resident sizing and the staged pipeline
----------------------------------------------
``exact_join_count`` used to be two host ``np.unique`` passes; it is now a
device-side sorted-key histogram: sort the build keys once, ``searchsorted``
the probe keys against them (per-probe segment counts), and reduce those
counts exactly in int64 via the two-limb base-2^15 trick the engine's
``Traffic64`` counters use (x64 stays off framework-wide).  The only
host↔device traffic is the two-scalar total.  The same primitive split into
``stage_join`` (sort + ranges + count, one jitted dispatch) and
``gather_staged`` (prefix-sum offsets + gather-materialize into a
bucketed-capacity buffer, one jitted dispatch) is the plan executor's
compiled binary-step pipeline: a cascade of binary steps never moves a
column to the host.  ``host_join_count`` keeps the old ``np.unique``
histogram as the parity oracle.
"""

from __future__ import annotations

import functools
import math
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import partition
from repro.core.reference import host_join_count  # noqa: F401  (oracle —
#   lives in core.reference now, the one np.unique-allowed module; kept
#   re-exported here because it is THE parity oracle for this module)
from repro.core.relation import SENTINEL, Relation

_MASK15 = 0x7FFF


def _sum64(x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Exact Σx over non-negative int32 values as two int32 limbs
    ``(hi, lo)`` with ``lo < 2^30`` and ``hi`` in units of 2^30 (the
    ``engine.Traffic64`` representation; ``int(hi) << 30 | lo`` recombines
    host-side).

    x64 is off framework-wide, so the reduction runs in base-2^15 limb
    planes: each plane value stays < 2^15, chunked partial sums of 2^14
    elements stay < 2^30, and carries re-normalize between levels.  Exact
    for totals < 2^61.
    """
    x = x.reshape(-1)
    if x.shape[0] == 0:
        return jnp.int32(0), jnp.int32(0)
    # base-2^15 limb planes of each element (x < 2^31 ⇒ 3 planes)
    planes = [x & _MASK15, (x >> 15) & _MASK15, x >> 30]
    chunk = 1 << 14
    while planes[0].shape[0] > 1:
        n = planes[0].shape[0]
        m = -(-n // chunk)
        pad = m * chunk - n
        carry = None
        nxt = []
        for p in planes:
            s = jnp.sum(jnp.pad(p, (0, pad)).reshape(m, chunk), axis=1)
            if carry is not None:
                s = s + carry            # partial < 2^29 + 2^15 < 2^30
            nxt.append(s & _MASK15)
            carry = s >> 15              # < 2^15: a valid next plane
        nxt.append(carry)
        planes = nxt
    p = [pl.reshape(()) for pl in planes] + [jnp.int32(0)] * 5
    lo = p[0] + (p[1] << 15)
    hi = p[2] + (p[3] << 15) + (p[4] << 30)
    return hi, lo


def _device_count(build: Relation, probe: Relation, *, build_key: str,
                  probe_key: str):
    """Sorted-key histogram count: per-probe segment counts + exact
    two-limb reduction, all on device."""
    _, skeys = partition.sort_by_key(build, build_key)
    lo, hi = match_ranges(skeys, probe.col(probe_key))
    cnt = jnp.where(probe.valid, hi - lo, 0).astype(jnp.int32)
    return _sum64(cnt)


_device_count_jit = jax.jit(_device_count,
                            static_argnames=("build_key", "probe_key"))


def exact_join_count(build: Relation, build_key: str,
                     probe: Relation, probe_key: str) -> int:
    """Exact ``|build ⋈ probe|``, int64-exact without x64: one jitted
    device dispatch (sort + searchsorted segment counts + two-limb
    reduction), one two-scalar transfer.  The plan IR uses this both to
    size materialized intermediates exactly (a materialize step cannot
    overflow) and as the root aggregate of an all-binary cascade —
    ``host_join_count`` is the np.unique oracle it is tested against."""
    hi, lo = _device_count_jit(build, probe, build_key=build_key,
                               probe_key=probe_key)
    return (int(hi) << 30) + int(lo)


# --------------------------------------------------------------------------
# sorted-path primitives
# --------------------------------------------------------------------------

def match_ranges(sorted_keys: jnp.ndarray, probe_keys: jnp.ndarray):
    """For each probe key, the [lo, hi) range of equal keys in sorted_keys."""
    lo = jnp.searchsorted(sorted_keys, probe_keys, side="left")
    hi = jnp.searchsorted(sorted_keys, probe_keys, side="right")
    return lo.astype(jnp.int32), hi.astype(jnp.int32)


def join_count(build: Relation, build_key: str,
               probe: Relation, probe_key: str) -> jnp.ndarray:
    """Exact number of matching (build, probe) pairs."""
    _, skeys = partition.sort_by_key(build, build_key)
    lo, hi = match_ranges(skeys, probe.col(probe_key))
    cnt = jnp.where(probe.valid, hi - lo, 0)
    return jnp.sum(cnt.astype(jnp.int64) if cnt.dtype == jnp.int64
                   else cnt.astype(jnp.int32)).astype(jnp.int32)


def probe_weight_sum(build: Relation, build_key: str, build_weights: jnp.ndarray,
                     probe_keys: jnp.ndarray, probe_valid: jnp.ndarray) -> jnp.ndarray:
    """For each probe row: sum of weights over matching build rows.

    The workhorse for per-key multiway aggregates: weights flow backwards
    through each join stage (T -> S -> R) without materializing anything.
    """
    srel, skeys = partition.sort_by_key(build, build_key)
    # weights must be permuted identically to the sort; recompute the order.
    keys = jnp.where(build.valid, build.col(build_key), jnp.int32(0x7FFFFFFF))
    order = jnp.argsort(keys, stable=True)
    w = jnp.where(build.valid, build_weights, 0)[order]
    cw = jnp.concatenate([jnp.zeros((1,), w.dtype), jnp.cumsum(w)])
    lo, hi = match_ranges(skeys, probe_keys)
    out = cw[hi] - cw[lo]
    return jnp.where(probe_valid, out, 0)


class MaterializeResult(NamedTuple):
    rel: Relation            # materialized join, fixed capacity, masked
    total: jnp.ndarray       # true (unclipped) number of result tuples
    overflowed: jnp.ndarray  # () bool — result exceeded out_capacity


def join_materialize(build: Relation, build_key: str,
                     probe: Relation, probe_key: str,
                     out_capacity: int,
                     build_prefix: str = "", probe_prefix: str = "") -> MaterializeResult:
    """Materialize the equi-join into a fixed-capacity Relation.

    Used for the cascaded-binary intermediate I = R ⋈ S (paper §6.3): the
    intermediate is written out (to DRAM in the paper) before the second
    join; ``overflowed`` models the spill condition.
    """
    sbuild, skeys = partition.sort_by_key(build, build_key)
    lo, hi = match_ranges(skeys, probe.col(probe_key))
    cnt = jnp.where(probe.valid, hi - lo, 0).astype(jnp.int32)
    off = jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(cnt)])
    total = off[-1]

    slots = jnp.arange(out_capacity, dtype=jnp.int32)
    # probe row owning output slot p: last i with off[i] <= p
    owner = jnp.searchsorted(off, slots, side="right").astype(jnp.int32) - 1
    owner = jnp.clip(owner, 0, probe.capacity - 1)
    rank = slots - off[owner]
    bidx = jnp.clip(lo[owner] + rank, 0, build.capacity - 1)
    ok = slots < total

    cols = {}
    for name, col in sbuild.columns.items():
        cols[build_prefix + name] = jnp.where(ok, col[bidx],
                                              jnp.int32(SENTINEL))
    for name, col in probe.columns.items():
        key = probe_prefix + name
        if key in cols:  # join column appears once
            continue
        cols[key] = jnp.where(ok, col[owner], jnp.int32(SENTINEL))
    return MaterializeResult(Relation(cols, ok), total, total > out_capacity)


# --------------------------------------------------------------------------
# compiled binary-step pipeline (the plan executor's hot path)
# --------------------------------------------------------------------------

class StagedJoin(NamedTuple):
    """Stage 1 of a pipelined binary step, still on device: the sorted
    build side, the per-probe match ranges, and the exact two-limb total.
    ``staged_total`` syncs the two scalars; ``gather_staged`` finishes the
    materialization without re-sorting."""

    sorted_build: Relation     # build side sorted by its join key
    lo: jnp.ndarray            # (probe_cap,) int32 match-range starts
    cnt: jnp.ndarray           # (probe_cap,) int32 per-probe match counts
    total_hi: jnp.ndarray      # () int32, units of 2^30
    total_lo: jnp.ndarray      # () int32, < 2^30


def _stage_core(build: Relation, probe: Relation, *, build_key: str,
                probe_key: str) -> StagedJoin:
    sbuild, skeys = partition.sort_by_key(build, build_key)
    lo, hi = match_ranges(skeys, probe.col(probe_key))
    cnt = jnp.where(probe.valid, hi - lo, 0).astype(jnp.int32)
    thi, tlo = _sum64(cnt)
    return StagedJoin(sbuild, lo, cnt, thi, tlo)


stage_join = jax.jit(_stage_core, static_argnames=("build_key", "probe_key"))


def staged_total(staged: StagedJoin) -> int:
    """Host-sync the exact join cardinality of a staged step (two int32
    scalars — the pipeline's only host↔device traffic)."""
    return (int(staged.total_hi) << 30) + int(staged.total_lo)


def bucket_capacity(total: int) -> int:
    """Static materialization capacity for an exact row total: the next
    power of two (>= 64).  Log-bucketing the shape (same idea as
    ``sketches.card_bucket``) means refreshed executions at a similar
    scale hit the SAME compiled gather — at most 2x buffer slack."""
    return max(64, 1 << math.ceil(math.log2(int(total) + 8)))


def _gather_core(sorted_build: Relation, lo: jnp.ndarray, cnt: jnp.ndarray,
                 probe: Relation, *, out_capacity: int,
                 build_prefix: str = "", probe_prefix: str = "") -> Relation:
    """Stage 2: prefix-sum offsets + gather-materialize (one dispatch).
    ``out_capacity`` must cover the staged total (int32 offsets)."""
    off = jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(cnt)])
    total = off[-1]
    slots = jnp.arange(out_capacity, dtype=jnp.int32)
    owner = jnp.searchsorted(off, slots, side="right").astype(jnp.int32) - 1
    owner = jnp.clip(owner, 0, probe.capacity - 1)
    rank = slots - off[owner]
    bidx = jnp.clip(lo[owner] + rank, 0, sorted_build.capacity - 1)
    ok = slots < total
    cols = {}
    for name, col in sorted_build.columns.items():
        cols[build_prefix + name] = jnp.where(ok, col[bidx],
                                              jnp.int32(SENTINEL))
    for name, col in probe.columns.items():
        key = probe_prefix + name
        if key in cols:  # join column appears once
            continue
        cols[key] = jnp.where(ok, col[owner], jnp.int32(SENTINEL))
    return Relation(cols, ok)


@functools.lru_cache(maxsize=None)
def _gather_jit(donate: bool):
    statics = ("out_capacity", "build_prefix", "probe_prefix")
    if donate:
        # the staged buffers are consumed here; donating them lets XLA
        # reuse the sorted-build storage for the materialized output
        return jax.jit(_gather_core, static_argnames=statics,
                       donate_argnums=(0, 1, 2))
    return jax.jit(_gather_core, static_argnames=statics)


def gather_staged(staged: StagedJoin, probe: Relation, out_capacity: int,
                  *, build_prefix: str = "",
                  probe_prefix: str = "") -> Relation:
    """Finish a staged materialize: one jitted dispatch, donated staged
    buffers on backends that support donation (CPU does not)."""
    donate = jax.default_backend() != "cpu"
    return _gather_jit(donate)(
        staged.sorted_build, staged.lo, staged.cnt, probe,
        out_capacity=out_capacity, build_prefix=build_prefix,
        probe_prefix=probe_prefix)


# --------------------------------------------------------------------------
# cascaded binary baseline:  (R ⋈ S) materialized, then ⋈ T aggregated
# --------------------------------------------------------------------------

class CascadeResult(NamedTuple):
    count: jnp.ndarray          # total 3-way join cardinality (aggregated)
    intermediate_total: jnp.ndarray
    intermediate_overflowed: jnp.ndarray


def cascaded_binary_count(r: Relation, s: Relation, t: Relation,
                          intermediate_capacity: int,
                          rb: str = "b", sb: str = "b", sc: str = "c",
                          tc: str = "c") -> CascadeResult:
    """COUNT(R(AB) ⋈ S(BC) ⋈ T(CD)) as two cascaded binary joins with a
    bounded, materialized intermediate (the paper's baseline plan)."""
    inter = join_materialize(r, rb, s, sb, intermediate_capacity,
                             build_prefix="r_", probe_prefix="s_")
    # second join: aggregate only (final output never materialized, §6)
    w = probe_weight_sum(t, tc, jnp.ones((t.capacity,), jnp.int32),
                         inter.rel.col("s_" + sc), inter.rel.valid)
    return CascadeResult(jnp.sum(w).astype(jnp.int32), inter.total,
                         inter.overflowed)


def cascaded_binary_per_r_counts(r: Relation, s: Relation, t: Relation,
                                 rb: str = "b", sb: str = "b", sc: str = "c",
                                 tc: str = "c") -> jnp.ndarray:
    """Per-R-row 3-way join counts via weight backflow (no materialization).

    w_s = |{t : t.c == s.c}| ;  count_r = Σ_{s : s.b == r.b} w_s.
    Exact; used as the oracle for the per-key (Example 1) aggregate.
    """
    w_s = probe_weight_sum(t, tc, jnp.ones((t.capacity,), jnp.int32),
                           s.col(sc), s.valid)
    c_r = probe_weight_sum(s, sb, w_s, r.col(rb), r.valid)
    return c_r


# --------------------------------------------------------------------------
# bucketed path (accelerator-shaped)
# --------------------------------------------------------------------------

def bucketed_join_count(build: Relation, build_key: str,
                        probe: Relation, probe_key: str,
                        n_buckets: int, build_cap: int, probe_cap: int,
                        use_kernel: bool = False):
    """Hash-partition both sides and count matches per bucket pair.

    Returns (count, overflowed).  Matching keys hash identically, so
    bucket-local exact compares lose nothing (completeness), and cross-bucket
    pairs can never match (soundness) — exactness holds unless a bucket
    overflows, which is reported.
    """
    from repro.kernels import ops as kops

    b = partition.bucketize(build, build_key, n_buckets, build_cap, fn="h")
    p = partition.bucketize(probe, probe_key, n_buckets, probe_cap, fn="h")
    counts = kops.bucket_pair_count(
        b.columns[build_key], b.valid, p.columns[probe_key], p.valid,
        use_kernel=use_kernel)
    return jnp.sum(counts), b.overflowed | p.overflowed
