#!/usr/bin/env python
"""CI gate: the plan verifier + width analysis over the bench plan corpus.

Re-plans every query shape ``benchmarks/engine_bench.py`` executes — the
three classified 3-relation kinds (linear, cyclic triangle, star), the
4-relation chain, the 6-relation two-branch tree, a 2-relation binary
query, a per-R pinned query — across every applicable strategy (planner
default, forced 3way, forced cascade), then runs ``verify_plan`` and
``check_widths`` on each.  Any validation error is a FALSE POSITIVE of the
static analysis (the bench executes these plans exactly, so they are known
good) and fails the job; width *hazard* diagnostics are reported but do
not fail.

Relations are generated at the bench's --quick sizes and distinct counts
(the planner reads live cardinalities AND per-column distinct estimates
off the relations' sketches, so the corpus must match the bench's data
shape for the emitted plans to match).

    python tools/verify_bench_plans.py
"""

from __future__ import annotations

import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent
                       / "src"))

import numpy as np  # noqa: E402

from repro.analysis.errors import PlanValidationError  # noqa: E402
from repro.analysis.verify_plan import verify_plan  # noqa: E402
from repro.analysis.widths import analyze_widths, check_widths  # noqa: E402
from repro.core import planner  # noqa: E402
from repro.core.query import Query  # noqa: E402
from repro.core.relation import Relation  # noqa: E402


def _rel(rng, n, cols, d):
    return Relation.from_arrays(
        **{c: rng.integers(0, d, size=n).astype(np.int32) for c in cols})


def _bench_corpus(rng):
    """(name, Query, cards, m_budget, strategies, per_r) per engine_bench
    shape, at the bench's --quick sizes/distinct counts (the planner reads
    distinct estimates off the relations' sketches, so the corpus data
    must match the bench's shape for the emitted plans to match)."""
    lin = {"r": _rel(rng, 24000, ("a", "b"), 4096),
           "s": _rel(rng, 24000, ("b", "c"), 4096),
           "t": _rel(rng, 24000, ("c", "d"), 4096)}
    cyc = {"r": _rel(rng, 6000, ("a", "b"), 512),
           "s": _rel(rng, 6000, ("b", "c"), 512),
           "t": _rel(rng, 6000, ("c", "a"), 512)}
    star = {"r": _rel(rng, 2000, ("a", "b"), 2048),
            "s": _rel(rng, 120000, ("b", "c"), 2048),
            "t": _rel(rng, 2000, ("c", "d"), 2048)}
    chain4 = {f"r{i + 1}": _rel(rng, 12000, cols, 2048)
              for i, cols in enumerate((("a", "b"), ("b", "c"),
                                        ("c", "d"), ("d", "e")))}
    tree6 = {"r1": _rel(rng, 8000, ("a", "b"), 1024),
             "r2": _rel(rng, 8000, ("b", "c"), 1024),
             "r3": _rel(rng, 8000, ("c", "d"), 1024),
             "r4": _rel(rng, 8000, ("e", "f"), 1024),
             "r5": _rel(rng, 8000, ("f", "g"), 1024),
             "r6": _rel(rng, 8000, ("d", "g"), 1024)}
    two = {"a_": lin["r"], "b_": lin["s"]}

    def cards(rels):
        return {name: int(rel.n) for name, rel in rels.items()}

    return [
        ("fig4ef_linear", Query(lin, [("r.b", "s.b"), ("s.c", "t.c")]),
         cards(lin), 1024, (None, "3way", "cascade"), False),
        ("cyclic_triangles",
         Query(cyc, [("r.b", "s.b"), ("s.c", "t.c"), ("t.a", "r.a")]),
         cards(cyc), 512, (None, "3way"), False),
        ("fig4hi_star", Query(star, [("r.b", "s.b"), ("s.c", "t.c")]),
         cards(star), 1024, (None, "3way", "cascade"), False),
        ("session_plan_cache/per_r",
         Query(lin, [("r.b", "s.b"), ("s.c", "t.c")]),
         cards(lin), 1024, ("3way",), True),
        ("cascade_4way", Query(chain4, [("r1.b", "r2.b"), ("r2.c", "r3.c"),
                                        ("r3.d", "r4.d")]),
         cards(chain4), 1024, (None, "3way", "cascade"), False),
        ("plan_pipeline_6way",
         Query(tree6, [("r1.b", "r2.b"), ("r2.c", "r3.c"),
                       ("r4.f", "r5.f"), ("r3.d", "r6.d"),
                       ("r5.g", "r6.g")]),
         cards(tree6), 1024, (None, "3way", "cascade"), False),
        ("binary_2rel", Query(two, [("a_.b", "b_.b")]),
         cards(two), 1024, (None, "cascade"), False),
    ]


def main() -> int:
    rng = np.random.default_rng(20260726)
    failures = 0
    hazards = 0
    plans = 0
    for name, query, cards, m_budget, strategies, per_r in \
            _bench_corpus(rng):
        for strategy in strategies:
            label = f"{name} [strategy={strategy or 'default'}]"
            try:
                qp = planner.plan_query(
                    query, cards, m_budget=m_budget, strategy=strategy,
                    per_r_name=(dict(query.classify(cards).roles)["r"]
                                if per_r else None))
            except PlanValidationError as e:
                print(f"FAIL {label}: planner raised {type(e).__name__}: "
                      f"{e}")
                failures += 1
                continue
            plans += 1
            schemas = {nm: frozenset(rel.columns)
                       for nm, rel in query.relations.items()}
            try:
                verify_plan(qp, schemas=schemas)
                diags = check_widths(qp, cards)
            except PlanValidationError as e:
                print(f"FAIL {label}: {type(e).__name__}: {e}")
                failures += 1
                continue
            for d in diags:
                hazards += 1
                print(f"  hazard {label}: {d}")
            print(f"ok   {label}: {len(qp.steps)} step(s), "
                  f"kind={qp.kind}, strategy={qp.strategy}")
    print(f"verify_bench_plans: {plans} plan(s) verified, "
          f"{failures} failure(s), {hazards} hazard diagnostic(s)")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
