"""Decoder-only transformer LM assembly (dense, MoE, local/global pattern,
and cross-attention VLM variants), with scan-over-layers (O(1) HLO size at
any depth), per-layer static-shape flags for heterogeneous stacks, optional
remat, and a stacked KV cache for serving.

Per-layer heterogeneity (gemma3's 5 local : 1 global pattern) rides the scan
as traced [L] arrays (window sizes, rope thetas) so a 26-layer model still
lowers as a single scanned block.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models import attention, layers, moe as moe_lib
from repro.models.config import ModelConfig
from repro.parallel import shard


# --------------------------------------------------------------------------
# per-layer schedule (window / rope theta per layer)
# --------------------------------------------------------------------------

def layer_schedule(cfg: ModelConfig, n_layers=None):
    """Returns (windows [L] int32, thetas [L] f32) for the layer scan."""
    nl = n_layers or cfg.n_layers
    windows, thetas = [], []
    for i in range(nl):
        if cfg.local_pattern and (i % (cfg.local_pattern + 1)
                                  != cfg.local_pattern):
            windows.append(cfg.sliding_window)
            thetas.append(cfg.rope_local_theta or cfg.rope_theta)
        elif cfg.sliding_window and not cfg.local_pattern:
            windows.append(cfg.sliding_window)
            thetas.append(cfg.rope_theta)
        else:
            windows.append(0)
            thetas.append(cfg.rope_theta)
    return (jnp.asarray(windows, jnp.int32), jnp.asarray(thetas, jnp.float32))


# --------------------------------------------------------------------------
# one block
# --------------------------------------------------------------------------

def init_block(key, cfg: ModelConfig):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {
        "ln_attn": layers.init_rms_norm(cfg.d_model),
        "attn": attention.init_attention(k1, cfg),
        "ln_mlp": layers.init_rms_norm(cfg.d_model),
    }
    if cfg.is_moe:
        p["moe"] = moe_lib.init_moe(k2, cfg)
    else:
        p["mlp"] = layers.init_glu_mlp(k3, cfg.d_model, cfg.d_ff)
    return p


def block_forward(p, cfg: ModelConfig, x, positions, window, theta,
                  return_kv=False):
    h = layers.rms_norm(x, p["ln_attn"]["scale"], cfg.norm_eps)
    attn_out = attention.self_attention(p["attn"], cfg, h, positions,
                                        causal=True, window=window,
                                        theta=theta, return_kv=return_kv)
    if return_kv:
        attn_out, kv_k, kv_v = attn_out
    x = x + attn_out
    x = shard(x, ("batch", "seq_res", "embed"))
    h = layers.rms_norm(x, p["ln_mlp"]["scale"], cfg.norm_eps)
    if cfg.is_moe:
        out, aux = moe_lib.moe_mlp_auto(h, p["moe"], cfg)
    else:
        out, aux = layers.glu_mlp(h, p["mlp"], cfg.act), None
    x = shard(x + out, ("batch", "seq_res", "embed"))
    if return_kv:
        return x, aux, (kv_k, kv_v)
    return x, aux


def init_cross_block(key, cfg: ModelConfig):
    return {
        "ln": layers.init_rms_norm(cfg.d_model),
        "xattn": attention.init_attention(key, cfg),
    }


def cross_block_forward(p, cfg, x, memory, positions):
    h = layers.rms_norm(x, p["ln"]["scale"], cfg.norm_eps)
    x = x + attention.cross_attention(p["xattn"], cfg, h, memory, positions)
    return shard(x, ("batch", "seq", "embed"))


def _stack_init(init_fn, key, n):
    keys = jax.random.split(key, n)
    return jax.vmap(init_fn)(keys)


# --------------------------------------------------------------------------
# full LM
# --------------------------------------------------------------------------

def init_lm(key, cfg: ModelConfig):
    k_embed, k_layers, k_cross, k_head = jax.random.split(key, 4)
    params = {
        "embed": layers.init_embed(k_embed, cfg.vocab_size, cfg.d_model),
        "layers": _stack_init(lambda k: init_block(k, cfg), k_layers,
                              cfg.n_layers),
        "final_norm": layers.init_rms_norm(cfg.d_model),
    }
    if cfg.cross_attn_every:
        n_cross = cfg.n_layers // cfg.cross_attn_every
        params["cross_layers"] = _stack_init(
            lambda k: init_cross_block(k, cfg), k_cross, n_cross)
    if not cfg.tie_embeddings:
        params["lm_head"] = layers.init_embed(k_head, cfg.vocab_size,
                                              cfg.d_model)
    return params


def _attn_attention_stack(params, cfg, x, positions, memory):
    """Scan the layer stack (optionally interleaving cross-attn groups)."""
    windows, thetas = layer_schedule(cfg)

    def one_block(x, p, w, th):
        return block_forward(p, cfg, x, positions, w, th)

    if cfg.remat:
        one_block = jax.checkpoint(one_block)

    if not cfg.cross_attn_every:
        def step(carry, xs):
            x, aux = carry
            p, w, th = xs
            x, a = one_block(x, p, w, th)
            if a is not None:
                aux = {k: aux[k] + a[k] for k in aux}
            return (x, aux), None

        aux0 = ({"aux_loss": jnp.zeros((), jnp.float32),
                 "dropped": jnp.zeros((), jnp.float32)}
                if cfg.is_moe else {})

        gk = cfg.scan_group
        if gk and cfg.n_layers % gk == 0 and gk < cfg.n_layers:
            # sqrt-L remat: outer checkpointed scan over L/gk groups; the
            # inner blocks stay individually rematted, so live residuals
            # are (L/gk + gk)·|x| instead of L·|x|.
            ng = cfg.n_layers // gk
            grouped = jax.tree.map(
                lambda a: a.reshape((ng, gk) + a.shape[1:]),
                params["layers"])

            def group_step(carry, xs):
                ps, ws, ths = xs
                carry, _ = jax.lax.scan(step, carry, (ps, ws, ths))
                return carry, None

            group_step = jax.checkpoint(group_step)
            (x, aux), _ = jax.lax.scan(
                group_step, (x, aux0),
                (grouped, windows.reshape(ng, gk), thetas.reshape(ng, gk)))
            return x, aux

        (x, aux), _ = jax.lax.scan(step, (x, aux0),
                                   (params["layers"], windows, thetas))
        return x, aux

    # VLM: groups of `cross_attn_every` self layers + 1 cross layer
    k = cfg.cross_attn_every
    ng = cfg.n_layers // k
    grouped = jax.tree.map(
        lambda a: a.reshape((ng, k) + a.shape[1:]), params["layers"])
    win_g = windows.reshape(ng, k)
    th_g = thetas.reshape(ng, k)

    def cross_fn(x, cp):
        return cross_block_forward(cp, cfg, x, memory, positions)

    if cfg.remat:
        cross_fn = jax.checkpoint(cross_fn)

    def group_step(x, xs):
        ps, cp, ws, ths = xs

        def inner(x2, ys):
            p, w, th = ys
            x2, _ = one_block(x2, p, w, th)
            return x2, None

        x, _ = jax.lax.scan(inner, x, (ps, ws, ths))
        x = cross_fn(x, cp)
        return x, None

    x, _ = jax.lax.scan(group_step, x,
                        (grouped, params["cross_layers"], win_g, th_g))
    return x, {}


def forward(params, cfg: ModelConfig, tokens, memory=None):
    """Training/prefill forward → f32 logits [B, S, V] (+ aux dict).

    `memory`: [B, n_frontend_tokens, d] precomputed modality embeddings for
    VLM cross-attention (stubbed frontend per the assignment).
    """
    b, s = tokens.shape
    dt = layers.dtype_of(cfg.dtype)
    x = layers.embed(tokens, params["embed"]["table"], dt)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    x, aux = _attn_attention_stack(params, cfg, x, positions, memory)
    x = layers.rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)
    table = (params["embed"]["table"] if cfg.tie_embeddings
             else params["lm_head"]["table"])
    return layers.unembed(x, table), aux


# --------------------------------------------------------------------------
# serving: prefill + decode
# --------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16):
    cache = attention.init_kv_cache(cfg, batch, max_len, dtype=dtype)
    if cfg.cross_attn_every and cfg.n_frontend_tokens:
        cache["memory"] = jnp.zeros(
            (batch, cfg.n_frontend_tokens, cfg.d_model), dtype)
    return cache


def decode_step(params, cfg: ModelConfig, cache, tokens):
    """One decode step.  tokens: [B, 1] → (logits [B, 1, V], new cache)."""
    b = tokens.shape[0]
    dt = layers.dtype_of(cfg.dtype)
    x = layers.embed(tokens, params["embed"]["table"], dt)
    length = cache["length"]
    windows, thetas = layer_schedule(cfg)
    memory = cache.get("memory")

    def layer_step(x, xs):
        """Append-style decode (§Perf decode-it-3): the cache is READ
        ONLY inside the scan; this token's k/v are emitted as tiny ys and
        written back with ONE stacked in-place update afterwards (the
        previous write-back of full [B,T,KVH,D] buffers per layer
        dominated decode HBM traffic)."""
        p, lk, lv, w, th = xs
        h = layers.rms_norm(x, p["ln_attn"]["scale"], cfg.norm_eps)
        k_new, v_new = attention.project_kv_token(p["attn"], cfg, h,
                                                  length, theta=th)
        x = x + attention.decode_attention_append(
            p["attn"], cfg, h, lk, lv, k_new, v_new, length,
            window=w, theta=th)
        h = layers.rms_norm(x, p["ln_mlp"]["scale"], cfg.norm_eps)
        if cfg.is_moe:
            out, _ = moe_lib.moe_mlp_auto(h, p["moe"], cfg)
        else:
            out = layers.glu_mlp(h, p["mlp"], cfg.act)
        return x + out, (k_new, v_new)

    if not cfg.cross_attn_every:
        x, (ks, vs) = jax.lax.scan(
            layer_step, x,
            (params["layers"], cache["k"], cache["v"], windows, thetas))
        nk, nv = attention.write_kv_stack(cache["k"], cache["v"],
                                          ks, vs, length)
    else:
        k = cfg.cross_attn_every
        ng = cfg.n_layers // k
        grouped = jax.tree.map(
            lambda a: a.reshape((ng, k) + a.shape[1:]), params["layers"])
        ck = jax.tree.map(lambda a: a.reshape((ng, k) + a.shape[1:]),
                          cache["k"])
        cv = jax.tree.map(lambda a: a.reshape((ng, k) + a.shape[1:]),
                          cache["v"])
        win_g = windows.reshape(ng, k)
        th_g = thetas.reshape(ng, k)
        pos = jnp.broadcast_to(length[None, None], (b, 1))

        def group_step(x, xs):
            ps, cp, lks, lvs, ws, ths = xs
            x, (nks, nvs) = jax.lax.scan(
                layer_step, x, (ps, lks, lvs, ws, ths))
            x = cross_block_forward(cp, cfg, x, memory, pos)
            return x, (nks, nvs)

        x, (ks, vs) = jax.lax.scan(
            group_step, x,
            (grouped, params["cross_layers"], ck, cv, win_g, th_g))
        ks = ks.reshape((cfg.n_layers,) + ks.shape[2:])
        vs = vs.reshape((cfg.n_layers,) + vs.shape[2:])
        nk, nv = attention.write_kv_stack(cache["k"], cache["v"],
                                          ks, vs, length)

    x = layers.rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)
    table = (params["embed"]["table"] if cfg.tie_embeddings
             else params["lm_head"]["table"])
    logits = layers.unembed(x, table)
    new_cache = dict(cache, k=nk, v=nv, length=length + 1)
    return logits, new_cache


def prefill(params, cfg: ModelConfig, tokens, cache, memory=None):
    """Run the full-sequence forward, collecting per-layer K/V into the
    cache (written at positions [0, S)); returns (logits, filled cache)."""
    b, s = tokens.shape
    dt = layers.dtype_of(cfg.dtype)
    x = layers.embed(tokens, params["embed"]["table"], dt)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    windows, thetas = layer_schedule(cfg)
    if memory is not None and "memory" in cache:
        cache = dict(cache, memory=memory.astype(cache["memory"].dtype))
    mem = cache.get("memory")

    def one_block(x, p, w, th):
        return block_forward(p, cfg, x, positions, w, th, return_kv=True)

    if cfg.remat:
        one_block = jax.checkpoint(one_block)

    if not cfg.cross_attn_every:
        def step(x, xs):
            p, w, th = xs
            x, _, (kk, vv) = one_block(x, p, w, th)
            return x, (kk, vv)

        x, (ks, vs) = jax.lax.scan(step, x,
                                   (params["layers"], windows, thetas))
    else:
        k = cfg.cross_attn_every
        ng = cfg.n_layers // k
        grouped = jax.tree.map(
            lambda a: a.reshape((ng, k) + a.shape[1:]), params["layers"])
        win_g = windows.reshape(ng, k)
        th_g = thetas.reshape(ng, k)

        def group_step(x, xs):
            ps, cp, ws, ths = xs

            def inner(x2, ys):
                p, w, th = ys
                x2, _, (kk, vv) = one_block(x2, p, w, th)
                return x2, (kk, vv)

            x, kvs = jax.lax.scan(inner, x, (ps, ws, ths))
            x = cross_block_forward(cp, cfg, x, mem, positions)
            return x, kvs

        x, (ks, vs) = jax.lax.scan(
            group_step, x, (grouped, params["cross_layers"], win_g, th_g))
        ks = ks.reshape((cfg.n_layers,) + ks.shape[2:])
        vs = vs.reshape((cfg.n_layers,) + vs.shape[2:])

    # write [L, B, S, KVH, D] into the cache prefix
    new_k = jax.lax.dynamic_update_slice_in_dim(
        cache["k"], ks.astype(cache["k"].dtype), 0, axis=2)
    new_v = jax.lax.dynamic_update_slice_in_dim(
        cache["v"], vs.astype(cache["v"].dtype), 0, axis=2)

    x = layers.rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)
    table = (params["embed"]["table"] if cfg.tie_embeddings
             else params["lm_head"]["table"])
    logits = layers.unembed(x[:, -1:], table)
    return logits, dict(cache, k=new_k, v=new_v,
                        length=jnp.asarray(s, jnp.int32))
