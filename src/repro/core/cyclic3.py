"""Cyclic 3-way join  R(AB) ⋈ S(BC) ⋈ T(CA)  (triangles) — paper §5.

Partitioning scheme (Fig 3):
  * coarse ``H(A) × G(B)`` → an H×G grid of R partitions, each sized to
    on-chip memory; T is partitioned by H(A) (read G times), S by G(B)
    (read H times),
  * fine ``h(A) × g(B)`` → the √U×√U PMU grid *within* a partition:
    r(a,b) → PMU[h(a), g(b)];  s(b,c) broadcast down column g(b);
    t(c,a) broadcast across row h(a),
  * ``f(C)`` → streaming buckets so the S'/T' pieces per step are tiny.

Cost: |R| + H·|S| + G·|T|, minimized at H* = √(|R||T| / (M|S|)) giving
|R| + 2√(|R||S||T|/M)  (§5.2) — `cost_model.cyclic3_*` computes both.

The per-PMU join is ``kernels.bucket_join.count3_cyclic``:
count = Σ (M1ᵀ·M2) ⊙ M3 over the three equality matrices — two MXU matmuls.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import partition
from repro.core.relation import Relation
from repro.kernels import ops as kops


class Cyclic3Plan(NamedTuple):
    h_parts: int   # coarse H(A) partitions
    g_parts: int   # coarse G(B) partitions
    uh: int        # PMU grid rows, h(A)
    ug: int        # PMU grid cols, g(B)
    f_parts: int   # streaming f(C) buckets
    r_cap: int
    s_cap: int
    t_cap: int


class Cyclic3Result(NamedTuple):
    count: jnp.ndarray
    overflowed: jnp.ndarray
    tuples_read: object      # int32 (scan) | engine.Traffic64 (fused)


def default_plan(n_r: int, n_s: int, n_t: int, *, m_budget: int,
                 uh: int = 8, ug: int = 8, f_parts: int | None = None,
                 slack: float = 2.5) -> Cyclic3Plan:
    """H·G = ceil(|R|/M); split via the optimal H* = √(|R||T|/(M|S|)) (§5.2),
    clamped to [1, HG]."""
    import math

    hg = max(1, math.ceil(n_r / m_budget))
    h_star = math.sqrt(max(1.0, n_r * n_t / (m_budget * max(1, n_s))))
    h_parts = int(min(max(1.0, h_star), hg))
    g_parts = max(1, math.ceil(hg / h_parts))
    if f_parts is None:
        f_parts = max(1, math.ceil(max(n_s / g_parts, n_t / h_parts) / m_budget))
    r_cap = partition.suggest_capacity(n_r, h_parts * g_parts * uh * ug, slack)
    s_cap = partition.suggest_capacity(n_s, g_parts * f_parts * ug, slack)
    t_cap = partition.suggest_capacity(n_t, h_parts * f_parts * uh, slack)
    return Cyclic3Plan(h_parts, g_parts, uh, ug, f_parts, r_cap, s_cap, t_cap)


def cyclic3_count(r: Relation, s: Relation, t: Relation,
                  plan: Cyclic3Plan, *, use_kernel: bool = False,
                  pair_index: bool = True,
                  ra: str = "a", rb: str = "b", sb: str = "b", sc: str = "c",
                  tc: str = "c", ta: str = "a") -> Cyclic3Result:
    """Scan-driver triangle count.  ``pair_index`` (default on) lex-sorts
    each T bucket row into a (c, a)-pair index ONCE after partitioning and
    probes it with searchsorted range scans per cell — the same trick the
    fused path defaults to — instead of the all-pairs compare kernel.
    ``use_kernel=True`` keeps the all-pairs Pallas kernel (the pair index
    has no SIMD realization)."""
    hp, gp, uh, ug, fp = (plan.h_parts, plan.g_parts, plan.uh, plan.ug,
                          plan.f_parts)
    pairidx = pair_index and not use_kernel

    # Fig 3 data reorganization.
    r_ids, r_nb = partition.composite_ids(
        r, [(ra, hp, "H"), (rb, gp, "G"), (ra, uh, "h"), (rb, ug, "g")])
    rg = partition.bucketize_by_ids(r, r_ids, r_nb, plan.r_cap,
                                    (hp, gp, uh, ug))
    s_ids, s_nb = partition.composite_ids(
        s, [(sb, gp, "G"), (sc, fp, "f"), (sb, ug, "g")])
    sg = partition.bucketize_by_ids(s, s_ids, s_nb, plan.s_cap, (gp, fp, ug))
    t_ids, t_nb = partition.composite_ids(
        t, [(ta, hp, "H"), (tc, fp, "f"), (ta, uh, "h")])
    tg = partition.bucketize_by_ids(t, t_ids, t_nb, plan.t_cap, (hp, fp, uh))

    if pairidx:
        # build the sorted (c, a)-pair index once per partitioning; the
        # validity plane is baked into the sentinels, so the scan below
        # carries it only to keep one code shape for both paths
        t_c_all, t_a_all = kops.sorted_pair_index(
            tg.columns[tc], tg.columns[ta], tg.valid)
    else:
        t_c_all, t_a_all = tg.columns[tc], tg.columns[ta]

    def hg_cell(r_a, r_b, r_v, s_b, s_c, s_v, t_c, t_a, t_v):
        """Join one (H(A)=i, G(B)=j) partition triple on the uh×ug grid,
        streaming over f(C) buckets."""

        def f_step(acc, ys):
            sb_f, sc_f, sv_f, tc_f, ta_f, tv_f = ys   # [ug, s_cap], [uh, t_cap]
            # s broadcast down columns, t across rows (Fig 3 routing)
            sbb = jnp.broadcast_to(sb_f[None], (uh,) + sb_f.shape)
            scb = jnp.broadcast_to(sc_f[None], (uh,) + sc_f.shape)
            svb = jnp.broadcast_to(sv_f[None], (uh,) + sv_f.shape)
            tcb = jnp.broadcast_to(tc_f[:, None], (uh, ug, tc_f.shape[-1]))
            tab = jnp.broadcast_to(ta_f[:, None], (uh, ug, ta_f.shape[-1]))
            tvb = jnp.broadcast_to(tv_f[:, None], (uh, ug, tv_f.shape[-1]))

            def flat(x):
                return x.reshape((uh * ug,) + x.shape[2:])

            if pairidx:
                c = kops.bucket_count3_cyclic_pairidx(
                    flat(r_a), flat(r_b), flat(r_v),
                    flat(sbb), flat(scb), flat(svb),
                    flat(tcb), flat(tab))
            else:
                c = kops.bucket_count3_cyclic(
                    flat(r_a), flat(r_b), flat(r_v),
                    flat(sbb), flat(scb), flat(svb),
                    flat(tcb), flat(tab), flat(tvb), use_kernel=use_kernel)
            return acc + jnp.sum(c), None

        acc, _ = jax.lax.scan(f_step, jnp.int32(0),
                              (s_b, s_c, s_v, t_c, t_a, t_v))
        return acc

    def h_step(total, xs):
        ria, rib, riv, tic, tia, tiv = xs   # row i: R[i], T[i]

        def g_step(acc, ys):
            rja, rjb, rjv, sjb, sjc, sjv = ys   # col j: R[i,j], S[j]
            return acc + hg_cell(rja, rjb, rjv, sjb, sjc, sjv,
                                 tic, tia, tiv), None

        acc, _ = jax.lax.scan(
            g_step, jnp.int32(0),
            (ria, rib, riv, sg.columns[sb], sg.columns[sc], sg.valid))
        return total + acc, None

    total, _ = jax.lax.scan(
        h_step, jnp.int32(0),
        (rg.columns[ra], rg.columns[rb], rg.valid,
         t_c_all, t_a_all, tg.valid))

    overflow = rg.overflowed | sg.overflowed | tg.overflowed
    tuples = r.n + hp * s.n + gp * t.n
    return Cyclic3Result(total, overflow, tuples.astype(jnp.int32))
