"""Core multiway hash-join engine (the paper's contribution).

Public API:
  Query / JoinSession      — the declarative front door: any connected
                             acyclic graph of N >= 2 relations + join
                             predicates in (cyclic at N = 3), decomposed +
                             planned + executed + skew-recovered
                             QueryResult out (plan-cached)
  JoinResult               — the unified result core every entry point
                             answers with (QueryResult / PerRResult /
                             StandingQuery.snapshot all subclass/return it)
  StandingQuery            — JoinSession.watch(query): exact incremental
                             counts under Relation.append ingest (delta
                             plan execution over resident intermediates)
  QueryPlan / PlanStep     — the multi-step plan IR: a DAG of fused 3-way
                             and binary join steps (planner.plan_query
                             decomposes, plan_ir.execute_plan walks)
  Relation                 — fixed-capacity columnar relation
  MultiwayJoinEngine       — fused partition-sweep engine + skew recovery
  linear3_count_fused / cyclic3_count_fused / star3_count_fused
                           — single-launch traceable fused sweeps
  linear3_count / linear3_per_r_counts / linear3_fm_distinct
  cyclic3_count            — triangle (cyclic) 3-way join
  star3_count              — star-schema 3-way join
  cascaded_binary_count    — the baseline plan
  cost_model               — the paper's tuple-traffic analysis
"""

from repro.core import cost_model, hashing, partition, reference, sketches  # noqa: F401
from repro.core.binary_join import (  # noqa: F401
    bucketed_join_count, cascaded_binary_count, cascaded_binary_per_r_counts,
    join_count, join_materialize, probe_weight_sum)
from repro.core.cyclic3 import Cyclic3Plan, cyclic3_count  # noqa: F401
from repro.core.cyclic3 import default_plan as cyclic3_default_plan  # noqa: F401
from repro.core.engine import (  # noqa: F401
    EngineResult, MultiwayJoinEngine, PerRResult, cyclic3_count_fused,
    linear3_count_fused, star3_count_fused)
from repro.core.linear3 import (  # noqa: F401
    Linear3Plan, linear3_count, linear3_fm_distinct, linear3_per_r_counts)
from repro.core.linear3 import default_plan as linear3_default_plan  # noqa: F401
from repro.core.plan_ir import PlanStep, QueryPlan, StepStats  # noqa: F401
from repro.core.query import (  # noqa: F401
    Binding, Classification, Query, QueryError, QueryGraphError,
    QuerySchemaError)
from repro.core.relation import Relation  # noqa: F401
from repro.core.results import JoinResult  # noqa: F401
from repro.core.session import JoinSession, QueryResult  # noqa: F401
from repro.core.streaming import DeltaRecord, StandingQuery  # noqa: F401
from repro.core.star3 import Star3Plan, star3_count  # noqa: F401
from repro.core.star3 import default_plan as star3_default_plan  # noqa: F401
