"""Unified multiway join engine: fused partition sweeps + skew recovery.

This is the execution layer the paper's numbers assume.  The per-algorithm
drivers in ``linear3.py`` / ``cyclic3.py`` / ``star3.py`` sweep the coarse
H(B)×g(C) partition grid with nested ``lax.scan`` loops, launching one
bucket-row kernel per step — the grid dimension (the paper's U-way PMU
parallelism, §4–§6) sits idle between launches.  The engine instead issues
ONE fused kernel per query (``kernels.ops.fused_*``): the Pallas grid spans
``(h_parts, u, g_parts)`` (resp. the cyclic/star equivalents), BlockSpec
index maps pick the partition row per program, and Pallas double-buffers the
HBM→VMEM operand streams across the whole sweep (§6.2 prefetching, now
spanning partitions rather than restarting per bucket row).

Skew recovery (paper §5's skew discussion, made correct-by-construction)
-----------------------------------------------------------------------
Fixed-capacity buckets overflow under key skew.  The scan drivers only
*flag* this; the ``core.reference`` baselines re-run the whole query with
grown capacities.  The engine recovers surgically instead via the shared round
engine in ``core.recovery``: exact coarse partitions keep their fused
partial counts, overflowed ones re-run with a salted hash and grown
capacities, and the final round is exact-histogram-sized so it cannot
overflow — ``overflowed == False`` is a postcondition.  Each round performs
exactly ONE hashing pass per relation (histograms, layouts and residual
masks all derive from one ``composite_ids`` call); see ``recovery``'s
docstring for the full contract and exactness argument.

The ``*_count_fused`` functions are single-pass and fully traceable (jit /
shard_map safe); ``MultiwayJoinEngine`` adds the host-side recovery loop.

The engine executes exactly one 3-relation step.  N-way queries reach it
through ``core.plan_ir``: the planner decomposes the predicate tree into
binary materialize steps feeding a fused 3-way root, and each ``fused3``
plan step runs through ``MultiwayJoinEngine.count`` — so the recovery
contract (one hashing pass per relation per round, exact partials kept,
``overflowed == False``) holds per step of a multi-step plan.
"""

from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

from repro.core import cyclic3, linear3, partition, recovery, star3
from repro.core.recovery import EngineResult, PerRResult  # noqa: F401  (re-export)
from repro.core.relation import Relation
from repro.kernels import ops as kops


# ==========================================================================
# int64-exact traffic counters (without jax_enable_x64)
# ==========================================================================

_MASK15 = 0x7FFF
_MASK30 = (1 << 30) - 1


class Traffic64(NamedTuple):
    """A tuples-read total as two int32 limbs (lo < 2^30, hi = value >> 30).

    x64 stays off framework-wide, so a traced ``h_parts * t.n`` product
    must not be computed in int32 — large sweeps wrap (h_parts=1024 over a
    4M-row T is already 2^32).  Same trick as the psum limbs in
    ``distributed._round_sharded``; ``int()`` recombines host-side.
    """

    hi: jnp.ndarray              # () int32, units of 2^30
    lo: jnp.ndarray              # () int32, < 2^30

    def __int__(self) -> int:
        return (int(self.hi) << 30) + int(self.lo)


def traffic64(terms) -> Traffic64:
    """Σ k·n over ``(static int k, traced int32 scalar n)`` terms, exactly.

    Every intermediate product stays below 2^31: k splits statically into
    15-bit limbs, n dynamically (n < 2^31 ⇒ n >> 15 < 2^16), and carries
    propagate after each partial product.  Supports totals up to 2^61.
    """
    hi = jnp.int32(0)
    lo = jnp.int32(0)

    def add(hi, lo, v):
        lo = lo + (v & _MASK30)
        hi = hi + (v >> 30) + ((lo >> 30) & 1)
        return hi, lo & _MASK30

    for k, n in terms:
        k = int(k)
        if k == 0:
            continue
        if not 0 < k < 2**31:
            raise ValueError(f"static traffic multiplier {k} out of range")
        k_hi, k_lo = divmod(k, 1 << 15)
        n = jnp.asarray(n, jnp.int32)
        n_hi = n >> 15
        n_lo = n & _MASK15
        hi, lo = add(hi, lo, jnp.int32(k_lo) * n_lo)
        for m in (jnp.int32(k_hi) * n_lo, jnp.int32(k_lo) * n_hi):
            hi, lo = add(hi, lo, (m & _MASK15) << 15)
            hi = hi + (m >> 15)
        hi = hi + jnp.int32(k_hi) * n_hi
    return Traffic64(hi, lo)


# ==========================================================================
# salted layouts (Fig 2 / Fig 3 data reorganization, re-randomizable)
# ==========================================================================

def linear3_layouts(r: Relation, s: Relation, t: Relation,
                    plan: linear3.Linear3Plan, *, salt: int = 0,
                    rb: str = "b", sb: str = "b", sc: str = "c",
                    tc: str = "c"):
    """R → [hp,u,cap], S → [hp,gp,u,cap], T → [gp,cap] (salted)."""
    hp, u, gp = plan.h_parts, plan.u, plan.g_parts
    r_ids, r_nb = partition.composite_ids(
        r, [(rb, hp, "H"), (rb, u, "h")], salt)
    rg = partition.bucketize_by_ids(r, r_ids, r_nb, plan.r_cap, (hp, u))
    s_ids, s_nb = partition.composite_ids(
        s, [(sb, hp, "H"), (sc, gp, "g"), (sb, u, "h")], salt)
    sg = partition.bucketize_by_ids(s, s_ids, s_nb, plan.s_cap, (hp, gp, u))
    tg = partition.bucketize(t, tc, gp, plan.t_cap, fn="g", salt=salt)
    return rg, sg, tg


def cyclic3_layouts(r: Relation, s: Relation, t: Relation,
                    plan: cyclic3.Cyclic3Plan, *, salt: int = 0,
                    ra: str = "a", rb: str = "b", sb: str = "b",
                    sc: str = "c", tc: str = "c", ta: str = "a"):
    """R → [hp,gp,uh,ug,cap], S → [gp,fp,ug,cap], T → [hp,fp,uh,cap]."""
    hp, gp, uh, ug, fp = (plan.h_parts, plan.g_parts, plan.uh, plan.ug,
                          plan.f_parts)
    r_ids, r_nb = partition.composite_ids(
        r, [(ra, hp, "H"), (rb, gp, "G"), (ra, uh, "h"), (rb, ug, "g")], salt)
    rg = partition.bucketize_by_ids(r, r_ids, r_nb, plan.r_cap,
                                    (hp, gp, uh, ug))
    s_ids, s_nb = partition.composite_ids(
        s, [(sb, gp, "G"), (sc, fp, "f"), (sb, ug, "g")], salt)
    sg = partition.bucketize_by_ids(s, s_ids, s_nb, plan.s_cap, (gp, fp, ug))
    t_ids, t_nb = partition.composite_ids(
        t, [(ta, hp, "H"), (tc, fp, "f"), (ta, uh, "h")], salt)
    tg = partition.bucketize_by_ids(t, t_ids, t_nb, plan.t_cap, (hp, fp, uh))
    return rg, sg, tg


def star3_layouts(r: Relation, s: Relation, t: Relation,
                  plan: star3.Star3Plan, *, salt: int = 0, rb: str = "b",
                  sb: str = "b", sc: str = "c", tc: str = "c"):
    """R → [uh,cap], S → [ch,uh,ug,cap], T → [ug,cap] (salted)."""
    uh, ug, ch = plan.uh, plan.ug, plan.chunks
    rg = partition.bucketize(r, rb, uh, plan.r_cap, fn="h", salt=salt)
    tg = partition.bucketize(t, tc, ug, plan.t_cap, fn="g", salt=salt)
    chunk_ids = jnp.where(
        s.valid,
        (jnp.arange(s.capacity, dtype=jnp.int32) * ch) // s.capacity, 0)
    hb = partition.bucket_ids_for(s, sb, uh, "h", salt)
    gc = partition.bucket_ids_for(s, sc, ug, "g", salt)
    flat = jnp.where(s.valid, (chunk_ids * uh + hb) * ug + gc,
                     jnp.int32(ch * uh * ug))
    sg = partition.bucketize_by_ids(s, flat, ch * uh * ug, plan.s_cap,
                                    (ch, uh, ug))
    return rg, sg, tg


# ==========================================================================
# single-pass fused counts (traceable: jit / shard_map safe)
# ==========================================================================

def linear3_count_fused(r: Relation, s: Relation, t: Relation,
                        plan: linear3.Linear3Plan, *,
                        use_kernel: bool = False, salt: int = 0,
                        rb: str = "b", sb: str = "b", sc: str = "c",
                        tc: str = "c") -> linear3.Linear3Result:
    """Algorithm 1 as ONE fused launch (overflow flagged, not recovered)."""
    rg, sg, tg = linear3_layouts(r, s, t, plan, salt=salt, rb=rb, sb=sb,
                                 sc=sc, tc=tc)
    c = kops.fused_count3_linear(rg.columns[rb], rg.valid, sg.columns[sb],
                                 sg.columns[sc], sg.valid, tg.columns[tc],
                                 tg.valid, use_kernel=use_kernel)
    overflow = rg.overflowed | sg.overflowed | tg.overflowed
    tuples = traffic64([(1, r.n), (1, s.n), (plan.h_parts, t.n)])
    return linear3.Linear3Result(jnp.sum(c), overflow, tuples)


def cyclic3_count_fused(r: Relation, s: Relation, t: Relation,
                        plan: cyclic3.Cyclic3Plan, *,
                        use_kernel: bool = False, salt: int = 0,
                        pair_index: bool = True,
                        ra: str = "a", rb: str = "b", sb: str = "b",
                        sc: str = "c", tc: str = "c",
                        ta: str = "a") -> cyclic3.Cyclic3Result:
    """The §5 grid algorithm as ONE fused launch (sorted (c, a)-pair-index
    probes by default; ``pair_index=False`` for the all-pairs contraction)."""
    rg, sg, tg = cyclic3_layouts(r, s, t, plan, salt=salt, ra=ra, rb=rb,
                                 sb=sb, sc=sc, tc=tc, ta=ta)
    c = kops.fused_count3_cyclic(rg.columns[ra], rg.columns[rb], rg.valid,
                                 sg.columns[sb], sg.columns[sc], sg.valid,
                                 tg.columns[tc], tg.columns[ta], tg.valid,
                                 use_kernel=use_kernel,
                                 pair_index=pair_index)
    overflow = rg.overflowed | sg.overflowed | tg.overflowed
    tuples = traffic64([(1, r.n), (plan.h_parts, s.n),
                        (plan.g_parts, t.n)])
    return cyclic3.Cyclic3Result(jnp.sum(c), overflow, tuples)


def star3_count_fused(r: Relation, s: Relation, t: Relation,
                      plan: star3.Star3Plan, *, use_kernel: bool = False,
                      salt: int = 0, rb: str = "b", sb: str = "b",
                      sc: str = "c", tc: str = "c") -> star3.Star3Result:
    """The §6.5 star join as ONE fused launch."""
    rg, sg, tg = star3_layouts(r, s, t, plan, salt=salt, rb=rb, sb=sb,
                               sc=sc, tc=tc)
    c = kops.fused_count3_star(rg.columns[rb], rg.valid, sg.columns[sb],
                               sg.columns[sc], sg.valid, tg.columns[tc],
                               tg.valid, use_kernel=use_kernel)
    overflow = rg.overflowed | sg.overflowed | tg.overflowed
    tuples = traffic64([(1, r.n), (1, s.n), (1, t.n)])
    return star3.Star3Result(jnp.sum(c), overflow, tuples)


# ==========================================================================
# the engine: fused sweeps + surgical skew recovery
# ==========================================================================

class MultiwayJoinEngine:
    """Executable multiway hash join with per-partition skew recovery.

    Parameters
    ----------
    kind:        "linear" | "cyclic" | "star" — which §4/§5/§6.5 plan.
    use_kernel:  dispatch the fused Pallas kernels (TPU) instead of the
                 fused jnp path (CPU/XLA).
    max_rounds:  recovery rounds before the exact-histogram final round.
    growth:      geometric per-round bucket-capacity growth for re-run
                 shards.

    ``count`` is host-side (it inspects overflow histograms between rounds);
    use the module-level ``*_count_fused`` functions inside jit/shard_map.
    """

    KINDS = ("linear", "cyclic", "star")

    def __init__(self, kind: str = "linear", *, use_kernel: bool = False,
                 max_rounds: int = 3, growth: float = 2.0,
                 base_salt: int = 0):
        if kind not in self.KINDS:
            raise ValueError(f"unknown kind {kind!r}; choose from {self.KINDS}")
        self.kind = kind
        self.use_kernel = use_kernel
        self.max_rounds = max_rounds
        self.growth = growth
        self.base_salt = base_salt

    # -- planning ----------------------------------------------------------

    def default_plan(self, n_r: int, n_s: int, n_t: int, *, m_budget: int,
                     **kw):
        if self.kind == "linear":
            return linear3.default_plan(n_r, n_s, n_t, m_budget=m_budget,
                                        **kw)
        if self.kind == "cyclic":
            return cyclic3.default_plan(n_r, n_s, n_t, m_budget=m_budget,
                                        **kw)
        return star3.default_plan(n_r, n_s, n_t, **kw)

    # -- execution ---------------------------------------------------------

    def count(self, r: Relation, s: Relation, t: Relation, plan=None, *,
              m_budget: int | None = None, binding=None,
              **cols) -> EngineResult:
        """Exact skew-recovered COUNT.  Column names come from ``binding``
        (a ``query.Binding`` — the recovery KindOps are built from it) or
        the legacy per-kind ``rb=/sb=/...`` kwargs."""
        if plan is None:
            if m_budget is None:
                raise ValueError("pass a plan or m_budget")
            plan = self.default_plan(int(r.n), int(s.n), int(t.n),
                                     m_budget=m_budget)
        if binding is not None:
            if binding.kind != self.kind:
                raise ValueError(f"binding classified {binding.kind!r}, "
                                 f"engine built for {self.kind!r}")
            ops = binding.kind_ops()
        else:
            ops = recovery.OPS[self.kind](**cols)
        return recovery.run_count_rounds(
            ops, r, s, t, plan, max_rounds=self.max_rounds,
            growth=self.growth, use_kernel=self.use_kernel,
            base_salt=self.base_salt)

    # -- per-R aggregates (linear only) ------------------------------------

    def per_r_counts(self, r: Relation, s: Relation, t: Relation, plan, *,
                     rb: str = "b", sb: str = "b", sc: str = "c",
                     tc: str = "c", key_col: str = "a",
                     binding=None) -> PerRResult:
        """Per-R-tuple counts (Example 1) with skew recovery.  Returns
        flattened (keys, counts, valid) concatenated across rounds."""
        if self.kind != "linear":
            raise ValueError("per_r_counts is a linear-join aggregate")
        if binding is not None:
            ops = binding.kind_ops()
        else:
            ops = recovery.LinearOps(rb=rb, sb=sb, sc=sc, tc=tc)
        return recovery.run_per_r_rounds(
            ops, r, s, t, plan, max_rounds=self.max_rounds,
            growth=self.growth, use_kernel=self.use_kernel,
            base_salt=self.base_salt, key_col=key_col)
