"""Pallas kernels vs pure-jnp oracles — interpret=True sweeps over
shapes/dtypes.  Counts are integers, so checks are exact equality."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _mk(rng, b, c, d, side):
    keys = rng.integers(0, d, size=(b, c)).astype(np.int32)
    valid = rng.random((b, c)) < 0.85
    return jnp.asarray(keys), jnp.asarray(valid)


SHAPES = [(1, 128, 128, 128), (4, 128, 256, 128), (3, 256, 128, 384),
          (2, 384, 384, 256)]


@pytest.mark.parametrize("b,cr,cs,ct", SHAPES)
@pytest.mark.parametrize("d", [7, 1000])
def test_count3_linear_kernel(b, cr, cs, ct, d):
    rng = np.random.default_rng(b * 1000 + cr + d)
    rb, rv = _mk(rng, b, cr, d, "r")
    sb, sv = _mk(rng, b, cs, d, "s")
    sc = jnp.asarray(rng.integers(0, d, size=(b, cs)).astype(np.int32))
    tc, tv = _mk(rng, b, ct, d, "t")
    want = ops.bucket_count3_linear(rb, rv, sb, sc, sv, tc, tv,
                                    use_kernel=False)
    got = ops.bucket_count3_linear(rb, rv, sb, sc, sv, tc, tv,
                                   use_kernel=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("b,cr,cs,ct", SHAPES[:2])
@pytest.mark.parametrize("d", [13, 400])
def test_per_r_counts_kernel(b, cr, cs, ct, d):
    rng = np.random.default_rng(cr + cs + d)
    rb, rv = _mk(rng, b, cr, d, "r")
    sb, sv = _mk(rng, b, cs, d, "s")
    sc = jnp.asarray(rng.integers(0, d, size=(b, cs)).astype(np.int32))
    tc, tv = _mk(rng, b, ct, d, "t")
    want = ops.bucket_per_r_counts(rb, rv, sb, sc, sv, tc, tv,
                                   use_kernel=False)
    got = ops.bucket_per_r_counts(rb, rv, sb, sc, sv, tc, tv,
                                  use_kernel=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("b,cr,cs,ct", SHAPES[:2])
@pytest.mark.parametrize("d", [11, 333])
def test_count3_cyclic_kernel(b, cr, cs, ct, d):
    rng = np.random.default_rng(2 * cr + cs + d)
    ra, rv = _mk(rng, b, cr, d, "r")
    rb = jnp.asarray(rng.integers(0, d, size=(b, cr)).astype(np.int32))
    sb, sv = _mk(rng, b, cs, d, "s")
    sc = jnp.asarray(rng.integers(0, d, size=(b, cs)).astype(np.int32))
    tc, tv = _mk(rng, b, ct, d, "t")
    ta = jnp.asarray(rng.integers(0, d, size=(b, ct)).astype(np.int32))
    want = ops.bucket_count3_cyclic(ra, rb, rv, sb, sc, sv, tc, ta, tv,
                                    use_kernel=False)
    got = ops.bucket_count3_cyclic(ra, rb, rv, sb, sc, sv, tc, ta, tv,
                                   use_kernel=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("b,ca,cb", [(1, 128, 128), (5, 256, 128), (2, 384, 512)])
@pytest.mark.parametrize("d", [5, 999])
def test_pair_count_kernel(b, ca, cb, d):
    rng = np.random.default_rng(ca + cb + d)
    ka, va = _mk(rng, b, ca, d, "a")
    kb, vb = _mk(rng, b, cb, d, "b")
    want = ops.bucket_pair_count(ka, va, kb, vb, use_kernel=False)
    got = ops.bucket_pair_count(ka, va, kb, vb, use_kernel=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("n,nb", [(1024, 16), (2048, 64), (4096, 128),
                                  (1000, 32)])
def test_radix_histogram_kernel(n, nb):
    rng = np.random.default_rng(n + nb)
    keys = jnp.asarray(rng.integers(0, 10000, size=n).astype(np.int32))
    valid = jnp.asarray(rng.random(n) < 0.9)
    want = ops.radix_histogram(keys, valid, n_buckets=nb, use_kernel=False)
    got = ops.radix_histogram(keys, valid, n_buckets=nb, use_kernel=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert int(np.asarray(want).sum()) == int(np.asarray(valid).sum())


def test_unaligned_capacity_padding():
    """ops.* pads non-128-multiple capacities with side sentinels; results
    must match the unpadded reference."""
    rng = np.random.default_rng(7)
    b, cr, cs, ct, d = 2, 100, 130, 70, 50
    rb, rv = _mk(rng, b, cr, d, "r")
    sb, sv = _mk(rng, b, cs, d, "s")
    sc = jnp.asarray(rng.integers(0, d, size=(b, cs)).astype(np.int32))
    tc, tv = _mk(rng, b, ct, d, "t")
    want = ops.bucket_count3_linear(rb, rv, sb, sc, sv, tc, tv,
                                    use_kernel=False)
    got = ops.bucket_count3_linear(rb, rv, sb, sc, sv, tc, tv,
                                   use_kernel=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_kernel_end_to_end_linear3(rng):
    """Full Algorithm 1 with the Pallas kernel as the inner join."""
    from conftest import make_rel, oracle_linear3_count
    from repro.core import linear3, reference
    r, rd = make_rel(rng, 90, ("a", "b"), 25)
    s, sd = make_rel(rng, 100, ("b", "c"), 25)
    t, td = make_rel(rng, 95, ("c", "d"), 25)
    expect = oracle_linear3_count(rd["b"], sd["b"], sd["c"], td["c"])
    plan = linear3.default_plan(90, 100, 95, m_budget=48, u=2)
    res, _ = reference.linear3_count_auto(r, s, t, plan, use_kernel=True)
    assert int(res.count) == expect


def test_fm_registers_ref_matches_direct_sketch(rng):
    """kernels.ref.fm_registers (implicit-join sketch) must equal the sketch
    of the explicitly materialized joined (a, d) pairs."""
    from repro.core import sketches
    b, cr, cs, ct, d, K = 2, 24, 30, 26, 12, 16
    ra = jnp.asarray(rng.integers(0, d, (b, cr)).astype(np.int32))
    rb = jnp.asarray(rng.integers(0, d, (b, cr)).astype(np.int32))
    sb = jnp.asarray(rng.integers(0, d, (b, cs)).astype(np.int32))
    sc = jnp.asarray(rng.integers(0, d, (b, cs)).astype(np.int32))
    tc = jnp.asarray(rng.integers(0, d, (b, ct)).astype(np.int32))
    td = jnp.asarray(rng.integers(0, d, (b, ct)).astype(np.int32))
    got = ref.fm_registers(ra, rb, sb, sc, tc, td, K)
    # oracle: materialize joined (a,d) pairs per bucket, sketch them
    from repro.core import hashing
    for bi in range(b):
        pairs = set()
        for i in range(cr):
            for j in range(cs):
                if int(rb[bi, i]) == int(sb[bi, j]):
                    for k in range(ct):
                        if int(sc[bi, j]) == int(tc[bi, k]):
                            pairs.add((int(ra[bi, i]), int(td[bi, k])))
        if not pairs:
            np.testing.assert_array_equal(np.asarray(got[bi]), 0)
            continue
        pa = jnp.asarray([p[0] for p in pairs], dtype=jnp.int32)
        pd = jnp.asarray([p[1] for p in pairs], dtype=jnp.int32)
        key = (hashing.mix32(pa, 0x1B873593)
               ^ hashing.mix32(pd, 0xE6546B64)).astype(jnp.int32)
        want = sketches.add(sketches.empty(K), key,
                            jnp.ones(key.shape, bool))
        np.testing.assert_array_equal(np.asarray(got[bi]), np.asarray(want))
