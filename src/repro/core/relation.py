"""Fixed-capacity, validity-masked relations (struct-of-arrays).

JAX requires static shapes, and the paper's algorithms never materialize the
final join output (aggregates are folded on the fly, §6).  A Relation is a
dict of equal-length int32 column arrays plus a boolean validity mask; the
capacity is static, the live count `n` is dynamic.  All core algorithms
consume and produce Relations (or aggregates).

Ingest is explicit: :meth:`Relation.append` is the ONE mutation point.  It
compacts live rows, grows capacity along log-bucketed (power-of-two) steps
so refreshed executions keep hitting the same compiled shapes, updates any
cached FM sketches incrementally (sketch insertion is a monotone bitwise
OR, so the incremental update equals a rebuild), bumps a version counter
that cache-like layers key resident state on, and notifies registered
append observers (``on_append``) with the delta — that notification is what
drives :class:`~repro.core.streaming.StandingQuery` delta execution.
Outside ``append`` the instance is immutable: the dataclass is frozen and
``columns`` is a read-only mapping view, so direct array mutation after
construction raises.
"""

from __future__ import annotations

import dataclasses
import types
from typing import Callable, Mapping

import jax
import jax.numpy as jnp


# The canonical padding sentinel for invalid relation slots.  Every layer
# that fills dead slots (``sentinel_fill``, ``partition.bucketize``,
# ``partition.bucketize_by_ids``) uses THIS constant; the per-side probe
# sentinels in ``kernels.ops`` are derived from it (SENTINEL + 15 + side)
# so no sentinel of any kind can ever equal a live key (keys are ≥ -2^30
# by the data-layer contract) or a sentinel from another side.
SENTINEL = -0x7FFFFFFF


def _log_bucket_capacity(need: int) -> int:
    """Next power-of-two capacity ≥ need (min 64) — the same log-bucketing
    rule as ``binary_join.bucket_capacity``, inlined to keep this module at
    the bottom of the import graph.  Appends that stay within the bucket
    reuse every compiled shape; only a bucket step re-jits."""
    return max(64, 1 << max(0, int(need) - 1).bit_length())


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class Relation:
    """Columnar relation with static capacity and a validity mask."""

    columns: Mapping[str, jnp.ndarray]  # each (capacity,) int32
    valid: jnp.ndarray                  # (capacity,) bool

    def __post_init__(self):
        # direct mutation after construction must raise: freeze the column
        # mapping behind a read-only view (the arrays themselves are
        # immutable jax arrays) — ``append`` is the one sanctioned mutator
        if not isinstance(self.columns, types.MappingProxyType):
            object.__setattr__(self, "columns",
                               types.MappingProxyType(dict(self.columns)))

    # -- pytree protocol ----------------------------------------------------
    def tree_flatten(self):
        names = tuple(sorted(self.columns))
        return tuple(self.columns[n] for n in names) + (self.valid,), names

    @classmethod
    def tree_unflatten(cls, names, leaves):
        *cols, valid = leaves
        return cls(columns=dict(zip(names, cols)), valid=valid)

    # -- introspection -------------------------------------------------------
    @property
    def capacity(self) -> int:
        return self.valid.shape[0]

    @property
    def n(self) -> jnp.ndarray:
        """Dynamic number of live tuples."""
        return jnp.sum(self.valid.astype(jnp.int32))

    @property
    def version(self) -> int:
        """Ingest version: bumped by every ``append``.  Cache-like layers
        (the standing-query resident intermediates, service snapshots) key
        the validity of derived state on this counter."""
        return self.__dict__.get("_version", 0)

    def col(self, name: str) -> jnp.ndarray:
        return self.columns[name]

    # -- distinct-count sketches ---------------------------------------------
    def distinct_sketch(self, col: str) -> jnp.ndarray:
        """The column's FM/PCSA register bitmaps (``core.sketches``),
        built on first use and cached on the instance.  ``append`` updates
        the cached sketch incrementally (FM insertion is a bitwise OR, so
        the incremental update is exactly the rebuild), which is what lets
        the planner estimate distinct counts without a host scan even
        under continuous ingest; derived relations (``select``/
        ``mask_where``/pytree reconstruction) start with an empty cache."""
        cache = self.__dict__.get("_sketch_cache")
        if cache is None:
            cache = {}
            object.__setattr__(self, "_sketch_cache", cache)
        sk = cache.get(col)
        if sk is None:
            from repro.core import sketches
            sk = sketches.add(sketches.empty(), self.columns[col],
                              self.valid)
            cache[col] = sk
        return sk

    def distinct_estimate(self, col: str) -> int:
        """FM-sketch distinct-count estimate of a column (>= 1), clipped
        to the column's capacity.  The planner's scan-free replacement
        for host ``np.unique`` passes."""
        from repro.core import sketches
        est = int(round(float(sketches.fm_estimate(
            self.distinct_sketch(col)))))
        return max(1, min(est, self.capacity))

    # -- ingest --------------------------------------------------------------
    def on_append(self, callback: Callable) -> None:
        """Register ``callback(relation, delta)`` to run after every
        ``append`` (the standing-query ingest hook)."""
        self.__dict__.setdefault("_observers", []).append(callback)

    def remove_on_append(self, callback: Callable) -> None:
        obs = self.__dict__.get("_observers")
        if obs and callback in obs:
            obs.remove(callback)

    def append(self, cols: Mapping[str, jnp.ndarray] | None = None,
               **col_arrays) -> "Relation":
        """THE ingest mutation point: append a batch of rows in place.

        ``cols`` (or keyword arrays) must cover exactly this relation's
        schema with equal-length arrays.  Live rows are compacted to a
        prefix, capacity grows along power-of-two buckets (so steady
        deltas keep hitting the same compiled shapes), cached FM sketches
        update incrementally, the :attr:`version` counter bumps, and
        ``on_append`` observers fire with the delta — which is what drives
        standing-query delta execution.  Returns the delta as a fresh
        Relation.
        """
        arrs = dict(cols or {})
        arrs.update(col_arrays)
        if set(arrs) != set(self.columns):
            raise ValueError(
                f"append schema mismatch: got {sorted(arrs)}, relation has "
                f"{sorted(self.columns)}")
        arrs = {k: jnp.asarray(v, dtype=jnp.int32) for k, v in arrs.items()}
        lens = {a.shape[0] for a in arrs.values()}
        if len(lens) != 1:
            raise ValueError(f"ragged delta columns: "
                             f"{ {k: v.shape for k, v in arrs.items()} }")
        (k,) = lens
        delta = Relation.from_arrays(**arrs)
        if k == 0:
            return delta
        n0 = int(self.n)
        need = n0 + k
        cap = self.capacity
        new_cap = cap if need <= cap else _log_bucket_capacity(need)
        # compact live rows to a prefix (stable: live order preserved),
        # then write the delta at [n0, n0+k)
        order = jnp.argsort(jnp.where(self.valid, 0, 1).astype(jnp.int32),
                            stable=True)
        pad = new_cap - cap
        new_cols = {}
        for name, col in self.columns.items():
            base = col[order]
            if pad:
                base = jnp.pad(base, (0, pad))
            new_cols[name] = base.at[n0:need].set(arrs[name])
        valid = jnp.arange(new_cap) < need
        object.__setattr__(self, "columns",
                           types.MappingProxyType(new_cols))
        object.__setattr__(self, "valid", valid)
        object.__setattr__(self, "_version", self.version + 1)
        cache = self.__dict__.get("_sketch_cache")
        if cache:
            from repro.core import sketches
            ones = jnp.ones((k,), bool)
            for name, sk in list(cache.items()):
                cache[name] = sketches.add(sk, arrs[name], ones)
        for cb in tuple(self.__dict__.get("_observers", ())):
            cb(self, delta)
        return delta

    # -- construction --------------------------------------------------------
    @classmethod
    def from_arrays(cls, capacity: int | None = None, **cols) -> "Relation":
        """Build from equal-length arrays, optionally padding to `capacity`."""
        arrs = {k: jnp.asarray(v, dtype=jnp.int32) for k, v in cols.items()}
        lens = {a.shape[0] for a in arrs.values()}
        if len(lens) != 1:
            raise ValueError(f"ragged columns: {dict((k, v.shape) for k, v in arrs.items())}")
        (n,) = lens
        cap = capacity or n
        if cap < n:
            raise ValueError(f"capacity {cap} < rows {n}")
        pad = cap - n
        if pad:
            arrs = {k: jnp.pad(a, (0, pad)) for k, a in arrs.items()}
        valid = jnp.arange(cap) < n
        return cls(columns=arrs, valid=valid)

    def select(self, idx: jnp.ndarray, idx_valid: jnp.ndarray) -> "Relation":
        """Gather rows by index (row validity AND idx_valid)."""
        cols = {k: v[idx] for k, v in self.columns.items()}
        return Relation(cols, self.valid[idx] & idx_valid)

    def with_columns(self, **cols) -> "Relation":
        new = dict(self.columns)
        new.update({k: jnp.asarray(v, jnp.int32) for k, v in cols.items()})
        return Relation(new, self.valid)

    def mask_where(self, keep: jnp.ndarray) -> "Relation":
        return Relation(dict(self.columns), self.valid & keep)


def sentinel_fill(rel: Relation, sentinel: int = SENTINEL) -> Relation:
    """Overwrite invalid rows' columns with a sentinel that never equals a
    live key, so masked compare loops need no extra predicate."""
    cols = {
        k: jnp.where(rel.valid, v, jnp.int32(sentinel))
        for k, v in rel.columns.items()
    }
    return Relation(cols, rel.valid)
