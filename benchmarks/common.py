"""Shared helpers for the paper-figure benchmarks: CSV emission + claim
checks.  Every fig4*.py writes artifacts/bench/<name>.csv and returns a
dict of validated claims for run.py's summary."""

from __future__ import annotations

import csv
import pathlib

OUTDIR = pathlib.Path("artifacts/bench")


def write_csv(name: str, header: list[str], rows: list[list]) -> pathlib.Path:
    OUTDIR.mkdir(parents=True, exist_ok=True)
    path = OUTDIR / f"{name}.csv"
    with open(path, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(header)
        w.writerows(rows)
    return path


def claim(results: dict, name: str, ok: bool, detail: str):
    results[name] = {"ok": bool(ok), "detail": detail}
    print(f"  [{'PASS' if ok else 'FAIL'}] {name}: {detail}")
