import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Join-engine dry-run on the production mesh: lower + compile the
distributed cyclic / linear / star 3-way joins, extract the collective
traffic from the partitioned HLO, and validate it against the paper's
replication cost model (§4.2/§5.2):

  cyclic:  wire ≈ (nrow-1)·|S| + (ncol-1)·|T| + 2·|R|   (H|S| + G|T| + R routing)
  linear:  wire ≈ (U-1)·|T|/U · U ≈ (chips-1)·|T|-ish   (T broadcast to all)
  star:    wire ≈ (nrow-1)·|R| + (ncol-1)·|T| + 2·|S|   (dims replicated, S routed)

This is the paper's "number of tuples read onto a chip" metric re-derived
from the compiled SPMD module — the strongest form of reproduction: the
cost model's replication terms are visible as all-gather bytes in HLO.

Run as a standalone process (forces host devices):
    PYTHONPATH=src python benchmarks/join_dryrun.py [--out artifacts/bench]
"""

import argparse
import json
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent
                       / "src"))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="artifacts/bench")
    ap.add_argument("--log-n", type=int, default=24,
                    help="log2 global tuples per relation")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    from repro.core import distributed as dist
    from repro.core.relation import Relation
    from repro.launch import hlo_stats, mesh as mesh_lib

    mesh = mesh_lib.make_production_mesh(multi_pod=args.multi_pod)
    mesh_lib.activate(mesh)
    if args.multi_pod:
        # fold the pod axis into rows: joins scale out along rows
        row, col = ("data", "model")
    else:
        row, col = ("data", "model")
    nrow, ncol = mesh.shape[row], mesh.shape[col]
    n_chips = mesh.devices.size

    n = 1 << args.log_n
    tb = 8     # two int32 columns

    def rel(cols):
        return Relation({c: jax.ShapeDtypeStruct((n,), jnp.int32)
                         for c in cols},
                        jax.ShapeDtypeStruct((n,), jnp.bool_))

    results = {}
    cases = {
        "cyclic3": (dist.cyclic3_count_sharded(mesh, row, col),
                    (rel("ab"), rel("bc"), rel("ca")),
                    2 * n * tb + (nrow - 1) * n * tb + (ncol - 1) * n * tb),
        "linear3": (dist.linear3_count_sharded(mesh, row, col),
                    (rel("ab"), rel("bc"), rel("cd")),
                    2 * n * tb + 2 * n * tb + (n_chips - 1) * n * tb),
        "star3": (dist.star3_count_sharded(mesh, row, col),
                  (rel("ab"), rel("bc"), rel("cd")),
                  (nrow - 1) * n * tb + (ncol - 1) * n * tb + 2 * n * tb),
    }
    outdir = pathlib.Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)

    for name, (fn, rels, predicted) in cases.items():
        with mesh:
            lowered = jax.jit(fn).lower(*rels)
            compiled = lowered.compile()
        ma = compiled.memory_analysis()
        hlo = compiled.as_text()
        stats = hlo_stats.analyze(hlo, world=n_chips)
        wire_total = stats["collective_wire_bytes"] * n_chips
        ratio = wire_total / predicted
        results[name] = {
            "n_tuples": n,
            "mesh": f"{nrow}x{ncol}" + ("x2pod" if args.multi_pod else ""),
            "wire_bytes_per_device": stats["collective_wire_bytes"],
            "wire_bytes_total": wire_total,
            "paper_predicted_bytes": predicted,
            "measured_over_predicted": ratio,
            "wire_by_kind": stats["wire_by_kind"],
            "temp_bytes_per_device": getattr(ma, "temp_size_in_bytes",
                                             None),
            "ok": True,
        }
        print(f"{name}: wire_total={wire_total:.3e} B  "
              f"paper_predicted={predicted:.3e} B  ratio={ratio:.2f}")

    (outdir / "join_dryrun.json").write_text(json.dumps(results, indent=2))
    print("wrote", outdir / "join_dryrun.json")


if __name__ == "__main__":
    main()
