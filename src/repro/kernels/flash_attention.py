"""Pallas TPU flash attention (forward + custom-VJP backward).

Why this kernel exists (EXPERIMENTS.md §Perf, dense-train cells): the HLO
trace of the jnp chunked-softmax attention shows ~6 HBM materializations of
the [qc, kc] score tensor per layer per pass — S²·B·H·4 bytes each, ~2 TB
per step for a 1.5B model at 4k — and iterations it-1/it-1b proved that
neither layout restructuring nor remat removes them: score traffic is
irreducible WITHOUT kernel fusion.  This kernel keeps scores in VMEM.

Design (TPU-native, not a CUDA port):
  grid = (batch, q_heads, n_q_chunks)  — embarrassingly parallel programs
  fwd:  q block [qc, D] pinned in VMEM; fori_loop over kv chunks streams
        k/v blocks [kc, D]; online-softmax state (m, l, acc) lives in VMEM
        scratch; one MXU dot per (q,kv) chunk pair each for q·kᵀ and p·v.
  bwd:  recompute-in-backward (two passes): pass 1 re-runs the forward
        loop to rebuild p from (q, k, m, l) and accumulates dv, dp, dq;
        dk accumulated via the transposed products.  No score tensor ever
        reaches HBM in either direction.

GQA: the kv head for q head h is h // (nq // nkv), applied in the
BlockSpec index_map — zero data duplication.

HBM traffic contract (what the roofline substitution accounts):
  fwd:   read q + k·nkc_eff + v·nkc_eff + write o + (m,l stats)
  bwd:   read q,k,v,o,do + write dq,dk,dv  (one recompute pass)
Causality halves the effective kv chunks (programs skip j > i blocks via
fori upper bound).

Validated against ref.flash_reference in interpret mode over
shape/dtype/window sweeps (tests/test_flash_kernel.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -2.0e38


def _load4(ref, h, start, size):
    """Load ref[0, h, start:start+size, :] as a [size, D] block.

    All four indices are Slice objects (size-1 slices squeezed afterwards):
    older jax pallas (0.4.x) rejects plain ints mixed into a pl.load index
    tuple, and ``h`` is dynamic in the dkv kernel anyway.
    """
    return pl.load(ref, (pl.dslice(0, 1), pl.dslice(h, 1),
                         pl.dslice(start, size), slice(None)))[0, 0]


# --------------------------------------------------------------------------
# forward
# --------------------------------------------------------------------------

def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, *,
                kv_chunk: int, causal: bool, window: int, scale: float):
    qc, d = q_ref.shape[2], q_ref.shape[3]
    t = k_ref.shape[2]
    nkc = t // kv_chunk
    qi = pl.program_id(2)
    q = q_ref[0, 0].astype(jnp.float32) * scale

    q_pos = qi * qc + jax.lax.broadcasted_iota(jnp.int32, (qc, 1), 0)

    def body(j, carry):
        m, l, acc = carry
        k = _load4(k_ref, 0, j * kv_chunk, kv_chunk).astype(jnp.float32)
        v = _load4(v_ref, 0, j * kv_chunk, kv_chunk).astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        k_pos = j * kv_chunk + jax.lax.broadcasted_iota(
            jnp.int32, (1, kv_chunk), 1)
        mask = jnp.ones_like(s, dtype=jnp.bool_)
        if causal:
            mask = mask & (k_pos <= q_pos)
        if window > 0:
            mask = mask & (k_pos > q_pos - window)
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=1, keepdims=True))
        safe = jnp.where(m_new <= NEG_INF / 2, 0.0, m_new)
        p = jnp.exp(s - safe)
        corr = jnp.exp(m - safe)
        l_new = l * corr + jnp.sum(p, axis=1, keepdims=True)
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_new = acc * corr + pv
        return m_new, l_new, acc_new

    m0 = jnp.full((qc, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((qc, 1), jnp.float32)
    a0 = jnp.zeros((qc, d), jnp.float32)
    if causal:
        # programs skip fully-masked kv blocks: j*kc <= (qi+1)*qc - 1
        upper = jnp.minimum(((qi + 1) * qc - 1) // kv_chunk + 1, nkc)
    else:
        upper = nkc
    m, l, acc = jax.lax.fori_loop(0, upper, body, (m0, l0, a0))
    o_ref[0, 0] = (acc / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)
    m_ref[0, 0] = m
    l_ref[0, 0] = l


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "q_chunk", "kv_chunk",
                              "interpret"))
def flash_fwd(q, k, v, *, causal=True, window=0, q_chunk=256,
              kv_chunk=512, interpret=True):
    """q: [B,S,H,D]; k/v: [B,T,KVH,D] -> (o [B,S,H,D], m, l [B,H,S,1])."""
    b, s_len, nq, d = q.shape
    t_len, nkv = k.shape[1], k.shape[2]
    g = nq // nkv
    q_chunk = min(q_chunk, s_len)
    kv_chunk = min(kv_chunk, t_len)
    assert s_len % q_chunk == 0 and t_len % kv_chunk == 0, \
        (s_len, q_chunk, t_len, kv_chunk)
    nqc = s_len // q_chunk
    scale = 1.0 / (d ** 0.5)

    # layouts: q -> [B,H,S,D]; k/v -> [B,KVH,T,D]
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)

    kernel = functools.partial(
        _fwd_kernel, kv_chunk=kv_chunk, causal=causal, window=window,
        scale=scale)
    o, m, l = pl.pallas_call(
        kernel,
        grid=(b, nq, nqc),
        in_specs=[
            pl.BlockSpec((1, 1, q_chunk, d),
                         lambda bi, h, qi: (bi, h, qi, 0)),
            pl.BlockSpec((1, 1, t_len, d),
                         lambda bi, h, qi, g=g: (bi, h // g, 0, 0)),
            pl.BlockSpec((1, 1, t_len, d),
                         lambda bi, h, qi, g=g: (bi, h // g, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, q_chunk, d),
                         lambda bi, h, qi: (bi, h, qi, 0)),
            pl.BlockSpec((1, 1, q_chunk, 1),
                         lambda bi, h, qi: (bi, h, qi, 0)),
            pl.BlockSpec((1, 1, q_chunk, 1),
                         lambda bi, h, qi: (bi, h, qi, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, nq, s_len, d), q.dtype),
            jax.ShapeDtypeStruct((b, nq, s_len, 1), jnp.float32),
            jax.ShapeDtypeStruct((b, nq, s_len, 1), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return o.transpose(0, 2, 1, 3), m, l


# --------------------------------------------------------------------------
# backward (recompute-in-backward, one pass builds dq; one builds dk/dv)
# --------------------------------------------------------------------------

def _bwd_dq_kernel(q_ref, k_ref, v_ref, do_ref, m_ref, l_ref, delta_ref,
                   dq_ref, *, kv_chunk: int, causal: bool, window: int,
                   scale: float):
    qc, d = q_ref.shape[2], q_ref.shape[3]
    t = k_ref.shape[2]
    nkc = t // kv_chunk
    qi = pl.program_id(2)
    q = q_ref[0, 0].astype(jnp.float32) * scale
    do = do_ref[0, 0].astype(jnp.float32)
    m = m_ref[0, 0]
    l = jnp.maximum(l_ref[0, 0], 1e-30)
    delta = delta_ref[0, 0]                    # Σ_d o·do per q row
    q_pos = qi * qc + jax.lax.broadcasted_iota(jnp.int32, (qc, 1), 0)

    def body(j, dq):
        k = _load4(k_ref, 0, j * kv_chunk, kv_chunk).astype(jnp.float32)
        v = _load4(v_ref, 0, j * kv_chunk, kv_chunk).astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        k_pos = j * kv_chunk + jax.lax.broadcasted_iota(
            jnp.int32, (1, kv_chunk), 1)
        mask = jnp.ones_like(s, dtype=jnp.bool_)
        if causal:
            mask = mask & (k_pos <= q_pos)
        if window > 0:
            mask = mask & (k_pos > q_pos - window)
        safe = jnp.where(m <= NEG_INF / 2, 0.0, m)
        p = jnp.where(mask, jnp.exp(s - safe), 0.0) / l
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta)
        return dq + jax.lax.dot_general(ds, k, (((1,), (0,)), ((), ())),
                                        preferred_element_type=jnp.float32)

    if causal:
        upper = jnp.minimum(((qi + 1) * qc - 1) // kv_chunk + 1, nkc)
    else:
        upper = nkc
    dq = jax.lax.fori_loop(0, upper, body,
                           jnp.zeros((qc, d), jnp.float32))
    dq_ref[0, 0] = (dq * scale).astype(dq_ref.dtype)


def _bwd_dkv_kernel(q_ref, k_ref, v_ref, do_ref, m_ref, l_ref, delta_ref,
                    dk_ref, dv_ref, *, q_chunk: int, causal: bool,
                    window: int, scale: float, g: int):
    """One program per (b, kv_head, kv chunk): loops q chunks × the g query
    heads of this kv head, accumulating dk/dv."""
    kc, d = dk_ref.shape[2], dk_ref.shape[3]
    s_total = q_ref.shape[2]
    nqc = s_total // q_chunk
    ki = pl.program_id(2)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    k_pos = ki * kc + jax.lax.broadcasted_iota(jnp.int32, (1, kc), 1)

    def q_loop(it, carry):
        dk, dv = carry
        hq = it // nqc
        qi = it % nqc
        q = _load4(q_ref, hq, qi * q_chunk, q_chunk).astype(jnp.float32) \
            * scale
        do = _load4(do_ref, hq, qi * q_chunk, q_chunk).astype(jnp.float32)
        m = _load4(m_ref, hq, qi * q_chunk, q_chunk)
        l = jnp.maximum(_load4(l_ref, hq, qi * q_chunk, q_chunk), 1e-30)
        delta = _load4(delta_ref, hq, qi * q_chunk, q_chunk)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        q_pos = qi * q_chunk + jax.lax.broadcasted_iota(
            jnp.int32, (q_chunk, 1), 0)
        mask = jnp.ones_like(s, dtype=jnp.bool_)
        if causal:
            mask = mask & (k_pos <= q_pos)
        if window > 0:
            mask = mask & (k_pos > q_pos - window)
        safe = jnp.where(m <= NEG_INF / 2, 0.0, m)
        p = jnp.where(mask, jnp.exp(s - safe), 0.0) / l
        dv_new = dv + jax.lax.dot_general(
            p, do, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta)
        dk_new = dk + jax.lax.dot_general(
            ds, q, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        return dk_new, dv_new

    dk0 = jnp.zeros((kc, d), jnp.float32)
    dv0 = jnp.zeros((kc, d), jnp.float32)
    dk, dv = jax.lax.fori_loop(0, g * nqc, q_loop, (dk0, dv0))
    dk_ref[0, 0] = dk.astype(dk_ref.dtype)
    dv_ref[0, 0] = dv.astype(dv_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "q_chunk", "kv_chunk",
                              "interpret"))
def flash_bwd(q, k, v, o, m, l, do, *, causal=True, window=0,
              q_chunk=256, kv_chunk=512, interpret=True):
    b, s_len, nq, d = q.shape
    t_len, nkv = k.shape[1], k.shape[2]
    g = nq // nkv
    q_chunk = min(q_chunk, s_len)
    kv_chunk = min(kv_chunk, t_len)
    nqc = s_len // q_chunk
    nkc = t_len // kv_chunk
    scale = 1.0 / (d ** 0.5)

    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    dot = do.transpose(0, 2, 1, 3)
    ot = o.transpose(0, 2, 1, 3)
    delta = jnp.sum(ot.astype(jnp.float32) * dot.astype(jnp.float32),
                    axis=-1, keepdims=True)                 # [B,H,S,1]

    dq = pl.pallas_call(
        functools.partial(_bwd_dq_kernel, kv_chunk=kv_chunk, causal=causal,
                          window=window, scale=scale),
        grid=(b, nq, nqc),
        in_specs=[
            pl.BlockSpec((1, 1, q_chunk, d),
                         lambda bi, h, qi: (bi, h, qi, 0)),
            pl.BlockSpec((1, 1, t_len, d),
                         lambda bi, h, qi, g=g: (bi, h // g, 0, 0)),
            pl.BlockSpec((1, 1, t_len, d),
                         lambda bi, h, qi, g=g: (bi, h // g, 0, 0)),
            pl.BlockSpec((1, 1, q_chunk, d),
                         lambda bi, h, qi: (bi, h, qi, 0)),
            pl.BlockSpec((1, 1, q_chunk, 1),
                         lambda bi, h, qi: (bi, h, qi, 0)),
            pl.BlockSpec((1, 1, q_chunk, 1),
                         lambda bi, h, qi: (bi, h, qi, 0)),
            pl.BlockSpec((1, 1, q_chunk, 1),
                         lambda bi, h, qi: (bi, h, qi, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, q_chunk, d),
                               lambda bi, h, qi: (bi, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, nq, s_len, d), q.dtype),
        interpret=interpret,
    )(qt, kt, vt, dot, m, l, delta)

    dk, dv = pl.pallas_call(
        functools.partial(_bwd_dkv_kernel, q_chunk=q_chunk, causal=causal,
                          window=window, scale=scale, g=g),
        grid=(b, nkv, nkc),
        in_specs=[
            pl.BlockSpec((1, g, s_len, d),
                         lambda bi, hk, ki, g=g: (bi, hk, 0, 0)),
            pl.BlockSpec((1, 1, kv_chunk, d),
                         lambda bi, hk, ki: (bi, hk, ki, 0)),
            pl.BlockSpec((1, 1, kv_chunk, d),
                         lambda bi, hk, ki: (bi, hk, ki, 0)),
            pl.BlockSpec((1, g, s_len, d),
                         lambda bi, hk, ki, g=g: (bi, hk, 0, 0)),
            pl.BlockSpec((1, g, s_len, 1),
                         lambda bi, hk, ki, g=g: (bi, hk, 0, 0)),
            pl.BlockSpec((1, g, s_len, 1),
                         lambda bi, hk, ki, g=g: (bi, hk, 0, 0)),
            pl.BlockSpec((1, g, s_len, 1),
                         lambda bi, hk, ki, g=g: (bi, hk, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, kv_chunk, d),
                         lambda bi, hk, ki: (bi, hk, ki, 0)),
            pl.BlockSpec((1, 1, kv_chunk, d),
                         lambda bi, hk, ki: (bi, hk, ki, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, nkv, t_len, d), k.dtype),
            jax.ShapeDtypeStruct((b, nkv, t_len, d), v.dtype),
        ],
        interpret=interpret,
    )(qt, kt, vt, dot, m, l, delta)
    return (dq.transpose(0, 2, 1, 3), dk.transpose(0, 2, 1, 3),
            dv.transpose(0, 2, 1, 3))


# --------------------------------------------------------------------------
# custom-VJP wrapper
# --------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def flash_attention_kernel(q, k, v, causal=True, window=0, q_chunk=256,
                           kv_chunk=512, interpret=True):
    o, _, _ = flash_fwd(q, k, v, causal=causal, window=window,
                        q_chunk=q_chunk, kv_chunk=kv_chunk,
                        interpret=interpret)
    return o


def _fa_fwd(q, k, v, causal, window, q_chunk, kv_chunk, interpret):
    o, m, l = flash_fwd(q, k, v, causal=causal, window=window,
                        q_chunk=q_chunk, kv_chunk=kv_chunk,
                        interpret=interpret)
    return o, (q, k, v, o, m, l)


def _fa_bwd(causal, window, q_chunk, kv_chunk, interpret, res, do):
    q, k, v, o, m, l = res
    dq, dk, dv = flash_bwd(q, k, v, o, m, l, do, causal=causal,
                           window=window, q_chunk=q_chunk,
                           kv_chunk=kv_chunk, interpret=interpret)
    return dq, dk, dv


flash_attention_kernel.defvjp(_fa_fwd, _fa_bwd)


def hbm_bytes(cfg, batch: int, seq: int, *, train: bool) -> float:
    """The kernel's HBM traffic contract (per layer, per device inputs):
    fwd reads q,k,v (+stats) and writes o; bwd reads q,k,v,o,do and writes
    dq,dk,dv.  Used by the dry-run's roofline substitution."""
    bt = 2  # bf16
    qo = batch * seq * cfg.n_heads * cfg.head_dim * bt
    kv = batch * seq * cfg.n_kv_heads * cfg.head_dim * bt
    fwd = 2 * qo + 2 * kv + 2 * (batch * seq * cfg.n_heads * 4) * 2
    if not train:
        return fwd
    bwd = 3 * qo + 2 * kv + (qo + 2 * kv)      # q,o,do reads + dq,dk,dv
    return fwd + bwd
