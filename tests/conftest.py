"""Shared fixtures: python set/dict join oracles + relation generators.

The oracles are deliberately naive (dict-of-lists nested loops) — they are
the ground truth every JAX/Pallas path is checked against.
"""

from __future__ import annotations

import pathlib
import sys
from collections import Counter, defaultdict

import numpy as np
import pytest

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

# Hermetic images may lack hypothesis (a dev dependency); fall back to the
# bundled deterministic shim so property tests still collect and run.  This
# must happen in conftest, before pytest imports any test module.
try:
    import hypothesis  # noqa: F401
except ImportError:
    import _hypothesis_shim
    sys.modules["hypothesis"] = _hypothesis_shim
    sys.modules["hypothesis.strategies"] = _hypothesis_shim.strategies

from repro.core.relation import Relation  # noqa: E402


# --------------------------------------------------------------------------
# data generators
# --------------------------------------------------------------------------

def skewed_keys(rng: np.random.Generator, n: int, d: int, frac: float,
                heavy: int = 1) -> np.ndarray:
    """Adversarial keys: a heavy hitter owning ``frac`` of all rows (a
    single hash bucket must absorb it — no salt can spread one key); the
    remaining rows are uniform over [0, d)."""
    n_heavy = int(n * frac)
    vals = np.concatenate([
        np.full(n_heavy, heavy, np.int32),
        rng.integers(0, d, size=n - n_heavy).astype(np.int32)])
    rng.shuffle(vals)
    return vals


def make_rel(rng: np.random.Generator, n: int, cols: tuple[str, ...],
             d: int, cap_extra: int = 0, zipf: float | None = None):
    """Random relation; returns (Relation, dict of raw numpy columns)."""
    data = {}
    for c in cols:
        if zipf is None:
            data[c] = rng.integers(0, d, size=n).astype(np.int32)
        else:
            v = rng.zipf(zipf, size=n)
            data[c] = (np.minimum(v, d) - 1).astype(np.int32)
    rel = Relation.from_arrays(capacity=n + cap_extra, **data)
    return rel, data


# --------------------------------------------------------------------------
# oracles
# --------------------------------------------------------------------------

def oracle_pair_count(a_keys, b_keys) -> int:
    ca = Counter(a_keys.tolist())
    return sum(ca.get(k, 0) for k in b_keys.tolist())


def oracle_linear3_count(rb, sb, sc, tc) -> int:
    ct = Counter(tc.tolist())
    w = np.array([ct.get(c, 0) for c in sc.tolist()], dtype=np.int64)
    cs = defaultdict(int)
    for b, wi in zip(sb.tolist(), w.tolist()):
        cs[b] += wi
    return int(sum(cs.get(b, 0) for b in rb.tolist()))


def oracle_linear3_per_r(rb, sb, sc, tc) -> np.ndarray:
    ct = Counter(tc.tolist())
    w = np.array([ct.get(c, 0) for c in sc.tolist()], dtype=np.int64)
    cs = defaultdict(int)
    for b, wi in zip(sb.tolist(), w.tolist()):
        cs[b] += wi
    return np.array([cs.get(b, 0) for b in rb.tolist()], dtype=np.int64)


def oracle_cyclic3_count(ra, rb, sb, sc, tc, ta) -> int:
    s_by_b = defaultdict(list)
    for b, c in zip(sb.tolist(), sc.tolist()):
        s_by_b[b].append(c)
    t_by_ca = Counter(zip(tc.tolist(), ta.tolist()))
    total = 0
    for a, b in zip(ra.tolist(), rb.tolist()):
        for c in s_by_b.get(b, ()):
            total += t_by_ca.get((c, a), 0)
    return total


def oracle_distinct_join_pairs(rb, ra, sb, sc, tc, td) -> int:
    """|distinct (a, d) pairs in the linear 3-way join output|."""
    s_by_b = defaultdict(set)
    for b, c in zip(sb.tolist(), sc.tolist()):
        s_by_b[b].add(c)
    t_by_c = defaultdict(set)
    for c, dv in zip(tc.tolist(), td.tolist()):
        t_by_c[c].add(dv)
    pairs = set()
    for a, b in zip(ra.tolist(), rb.tolist()):
        for c in s_by_b.get(b, ()):
            for dv in t_by_c.get(c, ()):
                pairs.add((a, dv))
    return len(pairs)


@pytest.fixture
def rng():
    return np.random.default_rng(1234)
