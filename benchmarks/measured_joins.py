"""Measured (real-execution) joins on this container's CPU backend: wall
time for the JAX linear-3-way vs the cascaded binary plan on the same
data, plus correctness cross-check (identical counts).  This grounds the
analytic Fig-4 model with an actually-executed data point; absolute times
are CPU-backend times, not TPU predictions."""

from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import claim, write_csv
from repro.core import (cascaded_binary_count, linear3_count,
                        linear3_default_plan)
from repro.data.relations import RelGenConfig, gen_relation


def _rst(n, d):
    """R(a,b), S(b,c), T(c,d) — three instances of the friends relation."""
    r = gen_relation(RelGenConfig(n=n, d=d, columns=("a", "b"), seed=1))
    s = gen_relation(RelGenConfig(n=n, d=d, columns=("b", "c"), seed=2))
    t = gen_relation(RelGenConfig(n=n, d=d, columns=("c", "d"), seed=3))
    return r, s, t


def _timeit(fn, *args, reps=3):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps, out


def main(results: dict | None = None):
    results = results if results is not None else {}
    print("measured_joins: real execution (CPU backend)")
    rows = []
    agree = True
    for n, d in ((2000, 200), (8000, 400), (20000, 500)):
        r, s, t = _rst(n, d)
        plan3 = linear3_default_plan(n, n, n, m_budget=max(n // 2, 512))
        # grow bucket capacities until nothing overflows (driver loop),
        # then time the final jitted plan
        from repro.core import reference
        res3, plan3 = reference.linear3_count_auto(r, s, t, plan3)
        icap = int(n * n / d * 2)          # |I| ≈ n²/d with 2x slack
        while bool(cascaded_binary_count(r, s, t, icap)
                   .intermediate_overflowed):
            icap *= 2

        f3 = jax.jit(lambda a, b, c: linear3_count(a, b, c, plan3))
        fc = jax.jit(lambda a, b, c: cascaded_binary_count(a, b, c, icap))
        t3, r3 = _timeit(f3, r, s, t)
        tc, rc = _timeit(fc, r, s, t)
        c3, cc = int(r3.count), int(rc.count)
        ovf = bool(r3.overflowed) or bool(rc.intermediate_overflowed)
        agree &= (c3 == cc) and not ovf
        rows.append([n, d, c3, cc, t3 * 1e3, tc * 1e3, tc / t3, ovf])
        print(f"  n={n:6d} d={d:4d}  count={c3}  3way={t3 * 1e3:8.1f}ms  "
              f"cascade={tc * 1e3:8.1f}ms  ratio={tc / t3:5.2f}x")
    write_csv("measured_joins",
              ["n", "d", "count_3way", "count_cascade", "t3_ms", "tc_ms",
               "cascade_over_3way", "overflowed"], rows)
    claim(results, "measured_counts_agree", agree,
          "3-way and cascaded counts identical, no overflow "
          "(real execution)")

    # brute-force oracle on the smallest size
    n, d = 2000, 200
    r, s, t = _rst(n, d)
    rb = np.asarray(r.col("b")); sb = np.asarray(s.col("b"))
    sc = np.asarray(s.col("c")); tcol = np.asarray(t.col("c"))
    exact = int(((rb[:, None] == sb[None, :]).sum(0).astype(np.int64)
                 * (sc[:, None] == tcol[None, :]).sum(1)).sum())
    from repro.core import reference
    plan3 = linear3_default_plan(n, n, n, m_budget=1024)
    res, _ = reference.linear3_count_auto(r, s, t, plan3)
    got = int(res.count)
    claim(results, "measured_matches_bruteforce", got == exact,
          f"linear3 count {got} == numpy brute force {exact}")
    return results


if __name__ == "__main__":
    main()
