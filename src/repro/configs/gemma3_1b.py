"""gemma3-1b — dense, 5:1 local:global attention, 128k rope
[hf:google/gemma-3-1b-pt; unverified].

26L d_model=1152 4H (GQA kv=1) d_ff=6912 vocab=262144.  Sliding window 512
on local layers; global layers use rope_theta=1e6, local layers 1e4.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-1b", family="dense",
    n_layers=26, d_model=1152, n_heads=4, n_kv_heads=1,
    d_ff=6912, vocab_size=262144, head_dim=256,
    rope_theta=1e6, rope_local_theta=1e4,
    sliding_window=512, local_pattern=5,
    qk_norm=True, act="gelu", tie_embeddings=True, norm_eps=1e-6,
    accum_steps=2,
)

SMOKE = ModelConfig(
    name="gemma3-1b-smoke", family="dense",
    n_layers=6, d_model=96, n_heads=4, n_kv_heads=1,
    d_ff=256, vocab_size=512, head_dim=32,
    rope_theta=1e6, rope_local_theta=1e4,
    sliding_window=16, local_pattern=5,
    qk_norm=True, act="gelu", tie_embeddings=True, norm_eps=1e-6,
    remat=False,
)
