"""AdamW with decoupled weight decay, global-norm clipping and a
warmup+cosine schedule — pure pytree functions (no optax dependency).

Optimizer state is stored in f32 and inherits each parameter's sharding
(m/v are tree-mapped over params), so ZeRO-style sharding falls out of the
parameter sharding rules for free.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def cosine_schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    scale = cfg.min_lr_ratio + (1.0 - cfg.min_lr_ratio) * cos
    return cfg.lr * warm * scale


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), gn


def adamw_init(params):
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)  # noqa: E731
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def adamw_update(params, grads, state, cfg: AdamWConfig):
    """Returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = state["step"] + 1
    lr = cosine_schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m2 = b1 * m + (1 - b1) * g
        v2 = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m2 / bc1
        vhat = v2 / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) \
            + cfg.weight_decay * p.astype(jnp.float32)
        return (p - lr * delta).astype(p.dtype), m2, v2

    flat_p, tree = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tree.unflatten([o[0] for o in out])
    new_m = tree.unflatten([o[1] for o in out])
    new_v = tree.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, \
        {"lr": lr, "grad_norm": gnorm}
