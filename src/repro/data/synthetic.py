"""Synthetic token streams for training/serving drivers and smoke tests.

Deterministic per (seed, step) so restarts resume mid-epoch without host
state (fault-tolerance: the data pipeline is a pure function of the step
counter — see repro.runtime)."""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class TokenGenConfig:
    vocab_size: int
    batch: int
    seq_len: int
    seed: int = 0
    n_frontend_tokens: int = 0   # audio/vlm memory stub
    d_model: int = 0


def batch_at(cfg: TokenGenConfig, step: int):
    """Pure function (cfg, step) -> batch dict (numpy, host-side)."""
    rng = np.random.default_rng((cfg.seed * 1_000_003 + step) & 0x7FFFFFFF)
    toks = rng.integers(0, cfg.vocab_size,
                        size=(cfg.batch, cfg.seq_len + 1)).astype(np.int32)
    batch = {"inputs": toks[:, :-1], "targets": toks[:, 1:]}
    if cfg.n_frontend_tokens:
        batch["memory"] = rng.normal(
            0, 1, size=(cfg.batch, cfg.n_frontend_tokens,
                        cfg.d_model)).astype(np.float32)
    return batch


def token_batches(cfg: TokenGenConfig, start_step: int = 0):
    """Infinite iterator of batches starting at `start_step` (resumable)."""
    step = start_step
    while True:
        yield step, batch_at(cfg, step)
        step += 1
