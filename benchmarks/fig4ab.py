"""Fig 4 (a,b): cascaded binary self-join execution time vs H_bkt / G_bkt
with phase breakdown.  Validates the paper's bottleneck markers: join 1 is
DRAM/store-bound (H_bkt has no effect); join 2 is compute-bound at small
G_bkt and stream-bound at large."""

from __future__ import annotations

from benchmarks.common import claim, write_csv
from repro.perfmodel import PLASTICINE, binary_cascade_time

N, D = 2e8, 7e5


def main(results: dict | None = None):
    results = results if results is not None else {}
    print("fig4ab: cascaded binary join hyperparameter sweeps")

    rows_a = []
    j1 = []
    for h in (4, 16, 64, 256, 1024, 4096, 16384, 65536):
        b = binary_cascade_time(N, N, N, D, PLASTICINE, h_bkt=h)
        rows_a.append([h, b.partition, b.join1, b.join2, b.total,
                       b.bottleneck])
        j1.append(b.join1)
    write_csv("fig4a_binary_hbkt", ["h_bkt", "partition_s", "join1_s",
                                    "join2_s", "total_s", "bottleneck"],
              rows_a)
    flat = (max(j1) - min(j1)) / max(j1) < 0.01
    claim(results, "fig4a_join1_dram_bound_flat_in_hbkt", flat,
          f"join1 varies {100 * (max(j1) - min(j1)) / max(j1):.2f}% "
          f"across H_bkt (paper: DRAM-bound, no effect)")

    rows_b = []
    bns = {}
    for g in (4, 16, 64, 256, 1024, 4096, 16384, 262144, 4194304):
        b = binary_cascade_time(N, N, N, D, PLASTICINE, g_bkt=g)
        comp = b.stages["j2_comp"]
        stream = b.stages["j2_stream_I"]
        bn = "comp" if comp > stream else "stream_RS"
        bns[g] = bn
        rows_b.append([g, b.partition, b.join1, b.join2, b.total, bn])
    write_csv("fig4b_binary_gbkt", ["g_bkt", "partition_s", "join1_s",
                                    "join2_s", "total_s", "j2_bottleneck"],
              rows_b)
    claim(results, "fig4b_join2_comp_to_stream_shift",
          bns[4] == "comp" and bns[4194304] == "stream_RS",
          f"j2 bottleneck small G={bns[4]} -> large G={bns[4194304]} "
          "(paper: compute-bound -> stream_RS)")
    return results


if __name__ == "__main__":
    main()
