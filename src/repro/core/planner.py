"""Join planner: 3-way vs cascaded-binary decision (§6 logic).

Three decision layers:
  * traffic  — the paper's closed-form tuple-traffic comparison
    (re-exported from cost_model: Examples 3/4 thresholds),
  * time     — the Appendix-A cycle model on a concrete hardware profile
    (captures the compute/DRAM/SSD terms traffic alone misses, e.g. the
    v5e case where fast host DMA shrinks the 3-way win to 2.1×),
  * execution — ``plan_query`` returns an **executable** ``EnginePlan``:
    the timed choice plus a sized shape plan bound to the fused
    ``MultiwayJoinEngine``, so ``plan.run(r, s, t)`` goes straight from
    planning to an exact (skew-recovered) answer.
"""

from __future__ import annotations

import dataclasses

from repro.core import binary_join, cyclic3, engine, linear3, star3
from repro.core.cost_model import (  # noqa: F401  (traffic layer)
    PlanChoice, cascaded_binary_tuples, choose_cyclic_strategy,
    choose_linear_strategy, cyclic3_tuples, linear3_tuples)
from repro.perfmodel import (HW, PLASTICINE, binary_cascade_time,
                             linear3_time, star3_binary_time, star3_time)


@dataclasses.dataclass(frozen=True)
class TimedChoice:
    strategy: str            # "3way" | "cascade"
    t_3way_s: float
    t_cascade_s: float
    speedup: float           # cascade / 3way (>1 favors the 3-way)
    bottleneck_3way: str
    bottleneck_cascade: str


def choose_linear_timed(n_r: float, n_s: float, n_t: float, d: float,
                        hw: HW = PLASTICINE) -> TimedChoice:
    """Self/linear 3-way vs cascade on a hardware profile (Fig 4 e/f)."""
    t3 = linear3_time(n_r, n_s, n_t, d, hw)
    tc = binary_cascade_time(n_r, n_s, n_t, d, hw)
    return TimedChoice(
        "3way" if t3.total < tc.total else "cascade",
        t3.total, tc.total, tc.total / t3.total,
        t3.bottleneck, tc.bottleneck)


def choose_star_timed(n_r: float, n_s: float, n_t: float, d: float,
                      hw: HW = PLASTICINE) -> TimedChoice:
    """Star 3-way vs cascade (Fig 4 g/h/i)."""
    t3 = star3_time(n_r, n_s, n_t, d, hw)
    tc = star3_binary_time(n_r, n_s, n_t, d, hw)
    return TimedChoice(
        "3way" if t3.total < tc.total else "cascade",
        t3.total, tc.total, tc.total / t3.total,
        t3.bottleneck, tc.bottleneck)


# --------------------------------------------------------------------------
# executable engine plans
# --------------------------------------------------------------------------

# the "no time model ran" marker: strategy forced to 3-way, time fields
# explicitly n/a rather than a wrong estimate
FORCED_3WAY_CHOICE = TimedChoice("3way", float("nan"), float("nan"),
                                 float("inf"), "n/a", "n/a")

@dataclasses.dataclass(frozen=True)
class EnginePlan:
    """A sized, executable query plan: the timed 3-way/cascade decision plus
    the shape plan the fused engine runs with.  ``run`` executes the chosen
    strategy and returns an exact count (skew-recovered on the 3-way path,
    capacity-retried on the cascade path)."""

    kind: str                                   # "linear"|"cyclic"|"star"
    strategy: str                               # "3way" | "cascade"
    shape_plan: object                          # Linear3Plan | Cyclic3Plan | Star3Plan
    choice: TimedChoice
    m_budget: int | None
    use_kernel: bool = False
    max_rounds: int = 3
    growth: float = 2.0
    base_salt: int = 0

    def build(self) -> engine.MultiwayJoinEngine:
        # base_salt MUST flow through: a plan-level salt that build()
        # drops would silently de-randomize every recovery round
        return engine.MultiwayJoinEngine(
            self.kind, use_kernel=self.use_kernel,
            max_rounds=self.max_rounds, growth=self.growth,
            base_salt=self.base_salt)

    def run(self, r, s, t, *, binding=None, **cols) -> engine.EngineResult:
        """Execute the chosen strategy.  Column names come from ``binding``
        (a ``query.Binding``, the declarative path) or the legacy
        ``rb=/sb=/...`` kwargs."""
        if binding is not None:
            cols = binding.col_kwargs()
        if self.strategy == "3way" or self.kind == "cyclic":
            return self.build().count(r, s, t, self.shape_plan,
                                      binding=binding, **cols)
        # cascade fallback: size the materialized intermediate from the
        # EXACT first-join cardinality (a cheap host-side histogram
        # product), so skewed keys can't overflow it
        import jax.numpy as jnp
        import numpy as np
        rv = np.asarray(r.col(cols.get("rb", "b")))[np.asarray(r.valid)]
        sv = np.asarray(s.col(cols.get("sb", "b")))[np.asarray(s.valid)]
        ru, rc = np.unique(rv, return_counts=True)
        su, sc = np.unique(sv, return_counts=True)
        _, ri, si = np.intersect1d(ru, su, return_indices=True)
        inter = int((rc[ri].astype(np.int64) * sc[si]).sum())
        res = binary_join.cascaded_binary_count(
            r, s, t, intermediate_capacity=max(64, inter + 8), **cols)
        assert not bool(res.intermediate_overflowed)   # exact-sized above
        # same result contract as the 3-way engine path; cascade traffic =
        # both inputs + the intermediate written then re-read + T
        tuples = int(r.n) + int(s.n) + 2 * inter + int(t.n)
        return engine.EngineResult(np.int64(int(res.count)),
                                   jnp.asarray(False), np.int64(tuples), 1)


def forced_3way_plan(kind: str, shape_plan, *, m_budget: int | None = None,
                     use_kernel: bool = False, max_rounds: int = 3,
                     growth: float = 2.0, base_salt: int = 0) -> EnginePlan:
    """An EnginePlan that always runs the fused 3-way engine with the
    given shape plan — no time model (the cyclic query has no 2-join
    cascade; callers with an explicit shape plan skip the planner)."""
    return EnginePlan(kind=kind, strategy="3way", shape_plan=shape_plan,
                      choice=FORCED_3WAY_CHOICE, m_budget=m_budget,
                      use_kernel=use_kernel, max_rounds=max_rounds,
                      growth=growth, base_salt=base_salt)


def plan_query(kind: str, n_r: int, n_s: int, n_t: int, d: float, *,
               m_budget: int | None = None, hw: HW = PLASTICINE,
               use_kernel: bool = False, max_rounds: int = 3,
               growth: float = 2.0, base_salt: int = 0,
               **plan_kw) -> EnginePlan:
    """Size a shape plan from the paper's partitioning rules AND pick the
    3-way vs cascade strategy from the Appendix-A time model — returning an
    executable plan rather than a recommendation."""
    if kind in ("linear", "cyclic") and m_budget is None:
        raise ValueError(f"{kind} plans need m_budget (on-chip partition "
                         "size in tuples)")
    if kind == "linear":
        choice = choose_linear_timed(n_r, n_s, n_t, d, hw)
        shape = linear3.default_plan(n_r, n_s, n_t, m_budget=m_budget,
                                     **plan_kw)
    elif kind == "cyclic":
        # the cyclic (triangle) query has no 2-join cascade, so the
        # strategy is forced; no cyclic cycle model exists yet either
        choice = FORCED_3WAY_CHOICE
        shape = cyclic3.default_plan(n_r, n_s, n_t, m_budget=m_budget,
                                     **plan_kw)
    elif kind == "star":
        choice = choose_star_timed(n_r, n_s, n_t, d, hw)
        shape = star3.default_plan(n_r, n_s, n_t, **plan_kw)
    else:
        raise ValueError(f"unknown kind {kind!r}")
    return EnginePlan(kind=kind, strategy=choice.strategy, shape_plan=shape,
                      choice=choice, m_budget=m_budget,
                      use_kernel=use_kernel, max_rounds=max_rounds,
                      growth=growth, base_salt=base_salt)
