import jax, jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

def kern(x_ref, o_ref):
    # dynamic indexed store + fori_loop
    n = x_ref.shape[0]
    def body(i, acc):
        v = x_ref[i]
        return acc + v
    s = jax.lax.fori_loop(0, n, body, jnp.zeros((), x_ref.dtype))
    o_ref[0] = s
    # dynamic store
    idx = (x_ref[0].astype(jnp.int32)) % o_ref.shape[0]
    o_ref[idx] = s * 2

x = jnp.arange(16, dtype=jnp.float32)
out = pl.pallas_call(
    kern,
    out_shape=jax.ShapeDtypeStruct((8,), jnp.float32),
    interpret=True,
)(x)
print("pallas interpret ok:", out)

# grid + BlockSpec probe
def mm_kern(a_ref, b_ref, o_ref):
    o_ref[...] = jnp.dot(a_ref[...], b_ref[...], preferred_element_type=jnp.float32)

M, K, N = 256, 128, 256
a = jnp.ones((M, K), jnp.float32); b = jnp.ones((K, N), jnp.float32)
out = pl.pallas_call(
    mm_kern,
    grid=(2, 2),
    in_specs=[pl.BlockSpec((128, K), lambda i, j: (i, 0)),
              pl.BlockSpec((K, 128), lambda i, j: (0, j))],
    out_specs=pl.BlockSpec((128, 128), lambda i, j: (i, j)),
    out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
    interpret=True,
)(a, b)
print("blockspec ok:", np.allclose(out, K))
import jax.experimental.pallas.tpu as pltpu
print("pltpu import ok:", hasattr(pltpu, "VMEM") or hasattr(pltpu, "TPUMemorySpace") or dir(pltpu)[:10])
