"""Device-resident plan execution: sizing, staged pipeline, arena, per-R.

Covers the scan-free executor tentpole: the device-side sorted-key
histogram (``binary_join.exact_join_count``) matches the host np.unique
oracle — including join cardinalities past 2^31, where the two-limb
reduction must stay exact with x64 off; the staged stage/gather pipeline
reproduces ``join_materialize`` column-for-column; a warm ``execute_plan``
provably performs ZERO host ``np.unique`` calls; the refcounting buffer
arena stays correct when an intermediate feeds multiple consumers; and
N-way per-R group counts (the per_r-through-the-plan-IR satellite) match
the weight-backflow oracle from either end of a 4-chain.
"""

from collections import defaultdict

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from conftest import make_rel, skewed_keys
from repro.core import binary_join, plan_ir
from repro.core.query import Predicate, Query
from repro.core.relation import Relation
from repro.core.session import JoinSession


# --------------------------------------------------------------------------
# device sizing vs the np.unique oracle
# --------------------------------------------------------------------------

@settings(max_examples=12, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n_a=st.integers(1, 300),
       n_b=st.integers(1, 300), d=st.integers(1, 40), skew=st.booleans())
def test_exact_join_count_matches_host_oracle(seed, n_a, n_b, d, skew):
    rng = np.random.default_rng(seed)

    def keys(n):
        if skew:
            return skewed_keys(rng, n, d, 0.4)
        return rng.integers(0, d, n).astype(np.int32)

    a = Relation.from_arrays(capacity=n_a + 7, b=keys(n_a))
    b = Relation.from_arrays(capacity=n_b + 3, b=keys(n_b))
    got = binary_join.exact_join_count(a, "b", b, "b")
    assert got == binary_join.host_join_count(a, "b", b, "b")


def test_exact_join_count_empty_and_disjoint(rng):
    a = Relation.from_arrays(b=np.arange(10, dtype=np.int32))
    empty = a.mask_where(np.zeros(10, bool))
    assert binary_join.exact_join_count(a, "b", empty, "b") == 0
    assert binary_join.exact_join_count(empty, "b", a, "b") == 0
    c = Relation.from_arrays(b=np.arange(100, 110, dtype=np.int32))
    assert binary_join.exact_join_count(a, "b", c, "b") == 0


def test_exact_join_count_past_int32(rng):
    """50k x 50k rows on one key: 2.5e9 matches > 2^31 — the two-limb
    reduction must stay exact with x64 disabled framework-wide."""
    n = 50_000
    a = Relation.from_arrays(b=np.full(n, 7, np.int32))
    b = Relation.from_arrays(b=np.full(n, 7, np.int32))
    got = binary_join.exact_join_count(a, "b", b, "b")
    assert got == n * n
    assert got > 2**31
    # mixed load: the heavy key rides with ordinary ones
    extra = np.concatenate([np.full(n, 7, np.int32),
                            np.arange(1000, dtype=np.int32)])
    c = Relation.from_arrays(b=extra)
    assert (binary_join.exact_join_count(a, "b", c, "b")
            == binary_join.host_join_count(a, "b", c, "b"))


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n_a=st.integers(1, 200),
       n_b=st.integers(1, 200), d=st.integers(1, 25))
def test_staged_pipeline_matches_join_materialize(seed, n_a, n_b, d):
    """stage_join + gather_staged == join_materialize, column for column
    (same build-sorted slot order), at the executor's bucketed capacity."""
    rng = np.random.default_rng(seed)
    a, _ = make_rel(rng, n_a, ("a", "b"), d)
    b, _ = make_rel(rng, n_b, ("b", "c"), d)
    st_ = binary_join.stage_join(a, b, build_key="b", probe_key="b")
    total = binary_join.staged_total(st_)
    assert total == binary_join.host_join_count(a, "b", b, "b")
    cap = binary_join.bucket_capacity(total)
    assert cap >= total
    got = binary_join.gather_staged(st_, b, cap)
    want = binary_join.join_materialize(a, "b", b, "b", cap)
    assert not bool(want.overflowed)
    assert int(want.total) == total
    np.testing.assert_array_equal(np.asarray(got.valid),
                                  np.asarray(want.rel.valid))
    for name in got.columns:
        np.testing.assert_array_equal(np.asarray(got.col(name)),
                                      np.asarray(want.rel.col(name)))


def test_bucket_capacity_is_log_bucketed():
    assert binary_join.bucket_capacity(0) == 64
    assert binary_join.bucket_capacity(100) == binary_join.bucket_capacity(120)
    for total in (63, 1000, 5000, 123457):
        cap = binary_join.bucket_capacity(total)
        assert cap >= total + 8 and cap <= 4 * max(total, 32)
        assert cap & (cap - 1) == 0          # power of two


# --------------------------------------------------------------------------
# regression: a warm execute_plan never touches host np.unique
# --------------------------------------------------------------------------

def test_warm_execute_plan_is_scan_free(rng, monkeypatch):
    """Acceptance: after one warm-up execution, neither re-planning (FM
    sketches) nor re-execution (staged device pipeline) may call host
    np.unique — the count is monkeypatch-enforced at zero."""
    rels = [make_rel(rng, 800, (c1, c2), 80)[0]
            for c1, c2 in (("a", "b"), ("b", "c"), ("c", "d"), ("d", "e"))]
    names = [f"r{i}" for i in range(1, 5)]
    q = Query(dict(zip(names, rels)),
              [("r1.b", "r2.b"), ("r2.c", "r3.c"), ("r3.d", "r4.d")])
    sess = JoinSession(m_budget=128)
    cold = sess.execute(q)                     # compile + plan warm-up
    calls = {"n": 0}
    real_unique = np.unique

    def counting_unique(*args, **kw):
        calls["n"] += 1
        return real_unique(*args, **kw)

    monkeypatch.setattr(np, "unique", counting_unique)
    warm = sess.execute(q)
    assert warm.cache_hit
    assert int(warm.count) == int(cold.count)
    assert calls["n"] == 0, (
        f"warm execute_plan made {calls['n']} host np.unique calls")


# --------------------------------------------------------------------------
# buffer arena: multi-consumer intermediates and profile mode
# --------------------------------------------------------------------------

def _oracle_pairs(keys_a, keys_b) -> int:
    cnt = defaultdict(int)
    for v in keys_b:
        cnt[int(v)] += 1
    return sum(cnt.get(int(v), 0) for v in keys_a)


def test_arena_keeps_multi_consumer_intermediate(rng):
    """A hand-built DAG where %i0 feeds BOTH %i1 and the root: the
    refcounting arena must keep %i0 alive until its second consumer has
    captured it (and the count must match brute force)."""
    r, rd = make_rel(rng, 60, ("a", "b"), 8)
    s, sd = make_rel(rng, 70, ("b", "c"), 8)
    t, td = make_rel(rng, 50, ("c", "d"), 8)
    steps = (
        plan_ir.PlanStep(
            op="binary", out="%i0", inputs=("r", "s"),
            preds=(Predicate(("r", "r.b"), ("s", "s.b")),),
            aggregate=False,
            project=((("b", "r.b"), ("a", "r.a")),
                     (("b", "s.b"), ("c", "s.c")))),
        plan_ir.PlanStep(
            op="binary", out="%i1", inputs=("%i0", "t"),
            preds=(Predicate(("%i0", "s.c"), ("t", "t.c")),),
            aggregate=False,
            project=((("s.c", "s.c"), ("r.a", "r.a")),
                     (("c", "t.c"), ("d", "t.d")))),
        plan_ir.PlanStep(
            op="binary", out=plan_ir.COUNT, inputs=("%i1", "%i0"),
            preds=(Predicate(("%i1", "r.a"), ("%i0", "r.a")),),
            aggregate=True),
    )
    qp = plan_ir.QueryPlan(steps=steps, n_relations=3, kind="binary",
                           strategy="cascade")
    res = plan_ir.execute_plan(qp, {"r": r, "s": s, "t": t})
    # oracle: i0 = r x s on b; i1 = i0 x t on c; root = |i1 x i0 on r.a|
    i0 = [(int(a), int(c)) for a, b in zip(rd["a"], rd["b"])
          for b2, c in zip(sd["b"], sd["c"]) if int(b) == int(b2)]
    i1_a = [a for a, c in i0 for c2 in td["c"].tolist() if c == int(c2)]
    want = _oracle_pairs(i1_a, [a for a, _ in i0])
    assert int(res.count) == want
    assert not res.overflowed


def test_profile_mode_fills_wall_and_matches_default(rng):
    """profile=True serializes the overlap for attribution: counts stay
    identical, wall_s is populated per step, dispatch_s is recorded."""
    rels = [make_rel(rng, 500, (c1, c2), 50)[0]
            for c1, c2 in (("a", "b"), ("b", "c"), ("c", "d"), ("d", "e"))]
    names = [f"r{i}" for i in range(1, 5)]
    q = Query(dict(zip(names, rels)),
              [("r1.b", "r2.b"), ("r2.c", "r3.c"), ("r3.d", "r4.d")])
    sess = JoinSession(m_budget=128)
    qp = sess.execute(q, strategy="cascade").plan
    fast = plan_ir.execute_plan(qp, dict(q.relations))
    prof = plan_ir.execute_plan(qp, dict(q.relations), profile=True)
    assert int(prof.count) == int(fast.count)
    assert all(s.wall_s > 0.0 for s in prof.step_stats)
    assert all(s.wall_s == 0.0 for s in fast.step_stats
               if s.op == "binary")
    assert all(s.dispatch_s >= 0.0 for s in prof.step_stats)
    # stats keep their aggregation contract under the new fields
    assert sum(s.tuples_read for s in prof.step_stats) == prof.tuples_read


# --------------------------------------------------------------------------
# per-R through the plan IR (N-way satellite + 3-rel compatibility)
# --------------------------------------------------------------------------

def _chain4(rng, n=300, d=25):
    cols = [("a", "b"), ("b", "c"), ("c", "d"), ("d", "e")]
    rels, raw = [], []
    for c1, c2 in cols:
        rel, rd = make_rel(rng, n, (c1, c2), d)
        rels.append(rel)
        raw.append(rd)
    names = [f"r{i}" for i in range(1, 5)]
    q = Query(dict(zip(names, rels)),
              [("r1.b", "r2.b"), ("r2.c", "r3.c"), ("r3.d", "r4.d")])
    return q, raw


def _backflow_weights(raw, join_cols):
    """Per-row join-output counts of the FIRST relation of a chain.
    ``join_cols``: the shared column of each edge, front to back."""
    w = np.ones(len(next(iter(raw[-1].values()))), np.int64)
    for i in range(len(raw) - 1, 0, -1):
        key = join_cols[i - 1]
        cnt = defaultdict(int)
        for k, wv in zip(raw[i][key].tolist(), w.tolist()):
            cnt[k] += wv
        w = np.array([cnt.get(k, 0) for k in raw[i - 1][key].tolist()],
                     np.int64)
    return w


def _group(keys, weights):
    out = defaultdict(int)
    for k, w in zip(keys, weights):
        if w:
            out[int(k)] += int(w)
    return dict(out)


def test_nway_per_r_matches_backflow_oracle(rng):
    """per_r on a 4-chain, pinned at BOTH leaves: group-by of the per-R
    (keys, counts) equals the weight-backflow oracle, and COUNT equals the
    full join cardinality."""
    q, raw = _chain4(rng)
    sess = JoinSession(m_budget=128)

    res = sess.execute(q, per_r="r1", key_col="a")
    assert not res.overflowed
    assert res.plan.root.per_r_key == "a"
    assert dict(res.plan.root.roles)["r"] == "r1"
    w1 = _backflow_weights(raw, ["b", "c", "d"])
    got = _group(np.asarray(res.per_r.keys)[np.asarray(res.per_r.valid)],
                 np.asarray(res.per_r.counts)[np.asarray(res.per_r.valid)])
    assert got == _group(raw[0]["a"], w1)
    assert int(res.count) == int(w1.sum())

    # pin the other leaf: the planner must keep its edge uncontracted and
    # swap it into role r
    res4 = sess.execute(q, per_r="r4", key_col="e")
    assert dict(res4.plan.root.roles)["r"] == "r4"
    w4 = _backflow_weights(raw[::-1], ["d", "c", "b"])
    got4 = _group(
        np.asarray(res4.per_r.keys)[np.asarray(res4.per_r.valid)],
        np.asarray(res4.per_r.counts)[np.asarray(res4.per_r.valid)])
    assert got4 == _group(raw[3]["e"], w4)
    assert int(res4.count) == int(w1.sum())

    # per_r=True defaults to the first-declared leaf (r1)
    res_def = sess.execute(q, per_r=True, key_col="a")
    assert dict(res_def.plan.root.roles)["r"] == "r1"
    assert _group(
        np.asarray(res_def.per_r.keys)[np.asarray(res_def.per_r.valid)],
        np.asarray(res_def.per_r.counts)[np.asarray(res_def.per_r.valid)]
    ) == got


def test_per_r_pin_validation(rng):
    q, _ = _chain4(rng, n=80, d=10)
    sess = JoinSession(m_budget=64)
    with pytest.raises(ValueError, match="leaf"):
        sess.execute(q, per_r="r2", key_col="b")   # interior relation
    with pytest.raises(ValueError, match="key column"):
        sess.execute(q, per_r="r1", key_col="zz")
    with pytest.raises(ValueError, match="not one of"):
        sess.execute(q, per_r="zzz")
    with pytest.raises(ValueError, match="cascade"):
        sess.execute(q, per_r=True, strategy="cascade")
    r, _ = make_rel(rng, 50, ("a", "b"), 8)
    s, _ = make_rel(rng, 50, ("b", "c"), 8)
    q2 = Query({"r": r, "s": s}, [("r.b", "s.b")])
    with pytest.raises(ValueError, match="3-way root"):
        sess.execute(q2, per_r=True)


def test_per_r_centre_pin_rejected(rng):
    r, _ = make_rel(rng, 60, ("a", "b"), 10)
    s, _ = make_rel(rng, 60, ("b", "c"), 10)
    t, _ = make_rel(rng, 60, ("c", "d"), 10)
    q = Query({"r": r, "s": s, "t": t}, [("r.b", "s.b"), ("s.c", "t.c")])
    with pytest.raises(ValueError, match="centre"):
        JoinSession(m_budget=64).execute(q, per_r="s", key_col="b")


def test_3rel_per_r_t_endpoint_swaps_roles(rng):
    """Pinning the t-side endpoint of a 3-relation path swaps the linear
    roles (the path is symmetric) and still matches the oracle."""
    r, rd = make_rel(rng, 120, ("a", "b"), 20)
    s, sd = make_rel(rng, 130, ("b", "c"), 20)
    t, td = make_rel(rng, 110, ("c", "d"), 20)
    q = Query({"r": r, "s": s, "t": t}, [("r.b", "s.b"), ("s.c", "t.c")])
    res = JoinSession(m_budget=64).execute(q, per_r="t", key_col="d")
    assert dict(res.plan.root.roles)["r"] == "t"
    raw = [td, sd, rd]
    w = _backflow_weights(raw, ["c", "b"])
    got = _group(np.asarray(res.per_r.keys)[np.asarray(res.per_r.valid)],
                 np.asarray(res.per_r.counts)[np.asarray(res.per_r.valid)])
    assert got == _group(td["d"], w)
