"""Distributed multiway joins on the device mesh (shard_map).

The paper's on-chip network routing maps 1:1 onto mesh collectives:

  Plasticine                          TPU mesh ("row" × "col")
  ---------------------------------   --------------------------------------
  route r(a,b) → PMU[h(a), g(b)]      two-phase all_to_all (rows, then cols)
  broadcast s(b,c) down column g(b)   all_to_all to column + all_gather rows
  broadcast t(c,a) across row h(a)    all_to_all to row + all_gather cols
  per-PMU bucket join                 per-device core join (Pallas kernels)
  merge partial aggregates            psum (counts) / OR-reduce (FM sketches)

Relations enter sharded in arrival order over all devices (the "DRAM-
resident, evenly striped" state); the shuffle phases above are the
partitioning the paper configures the accelerator to perform first (§4).

Everything is static-shape: the shuffles use fixed-capacity per-destination
send buffers, and overflow is psum-reduced and reported, never hidden.

Cross-device skew recovery
--------------------------
``engine_count_sharded`` extends the fused one-shot joins with the same
round contract as ``core.recovery``, lifted to the mesh: each round is ONE
shard_map launch whose devices join their shard with a salted local plan and
``lax.psum``-merge the partial counts of overflow-free devices (the "kept
exact partials"); the per-device overflow bitmap comes back as a
``P(row, col)`` output.  The host masks the driving relation's rows down to
the overflowed devices (their mesh position is a pure function of the join
keys — no data movement) and re-runs only those across the whole mesh with
grown capacities and a fresh salt.  The final round sizes every shuffle
buffer to accept-all and every local bucket from its exact host-side
histogram, so it cannot overflow: ``overflowed == False`` is a guarantee,
not a flag.

The same functions compile on the 2-pod production mesh: the "pod" axis is
folded into "row" (joins scale out along rows; the extra hop is the paper's
multi-chip case, and the collective-term roofline in EXPERIMENTS.md
quantifies it).

Declarative entry: ``session.JoinSession.execute_sharded(query, mesh, row,
col)`` classifies the query's predicate graph, re-keys the relations to the
canonical routing columns via the binding, and dispatches here — the
``kind=`` string below is the internal dispatch key, not user API.
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import compat
from repro.core import cyclic3, engine, hashing, linear3, partition, star3
from repro.core.recovery import exact_cap
from repro.core.relation import Relation


class DistJoinResult(NamedTuple):
    count: jnp.ndarray       # () int32, global
    overflowed: jnp.ndarray  # () bool, any shuffle/bucket overflow anywhere


class DistEngineResult(NamedTuple):
    count: np.int64          # exact global count (int64)
    overflowed: jnp.ndarray  # () bool — False by construction
    rounds: int              # shard_map rounds executed (1 = no skew)


# --------------------------------------------------------------------------
# shuffle primitives (inside shard_map)
# --------------------------------------------------------------------------

def _to_buckets(cols: dict, valid: jnp.ndarray, dest: jnp.ndarray,
                n_dest: int, cap: int):
    """Pack local rows into [n_dest, cap] send buffers (+ overflow flag)."""
    rel = Relation(cols, valid)
    ids = jnp.where(valid, dest, jnp.int32(n_dest))
    b = partition.bucketize_by_ids(rel, ids, n_dest, cap, (n_dest,))
    return b.columns, b.valid, b.overflowed


def _all_to_all(cols: dict, valid: jnp.ndarray, axis: str):
    """Exchange [n_dest, cap] buffers along a mesh axis → received rows,
    flattened back to a local [n_src * cap] relation."""
    def xc(x):
        out = jax.lax.all_to_all(x, axis, split_axis=0, concat_axis=0,
                                 tiled=True)
        return out.reshape((-1,))
    return {k: xc(v) for k, v in cols.items()}, xc(valid)


def _shuffle(cols: dict, valid: jnp.ndarray, key_col: str, axis: str,
             n_dest: int, cap: int, fn: str):
    """Route rows to the device at position hash(key) along `axis`."""
    dest = hashing.hash_bucket(cols[key_col], n_dest, fn)
    bcols, bvalid, ovf = _to_buckets(cols, valid, dest, n_dest, cap)
    cols2, valid2 = _all_to_all(bcols, bvalid, axis)
    return cols2, valid2, ovf


def _replicate(cols: dict, valid: jnp.ndarray, axis: str):
    """all_gather along `axis` (the paper's broadcast) → concatenated rows."""
    def g(x):
        return jax.lax.all_gather(x, axis, axis=0, tiled=True)
    return {k: g(v) for k, v in cols.items()}, g(valid)


def _or_all(x: jnp.ndarray, axes) -> jnp.ndarray:
    """Global bitwise-OR via all_gather + local reduce (for FM bitmaps)."""
    for ax in axes:
        g = jax.lax.all_gather(x, ax, axis=0)
        x = jax.lax.reduce(g, jnp.int32(0), jax.lax.bitwise_or, (0,))
    return x


def _psum_bool(x: jnp.ndarray, axes) -> jnp.ndarray:
    return jax.lax.psum(x.astype(jnp.int32), axes) > 0


def _scaled(cap: int, scale: float, align: int = 8) -> int:
    if scale == 1.0:
        return cap
    return max(align, int(math.ceil(cap * scale / align)) * align)


# --------------------------------------------------------------------------
# per-kind local cores: shuffles + local fused/scan join on one device.
# Each returns (local count, local join overflow, shuffle overflow) so both
# the legacy one-shot wrappers and the recovery rounds can share them.
# --------------------------------------------------------------------------

def _cyclic_local_core(nrow, ncol, row, col, *, shuffle_slack=3.0,
                       local_uh=4, local_ug=4, local_f=2, local_slack=3.0,
                       use_kernel=False, fused=False, salt=0, cap_scale=1.0,
                       shuffle_caps=None, local_caps=None, pair_index=True):
    """R(a,b), S(b,c), T(c,a) arrive sharded in arrival order; device (i, j)
    ends up owning R tuples with (H(a), G(b)) == (i, j), the full S_j column
    partition and the full T_i row partition — exactly Fig 3."""
    sc = shuffle_caps or {}

    def local(r_cols, r_valid, s_cols, s_valid, t_cols, t_valid):
        # --- R → cell (H(a), G(b)): two-phase all_to_all ----------------
        cap_r = sc.get("r1") or partition.suggest_capacity(
            r_valid.shape[0], nrow, shuffle_slack)
        r1, rv1, ovf_r1 = _shuffle(r_cols, r_valid, "a", row, nrow, cap_r, "H")
        cap_r2 = sc.get("r2") or partition.suggest_capacity(
            rv1.shape[0], ncol, shuffle_slack)
        r2, rv2, ovf_r2 = _shuffle(r1, rv1, "b", col, ncol, cap_r2, "G")

        # --- S → column G(b), replicated down the column ----------------
        cap_s = sc.get("s1") or partition.suggest_capacity(
            s_valid.shape[0], ncol, shuffle_slack)
        s1, sv1, ovf_s = _shuffle(s_cols, s_valid, "b", col, ncol, cap_s, "G")
        s2, sv2 = _replicate(s1, sv1, row)

        # --- T → row H(a), replicated across the row --------------------
        cap_t = sc.get("t1") or partition.suggest_capacity(
            t_valid.shape[0], nrow, shuffle_slack)
        t1, tv1, ovf_t = _shuffle(t_cols, t_valid, "a", row, nrow, cap_t, "H")
        t2, tv2 = _replicate(t1, tv1, col)

        # --- local grid join (coarse level done; fine level = VMEM) -----
        rl = Relation(r2, rv2)
        sl = Relation(s2, sv2)
        tl = Relation(t2, tv2)
        caps = local_caps or (
            _scaled(partition.suggest_capacity(
                rl.capacity, local_uh * local_ug, local_slack), cap_scale),
            _scaled(partition.suggest_capacity(
                sl.capacity, local_f * local_ug, local_slack), cap_scale),
            _scaled(partition.suggest_capacity(
                tl.capacity, local_f * local_uh, local_slack), cap_scale))
        plan = cyclic3.Cyclic3Plan(
            h_parts=1, g_parts=1, uh=local_uh, ug=local_ug, f_parts=local_f,
            r_cap=caps[0], s_cap=caps[1], t_cap=caps[2])
        if fused:
            res = engine.cyclic3_count_fused(rl, sl, tl, plan,
                                             use_kernel=use_kernel,
                                             salt=salt,
                                             pair_index=pair_index)
        else:
            res = cyclic3.cyclic3_count(rl, sl, tl, plan,
                                        use_kernel=use_kernel)
        return res.count, res.overflowed, ovf_r1 | ovf_r2 | ovf_s | ovf_t

    return local


def _linear_local_core(nrow, ncol, row, col, *, shuffle_slack=3.0,
                       local_u=8, local_g=4, local_slack=3.0,
                       use_kernel=False, fused=False, salt=0, cap_scale=1.0,
                       shuffle_caps=None, local_caps=None):
    """Distributed Algorithm 1: the whole mesh is the flat U-way PMU grid.
    R and S shuffle to device h(B) (two-phase: row then col hash of B);
    T is broadcast to every device."""
    sc = shuffle_caps or {}

    def local(r_cols, r_valid, s_cols, s_valid, t_cols, t_valid):
        cap_r = sc.get("r1") or partition.suggest_capacity(
            r_valid.shape[0], nrow, shuffle_slack)
        r1, rv1, ovf_r1 = _shuffle(r_cols, r_valid, "b", row, nrow, cap_r, "H")
        cap_r2 = sc.get("r2") or partition.suggest_capacity(
            rv1.shape[0], ncol, shuffle_slack)
        r2, rv2, ovf_r2 = _shuffle(r1, rv1, "b", col, ncol, cap_r2, "G")

        cap_s = sc.get("s1") or partition.suggest_capacity(
            s_valid.shape[0], nrow, shuffle_slack)
        s1, sv1, ovf_s1 = _shuffle(s_cols, s_valid, "b", row, nrow, cap_s, "H")
        cap_s2 = sc.get("s2") or partition.suggest_capacity(
            sv1.shape[0], ncol, shuffle_slack)
        s2, sv2, ovf_s2 = _shuffle(s1, sv1, "b", col, ncol, cap_s2, "G")

        # T broadcast to all devices (streamed bucket-by-bucket locally)
        t1, tv1 = _replicate(t_cols, t_valid, row)
        t2, tv2 = _replicate(t1, tv1, col)

        rl = Relation(r2, rv2)
        sl = Relation(s2, sv2)
        tl = Relation(t2, tv2)
        caps = local_caps or (
            _scaled(partition.suggest_capacity(
                rl.capacity, local_u, local_slack), cap_scale),
            _scaled(partition.suggest_capacity(
                sl.capacity, local_g * local_u, local_slack), cap_scale),
            _scaled(partition.suggest_capacity(
                tl.capacity, local_g, local_slack), cap_scale))
        plan = linear3.Linear3Plan(h_parts=1, u=local_u, g_parts=local_g,
                                   r_cap=caps[0], s_cap=caps[1],
                                   t_cap=caps[2])
        if fused:
            res = engine.linear3_count_fused(rl, sl, tl, plan,
                                             use_kernel=use_kernel, salt=salt)
        else:
            res = linear3.linear3_count(rl, sl, tl, plan,
                                        use_kernel=use_kernel)
        return res.count, res.overflowed, ovf_r1 | ovf_r2 | ovf_s1 | ovf_s2

    return local


def _star_local_core(nrow, ncol, row, col, *, shuffle_slack=3.0,
                     local_chunks=1, local_slack=3.0, use_kernel=False,
                     fused=False, salt=0, cap_scale=1.0, shuffle_caps=None,
                     local_caps=None, local_uh=4, local_ug=4):
    """Distributed star join: R pinned by h(B) on rows (replicated along
    cols), T pinned by g(C) on cols (replicated along rows); each fact tuple
    s(b,c) is routed to exactly the one device (h(b), g(c))."""
    sc = shuffle_caps or {}

    def local(r_cols, r_valid, s_cols, s_valid, t_cols, t_valid):
        # routing uses the coarse H/G families, NOT the local layout's
        # h/g: with a shared family (and salt 0 in round 0) device-local
        # buckets would be modulo-correlated with device placement,
        # leaving most local buckets empty and the loaded ones ~uh x over
        cap_r = sc.get("r1") or partition.suggest_capacity(
            r_valid.shape[0], nrow, shuffle_slack)
        r1, rv1, ovf_r = _shuffle(r_cols, r_valid, "b", row, nrow, cap_r, "H")
        r2, rv2 = _replicate(r1, rv1, col)

        cap_t = sc.get("t1") or partition.suggest_capacity(
            t_valid.shape[0], ncol, shuffle_slack)
        t1, tv1, ovf_t = _shuffle(t_cols, t_valid, "c", col, ncol, cap_t, "G")
        t2, tv2 = _replicate(t1, tv1, row)

        # fact: two-phase point routing (H(b) row, then G(c) col)
        cap_s = sc.get("s1") or partition.suggest_capacity(
            s_valid.shape[0], nrow, shuffle_slack)
        s1, sv1, ovf_s1 = _shuffle(s_cols, s_valid, "b", row, nrow, cap_s, "H")
        cap_s2 = sc.get("s2") or partition.suggest_capacity(
            sv1.shape[0], ncol, shuffle_slack)
        s2, sv2, ovf_s2 = _shuffle(s1, sv1, "c", col, ncol, cap_s2, "G")

        rl = Relation(r2, rv2)
        sl = Relation(s2, sv2)
        tl = Relation(t2, tv2)
        caps = local_caps or (
            _scaled(partition.suggest_capacity(
                rl.capacity, local_uh, local_slack), cap_scale),
            _scaled(partition.suggest_capacity(
                sl.capacity, local_chunks * local_uh * local_ug,
                local_slack), cap_scale),
            _scaled(partition.suggest_capacity(
                tl.capacity, local_ug, local_slack), cap_scale))
        plan = star3.Star3Plan(uh=local_uh, ug=local_ug, chunks=local_chunks,
                               r_cap=caps[0], s_cap=caps[1], t_cap=caps[2])
        if fused:
            res = engine.star3_count_fused(rl, sl, tl, plan,
                                           use_kernel=use_kernel, salt=salt)
        else:
            res = star3.star3_count(rl, sl, tl, plan, use_kernel=use_kernel)
        return res.count, res.overflowed, ovf_r | ovf_t | ovf_s1 | ovf_s2

    return local


_CORES = {"linear": _linear_local_core, "cyclic": _cyclic_local_core,
          "star": _star_local_core}


# --------------------------------------------------------------------------
# one-shot wrappers (legacy API: count + a single overflow flag)
# --------------------------------------------------------------------------

def _count_sharded(mesh: Mesh, row: str, col: str, local):
    spec = P((row, col))

    def local_fn(rc, rv, scols, sv, tcols, tv):
        count, loc_ovf, sh_ovf = local(rc, rv, scols, sv, tcols, tv)
        return (jax.lax.psum(count, (row, col)),
                _psum_bool(loc_ovf | sh_ovf, (row, col)))

    def fn(r: Relation, s: Relation, t: Relation) -> DistJoinResult:
        sm = compat.shard_map(local_fn, mesh=mesh, in_specs=(spec,) * 6,
                              out_specs=(P(), P()))
        count, ovf = sm(dict(r.columns), r.valid, dict(s.columns), s.valid,
                        dict(t.columns), t.valid)
        return DistJoinResult(count, ovf)

    return fn


def cyclic3_count_sharded(mesh: Mesh, row: str, col: str, **kw):
    """Build a jit-able distributed triangle-count:  f(R, S, T) -> result
    (the paper's grid algorithm, §5.1, on the mesh)."""
    local = _cyclic_local_core(mesh.shape[row], mesh.shape[col], row, col,
                               **kw)
    return _count_sharded(mesh, row, col, local)


def linear3_count_sharded(mesh: Mesh, row: str, col: str, **kw):
    """Distributed Algorithm 1 (§4); the |R||T|/M term of the cost model
    becomes the T all-gather bytes.  Call once per coarse H(B) partition
    when R exceeds aggregate device memory."""
    local = _linear_local_core(mesh.shape[row], mesh.shape[col], row, col,
                               **kw)
    return _count_sharded(mesh, row, col, local)


def star3_count_sharded(mesh: Mesh, row: str, col: str, **kw):
    """Distributed star join (§6.5): S crosses the network once, R and T are
    the only replicated (small) relations."""
    local = _star_local_core(mesh.shape[row], mesh.shape[col], row, col,
                             **kw)
    return _count_sharded(mesh, row, col, local)


# --------------------------------------------------------------------------
# cross-device skew recovery (engine entry point)
# --------------------------------------------------------------------------

def _round_sharded(mesh: Mesh, row: str, col: str, local):
    """One recovery round as ONE shard_map: psum-merged exact partials from
    overflow-free devices, the per-device overflow bitmap, and the global
    shuffle-overflow flag.

    The merge is exact past int32: each device's kept partial (which must
    fit int32 — the same per-partial contract as the fused kernels' cells)
    is split into two 16-bit limbs that are psum'd separately and
    recombined host-side in int64, so the GLOBAL round total may exceed
    2^31 without wrapping.
    """
    spec = P((row, col))

    def local_fn(rc, rv, scols, sv, tcols, tv):
        count, loc_ovf, sh_ovf = local(rc, rv, scols, sv, tcols, tv)
        kept = jnp.where(loc_ovf, 0, count)                # int32 per device
        lo = jax.lax.psum(kept & 0xFFFF, (row, col))
        hi = jax.lax.psum(kept >> 16, (row, col))
        return (lo, hi, loc_ovf.reshape(1, 1),
                _psum_bool(sh_ovf, (row, col)))

    sm = jax.jit(compat.shard_map(local_fn, mesh=mesh, in_specs=(spec,) * 6,
                                  out_specs=(P(), P(), P(row, col), P())))

    def fn(r: Relation, s: Relation, t: Relation):
        lo, hi, bad, sh = sm(dict(r.columns), r.valid, dict(s.columns),
                             s.valid, dict(t.columns), t.valid)
        kept64 = (np.int64(int(hi)) << 16) + np.int64(int(lo))
        return kept64, bad, sh

    return fn


def _np_bucket(col, nb: int, fn: str, salt: int = 0) -> np.ndarray:
    return np.asarray(hashing.hash_bucket(jnp.asarray(col), nb, fn, salt))


def _device_of(kind: str, rel_key: str, rel: Relation, nrow: int,
               ncol: int) -> tuple[np.ndarray, np.ndarray]:
    """Mesh position (i, j) per row — the pure-function image of the
    (unsalted) shuffle destinations.  Used for residual masks and exact
    final-round capacity histograms; never moves data."""
    if kind == "linear":                      # r/s by H,G of b; t replicated
        b = rel.col("b")
        return _np_bucket(b, nrow, "H"), _np_bucket(b, ncol, "G")
    if kind == "cyclic":
        if rel_key == "r":
            return (_np_bucket(rel.col("a"), nrow, "H"),
                    _np_bucket(rel.col("b"), ncol, "G"))
        if rel_key == "s":                    # column-replicated
            return None, _np_bucket(rel.col("b"), ncol, "G")
        return _np_bucket(rel.col("a"), nrow, "H"), None
    # star
    if rel_key == "r":                        # row-pinned, col-replicated
        return _np_bucket(rel.col("b"), nrow, "H"), None
    if rel_key == "t":
        return None, _np_bucket(rel.col("c"), ncol, "G")
    return (_np_bucket(rel.col("b"), nrow, "H"),
            _np_bucket(rel.col("c"), ncol, "G"))


_DRIVING = {"linear": ("r", "s"), "cyclic": ("r",), "star": ("s",)}


def _mask_residual(kind: str, rels: dict, bad: np.ndarray, nrow: int,
                   ncol: int) -> dict:
    """Keep only the driving relation's rows that live on overflowed
    devices; their device is a hash of their keys, so no shuffle needed."""
    out = dict(rels)
    for key in _DRIVING[kind]:
        i, j = _device_of(kind, key, rels[key], nrow, ncol)
        keep = bad[i if i is not None else 0, j if j is not None else 0]
        out[key] = rels[key].mask_where(jnp.asarray(keep))
    return out


def _acceptall_shuffle_caps(kind: str, rels: dict, nrow: int,
                            ncol: int) -> dict:
    """Send-buffer capacities that can absorb ANY routing (every destination
    bucket can hold the whole local shard) — shuffle overflow impossible."""
    ndev = nrow * ncol
    lr = rels["r"].capacity // ndev
    ls = rels["s"].capacity // ndev
    lt = rels["t"].capacity // ndev
    if kind == "linear":
        return {"r1": lr, "r2": nrow * lr, "s1": ls, "s2": nrow * ls}
    if kind == "cyclic":
        return {"r1": lr, "r2": nrow * lr, "s1": ls, "t1": lt}
    return {"r1": lr, "t1": lt, "s1": ls, "s2": nrow * ls}


def _exact_local_caps(kind: str, rels: dict, salt: int, nrow: int, ncol: int,
                      dims: dict) -> tuple[int, int, int]:
    """Exact per-bucket capacities for the final round: the (device, local
    bucket) of a row is a pure function of its keys, so the true maximum
    bucket load is one host-side histogram per relation."""
    def hist_max(rel, flat, n):
        v = np.asarray(rel.valid)
        h = np.bincount(flat[v], minlength=n) if v.any() else np.zeros(1, int)
        return exact_cap(h)

    r, s, t = rels["r"], rels["s"], rels["t"]
    if kind == "linear":
        u, g = dims["local_u"], dims["local_g"]
        ri, rj = _device_of(kind, "r", r, nrow, ncol)
        r_flat = (ri * ncol + rj) * u + _np_bucket(r.col("b"), u, "h", salt)
        si, sj = _device_of(kind, "s", s, nrow, ncol)
        s_flat = ((si * ncol + sj) * g
                  + _np_bucket(s.col("c"), g, "g", salt)) * u \
            + _np_bucket(s.col("b"), u, "h", salt)
        t_flat = _np_bucket(t.col("c"), g, "g", salt)      # replicated
        return (hist_max(r, r_flat, nrow * ncol * u),
                hist_max(s, s_flat, nrow * ncol * g * u),
                hist_max(t, t_flat, g))
    if kind == "cyclic":
        uh, ug, fp = dims["local_uh"], dims["local_ug"], dims["local_f"]
        ri, rj = _device_of(kind, "r", r, nrow, ncol)
        r_flat = ((ri * ncol + rj) * uh
                  + _np_bucket(r.col("a"), uh, "h", salt)) * ug \
            + _np_bucket(r.col("b"), ug, "g", salt)
        _, sj = _device_of(kind, "s", s, nrow, ncol)
        s_flat = (sj * fp + _np_bucket(s.col("c"), fp, "f", salt)) * ug \
            + _np_bucket(s.col("b"), ug, "g", salt)
        ti, _ = _device_of(kind, "t", t, nrow, ncol)
        t_flat = (ti * fp + _np_bucket(t.col("c"), fp, "f", salt)) * uh \
            + _np_bucket(t.col("a"), uh, "h", salt)
        return (hist_max(r, r_flat, nrow * ncol * uh * ug),
                hist_max(s, s_flat, ncol * fp * ug),
                hist_max(t, t_flat, nrow * fp * uh))
    # star (chunks forced to 1 in the final round: arrival-order chunk ids
    # are layout-dependent, the hashed (h, g) cell is not)
    uh, ug = dims["local_uh"], dims["local_ug"]
    ri, _ = _device_of(kind, "r", r, nrow, ncol)
    r_flat = ri * uh + _np_bucket(r.col("b"), uh, "h", salt)
    _, tj = _device_of(kind, "t", t, nrow, ncol)
    t_flat = tj * ug + _np_bucket(t.col("c"), ug, "g", salt)
    si, sj = _device_of(kind, "s", s, nrow, ncol)
    s_flat = ((si * ncol + sj) * uh
              + _np_bucket(s.col("b"), uh, "h", salt)) * ug \
        + _np_bucket(s.col("c"), ug, "g", salt)
    return (hist_max(r, r_flat, nrow * uh),
            hist_max(s, s_flat, nrow * ncol * uh * ug),
            hist_max(t, t_flat, ncol * ug))


def engine_count_sharded(mesh: Mesh, row: str, col: str,
                         kind: str = "linear", *, max_rounds: int = 2,
                         growth: float = 2.0, use_kernel: bool = False,
                         shuffle_slack: float = 3.0, **kw):
    """Distributed fused-engine join WITH cross-device skew recovery.

    Returns a host-driven callable ``fn(r, s, t) -> DistEngineResult`` (each
    round re-traces a shard_map with new static capacities, so the whole
    thing is not itself jit-able).  Per round: one shard_map launch joins
    every shard with a salted local plan, psum-merges the exact partials of
    overflow-free devices, and reports the per-device overflow bitmap; the
    host re-runs only the rows owned by overflowed devices.  The final round
    is exact-sized (accept-all shuffles + histogram-true bucket capacities),
    so ``overflowed`` is always False and the count is exact under ANY skew.
    """
    if kind not in _CORES:
        raise ValueError(f"unknown kind {kind!r}; choose from "
                         f"{sorted(_CORES)}")
    nrow, ncol = mesh.shape[row], mesh.shape[col]
    core = _CORES[kind]
    dims = {"linear": {"local_u": 8, "local_g": 4},
            "cyclic": {"local_uh": 4, "local_ug": 4, "local_f": 2},
            "star": {"local_uh": 4, "local_ug": 4}}[kind]
    dims.update({k: v for k, v in kw.items() if k in dims})

    def fn(r: Relation, s: Relation, t: Relation) -> DistEngineResult:
        rels = {"r": r, "s": s, "t": t}
        total, rounds = 0, 0
        sh_scale, cap_scale = 1.0, 1.0
        for rnd in range(max_rounds + 1):
            final = rnd == max_rounds
            opts = dict(kw)
            if final:
                opts["shuffle_caps"] = _acceptall_shuffle_caps(
                    kind, rels, nrow, ncol)
                opts["local_caps"] = _exact_local_caps(
                    kind, rels, rnd, nrow, ncol, dims)
                if kind == "star":
                    opts["local_chunks"] = 1
            local = core(nrow, ncol, row, col, fused=True,
                         use_kernel=use_kernel, salt=rnd,
                         cap_scale=cap_scale,
                         shuffle_slack=shuffle_slack * sh_scale, **opts)
            kept, bad, sh_any = _round_sharded(mesh, row, col, local)(
                rels["r"], rels["s"], rels["t"])
            rounds += 1
            if bool(sh_any):
                # send buffers dropped rows: the round's partials are not
                # trustworthy anywhere — discard and retry with roomier
                # shuffles (the final round's accept-all caps cannot hit
                # this branch)
                assert not final, "accept-all shuffle caps overflowed"
                sh_scale *= growth
                cap_scale *= growth
                continue
            total += int(kept)
            bad_np = np.asarray(bad)
            if not bad_np.any():
                return DistEngineResult(np.int64(total), jnp.asarray(False),
                                        rounds)
            assert not final, "exact-sized final round overflowed"
            rels = _mask_residual(kind, rels, bad_np, nrow, ncol)
            cap_scale *= growth
        raise AssertionError("unreachable: final round is exact-sized")

    return fn


# --------------------------------------------------------------------------
# helpers for drivers/tests
# --------------------------------------------------------------------------

def shard_relation(rel: Relation, mesh: Mesh, row: str, col: str) -> Relation:
    """Place a host relation onto the mesh, striped in arrival order."""
    spec = P((row, col))
    sharding = NamedSharding(mesh, spec)
    cols = {k: jax.device_put(v, sharding) for k, v in rel.columns.items()}
    valid = jax.device_put(rel.valid, sharding)
    return Relation(cols, valid)


def pad_to_multiple(rel: Relation, multiple: int) -> Relation:
    """Pad capacity so it divides evenly over the mesh."""
    cap = rel.capacity
    rem = (-cap) % multiple
    if rem == 0:
        return rel
    cols = {k: jnp.pad(v, (0, rem)) for k, v in rel.columns.items()}
    valid = jnp.pad(rel.valid, (0, rem))
    return Relation(cols, valid)
