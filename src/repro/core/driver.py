"""Overflow-handling drivers around the join algorithms.

The paper assumes near-uniform keys (§1.2) and notes that skew must be
handled by "leaving some components to handle overflow" or re-partitioning.
Our bucketized layouts are fixed-capacity, so skew (including plain key
multiplicity, |rel|/d copies per value) surfaces as an ``overflowed`` flag —
never as silent wrong answers.

These drivers implement the re-partition loop: on overflow, grow the
per-bucket capacities geometrically (and optionally re-salt the hash
functions) and re-run.  Capacities are static shapes, so each retry re-jits;
retries are rare under the plan defaults and the cost is off the hot path.

DEPRECATED: the declarative front door replaces this module.  Build a
``core.query.Query`` (named relations + join predicates — the kind is
inferred from the predicate graph) and execute it through
``core.session.JoinSession``; see README "Writing a query" for the
migration table.  ``engine_count`` / ``engine_per_r_counts`` remain as thin
shims that construct the Query internally; the ``*_auto`` whole-query retry
drivers remain only as the scan-based baseline the fused engine is
benchmarked against.
"""

from __future__ import annotations

import warnings
from typing import Any

import jax.numpy as jnp

from repro.core import cyclic3, engine, linear3, recovery, star3
from repro.core.query import _legacy_query
from repro.core.session import JoinSession


class OverflowError_(RuntimeError):
    pass


def _deprecated(old: str) -> None:
    # stacklevel=3 attributes the warning to the CALLER of the shim
    # (1 = this warn call, 2 = the shim body, 3 = user code) — pinned by
    # test_plan_ir.test_deprecation_warning_points_at_caller, so the
    # warning's file:line leads users to the site they must migrate
    warnings.warn(
        f"driver.{old} is deprecated: build a core.query.Query and execute "
        "it through core.session.JoinSession (the kind is inferred from "
        "the predicate graph; queries over more than 3 relations are "
        "supported there via the multi-step plan IR)",
        DeprecationWarning, stacklevel=3)


def engine_count(kind: str, r, s, t, plan=None, *, m_budget: int | None = None,
                 use_kernel: bool = False, max_rounds: int = 3,
                 growth: float = 2.0, base_salt: int = 0,
                 **cols) -> engine.EngineResult:
    """Fused-engine count with surgical skew recovery (exact by
    construction; ``overflowed`` is always False on return).

    Deprecation shim: constructs the ``Query`` the (kind, columns) pair
    implies and executes it through a ``JoinSession``.
    """
    _deprecated("engine_count")
    query, cls_ = _legacy_query(kind, r, s, t, cols)
    sess = JoinSession(m_budget=m_budget, use_kernel=use_kernel,
                       max_rounds=max_rounds, growth=growth,
                       base_salt=base_salt)
    qr = sess.execute(query, plan=plan, strategy="3way",
                      classification=cls_)
    return engine.EngineResult(qr.count, jnp.asarray(qr.overflowed),
                               qr.tuples_read, qr.rounds)


def engine_per_r_counts(r, s, t, plan, *, use_kernel: bool = False,
                        max_rounds: int = 3, growth: float = 2.0,
                        base_salt: int = 0, key_col: str = "a",
                        **cols) -> engine.PerRResult:
    """Fused-engine per-R-tuple counts (Example 1) with skew recovery.

    Deprecation shim over ``JoinSession.execute(..., per_r=True)``.
    """
    _deprecated("engine_per_r_counts")
    query, cls_ = _legacy_query("linear", r, s, t, cols)
    sess = JoinSession(use_kernel=use_kernel, max_rounds=max_rounds,
                       growth=growth, base_salt=base_salt)
    qr = sess.execute(query, plan=plan, strategy="3way",
                      classification=cls_, per_r=True, key_col=key_col)
    return qr.per_r


def _grown(plan: Any, growth: float, align: int = 8) -> Any:
    return recovery.grown(plan, growth, align)


def linear3_count_auto(r, s, t, plan: linear3.Linear3Plan, *,
                       max_retries: int = 4, growth: float = 2.0, **kw):
    """linear3_count with geometric capacity growth on overflow."""
    for _ in range(max_retries + 1):
        res = linear3.linear3_count(r, s, t, plan, **kw)
        if not bool(res.overflowed):
            return res, plan
        plan = _grown(plan, growth)
    raise OverflowError_(f"linear3 overflow persisted; final plan {plan}")


def linear3_per_r_counts_auto(r, s, t, plan: linear3.Linear3Plan, *,
                              max_retries: int = 4, growth: float = 2.0, **kw):
    for _ in range(max_retries + 1):
        keys, counts, valid, ovf = linear3.linear3_per_r_counts(
            r, s, t, plan, **kw)
        if not bool(ovf):
            return (keys, counts, valid), plan
        plan = _grown(plan, growth)
    raise OverflowError_(f"linear3 per-r overflow persisted; final plan {plan}")


def cyclic3_count_auto(r, s, t, plan: cyclic3.Cyclic3Plan, *,
                       max_retries: int = 4, growth: float = 2.0, **kw):
    for _ in range(max_retries + 1):
        res = cyclic3.cyclic3_count(r, s, t, plan, **kw)
        if not bool(res.overflowed):
            return res, plan
        plan = _grown(plan, growth)
    raise OverflowError_(f"cyclic3 overflow persisted; final plan {plan}")


def star3_count_auto(r, s, t, plan: star3.Star3Plan, *,
                     max_retries: int = 4, growth: float = 2.0, **kw):
    for _ in range(max_retries + 1):
        res = star3.star3_count(r, s, t, plan, **kw)
        if not bool(res.overflowed):
            return res, plan
        plan = _grown(plan, growth)
    raise OverflowError_(f"star3 overflow persisted; final plan {plan}")
