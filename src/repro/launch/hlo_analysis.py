"""Post-SPMD HLO analysis: collective-byte accounting + roofline terms.

``cost_analysis()`` has no collective information, so we parse the
optimized HLO module text (``compiled.as_text()``) and sum operand bytes of
every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute, bucketed by op kind and by replica-group size (group
size 16 = one mesh axis, 32 = pod×data, 512 = world — this is how cross-pod
traffic is attributed).

Wire-byte convention (ring algorithms, per participating device):
  all-reduce      2·(n-1)/n · bytes     (reduce-scatter + all-gather phases)
  all-gather      (n-1)/n · result      (operand is the local shard)
  reduce-scatter  (n-1)/n · operand
  all-to-all      (n-1)/n · operand
  collective-permute  1   · operand

Hardware model (TPU v5e, per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI (the assignment's constants).  Roofline terms are
seconds-per-step on the partitioned (per-device) module:

  compute    = HLO_FLOPs / peak_FLOPs
  memory     = HLO_bytes / HBM_bw
  collective = wire_bytes / link_bw
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"=\s*(?:\(([^)]*)\)|([a-z0-9]+\[[0-9,]*\][^\s]*))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_DONE_RE = re.compile(r"(all-reduce|all-gather|reduce-scatter|all-to-all|"
                      r"collective-permute)-done")
_GROUPS_RE = re.compile(r"replica_groups=\{(\{[^}]*\})")
_IOTA_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, world: int) -> int:
    m = _IOTA_GROUPS_RE.search(line)
    if m:
        # iota list [num_groups, group_size]
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        return len(m.group(1).strip("{}").split(","))
    return world


@dataclasses.dataclass
class CollectiveStats:
    # raw operand/result bytes and effective wire bytes per device
    by_kind_bytes: dict
    by_kind_wire: dict
    by_group_wire: dict      # group size -> wire bytes
    n_ops: int

    @property
    def total_wire(self) -> float:
        return sum(self.by_kind_wire.values())

    def to_json(self):
        return {
            "bytes_by_kind": dict(self.by_kind_bytes),
            "wire_by_kind": dict(self.by_kind_wire),
            "wire_by_group_size": {str(k): v
                                   for k, v in self.by_group_wire.items()},
            "n_ops": self.n_ops,
            "total_wire_bytes": self.total_wire,
        }


def parse_collectives(hlo_text: str, world: int) -> CollectiveStats:
    by_kind = defaultdict(float)
    wire = defaultdict(float)
    by_group = defaultdict(float)
    n_ops = 0
    for line in hlo_text.splitlines():
        if _DONE_RE.search(line):
            continue
        m = _OP_RE.search(line)
        if not m:
            continue
        tuple_res, single_res, kind = m.group(1), m.group(2), m.group(3)
        result_bytes = _shape_bytes(tuple_res or single_res)
        g = _group_size(line, world)
        n = max(g, 1)
        # every op's traffic derives from its RESULT size (robust to
        # operand-list formatting): all-reduce/all-to-all/permute results
        # equal their operands; all-gather result is the gathered tensor;
        # reduce-scatter operand = result × n.
        if kind == "all-reduce":
            base = result_bytes
            w = 2.0 * (n - 1) / n * base
        elif kind == "all-gather":
            base = result_bytes
            w = (n - 1) / n * base
        elif kind == "reduce-scatter":
            base = result_bytes * n
            w = (n - 1) / n * base
        elif kind == "all-to-all":
            base = result_bytes
            w = (n - 1) / n * base
        else:  # collective-permute
            base = result_bytes
            w = float(base)
        by_kind[kind] += base
        wire[kind] += w
        by_group[n] += w
        n_ops += 1
    return CollectiveStats(dict(by_kind), dict(wire), dict(by_group), n_ops)


# --------------------------------------------------------------------------
# roofline
# --------------------------------------------------------------------------

@dataclasses.dataclass
class Roofline:
    flops: float             # per device per step (partitioned module)
    hbm_bytes: float
    wire_bytes: float
    model_flops: float       # 6·N·D (train) / 2·N·D (serve), per device

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.wire_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def step_time(self) -> float:
        """Perfect-overlap lower bound: max of the three terms."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_fraction(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — remat/redundancy waste detector."""
        return self.model_flops / self.flops if self.flops else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Achievable MFU at the perfect-overlap step time."""
        if self.step_time == 0:
            return 0.0
        return (self.model_flops / PEAK_FLOPS) / self.step_time

    def to_json(self):
        return {
            "flops_per_device": self.flops,
            "hbm_bytes_per_device": self.hbm_bytes,
            "wire_bytes_per_device": self.wire_bytes,
            "model_flops_per_device": self.model_flops,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "step_time_lb_s": self.step_time,
            "useful_flops_fraction": self.useful_flops_fraction,
            "roofline_fraction": self.roofline_fraction,
        }


def model_flops_per_device(cfg, kind: str, global_batch: int, seq_len: int,
                           n_chips: int) -> float:
    """6·N_active·D for train, 2·N_active·D for serve (decode: D = one
    token per sequence), split evenly over chips.  Attention score FLOPs
    (12·L·d·s per token at full attention) are added for completeness —
    they matter at 32k."""
    n_active = cfg.active_param_count()
    if kind == "train":
        tokens = global_batch * seq_len
        factor = 6.0
        attn_ctx = seq_len
    elif kind == "prefill":
        tokens = global_batch * seq_len
        factor = 2.0
        attn_ctx = seq_len
    else:  # decode: one new token against a seq_len cache
        tokens = global_batch * 1
        factor = 2.0
        attn_ctx = seq_len
    core = factor * n_active * tokens
    # causal attention: 2·2·(ctx/2)·(nq·hd)·L per token fwd, ×3 with bwd
    if cfg.family not in ("ssm",):
        n_attn = cfg.n_layers
        if cfg.is_hybrid and cfg.hybrid_every:
            n_attn = cfg.n_layers // cfg.hybrid_every   # shared-block only
        if cfg.n_enc_layers:
            n_attn = cfg.n_layers + cfg.n_enc_layers    # enc self + dec
        att = (2 * 2 * (attn_ctx / 2) * cfg.n_heads * cfg.head_dim
               * n_attn * tokens)
        core += att * (3.0 if kind == "train" else 1.0)
    return core / n_chips
