"""Public jit'd wrappers around the Pallas kernels (with jnp fallback).

Responsibilities kept out of the kernels so they stay branch-free:
  * sentinel-mask invalid slots with per-side sentinels (so invalid slots can
    never equal anything on the other side),
  * pad capacities to 128-lane multiples (MXU/VPU alignment),
  * dispatch kernel vs. pure-jnp reference (``use_kernel=False`` is the CPU
    default — interpret-mode Pallas is for validation, not speed),
  * cast/clip results back to caller shapes.

Keys must be > SENT_BASE (= -2^31 + 16); the data generators and the
relational layer guarantee int32 keys ≥ -2^30.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.relation import SENTINEL
from repro.kernels import bucket_join, radix_hist, ref

# Per-side probe sentinels, derived from the ONE canonical padding sentinel
# (``relation.SENTINEL``, also the fill value of every bucketized layout) so
# the whole constellation lives in [SENTINEL, SENTINEL + 20] — far below the
# ≥ -2^30 key floor — and no two sides can ever false-match each other or a
# padded slot.
SENT_BASE = SENTINEL + 15
_SENT = {"r": SENT_BASE + 1, "s": SENT_BASE + 2, "t": SENT_BASE + 3,
         "a": SENT_BASE + 4, "b": SENT_BASE + 5}
assert len(set(_SENT.values()) | {SENTINEL}) == len(_SENT) + 1

# Largest integer f32 represents exactly (24-bit mantissa).  The fused
# kernels accumulate per-cell partials in int32 on purpose; any compiled
# variant tempted to accumulate in f32 (e.g. to ride the MXU) silently
# loses counts past this — ``analysis.widths`` flags accumulator cells
# whose capacity-product ceiling crosses it.
EXACT_F32_MAX = 1 << 24


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _mask(keys: jnp.ndarray, valid: jnp.ndarray, side: str) -> jnp.ndarray:
    return jnp.where(valid, keys, jnp.int32(_SENT[side]))


def _pad_lanes(x: jnp.ndarray, side: str, align: int = 128) -> jnp.ndarray:
    c = x.shape[-1]
    rem = (-c) % align
    if rem == 0:
        return x
    pad = [(0, 0)] * (x.ndim - 1) + [(0, rem)]
    return jnp.pad(x, pad, constant_values=_SENT[side])


def bucket_pair_count(ka, va, kb, vb, *, use_kernel: bool = False):
    ka = _mask(ka, va, "a")
    kb = _mask(kb, vb, "b")
    if use_kernel:
        return bucket_join.pair_count(_pad_lanes(ka, "a"), _pad_lanes(kb, "b"),
                                      interpret=_interpret())
    return ref.bucket_pair_count(ka, kb)


def bucket_count3_linear(rb, rv, sb, sc, sv, tc, tv, *,
                         use_kernel: bool = False):
    rb = _mask(rb, rv, "r")
    sb = _mask(sb, sv, "s")
    sc = _mask(sc, sv, "s")
    tc = _mask(tc, tv, "t")
    if use_kernel:
        return bucket_join.count3_linear(
            _pad_lanes(rb, "r"), _pad_lanes(sb, "s"), _pad_lanes(sc, "s"),
            _pad_lanes(tc, "t"), interpret=_interpret())
    return ref.bucket_count3_linear(rb, sb, sc, tc)


def bucket_per_r_counts(rb, rv, sb, sc, sv, tc, tv, *,
                        use_kernel: bool = False):
    cr = rb.shape[-1]
    rb = _mask(rb, rv, "r")
    sb = _mask(sb, sv, "s")
    sc = _mask(sc, sv, "s")
    tc = _mask(tc, tv, "t")
    if use_kernel:
        out = bucket_join.per_r_counts(
            _pad_lanes(rb, "r"), _pad_lanes(sb, "s"), _pad_lanes(sc, "s"),
            _pad_lanes(tc, "t"), interpret=_interpret())
        return out[:, :cr]
    return ref.bucket_per_r_counts(rb, sb, sc, tc)


def bucket_count3_cyclic(ra, rb, rv, sb, sc, sv, tc, ta, tv, *,
                         use_kernel: bool = False):
    ra = _mask(ra, rv, "r")
    rb = _mask(rb, rv, "r")
    sb = _mask(sb, sv, "s")
    sc = _mask(sc, sv, "s")
    tc = _mask(tc, tv, "t")
    ta = _mask(ta, tv, "t")
    if use_kernel:
        return bucket_join.count3_cyclic(
            _pad_lanes(ra, "r"), _pad_lanes(rb, "r"), _pad_lanes(sb, "s"),
            _pad_lanes(sc, "s"), _pad_lanes(tc, "t"), _pad_lanes(ta, "t"),
            interpret=_interpret())
    return ref.bucket_count3_cyclic(ra, rb, sb, sc, tc, ta)


# --------------------------------------------------------------------------
# fused partition-sweep ops (engine hot path)
# --------------------------------------------------------------------------
#
# One call covers the WHOLE coarse partition sweep instead of one bucket
# row.  ``use_kernel=True`` dispatches to the single-pallas_call fused
# kernels (grid spans the sweep, §6.2 double buffering across partitions);
# the default jnp path is equally fused at the XLA level: the partition
# sweep is batched into one op (or one scan over the streaming dimension
# when the compare tensors would not fit), so the hot path is one launch —
# not h_parts × g_parts of them.

# Full-batch threshold for the compare-based jnp fused paths: largest
# compare tensor (in elements) we are willing to materialize before falling
# back to a scan over the streaming dimension.
_FUSE_BATCH_ELEMS = 1 << 26


def _bucket_multiplicity(table, probes):
    """Per-probe occurrence counts within aligned bucket rows.

    table: [B, Ct] sentinel-masked keys; probes: [B, Cp].  Returns [B, Cp]
    int32 — for each probe, how many equal keys its OWN bucket row holds.
    Sorted rows + two binary searches per probe (O(Cp log Ct) per bucket,
    vs O(Cp·Ct) for the all-pairs compare the SIMD kernels use — the right
    realization of the same per-bucket math for a scalar/CPU backend).
    """
    srt = jnp.sort(table, axis=-1)
    lo = jax.vmap(lambda t, p: jnp.searchsorted(t, p, side="left"))(
        srt, probes)
    hi = jax.vmap(lambda t, p: jnp.searchsorted(t, p, side="right"))(
        srt, probes)
    return (hi - lo).astype(jnp.int32)


def _fused_linear_ref(rb, sb, sc, tc):
    """rb [hp,u,Cr], sb/sc [hp,gp,u,Cs], tc [gp,Ct] -> [hp,u] int32.

    One fused pass over the whole sweep: every S slot is weighted by its R
    multiplicity (probing the matching (H, h) bucket) times its T
    multiplicity (probing the matching g bucket), then per-(H, h) partial
    sums — identical per-bucket semantics to the scan driver, realized with
    sorted-bucket probes instead of all-pairs compares.
    """
    hp, u, cr = rb.shape
    _, gp, _, cs = sb.shape
    _, ct = tc.shape
    # wr: probe R bucket (H, h) with the S keys routed to it
    s_by_r = sb.transpose(0, 2, 1, 3).reshape(hp * u, gp * cs)
    wr = _bucket_multiplicity(rb.reshape(hp * u, cr), s_by_r)
    # wt: probe T bucket g with the S keys streamed against it
    s_by_t = sc.transpose(1, 0, 2, 3).reshape(gp, hp * u * cs)
    wt = _bucket_multiplicity(tc, s_by_t)
    wt = wt.reshape(gp, hp, u, cs).transpose(1, 2, 0, 3).reshape(
        hp * u, gp * cs)
    return jnp.sum(wr * wt, axis=-1).reshape(hp, u)


def _fused_per_r_ref(rb, sb, sc, tc):
    """rb [hp,u,Cr], sb/sc [hp,gp,u,Cs], tc [gp,Ct] -> [hp,u,Cr] int32."""
    hp, u, cr = rb.shape
    _, gp, _, cs = sb.shape
    _, ct = tc.shape
    if hp * gp * u * cs * max(cr, ct) <= _FUSE_BATCH_ELEMS:
        m1 = (sb[..., :, None] == rb[:, None, :, None, :]).astype(jnp.int32)
        wt = jnp.sum(sc[..., :, None] == tc[None, :, None, None, :], axis=-1)
        return jnp.einsum("hgusr,hgus->hur", m1, wt).astype(jnp.int32)

    def g_step(acc, ys):
        sb_j, sc_j, tc_j = ys
        m1 = (sb_j[..., :, None] == rb[..., None, :]).astype(jnp.int32)
        wt = jnp.sum(sc_j[..., :, None] == tc_j[None, None, None, :], axis=-1)
        return acc + jnp.einsum("husr,hus->hur", m1, wt), None

    acc, _ = jax.lax.scan(
        g_step, jnp.zeros((hp, u, cr), jnp.int32),
        (sb.transpose(1, 0, 2, 3), sc.transpose(1, 0, 2, 3), tc))
    return acc


def lex_sort_pairs(tc, ta):
    """Sort each bucket row's (c, a) pairs lexicographically by (c, then a).

    tc/ta: [..., Ct] sentinel-masked keys.  Returns (tc_sorted, ta_sorted) —
    the sorted (c, a)-pair index the cyclic probes range-scan.
    """
    order = jnp.lexsort((ta, tc), axis=-1)
    return (jnp.take_along_axis(tc, order, axis=-1),
            jnp.take_along_axis(ta, order, axis=-1))


def sorted_pair_index(tc, ta, tv):
    """Build the sorted (c, a)-pair index for a grid of T bucket rows:
    sentinel-mask invalid slots, then lex-sort each row by (c, then a).

    tc/ta: [..., Ct] raw keys, tv: [..., Ct] validity.  Built ONCE per
    partitioning and probed many times (``bucket_count3_cyclic_pairidx``)
    — the public entry the scan driver's pair-index path uses.
    """
    return lex_sort_pairs(_mask(tc, tv, "t"), _mask(ta, tv, "t"))


def bucket_count3_cyclic_pairidx(ra, rb, rv, sb, sc, sv, tcs, tas):
    """Per-bucket triangle counts against a pre-built sorted pair index.

    Same contract as ``bucket_count3_cyclic`` except the T side arrives
    as ``sorted_pair_index`` output (already masked + lex-sorted, so no
    validity argument): each S slot finds its T matches with two
    ``searchsorted`` range probes and a prefix-sum table instead of the
    all-pairs compare — O(Ct·Cr + Cs·Cr + Cs·log Ct) per bucket.
    """
    return _pairidx_cell_counts(_mask(ra, rv, "r"), _mask(rb, rv, "r"),
                                _mask(sb, sv, "s"), _mask(sc, sv, "s"),
                                tcs, tas)


def _pairidx_cell_counts(ra, rb, sb, sc, tcs, tas):
    """Per-bucket triangle counts via the sorted (c, a)-pair index.

    ra/rb: [B, Cr], sb/sc: [B, Cs], tcs/tas: [B, Ct] with (tcs, tas)
    lex-sorted per bucket (``lex_sort_pairs``).  Returns [B] int32.

    Instead of the all-pairs contraction Σ (M1ᵀM2) ⊙ M3 (O(Cs·Cr·Ct) per
    bucket), each S slot range-scans the pair index: its T matches are the
    contiguous run tcs ∈ [lo, hi) found by two ``searchsorted`` probes, and
    the per-R a-match counts over that run come from a prefix-sum table —
    O(Ct·Cr + Cs·Cr + Cs·log Ct) per bucket.  Same per-bucket semantics,
    TrieJax-style indexed second-relation probe.
    """
    lo = jax.vmap(lambda t, p: jnp.searchsorted(t, p, side="left"))(tcs, sc)
    hi = jax.vmap(lambda t, p: jnp.searchsorted(t, p, side="right"))(tcs, sc)
    # prefix sums over the sorted T run of per-R a-equality
    m3 = (tas[:, :, None] == ra[:, None, :]).astype(jnp.int32)   # [B, Ct, Cr]
    pre = jnp.pad(jnp.cumsum(m3, axis=1), ((0, 0), (1, 0), (0, 0)))
    # per-(s, r): # t with t.c == s.c and t.a == r.a  (range-sum of prefixes)
    g = (jnp.take_along_axis(pre, hi[:, :, None], axis=1)
         - jnp.take_along_axis(pre, lo[:, :, None], axis=1))     # [B, Cs, Cr]
    e = (sb[:, :, None] == rb[:, None, :]).astype(jnp.int32)     # [B, Cs, Cr]
    return jnp.sum(e * g, axis=(1, 2)).astype(jnp.int32)


def _fused_cyclic_pairidx_ref(ra, rb, sb, sc, tc, ta):
    """Pair-index realization of the fused cyclic sweep (CPU hot path).

    Same shapes/contract as ``_fused_cyclic_ref``; the T stream is lex-sorted
    into a (c, a)-pair index once per bucket, then every (cell, f) step probes
    it with searchsorted range scans instead of all-pairs compares.
    """
    hp, gp, uh, ug, cr = ra.shape
    _, fp, _, cs = sb.shape
    _, _, _, ct = tc.shape
    tcs, tas = lex_sort_pairs(tc, ta)            # [hp, fp, uh, Ct]
    b = hp * gp * uh * ug
    ra_f = ra.reshape(b, cr)
    rb_f = rb.reshape(b, cr)

    def bcast(x, shape):
        return jnp.broadcast_to(x, shape).reshape((b,) + x.shape[-1:])

    def f_step(acc, ys):
        sb_f, sc_f, tcs_f, tas_f = ys            # [gp,ug,Cs], [hp,uh,Ct]
        s_shape = (hp, gp, uh, ug, cs)
        t_shape = (hp, gp, uh, ug, ct)
        c = _pairidx_cell_counts(
            ra_f, rb_f,
            bcast(sb_f[None, :, None, :, :], s_shape),
            bcast(sc_f[None, :, None, :, :], s_shape),
            bcast(tcs_f[:, None, :, None, :], t_shape),
            bcast(tas_f[:, None, :, None, :], t_shape))
        return acc + c.reshape(hp, gp, uh, ug), None

    acc, _ = jax.lax.scan(
        f_step, jnp.zeros((hp, gp, uh, ug), jnp.int32),
        (sb.transpose(1, 0, 2, 3), sc.transpose(1, 0, 2, 3),
         tcs.transpose(1, 0, 2, 3), tas.transpose(1, 0, 2, 3)))
    return acc


def _fused_cyclic_ref(ra, rb, sb, sc, tc, ta):
    """ra/rb [hp,gp,uh,ug,Cr], sb/sc [gp,fp,ug,Cs], tc/ta [hp,fp,uh,Ct]
    -> [hp,gp,uh,ug] int32.  Batched over the coarse grid, scanned over f."""
    hp, gp, uh, ug, cr = ra.shape
    _, fp, _, cs = sb.shape
    _, _, _, ct = tc.shape

    def f_step(acc, ys):
        sb_f, sc_f, tc_f, ta_f = ys      # [gp,ug,Cs], [hp,uh,Ct]
        def flat(x, shape):
            return jnp.broadcast_to(x, shape).reshape(
                (hp * gp * uh * ug,) + x.shape[-1:])
        s_shape = (hp, gp, uh, ug, cs)
        t_shape = (hp, gp, uh, ug, ct)
        c = ref.bucket_count3_cyclic(
            ra.reshape(-1, cr), rb.reshape(-1, cr),
            flat(sb_f[None, :, None, :, :], s_shape),
            flat(sc_f[None, :, None, :, :], s_shape),
            flat(tc_f[:, None, :, None, :], t_shape),
            flat(ta_f[:, None, :, None, :], t_shape))
        return acc + c.reshape(hp, gp, uh, ug), None

    acc, _ = jax.lax.scan(
        f_step, jnp.zeros((hp, gp, uh, ug), jnp.int32),
        (sb.transpose(1, 0, 2, 3), sc.transpose(1, 0, 2, 3),
         tc.transpose(1, 0, 2, 3), ta.transpose(1, 0, 2, 3)))
    return acc


def _fused_star_ref(rb, sb, sc, tc):
    """rb [uh,Cr], sb/sc [ch,uh,ug,Cs], tc [ug,Ct] -> [uh,ug] int32.

    Same sorted-bucket-probe scheme as ``_fused_linear_ref``: each fact slot
    probes the R bucket of its row and the T bucket of its column.
    """
    uh, cr = rb.shape
    ch, _, ug, cs = sb.shape
    _, ct = tc.shape
    s_by_r = sb.transpose(1, 0, 2, 3).reshape(uh, ch * ug * cs)
    wr = _bucket_multiplicity(rb, s_by_r)
    wr = wr.reshape(uh, ch, ug, cs).transpose(1, 0, 2, 3)   # [ch,uh,ug,cs]
    s_by_t = sc.transpose(2, 0, 1, 3).reshape(ug, ch * uh * cs)
    wt = _bucket_multiplicity(tc, s_by_t)
    wt = wt.reshape(ug, ch, uh, cs).transpose(1, 2, 0, 3)   # [ch,uh,ug,cs]
    return jnp.sum(wr * wt, axis=(0, 3)).astype(jnp.int32)


def fused_count3_linear(rb, rv, sb, sc, sv, tc, tv, *,
                        use_kernel: bool = False):
    """Fused linear-3 sweep: per-(H, h) bucket counts [hp, u] int32."""
    rb = _mask(rb, rv, "r")
    sb = _mask(sb, sv, "s")
    sc = _mask(sc, sv, "s")
    tc = _mask(tc, tv, "t")
    if use_kernel:
        return bucket_join.fused_count3_linear(
            _pad_lanes(rb, "r"), _pad_lanes(sb, "s"), _pad_lanes(sc, "s"),
            _pad_lanes(tc, "t"), interpret=_interpret())
    return _fused_linear_ref(rb, sb, sc, tc)


def fused_per_r_counts(rb, rv, sb, sc, sv, tc, tv, *,
                       use_kernel: bool = False):
    """Fused per-R-slot counts [hp, u, Cr] int32 (Example 1 aggregate)."""
    cr = rb.shape[-1]
    rb = _mask(rb, rv, "r")
    sb = _mask(sb, sv, "s")
    sc = _mask(sc, sv, "s")
    tc = _mask(tc, tv, "t")
    if use_kernel:
        out = bucket_join.fused_per_r_counts(
            _pad_lanes(rb, "r"), _pad_lanes(sb, "s"), _pad_lanes(sc, "s"),
            _pad_lanes(tc, "t"), interpret=_interpret())
        return out[..., :cr]
    return _fused_per_r_ref(rb, sb, sc, tc)


def fused_count3_cyclic(ra, rb, rv, sb, sc, sv, tc, ta, tv, *,
                        use_kernel: bool = False, pair_index: bool = True):
    """Fused cyclic sweep: per-cell counts [hp, gp, uh, ug] int32.

    ``pair_index=True`` (default) probes a sorted (c, a)-pair index of the T
    stream with searchsorted range scans — the indexed backend that takes the
    cyclic CPU path past the all-pairs compare bottleneck.  Set False for the
    all-pairs contraction (the MXU-shaped formulation).
    """
    ra = _mask(ra, rv, "r")
    rb = _mask(rb, rv, "r")
    sb = _mask(sb, sv, "s")
    sc = _mask(sc, sv, "s")
    tc = _mask(tc, tv, "t")
    ta = _mask(ta, tv, "t")
    if use_kernel:
        # The pair-index kernel's binary-search gathers don't lower to
        # Mosaic yet: dispatch it only where Pallas runs in interpret mode
        # (CPU validation); compiled TPU keeps the all-pairs MXU kernel.
        if pair_index and _interpret():
            tcs, tas = lex_sort_pairs(_pad_lanes(tc, "t"), _pad_lanes(ta, "t"))
            return bucket_join.fused_count3_cyclic_pairidx(
                _pad_lanes(ra, "r"), _pad_lanes(rb, "r"), _pad_lanes(sb, "s"),
                _pad_lanes(sc, "s"), tcs, tas, interpret=True)
        return bucket_join.fused_count3_cyclic(
            _pad_lanes(ra, "r"), _pad_lanes(rb, "r"), _pad_lanes(sb, "s"),
            _pad_lanes(sc, "s"), _pad_lanes(tc, "t"), _pad_lanes(ta, "t"),
            interpret=_interpret())
    if pair_index:
        return _fused_cyclic_pairidx_ref(ra, rb, sb, sc, tc, ta)
    return _fused_cyclic_ref(ra, rb, sb, sc, tc, ta)


def fused_count3_star(rb, rv, sb, sc, sv, tc, tv, *,
                      use_kernel: bool = False):
    """Fused star sweep: per-PMU counts [uh, ug] int32."""
    rb = _mask(rb, rv, "r")
    sb = _mask(sb, sv, "s")
    sc = _mask(sc, sv, "s")
    tc = _mask(tc, tv, "t")
    if use_kernel:
        return bucket_join.fused_count3_star(
            _pad_lanes(rb, "r"), _pad_lanes(sb, "s"), _pad_lanes(sc, "s"),
            _pad_lanes(tc, "t"), interpret=_interpret())
    return _fused_star_ref(rb, sb, sc, tc)


@functools.partial(jax.jit, static_argnames=("n_buckets", "use_kernel"))
def radix_histogram(keys, valid, *, n_buckets: int, use_kernel: bool = False):
    """Histogram of hash_bucket(keys) over live rows."""
    from repro.core import hashing

    if use_kernel:
        # pad the stream to the tile size with a sentinel whose bucket we
        # compute and subtract afterwards.
        tile = 1024
        n = keys.shape[0]
        padded = jnp.where(valid, keys, jnp.int32(_SENT["s"]))
        rem = (-n) % tile
        if rem:
            padded = jnp.pad(padded, (0, rem), constant_values=_SENT["s"])
        hist = radix_hist.radix_histogram(padded, n_buckets=n_buckets,
                                          interpret=_interpret())
        n_invalid = (padded.shape[0] - jnp.sum(valid)).astype(jnp.int32)
        sent_bucket = hashing.hash_bucket(
            jnp.full((1,), _SENT["s"], jnp.int32), n_buckets, "H")[0]
        return hist.at[sent_bucket].add(-n_invalid)
    ids = jnp.where(valid, hashing.hash_bucket(keys, n_buckets, "H"),
                    jnp.int32(n_buckets))
    return ref.radix_histogram(keys, ids, n_buckets)


def fm_registers(ra, rv, rb, sb, sc, sv, tc, td, tv, *, n_registers: int = 32,
                 use_kernel: bool = False):
    """FM sketch registers over implicit joined (a, d) pairs (ref path only;
    the matmul inside dominates and is already MXU-shaped under jit)."""
    del use_kernel
    ra = _mask(ra, rv, "r")
    rb = _mask(rb, rv, "r")
    sb = _mask(sb, sv, "s")
    sc = _mask(sc, sv, "s")
    tc = _mask(tc, tv, "t")
    td = _mask(td, tv, "t")
    return ref.fm_registers(ra, rb, sb, sc, tc, td, n_registers)
