"""Fixed-capacity, validity-masked relations (struct-of-arrays).

JAX requires static shapes, and the paper's algorithms never materialize the
final join output (aggregates are folded on the fly, §6).  A Relation is a
dict of equal-length int32 column arrays plus a boolean validity mask; the
capacity is static, the live count `n` is dynamic.  All core algorithms
consume and produce Relations (or aggregates).
"""

from __future__ import annotations

import dataclasses
from typing import Mapping

import jax
import jax.numpy as jnp


# The canonical padding sentinel for invalid relation slots.  Every layer
# that fills dead slots (``sentinel_fill``, ``partition.bucketize``,
# ``partition.bucketize_by_ids``) uses THIS constant; the per-side probe
# sentinels in ``kernels.ops`` are derived from it (SENTINEL + 15 + side)
# so no sentinel of any kind can ever equal a live key (keys are ≥ -2^30
# by the data-layer contract) or a sentinel from another side.
SENTINEL = -0x7FFFFFFF


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class Relation:
    """Columnar relation with static capacity and a validity mask."""

    columns: Mapping[str, jnp.ndarray]  # each (capacity,) int32
    valid: jnp.ndarray                  # (capacity,) bool

    # -- pytree protocol ----------------------------------------------------
    def tree_flatten(self):
        names = tuple(sorted(self.columns))
        return tuple(self.columns[n] for n in names) + (self.valid,), names

    @classmethod
    def tree_unflatten(cls, names, leaves):
        *cols, valid = leaves
        return cls(columns=dict(zip(names, cols)), valid=valid)

    # -- introspection -------------------------------------------------------
    @property
    def capacity(self) -> int:
        return self.valid.shape[0]

    @property
    def n(self) -> jnp.ndarray:
        """Dynamic number of live tuples."""
        return jnp.sum(self.valid.astype(jnp.int32))

    def col(self, name: str) -> jnp.ndarray:
        return self.columns[name]

    # -- construction --------------------------------------------------------
    @classmethod
    def from_arrays(cls, capacity: int | None = None, **cols) -> "Relation":
        """Build from equal-length arrays, optionally padding to `capacity`."""
        arrs = {k: jnp.asarray(v, dtype=jnp.int32) for k, v in cols.items()}
        lens = {a.shape[0] for a in arrs.values()}
        if len(lens) != 1:
            raise ValueError(f"ragged columns: {dict((k, v.shape) for k, v in arrs.items())}")
        (n,) = lens
        cap = capacity or n
        if cap < n:
            raise ValueError(f"capacity {cap} < rows {n}")
        pad = cap - n
        if pad:
            arrs = {k: jnp.pad(a, (0, pad)) for k, a in arrs.items()}
        valid = jnp.arange(cap) < n
        return cls(columns=arrs, valid=valid)

    def select(self, idx: jnp.ndarray, idx_valid: jnp.ndarray) -> "Relation":
        """Gather rows by index (row validity AND idx_valid)."""
        cols = {k: v[idx] for k, v in self.columns.items()}
        return Relation(cols, self.valid[idx] & idx_valid)

    def with_columns(self, **cols) -> "Relation":
        new = dict(self.columns)
        new.update({k: jnp.asarray(v, jnp.int32) for k, v in cols.items()})
        return Relation(new, self.valid)

    def mask_where(self, keep: jnp.ndarray) -> "Relation":
        return Relation(dict(self.columns), self.valid & keep)


def sentinel_fill(rel: Relation, sentinel: int = SENTINEL) -> Relation:
    """Overwrite invalid rows' columns with a sentinel that never equals a
    live key, so masked compare loops need no extra predicate."""
    cols = {
        k: jnp.where(rel.valid, v, jnp.int32(sentinel))
        for k, v in rel.columns.items()
    }
    return Relation(cols, rel.valid)
