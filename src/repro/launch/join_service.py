"""Async batch front end for join queries and standing-query ingest.

    PYTHONPATH=src python -m repro.launch.join_service --smoke \
        --deltas 6 --delta-rows 64

The service reuses the wave-scheduling structure of ``launch.serve``
(batch-synchronous waves: admit a bounded wave, run it, answer, repeat) on
top of the declarative join engine:

  * **Admission.**  ``submit`` / ``watch`` / ``ingest`` / ``snapshot``
    enqueue a request onto a bounded queue and return a
    ``concurrent.futures.Future``; a full queue raises
    :class:`ServiceOverloaded` immediately (backpressure — callers retry
    or shed, the service never buffers unboundedly).
  * **Waves.**  The pump drains up to ``wave_size`` requests, groups plain
    executes per tenant and runs them through
    ``JoinSession.execute_many`` — structurally repeated queries in a
    wave share the tenant session's log-bucketed plan cache — and applies
    ingests in admission order (each ``Relation.append`` synchronously
    drives the registered standing queries' delta plans).
  * **Tenancy.**  Each tenant name owns one ``JoinSession`` (plan cache,
    m_budget) and its standing-query handles; tenants never share plans.
  * **Metrics.**  Per-tenant power-of-two histograms of per-query latency
    (microseconds), recovery rounds, and tuples read, exported by
    :meth:`JoinService.metrics` next to the per-step ``StepStats`` the
    results already carry.  Bucket ``"2^k"`` counts observations with
    ``2^(k-1) < value <= 2^k`` (``"0"`` holds zeros); every histogram also
    reports ``count`` and ``sum`` so averages need no client-side state.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import queue
import threading
import time
from concurrent.futures import Future

from repro.core.query import Query
from repro.core.relation import Relation
from repro.core.session import JoinSession


class ServiceOverloaded(RuntimeError):
    """Admission queue is full: shed or retry later (backpressure)."""


class _Hist:
    """Power-of-two bucketed histogram (host ints — int64-exact sums)."""

    def __init__(self):
        self.buckets: dict[int, int] = {}   # exponent k -> count (-1: zeros)
        self.count = 0
        self.sum = 0

    def record(self, value: int) -> None:
        v = int(value)
        k = -1 if v <= 0 else (v - 1).bit_length()
        self.buckets[k] = self.buckets.get(k, 0) + 1
        self.count += 1
        self.sum += max(v, 0)

    def export(self) -> dict:
        return {
            "buckets": {("0" if k < 0 else f"2^{k}"): self.buckets[k]
                        for k in sorted(self.buckets)},
            "count": self.count,
            "sum": self.sum,
        }


@dataclasses.dataclass
class _Request:
    kind: str                    # execute | watch | ingest | snapshot
    tenant: str
    future: Future
    query: Query | None = None
    relation: Relation | None = None
    cols: dict | None = None
    handle: object = None        # StandingQuery for snapshot
    strategy: str | None = None
    admitted: float = 0.0


class _Tenant:
    def __init__(self, **session_kw):
        self.session = JoinSession(**session_kw)
        self.latency_us = _Hist()
        self.rounds = _Hist()
        self.tuples_read = _Hist()


class JoinService:
    """Bounded-queue, wave-batched join service with standing queries."""

    def __init__(self, *, max_queue: int = 64, wave_size: int = 8,
                 **session_kw):
        self._queue: queue.Queue[_Request] = queue.Queue(maxsize=max_queue)
        self.wave_size = wave_size
        self._session_kw = session_kw
        self._tenants: dict[str, _Tenant] = {}
        self._thread: threading.Thread | None = None
        self._running = False
        self.waves = 0
        self.rejected = 0

    # -- admission (any thread) -------------------------------------------

    def _admit(self, req: _Request) -> Future:
        req.admitted = time.perf_counter()
        try:
            self._queue.put_nowait(req)
        except queue.Full:
            self.rejected += 1
            raise ServiceOverloaded(
                f"admission queue full ({self._queue.maxsize}); retry "
                "later") from None
        return req.future

    def submit(self, tenant: str, query: Query, *,
               strategy: str | None = None) -> Future:
        """One-shot query → Future[QueryResult]."""
        return self._admit(_Request("execute", tenant, Future(),
                                    query=query, strategy=strategy))

    def watch(self, tenant: str, query: Query, *,
              strategy: str | None = None) -> Future:
        """Register a standing query → Future[StandingQuery]."""
        return self._admit(_Request("watch", tenant, Future(),
                                    query=query, strategy=strategy))

    def ingest(self, tenant: str, relation: Relation, cols: dict) -> Future:
        """Append a delta batch → Future[int] (rows applied).  The append
        synchronously drives every standing query watching ``relation``
        through its delta plan before the Future resolves."""
        return self._admit(_Request("ingest", tenant, Future(),
                                    relation=relation, cols=dict(cols)))

    def snapshot(self, tenant: str, handle) -> Future:
        """Standing answer → Future[QueryResult] (same type as submit)."""
        return self._admit(_Request("snapshot", tenant, Future(),
                                    handle=handle))

    # -- wave pump (service thread) ---------------------------------------

    def _tenant(self, name: str) -> _Tenant:
        t = self._tenants.get(name)
        if t is None:
            t = self._tenants[name] = _Tenant(**self._session_kw)
        return t

    def _observe(self, ten: _Tenant, req: _Request, res) -> None:
        ten.latency_us.record(
            int((time.perf_counter() - req.admitted) * 1e6))
        ten.rounds.record(int(getattr(res, "rounds", 0) or 0))
        tr = getattr(res, "tuples_read", None)
        ten.tuples_read.record(0 if tr is None else int(tr))

    def pump(self) -> int:
        """Drain one wave (≤ wave_size requests): group executes per
        tenant through ``execute_many``, apply the rest in admission
        order.  Returns the number of requests served."""
        wave: list[_Request] = []
        while len(wave) < self.wave_size:
            try:
                wave.append(self._queue.get_nowait())
            except queue.Empty:
                break
        if not wave:
            return 0
        self.waves += 1
        # batch the plain executes per tenant (shared plan cache per wave)
        by_tenant: dict[str, list[_Request]] = {}
        for req in wave:
            if req.kind == "execute":
                by_tenant.setdefault(req.tenant, []).append(req)
        done: set[int] = set()
        for tenant, reqs in by_tenant.items():
            ten = self._tenant(tenant)
            try:
                results = ten.session.execute_many(
                    [r.query for r in reqs],
                    strategy=reqs[0].strategy)
            except Exception as e:          # noqa: BLE001 — fail the wave's futures
                for r in reqs:
                    r.future.set_exception(e)
                    done.add(id(r))
                continue
            for r, res in zip(reqs, results):
                self._observe(ten, r, res)
                r.future.set_result(res)
                done.add(id(r))
        for req in wave:
            if id(req) in done:
                continue
            ten = self._tenant(req.tenant)
            try:
                if req.kind == "watch":
                    res = ten.session.watch(req.query,
                                            strategy=req.strategy)
                    req.future.set_result(res)
                elif req.kind == "ingest":
                    delta = req.relation.append(req.cols)
                    self._observe(ten, req, None)
                    req.future.set_result(int(delta.n))
                elif req.kind == "snapshot":
                    res = req.handle.snapshot()
                    self._observe(ten, req, res)
                    req.future.set_result(res)
                else:
                    raise ValueError(f"unknown request kind {req.kind!r}")
            except Exception as e:          # noqa: BLE001
                req.future.set_exception(e)
        return len(wave)

    def run_until_idle(self) -> int:
        """Synchronously pump waves until the queue drains (tests/CLI)."""
        served = 0
        while True:
            n = self.pump()
            if n == 0:
                return served
            served += n

    # -- background thread --------------------------------------------------

    def start(self) -> None:
        if self._running:
            return
        self._running = True
        self._thread = threading.Thread(target=self._loop, daemon=True)
        self._thread.start()

    def stop(self) -> None:
        self._running = False
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _loop(self) -> None:
        while self._running:
            if self.pump() == 0:
                time.sleep(0.002)

    # -- metrics -------------------------------------------------------------

    def metrics(self) -> dict:
        """Per-tenant histogram export (see module docstring for the
        bucket format) plus service counters."""
        return {
            "waves": self.waves,
            "rejected": self.rejected,
            "queue_depth": self._queue.qsize(),
            "tenants": {
                name: {
                    "latency_us": t.latency_us.export(),
                    "rounds": t.rounds.export(),
                    "tuples_read": t.tuples_read.export(),
                    "plan_cache": {"hits": t.session._hits,
                                   "misses": t.session._misses},
                }
                for name, t in self._tenants.items()
            },
        }


# -- smoke entry point ------------------------------------------------------

def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--rows", type=int, default=4000)
    ap.add_argument("--distinct", type=int, default=512)
    ap.add_argument("--deltas", type=int, default=6)
    ap.add_argument("--delta-rows", type=int, default=64)
    ap.add_argument("--m-budget", type=int, default=256)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    import numpy as np

    rng = np.random.default_rng(args.seed)
    n, d = args.rows, args.distinct

    def mk(*cols):
        return Relation.from_arrays(
            **{c: rng.integers(0, d, n) for c in cols})

    r, s, t = mk("a", "b"), mk("b", "c"), mk("c", "e")
    q = Query({"R": r, "S": s, "T": t},
              [("R.b", "S.b"), ("S.c", "T.c")])

    svc = JoinService(max_queue=32, wave_size=4, m_budget=args.m_budget)
    handle = svc.watch("smoke", q)
    svc.run_until_idle()
    sq = handle.result()
    print(f"standing query registered: count={sq.count}")

    for i in range(args.deltas):
        k = args.delta_rows
        which, cols = [(r, ("a", "b")), (s, ("b", "c")),
                       (t, ("c", "e"))][i % 3]
        fut = svc.ingest("smoke", which,
                         {c: rng.integers(0, d, k) for c in cols})
        svc.run_until_idle()
        fut.result()
        rec = sq.delta_rounds[-1]
        print(f"delta {i}: +{rec.delta_rows} rows into {rec.relation} → "
              f"Δcount={rec.count_delta} rounds={rec.rounds} "
              f"overflowed={rec.overflowed}")
        assert not rec.overflowed, "delta round overflowed"

    snap_f = svc.snapshot("smoke", sq)
    svc.run_until_idle()
    snap = snap_f.result()
    oracle = JoinSession(m_budget=args.m_budget).execute(q)
    match = int(snap.count) == int(oracle.count)
    print(f"final: standing={int(snap.count)} "
          f"from_scratch={int(oracle.count)} match={match} "
          f"overflowed={bool(snap.overflowed)}")
    print(json.dumps(svc.metrics(), indent=2, sort_keys=True))
    if not match:
        raise SystemExit("standing count diverged from from-scratch oracle")
    print("smoke OK")


if __name__ == "__main__":
    main()
