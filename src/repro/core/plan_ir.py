"""Multi-step query-plan IR: cascades of fused 3-way and binary joins.

The paper's central result is a *choice* — one fused 3-way join versus a
cascade of binary hash joins — and this module is the representation that
makes the choice first-class for any connected acyclic equality-join graph
over N >= 2 named relations (cyclic graphs stay supported at N = 3, the
triangle query):

  * :class:`PlanStep` — one physical step.  ``op == "binary"`` is a
    sorted-path hash join (materialized into a fixed-capacity intermediate
    ``Relation``, or host-aggregated when it is the root); ``op ==
    "fused3"`` is the fused 3-way engine, recovery-wrapped: skew rounds +
    the exact-histogram final round make ``overflowed == False`` a
    per-step postcondition.
  * :class:`QueryPlan` — a DAG of steps in topological order.  Steps name
    their inputs (base relations by query name, intermediates as
    ``%i<k>``); intermediate schemas (``project``) and plan-time
    cardinality estimates (``est_rows``/``est_out``) flow between steps;
    the root step writes :data:`COUNT`.
  * :func:`execute_plan` — the ONE executor, device-resident end to end.
    Each binary materialize step runs as a compiled two-dispatch pipeline
    (``binary_join.stage_join`` → ``gather_staged``) whose only host↔
    device traffic is the two-scalar exact total that sizes the output
    buffer (log-bucketed static capacities, so refreshed executions hit
    the same compiled gather).  Steps overlap: before the executor blocks
    on a step's total it dispatches stage 1 of every later binary step
    whose inputs are already live (independent DAG branches run
    concurrently under JAX async dispatch), and a refcounting buffer
    arena drops each ``%i<k>`` intermediate the moment its last consumer
    has captured it.  ``base_salt``/``max_rounds``/``growth`` thread
    through every fused step; count / tuples_read / recovery rounds /
    per-step timings aggregate into a single result.

``planner.plan_query`` is the decomposer that produces these plans;
``session.JoinSession.execute`` walks them.  The legacy
``planner.EnginePlan.run`` cascade branch now routes through this
executor too — there is no second cascade implementation.
"""

from __future__ import annotations

import dataclasses
import os
import time
from typing import Mapping, NamedTuple

import jax
import numpy as np

from repro.analysis import arena_sanitizer
from repro.analysis.errors import (PlanPerRError, PlanStructureError,
                                   PlanWidthError)
from repro.core import binary_join, engine, recovery
from repro.core.query import Predicate
from repro.core.relation import Relation

# The root step's output name: the aggregated COUNT of the whole query.
COUNT = "%count"


@dataclasses.dataclass(frozen=True)
class PlanStep:
    """One physical step of a :class:`QueryPlan`.

    ``inputs`` are environment names: base relations keep their query
    names, intermediates are ``%i<k>``.  ``preds`` reference columns in
    the *post-projection* key space of each input (base relations keep
    their original column names; intermediate columns are
    ``"<relation>.<column>"``, stamped by the materialize step that
    produced them).
    """

    op: str                              # "binary" | "fused3"
    out: str                             # "%i<k>" or COUNT
    inputs: tuple[str, ...]              # 2 (binary) or 3 (fused3) names
    preds: tuple[Predicate, ...]         # equality predicates among inputs
    aggregate: bool                      # root COUNT step vs materialize
    # binary materialize: per-input projection ((src col, dst col), ...) —
    # only the columns later steps read survive into the intermediate
    project: tuple = ()
    # fused3 bookkeeping: the classified kind, engine role -> input name,
    # engine col kwarg -> column key, and (optionally) a pre-sized shape
    # plan.  ``shape_plan is None`` means "size at execute time from the
    # live cardinalities" — the rule for steps that read intermediates.
    kind: str | None = None
    roles: tuple[tuple[str, str], ...] = ()
    cols: tuple[tuple[str, str], ...] = ()
    shape_plan: object | None = None
    recovery: bool = True                # fused3 steps run skew recovery
    choice: object | None = None         # planner.TimedChoice, if one ran
    est_rows: tuple[int, ...] = ()       # plan-time input-card estimates
    est_out: int | None = None           # plan-time output-rows estimate
    # fused3 root only: per-R group counts requested, keyed by this column
    # of the role-r input — the executor answers through the recovery
    # engine's per-R rounds and surfaces PlanExecResult.per_r
    per_r_key: str | None = None

    def describe(self) -> str:
        if self.op == "fused3":
            ins = ", ".join(self.inputs)
            per_r = (f", per_r[{self.per_r_key}]" if self.per_r_key
                     else "")
            return (f"{self.out} <- fused3[{self.kind}"
                    f"{', recovery' if self.recovery else ''}{per_r}]"
                    f"({ins})")
        (p,) = self.preds
        verb = "count" if self.aggregate else "join"
        est = "" if self.est_out is None else f"  [~{self.est_out} rows]"
        return (f"{self.out} <- binary-{verb}({self.inputs[0]} ⋈ "
                f"{self.inputs[1]} on {p.left[1]} = {p.right[1]}){est}")


@dataclasses.dataclass(frozen=True)
class QueryPlan:
    """A DAG of :class:`PlanStep` in topological order, plus the engine
    configuration every step shares.  This object is what the session's
    plan cache stores: it references relations by NAME only, so a cached
    plan re-executes against refreshed data of similar size."""

    steps: tuple[PlanStep, ...]
    n_relations: int
    kind: str                # classified kind of the (root) frontier
    strategy: str            # "3way" | "cascade" | "hybrid"
    m_budget: int | None = None
    use_kernel: bool = False
    max_rounds: int = 3
    growth: float = 2.0
    base_salt: int = 0

    @property
    def fused3_steps(self) -> tuple[PlanStep, ...]:
        return tuple(s for s in self.steps if s.op == "fused3")

    @property
    def root(self) -> PlanStep:
        return self.steps[-1]

    def describe(self) -> str:
        head = (f"QueryPlan[{self.n_relations} relations, kind={self.kind}, "
                f"strategy={self.strategy}]")
        return "\n".join([head] + ["  " + s.describe() for s in self.steps])


class StepStats(NamedTuple):
    """Per-step execution record (aggregated onto the QueryResult).

    ``exec_s`` is the host time the executor's loop spent on the step —
    under async dispatch that is mostly the blocking two-scalar total
    sync, NOT the device work.  ``dispatch_s`` is the slice of it spent
    enqueueing the step's compiled calls (stage + gather).  ``wall_s`` is
    the step's start-to-buffers-ready wall time and is only populated
    when ``execute_plan(..., profile=True)`` blocks per step — it is 0.0
    on the overlapped default path, where per-step wall time is not a
    well-defined quantity."""

    op: str
    out: str
    rows: int                # materialized rows, or the aggregated count
    rounds: int              # recovery rounds (0 for binary steps)
    tuples_read: int
    exec_s: float
    dispatch_s: float = 0.0  # host time enqueueing compiled calls
    wall_s: float = 0.0      # blocked wall time (profile=True only)


class PlanExecResult(NamedTuple):
    count: int
    overflowed: bool         # False by construction (see execute_plan)
    tuples_read: int         # summed over steps (intermediates counted as
    rounds: int              # written once + read once, like §6.3)
    step_stats: tuple
    per_r: recovery.PerRResult | None = None  # root per-R group counts
    # keep_intermediates=True only: the materialized %i<k> Relations, kept
    # resident instead of arena-dropped (standing queries refresh these
    # incrementally on ingest)
    intermediates: dict | None = None


def _step_keys(step: PlanStep) -> tuple[str, str]:
    """The (left-input, right-input) join column keys of a binary step."""
    (pred,) = step.preds
    if pred.left[0] == step.inputs[0]:
        return pred.left[1], pred.right[1]
    return pred.right[1], pred.left[1]


def _project(rel: Relation, mapping) -> Relation:
    if not mapping:
        return rel
    return Relation({dst: rel.columns[src] for src, dst in mapping},
                    rel.valid)


class _Staged(NamedTuple):
    """A binary step whose stage-1 pipeline (sort + ranges + exact total)
    has been dispatched.  The inputs are captured here — once every
    consumer of an intermediate holds its capture, the arena drops the
    intermediate from the environment."""

    staged: binary_join.StagedJoin
    probe: Relation            # projected probe side (stage 2 reads it)
    na: object                 # device scalars: live input cardinalities
    nb: object                 # (synced with the total, not eagerly)
    dispatch_s: float


def _stage_binary(step: PlanStep, env) -> _Staged:
    """Dispatch stage 1 of a binary step (one compiled call, async)."""
    a, b = env[step.inputs[0]], env[step.inputs[1]]
    proj_a, proj_b = step.project if step.project else ((), ())
    a2, b2 = _project(a, proj_a), _project(b, proj_b)
    ka, kb = _step_keys(step)
    t0 = time.perf_counter()
    st = binary_join.stage_join(a2, b2, build_key=ka, probe_key=kb)
    return _Staged(st, b2, a.n, b.n, time.perf_counter() - t0)


def _run_fused3(step: PlanStep, plan: QueryPlan, env):
    """Execute a fused 3-way step through the recovery-wrapped engine.
    ``shape_plan is None`` sizes the partition shape here, from the LIVE
    input cardinalities (the inputs may be just-materialized
    intermediates whose sizes no plan-time estimate pinned down).  A
    ``per_r_key`` stamp routes the step through the per-R recovery
    rounds instead of the scalar count — returns a PerRResult then."""
    rels = {role: env[name] for role, name in step.roles}
    r, s, t = rels["r"], rels["s"], rels["t"]
    eng = engine.MultiwayJoinEngine(
        step.kind, use_kernel=plan.use_kernel, max_rounds=plan.max_rounds,
        growth=plan.growth, base_salt=plan.base_salt)
    shape = step.shape_plan
    if shape is None:
        shape = eng.default_plan(int(r.n), int(s.n), int(t.n),
                                 m_budget=plan.m_budget)
    if step.per_r_key is not None:
        if step.kind != "linear":
            raise PlanPerRError(
                "per-R fused steps must be linear; planner emitted kind "
                f"{step.kind!r}", step=step)
        return recovery.run_per_r_rounds(
            recovery.LinearOps(**dict(step.cols)), r, s, t, shape,
            max_rounds=plan.max_rounds, growth=plan.growth,
            use_kernel=plan.use_kernel, base_salt=plan.base_salt,
            key_col=step.per_r_key)
    return eng.count(r, s, t, shape, **dict(step.cols))


def execute_plan(plan: QueryPlan, relations: Mapping[str, Relation], *,
                 profile: bool = False,
                 keep_intermediates: bool = False) -> PlanExecResult:
    """Walk the DAG: materialize intermediates, aggregate at the root.

    Device-resident and overlapped: every binary step is two compiled
    dispatches (stage: sort + match ranges + exact two-limb total;
    gather: prefix-sum offsets + materialize into a log-bucketed static
    capacity), and before blocking on a step's two-scalar total the
    executor dispatches stage 1 of every later binary step whose inputs
    are already live — independent DAG branches overlap under JAX async
    dispatch, and the fused root's recovery rounds queue behind
    still-in-flight gathers instead of waiting for them.  A refcounting
    arena drops each ``%i<k>`` intermediate from the environment as soon
    as its last consumer has captured it, so donated gather buffers can
    be reused.

    ``overflowed == False`` is a postcondition of the whole walk: binary
    materialize steps are exact-sized on device (the gather capacity
    covers the exact total), binary aggregates are exact two-limb int64
    sums, and fused steps inherit the recovery engine's exact-histogram
    final round.

    ``profile=True`` blocks on each step's output buffers and fills
    ``StepStats.wall_s`` — attribution mode for benches; it serializes
    the overlap, so leave it off on the hot path.

    ``keep_intermediates=True`` disables the arena drop and returns every
    materialized ``%i<k>`` on ``PlanExecResult.intermediates`` — the
    standing-query path, which keeps them resident and refreshes them
    incrementally on ingest instead of recomputing.
    """
    if os.environ.get("REPRO_VERIFY_PLANS", "") not in ("", "0"):
        # execute-time re-verification: static checks against the live
        # environment plus width analysis over the live cardinalities
        from repro.analysis import verify_plan as _verify
        from repro.analysis import widths as _widths
        _verify.verify_plan(plan, external=set(relations))
        _widths.check_widths(
            plan, {name: int(rel.n) for name, rel in relations.items()})

    steps = plan.steps
    env: dict[str, Relation] = dict(relations)
    # arena refcounts: consumers left per environment name (base relations
    # are caller-owned and never dropped; every %i<k> is dropped at zero)
    readers: dict[str, int] = {}
    for s in steps:
        for n in s.inputs:
            readers[n] = readers.get(n, 0) + 1
    shadow = arena_sanitizer.begin(plan, relations, keep_intermediates)

    def release(name: str) -> None:
        if shadow is not None:
            shadow.on_release(name)
        readers[name] -= 1
        if (readers[name] == 0 and name.startswith("%")
                and not keep_intermediates):
            if shadow is not None:
                shadow.on_drop(name)
            env.pop(name, None)

    staged: dict[int, _Staged] = {}

    def stage_ready(start: int) -> None:
        # dispatch stage 1 of every not-yet-staged later binary step whose
        # inputs are live — this is the overlap: it runs BEFORE the
        # executor blocks on the current step's total
        for j in range(start, len(steps)):
            s = steps[j]
            if (j not in staged and s.op == "binary"
                    and all(n in env for n in s.inputs)):
                staged[j] = _stage_binary(s, env)
                for n in s.inputs:
                    release(n)

    total_tuples = 0
    rounds = 0
    count = 0
    per_r = None
    stats: list[StepStats] = []
    for i, step in enumerate(steps):
        t0 = time.perf_counter()
        if step.op == "binary":
            stage_ready(i)
            sg = staged.pop(i)
            dispatch_s = sg.dispatch_s
            total = binary_join.staged_total(sg.staged)  # sync: 2 scalars
            tuples = int(sg.na) + int(sg.nb)
            if step.aggregate:
                count = total
                out = None
            else:
                if total >= 2**31:
                    raise PlanWidthError(
                        f"intermediate {step.out} has {total} rows — too "
                        "large to materialize; re-plan with "
                        "strategy='3way' (the fused 3-way engine never "
                        "materializes the join output)", step=step)
                cap = binary_join.bucket_capacity(total)
                t_d = time.perf_counter()
                out = binary_join.gather_staged(sg.staged, sg.probe, cap)
                dispatch_s += time.perf_counter() - t_d
                if shadow is not None:
                    shadow.on_produce(step.out)
                env[step.out] = out
                tuples += total               # intermediate written once
                # producing %i<k> may unblock dependent steps: overlap
                # their stage 1 with this gather already in flight
                stage_ready(i + 1)
            if profile and out is not None:
                jax.block_until_ready(out)
            rows = count if step.aggregate else total
            total_tuples += tuples
            stats.append(StepStats(
                "binary", step.out, rows, 0, tuples,
                time.perf_counter() - t0, dispatch_s,
                (time.perf_counter() - t0) if profile else 0.0))
        elif step.op == "fused3":
            if not step.aggregate:
                raise PlanStructureError(
                    "fused3 steps aggregate (the engine never materializes "
                    f"its output); step {step.out!r} tries to materialize",
                    step=step)
            res = _run_fused3(step, plan, env)
            for n in step.inputs:
                release(n)
            if step.per_r_key is not None:
                per_r = res
            count = int(res.count)
            total_tuples += int(res.tuples_read)
            rounds += int(res.rounds)
            stats.append(StepStats(
                "fused3", step.out, count, int(res.rounds),
                int(res.tuples_read), time.perf_counter() - t0, 0.0,
                (time.perf_counter() - t0) if profile else 0.0))
        else:
            raise PlanStructureError(f"unknown plan-step op {step.op!r}",
                                     step=step)
    overflowed = bool(per_r.overflowed) if per_r is not None else False
    if shadow is not None:
        shadow.finish(env)
    inter = None
    if keep_intermediates:
        inter = {s.out: env[s.out] for s in steps
                 if s.op == "binary" and not s.aggregate and s.out in env}
    return PlanExecResult(int(count), overflowed, int(total_tuples),
                          max(rounds, 1), tuple(stats), per_r, inter)


def result_as_engine(res: PlanExecResult) -> engine.EngineResult:
    """Repackage a plan walk as the legacy EngineResult contract."""
    import jax.numpy as jnp
    return engine.EngineResult(np.int64(res.count), jnp.asarray(False),
                               np.int64(res.tuples_read), res.rounds)
