"""Standing queries: exact incremental counts under continuous ingest.

A production join service does not re-count from scratch on every append —
that throws away exactly the per-step intermediate materialization the plan
IR tracks.  :class:`StandingQuery` (registered through
``JoinSession.watch(query)``) keeps the standing plan's binary-step
intermediates (``%i<k>``) resident in the executor's arena and, on
``Relation.append(delta)``, executes only the *delta plan*:

  * **Delta rule.**  With one relation X changed by ΔX, the count delta of
    the whole multiway join is the same join with X replaced by ΔX and
    every other input at its current value.  Along the standing plan this
    touches exactly the path from X's leaf to the root: each step on the
    path joins its Δ-input against the *resident* value of its sibling
    (a kept-hot ``%i<k>`` or a base relation) — siblings off the path are
    never recomputed.
  * **Same machinery.**  The delta plan is the standing plan's path steps
    with the Δ-carrying input renamed (``%d·<name>``) and re-executed
    through the very same ``plan_ir.execute_plan``; binary materialize
    steps append-merge their Δ-output into the resident intermediate
    (``Relation.append`` — log-bucketed capacities keep the compiled
    shapes stable), and the fused root re-runs recovery-wrapped over only
    the hash-families the delta's histogram actually touches (sibling
    rows hashing to untouched families cannot match any delta row, so
    they are masked out before the engine sizes its partitions).
  * **Drift → re-plan.**  Each ingest re-derives the plan through the
    session's log-bucketed plan cache: ±5% drift maps to the same bucket
    and keeps the standing plan (and its residents); a ≥4x resize misses
    the cache, and the fresh plan triggers a full refresh.  FM sketches on
    each Relation update incrementally inside ``append`` itself, so a
    re-plan always sees current distinct estimates without a host scan.

``overflowed == False`` holds per delta round (every delta run inherits
the recovery engine's exact-histogram final round), and all totals
accumulate in host Python ints (int64-exact under unbounded ingest).
"""

from __future__ import annotations

import dataclasses
import time

import jax.numpy as jnp
import numpy as np

from repro.analysis import arena_sanitizer
from repro.core import plan_ir
from repro.core.plan_ir import COUNT, PlanStep, QueryPlan
from repro.core.query import Predicate, Query
from repro.core.relation import Relation

# Family-masking geometry: the delta's join keys are histogrammed into
# N_FAMILIES hash families; sibling rows outside the touched set are masked
# before the fused root sizes its partitions.  Masking is skipped when the
# delta touches more than MASK_SKIP_FRACTION of the families (nothing to
# save) — correctness never depends on it.
N_FAMILIES = 4096
MASK_SKIP_FRACTION = 0.5
_MASK_SALT = 0x5EED


def _dname(name: str) -> str:
    """Environment name of a delta value (delta plans rename the
    Δ-carrying input so the resident/base value stays addressable)."""
    return f"%d·{name}"


def _pow2(n: int) -> int:
    """Round a live cardinality up to its power-of-two bucket — the shape
    quantization that keeps delta-plan compilations stable across steady
    ingest (recovery absorbs any under-sizing exactly)."""
    return 1 << max(0, int(n) - 1).bit_length()


def touched_families(delta: Relation, col: str,
                     n_families: int = N_FAMILIES) -> jnp.ndarray:
    """Boolean histogram of the hash families the delta's keys touch."""
    from repro.core import hashing
    ids = hashing.hash_bucket(delta.col(col), n_families, "H", _MASK_SALT)
    ids = jnp.where(delta.valid, ids, jnp.int32(n_families))
    return jnp.zeros((n_families,), bool).at[ids].set(True, mode="drop")


def mask_to_families(rel: Relation, col: str, touched: jnp.ndarray
                     ) -> Relation:
    """Mask ``rel`` to the rows whose ``col`` hashes into a touched
    family.  Exact for equality joins: an untouched-family row cannot
    match any delta key (same hash function, same salt)."""
    n_families = touched.shape[0]
    if int(touched.sum()) > n_families * MASK_SKIP_FRACTION:
        return rel
    from repro.core import hashing
    ids = hashing.hash_bucket(rel.col(col), n_families, "H", _MASK_SALT)
    return rel.mask_where(touched[jnp.clip(ids, 0, n_families - 1)])


@dataclasses.dataclass(frozen=True)
class DeltaRecord:
    """One ingest round of a standing query (``StandingQuery.delta_rounds``)."""

    relation: str            # which base relation took the append
    delta_rows: int          # rows in the delta batch
    count_delta: int         # exact contribution to the standing count
    overflowed: bool         # False by construction (recovery contract)
    rounds: int              # recovery rounds of the delta run
    tuples_read: int         # delta-run traffic
    replanned: bool          # drift forced a full re-plan + refresh
    exec_s: float            # host seconds for the delta run


class StandingQuery:
    """A registered standing query: exact count kept fresh under ingest.

    Create through :meth:`JoinSession.watch`.  ``snapshot()`` answers with
    the same :class:`~repro.core.session.QueryResult` type as
    ``JoinSession.execute``; ``delta_rounds`` records every ingest.
    ``close()`` deregisters the append observers.
    """

    def __init__(self, session, query: Query, *,
                 m_budget: int | None = None, strategy: str | None = None):
        self._sess = session
        self.query = query
        self._m_budget = session.m_budget if m_budget is None else m_budget
        self._strategy = strategy
        self._plan: QueryPlan | None = None
        self._intermediates: dict[str, Relation] = {}
        self._versions: dict[str, int] = {}
        self._delta_shapes: dict = {}
        self._count = 0
        self._tuples = 0
        self._rounds = 0
        self._last_steps: tuple = ()
        self._last_plan_s = 0.0
        self._last_exec_s = 0.0
        self._last_cache_hit = False
        self._closed = False
        self.delta_rounds: list[DeltaRecord] = []
        seen: list[int] = []
        for rel in query.relations.values():
            if id(rel) not in seen:
                seen.append(id(rel))
                rel.on_append(self._on_append)
        self.refresh()

    # -- lifecycle ---------------------------------------------------------

    def close(self) -> None:
        """Deregister the append observers; the handle goes inert."""
        if self._closed:
            return
        self._closed = True
        seen: list[int] = []
        for rel in self.query.relations.values():
            if id(rel) not in seen:
                seen.append(id(rel))
                rel.remove_on_append(self._on_append)

    # -- planning ----------------------------------------------------------

    def _plan_now(self) -> tuple[QueryPlan, bool]:
        cards = {nm: int(rel.n)
                 for nm, rel in self.query.relations.items()}
        return self._sess._plan(self.query, cards, self._m_budget,
                                self._strategy, None)

    # -- full (re)execution ------------------------------------------------

    def refresh(self) -> None:
        """Execute the standing plan from scratch, keeping every binary
        step's materialized intermediate resident.  Runs at registration
        and whenever drift re-plans (or the delta rule cannot apply —
        e.g. an appended relation bound under several names)."""
        t0 = time.perf_counter()
        qp, hit = self._plan_now()
        plan_s = time.perf_counter() - t0
        t1 = time.perf_counter()
        res = plan_ir.execute_plan(qp, dict(self.query.relations),
                                   keep_intermediates=True)
        self._last_exec_s = time.perf_counter() - t1
        self._plan = qp
        self._intermediates = dict(res.intermediates or {})
        # sanitizer (opt-in): the residents must be exactly the plan's
        # materialized outs — a divergence here means later delta rounds
        # would join against stale or missing intermediates
        arena_sanitizer.check_residents(qp, self._intermediates)
        self._delta_shapes.clear()
        self._count = int(res.count)
        self._tuples += int(res.tuples_read)
        self._rounds += int(res.rounds)
        self._last_steps = res.step_stats
        self._last_plan_s = plan_s
        self._last_cache_hit = hit
        self._versions = {nm: rel.version
                          for nm, rel in self.query.relations.items()}

    # -- ingest ------------------------------------------------------------

    def _on_append(self, rel: Relation, delta: Relation) -> None:
        if self._closed:
            return
        names = [nm for nm, rr in self.query.relations.items()
                 if rr is rel]
        if not names:      # observer outlived a rebinding; nothing to do
            return
        t0 = time.perf_counter()
        if len(names) > 1:
            # the delta rule needs single occurrence (a self-join delta has
            # cross terms); fall back to a full refresh — still exact
            self.refresh()
            self.delta_rounds.append(DeltaRecord(
                relation=names[0], delta_rows=int(delta.n),
                count_delta=0, overflowed=False, rounds=0,
                tuples_read=0, replanned=True,
                exec_s=time.perf_counter() - t0))
            return
        self._delta_update(names[0], delta, t0)

    def _delta_update(self, name: str, delta: Relation,
                      t0: float) -> None:
        qp, _hit = self._plan_now()
        if qp is not self._plan:
            # log-bucketed cache key moved (≥4x-scale drift): the session
            # re-planned, residents match the OLD plan — full refresh
            self.refresh()
            self.delta_rounds.append(DeltaRecord(
                relation=name, delta_rows=int(delta.n), count_delta=0,
                overflowed=False, rounds=0, tuples_read=0,
                replanned=True, exec_s=time.perf_counter() - t0))
            return
        has_resident = any(s.op == "binary" and not s.aggregate
                           for s in self._plan.steps)
        if not has_resident and self._plan.kind != "cyclic":
            # single-root standing plan, nothing resident to refresh: the
            # cheapest exact delta is the all-binary cascade planned at
            # the DELTA's cardinality (same plan_query machinery, cached
            # in the session under the delta's log bucket) — a tiny build
            # side and one staged probe pass per sibling, no partition
            # sweep at all
            res = self._delta_exec_cascade(name, delta)
        else:
            dsteps, env, outs = self._delta_steps(name, delta)
            dplan = QueryPlan(
                steps=tuple(dsteps), n_relations=self._plan.n_relations,
                kind=self._plan.kind, strategy=self._plan.strategy,
                m_budget=self._plan.m_budget,
                use_kernel=self._plan.use_kernel,
                max_rounds=self._plan.max_rounds, growth=self._plan.growth,
                base_salt=self._plan.base_salt)
            res = plan_ir.execute_plan(dplan, env, keep_intermediates=True)
            rows = {st.out: st.rows for st in res.step_stats}
            for delta_out, orig_out in outs.items():
                self._merge_intermediate(
                    orig_out, (res.intermediates or {})[delta_out],
                    rows.get(delta_out, 0))
            arena_sanitizer.check_residents(self._plan,
                                            self._intermediates)
        self._count += int(res.count)
        self._tuples += int(res.tuples_read)
        self._rounds += int(res.rounds)
        self._last_steps = res.step_stats
        self._last_exec_s = time.perf_counter() - t0
        self._versions = {nm: rel.version
                          for nm, rel in self.query.relations.items()}
        self.delta_rounds.append(DeltaRecord(
            relation=name, delta_rows=int(delta.n),
            count_delta=int(res.count), overflowed=bool(res.overflowed),
            rounds=int(res.rounds), tuples_read=int(res.tuples_read),
            replanned=False, exec_s=time.perf_counter() - t0))

    def _delta_exec_cascade(self, name: str, delta: Relation):
        """Delta execution for single-root standing plans: plan the same
        query as an all-binary cascade with the delta's cardinality in
        ``name``'s slot (the session caches it under the delta's log
        bucket, so steady ingest re-plans nothing) and execute with the
        delta substituted for the base relation."""
        cards = {nm: int(rel.n) for nm, rel in self.query.relations.items()}
        cards[name] = max(1, int(delta.n))
        dqp, _ = self._sess._plan(self.query, cards, self._m_budget,
                                  "cascade", None)
        env = dict(self.query.relations)
        env[name] = delta
        return plan_ir.execute_plan(dqp, env)

    def _delta_steps(self, name: str, delta: Relation):
        """Build the delta plan: the standing plan's steps on the path
        from ``name``'s leaf to the root, Δ-carrying inputs renamed, plus
        the execution environment (base relations + resident
        intermediates + the delta + family-masked siblings)."""
        env: dict[str, Relation] = dict(self.query.relations)
        env.update(self._intermediates)
        env[_dname(name)] = delta
        # family masking, two hops out from the delta: first every base
        # sibling sharing an equality predicate with the delta relation
        # shrinks to the delta's touched hash families, then each MASKED
        # sibling's own touched families shrink ITS other neighbors (a
        # masked sibling keeps a superset of the rows reaching the delta,
        # so its family histogram over the shared column bounds what the
        # next hop can match — still exact, see mask_to_families)
        sources: dict[str, Relation] = {name: delta}
        for _hop in range(2):
            nxt: dict[str, Relation] = {}
            for a, src in sources.items():
                for pred in self.query.predicates:
                    for (x, xcol), (y, ycol) in ((pred.left, pred.right),
                                                 (pred.right, pred.left)):
                        if (x == a and y != name and y in env
                                and y not in sources and y not in nxt):
                            m = mask_to_families(
                                env[y], ycol, touched_families(src, xcol))
                            if m is not env[y]:
                                env[y] = m
                                nxt[y] = m
            if not nxt:
                break
            sources = nxt
        deltas = {name}
        rename = {name: _dname(name)}
        # Δ-size estimates for inputs that only exist at execution time:
        # a delta intermediate is roughly its resident's rows scaled by the
        # delta fraction (recovery absorbs under-sizing exactly, so these
        # only steer partition sizing, never correctness)
        base_n = max(1, int(self.query.relations[name].n))
        frac = min(1.0, int(delta.n) / base_n)
        est: dict[str, int] = {_dname(name): int(delta.n)}
        out_steps: list[PlanStep] = []
        outs: dict[str, str] = {}      # delta out -> resident out
        for step in self._plan.steps:
            carrying = [i for i in step.inputs if i in deltas]
            if not carrying:
                continue               # off-path: resident value stands
            inputs = tuple(rename.get(i, i) for i in step.inputs)
            preds = tuple(
                Predicate((rename.get(p.left[0], p.left[0]), p.left[1]),
                          (rename.get(p.right[0], p.right[0]), p.right[1]))
                for p in step.preds)
            if step.op == "binary":
                if step.aggregate:
                    out = COUNT
                else:
                    out = _dname(step.out)
                    deltas.add(step.out)
                    rename[step.out] = out
                    outs[out] = step.out
                    resident = self._intermediates.get(step.out)
                    full = int(resident.n) if resident is not None else base_n
                    est[out] = max(64, int(full * frac * 2))
                out_steps.append(dataclasses.replace(
                    step, out=out, inputs=inputs, preds=preds))
            else:
                roles = tuple((role, rename.get(nm, nm))
                              for role, nm in step.roles)
                shape = self._delta_shape(step, roles, env, est)
                out_steps.append(dataclasses.replace(
                    step, inputs=inputs, preds=preds, roles=roles,
                    shape_plan=shape))
        return out_steps, env, outs

    def _delta_shape(self, step: PlanStep, roles, env, est):
        """Pre-size the delta fused root from power-of-two-bucketed live
        cardinalities (Δ-inputs not yet materialized use the ``est``
        scaled estimates), cached per bucket tuple: steady same-size
        deltas reuse one compiled shape instead of re-jitting every round
        (recovery absorbs the quantized sizing exactly)."""
        from repro.core import engine
        role_map = dict(roles)
        cards = tuple(
            _pow2(max(1, est[nm] if nm in est else int(env[nm].n)))
            for nm in (role_map[k] for k in ("r", "s", "t")))
        key = (step.kind, cards, self._plan.m_budget)
        shape = self._delta_shapes.get(key)
        if shape is None:
            eng = engine.MultiwayJoinEngine(step.kind)
            shape = eng.default_plan(*cards, m_budget=self._plan.m_budget)
            self._delta_shapes[key] = shape
        return shape

    def _merge_intermediate(self, orig_out: str, delta_rel: Relation,
                            rows: int) -> None:
        """Append-merge a binary step's Δ-output into the resident
        intermediate.  Gather outputs are valid-prefix Relations, so the
        merge is a static slice + ``Relation.append``."""
        if rows <= 0:
            return
        resident = self._intermediates.get(orig_out)
        if resident is None:       # plan had no materialize step resident
            if arena_sanitizer.active() and orig_out.startswith("%"):
                raise arena_sanitizer.ArenaSanitizerError(
                    f"arena shadow: delta merge targets {orig_out!r} but "
                    "no resident intermediate exists — the standing "
                    "plan's residents leaked or were never kept")
            return
        resident.append({c: v[:rows]
                         for c, v in delta_rel.columns.items()})

    # -- answers -----------------------------------------------------------

    @property
    def count(self) -> int:
        return self._count

    def snapshot(self):
        """The standing answer, as the same ``QueryResult`` type
        ``JoinSession.execute`` returns.  ``tuples_read``/``rounds``
        accumulate over the standing query's whole life (int64-exact)."""
        from repro.core.session import QueryResult
        stale = any(rel.version != self._versions.get(nm)
                    for nm, rel in self.query.relations.items())
        if stale:                  # out-of-band change: re-anchor exactly
            self.refresh()
        return QueryResult(
            count=np.int64(self._count), overflowed=False,
            tuples_read=np.int64(self._tuples),
            rounds=max(self._rounds, 1), steps=self._last_steps,
            kind=self._plan.kind, strategy=self._plan.strategy,
            cache_hit=self._last_cache_hit, plan_s=self._last_plan_s,
            exec_s=self._last_exec_s, plan=self._plan)
