"""Fused engine vs scan-based driver on the Fig 4 workload shapes.

Measures the tentpole claim of the engine PR: sweeping the H(B)×g(C)
partition grid as ONE fused launch (``core.engine.*_count_fused``) beats the
nested-``lax.scan`` per-bucket-row drivers (``core.linear3`` etc.) — the
same partitioning, the same per-bucket math, only the launch structure
differs.  Shapes are the paper's Fig 4 workloads (e,f: linear self-join;
g,h,i: star; plus the §5 triangle query), scaled to CPU-benchable sizes with
the partition counts preserved (tens of coarse partitions, so the scan
driver pays hundreds of sequential steps).

Both sides run the compiled XLA path (``use_kernel=False``) so the
comparison is launch-structure vs launch-structure, not interpreter
overhead.  Results go to BENCH_engine.json (CI uploads it every run —
the perf trajectory record).

    PYTHONPATH=src python benchmarks/engine_bench.py [--quick] [--repeats N]
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent
                       / "src"))

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import cyclic3, engine, linear3, plan_ir, star3  # noqa: E402
from repro.core.query import Query  # noqa: E402
from repro.core.relation import Relation  # noqa: E402
from repro.core.session import JoinSession  # noqa: E402
from repro.perfmodel import Calibration, calibrate  # noqa: E402

OUT = pathlib.Path("BENCH_engine.json")
STEPS_OUT = pathlib.Path("BENCH_plan_steps.json")
CAL_OUT = pathlib.Path(calibrate.CALIBRATION_FILE)


def _rel(rng, n, cols, d):
    return Relation.from_arrays(
        **{c: rng.integers(0, d, size=n).astype(np.int32) for c in cols})


def _time(fn, *args, repeats: int) -> float:
    """Best-of-N wall time in ms for an already-jitted callable."""
    jax.block_until_ready(fn(*args))          # compile + warm caches
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best * 1e3


def bench_linear(rng, n, d, m_budget, u, repeats):
    r = _rel(rng, n, ("a", "b"), d)
    s = _rel(rng, n, ("b", "c"), d)
    t = _rel(rng, n, ("c", "d"), d)
    plan = linear3.default_plan(n, n, n, m_budget=m_budget, u=u, slack=3.0)
    scan_fn = jax.jit(lambda a, b, c: linear3.linear3_count(a, b, c, plan))
    fused_fn = jax.jit(
        lambda a, b, c: engine.linear3_count_fused(a, b, c, plan))
    scan_ms = _time(scan_fn, r, s, t, repeats=repeats)
    fused_ms = _time(fused_fn, r, s, t, repeats=repeats)
    c0, c1 = int(scan_fn(r, s, t).count), int(fused_fn(r, s, t).count)
    return {"n": n, "d": d, "h_parts": plan.h_parts, "g_parts": plan.g_parts,
            "u": plan.u, "scan_ms": scan_ms, "fused_ms": fused_ms,
            "speedup": scan_ms / fused_ms, "count_scan": c0,
            "count_fused": c1, "match": c0 == c1}


def bench_cyclic(rng, n, d, m_budget, repeats):
    """Cyclic (triangle) query: the fused path now probes a sorted
    (c, a)-pair index of T (searchsorted range scans) instead of the
    all-pairs contraction — the backend that unsticks the ~1x cyclic CPU
    number.  The scan driver defaults to the pair index too now, so the
    GATED ``speedup`` pins ``pair_index=False`` to keep its historical
    all-pairs-scan-baseline semantics (the committed ratio stays
    comparable); the pair-index scan is recorded separately
    (``scan_pairidx_ms`` / ``speedup_vs_pairidx_scan``, not gated)."""
    r = _rel(rng, n, ("a", "b"), d)
    s = _rel(rng, n, ("b", "c"), d)
    t = _rel(rng, n, ("c", "a"), d)
    plan = cyclic3.default_plan(n, n, n, m_budget=m_budget, uh=4, ug=4,
                                slack=3.0)
    scan_fn = jax.jit(lambda a, b, c: cyclic3.cyclic3_count(
        a, b, c, plan, pair_index=False))
    scan_pi_fn = jax.jit(
        lambda a, b, c: cyclic3.cyclic3_count(a, b, c, plan))
    fused_fn = jax.jit(
        lambda a, b, c: engine.cyclic3_count_fused(a, b, c, plan))
    allpairs_fn = jax.jit(
        lambda a, b, c: engine.cyclic3_count_fused(a, b, c, plan,
                                                   pair_index=False))
    scan_ms = _time(scan_fn, r, s, t, repeats=repeats)
    scan_pi_ms = _time(scan_pi_fn, r, s, t, repeats=repeats)
    fused_ms = _time(fused_fn, r, s, t, repeats=repeats)
    allpairs_ms = _time(allpairs_fn, r, s, t, repeats=repeats)
    c0, c1 = int(scan_fn(r, s, t).count), int(fused_fn(r, s, t).count)
    c2 = int(allpairs_fn(r, s, t).count)
    c3 = int(scan_pi_fn(r, s, t).count)
    return {"n": n, "d": d, "h_parts": plan.h_parts, "g_parts": plan.g_parts,
            "f_parts": plan.f_parts, "scan_ms": scan_ms,
            "scan_pairidx_ms": scan_pi_ms,
            "fused_ms": fused_ms, "fused_allpairs_ms": allpairs_ms,
            "speedup": scan_ms / fused_ms,
            "speedup_vs_pairidx_scan": scan_pi_ms / fused_ms,
            "count_scan": c0, "count_fused": c1,
            "match": c0 == c1 == c2 == c3}


def bench_star(rng, n_dim, n_fact, d, chunks, repeats):
    r = _rel(rng, n_dim, ("a", "b"), d)
    s = _rel(rng, n_fact, ("b", "c"), d)
    t = _rel(rng, n_dim, ("c", "d"), d)
    plan = star3.default_plan(n_dim, n_fact, n_dim, uh=8, ug=8,
                              chunks=chunks, slack=3.0)
    scan_fn = jax.jit(lambda a, b, c: star3.star3_count(a, b, c, plan))
    fused_fn = jax.jit(
        lambda a, b, c: engine.star3_count_fused(a, b, c, plan))
    scan_ms = _time(scan_fn, r, s, t, repeats=repeats)
    fused_ms = _time(fused_fn, r, s, t, repeats=repeats)
    c0, c1 = int(scan_fn(r, s, t).count), int(fused_fn(r, s, t).count)
    return {"n_dim": n_dim, "n_fact": n_fact, "d": d, "chunks": chunks,
            "scan_ms": scan_ms, "fused_ms": fused_ms,
            "speedup": scan_ms / fused_ms, "count_scan": c0,
            "count_fused": c1, "match": c0 == c1}


def bench_session_cache(rng, n, d, m_budget, repeats):
    """The declarative front door's plan cache: a cold ``execute`` pays
    classification + strategy/shape sizing (incl. a host-side distinct
    estimate), a warm one skips straight to the fused engine.  Gated on
    cached-plan behavior (warm must re-plan nothing), recorded as cold vs
    warm PLANNING milliseconds (execution time is identical by
    construction and noisy, so it is excluded from the gate)."""
    r = _rel(rng, n, ("a", "b"), d)
    s = _rel(rng, n, ("b", "c"), d)
    t = _rel(rng, n, ("c", "d"), d)
    q = Query(relations={"r": r, "s": s, "t": t},
              predicates=[("r.b", "s.b"), ("s.c", "t.c")])
    sess = JoinSession(m_budget=m_budget)
    cold = sess.execute(q)
    warm_plan_ms = float("inf")
    warm_hits = True
    for _ in range(max(repeats, 2)):
        w = sess.execute(q)
        warm_hits &= w.cache_hit
        warm_plan_ms = min(warm_plan_ms, w.plan_s * 1e3)
    return {"n": n, "d": d, "kind": cold.kind, "strategy": cold.strategy,
            "cold_plan_ms": cold.plan_s * 1e3,
            "warm_plan_ms": warm_plan_ms,
            "plan_speedup": cold.plan_s * 1e3 / max(warm_plan_ms, 1e-6),
            "count": int(cold.count), "warm_cache_hits": warm_hits,
            "match": warm_hits and int(w.count) == int(cold.count)}


def _chain4_query(rng, n, d):
    rels = {f"r{i + 1}": _rel(rng, n, cols, d)
            for i, cols in enumerate((("a", "b"), ("b", "c"), ("c", "d"),
                                      ("d", "e")))}
    preds = [("r1.b", "r2.b"), ("r2.c", "r3.c"), ("r3.d", "r4.d")]
    return Query(relations=rels, predicates=preds)


def bench_cascade_4way(rng, n, d, m_budget, repeats):
    """The N-way plan IR on a 4-relation chain, with Appendix-A time-model
    calibration closed into a loop:

    1. measure BOTH roots through the same executor — forced ``"3way"``
       (hybrid: binary materialize + fused recovery-wrapped root) gives
       ``fused_root_s``, forced ``"cascade"`` gives ``binary_tail_s`` (the
       two binary steps standing in for the root),
    2. read the UNCALIBRATED model totals off the default plan's root
       ``TimedChoice`` (``model_t3_s`` / ``model_tc_s``) — these four
       numbers are what ``perfmodel.calibration_from_bench`` re-anchors
       the constants from (they are committed in BENCH_engine.json),
    3. re-plan with that measured calibration and time the calibrated
       default.  The calibrated pick is the measured-faster root, so
       ``ir_vs_binary = allbinary_ms / ir_ms`` is >= 1.0 up to timer noise
       — when the calibrated planner picks the cascade itself the two
       plans are IDENTICAL and the ratio is exactly 1.0 by construction
       (recorded with ``same_plan``).  check_bench_regression.py gates
       ``ir_vs_binary >= 1.0``; ``match`` gates exact count agreement."""
    q = _chain4_query(rng, n, d)
    sess = JoinSession(m_budget=m_budget)
    cold = sess.execute(q)                      # decompose + compile
    model_t3_s = cold.plan.root.choice.t_3way_s
    model_tc_s = cold.plan.root.choice.t_cascade_s
    fused = sess.execute(q, strategy="3way")
    binary = sess.execute(q, strategy="cascade")
    fused_root_s = binary_tail_s = binary_ms = fused_ms = float("inf")
    for _ in range(max(repeats, 2)):
        wf = sess.execute(q, strategy="3way")
        fused_ms = min(fused_ms, wf.exec_s * 1e3)
        fused_root_s = min(fused_root_s, sum(
            s.exec_s for s in wf.step_stats if s.op == "fused3"))
        wb = sess.execute(q, strategy="cascade")
        binary_ms = min(binary_ms, wb.exec_s * 1e3)
        binary_tail_s = min(binary_tail_s, sum(
            s.exec_s for s in wb.step_stats[-2:]))

    cal = Calibration(
        fused3_scale=fused_root_s / max(model_t3_s, 1e-12),
        cascade_scale=binary_tail_s / max(model_tc_s, 1e-12),
        source="bench:cascade_4way (in-process)")
    csess = JoinSession(m_budget=m_budget, calibration=cal)
    calib = csess.execute(q)                    # calibrated re-plan
    # a calibrated cascade pick IS the forced-cascade plan (only the root
    # step's recorded TimedChoice differs) — the ratio is 1.0 by
    # construction, not worth measuring against timer jitter
    same_plan = calib.strategy == binary.strategy == "cascade"
    ir_ms = float("inf")
    for _ in range(max(repeats, 2)):
        w = csess.execute(q)
        ir_ms = min(ir_ms, w.exec_s * 1e3)
    ir_vs_binary = (1.0 if same_plan
                    else binary_ms / max(ir_ms, 1e-9))
    return {"n": n, "d": d, "n_relations": 4,
            "steps": len(calib.plan.steps),
            "fused3_steps": len(calib.plan.fused3_steps),
            "strategy": calib.strategy,
            "model_strategy": cold.strategy,
            "ir_ms": ir_ms, "allbinary_ms": binary_ms,
            "forced3way_ms": fused_ms,
            "ir_vs_binary": ir_vs_binary, "same_plan": same_plan,
            "fused_root_s": fused_root_s, "binary_tail_s": binary_tail_s,
            "model_t3_s": model_t3_s, "model_tc_s": model_tc_s,
            "fused3_scale": cal.fused3_scale,
            "cascade_scale": cal.cascade_scale,
            "count": int(calib.count),
            "match": (int(cold.count) == int(binary.count)
                      == int(fused.count) == int(calib.count)
                      and not cold.overflowed and not binary.overflowed
                      and not calib.overflowed
                      and len(cold.plan.steps) >= 2)}


def _tree6_query(rng, n, d):
    """Six relations, five edges, TWO independent branches meeting at a
    shared sink: r1-r2-r3 (chain) and r4-r5 (chain) both join r6.  The
    branches share no relation, so the overlapped executor can have one
    branch's gather in flight while it stages the other."""
    rels = {"r1": _rel(rng, n, ("a", "b"), d),
            "r2": _rel(rng, n, ("b", "c"), d),
            "r3": _rel(rng, n, ("c", "d"), d),
            "r4": _rel(rng, n, ("e", "f"), d),
            "r5": _rel(rng, n, ("f", "g"), d),
            "r6": _rel(rng, n, ("d", "g"), d)}
    preds = [("r1.b", "r2.b"), ("r2.c", "r3.c"), ("r4.f", "r5.f"),
             ("r3.d", "r6.d"), ("r5.g", "r6.g")]
    return Query(relations=rels, predicates=preds)


def _tree6_oracle(q) -> int:
    """Exact count of the 6-relation tree by numpy/dict weight backflow:
    per-row weights flow from the leaves (r1, r4) to the sink (r6)."""
    from collections import Counter, defaultdict

    def rows(name, col):
        rel = q.relations[name]
        return np.asarray(rel.col(col))[np.asarray(rel.valid)]

    def flow(keys, weights, probe):
        acc = defaultdict(int)
        for k, w in zip(keys.tolist(), weights.tolist()):
            acc[k] += w
        return np.array([acc.get(k, 0) for k in probe.tolist()], np.int64)

    w2 = np.array([Counter(rows("r1", "b").tolist()).get(k, 0)
                   for k in rows("r2", "b").tolist()], np.int64)
    w3 = flow(rows("r2", "c"), w2, rows("r3", "c"))
    w5 = np.array([Counter(rows("r4", "f").tolist()).get(k, 0)
                   for k in rows("r5", "f").tolist()], np.int64)
    w6 = (flow(rows("r3", "d"), w3, rows("r6", "d"))
          * flow(rows("r5", "g"), w5, rows("r6", "g")))
    return int(w6.sum())


def bench_plan_pipeline_6way(rng, n, d, m_budget, repeats):
    """The overlapped DAG executor on a 6-relation tree with two
    independent branches: the default (overlapped) walk is timed, then one
    ``profile=True`` walk blocks per step to attribute time
    (``StepStats.wall_s`` / ``dispatch_s`` — the per-step record CI
    uploads).  Gated on exact agreement with a numpy backflow oracle."""
    q = _tree6_query(rng, n, d)
    sess = JoinSession(m_budget=m_budget)
    cold = sess.execute(q)                      # decompose + compile
    exec_ms = float("inf")
    for _ in range(max(repeats, 2)):
        w = sess.execute(q)
        exec_ms = min(exec_ms, w.exec_s * 1e3)
    prof = plan_ir.execute_plan(cold.plan, dict(q.relations), profile=True)
    step_timings = [
        {"op": s.op, "out": s.out, "rows": int(s.rows),
         "exec_ms": s.exec_s * 1e3, "dispatch_ms": s.dispatch_s * 1e3,
         "wall_ms": s.wall_s * 1e3}
        for s in prof.step_stats]
    profile_ms = sum(s["wall_ms"] for s in step_timings)
    oracle = _tree6_oracle(q)
    return {"n": n, "d": d, "n_relations": 6,
            "steps": len(cold.plan.steps),
            "fused3_steps": len(cold.plan.fused3_steps),
            "strategy": cold.strategy,
            "exec_ms": exec_ms, "profile_ms": profile_ms,
            "step_timings": step_timings,
            "count": int(cold.count), "oracle_count": oracle,
            "match": (int(cold.count) == oracle == int(prof.count)
                      and not cold.overflowed
                      and len(cold.plan.steps) >= 4)}


def bench_execute_many(rng, n, d, m_budget, batch, repeats):
    """JoinSession.execute_many warm-cache amortization: a batch of
    structurally identical 4-way queries plans ONCE — every query after
    the first is a plan-cache hit (log-bucketed cardinality keys), so
    per-query planning cost collapses.  Gated on cache behavior + exact
    counts (match)."""
    q = _chain4_query(rng, n, d)
    sess = JoinSession(m_budget=m_budget)
    results = sess.execute_many([q] * batch)
    counts = {int(r.count) for r in results}
    cold_plan_ms = results[0].plan_s * 1e3
    warm_plan_ms = min(r.plan_s for r in results[1:]) * 1e3
    for _ in range(max(repeats - 1, 1)):
        again = sess.execute_many([q] * batch)
        warm_plan_ms = min(warm_plan_ms,
                           min(r.plan_s for r in again) * 1e3)
    return {"n": n, "d": d, "batch": batch,
            "cold_plan_ms": cold_plan_ms, "warm_plan_ms": warm_plan_ms,
            "plan_amortization": cold_plan_ms / max(warm_plan_ms, 1e-6),
            "warm_cache_hits": all(r.cache_hit for r in results[1:]),
            "count": int(results[0].count),
            "match": (len(counts) == 1
                      and all(r.cache_hit for r in results[1:]))}


def bench_streaming_ingest(rng, n, d, m_budget, delta_frac, deltas,
                           repeats):
    """Standing-query delta execution vs full re-execution at ingest.

    A watched linear 3-way query absorbs delta batches (``delta_frac`` of
    the base size, rotating over R/S/T) through the delta plan — resident
    intermediates + family-masked siblings — while the oracle side
    re-executes the whole query from scratch at the final state.  One
    warm-up ingest per relation compiles the delta shapes and is excluded
    from timing.  Gated on exact count match and the per-round
    ``overflowed == False`` recovery contract."""
    k = max(1, int(n * delta_frac))
    rels = {"R": _rel(rng, n, ("a", "b"), d),
            "S": _rel(rng, n, ("b", "c"), d),
            "T": _rel(rng, n, ("c", "e"), d)}
    schema = {"R": ("a", "b"), "S": ("b", "c"), "T": ("c", "e")}
    q = Query(rels, [("R.b", "S.b"), ("S.c", "T.c")])
    sq = JoinSession(m_budget=m_budget).watch(q)
    names = list(rels)

    def ingest(i):
        name = names[i % 3]
        batch = {c: rng.integers(0, d, k).astype(np.int32)
                 for c in schema[name]}
        t0 = time.perf_counter()
        rels[name].append(batch)
        return (time.perf_counter() - t0) * 1e3

    for i in range(3):                      # warm-up: compile delta shapes
        ingest(i)
    delta_ms = min(ingest(3 + i) for i in range(deltas))
    overflow_free = all(not r.overflowed for r in sq.delta_rounds)
    standing = int(sq.snapshot().count)

    oracle_sess = JoinSession(m_budget=m_budget)
    full = oracle_sess.execute(q)           # compile + plan at final state
    full_ms = float("inf")
    for _ in range(max(repeats, 2)):
        t0 = time.perf_counter()
        full = oracle_sess.execute(q)
        full_ms = min(full_ms, (time.perf_counter() - t0) * 1e3)
    sq.close()
    return {"n": n, "d": d, "delta_rows": k, "deltas": deltas,
            "delta_ms": delta_ms, "full_ms": full_ms,
            "speedup": full_ms / max(delta_ms, 1e-6),
            "count": standing, "overflow_free": overflow_free,
            "match": standing == int(full.count) and overflow_free}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI sizes (smaller relations, fewer repeats)")
    ap.add_argument("--repeats", type=int, default=None)
    args = ap.parse_args()

    repeats = args.repeats or (2 if args.quick else 4)
    scale = 1 if args.quick else 2
    rng = np.random.default_rng(20260726)

    shapes = {}
    print(f"engine_bench: backend={jax.default_backend()} "
          f"quick={args.quick}")
    # Fig 4(e,f): linear self-join, |R|=|S|=|T|, tens of coarse partitions
    shapes["fig4ef_linear"] = bench_linear(
        rng, n=24000 * scale, d=4096 * scale, m_budget=1024 * scale, u=16,
        repeats=repeats)
    # §5 triangle query on a random graph
    shapes["cyclic_triangles"] = bench_cyclic(
        rng, n=6000 * scale, d=512 * scale, m_budget=512 * scale,
        repeats=repeats)
    # Fig 4(h,i): star schema — small dimensions, streamed fact
    shapes["fig4hi_star"] = bench_star(
        rng, n_dim=2000 * scale, n_fact=120000 * scale, d=2048 * scale,
        chunks=8, repeats=repeats)
    # declarative session: cold vs warm plan-cache execute
    shapes["session_plan_cache"] = bench_session_cache(
        rng, n=24000 * scale, d=4096 * scale, m_budget=1024 * scale,
        repeats=repeats)
    # N-way plan IR: 4-relation chain, calibrated default vs all-binary
    shapes["cascade_4way"] = bench_cascade_4way(
        rng, n=12000 * scale, d=2048 * scale, m_budget=1024 * scale,
        repeats=repeats)
    # overlapped DAG dispatch: 6-relation tree, two independent branches
    shapes["plan_pipeline_6way"] = bench_plan_pipeline_6way(
        rng, n=8000 * scale, d=1024 * scale, m_budget=1024 * scale,
        repeats=repeats)
    # batched execution over the plan cache
    shapes["session_execute_many"] = bench_execute_many(
        rng, n=12000 * scale, d=2048 * scale, m_budget=1024 * scale,
        batch=6, repeats=repeats)
    # standing-query ingest: delta plans vs from-scratch re-execution
    shapes["streaming_ingest"] = bench_streaming_ingest(
        rng, n=24000 * scale, d=4096 * scale, m_budget=1024 * scale,
        delta_frac=0.01, deltas=max(repeats * 2, 4), repeats=repeats)

    for name, row in shapes.items():
        if "delta_ms" in row:
            print(f"  {name}: delta {row['delta_ms']:.1f} ms "
                  f"({row['delta_rows']} rows), full re-execute "
                  f"{row['full_ms']:.1f} ms, speedup "
                  f"{row['speedup']:.1f}x, match={row['match']}")
        elif "scan_ms" in row:
            print(f"  {name}: scan {row['scan_ms']:.1f} ms, "
                  f"fused {row['fused_ms']:.1f} ms, "
                  f"speedup {row['speedup']:.2f}x, match={row['match']}")
        elif "ir_ms" in row:
            print(f"  {name}: ir {row['ir_ms']:.1f} ms "
                  f"({row['steps']} steps, {row['fused3_steps']} fused), "
                  f"all-binary {row['allbinary_ms']:.1f} ms, "
                  f"ir_vs_binary {row['ir_vs_binary']:.2f}x, "
                  f"match={row['match']}")
        elif "exec_ms" in row:
            print(f"  {name}: exec {row['exec_ms']:.1f} ms overlapped "
                  f"({row['steps']} steps), profiled "
                  f"{row['profile_ms']:.1f} ms, match={row['match']}")
        else:
            print(f"  {name}: cold plan {row['cold_plan_ms']:.2f} ms, "
                  f"warm plan {row['warm_plan_ms']:.3f} ms, "
                  f"cache hits={row['warm_cache_hits']}")

    best = max(s["speedup"] for name, s in shapes.items()
               if "speedup" in s and name != "streaming_ingest")
    cyc = shapes["cyclic_triangles"]["speedup"]
    cache = shapes["session_plan_cache"]
    ok = best >= 2.0 and all(s["match"] for s in shapes.values())
    # the exit gate uses a noise-tolerant 2x floor (shared CI runners
    # jitter); the measured value and the 3x claim go in the JSON record,
    # and check_bench_regression.py guards the trajectory against the
    # committed baseline ratio
    cyc_ok = cyc >= 2.0
    report = {
        "backend": jax.default_backend(),
        "quick": bool(args.quick),
        "repeats": repeats,
        "shapes": shapes,
        "claim_fused_ge_2x": {
            "ok": ok, "best_speedup": best,
            "detail": "fused engine >= 2x over scan driver on at least one "
                      "Fig 4 shape, counts exactly equal",
        },
        "claim_cyclic_pairidx_ge_3x": {
            "ok": cyc >= 3.0, "speedup": cyc,
            "detail": "cyclic fused path with the sorted (c,a)-pair-index "
                      "backend >= 3x over the cyclic scan driver",
        },
        "claim_session_plan_cache": {
            "ok": bool(cache["warm_cache_hits"]),
            "cold_plan_ms": cache["cold_plan_ms"],
            "warm_plan_ms": cache["warm_plan_ms"],
            "detail": "warm JoinSession.execute hits the plan cache "
                      "(skips classification + sizing entirely)",
        },
        "claim_nway_plan_ir": {
            "ok": bool(shapes["cascade_4way"]["match"]
                       and shapes["session_execute_many"]["match"]),
            "steps": shapes["cascade_4way"]["steps"],
            "fused3_steps": shapes["cascade_4way"]["fused3_steps"],
            "plan_amortization":
                shapes["session_execute_many"]["plan_amortization"],
            "detail": "a 4-relation chain decomposes into a multi-step "
                      "plan with a fused 3-way root whose count equals "
                      "the all-binary cascade exactly, and execute_many "
                      "amortizes planning over the cache",
        },
        "claim_streaming_delta_ge_5x": {
            "ok": bool(shapes["streaming_ingest"]["speedup"] >= 5.0
                       and shapes["streaming_ingest"]["match"]),
            "speedup": shapes["streaming_ingest"]["speedup"],
            "overflow_free": shapes["streaming_ingest"]["overflow_free"],
            "detail": "standing-query delta execution (resident "
                      "intermediates + family-masked siblings) >= 5x "
                      "faster than from-scratch re-execution at a 1% "
                      "delta, exact counts, overflowed == False every "
                      "delta round",
        },
        "claim_calibrated_plan_never_loses": {
            "ok": bool(shapes["cascade_4way"]["ir_vs_binary"] >= 1.0
                       and shapes["cascade_4way"]["match"]
                       and shapes["plan_pipeline_6way"]["match"]),
            "ir_vs_binary": shapes["cascade_4way"]["ir_vs_binary"],
            "calibrated_strategy": shapes["cascade_4way"]["strategy"],
            "detail": "with the time model calibrated from measured "
                      "per-root seconds, the session's default plan is "
                      "never slower than the forced all-binary cascade "
                      "(the overlapped device-resident executor runs "
                      "both), and the 6-relation DAG walk matches the "
                      "numpy oracle exactly",
        },
    }
    OUT.write_text(json.dumps(report, indent=2))
    # refresh the committed calibration snapshot from THIS report, so
    # calibration_from_file never reads constants staler than the latest
    # committed bench record (the carried ROADMAP follow-up)
    cal = calibrate.refresh_calibration_file(report, CAL_OUT)
    print(f"  calibration -> {CAL_OUT} (fused3 {cal.fused3_scale:.3g}, "
          f"cascade {cal.cascade_scale:.3g}, {cal.source})")
    # per-step timing record (CI uploads this next to BENCH_engine.json)
    STEPS_OUT.write_text(json.dumps({
        "backend": jax.default_backend(), "quick": bool(args.quick),
        "plan_pipeline_6way": shapes["plan_pipeline_6way"]["step_timings"],
    }, indent=2))
    cache_ok = bool(cache["warm_cache_hits"])
    nway_ok = bool(report["claim_nway_plan_ir"]["ok"])
    cal_ok = bool(report["claim_calibrated_plan_never_loses"]["ok"])
    print(f"[{'PASS' if ok else 'FAIL'}] best fused speedup {best:.2f}x; "
          f"[{'PASS' if cyc_ok else 'FAIL'}] cyclic pair-index {cyc:.2f}x; "
          f"[{'PASS' if cache_ok else 'FAIL'}] session plan cache; "
          f"[{'PASS' if nway_ok else 'FAIL'}] N-way plan IR; "
          f"[{'PASS' if cal_ok else 'FAIL'}] calibrated plan "
          f">= cascade -> {OUT}")
    return 0 if (ok and cyc_ok and cache_ok and nway_ok and cal_ok) else 1


if __name__ == "__main__":
    raise SystemExit(main())
