"""zamba2-1.2b — hybrid Mamba2 backbone + shared attention block
[arXiv:2411.15242; hf].

38 Mamba2 layers, d_model=2048, ssm_state=64; one shared transformer block
(32H MHA, d_ff=8192) invoked every 6 SSM layers.  vocab 32000.
Simplifications vs release (DESIGN.md): no per-invocation LoRA, shared
block input is the running stream (no embedding concat).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab_size=32000, head_dim=64,
    ssm_state=64, ssm_expand=2, ssm_headdim=64, ssm_conv=4, ssm_ngroups=1,
    hybrid_every=6, tie_embeddings=True, norm_eps=1e-5,
    accum_steps=2,
)

SMOKE = ModelConfig(
    name="zamba2-1.2b-smoke", family="hybrid",
    n_layers=5, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab_size=512, head_dim=16,
    ssm_state=16, ssm_expand=2, ssm_headdim=16, ssm_conv=4, ssm_ngroups=1,
    hybrid_every=2, tie_embeddings=True, norm_eps=1e-5, remat=False,
)
