"""Fixed-capacity, validity-masked relations (struct-of-arrays).

JAX requires static shapes, and the paper's algorithms never materialize the
final join output (aggregates are folded on the fly, §6).  A Relation is a
dict of equal-length int32 column arrays plus a boolean validity mask; the
capacity is static, the live count `n` is dynamic.  All core algorithms
consume and produce Relations (or aggregates).
"""

from __future__ import annotations

import dataclasses
from typing import Mapping

import jax
import jax.numpy as jnp


# The canonical padding sentinel for invalid relation slots.  Every layer
# that fills dead slots (``sentinel_fill``, ``partition.bucketize``,
# ``partition.bucketize_by_ids``) uses THIS constant; the per-side probe
# sentinels in ``kernels.ops`` are derived from it (SENTINEL + 15 + side)
# so no sentinel of any kind can ever equal a live key (keys are ≥ -2^30
# by the data-layer contract) or a sentinel from another side.
SENTINEL = -0x7FFFFFFF


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class Relation:
    """Columnar relation with static capacity and a validity mask."""

    columns: Mapping[str, jnp.ndarray]  # each (capacity,) int32
    valid: jnp.ndarray                  # (capacity,) bool

    # -- pytree protocol ----------------------------------------------------
    def tree_flatten(self):
        names = tuple(sorted(self.columns))
        return tuple(self.columns[n] for n in names) + (self.valid,), names

    @classmethod
    def tree_unflatten(cls, names, leaves):
        *cols, valid = leaves
        return cls(columns=dict(zip(names, cols)), valid=valid)

    # -- introspection -------------------------------------------------------
    @property
    def capacity(self) -> int:
        return self.valid.shape[0]

    @property
    def n(self) -> jnp.ndarray:
        """Dynamic number of live tuples."""
        return jnp.sum(self.valid.astype(jnp.int32))

    def col(self, name: str) -> jnp.ndarray:
        return self.columns[name]

    # -- distinct-count sketches ---------------------------------------------
    def distinct_sketch(self, col: str) -> jnp.ndarray:
        """The column's FM/PCSA register bitmaps (``core.sketches``),
        built on first use and cached for the life of the instance (the
        arrays are immutable, so the sketch can never go stale).  This is
        what lets the planner estimate distinct counts without a host
        scan; derived relations (``select``/``mask_where``/pytree
        reconstruction) start with an empty cache."""
        cache = self.__dict__.get("_sketch_cache")
        if cache is None:
            cache = {}
            object.__setattr__(self, "_sketch_cache", cache)
        sk = cache.get(col)
        if sk is None:
            from repro.core import sketches
            sk = sketches.add(sketches.empty(), self.columns[col],
                              self.valid)
            cache[col] = sk
        return sk

    def distinct_estimate(self, col: str) -> int:
        """FM-sketch distinct-count estimate of a column (>= 1), clipped
        to the column's capacity.  The planner's scan-free replacement
        for host ``np.unique`` passes."""
        from repro.core import sketches
        est = int(round(float(sketches.fm_estimate(
            self.distinct_sketch(col)))))
        return max(1, min(est, self.capacity))

    # -- construction --------------------------------------------------------
    @classmethod
    def from_arrays(cls, capacity: int | None = None, **cols) -> "Relation":
        """Build from equal-length arrays, optionally padding to `capacity`."""
        arrs = {k: jnp.asarray(v, dtype=jnp.int32) for k, v in cols.items()}
        lens = {a.shape[0] for a in arrs.values()}
        if len(lens) != 1:
            raise ValueError(f"ragged columns: {dict((k, v.shape) for k, v in arrs.items())}")
        (n,) = lens
        cap = capacity or n
        if cap < n:
            raise ValueError(f"capacity {cap} < rows {n}")
        pad = cap - n
        if pad:
            arrs = {k: jnp.pad(a, (0, pad)) for k, a in arrs.items()}
        valid = jnp.arange(cap) < n
        return cls(columns=arrs, valid=valid)

    def select(self, idx: jnp.ndarray, idx_valid: jnp.ndarray) -> "Relation":
        """Gather rows by index (row validity AND idx_valid)."""
        cols = {k: v[idx] for k, v in self.columns.items()}
        return Relation(cols, self.valid[idx] & idx_valid)

    def with_columns(self, **cols) -> "Relation":
        new = dict(self.columns)
        new.update({k: jnp.asarray(v, jnp.int32) for k, v in cols.items()})
        return Relation(new, self.valid)

    def mask_where(self, keep: jnp.ndarray) -> "Relation":
        return Relation(dict(self.columns), self.valid & keep)


def sentinel_fill(rel: Relation, sentinel: int = SENTINEL) -> Relation:
    """Overwrite invalid rows' columns with a sentinel that never equals a
    live key, so masked compare loops need no extra predicate."""
    cols = {
        k: jnp.where(rel.valid, v, jnp.int32(sentinel))
        for k, v in rel.columns.items()
    }
    return Relation(cols, rel.valid)
