"""Pallas TPU kernels for the per-bucket join inner loops.

This is the compute hot-spot the paper optimizes: once relations are radix
partitioned, each PMU (here: one VMEM-resident bucket triple per grid step)
joins tiny relations with all-pairs compares.  On Plasticine the compare is
a 16-lane SIMD loop in a PCU; on TPU we map it to:

* VPU 8×128 lanes for the equality matrices (branch-free compares on
  sentinel-masked keys), and
* the MXU for the contraction steps — per-key probe weights and the cyclic
  existence matrix are literally matmuls over 0/1 matrices
  (``count = Σ (M1ᵀ M2) ⊙ M3``).

Layout contract (enforced by ``ops.py``):
  - bucket grids ``[n_buckets, capacity]`` int32, capacity a multiple of 128
    (MXU/VPU lane alignment),
  - invalid slots pre-masked to per-side sentinels so cross-side equality of
    invalid slots is impossible and kernels stay mask-free,
  - per-bucket counts ≤ 2^24 so f32 accumulation is exact (bucket capacities
    are VMEM-bounded, far below this).

Grid: one program per bucket (the ``n_buckets`` grid dimension is
embarrassingly parallel — Plasticine's U-way PMU parallelism).  BlockSpecs
pin one bucket row of each operand in VMEM per step; Pallas double-buffers
the HBM→VMEM streams across grid steps, which is exactly the paper's
prefetch/double-buffering optimization (§6.2).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _row(ref):
    """Load a (1, C) block as a (C,) vector."""
    return ref[0, :]


# --------------------------------------------------------------------------
# binary pair count
# --------------------------------------------------------------------------

def _pair_count_kernel(ka_ref, kb_ref, out_ref):
    ka = _row(ka_ref)
    kb = _row(kb_ref)
    m = (ka[:, None] == kb[None, :]).astype(jnp.float32)
    out_ref[0, 0] = jnp.sum(m)


@functools.partial(jax.jit, static_argnames=("interpret",))
def pair_count(ka: jnp.ndarray, kb: jnp.ndarray, *, interpret: bool = True):
    b, ca = ka.shape
    _, cb = kb.shape
    out = pl.pallas_call(
        _pair_count_kernel,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, ca), lambda i: (i, 0)),
            pl.BlockSpec((1, cb), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, 1), jnp.float32),
        interpret=interpret,
    )(ka, kb)
    return out[:, 0].astype(jnp.int32)


# --------------------------------------------------------------------------
# linear 3-way count (Algorithm 1 inner join)
# --------------------------------------------------------------------------

def _count3_linear_kernel(rb_ref, sb_ref, sc_ref, tc_ref, out_ref):
    rb = _row(rb_ref)
    sb = _row(sb_ref)
    sc = _row(sc_ref)
    tc = _row(tc_ref)
    wr = jnp.sum((sb[:, None] == rb[None, :]).astype(jnp.float32), axis=1)
    wt = jnp.sum((sc[:, None] == tc[None, :]).astype(jnp.float32), axis=1)
    out_ref[0, 0] = jnp.sum(wr * wt)


@functools.partial(jax.jit, static_argnames=("interpret",))
def count3_linear(rb, sb, sc, tc, *, interpret: bool = True):
    b, cr = rb.shape
    _, cs = sb.shape
    _, ct = tc.shape
    out = pl.pallas_call(
        _count3_linear_kernel,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, cr), lambda i: (i, 0)),
            pl.BlockSpec((1, cs), lambda i: (i, 0)),
            pl.BlockSpec((1, cs), lambda i: (i, 0)),
            pl.BlockSpec((1, ct), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, 1), jnp.float32),
        interpret=interpret,
    )(rb, sb, sc, tc)
    return out[:, 0].astype(jnp.int32)


# --------------------------------------------------------------------------
# per-R-slot counts (Example 1 per-user aggregate) — MXU contraction
# --------------------------------------------------------------------------

def _per_r_kernel(rb_ref, sb_ref, sc_ref, tc_ref, out_ref):
    rb = _row(rb_ref)
    sb = _row(sb_ref)
    sc = _row(sc_ref)
    tc = _row(tc_ref)
    wt = jnp.sum((sc[:, None] == tc[None, :]).astype(jnp.float32), axis=1)
    m1 = (sb[:, None] == rb[None, :]).astype(jnp.float32)      # (Cs, Cr)
    # c[r] = Σ_s w_s · m1[s, r]  ==  (1, Cs) @ (Cs, Cr)  — MXU
    out_ref[0, :] = jnp.dot(wt[None, :], m1,
                            preferred_element_type=jnp.float32)[0]


@functools.partial(jax.jit, static_argnames=("interpret",))
def per_r_counts(rb, sb, sc, tc, *, interpret: bool = True):
    b, cr = rb.shape
    _, cs = sb.shape
    _, ct = tc.shape
    out = pl.pallas_call(
        _per_r_kernel,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, cr), lambda i: (i, 0)),
            pl.BlockSpec((1, cs), lambda i: (i, 0)),
            pl.BlockSpec((1, cs), lambda i: (i, 0)),
            pl.BlockSpec((1, ct), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, cr), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, cr), jnp.float32),
        interpret=interpret,
    )(rb, sb, sc, tc)
    return out.astype(jnp.int32)


# --------------------------------------------------------------------------
# cyclic 3-way (triangle) count — two MXU matmuls per bucket triple
# --------------------------------------------------------------------------

def _count3_cyclic_kernel(ra_ref, rb_ref, sb_ref, sc_ref, tc_ref, ta_ref,
                          out_ref):
    ra = _row(ra_ref)
    rb = _row(rb_ref)
    sb = _row(sb_ref)
    sc = _row(sc_ref)
    tc = _row(tc_ref)
    ta = _row(ta_ref)
    m1 = (sb[:, None] == rb[None, :]).astype(jnp.float32)      # (Cs, Cr)
    m2 = (sc[:, None] == tc[None, :]).astype(jnp.float32)      # (Cs, Ct)
    p = jnp.dot(m1.T, m2, preferred_element_type=jnp.float32)  # (Cr, Ct)
    m3 = (ra[:, None] == ta[None, :]).astype(jnp.float32)      # (Cr, Ct)
    out_ref[0, 0] = jnp.sum(p * m3)


@functools.partial(jax.jit, static_argnames=("interpret",))
def count3_cyclic(ra, rb, sb, sc, tc, ta, *, interpret: bool = True):
    b, cr = ra.shape
    _, cs = sb.shape
    _, ct = tc.shape
    out = pl.pallas_call(
        _count3_cyclic_kernel,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, cr), lambda i: (i, 0)),
            pl.BlockSpec((1, cr), lambda i: (i, 0)),
            pl.BlockSpec((1, cs), lambda i: (i, 0)),
            pl.BlockSpec((1, cs), lambda i: (i, 0)),
            pl.BlockSpec((1, ct), lambda i: (i, 0)),
            pl.BlockSpec((1, ct), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, 1), jnp.float32),
        interpret=interpret,
    )(ra, rb, sb, sc, tc, ta)
    return out[:, 0].astype(jnp.int32)


# ==========================================================================
# Fused partition-sweep kernels (engine hot path)
# ==========================================================================
#
# The kernels above join ONE bucket row per grid step; the drivers in
# core/{linear3,cyclic3,star3}.py sweep the coarse H(B)×g(C) partition grid
# with nested lax.scan loops, launching a fresh pallas_call per step.  That
# serializes the sweep and leaves the grid dimension — the paper's U-way PMU
# parallelism — idle between launches.
#
# The fused variants below put the WHOLE sweep into one pallas_call: the grid
# spans (coarse partitions × PMU buckets × streaming buckets) and BlockSpec
# index maps pick the partition row per program.  Consequences:
#   * one kernel launch per query instead of h_parts·g_parts of them,
#   * Pallas double-buffers the HBM→VMEM operand streams across the whole
#     sweep (the §6.2 prefetch optimization, now spanning partitions),
#   * operands whose index map ignores the innermost grid dim (e.g. the R
#     partition during the g(C) stream) stay resident in VMEM — the paper's
#     "R partition pinned on-chip" falls out of the revisiting rule.
#
# The streaming dimension is innermost and accumulates into a revisited
# output block (zeroed when its program_id is 0 — the standard matmul-K
# pattern), so outputs are per-PMU-bucket partials, summed by the caller.
#
# Accumulators are int32, NOT f32: a single per-bucket step stays within
# the ≤2^24 exact-f32 contract, but the fused kernels accumulate a whole
# partition's sweep into one output cell, which can exceed it.


def _fused_linear_kernel(rb_ref, sb_ref, sc_ref, tc_ref, out_ref):
    """grid = (h_parts, u, g_parts);  g (T stream) innermost."""
    @pl.when(pl.program_id(2) == 0)
    def _():
        out_ref[0, 0] = 0

    rb = rb_ref[0, 0, :]
    sb = sb_ref[0, 0, 0, :]
    sc = sc_ref[0, 0, 0, :]
    tc = tc_ref[0, :]
    wr = jnp.sum((sb[:, None] == rb[None, :]).astype(jnp.int32), axis=1)
    wt = jnp.sum((sc[:, None] == tc[None, :]).astype(jnp.int32), axis=1)
    out_ref[0, 0] += jnp.sum(wr * wt)


@functools.partial(jax.jit, static_argnames=("interpret",))
def fused_count3_linear(rb, sb, sc, tc, *, interpret: bool = True):
    """Whole linear-3 sweep in one launch.

    rb: [hp, u, Cr], sb/sc: [hp, gp, u, Cs], tc: [gp, Ct]
    returns per-(H, h) bucket counts [hp, u] int32.
    """
    hp, u, cr = rb.shape
    _, gp, _, cs = sb.shape
    _, ct = tc.shape
    out = pl.pallas_call(
        _fused_linear_kernel,
        grid=(hp, u, gp),
        in_specs=[
            pl.BlockSpec((1, 1, cr), lambda i, k, j: (i, k, 0)),
            pl.BlockSpec((1, 1, 1, cs), lambda i, k, j: (i, j, k, 0)),
            pl.BlockSpec((1, 1, 1, cs), lambda i, k, j: (i, j, k, 0)),
            pl.BlockSpec((1, ct), lambda i, k, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i, k, j: (i, k)),
        out_shape=jax.ShapeDtypeStruct((hp, u), jnp.int32),
        interpret=interpret,
    )(rb, sb, sc, tc)
    return out


def _fused_per_r_kernel(rb_ref, sb_ref, sc_ref, tc_ref, out_ref):
    """grid = (h_parts, u, g_parts);  per-R-slot counts, g innermost."""
    @pl.when(pl.program_id(2) == 0)
    def _():
        out_ref[0, 0, :] = jnp.zeros_like(out_ref[0, 0, :])

    rb = rb_ref[0, 0, :]
    sb = sb_ref[0, 0, 0, :]
    sc = sc_ref[0, 0, 0, :]
    tc = tc_ref[0, :]
    # per-step dot stays on the MXU in f32 (exact: one bucket step ≤ 2^24);
    # the cross-step accumulation is int32
    wt = jnp.sum((sc[:, None] == tc[None, :]).astype(jnp.float32), axis=1)
    m1 = (sb[:, None] == rb[None, :]).astype(jnp.float32)       # (Cs, Cr)
    step = jnp.dot(wt[None, :], m1, preferred_element_type=jnp.float32)[0]
    out_ref[0, 0, :] += step.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def fused_per_r_counts(rb, sb, sc, tc, *, interpret: bool = True):
    """Per-R-slot counts for the whole sweep: returns [hp, u, Cr] int32."""
    hp, u, cr = rb.shape
    _, gp, _, cs = sb.shape
    _, ct = tc.shape
    out = pl.pallas_call(
        _fused_per_r_kernel,
        grid=(hp, u, gp),
        in_specs=[
            pl.BlockSpec((1, 1, cr), lambda i, k, j: (i, k, 0)),
            pl.BlockSpec((1, 1, 1, cs), lambda i, k, j: (i, j, k, 0)),
            pl.BlockSpec((1, 1, 1, cs), lambda i, k, j: (i, j, k, 0)),
            pl.BlockSpec((1, ct), lambda i, k, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, cr), lambda i, k, j: (i, k, 0)),
        out_shape=jax.ShapeDtypeStruct((hp, u, cr), jnp.int32),
        interpret=interpret,
    )(rb, sb, sc, tc)
    return out


def _fused_cyclic_kernel(ra_ref, rb_ref, sb_ref, sc_ref, tc_ref, ta_ref,
                         out_ref):
    """grid = (hp, gp, uh, ug, fp);  f (C stream) innermost."""
    @pl.when(pl.program_id(4) == 0)
    def _():
        out_ref[0, 0, 0, 0] = 0

    ra = ra_ref[0, 0, 0, 0, :]
    rb = rb_ref[0, 0, 0, 0, :]
    sb = sb_ref[0, 0, 0, :]
    sc = sc_ref[0, 0, 0, :]
    tc = tc_ref[0, 0, 0, :]
    ta = ta_ref[0, 0, 0, :]
    m1 = (sb[:, None] == rb[None, :]).astype(jnp.float32)      # (Cs, Cr)
    m2 = (sc[:, None] == tc[None, :]).astype(jnp.float32)      # (Cs, Ct)
    p = jnp.dot(m1.T, m2, preferred_element_type=jnp.float32)  # (Cr, Ct)
    m3 = (ra[:, None] == ta[None, :]).astype(jnp.float32)      # (Cr, Ct)
    out_ref[0, 0, 0, 0] += jnp.sum(p * m3).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def fused_count3_cyclic(ra, rb, sb, sc, tc, ta, *, interpret: bool = True):
    """Whole cyclic (triangle) sweep in one launch.

    ra/rb: [hp, gp, uh, ug, Cr] — the (H(A), G(B)) coarse grid × PMU grid;
    sb/sc: [gp, fp, ug, Cs] — S broadcast down columns via the index map;
    tc/ta: [hp, fp, uh, Ct] — T broadcast across rows via the index map.
    returns per-cell counts [hp, gp, uh, ug] int32.
    """
    hp, gp, uh, ug, cr = ra.shape
    _, fp, _, cs = sb.shape
    _, _, _, ct = tc.shape
    out = pl.pallas_call(
        _fused_cyclic_kernel,
        grid=(hp, gp, uh, ug, fp),
        in_specs=[
            pl.BlockSpec((1, 1, 1, 1, cr),
                         lambda i, j, a, b, f: (i, j, a, b, 0)),
            pl.BlockSpec((1, 1, 1, 1, cr),
                         lambda i, j, a, b, f: (i, j, a, b, 0)),
            pl.BlockSpec((1, 1, 1, cs), lambda i, j, a, b, f: (j, f, b, 0)),
            pl.BlockSpec((1, 1, 1, cs), lambda i, j, a, b, f: (j, f, b, 0)),
            pl.BlockSpec((1, 1, 1, ct), lambda i, j, a, b, f: (i, f, a, 0)),
            pl.BlockSpec((1, 1, 1, ct), lambda i, j, a, b, f: (i, f, a, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, 1),
                               lambda i, j, a, b, f: (i, j, a, b)),
        out_shape=jax.ShapeDtypeStruct((hp, gp, uh, ug), jnp.int32),
        interpret=interpret,
    )(ra, rb, sb, sc, tc, ta)
    return out


def _fused_cyclic_pairidx_kernel(ra_ref, rb_ref, sb_ref, sc_ref, tcs_ref,
                                 tas_ref, out_ref):
    """grid = (hp, gp, uh, ug, fp); T arrives as a lex-sorted (c, a)-pair
    index and each S slot range-scans it (two searchsorted probes) instead
    of the all-pairs contraction.  The range sums come from a prefix-sum
    table over the sorted run — O(Ct·Cr + Cs·Cr) per step instead of
    O(Cs·Cr·Ct).  Binary-search gathers keep this kernel interpret-mode
    (CPU/XLA) territory; the all-pairs variant remains the MXU mapping."""
    @pl.when(pl.program_id(4) == 0)
    def _():
        out_ref[0, 0, 0, 0] = 0

    ra = ra_ref[0, 0, 0, 0, :]
    rb = rb_ref[0, 0, 0, 0, :]
    sb = sb_ref[0, 0, 0, :]
    sc = sc_ref[0, 0, 0, :]
    tcs = tcs_ref[0, 0, 0, :]
    tas = tas_ref[0, 0, 0, :]
    lo = jnp.searchsorted(tcs, sc, side="left")                # [Cs]
    hi = jnp.searchsorted(tcs, sc, side="right")               # [Cs]
    m3 = (tas[:, None] == ra[None, :]).astype(jnp.int32)       # (Ct, Cr)
    pre = jnp.pad(jnp.cumsum(m3, axis=0), ((1, 0), (0, 0)))    # (Ct+1, Cr)
    g = jnp.take(pre, hi, axis=0) - jnp.take(pre, lo, axis=0)  # (Cs, Cr)
    e = (sb[:, None] == rb[None, :]).astype(jnp.int32)         # (Cs, Cr)
    out_ref[0, 0, 0, 0] += jnp.sum(e * g)


@functools.partial(jax.jit, static_argnames=("interpret",))
def fused_count3_cyclic_pairidx(ra, rb, sb, sc, tcs, tas, *,
                                interpret: bool = True):
    """Fused cyclic sweep over a sorted (c, a)-pair index of T.

    Same layout contract as ``fused_count3_cyclic`` except tcs/tas must be
    lex-sorted by (c, a) along the capacity axis (``ops.lex_sort_pairs``).
    returns per-cell counts [hp, gp, uh, ug] int32.
    """
    hp, gp, uh, ug, cr = ra.shape
    _, fp, _, cs = sb.shape
    _, _, _, ct = tcs.shape
    out = pl.pallas_call(
        _fused_cyclic_pairidx_kernel,
        grid=(hp, gp, uh, ug, fp),
        in_specs=[
            pl.BlockSpec((1, 1, 1, 1, cr),
                         lambda i, j, a, b, f: (i, j, a, b, 0)),
            pl.BlockSpec((1, 1, 1, 1, cr),
                         lambda i, j, a, b, f: (i, j, a, b, 0)),
            pl.BlockSpec((1, 1, 1, cs), lambda i, j, a, b, f: (j, f, b, 0)),
            pl.BlockSpec((1, 1, 1, cs), lambda i, j, a, b, f: (j, f, b, 0)),
            pl.BlockSpec((1, 1, 1, ct), lambda i, j, a, b, f: (i, f, a, 0)),
            pl.BlockSpec((1, 1, 1, ct), lambda i, j, a, b, f: (i, f, a, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, 1),
                               lambda i, j, a, b, f: (i, j, a, b)),
        out_shape=jax.ShapeDtypeStruct((hp, gp, uh, ug), jnp.int32),
        interpret=interpret,
    )(ra, rb, sb, sc, tcs, tas)
    return out


def _fused_star_kernel(rb_ref, sb_ref, sc_ref, tc_ref, out_ref):
    """grid = (uh, ug, chunks);  the S arrival-order stream innermost."""
    @pl.when(pl.program_id(2) == 0)
    def _():
        out_ref[0, 0] = 0

    rb = rb_ref[0, :]
    sb = sb_ref[0, 0, 0, :]
    sc = sc_ref[0, 0, 0, :]
    tc = tc_ref[0, :]
    wr = jnp.sum((sb[:, None] == rb[None, :]).astype(jnp.int32), axis=1)
    wt = jnp.sum((sc[:, None] == tc[None, :]).astype(jnp.int32), axis=1)
    out_ref[0, 0] += jnp.sum(wr * wt)


@functools.partial(jax.jit, static_argnames=("interpret",))
def fused_count3_star(rb, sb, sc, tc, *, interpret: bool = True):
    """Whole star sweep in one launch: R pinned by rows, T by cols, S
    streamed in chunks.

    rb: [uh, Cr], sb/sc: [chunks, uh, ug, Cs], tc: [ug, Ct]
    returns per-PMU counts [uh, ug] int32.
    """
    uh, cr = rb.shape
    ch, _, ug, cs = sb.shape
    _, ct = tc.shape
    out = pl.pallas_call(
        _fused_star_kernel,
        grid=(uh, ug, ch),
        in_specs=[
            pl.BlockSpec((1, cr), lambda i, k, j: (i, 0)),
            pl.BlockSpec((1, 1, 1, cs), lambda i, k, j: (j, i, k, 0)),
            pl.BlockSpec((1, 1, 1, cs), lambda i, k, j: (j, i, k, 0)),
            pl.BlockSpec((1, ct), lambda i, k, j: (k, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i, k, j: (i, k)),
        out_shape=jax.ShapeDtypeStruct((uh, ug), jnp.int32),
        interpret=interpret,
    )(rb, sb, sc, tc)
    return out
