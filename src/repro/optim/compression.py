"""Int8 error-feedback gradient compression for the slow (cross-pod) axis.

At 2+ pods the gradient all-reduce crosses DCN-class links; quantizing the
cross-pod reduction 4× (f32 → int8 + per-tensor scale) cuts that traffic
while error feedback keeps the *accumulated* quantization error in the
update path (Seide et al. 2014; 1-bit Adam lineage).

Usage (pure pytree functions — the launcher owns the residual state):

    residual = ef_init(grads_template)
    grads_q, residual = compress_grads(grads + residual)   # before psum
    ... psum over "pod" ...
    grads = decompress(grads_q)

`simulate_roundtrip` applies compress→decompress locally; tests use it to
assert the error-feedback convergence property (quantization error does not
accumulate over steps).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ef_init(grads):
    return jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32), grads)


def _q8(x):
    """Symmetric per-tensor int8 quantization: (q, scale)."""
    amax = jnp.max(jnp.abs(x))
    scale = jnp.where(amax > 0, amax / 127.0, 1.0).astype(jnp.float32)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dq8(q, scale):
    return q.astype(jnp.float32) * scale


def compress_grads(grads, residual):
    """(q_tree, scales_tree, new_residual): error feedback folds the
    quantization error of THIS step into the next step's gradient."""
    def one(g, r):
        v = g.astype(jnp.float32) + r
        q, s = _q8(v)
        err = v - _dq8(q, s)
        return (q, s), err
    flat_g, tree = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(residual)
    qs, errs = zip(*(one(g, r) for g, r in zip(flat_g, flat_r)))
    q_tree = tree.unflatten([q for q, _ in qs])
    s_tree = tree.unflatten([s for _, s in qs])
    r_tree = tree.unflatten(list(errs))
    return q_tree, s_tree, r_tree


def decompress_grads(q_tree, s_tree):
    return jax.tree.map(_dq8, q_tree, s_tree)


def simulate_roundtrip(grads, residual):
    """Local compress→decompress (what each pod sees after the quantized
    cross-pod reduction, modulo the mean)."""
    q, s, r = compress_grads(grads, residual)
    return decompress_grads(q, s), r
