"""Mixture-of-Experts FFN: top-k routing with sort-based capacity dispatch.

Dispatch is the same fixed-capacity radix idiom as the join engine's
``partition.bucketize`` (DESIGN.md §4: token→expert routing *is* a
relational shuffle): assignments are ranked within their expert via a stable
sort, dropped beyond capacity (standard GShard capacity-factor semantics,
reported via aux stats), gathered into dense [E, C, d] blocks, run through
per-expert GLU FFNs as one einsum (MXU-friendly grouped GEMM), and
combine-scattered back with router weights.

Experts shard over the "model" mesh axis (EP); the gather/scatter across the
token (batch-sharded) and expert dimensions lowers to the expected
all-to-all pair under SPMD.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.parallel import shard


def init_moe(key, cfg):
    d, e, ff = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    import math
    p = {
        "router": {"w": layers.normal(k1, (d, e), 1.0 / math.sqrt(d))},
        "gate": layers.normal(k2, (e, d, ff), 1.0 / math.sqrt(d)),
        "up": layers.normal(k3, (e, d, ff), 1.0 / math.sqrt(d)),
        "down": layers.normal(k4, (e, ff, d), 1.0 / math.sqrt(ff)),
    }
    if cfg.n_shared_experts:
        p["shared"] = layers.init_glu_mlp(k5, d,
                                          cfg.moe_d_ff * cfg.n_shared_experts)
    return p


def _capacity(n_tokens: int, n_experts: int, top_k: int,
              factor: float = 1.25, align: int = 8) -> int:
    import math
    c = math.ceil(n_tokens * top_k / n_experts * factor)
    return max(align, math.ceil(c / align) * align)


def moe_mlp(x, p, cfg, capacity_factor: float = 1.25):
    """Returns (out [B,S,d], aux) — aux carries the load-balance loss and
    drop fraction."""
    b, s, d = x.shape
    n = b * s
    e, k = cfg.n_experts, cfg.top_k
    cap = _capacity(n, e, k, capacity_factor)

    xt = x.reshape(n, d)
    logits = (xt.astype(jnp.float32) @ p["router"]["w"])        # [N, E]
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, k)                      # [N, k]
    if cfg.norm_topk:
        top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)

    # ---- rank-within-expert via stable sort (the bucketize idiom) -------
    flat_e = top_i.reshape(-1)                                  # [N*k]
    token_of = jnp.repeat(jnp.arange(n, dtype=jnp.int32), k)    # [N*k]
    weight_of = top_p.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    starts = jnp.searchsorted(sorted_e, jnp.arange(e + 1), side="left")
    rank = jnp.arange(n * k, dtype=jnp.int32) - starts[sorted_e].astype(jnp.int32)
    keep = rank < cap
    dest = jnp.where(keep, sorted_e * cap + rank, e * cap)      # drop slot

    # ---- gather tokens into [E, C, d] expert blocks ----------------------
    xe = jnp.zeros((e * cap + 1, d), x.dtype)
    xe = xe.at[dest].set(xt[token_of[order]], mode="drop")
    xe = shard(xe[:-1].reshape(e, cap, d), ("experts", None, None))

    # ---- grouped per-expert GLU FFN (one einsum per projection) ---------
    g = jnp.einsum("ecd,edf->ecf", xe, p["gate"].astype(x.dtype))
    u = jnp.einsum("ecd,edf->ecf", xe, p["up"].astype(x.dtype))
    h = jax.nn.silu(g) * u
    h = shard(h, ("experts", None, "mlp"))
    ye = jnp.einsum("ecf,efd->ecd", h, p["down"].astype(x.dtype))

    # ---- combine-scatter back with router weights ------------------------
    ye_flat = ye.reshape(e * cap, d)
    contrib = jnp.where(keep[:, None],
                        ye_flat[jnp.clip(dest, 0, e * cap - 1)]
                        * weight_of[order][:, None].astype(x.dtype),
                        0)
    out = jnp.zeros((n, d), x.dtype).at[token_of[order]].add(contrib)

    if cfg.n_shared_experts:
        out = out + layers.glu_mlp(xt, p["shared"], cfg.act)

    # ---- aux: Switch-style load-balance loss + drop fraction ------------
    me = jnp.mean(probs, axis=0)                                # [E]
    fe = jnp.zeros((e,), jnp.float32).at[flat_e].add(1.0) / (n * k)
    aux_loss = e * jnp.sum(me * fe)
    dropped = 1.0 - jnp.sum(keep.astype(jnp.float32)) / (n * k)
    return out.reshape(b, s, d), {"aux_loss": aux_loss, "dropped": dropped}


def moe_mlp_sharded(x, p, cfg, capacity_factor: float = 1.25):
    """EP dispatch inside shard_map — the paper's partition phase on the
    mesh (EXPERIMENTS.md §Perf, MoE cells).

    The naive GSPMD lowering of `moe_mlp` is catastrophic at scale: the
    token→expert argsort is GLOBAL, so XLA replicates [N_global·k, d]
    dispatch tensors on every device (traced at 69 GB/op/layer for
    qwen3-moe train_4k) and emits ~137 GB/layer all-reduces.  Exactly as
    in the paper's star join, the shuffle must be *local partitioning +
    hash routing*: tokens are batch-sharded (replicated over "model"), so
    each model shard simply selects the assignments owned by its local
    experts, runs its expert FFNs, and one psum over "model" merges the
    combine — the same single all-reduce a dense row-parallel MLP needs.
    Per-device dispatch state shrinks from [N_global·k, d] to
    [N_local·k, d]."""
    import jax as _jax
    from jax.sharding import PartitionSpec as P
    from repro.parallel import sharding as shd

    ctx = shd.current_context()
    mesh = ctx.mesh
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    model_ax = "model"
    e, k = cfg.n_experts, cfg.top_k
    tp = mesh.shape[model_ax]
    e_loc = e // tp
    b, s, d = x.shape

    def local(xb, rw, gate, up, down, shared):
        from repro.parallel import sharding as _shd
        with _shd.manual_mode():
            return _local(xb, rw, gate, up, down, shared)

    def _local(xb, rw, gate, up, down, shared):
        nb, ns, _ = xb.shape
        n = nb * ns
        cap = _capacity(n, e, k, capacity_factor)
        m_idx = jax.lax.axis_index(model_ax)
        xt = xb.reshape(n, d)

        logits = xt.astype(jnp.float32) @ rw                 # [n_loc, E]
        probs = jax.nn.softmax(logits, axis=-1)
        top_p, top_i = jax.lax.top_k(probs, k)
        if cfg.norm_topk:
            top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)

        flat_e = top_i.reshape(-1)
        token_of = jnp.repeat(jnp.arange(n, dtype=jnp.int32), k)
        weight_of = top_p.reshape(-1)
        order = jnp.argsort(flat_e, stable=True)
        sorted_e = flat_e[order]
        starts = jnp.searchsorted(sorted_e, jnp.arange(e + 1), side="left")
        rank = jnp.arange(n * k, dtype=jnp.int32) \
            - starts[sorted_e].astype(jnp.int32)
        keep = rank < cap
        # local-expert ownership: this shard owns [m_idx·e_loc, …+e_loc)
        local_e = sorted_e - m_idx * e_loc
        mine = keep & (local_e >= 0) & (local_e < e_loc)
        dest = jnp.where(mine, local_e * cap + rank, e_loc * cap)

        xe = jnp.zeros((e_loc * cap + 1, d), xb.dtype)
        xe = xe.at[dest].set(xt[token_of[order]], mode="drop")
        xe = xe[:-1].reshape(e_loc, cap, d)

        g = jnp.einsum("ecd,edf->ecf", xe, gate.astype(xb.dtype))
        u = jnp.einsum("ecd,edf->ecf", xe, up.astype(xb.dtype))
        h = jax.nn.silu(g) * u
        ye = jnp.einsum("ecf,efd->ecd", h, down.astype(xb.dtype))

        ye_flat = ye.reshape(e_loc * cap, d)
        contrib = jnp.where(
            mine[:, None],
            ye_flat[jnp.clip(dest, 0, e_loc * cap - 1)]
            * weight_of[order][:, None].astype(xb.dtype), 0)
        out = jnp.zeros((n, d), xb.dtype).at[token_of[order]].add(contrib)
        out = jax.lax.psum(out, model_ax)          # merge expert shards
        if cfg.n_shared_experts:
            out = out + layers.glu_mlp(xt, shared, cfg.act)

        me = jnp.mean(probs, axis=0)
        fe = jnp.zeros((e,), jnp.float32).at[flat_e].add(1.0) / (n * k)
        aux_loss = e * jnp.sum(me * fe)
        dropped = 1.0 - jnp.sum(keep.astype(jnp.float32)) / (n * k)
        for ax in batch_axes:
            aux_loss = jax.lax.pmean(aux_loss, ax)
            dropped = jax.lax.pmean(dropped, ax)
        return out.reshape(nb, ns, d), aux_loss, dropped

    baxes = (batch_axes if len(batch_axes) > 1
             else (batch_axes[0] if batch_axes else None))
    shared_specs = jax.tree.map(lambda _: P(), p.get("shared", {}))
    from repro import compat
    out, aux_loss, dropped = compat.shard_map(
        local, mesh=mesh,
        in_specs=(P(baxes, None, None), P(), P(model_ax, None, None),
                  P(model_ax, None, None), P(model_ax, None, None),
                  shared_specs),
        out_specs=(P(baxes, None, None), P(), P()),
    )(x, p["router"]["w"], p["gate"], p["up"], p["down"],
      p.get("shared", {}))
    return out, {"aux_loss": aux_loss, "dropped": dropped}


def moe_mlp_auto(x, p, cfg):
    """Dispatch: shard_map EP path under a mesh context with a usable
    "model" axis (divisible experts + batch), else the reference path."""
    from repro.parallel import sharding as shd
    ctx = shd.current_context()
    if (getattr(cfg, "moe_impl", "shard_map") == "shard_map"
            and ctx is not None and "model" in ctx.mesh.shape
            and ctx.mesh.shape["model"] > 1
            and cfg.n_experts % ctx.mesh.shape["model"] == 0):
        baxes = tuple(a for a in ("pod", "data") if a in ctx.mesh.shape)
        nb = 1
        for a in baxes:
            nb *= ctx.mesh.shape[a]
        if x.shape[0] % nb == 0:
            return moe_mlp_sharded(x, p, cfg)
    return moe_mlp(x, p, cfg)


def moe_mlp_dense_ref(x, p, cfg):
    """O(E) dense reference (every expert on every token) — oracle for the
    dispatch path (exact when nothing is dropped)."""
    b, s, d = x.shape
    xt = x.reshape(b * s, d)
    logits = xt.astype(jnp.float32) @ p["router"]["w"]
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, cfg.top_k)
    if cfg.norm_topk:
        top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)
    w = jnp.zeros_like(probs).at[jnp.arange(xt.shape[0])[:, None],
                                 top_i].set(top_p)              # [N, E]
    g = jnp.einsum("nd,edf->enf", xt, p["gate"].astype(x.dtype))
    u = jnp.einsum("nd,edf->enf", xt, p["up"].astype(x.dtype))
    ye = jnp.einsum("enf,efd->end", jax.nn.silu(g) * u,
                    p["down"].astype(x.dtype))
    out = jnp.einsum("end,ne->nd", ye, w.astype(x.dtype))
    if cfg.n_shared_experts:
        out = out + layers.glu_mlp(xt, p["shared"], cfg.act)
    return out.reshape(b, s, d)
