"""Regression gate for BENCH_engine.json: compare a fresh run against the
committed baseline and fail on a >20% slowdown.

CI runners vary wildly in absolute wall-clock, so the gated metric is each
shape's *speedup ratio* (scan driver vs fused engine, measured back-to-back
on the same machine in the same process) — it self-normalizes for machine
speed while still catching real regressions in the fused hot path (a 20%
drop in speedup means the fused side got ~20% slower relative to the
untouched scan baseline).  Counts must also still match exactly.

The ratio normalizes machine SPEED, not relative op costs: if a runner
class proves systematically cheaper/dearer on the gather-heavy pair-index
path than the machine that produced the committed baseline, regenerate
BENCH_engine.json on that runner class (or raise --tolerance) rather than
letting the gate flap.

    python benchmarks/check_bench_regression.py \
        --baseline BENCH_engine.json.committed --new BENCH_engine.json
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys

TOLERANCE = 0.20  # fail when speedup drops more than this fraction


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", required=True,
                    help="committed BENCH_engine.json (pre-run copy)")
    ap.add_argument("--new", required=True,
                    help="freshly produced BENCH_engine.json")
    ap.add_argument("--tolerance", type=float, default=TOLERANCE)
    args = ap.parse_args()

    base = json.loads(pathlib.Path(args.baseline).read_text())
    new = json.loads(pathlib.Path(args.new).read_text())
    failures = []
    base_shapes = base.get("shapes", {})
    new_shapes = new.get("shapes", {})
    # shapes a NEWER bench emits that the committed baseline predates are
    # fine (the next baseline refresh picks them up) — warn, don't fail,
    # and never KeyError on them
    for name in sorted(set(new_shapes) - set(base_shapes)):
        print(f"  [NEW] {name}: not in committed baseline — not gated")
    for name, b in base_shapes.items():
        n = new_shapes.get(name)
        if n is None:
            failures.append(f"{name}: shape missing from new run")
            continue
        if not n.get("match", False):
            failures.append(f"{name}: fused/scan counts diverged")
            continue
        if "speedup" not in b:
            # non-ratio shapes (e.g. the session plan-cache entry) carry
            # no scan/fused speedup; their gate is the match flag above
            print(f"  [OK ] {name}: no speedup ratio (match-only gate)")
            continue
        if "speedup" not in n:
            # the baseline gated a ratio here — a new run silently losing
            # it would disable the gate, so treat it as a failure
            failures.append(f"{name}: 'speedup' missing from new run "
                            "(baseline has one)")
            continue
        floor = b["speedup"] * (1.0 - args.tolerance)
        status = "OK " if n["speedup"] >= floor else "REG"
        print(f"  [{status}] {name}: speedup {b['speedup']:.2f}x -> "
              f"{n['speedup']:.2f}x (floor {floor:.2f}x)")
        if n["speedup"] < floor:
            failures.append(
                f"{name}: speedup regressed {b['speedup']:.2f}x -> "
                f"{n['speedup']:.2f}x (> {args.tolerance:.0%} slowdown)")
    # absolute floor (not baseline-relative): the calibrated default plan
    # must never lose to the forced all-binary cascade.  The bench pins
    # the ratio to exactly 1.0 when the calibrated pick IS the cascade
    # (identical plans), so >= 1.0 only fails when a genuinely slower
    # root was picked — a calibration or executor regression.
    c4 = new_shapes.get("cascade_4way", {})
    if "ir_vs_binary" in c4:
        status = "OK " if c4["ir_vs_binary"] >= 1.0 else "REG"
        print(f"  [{status}] cascade_4way: ir_vs_binary "
              f"{c4['ir_vs_binary']:.2f}x (floor 1.00x, absolute)")
        if c4["ir_vs_binary"] < 1.0:
            failures.append(
                f"cascade_4way: calibrated default plan slower than the "
                f"all-binary cascade ({c4['ir_vs_binary']:.2f}x < 1.0)")
    elif "cascade_4way" in base_shapes and "ir_vs_binary" in base_shapes[
            "cascade_4way"]:
        failures.append("cascade_4way: 'ir_vs_binary' missing from new "
                        "run (baseline has one)")
    # NOTE: the claim_* booleans in the JSON are a record, not a gate here —
    # the per-shape speedup-ratio floor above is the regression signal
    # (absolute claim thresholds re-checked on a noisy runner would flap).
    if failures:
        print("BENCH REGRESSION:\n  " + "\n  ".join(failures))
        return 1
    print("bench regression gate: all shapes within tolerance")
    return 0


if __name__ == "__main__":
    sys.exit(main())
