"""Standing queries + ingest: delta execution == from-scratch, exactly.

Tentpole property: for any query kind and any append schedule,
``watch(q); append*(deltas); snapshot()`` equals executing the final state
from scratch — with ``overflowed == False`` on every delta round.  Also
covers the ingest API itself (append is THE mutation point; direct array
mutation raises; versions bump; sketches update incrementally) and the
plan-cache drift behavior under incremental sketch updates (±5% absorbs
into delta execution, a ≥4x resize re-plans + refreshes).
"""

import dataclasses
from collections import defaultdict

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from conftest import make_rel, skewed_keys
from repro.core import sketches
from repro.core.query import Query
from repro.core.relation import Relation
from repro.core.session import JoinSession, QueryResult
from repro.core.streaming import (
    StandingQuery, mask_to_families, touched_families)


# --------------------------------------------------------------------------
# oracles (independent of the engine)
# --------------------------------------------------------------------------

def _np_cols(rel, cols):
    ok = np.asarray(rel.valid)
    return {c: np.asarray(rel.col(c))[ok] for c in cols}


def oracle_linear(r, s, t):
    rd, sd, td = (_np_cols(r, ("b",)), _np_cols(s, ("b", "c")),
                  _np_cols(t, ("c",)))
    rb = defaultdict(int)
    for v in rd["b"].tolist():
        rb[v] += 1
    tc = defaultdict(int)
    for v in td["c"].tolist():
        tc[v] += 1
    return sum(rb.get(b, 0) * tc.get(c, 0)
               for b, c in zip(sd["b"].tolist(), sd["c"].tolist()))


def oracle_cyclic(r, s, t):
    rd = _np_cols(r, ("a", "b"))
    sd = _np_cols(s, ("b", "c"))
    td = _np_cols(t, ("c", "a"))
    sc = defaultdict(list)
    for b, c in zip(sd["b"].tolist(), sd["c"].tolist()):
        sc[b].append(c)
    ta = defaultdict(int)
    for c, a in zip(td["c"].tolist(), td["a"].tolist()):
        ta[(c, a)] += 1
    total = 0
    for a, b in zip(rd["a"].tolist(), rd["b"].tolist()):
        for c in sc.get(b, ()):
            total += ta.get((c, a), 0)
    return total


def oracle_star(f, d1, d2):
    fd = _np_cols(f, ("a", "b"))
    c1 = defaultdict(int)
    for v in _np_cols(d1, ("a",))["a"].tolist():
        c1[v] += 1
    c2 = defaultdict(int)
    for v in _np_cols(d2, ("b",))["b"].tolist():
        c2[v] += 1
    return sum(c1.get(a, 0) * c2.get(b, 0)
               for a, b in zip(fd["a"].tolist(), fd["b"].tolist()))


# --------------------------------------------------------------------------
# ingest API: append is THE mutation point
# --------------------------------------------------------------------------

def test_append_is_only_mutation_point(rng):
    rel, _ = make_rel(rng, 50, ("a", "b"), 10)
    with pytest.raises(TypeError):
        rel.columns["a"] = jnp.zeros(50, jnp.int32)
    with pytest.raises(TypeError):
        del rel.columns["a"]
    with pytest.raises(dataclasses.FrozenInstanceError):
        rel.valid = jnp.zeros(50, bool)


def test_append_schema_and_shape_checks(rng):
    rel, _ = make_rel(rng, 20, ("a", "b"), 10)
    with pytest.raises(ValueError, match="schema"):
        rel.append(a=np.arange(3, dtype=np.int32))
    with pytest.raises(ValueError, match="ragged"):
        rel.append(a=np.arange(3, dtype=np.int32),
                   b=np.arange(4, dtype=np.int32))


def test_append_versions_capacity_and_rows(rng):
    rel, data = make_rel(rng, 60, ("a", "b"), 10)
    assert rel.version == 0
    delta = rel.append(a=np.arange(5, dtype=np.int32),
                       b=np.arange(5, dtype=np.int32))
    assert rel.version == 1
    assert int(delta.n) == 5
    assert int(rel.n) == 65
    # capacity grows along power-of-two buckets
    assert rel.capacity == 128
    # live rows keep the original data then the delta, as a valid prefix
    a = np.asarray(rel.col("a"))[np.asarray(rel.valid)]
    np.testing.assert_array_equal(a[:60], data["a"])
    np.testing.assert_array_equal(a[60:], np.arange(5))
    # in-bucket appends do not re-grow
    rel.append(a=np.arange(3, dtype=np.int32),
               b=np.arange(3, dtype=np.int32))
    assert rel.capacity == 128 and rel.version == 2


def test_append_updates_sketches_incrementally(rng):
    rel, _ = make_rel(rng, 200, ("a", "b"), 64)
    before = rel.distinct_sketch("a")          # force + cache
    new = rng.integers(64, 128, 40).astype(np.int32)
    rel.append(a=new, b=rng.integers(0, 64, 40).astype(np.int32))
    got = rel.distinct_sketch("a")
    want = sketches.add(sketches.empty(), rel.col("a"), rel.valid)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # and the incremental update actually changed the registers
    assert not np.array_equal(np.asarray(before), np.asarray(got))


def test_append_observers_fire_and_unregister(rng):
    rel, _ = make_rel(rng, 30, ("a", "b"), 10)
    seen = []
    cb = lambda r, d: seen.append(int(d.n))  # noqa: E731
    rel.on_append(cb)
    rel.append(a=np.arange(4, dtype=np.int32),
               b=np.arange(4, dtype=np.int32))
    assert seen == [4]
    rel.remove_on_append(cb)
    rel.append(a=np.arange(2, dtype=np.int32),
               b=np.arange(2, dtype=np.int32))
    assert seen == [4]


# --------------------------------------------------------------------------
# family masking is exact
# --------------------------------------------------------------------------

def test_family_mask_keeps_all_possible_matches(rng):
    rel, rd = make_rel(rng, 500, ("b", "c"), 120)
    delta = Relation.from_arrays(b=rng.integers(0, 30, 16).astype(np.int32),
                                 c=rng.integers(0, 30, 16).astype(np.int32))
    touched = touched_families(delta, "b")
    masked = mask_to_families(rel, "b", touched)
    kept = set(np.asarray(masked.col("b"))[np.asarray(masked.valid)]
               .tolist())
    # every row whose key occurs in the delta must survive the mask
    for v in np.asarray(delta.col("b")).tolist():
        rows = np.asarray(rel.col("b"))[np.asarray(rel.valid)] == v
        if rows.any():
            assert v in kept
    assert int(masked.n) <= int(rel.n)


# --------------------------------------------------------------------------
# tentpole property: snapshot == from-scratch across kinds
# --------------------------------------------------------------------------

def _mk(rng, n, d, cols):
    return Relation.from_arrays(
        **{c: rng.integers(0, d, n).astype(np.int32) for c in cols})


@settings(deadline=None, max_examples=6)
@given(kind=st.sampled_from(["linear", "cyclic", "star"]),
       seed=st.integers(0, 2**31 - 1),
       n_deltas=st.integers(1, 3))
def test_standing_query_matches_from_scratch(kind, seed, n_deltas):
    rng = np.random.default_rng(seed)
    n, d = 400, 80
    if kind == "linear":
        rels = {"R": _mk(rng, n, d, ("a", "b")),
                "S": _mk(rng, n, d, ("b", "c")),
                "T": _mk(rng, n, d, ("c", "e"))}
        preds = [("R.b", "S.b"), ("S.c", "T.c")]
        oracle = lambda: oracle_linear(rels["R"], rels["S"], rels["T"])  # noqa: E731
    elif kind == "cyclic":
        rels = {"R": _mk(rng, n, d, ("a", "b")),
                "S": _mk(rng, n, d, ("b", "c")),
                "T": _mk(rng, n, d, ("c", "a"))}
        preds = [("R.b", "S.b"), ("S.c", "T.c"), ("T.a", "R.a")]
        oracle = lambda: oracle_cyclic(rels["R"], rels["S"], rels["T"])  # noqa: E731
    else:
        rels = {"F": _mk(rng, 4 * n, d, ("a", "b")),
                "D1": _mk(rng, d, d, ("a", "x")),
                "D2": _mk(rng, d, d, ("b", "y"))}
        preds = [("F.a", "D1.a"), ("F.b", "D2.b")]
        oracle = lambda: oracle_star(rels["F"], rels["D1"], rels["D2"])  # noqa: E731
    q = Query(rels, preds)
    sess = JoinSession(m_budget=128)
    sq = sess.watch(q)
    assert sq.count == oracle()
    names = list(rels)
    for i in range(n_deltas):
        name = names[int(rng.integers(0, len(names)))]
        rel = rels[name]
        k = int(rng.integers(1, 60))
        rel.append(**{c: rng.integers(0, d, k).astype(np.int32)
                      for c in rel.columns})
        assert not sq.delta_rounds[-1].overflowed
    snap = sq.snapshot()
    assert isinstance(snap, QueryResult)
    assert int(snap.count) == oracle()
    assert int(JoinSession(m_budget=128).execute(q).count) == oracle()
    assert not bool(snap.overflowed)
    sq.close()


def test_standing_query_adversarial_skew_delta(rng):
    """A delta that is one giant heavy hitter: the per-round recovery
    contract must hold (overflowed False, exact count)."""
    n, d = 600, 100
    R = _mk(rng, n, d, ("a", "b"))
    S = _mk(rng, n, d, ("b", "c"))
    T = _mk(rng, n, d, ("c", "e"))
    q = Query({"R": R, "S": S, "T": T}, [("R.b", "S.b"), ("S.c", "T.c")])
    sq = JoinSession(m_budget=128).watch(q)
    S.append(b=skewed_keys(rng, 80, d, 0.9),
             c=skewed_keys(rng, 80, d, 0.9, 2))
    rec = sq.delta_rounds[-1]
    assert not rec.overflowed
    assert int(sq.snapshot().count) == oracle_linear(R, S, T)
    sq.close()


def test_standing_query_cascade_merges_intermediates(rng):
    """Forced-cascade plans keep the binary %i intermediates resident and
    append-merge each delta's contribution instead of recomputing."""
    n, d = 500, 90
    R = _mk(rng, n, d, ("a", "b"))
    S = _mk(rng, n, d, ("b", "c"))
    T = _mk(rng, n, d, ("c", "e"))
    q = Query({"R": R, "S": S, "T": T}, [("R.b", "S.b"), ("S.c", "T.c")])
    sq = JoinSession(m_budget=128).watch(q, strategy="cascade")
    assert sq._intermediates            # cascade materialized %i0
    resident = next(iter(sq._intermediates.values()))
    rows0 = int(resident.n)
    R.append(a=rng.integers(0, d, 40).astype(np.int32),
             b=rng.integers(0, d, 40).astype(np.int32))
    assert not sq.delta_rounds[-1].replanned
    assert int(resident.n) >= rows0     # merged, not rebuilt
    assert int(sq.snapshot().count) == oracle_linear(R, S, T)
    sq.close()


def test_standing_query_4way_chain(rng):
    n, d = 400, 80
    rels = {"A": _mk(rng, n, d, ("a", "b")), "B": _mk(rng, n, d, ("b", "c")),
            "C": _mk(rng, n, d, ("c", "e")), "D": _mk(rng, n, d, ("e", "f"))}
    q = Query(rels, [("A.b", "B.b"), ("B.c", "C.c"), ("C.e", "D.e")])
    sq = JoinSession(m_budget=128).watch(q)
    for name in ("A", "C", "D"):
        rels[name].append(**{c: rng.integers(0, d, 30).astype(np.int32)
                             for c in rels[name].columns})
    assert int(sq.snapshot().count) == int(
        JoinSession(m_budget=128).execute(q).count)
    sq.close()


def test_aliased_relation_falls_back_to_refresh(rng):
    """One object bound under two names: the single-occurrence delta rule
    does not apply, so the standing query must full-refresh (exactly)."""
    n, d = 300, 60
    X = _mk(rng, n, d, ("a", "b"))
    Y = _mk(rng, n, d, ("b", "a"))
    q = Query({"P": X, "Q": Y, "P2": X}, [("P.b", "Q.b"), ("Q.a", "P2.a")])
    sq = JoinSession(m_budget=128).watch(q)
    X.append(a=rng.integers(0, d, 25).astype(np.int32),
             b=rng.integers(0, d, 25).astype(np.int32))
    assert sq.delta_rounds[-1].replanned      # refresh path taken
    assert int(sq.snapshot().count) == int(
        JoinSession(m_budget=128).execute(q).count)
    sq.close()


# --------------------------------------------------------------------------
# drift: small deltas keep the plan, big resizes re-plan + refresh
# --------------------------------------------------------------------------

def test_small_drift_keeps_plan_big_drift_replans(rng):
    n, d = 1000, 150
    R = _mk(rng, n, d, ("a", "b"))
    S = _mk(rng, n, d, ("b", "c"))
    T = _mk(rng, n, d, ("c", "e"))
    q = Query({"R": R, "S": S, "T": T}, [("R.b", "S.b"), ("S.c", "T.c")])
    sess = JoinSession(m_budget=128)
    sq = sess.watch(q)
    plan0 = sq._plan
    # ±5%-scale delta: same log-bucketed cache key, no re-plan
    R.append(a=rng.integers(0, d, 30).astype(np.int32),
             b=rng.integers(0, d, 30).astype(np.int32))
    assert not sq.delta_rounds[-1].replanned
    assert sq._plan is plan0
    # ≥4x growth in one relation: key moves, session re-plans, the
    # standing query refreshes off the fresh plan
    k = 4 * n
    T.append(c=rng.integers(0, d, k).astype(np.int32),
             e=rng.integers(0, d, k).astype(np.int32))
    assert sq.delta_rounds[-1].replanned
    assert sq._plan is not plan0
    assert int(sq.snapshot().count) == oracle_linear(R, S, T)
    sq.close()


def test_drift_replan_uses_incremental_sketches(rng):
    """After heavy ingest the re-plan sees fresh FM distinct estimates
    without any host scan: the incrementally-updated sketch equals a
    from-scratch rebuild, so the session's cards/d estimates agree."""
    rel, _ = make_rel(rng, 400, ("a", "b"), 50)
    rel.distinct_sketch("a")
    rel.append(a=rng.integers(50, 400, 1600).astype(np.int32),
               b=rng.integers(0, 50, 1600).astype(np.int32))
    est_inc = rel.distinct_estimate("a")
    rebuilt = int(round(float(sketches.fm_estimate(sketches.add(
        sketches.empty(), rel.col("a"), rel.valid)))))
    assert est_inc == max(1, min(rebuilt, rel.capacity))


# --------------------------------------------------------------------------
# unbounded accumulation stays int64-exact
# --------------------------------------------------------------------------

def test_totals_accumulate_in_python_ints(rng):
    n, d = 300, 40
    R = _mk(rng, n, d, ("a", "b"))
    S = _mk(rng, n, d, ("b", "c"))
    T = _mk(rng, n, d, ("c", "e"))
    q = Query({"R": R, "S": S, "T": T}, [("R.b", "S.b"), ("S.c", "T.c")])
    sq = JoinSession(m_budget=128).watch(q)
    # simulate a long-lived standing query whose accumulated totals have
    # outgrown int32: the int64-typed snapshot must carry them exactly
    sq._tuples += 2**40
    snap = sq.snapshot()
    assert np.asarray(snap.tuples_read).dtype == np.int64
    assert int(snap.tuples_read) > 2**40
    sq.close()


def test_watch_requires_session():
    rng = np.random.default_rng(0)
    R = _mk(rng, 100, 20, ("a", "b"))
    S = _mk(rng, 100, 20, ("b", "c"))
    T = _mk(rng, 100, 20, ("c", "e"))
    q = Query({"R": R, "S": S, "T": T}, [("R.b", "S.b"), ("S.c", "T.c")])
    sq = JoinSession(m_budget=64).watch(q)
    assert isinstance(sq, StandingQuery)
    sq.close()
    # closed handles ignore further ingest
    before = len(sq.delta_rounds)
    R.append(a=np.arange(5, dtype=np.int32), b=np.arange(5, dtype=np.int32))
    assert len(sq.delta_rounds) == before
