import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding
mesh = jax.make_mesh((16, 16), ("data", "model"), axis_types=(jax.sharding.AxisType.Auto,)*2)

# heads=56 over model=16 (uneven), batch=16 over data=16 (even)
x = jax.ShapeDtypeStruct((16, 56, 128, 64), jnp.bfloat16)
w = jax.ShapeDtypeStruct((64, 56, 128), jnp.bfloat16)
def f(x, w):
    return jnp.einsum("bhsd,dhe->bhse", x, w)
try:
    c = jax.jit(f,
        in_shardings=(NamedSharding(mesh, P("data", "model", None, None)),
                      NamedSharding(mesh, P(None, "model", None))),
        out_shardings=NamedSharding(mesh, P("data", "model", None, None)),
    ).lower(x, w).compile()
    print("HEAD-UNEVEN OK")
except Exception as e:
    print("HEAD-UNEVEN FAILED:", str(e)[:300])

# internal-only uneven: inputs replicated on that dim, constraint inside
def g(x, w):
    y = jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P("data", "model", None, None)))
    return jnp.einsum("bhsd,dhe->bhse", y, w)
try:
    c = jax.jit(g,
        in_shardings=(NamedSharding(mesh, P("data", None, None, None)),
                      NamedSharding(mesh, P(None, None, None))),
    ).lower(x, w).compile()
    print("INTERNAL-UNEVEN OK")
except Exception as e:
    print("INTERNAL-UNEVEN FAILED:", str(e)[:300])
