"""Shared skew-recovery round engine (the paper's §5 skew handling, unified).

Every multiway kind (linear §4, cyclic §5, star §6.5) recovers from bucket
overflow the same way — only the partition geometry differs.  This module
owns the round loop once; ``engine.MultiwayJoinEngine`` binds it to a kind
via a small :class:`KindOps` adapter.

The recovery-round contract
---------------------------
Per round ``rnd`` (salt = ``base_salt + rnd``):

1. **One hashing pass per relation.**  ``partition.composite_ids`` is called
   exactly once per relation per round; everything else in the round derives
   from those ids:

   * the exact per-bucket histogram (``np.bincount`` of the ids) — used for
     capacity sizing and overflow detection,
   * the salted bucket layout (``partition.bucketize_by_ids`` re-uses the
     ids — no re-hash),
   * the residual mask (the coarse cell of a row is id arithmetic:
     ``ids // inner_buckets`` — no re-hash).

   Earlier revisions re-hashed each relation 2–3× per round (layouts,
   histograms and residual masks each hashed independently); tests pin the
   one-pass property with a call-count probe on ``composite_ids`` /
   ``hashing.hash_bucket``.

2. **Exact partials are kept.**  Coarse cells whose buckets all fit are
   final: their fused partial counts are accumulated and never recomputed.
   Each output tuple is owned by exactly one row of the kind's *driving*
   relation (R for linear/cyclic, S for star), and that row lives in exactly
   one coarse cell per round, so kept partials never double count.

3. **Overflowed cells re-run.**  Rows of the driving relation in overflowed
   cells stay valid for the next round; everything else is masked out.  The
   next round re-partitions them with a fresh salt and geometrically grown
   capacities.

4. **The final round cannot overflow.**  Round ``max_rounds`` sizes every
   capacity from the exact histogram of that round's ids, so
   ``overflowed == False`` is a postcondition, not a hope.

Totals are accumulated host-side in Python ints and returned as
``np.int64`` — the fused kernels produce int32 *per-cell* partials (each
cell must stay below 2^31, which VMEM-bounded bucket capacities guarantee),
but the query total routinely exceeds int32 on large-cardinality joins.

Multi-step plans (``core.plan_ir``) wrap every fused 3-way step in this
round loop independently: a skewed materialized intermediate entering a
fused root is recovered exactly like a skewed base relation, because the
loop only ever sees (Relation, shape plan, KindOps) — it has no notion of
where its inputs came from.
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

from repro.core import partition
from repro.core.relation import Relation
from repro.core.results import JoinResult, PerRResult  # noqa: F401 (re-export)
from repro.kernels import ops as kops

# Internal alias (see core.results): the recovery loop's scalar result IS
# the unified JoinResult — kept under the engine layer's historical name.
EngineResult = JoinResult


class RelPass(NamedTuple):
    """One relation's single hashing pass for one round."""
    ids: jnp.ndarray             # flat composite bucket id per row
    nb: int                      # number of flat buckets
    hist: np.ndarray             # exact per-bucket histogram, out_shape
    out_shape: tuple


def _align(n: int, align: int = 8) -> int:
    return max(align, int(math.ceil(n / align)) * align)


def grown(plan, growth: float, align: int = 8):
    """Geometric per-round bucket-capacity growth for re-run shards."""
    caps = {f: getattr(plan, f) for f in ("r_cap", "s_cap", "t_cap")}
    caps = {f: int(math.ceil(c * growth / align) * align)
            for f, c in caps.items()}
    return plan._replace(**caps)


def exact_cap(hist: np.ndarray) -> int:
    return _align(max(int(hist.max(initial=0)), 1))


def hash_pass(rel: Relation, specs, out_shape: tuple, salt: int) -> RelPass:
    """THE hashing pass: composite ids + the exact histogram derived from
    them.  Everything else a round needs re-uses the returned ids."""
    ids, nb = partition.composite_ids(rel, specs, salt)
    hist = np.bincount(np.asarray(ids), minlength=nb + 1)[:nb]
    return RelPass(ids, nb, hist.reshape(out_shape), out_shape)


def layout(rel: Relation, p: RelPass, cap: int) -> partition.Buckets:
    """Bucketize from an existing pass — zero additional hashing."""
    return partition.bucketize_by_ids(rel, p.ids, p.nb, cap, p.out_shape)


def cell_of(p: RelPass, inner: int, n_cells: int) -> np.ndarray:
    """Coarse-cell id per row from composite-id arithmetic (no re-hash).
    Invalid rows land on a clipped cell; callers AND with ``rel.valid``."""
    return np.clip(np.asarray(p.ids) // inner, 0, n_cells - 1)


# ==========================================================================
# kind adapters
# ==========================================================================

class LinearOps:
    """R(aB) ⋈ S(BC) ⋈ T(Cd): coarse cells are the H(B) partitions; the
    driving relation is R (T is shared by every cell and therefore exact-
    sized from its histogram every round — H-splitting cannot recover it)."""

    kind = "linear"
    driving = "r"

    def __init__(self, rb="b", sb="b", sc="c", tc="c"):
        self.rb, self.sb, self.sc, self.tc = rb, sb, sc, tc

    def specs(self, plan):
        hp, u, gp = plan.h_parts, plan.u, plan.g_parts
        return {
            "r": ([(self.rb, hp, "H"), (self.rb, u, "h")], (hp, u)),
            "s": ([(self.sb, hp, "H"), (self.sc, gp, "g"),
                   (self.sb, u, "h")], (hp, gp, u)),
            "t": ([(self.tc, gp, "g")], (gp,)),
        }

    def size_caps(self, plan, passes, final):
        plan = plan._replace(
            t_cap=max(plan.t_cap, exact_cap(passes["t"].hist)))
        if final:
            plan = plan._replace(r_cap=exact_cap(passes["r"].hist),
                                 s_cap=exact_cap(passes["s"].hist))
        return plan

    def count(self, L, plan, use_kernel):
        return kops.fused_count3_linear(
            L["r"].columns[self.rb], L["r"].valid, L["s"].columns[self.sb],
            L["s"].columns[self.sc], L["s"].valid, L["t"].columns[self.tc],
            L["t"].valid, use_kernel=use_kernel)                  # [hp, u]

    def bad_cells(self, passes, plan):
        return ((passes["r"].hist > plan.r_cap).any(axis=1)
                | (passes["s"].hist > plan.s_cap).any(axis=(1, 2)))  # [hp]

    def good_weight(self, bad):
        return ~bad[:, None]                                      # [hp, u]

    def residual(self, rels, passes, bad, plan):
        hp = plan.h_parts
        r_cell = cell_of(passes["r"], plan.u, hp)
        s_cell = cell_of(passes["s"], plan.g_parts * plan.u, hp)
        return {**rels,
                "r": rels["r"].mask_where(jnp.asarray(bad[r_cell])),
                "s": rels["s"].mask_where(jnp.asarray(bad[s_cell]))}

    def tuples_read(self, rels, plan):
        return (int(rels["r"].n) + int(rels["s"].n)
                + plan.h_parts * int(rels["t"].n))


class CyclicOps:
    """R(AB) ⋈ S(BC) ⋈ T(CA) triangles: coarse cells are the H(A)×G(B)
    grid; R drives.  An S column / T row overflow taints every cell that
    reads it."""

    kind = "cyclic"
    driving = "r"

    def __init__(self, ra="a", rb="b", sb="b", sc="c", tc="c", ta="a",
                 pair_index=True):
        self.ra, self.rb, self.sb = ra, rb, sb
        self.sc, self.tc, self.ta = sc, tc, ta
        self.pair_index = pair_index

    def specs(self, plan):
        hp, gp, uh, ug, fp = (plan.h_parts, plan.g_parts, plan.uh, plan.ug,
                              plan.f_parts)
        return {
            "r": ([(self.ra, hp, "H"), (self.rb, gp, "G"),
                   (self.ra, uh, "h"), (self.rb, ug, "g")], (hp, gp, uh, ug)),
            "s": ([(self.sb, gp, "G"), (self.sc, fp, "f"),
                   (self.sb, ug, "g")], (gp, fp, ug)),
            "t": ([(self.ta, hp, "H"), (self.tc, fp, "f"),
                   (self.ta, uh, "h")], (hp, fp, uh)),
        }

    def size_caps(self, plan, passes, final):
        if final:
            plan = plan._replace(r_cap=exact_cap(passes["r"].hist),
                                 s_cap=exact_cap(passes["s"].hist),
                                 t_cap=exact_cap(passes["t"].hist))
        return plan

    def count(self, L, plan, use_kernel):
        return kops.fused_count3_cyclic(
            L["r"].columns[self.ra], L["r"].columns[self.rb], L["r"].valid,
            L["s"].columns[self.sb], L["s"].columns[self.sc], L["s"].valid,
            L["t"].columns[self.tc], L["t"].columns[self.ta], L["t"].valid,
            use_kernel=use_kernel,
            pair_index=self.pair_index)               # [hp, gp, uh, ug]

    def bad_cells(self, passes, plan):
        r_bad = (passes["r"].hist > plan.r_cap).any(axis=(2, 3))  # [hp, gp]
        s_bad = (passes["s"].hist > plan.s_cap).any(axis=(1, 2))  # [gp]
        t_bad = (passes["t"].hist > plan.t_cap).any(axis=(1, 2))  # [hp]
        return r_bad | s_bad[None, :] | t_bad[:, None]

    def good_weight(self, bad):
        return ~bad[:, :, None, None]

    def residual(self, rels, passes, bad, plan):
        n_cells = plan.h_parts * plan.g_parts
        r_cell = cell_of(passes["r"], plan.uh * plan.ug, n_cells)
        return {**rels,
                "r": rels["r"].mask_where(
                    jnp.asarray(bad.reshape(-1)[r_cell]))}

    def tuples_read(self, rels, plan):
        return (int(rels["r"].n) + plan.h_parts * int(rels["s"].n)
                + plan.g_parts * int(rels["t"].n))


class StarOps:
    """Dimension R(aB), fact S(BC), dimension T(Cd): coarse cells are the
    uh×ug PMU grid; the fact relation S drives (each output tuple owns
    exactly one fact row)."""

    kind = "star"
    driving = "s"

    def __init__(self, rb="b", sb="b", sc="c", tc="c"):
        self.rb, self.sb, self.sc, self.tc = rb, sb, sc, tc

    def specs(self, plan):
        return {
            "r": ([(self.rb, plan.uh, "h")], (plan.uh,)),
            "t": ([(self.tc, plan.ug, "g")], (plan.ug,)),
        }

    def s_pass(self, rel, plan, salt):
        """S adds an arrival-order chunk level on top of the hashed
        (h(B), g(C)) pair — composed arithmetically, still ONE hash pass."""
        uh, ug, ch = plan.uh, plan.ug, plan.chunks
        ids2, nb2 = partition.composite_ids(
            rel, [(self.sb, uh, "h"), (self.sc, ug, "g")], salt)
        chunk = jnp.where(
            rel.valid,
            (jnp.arange(rel.capacity, dtype=jnp.int32) * ch) // rel.capacity,
            0)
        nb = ch * nb2
        ids = jnp.where(rel.valid,
                        chunk * nb2 + jnp.clip(ids2, 0, nb2 - 1),
                        jnp.int32(nb))
        hist = np.bincount(np.asarray(ids), minlength=nb + 1)[:nb]
        return RelPass(ids, nb, hist.reshape(ch, uh, ug), (ch, uh, ug))

    def size_caps(self, plan, passes, final):
        if final:
            plan = plan._replace(r_cap=exact_cap(passes["r"].hist),
                                 s_cap=exact_cap(passes["s"].hist),
                                 t_cap=exact_cap(passes["t"].hist))
        return plan

    def count(self, L, plan, use_kernel):
        return kops.fused_count3_star(
            L["r"].columns[self.rb], L["r"].valid, L["s"].columns[self.sb],
            L["s"].columns[self.sc], L["s"].valid, L["t"].columns[self.tc],
            L["t"].valid, use_kernel=use_kernel)                  # [uh, ug]

    def bad_cells(self, passes, plan):
        r_bad = passes["r"].hist > plan.r_cap                     # [uh]
        t_bad = passes["t"].hist > plan.t_cap                     # [ug]
        s_bad = (passes["s"].hist > plan.s_cap).any(axis=0)       # [uh, ug]
        return r_bad[:, None] | t_bad[None, :] | s_bad

    def good_weight(self, bad):
        return ~bad

    def residual(self, rels, passes, bad, plan):
        uh, ug = plan.uh, plan.ug
        s_cell = np.asarray(passes["s"].ids) % (uh * ug)
        s_cell = np.clip(s_cell, 0, uh * ug - 1)
        return {**rels,
                "s": rels["s"].mask_where(
                    jnp.asarray(bad.reshape(-1)[s_cell]))}

    def tuples_read(self, rels, plan):
        return int(rels["r"].n) + int(rels["s"].n) + int(rels["t"].n)


OPS = {"linear": LinearOps, "cyclic": CyclicOps, "star": StarOps}


def ops_from_binding(binding, **kw):
    """Build the KindOps adapter from a ``query.Binding`` — the checked
    column binding replaces the per-kind kwarg soup, so the recovery layer
    and the fused layouts are guaranteed to agree on column roles."""
    return OPS[binding.kind](**binding.col_kwargs(), **kw)


# ==========================================================================
# the round loop
# ==========================================================================

def _round_pass(ops, rels, plan, salt, final):
    """One round's single-hash passes, capacity sizing and layouts."""
    passes = {}
    for key, (specs, out_shape) in ops.specs(plan).items():
        passes[key] = hash_pass(rels[key], specs, out_shape, salt)
    if hasattr(ops, "s_pass"):
        passes["s"] = ops.s_pass(rels["s"], plan, salt)
    plan = ops.size_caps(plan, passes, final)
    caps = {"r": plan.r_cap, "s": plan.s_cap, "t": plan.t_cap}
    layouts = {k: layout(rels[k], passes[k], caps[k]) for k in passes}
    return plan, passes, layouts


def run_count_rounds(ops, r: Relation, s: Relation, t: Relation, plan, *,
                     max_rounds: int = 3, growth: float = 2.0,
                     use_kernel: bool = False,
                     base_salt: int = 0) -> EngineResult:
    """The shared recovery loop: fused sweep, keep exact partials, re-run
    overflowed cells, exact-sized final round (see module docstring)."""
    rels = {"r": r, "s": s, "t": t}
    total, tuples = 0, 0
    for rnd in range(max_rounds + 1):
        final = rnd == max_rounds
        plan, passes, layouts = _round_pass(ops, rels, plan,
                                            base_salt + rnd, final)
        counts = np.asarray(ops.count(layouts, plan, use_kernel),
                            dtype=np.int64)
        bad = ops.bad_cells(passes, plan)
        tuples += ops.tuples_read(rels, plan)
        if final or not bad.any():
            total += int(counts.sum())
            return EngineResult(np.int64(total), jnp.asarray(False),
                                np.int64(tuples), rnd + 1)
        total += int((counts * ops.good_weight(bad)).sum())
        rels = ops.residual(rels, passes, bad, plan)
        plan = grown(plan, growth)
    raise AssertionError("unreachable: final round is exact-sized")


def run_per_r_rounds(ops: LinearOps, r: Relation, s: Relation, t: Relation,
                     plan, *, max_rounds: int = 3, growth: float = 2.0,
                     use_kernel: bool = False, base_salt: int = 0,
                     key_col: str = "a") -> PerRResult:
    """Linear-only per-R-tuple aggregate under the same round contract.
    Emits (keys, counts, valid) aligned with each round's R layout; kept
    slots are those of exact cells (plus everything in the final round)."""
    rels = {"r": r, "s": s, "t": t}
    keys_out, counts_out, valid_out = [], [], []
    rounds, tuples = 0, 0
    for rnd in range(max_rounds + 1):
        final = rnd == max_rounds
        plan, passes, layouts = _round_pass(ops, rels, plan,
                                            base_salt + rnd, final)
        tuples += ops.tuples_read(rels, plan)
        rg = layouts["r"]
        counts = kops.fused_per_r_counts(
            rg.columns[ops.rb], rg.valid, layouts["s"].columns[ops.sb],
            layouts["s"].columns[ops.sc], layouts["s"].valid,
            layouts["t"].columns[ops.tc], layouts["t"].valid,
            use_kernel=use_kernel)                            # [hp, u, Cr]
        bad = ops.bad_cells(passes, plan)
        key = key_col if key_col in rg.columns else ops.rb
        valid = rg.valid
        if bad.any() and not final:
            valid = valid & jnp.asarray(~bad)[:, None, None]
        keys_out.append(rg.columns[key].reshape(-1))
        counts_out.append(np.asarray(counts, dtype=np.int64).reshape(-1))
        valid_out.append(valid.reshape(-1))
        rounds = rnd + 1
        if final or not bad.any():
            break
        rels = ops.residual(rels, passes, bad, plan)
        plan = grown(plan, growth)
    keys = jnp.concatenate(keys_out)
    counts = np.concatenate(counts_out)
    valid = jnp.concatenate(valid_out)
    total = int(counts[np.asarray(valid)].sum())
    return PerRResult(count=np.int64(total), overflowed=jnp.asarray(False),
                      tuples_read=np.int64(tuples), rounds=rounds,
                      keys=keys, counts=counts, valid=valid)
