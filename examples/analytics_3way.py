"""End-to-end analytics driver: the paper's Example 1 (friends-of-friends-
of-friends) and Example 2 (triangles) on a synthetic social graph.

    PYTHONPATH=src python examples/analytics_3way.py [--users 2000] \
        [--friends 40]

Pipeline (all on the join engine, aggregates only — nothing materialized):
  1. generate a friends relation F (n = users·friends edges),
  2. linear self 3-way  F ⋈ F ⋈ F with per-user COUNT + Flajolet-Martin
     DISTINCT sketch (the paper's footnote-4 aggregation),
  3. cyclic 3-way (triangle count) — community cohesion metric,
  4. planner report: what the cost model would pick at Facebook scale.
"""

import argparse
import pathlib
import sys
import time

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent
                       / "src"))

import numpy as np  # noqa: E402

from repro.core import (cost_model, cyclic3, driver, linear3,  # noqa: E402
                        sketches)
from repro.core.relation import Relation  # noqa: E402


def friends_graph(users: int, friends: int, seed: int = 0):
    """Symmetric friendship edges, ~friends per user."""
    rng = np.random.default_rng(seed)
    n_edges = users * friends // 2
    a = rng.integers(0, users, size=n_edges).astype(np.int32)
    b = rng.integers(0, users, size=n_edges).astype(np.int32)
    keep = a != b
    a, b = a[keep], b[keep]
    src = np.concatenate([a, b])
    dst = np.concatenate([b, a])
    return src, dst


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--users", type=int, default=2000)
    ap.add_argument("--friends", type=int, default=40)
    args = ap.parse_args()

    src, dst = friends_graph(args.users, args.friends)
    n = len(src)
    print(f"friends relation: {n} edges over {args.users} users "
          f"(f ≈ {n / args.users:.0f})")

    r = Relation.from_arrays(a=src, b=dst)
    s = Relation.from_arrays(b=src, c=dst)
    t = Relation.from_arrays(c=src, d=dst)

    # --- Example 1: friends-of-friends-of-friends ------------------------
    plan = linear3.default_plan(n, n, n, m_budget=max(n // 4, 2048))
    t0 = time.time()
    res, plan = driver.linear3_count_auto(r, s, t, plan)
    print(f"\nFoFoF paths (COUNT, with duplicates): {int(res.count):,} "
          f"in {time.time() - t0:.2f}s; tuples read on-chip = "
          f"{int(res.tuples_read):,}")

    (keys, counts, valid), _ = driver.linear3_per_r_counts_auto(
        r, s, t, plan)
    k = np.asarray(keys)[np.asarray(valid)]
    c = np.asarray(counts)[np.asarray(valid)]
    top = np.argsort(c)[-5:][::-1]
    print("top-5 users by FoFoF reach (edge-endpoint aggregation):")
    for i in top:
        print(f"   user-edge b={k[i]}: {c[i]:,} paths")

    # FM sketch: approximate DISTINCT d-endpoints over the whole join
    regs, _fm_ovf = linear3.linear3_fm_distinct(r, s, t, plan,
                                                n_registers=64)
    est = sketches.fm_estimate(regs)
    exact_d = len(np.unique(dst))
    print(f"FM-sketch distinct d-endpoints ≈ {est:,.0f} "
          f"(exact {exact_d}; sketch bytes = {64 * 4})")

    # --- Example 2: triangles -------------------------------------------
    t_cyc = Relation.from_arrays(c=src, a=dst)
    cplan = cyclic3.default_plan(n, n, n, m_budget=max(n // 4, 2048))
    t0 = time.time()
    cres, _ = driver.cyclic3_count_auto(r, s, t_cyc, cplan)
    tri = int(cres.count) // 6        # each triangle counted 6x (3! orders)
    print(f"\ntriangles: {tri:,} (raw oriented count {int(cres.count):,}) "
          f"in {time.time() - t0:.2f}s")

    # --- planner at Facebook scale (paper Examples 3/4) ------------------
    print("\nplanner at paper scale (N=6e11, M=16MB-chip -> 1e6 tuples):")
    lin = cost_model.choose_linear_strategy(6e11, 6e11, 6e11, 1e6, 2e9)
    cyc = cost_model.choose_cyclic_strategy(6e11, 6e11, 6e11, 1e6, 2e9)
    print(f"   linear: {lin.strategy} (3way traffic {lin.tuples_3way:.2e} "
          f"vs cascade {lin.tuples_cascade:.2e})")
    print(f"   cyclic: {cyc.strategy} (3way traffic {cyc.tuples_3way:.2e} "
          f"vs cascade {cyc.tuples_cascade:.2e})")
    print("\nanalytics_3way OK")


if __name__ == "__main__":
    main()
