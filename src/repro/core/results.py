"""The unified result hierarchy: every executor answers with a JoinResult.

One query can be answered by four different machines — the recovery-wrapped
fused engine, the multi-step plan executor, a session execute, a standing
query's incremental snapshot — and they historically each had their own
result shape.  This module unifies them around a single common core:

  * :class:`JoinResult` — ``count`` (int64-exact), ``overflowed`` (False by
    construction everywhere recovery runs), ``tuples_read`` (int64 traffic,
    summed over steps and rounds), ``rounds`` (recovery rounds) and
    ``steps`` (per-step ``plan_ir.StepStats``, empty where no plan walked).
  * :class:`~repro.core.session.QueryResult` — the session's answer:
    JoinResult plus plan/cache/timing metadata.  ``JoinSession.execute``,
    ``execute_sharded`` and ``StandingQuery.snapshot`` all return it.
  * :class:`PerRResult` — per-R-tuple group counts (paper Example 1):
    JoinResult (``count`` is the valid per-key sum) plus the aligned
    (keys, counts, valid) arrays.

``recovery.EngineResult`` is an internal alias of :class:`JoinResult` kept
for the engine layer's own call sites; new code should name JoinResult.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class JoinResult:
    """Common result core shared by every join entry point."""

    count: object                 # np.int64 — exact cardinality (> 2^31 safe)
    overflowed: object            # bool / () bool — False after recovery
    tuples_read: object           # np.int64 | None — traffic over steps/rounds
    rounds: int                   # recovery rounds executed (1 = no skew)
    steps: tuple = ()             # per-step plan_ir.StepStats, if a plan ran

    @property
    def step_stats(self) -> tuple:
        """Back-compat alias for ``steps`` (the pre-unification name)."""
        return self.steps


@dataclasses.dataclass(frozen=True, kw_only=True)
class PerRResult(JoinResult):
    """Per-R-tuple aggregate: ``count`` is the valid per-key sum and the
    aligned (keys, counts, valid) arrays carry the group breakdown."""

    keys: object                  # [N] int32 carried key column (flattened)
    counts: object                # [N] int64 per-R-tuple counts
    valid: object                 # [N] bool
