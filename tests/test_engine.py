"""MultiwayJoinEngine: fused sweeps vs scan drivers vs kernels/ref.py,
plus the skew-recovery guarantee (exact counts, no residual overflow)."""

import numpy as np
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.core import cyclic3, driver, engine, linear3, planner, star3
from repro.core.relation import Relation
from repro.kernels import ops as kops
from conftest import (make_rel, oracle_cyclic3_count, oracle_linear3_count,
                      oracle_linear3_per_r)


def _ref_linear_count(rb, sb, sc, tc) -> int:
    """Single-bucket kernels/ref.py oracle (everything in one PMU)."""
    c = kops.bucket_count3_linear(
        jnp.asarray(rb)[None, :], jnp.ones((1, len(rb)), bool),
        jnp.asarray(sb)[None, :], jnp.asarray(sc)[None, :],
        jnp.ones((1, len(sb)), bool),
        jnp.asarray(tc)[None, :], jnp.ones((1, len(tc)), bool))
    return int(c[0])


def _ref_cyclic_count(ra, rb, sb, sc, tc, ta) -> int:
    c = kops.bucket_count3_cyclic(
        jnp.asarray(ra)[None, :], jnp.asarray(rb)[None, :],
        jnp.ones((1, len(ra)), bool),
        jnp.asarray(sb)[None, :], jnp.asarray(sc)[None, :],
        jnp.ones((1, len(sb)), bool),
        jnp.asarray(tc)[None, :], jnp.asarray(ta)[None, :],
        jnp.ones((1, len(tc)), bool))
    return int(c[0])


def _skewed(rng, n, d, heavy_frac, heavy_key=1):
    """Adversarial keys: a heavy hitter owning `heavy_frac` of all rows (a
    single hash bucket must absorb it — no salt can spread one key)."""
    n_heavy = int(n * heavy_frac)
    vals = np.concatenate([
        np.full(n_heavy, heavy_key, np.int32),
        rng.integers(0, d, size=n - n_heavy).astype(np.int32)])
    rng.shuffle(vals)
    return vals


# --------------------------------------------------------------------------
# fused sweep == scan driver (same plan, same layouts)
# --------------------------------------------------------------------------

@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), d=st.integers(3, 80),
       u=st.sampled_from([2, 4, 8]))
def test_linear_fused_matches_scan(seed, d, u):
    rng = np.random.default_rng(seed)
    r, rd = make_rel(rng, 150, ("a", "b"), d)
    s, sd = make_rel(rng, 180, ("b", "c"), d)
    t, td = make_rel(rng, 160, ("c", "d"), d)
    plan = linear3.default_plan(150, 180, 160, m_budget=64, u=u)
    res_scan, grown = driver.linear3_count_auto(r, s, t, plan)
    res_fused = engine.linear3_count_fused(r, s, t, grown)
    assert int(res_fused.count) == int(res_scan.count)
    assert not bool(res_fused.overflowed)


@settings(max_examples=4, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), d=st.integers(3, 60))
def test_cyclic_fused_matches_scan(seed, d):
    rng = np.random.default_rng(seed)
    r, _ = make_rel(rng, 140, ("a", "b"), d)
    s, _ = make_rel(rng, 150, ("b", "c"), d)
    t, _ = make_rel(rng, 130, ("c", "a"), d)
    plan = cyclic3.default_plan(140, 150, 130, m_budget=64, uh=4, ug=2)
    res_scan, grown = driver.cyclic3_count_auto(r, s, t, plan)
    res_fused = engine.cyclic3_count_fused(r, s, t, grown)
    assert int(res_fused.count) == int(res_scan.count)
    assert not bool(res_fused.overflowed)


@settings(max_examples=4, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), d=st.integers(3, 60),
       chunks=st.sampled_from([1, 2, 4]))
def test_star_fused_matches_scan(seed, d, chunks):
    rng = np.random.default_rng(seed)
    r, _ = make_rel(rng, 60, ("a", "b"), d)
    s, _ = make_rel(rng, 400, ("b", "c"), d)
    t, _ = make_rel(rng, 70, ("c", "d"), d)
    plan = star3.default_plan(60, 400, 70, uh=4, ug=4, chunks=chunks)
    res_scan, grown = driver.star3_count_auto(r, s, t, plan)
    res_fused = engine.star3_count_fused(r, s, t, grown)
    assert int(res_fused.count) == int(res_scan.count)
    assert not bool(res_fused.overflowed)


def test_fused_pallas_kernels_match_jnp(rng):
    """The fused Pallas grid kernels (interpret mode) and the fused jnp
    paths are the same function."""
    r, _ = make_rel(rng, 120, ("a", "b"), 30)
    s, _ = make_rel(rng, 140, ("b", "c"), 30)
    t, _ = make_rel(rng, 130, ("c", "d"), 30)
    plan = linear3.default_plan(120, 140, 130, m_budget=48, u=4, slack=4.0)
    rg, sg, tg = engine.linear3_layouts(r, s, t, plan)
    a = kops.fused_count3_linear(rg.columns["b"], rg.valid, sg.columns["b"],
                                 sg.columns["c"], sg.valid, tg.columns["c"],
                                 tg.valid, use_kernel=False)
    b = kops.fused_count3_linear(rg.columns["b"], rg.valid, sg.columns["b"],
                                 sg.columns["c"], sg.valid, tg.columns["c"],
                                 tg.valid, use_kernel=True)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    pa = kops.fused_per_r_counts(rg.columns["b"], rg.valid, sg.columns["b"],
                                 sg.columns["c"], sg.valid, tg.columns["c"],
                                 tg.valid, use_kernel=False)
    pb = kops.fused_per_r_counts(rg.columns["b"], rg.valid, sg.columns["b"],
                                 sg.columns["c"], sg.valid, tg.columns["c"],
                                 tg.valid, use_kernel=True)
    np.testing.assert_array_equal(np.asarray(pa), np.asarray(pb))


# --------------------------------------------------------------------------
# skew recovery: adversarial keys, exact counts, overflowed == False
# --------------------------------------------------------------------------

@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       heavy_frac=st.sampled_from([0.3, 0.5, 0.7]),
       d=st.integers(8, 60))
def test_linear_skew_recovery_exact(seed, heavy_frac, d):
    """A heavy-hitter join key overflows any uniform plan (one bucket must
    hold every copy); the engine must still return the kernels/ref.py
    reference count exactly, with no residual overflow flag."""
    rng = np.random.default_rng(seed)
    rb = _skewed(rng, 200, d, heavy_frac)
    sb = _skewed(rng, 220, d, heavy_frac)
    sc = _skewed(rng, 220, d, heavy_frac, heavy_key=2)
    tc = _skewed(rng, 210, d, heavy_frac, heavy_key=2)
    r = Relation.from_arrays(a=rng.integers(0, 999, 200).astype(np.int32),
                             b=rb)
    s = Relation.from_arrays(b=sb, c=sc)
    t = Relation.from_arrays(c=tc,
                             d=rng.integers(0, 999, 210).astype(np.int32))
    want = _ref_linear_count(rb, sb, sc, tc)
    plan = linear3.default_plan(200, 220, 210, m_budget=64, u=4, slack=1.2)
    res = engine.MultiwayJoinEngine("linear").count(r, s, t, plan)
    assert int(res.count) == want
    assert not bool(res.overflowed)


@settings(max_examples=4, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       heavy_frac=st.sampled_from([0.3, 0.6]))
def test_cyclic_skew_recovery_exact(seed, heavy_frac):
    rng = np.random.default_rng(seed)
    ra, rb = _skewed(rng, 160, 30, heavy_frac), _skewed(rng, 160, 30,
                                                        heavy_frac, 3)
    sb, sc = _skewed(rng, 170, 30, heavy_frac, 3), _skewed(rng, 170, 30,
                                                           heavy_frac, 5)
    tc, ta = _skewed(rng, 150, 30, heavy_frac, 5), _skewed(rng, 150, 30,
                                                           heavy_frac)
    r = Relation.from_arrays(a=ra, b=rb)
    s = Relation.from_arrays(b=sb, c=sc)
    t = Relation.from_arrays(c=tc, a=ta)
    want = _ref_cyclic_count(ra, rb, sb, sc, tc, ta)
    plan = cyclic3.default_plan(160, 170, 150, m_budget=48, uh=2, ug=2,
                                slack=1.2)
    res = engine.MultiwayJoinEngine("cyclic").count(r, s, t, plan)
    assert int(res.count) == want
    assert not bool(res.overflowed)


@settings(max_examples=4, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       heavy_frac=st.sampled_from([0.4, 0.7]))
def test_star_skew_recovery_exact(seed, heavy_frac):
    """Skewed FACT keys: most of S routes to one PMU cell."""
    rng = np.random.default_rng(seed)
    r, rd = make_rel(rng, 60, ("a", "b"), 25)
    sb = _skewed(rng, 400, 25, heavy_frac, heavy_key=7)
    sc = _skewed(rng, 400, 25, heavy_frac, heavy_key=9)
    s = Relation.from_arrays(b=sb, c=sc)
    t, td = make_rel(rng, 70, ("c", "d"), 25)
    want = _ref_linear_count(rd["b"], sb, sc, td["c"])
    plan = star3.default_plan(60, 400, 70, uh=4, ug=4, chunks=2, slack=1.2)
    res = engine.MultiwayJoinEngine("star").count(r, s, t, plan)
    assert int(res.count) == want
    assert not bool(res.overflowed)


def test_linear_zipf_recovery_exact(rng):
    """The seed suite's zipf scenario, now recovered by the engine without
    whole-query capacity retries."""
    r, rd = make_rel(rng, 200, ("a", "b"), 50, zipf=1.4)
    s, sd = make_rel(rng, 220, ("b", "c"), 50, zipf=1.4)
    t, td = make_rel(rng, 210, ("c", "d"), 50, zipf=1.4)
    want = oracle_linear3_count(rd["b"], sd["b"], sd["c"], td["c"])
    plan = linear3.default_plan(200, 220, 210, m_budget=64, u=4, slack=1.2)
    res = driver.engine_count("linear", r, s, t, plan)
    assert int(res.count) == want
    assert not bool(res.overflowed)


def test_per_r_skew_recovery_exact(rng):
    """Per-R aggregates survive recovery: group-by over the concatenated
    round outputs equals the oracle."""
    rb = _skewed(rng, 180, 40, 0.5)
    r = Relation.from_arrays(a=rng.integers(0, 99, 180).astype(np.int32),
                             b=rb)
    rd_a = np.asarray(r.col("a"))
    s, sd = make_rel(rng, 200, ("b", "c"), 40, zipf=1.3)
    t, td = make_rel(rng, 190, ("c", "d"), 40, zipf=1.3)
    plan = linear3.default_plan(180, 200, 190, m_budget=64, u=4, slack=1.2)
    res = driver.engine_per_r_counts(r, s, t, plan)
    assert not bool(res.overflowed)
    from collections import defaultdict
    got = defaultdict(int)
    for k, c, v in zip(np.asarray(res.keys), np.asarray(res.counts),
                       np.asarray(res.valid)):
        if v:
            got[int(k)] += int(c)
    per = oracle_linear3_per_r(rb, sd["b"], sd["c"], td["c"])
    want = defaultdict(int)
    for a, c in zip(rd_a, per):
        want[int(a)] += int(c)
    assert dict(got) == dict(want)


# --------------------------------------------------------------------------
# planner: executable engine plans
# --------------------------------------------------------------------------

def test_planner_engine_plan_runs(rng):
    r, rd = make_rel(rng, 150, ("a", "b"), 37)
    s, sd = make_rel(rng, 180, ("b", "c"), 37)
    t, td = make_rel(rng, 160, ("c", "d"), 37)
    want = oracle_linear3_count(rd["b"], sd["b"], sd["c"], td["c"])
    ep = planner.plan_query("linear", 150, 180, 160, 37, m_budget=48, u=4)
    assert ep.strategy in ("3way", "cascade")
    res = ep.run(r, s, t)
    assert int(res.count) == want


def test_planner_cyclic_always_3way(rng):
    r, rd = make_rel(rng, 140, ("a", "b"), 31)
    s, sd = make_rel(rng, 150, ("b", "c"), 31)
    t, td = make_rel(rng, 130, ("c", "a"), 31)
    want = oracle_cyclic3_count(rd["a"], rd["b"], sd["b"], sd["c"],
                                td["c"], td["a"])
    ep = planner.plan_query("cyclic", 140, 150, 130, 31, m_budget=64,
                            uh=4, ug=2)
    assert ep.strategy == "3way"
    res = ep.run(r, s, t)
    assert int(res.count) == want
    assert res.rounds >= 1
