"""Measured-constant calibration for the Appendix-A time model.

The closed-form cycle model (``perfmodel.model``) compares a fused 3-way
root against a binary cascade with HAND-SET hardware constants.  Those
constants describe Plasticine, not the machine the bench actually runs on —
and the ``cascade_4way`` bench showed the failure mode: the model picked
the fused root at a scale where the measured binary tail was faster.

This module closes the loop: ``benchmarks/engine_bench.py`` records, next
to each measured time, the model's own predicted seconds for the same root
(``model_t3_s`` / ``model_tc_s`` from the planner's ``TimedChoice``).
``calibration_from_bench`` turns one committed BENCH_engine.json into a
:class:`Calibration` — two multiplicative scales (measured / predicted, one
per plan family) that ``planner.choose_linear_timed`` /
``choose_star_timed`` apply before comparing totals.  A scale is a pure
re-anchoring: the model keeps its shape (how times grow with n, d, M), the
bench pins its absolute level on THIS machine.

Calibration is opt-in (``JoinSession(calibration=...)``): the default
``None`` keeps the paper's hand-set constants, so published Fig-4 model
numbers and small-scale planning behavior are untouched.
"""

from __future__ import annotations

import dataclasses
import json
import pathlib
from typing import Any, Mapping

# measured/predicted ratios outside this band are treated as a corrupt
# record rather than a constant to bake in.  The band is WIDE on purpose:
# the hand-set constants model Plasticine cycles, so a CPU runner's
# measured/predicted ratio sits around 1e3-1e4 legitimately.
_MAX_SCALE = 1e7


@dataclasses.dataclass(frozen=True)
class Calibration:
    """Multiplicative re-anchoring of the Appendix-A closed forms.

    ``fused3_scale`` multiplies the fused 3-way root's predicted total,
    ``cascade_scale`` the binary cascade's, before the planner compares
    them.  ``source`` records provenance for plan-cache keys and debug
    output.  The identity calibration reproduces the uncalibrated model.
    """

    fused3_scale: float = 1.0
    cascade_scale: float = 1.0
    source: str = "identity"

    def scaled(self, t_3way_s: float, t_cascade_s: float):
        return t_3way_s * self.fused3_scale, t_cascade_s * self.cascade_scale


IDENTITY = Calibration()


def calibration_from_bench(bench: Mapping[str, Any] | str | pathlib.Path,
                           *, shape: str = "cascade_4way") -> Calibration:
    """Build a :class:`Calibration` from a BENCH_engine.json report.

    Reads the named shape's measured per-path seconds (``fused_root_s``:
    the fused root step's blocked wall time; ``binary_tail_s``: the
    all-binary root steps') and the model's predicted seconds for the same
    decision (``model_t3_s`` / ``model_tc_s``).  Missing or degenerate
    entries fall back to the identity calibration rather than guessing —
    and a single implausible ratio degrades BOTH scales to identity:
    re-anchoring only one side would skew the 3-way/cascade comparison
    worse than no calibration at all.
    """
    if isinstance(bench, (str, pathlib.Path)):
        path = pathlib.Path(bench)
        if not path.exists():
            return IDENTITY
        bench = json.loads(path.read_text())
    row = bench.get("shapes", {}).get(shape, {})
    needed = ("fused_root_s", "binary_tail_s", "model_t3_s", "model_tc_s")
    if any(not isinstance(row.get(k), (int, float)) or row[k] <= 0
           for k in needed):
        return IDENTITY
    f3 = row["fused_root_s"] / row["model_t3_s"]
    cs = row["binary_tail_s"] / row["model_tc_s"]
    if not all(1.0 / _MAX_SCALE <= s <= _MAX_SCALE for s in (f3, cs)):
        return IDENTITY
    return Calibration(fused3_scale=float(f3), cascade_scale=float(cs),
                       source=f"bench:{shape}")


# ---------------------------------------------------------------------------
# persistence: the committed calibration file
# ---------------------------------------------------------------------------

# The committed snapshot next to BENCH_engine.json.  The bench refreshes it
# after every run (``engine_bench.main`` calls ``refresh_calibration_file``)
# so ``calibration_from_file`` never reads constants staler than the last
# committed bench report.
CALIBRATION_FILE = "CALIBRATION_engine.json"


def refresh_calibration_file(bench: Mapping[str, Any] | str | pathlib.Path
                             = "BENCH_engine.json",
                             out_path: str | pathlib.Path = CALIBRATION_FILE,
                             *, shape: str = "cascade_4way") -> Calibration:
    """Re-derive the calibration from ``bench`` and persist it to
    ``out_path``.  Returns the calibration written (the identity one when
    the bench record is missing or degenerate — persisted too, so a stale
    non-identity file cannot outlive the report that justified it)."""
    cal = calibration_from_bench(bench, shape=shape)
    payload = {"fused3_scale": cal.fused3_scale,
               "cascade_scale": cal.cascade_scale,
               "source": cal.source, "shape": shape}
    pathlib.Path(out_path).write_text(json.dumps(payload, indent=2) + "\n")
    return cal


def calibration_from_file(path: str | pathlib.Path = CALIBRATION_FILE
                          ) -> Calibration:
    """Load the committed calibration snapshot; identity when absent or
    malformed (same never-guess posture as ``calibration_from_bench``)."""
    p = pathlib.Path(path)
    if not p.exists():
        return IDENTITY
    try:
        payload = json.loads(p.read_text())
        f3 = float(payload["fused3_scale"])
        cs = float(payload["cascade_scale"])
    except (ValueError, KeyError, TypeError):
        return IDENTITY
    if not all(1.0 / _MAX_SCALE <= s <= _MAX_SCALE for s in (f3, cs)):
        return IDENTITY
    return Calibration(fused3_scale=f3, cascade_scale=cs,
                       source=str(payload.get("source", f"file:{p}")))
