"""MultiwayJoinEngine: fused sweeps vs scan drivers vs kernels/ref.py,
plus the skew-recovery guarantee (exact counts, no residual overflow)."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from conftest import (make_rel, oracle_cyclic3_count, oracle_linear3_count,
                      oracle_linear3_per_r, skewed_keys)
from repro.core import cyclic3, engine, linear3, planner, reference, star3
from repro.core.relation import Relation
from repro.kernels import ops as kops


def _ref_linear_count(rb, sb, sc, tc) -> int:
    """Single-bucket kernels/ref.py oracle (everything in one PMU)."""
    c = kops.bucket_count3_linear(
        jnp.asarray(rb)[None, :], jnp.ones((1, len(rb)), bool),
        jnp.asarray(sb)[None, :], jnp.asarray(sc)[None, :],
        jnp.ones((1, len(sb)), bool),
        jnp.asarray(tc)[None, :], jnp.ones((1, len(tc)), bool))
    return int(c[0])


def _ref_cyclic_count(ra, rb, sb, sc, tc, ta) -> int:
    c = kops.bucket_count3_cyclic(
        jnp.asarray(ra)[None, :], jnp.asarray(rb)[None, :],
        jnp.ones((1, len(ra)), bool),
        jnp.asarray(sb)[None, :], jnp.asarray(sc)[None, :],
        jnp.ones((1, len(sb)), bool),
        jnp.asarray(tc)[None, :], jnp.asarray(ta)[None, :],
        jnp.ones((1, len(tc)), bool))
    return int(c[0])


def _skewed(rng, n, d, heavy_frac, heavy_key=1):
    return skewed_keys(rng, n, d, heavy_frac, heavy_key)


# --------------------------------------------------------------------------
# fused sweep == scan driver (same plan, same layouts)
# --------------------------------------------------------------------------

@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), d=st.integers(3, 80),
       u=st.sampled_from([2, 4, 8]))
def test_linear_fused_matches_scan(seed, d, u):
    rng = np.random.default_rng(seed)
    r, rd = make_rel(rng, 150, ("a", "b"), d)
    s, sd = make_rel(rng, 180, ("b", "c"), d)
    t, td = make_rel(rng, 160, ("c", "d"), d)
    plan = linear3.default_plan(150, 180, 160, m_budget=64, u=u)
    res_scan, grown = reference.linear3_count_auto(r, s, t, plan)
    res_fused = engine.linear3_count_fused(r, s, t, grown)
    assert int(res_fused.count) == int(res_scan.count)
    assert not bool(res_fused.overflowed)


@settings(max_examples=4, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), d=st.integers(3, 60))
def test_cyclic_fused_matches_scan(seed, d):
    rng = np.random.default_rng(seed)
    r, _ = make_rel(rng, 140, ("a", "b"), d)
    s, _ = make_rel(rng, 150, ("b", "c"), d)
    t, _ = make_rel(rng, 130, ("c", "a"), d)
    plan = cyclic3.default_plan(140, 150, 130, m_budget=64, uh=4, ug=2)
    res_scan, grown = reference.cyclic3_count_auto(r, s, t, plan)
    res_fused = engine.cyclic3_count_fused(r, s, t, grown)
    assert int(res_fused.count) == int(res_scan.count)
    assert not bool(res_fused.overflowed)


@settings(max_examples=4, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), d=st.integers(3, 60),
       chunks=st.sampled_from([1, 2, 4]))
def test_star_fused_matches_scan(seed, d, chunks):
    rng = np.random.default_rng(seed)
    r, _ = make_rel(rng, 60, ("a", "b"), d)
    s, _ = make_rel(rng, 400, ("b", "c"), d)
    t, _ = make_rel(rng, 70, ("c", "d"), d)
    plan = star3.default_plan(60, 400, 70, uh=4, ug=4, chunks=chunks)
    res_scan, grown = reference.star3_count_auto(r, s, t, plan)
    res_fused = engine.star3_count_fused(r, s, t, grown)
    assert int(res_fused.count) == int(res_scan.count)
    assert not bool(res_fused.overflowed)


def test_fused_pallas_kernels_match_jnp(rng):
    """The fused Pallas grid kernels (interpret mode) and the fused jnp
    paths are the same function."""
    r, _ = make_rel(rng, 120, ("a", "b"), 30)
    s, _ = make_rel(rng, 140, ("b", "c"), 30)
    t, _ = make_rel(rng, 130, ("c", "d"), 30)
    plan = linear3.default_plan(120, 140, 130, m_budget=48, u=4, slack=4.0)
    rg, sg, tg = engine.linear3_layouts(r, s, t, plan)
    a = kops.fused_count3_linear(rg.columns["b"], rg.valid, sg.columns["b"],
                                 sg.columns["c"], sg.valid, tg.columns["c"],
                                 tg.valid, use_kernel=False)
    b = kops.fused_count3_linear(rg.columns["b"], rg.valid, sg.columns["b"],
                                 sg.columns["c"], sg.valid, tg.columns["c"],
                                 tg.valid, use_kernel=True)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    pa = kops.fused_per_r_counts(rg.columns["b"], rg.valid, sg.columns["b"],
                                 sg.columns["c"], sg.valid, tg.columns["c"],
                                 tg.valid, use_kernel=False)
    pb = kops.fused_per_r_counts(rg.columns["b"], rg.valid, sg.columns["b"],
                                 sg.columns["c"], sg.valid, tg.columns["c"],
                                 tg.valid, use_kernel=True)
    np.testing.assert_array_equal(np.asarray(pa), np.asarray(pb))


# --------------------------------------------------------------------------
# skew recovery: adversarial keys, exact counts, overflowed == False
# --------------------------------------------------------------------------

@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       heavy_frac=st.sampled_from([0.3, 0.5, 0.7]),
       d=st.integers(8, 60))
def test_linear_skew_recovery_exact(seed, heavy_frac, d):
    """A heavy-hitter join key overflows any uniform plan (one bucket must
    hold every copy); the engine must still return the kernels/ref.py
    reference count exactly, with no residual overflow flag."""
    rng = np.random.default_rng(seed)
    rb = _skewed(rng, 200, d, heavy_frac)
    sb = _skewed(rng, 220, d, heavy_frac)
    sc = _skewed(rng, 220, d, heavy_frac, heavy_key=2)
    tc = _skewed(rng, 210, d, heavy_frac, heavy_key=2)
    r = Relation.from_arrays(a=rng.integers(0, 999, 200).astype(np.int32),
                             b=rb)
    s = Relation.from_arrays(b=sb, c=sc)
    t = Relation.from_arrays(c=tc,
                             d=rng.integers(0, 999, 210).astype(np.int32))
    want = _ref_linear_count(rb, sb, sc, tc)
    plan = linear3.default_plan(200, 220, 210, m_budget=64, u=4, slack=1.2)
    res = engine.MultiwayJoinEngine("linear").count(r, s, t, plan)
    assert int(res.count) == want
    assert not bool(res.overflowed)


@settings(max_examples=4, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       heavy_frac=st.sampled_from([0.3, 0.6]))
def test_cyclic_skew_recovery_exact(seed, heavy_frac):
    rng = np.random.default_rng(seed)
    ra, rb = _skewed(rng, 160, 30, heavy_frac), _skewed(rng, 160, 30,
                                                        heavy_frac, 3)
    sb, sc = _skewed(rng, 170, 30, heavy_frac, 3), _skewed(rng, 170, 30,
                                                           heavy_frac, 5)
    tc, ta = _skewed(rng, 150, 30, heavy_frac, 5), _skewed(rng, 150, 30,
                                                           heavy_frac)
    r = Relation.from_arrays(a=ra, b=rb)
    s = Relation.from_arrays(b=sb, c=sc)
    t = Relation.from_arrays(c=tc, a=ta)
    want = _ref_cyclic_count(ra, rb, sb, sc, tc, ta)
    plan = cyclic3.default_plan(160, 170, 150, m_budget=48, uh=2, ug=2,
                                slack=1.2)
    res = engine.MultiwayJoinEngine("cyclic").count(r, s, t, plan)
    assert int(res.count) == want
    assert not bool(res.overflowed)


@settings(max_examples=4, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       heavy_frac=st.sampled_from([0.4, 0.7]))
def test_star_skew_recovery_exact(seed, heavy_frac):
    """Skewed FACT keys: most of S routes to one PMU cell."""
    rng = np.random.default_rng(seed)
    r, rd = make_rel(rng, 60, ("a", "b"), 25)
    sb = _skewed(rng, 400, 25, heavy_frac, heavy_key=7)
    sc = _skewed(rng, 400, 25, heavy_frac, heavy_key=9)
    s = Relation.from_arrays(b=sb, c=sc)
    t, td = make_rel(rng, 70, ("c", "d"), 25)
    want = _ref_linear_count(rd["b"], sb, sc, td["c"])
    plan = star3.default_plan(60, 400, 70, uh=4, ug=4, chunks=2, slack=1.2)
    res = engine.MultiwayJoinEngine("star").count(r, s, t, plan)
    assert int(res.count) == want
    assert not bool(res.overflowed)


def test_linear_zipf_recovery_exact(rng):
    """The seed suite's zipf scenario, now recovered by the engine without
    whole-query capacity retries."""
    r, rd = make_rel(rng, 200, ("a", "b"), 50, zipf=1.4)
    s, sd = make_rel(rng, 220, ("b", "c"), 50, zipf=1.4)
    t, td = make_rel(rng, 210, ("c", "d"), 50, zipf=1.4)
    want = oracle_linear3_count(rd["b"], sd["b"], sd["c"], td["c"])
    plan = linear3.default_plan(200, 220, 210, m_budget=64, u=4, slack=1.2)
    res = engine.MultiwayJoinEngine("linear").count(r, s, t, plan)
    assert int(res.count) == want
    assert not bool(res.overflowed)


def test_per_r_skew_recovery_exact(rng):
    """Per-R aggregates survive recovery: group-by over the concatenated
    round outputs equals the oracle."""
    rb = _skewed(rng, 180, 40, 0.5)
    r = Relation.from_arrays(a=rng.integers(0, 99, 180).astype(np.int32),
                             b=rb)
    rd_a = np.asarray(r.col("a"))
    s, sd = make_rel(rng, 200, ("b", "c"), 40, zipf=1.3)
    t, td = make_rel(rng, 190, ("c", "d"), 40, zipf=1.3)
    plan = linear3.default_plan(180, 200, 190, m_budget=64, u=4, slack=1.2)
    res = engine.MultiwayJoinEngine("linear").per_r_counts(r, s, t, plan)
    assert not bool(res.overflowed)
    from collections import defaultdict
    got = defaultdict(int)
    for k, c, v in zip(np.asarray(res.keys), np.asarray(res.counts),
                       np.asarray(res.valid)):
        if v:
            got[int(k)] += int(c)
    per = oracle_linear3_per_r(rb, sd["b"], sd["c"], td["c"])
    want = defaultdict(int)
    for a, c in zip(rd_a, per):
        want[int(a)] += int(c)
    assert dict(got) == dict(want)


# --------------------------------------------------------------------------
# planner: executable engine plans
# --------------------------------------------------------------------------

def test_planner_engine_plan_runs(rng):
    r, rd = make_rel(rng, 150, ("a", "b"), 37)
    s, sd = make_rel(rng, 180, ("b", "c"), 37)
    t, td = make_rel(rng, 160, ("c", "d"), 37)
    want = oracle_linear3_count(rd["b"], sd["b"], sd["c"], td["c"])
    ep = planner.plan_step("linear", 150, 180, 160, 37, m_budget=48, u=4)
    assert ep.strategy in ("3way", "cascade")
    res = ep.run(r, s, t)
    assert int(res.count) == want


def test_planner_cyclic_always_3way(rng):
    r, rd = make_rel(rng, 140, ("a", "b"), 31)
    s, sd = make_rel(rng, 150, ("b", "c"), 31)
    t, td = make_rel(rng, 130, ("c", "a"), 31)
    want = oracle_cyclic3_count(rd["a"], rd["b"], sd["b"], sd["c"],
                                td["c"], td["a"])
    ep = planner.plan_step("cyclic", 140, 150, 130, 31, m_budget=64,
                           uh=4, ug=2)
    assert ep.strategy == "3way"
    res = ep.run(r, s, t)
    assert int(res.count) == want
    assert res.rounds >= 1


# --------------------------------------------------------------------------
# recovery-round contract: ONE hashing pass per relation per round
# --------------------------------------------------------------------------

def _probe_hashing(monkeypatch):
    """Count composite_ids invocations and raw hash_bucket evaluations."""
    from repro.core import hashing, partition
    calls = {"composite": 0, "hash": 0}
    orig_ci = partition.composite_ids
    orig_hb = hashing.hash_bucket

    def ci(*a, **kw):
        calls["composite"] += 1
        return orig_ci(*a, **kw)

    def hb(*a, **kw):
        calls["hash"] += 1
        return orig_hb(*a, **kw)

    monkeypatch.setattr(partition, "composite_ids", ci)
    monkeypatch.setattr(hashing, "hash_bucket", hb)
    return calls


def test_one_hash_pass_per_relation_per_round(rng, monkeypatch):
    """Histograms, layouts and residual masks must all derive from a single
    composite_ids pass per relation per round (the recovery-round contract);
    hash_bucket runs once per spec level, never more."""
    levels = {"linear": 2 + 3 + 1, "cyclic": 4 + 3 + 3, "star": 1 + 2 + 1}
    for kind in ("linear", "cyclic", "star"):
        t_cols = ("c", "a") if kind == "cyclic" else ("c", "d")
        rb = _skewed(rng, 200, 30, 0.5)
        r = Relation.from_arrays(a=_skewed(rng, 200, 30, 0.5), b=rb)
        s = Relation.from_arrays(b=_skewed(rng, 220, 30, 0.5, 3),
                                 c=_skewed(rng, 220, 30, 0.5, 5))
        t = Relation.from_arrays(**{t_cols[0]: _skewed(rng, 210, 30, 0.5, 5),
                                    t_cols[1]: _skewed(rng, 210, 30, 0.5)})
        if kind == "linear":
            plan = linear3.default_plan(200, 220, 210, m_budget=64, u=4,
                                        slack=1.2)
        elif kind == "cyclic":
            plan = cyclic3.default_plan(200, 220, 210, m_budget=48, uh=2,
                                        ug=2, slack=1.2)
        else:
            plan = star3.default_plan(200, 220, 210, uh=4, ug=4, chunks=2,
                                      slack=1.2)
        calls = _probe_hashing(monkeypatch)
        res = engine.MultiwayJoinEngine(kind).count(r, s, t, plan)
        assert res.rounds > 1, f"{kind}: skew did not trigger recovery"
        assert calls["composite"] == 3 * res.rounds, (
            f"{kind}: {calls['composite']} composite passes over "
            f"{res.rounds} rounds — want exactly one per relation per round")
        assert calls["hash"] == levels[kind] * res.rounds, (
            f"{kind}: {calls['hash']} hash_bucket calls, want "
            f"{levels[kind]} per round x {res.rounds} rounds")
        monkeypatch.undo()


# --------------------------------------------------------------------------
# int64 totals: > 2^31 cardinality must not wrap
# --------------------------------------------------------------------------

def test_int64_total_over_2e31(rng):
    """Regression: EngineResult.count used to accumulate via jnp int32 and
    silently wrapped past 2^31.  A uniform d=64 self-join at n=22000 has
    ~2.6e9 results (each per-cell partial stays < 2^31 — the kernels' int32
    cell contract — but the total does not fit int32)."""
    n, d = 22000, 64
    rd = {c: rng.integers(0, d, n).astype(np.int32) for c in ("a", "b")}
    sd = {c: rng.integers(0, d, n).astype(np.int32) for c in ("b", "c")}
    td = {c: rng.integers(0, d, n).astype(np.int32) for c in ("c", "d")}
    r = Relation.from_arrays(**rd)
    s = Relation.from_arrays(**sd)
    t = Relation.from_arrays(**td)
    want = oracle_linear3_count(rd["b"], sd["b"], sd["c"], td["c"])
    assert want > 2**31, "shape no longer exercises the int64 regression"
    plan = linear3.default_plan(n, n, n, m_budget=4096, u=8)
    res = engine.MultiwayJoinEngine("linear").count(r, s, t, plan)
    assert int(res.count) == want
    assert np.asarray(res.count).dtype == np.int64
    assert not bool(res.overflowed)


def test_per_r_counts_are_int64(rng):
    r, rd = make_rel(rng, 120, ("a", "b"), 25)
    s, sd = make_rel(rng, 140, ("b", "c"), 25)
    t, td = make_rel(rng, 130, ("c", "d"), 25)
    plan = linear3.default_plan(120, 140, 130, m_budget=48, u=4)
    res = engine.MultiwayJoinEngine("linear").per_r_counts(r, s, t, plan)
    assert np.asarray(res.counts).dtype == np.int64


# --------------------------------------------------------------------------
# cyclic pair-index backend == all-pairs == Pallas kernels
# --------------------------------------------------------------------------

def test_cyclic_pairidx_matches_allpairs_and_kernels(rng):
    """The sorted (c, a)-pair-index backend is the same function as the
    all-pairs contraction, on both the jnp and the (interpret-mode) Pallas
    fused paths."""
    r, _ = make_rel(rng, 300, ("a", "b"), 40)
    s, _ = make_rel(rng, 320, ("b", "c"), 40)
    t, _ = make_rel(rng, 280, ("c", "a"), 40)
    plan = cyclic3.default_plan(300, 320, 280, m_budget=96, uh=4, ug=2,
                                slack=4.0)
    rg, sg, tg = engine.cyclic3_layouts(r, s, t, plan)
    args = (rg.columns["a"], rg.columns["b"], rg.valid, sg.columns["b"],
            sg.columns["c"], sg.valid, tg.columns["c"], tg.columns["a"],
            tg.valid)
    base = np.asarray(kops.fused_count3_cyclic(*args, pair_index=False))
    for kw in (dict(pair_index=True),
               dict(pair_index=True, use_kernel=True),
               dict(pair_index=False, use_kernel=True)):
        got = np.asarray(kops.fused_count3_cyclic(*args, **kw))
        np.testing.assert_array_equal(got, base, err_msg=str(kw))


def test_cyclic_fused_pairidx_matches_scan_driver(rng):
    r, _ = make_rel(rng, 400, ("a", "b"), 50)
    s, _ = make_rel(rng, 420, ("b", "c"), 50)
    t, _ = make_rel(rng, 380, ("c", "a"), 50)
    plan = cyclic3.default_plan(400, 420, 380, m_budget=96, uh=4, ug=2,
                                slack=4.0)
    res_scan, grown_plan = reference.cyclic3_count_auto(r, s, t, plan)
    res_pair = engine.cyclic3_count_fused(r, s, t, grown_plan,
                                          pair_index=True)
    assert int(res_pair.count) == int(res_scan.count)
