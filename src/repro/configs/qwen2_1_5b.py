"""qwen2-1.5b — dense GQA with QKV bias [arXiv:2407.10671; hf].

28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-1.5b", family="dense",
    n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2,
    d_ff=8960, vocab_size=151936, head_dim=128,
    qkv_bias=True, rope_theta=1e6, tie_embeddings=True, norm_eps=1e-6,
    accum_steps=4,
)

SMOKE = ModelConfig(
    name="qwen2-1.5b-smoke", family="dense",
    n_layers=3, d_model=96, n_heads=6, n_kv_heads=2,
    d_ff=256, vocab_size=512, head_dim=16,
    qkv_bias=True, rope_theta=1e6, tie_embeddings=True, norm_eps=1e-6,
    remat=False,
)
