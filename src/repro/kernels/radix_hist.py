"""Pallas kernel: hash + radix histogram via one-hot MXU matmul.

Partitioning (the paper's Fig 2/3 data reorganization) first needs per-bucket
counts.  The TPU-native trick: a histogram over `n_buckets` is
``ones(1, T) @ onehot(bucket_id)(T, n_buckets)`` — a matmul the MXU eats,
instead of a scatter the TPU hates.  The hash itself (Murmur-style mixer +
Lemire reduction) is fused into the kernel so keys stream HBM→VMEM once.

Grid: tiles of the key stream; the single output block is accumulated across
grid steps (zero-initialized at step 0) — the canonical Pallas reduction
pattern.  The same one-hot idiom is reused by the MoE router stats in
``repro.models.moe`` (see DESIGN.md §4).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _hist_kernel(keys_ref, out_ref, *, n_buckets: int, seed: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    k = keys_ref[0, :]
    # Murmur fmix32 (inline so the kernel is self-contained)
    h = k.astype(jnp.uint32) ^ jnp.uint32(seed)
    h = h ^ (h >> 16)
    h = h * jnp.uint32(0x85EBCA6B)
    h = h ^ (h >> 13)
    h = h * jnp.uint32(0xC2B2AE35)
    h = h ^ (h >> 16)
    bucket = (h % jnp.uint32(n_buckets)).astype(jnp.int32)
    # invalid slots are pre-masked to a negative sentinel -> bucket id mapped
    # out of range by the caller contract (sentinel keys hash somewhere, so
    # ops.py masks them to -1 directly on the bucket side instead):
    onehot = (bucket[:, None] == jax.lax.broadcasted_iota(
        jnp.int32, (k.shape[0], n_buckets), 1)).astype(jnp.float32)
    out_ref[0, :] += jnp.dot(jnp.ones((1, k.shape[0]), jnp.float32), onehot,
                             preferred_element_type=jnp.float32)[0]


@functools.partial(jax.jit,
                   static_argnames=("n_buckets", "seed", "tile", "interpret"))
def radix_histogram(keys: jnp.ndarray, *, n_buckets: int, seed: int = 0x9E3779B1,
                    tile: int = 1024, interpret: bool = True) -> jnp.ndarray:
    """Histogram of hash buckets over a 1-D key stream.

    keys: (n,) int32, n a multiple of `tile` (caller pads with a sentinel and
    subtracts the sentinel bucket afterwards — see ops.radix_histogram).
    """
    n = keys.shape[0]
    assert n % tile == 0, (n, tile)
    out = pl.pallas_call(
        functools.partial(_hist_kernel, n_buckets=n_buckets, seed=seed),
        grid=(n // tile,),
        in_specs=[pl.BlockSpec((1, tile), lambda i: (0, i))],
        out_specs=pl.BlockSpec((1, n_buckets), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, n_buckets), jnp.float32),
        interpret=interpret,
    )(keys.reshape(1, n))
    return out[0].astype(jnp.int32)
