"""Data substrate: synthetic token streams, relation workload generators,
and the join-enriched pipeline (the paper's engine as a framework feature)."""

from repro.data.synthetic import token_batches, TokenGenConfig  # noqa: F401
from repro.data.relations import gen_relation, RelGenConfig  # noqa: F401
from repro.data.pipeline import JoinEnrichedPipeline  # noqa: F401
