"""Pallas TPU kernels for the per-bucket join inner loops.

This is the compute hot-spot the paper optimizes: once relations are radix
partitioned, each PMU (here: one VMEM-resident bucket triple per grid step)
joins tiny relations with all-pairs compares.  On Plasticine the compare is
a 16-lane SIMD loop in a PCU; on TPU we map it to:

* VPU 8×128 lanes for the equality matrices (branch-free compares on
  sentinel-masked keys), and
* the MXU for the contraction steps — per-key probe weights and the cyclic
  existence matrix are literally matmuls over 0/1 matrices
  (``count = Σ (M1ᵀ M2) ⊙ M3``).

Layout contract (enforced by ``ops.py``):
  - bucket grids ``[n_buckets, capacity]`` int32, capacity a multiple of 128
    (MXU/VPU lane alignment),
  - invalid slots pre-masked to per-side sentinels so cross-side equality of
    invalid slots is impossible and kernels stay mask-free,
  - per-bucket counts ≤ 2^24 so f32 accumulation is exact (bucket capacities
    are VMEM-bounded, far below this).

Grid: one program per bucket (the ``n_buckets`` grid dimension is
embarrassingly parallel — Plasticine's U-way PMU parallelism).  BlockSpecs
pin one bucket row of each operand in VMEM per step; Pallas double-buffers
the HBM→VMEM streams across grid steps, which is exactly the paper's
prefetch/double-buffering optimization (§6.2).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _row(ref):
    """Load a (1, C) block as a (C,) vector."""
    return ref[0, :]


# --------------------------------------------------------------------------
# binary pair count
# --------------------------------------------------------------------------

def _pair_count_kernel(ka_ref, kb_ref, out_ref):
    ka = _row(ka_ref)
    kb = _row(kb_ref)
    m = (ka[:, None] == kb[None, :]).astype(jnp.float32)
    out_ref[0, 0] = jnp.sum(m)


@functools.partial(jax.jit, static_argnames=("interpret",))
def pair_count(ka: jnp.ndarray, kb: jnp.ndarray, *, interpret: bool = True):
    b, ca = ka.shape
    _, cb = kb.shape
    out = pl.pallas_call(
        _pair_count_kernel,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, ca), lambda i: (i, 0)),
            pl.BlockSpec((1, cb), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, 1), jnp.float32),
        interpret=interpret,
    )(ka, kb)
    return out[:, 0].astype(jnp.int32)


# --------------------------------------------------------------------------
# linear 3-way count (Algorithm 1 inner join)
# --------------------------------------------------------------------------

def _count3_linear_kernel(rb_ref, sb_ref, sc_ref, tc_ref, out_ref):
    rb = _row(rb_ref)
    sb = _row(sb_ref)
    sc = _row(sc_ref)
    tc = _row(tc_ref)
    wr = jnp.sum((sb[:, None] == rb[None, :]).astype(jnp.float32), axis=1)
    wt = jnp.sum((sc[:, None] == tc[None, :]).astype(jnp.float32), axis=1)
    out_ref[0, 0] = jnp.sum(wr * wt)


@functools.partial(jax.jit, static_argnames=("interpret",))
def count3_linear(rb, sb, sc, tc, *, interpret: bool = True):
    b, cr = rb.shape
    _, cs = sb.shape
    _, ct = tc.shape
    out = pl.pallas_call(
        _count3_linear_kernel,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, cr), lambda i: (i, 0)),
            pl.BlockSpec((1, cs), lambda i: (i, 0)),
            pl.BlockSpec((1, cs), lambda i: (i, 0)),
            pl.BlockSpec((1, ct), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, 1), jnp.float32),
        interpret=interpret,
    )(rb, sb, sc, tc)
    return out[:, 0].astype(jnp.int32)


# --------------------------------------------------------------------------
# per-R-slot counts (Example 1 per-user aggregate) — MXU contraction
# --------------------------------------------------------------------------

def _per_r_kernel(rb_ref, sb_ref, sc_ref, tc_ref, out_ref):
    rb = _row(rb_ref)
    sb = _row(sb_ref)
    sc = _row(sc_ref)
    tc = _row(tc_ref)
    wt = jnp.sum((sc[:, None] == tc[None, :]).astype(jnp.float32), axis=1)
    m1 = (sb[:, None] == rb[None, :]).astype(jnp.float32)      # (Cs, Cr)
    # c[r] = Σ_s w_s · m1[s, r]  ==  (1, Cs) @ (Cs, Cr)  — MXU
    out_ref[0, :] = jnp.dot(wt[None, :], m1,
                            preferred_element_type=jnp.float32)[0]


@functools.partial(jax.jit, static_argnames=("interpret",))
def per_r_counts(rb, sb, sc, tc, *, interpret: bool = True):
    b, cr = rb.shape
    _, cs = sb.shape
    _, ct = tc.shape
    out = pl.pallas_call(
        _per_r_kernel,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, cr), lambda i: (i, 0)),
            pl.BlockSpec((1, cs), lambda i: (i, 0)),
            pl.BlockSpec((1, cs), lambda i: (i, 0)),
            pl.BlockSpec((1, ct), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, cr), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, cr), jnp.float32),
        interpret=interpret,
    )(rb, sb, sc, tc)
    return out.astype(jnp.int32)


# --------------------------------------------------------------------------
# cyclic 3-way (triangle) count — two MXU matmuls per bucket triple
# --------------------------------------------------------------------------

def _count3_cyclic_kernel(ra_ref, rb_ref, sb_ref, sc_ref, tc_ref, ta_ref,
                          out_ref):
    ra = _row(ra_ref)
    rb = _row(rb_ref)
    sb = _row(sb_ref)
    sc = _row(sc_ref)
    tc = _row(tc_ref)
    ta = _row(ta_ref)
    m1 = (sb[:, None] == rb[None, :]).astype(jnp.float32)      # (Cs, Cr)
    m2 = (sc[:, None] == tc[None, :]).astype(jnp.float32)      # (Cs, Ct)
    p = jnp.dot(m1.T, m2, preferred_element_type=jnp.float32)  # (Cr, Ct)
    m3 = (ra[:, None] == ta[None, :]).astype(jnp.float32)      # (Cr, Ct)
    out_ref[0, 0] = jnp.sum(p * m3)


@functools.partial(jax.jit, static_argnames=("interpret",))
def count3_cyclic(ra, rb, sb, sc, tc, ta, *, interpret: bool = True):
    b, cr = ra.shape
    _, cs = sb.shape
    _, ct = tc.shape
    out = pl.pallas_call(
        _count3_cyclic_kernel,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, cr), lambda i: (i, 0)),
            pl.BlockSpec((1, cr), lambda i: (i, 0)),
            pl.BlockSpec((1, cs), lambda i: (i, 0)),
            pl.BlockSpec((1, cs), lambda i: (i, 0)),
            pl.BlockSpec((1, ct), lambda i: (i, 0)),
            pl.BlockSpec((1, ct), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, 1), jnp.float32),
        interpret=interpret,
    )(ra, rb, sb, sc, tc, ta)
    return out[:, 0].astype(jnp.int32)
