"""Building-block layers: norms, MLPs, embeddings, rotary — pure functions
over plain dict params.  Weights live in f32 (master); forward casts to the
config compute dtype.  Sharding is annotated with logical axes."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.parallel import shard


def dtype_of(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
            "float16": jnp.float16}[name]


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------

def normal(key, shape, scale, logical=None):
    w = jax.random.normal(key, shape, dtype=jnp.float32) * scale
    return w


def fan_in_init(key, shape, logical=None):
    import math
    return normal(key, shape, 1.0 / math.sqrt(shape[0]), logical)


# --------------------------------------------------------------------------
# norms
# --------------------------------------------------------------------------

def rms_norm(x, w, eps: float):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + w.astype(jnp.float32))).astype(dt)


def init_rms_norm(d: int):
    return {"scale": jnp.zeros((d,), jnp.float32)}


# --------------------------------------------------------------------------
# linear / mlp
# --------------------------------------------------------------------------

def linear(x, w, b=None):
    y = x @ w.astype(x.dtype)
    if b is not None:
        y = y + b.astype(x.dtype)
    return y


def init_linear(key, d_in, d_out, bias=False, logical=("p_embed", "p_mlp")):
    p = {"w": fan_in_init(key, (d_in, d_out), logical)}
    if bias:
        p["b"] = jnp.zeros((d_out,), jnp.float32)
    return p


def glu_mlp(x, p, act: str):
    """SwiGLU / GeGLU: act(x @ w_gate) * (x @ w_up) @ w_down.
    Accepts [B, S, d] or flattened [N, d] (MoE shared-expert path)."""
    g = linear(x, p["gate"]["w"])
    u = linear(x, p["up"]["w"])
    g = jax.nn.silu(g) if act == "silu" else jax.nn.gelu(g, approximate=True)
    logical = ("batch",) + ("seq",) * (x.ndim - 2) + ("mlp",)
    h = shard(g * u, logical)
    return linear(h, p["down"]["w"])


def init_glu_mlp(key, d_model, d_ff):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "gate": init_linear(k1, d_model, d_ff, logical=("p_embed", "p_mlp")),
        "up": init_linear(k2, d_model, d_ff, logical=("p_embed", "p_mlp")),
        "down": init_linear(k3, d_ff, d_model, logical=("p_mlp", "p_embed")),
    }


# --------------------------------------------------------------------------
# embeddings
# --------------------------------------------------------------------------

def embed(tokens, table, dtype):
    out = jnp.take(table, tokens, axis=0).astype(dtype)
    return shard(out, ("batch", "seq_res", "embed"))


def unembed(x, table):
    """Logits projection against the [vocab, d_model] table (tied or untied);
    returns f32 logits sharded over vocab."""
    logits = x.astype(jnp.float32) @ table.astype(jnp.float32).T
    return shard(logits, ("batch", "seq", "vocab"))


def init_embed(key, vocab, d_model):
    # std 1/sqrt(d): with tied unembedding, final-norm activations (RMS~1)
    # against this table give logits ~ N(0, 1) at init (CE starts near ln V).
    return {"table": normal(key, (vocab, d_model), d_model ** -0.5,
                            ("p_vocab", "p_embed"))}


# --------------------------------------------------------------------------
# rotary
# --------------------------------------------------------------------------

def rope(x, positions, theta: float):
    """Apply rotary embedding.  x: [B, S, H, D], positions: [B, S]."""
    d = x.shape[-1]
    half = d // 2
    freq = jnp.exp(-jnp.log(theta) *
                   jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freq  # [B, S, half]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)
