"""Synthetic relation generators for the join workloads (paper §6).

The paper's workloads are parameterized by (N records, d distinct values) —
"average friends per person" f = N/d.  Uniform by default; Zipf skew
available for the §1.2 skew-handling tests.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.relation import Relation


@dataclasses.dataclass(frozen=True)
class RelGenConfig:
    n: int                  # records
    d: int                  # distinct values per column
    columns: tuple = ("a", "b")
    zipf: float = 0.0       # 0 = uniform
    seed: int = 0
    capacity: int = 0       # 0 = exactly n


def gen_relation(cfg: RelGenConfig) -> Relation:
    rng = np.random.default_rng(cfg.seed)
    cols = {}
    for i, c in enumerate(cfg.columns):
        r = np.random.default_rng(cfg.seed * 7 + i)
        if cfg.zipf:
            v = np.minimum(r.zipf(cfg.zipf, size=cfg.n), cfg.d) - 1
        else:
            v = r.integers(0, cfg.d, size=cfg.n)
        cols[c] = v.astype(np.int32)
    del rng
    return Relation.from_arrays(capacity=cfg.capacity or cfg.n, **cols)


def friends_relation(n: int, d: int, seed: int = 0) -> Relation:
    """The paper's friends(F) relation: n edges over d users."""
    return gen_relation(RelGenConfig(n=n, d=d, columns=("a", "b"), seed=seed))
