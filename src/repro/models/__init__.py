"""LM substrate: model families for the assigned architectures."""

from repro.models import zoo  # noqa: F401
from repro.models.config import ModelConfig  # noqa: F401
