"""Encoder-decoder backbone (seamless-m4t-medium).

The modality frontend is a STUB per the assignment: `input_specs()` provides
precomputed frame embeddings [B, S_enc, d_model] (the speech conv frontend
is not part of the backbone).  The encoder is a bidirectional transformer
stack over those frames; the decoder is causal self-attention +
cross-attention to the encoder memory.  Decode shapes run the
autoregressive decoder with a cached encoder memory.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention, layers, transformer
from repro.models.config import ModelConfig
from repro.parallel import shard


def init_dec_block(key, cfg):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln_attn": layers.init_rms_norm(cfg.d_model),
        "attn": attention.init_attention(k1, cfg),
        "ln_cross": layers.init_rms_norm(cfg.d_model),
        "xattn": attention.init_attention(k2, cfg),
        "ln_mlp": layers.init_rms_norm(cfg.d_model),
        "mlp": layers.init_glu_mlp(k3, cfg.d_model, cfg.d_ff),
    }


def init_encdec(key, cfg: ModelConfig):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "embed": layers.init_embed(k1, cfg.vocab_size, cfg.d_model),
        "enc_layers": transformer._stack_init(
            lambda k: transformer.init_block(k, cfg), k2, cfg.n_enc_layers),
        "enc_norm": layers.init_rms_norm(cfg.d_model),
        "dec_layers": transformer._stack_init(
            lambda k: init_dec_block(k, cfg), k3, cfg.n_layers),
        "final_norm": layers.init_rms_norm(cfg.d_model),
        "lm_head": layers.init_embed(k4, cfg.vocab_size, cfg.d_model),
    }


def encode(params, cfg: ModelConfig, frames):
    """frames: [B, S_enc, d_model] stub embeddings → encoder memory."""
    b, s, _ = frames.shape
    x = shard(frames.astype(layers.dtype_of(cfg.dtype)),
              ("batch", "seq", "embed"))
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    def one_block(x, p):
        h = layers.rms_norm(x, p["ln_attn"]["scale"], cfg.norm_eps)
        x = x + attention.self_attention(p["attn"], cfg, h, positions,
                                         causal=False)
        h = layers.rms_norm(x, p["ln_mlp"]["scale"], cfg.norm_eps)
        return x + layers.glu_mlp(h, p["mlp"], cfg.act)

    if cfg.remat:
        one_block = jax.checkpoint(one_block)

    def step(x, p):
        return one_block(x, p), None

    x, _ = jax.lax.scan(step, x, params["enc_layers"])
    return layers.rms_norm(x, params["enc_norm"]["scale"], cfg.norm_eps)


def _dec_block(p, cfg, x, positions, memory):
    h = layers.rms_norm(x, p["ln_attn"]["scale"], cfg.norm_eps)
    x = x + attention.self_attention(p["attn"], cfg, h, positions,
                                     causal=True)
    h = layers.rms_norm(x, p["ln_cross"]["scale"], cfg.norm_eps)
    x = x + attention.cross_attention(p["xattn"], cfg, h, memory, positions)
    h = layers.rms_norm(x, p["ln_mlp"]["scale"], cfg.norm_eps)
    return x + layers.glu_mlp(h, p["mlp"], cfg.act)


def forward(params, cfg: ModelConfig, tokens, memory=None):
    """Teacher-forced decode over `tokens` given encoder `memory`
    ([B, S_enc, d] stub frame embeddings, pre-encoder)."""
    b, s = tokens.shape
    mem = encode(params, cfg, memory)
    dt = layers.dtype_of(cfg.dtype)
    x = layers.embed(tokens, params["embed"]["table"], dt)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    def one_block(x, p):
        return _dec_block(p, cfg, x, positions, mem)

    if cfg.remat:
        one_block = jax.checkpoint(one_block)

    def step(x, p):
        return one_block(x, p), None

    x, _ = jax.lax.scan(step, x, params["dec_layers"])
    x = layers.rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)
    return layers.unembed(x, params["lm_head"]["table"]), {}


# --------------------------------------------------------------------------
# serving
# --------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16):
    cache = attention.init_kv_cache(cfg, batch, max_len, dtype=dtype)
    cache["memory"] = jnp.zeros(
        (batch, cfg.n_frontend_tokens, cfg.d_model), dtype)
    return cache


def prefill(params, cfg: ModelConfig, tokens, cache, memory=None):
    """Encode the source, then run the decoder over the target prefix,
    filling the self-attention cache."""
    b, s = tokens.shape
    mem = encode(params, cfg, memory)
    cache = dict(cache, memory=mem.astype(cache["memory"].dtype))
    dt = layers.dtype_of(cfg.dtype)
    x = layers.embed(tokens, params["embed"]["table"], dt)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    def one_block(x, p):
        h = layers.rms_norm(x, p["ln_attn"]["scale"], cfg.norm_eps)
        out, kk, vv = attention.self_attention(p["attn"], cfg, h, positions,
                                               causal=True, return_kv=True)
        x = x + out
        h = layers.rms_norm(x, p["ln_cross"]["scale"], cfg.norm_eps)
        x = x + attention.cross_attention(p["xattn"], cfg, h, mem, positions)
        h = layers.rms_norm(x, p["ln_mlp"]["scale"], cfg.norm_eps)
        return x + layers.glu_mlp(h, p["mlp"], cfg.act), kk, vv

    if cfg.remat:
        one_block = jax.checkpoint(one_block)

    def step(x, p):
        x, kk, vv = one_block(x, p)
        return x, (kk, vv)

    x, (ks, vs) = jax.lax.scan(step, x, params["dec_layers"])
    new_k = jax.lax.dynamic_update_slice_in_dim(
        cache["k"], ks.astype(cache["k"].dtype), 0, axis=2)
    new_v = jax.lax.dynamic_update_slice_in_dim(
        cache["v"], vs.astype(cache["v"].dtype), 0, axis=2)
    x = layers.rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)
    logits = layers.unembed(x[:, -1:], params["lm_head"]["table"])
    return logits, dict(cache, k=new_k, v=new_v,
                        length=jnp.asarray(s, jnp.int32))


def decode_step(params, cfg: ModelConfig, cache, tokens):
    b = tokens.shape[0]
    dt = layers.dtype_of(cfg.dtype)
    x = layers.embed(tokens, params["embed"]["table"], dt)
    length = cache["length"]
    mem = cache["memory"]
    pos = jnp.broadcast_to(length[None, None], (b, 1))

    def step(x, xs):
        p, lk, lv = xs
        h = layers.rms_norm(x, p["ln_attn"]["scale"], cfg.norm_eps)
        lk, lv = attention.append_kv(p["attn"], cfg, h, lk, lv, length)
        x = x + attention.decode_attention(p["attn"], cfg, h, lk, lv, length)
        h = layers.rms_norm(x, p["ln_cross"]["scale"], cfg.norm_eps)
        x = x + attention.cross_attention(p["xattn"], cfg, h, mem, pos)
        h = layers.rms_norm(x, p["ln_mlp"]["scale"], cfg.norm_eps)
        x = x + layers.glu_mlp(h, p["mlp"], cfg.act)
        return x, (lk, lv)

    x, (nk, nv) = jax.lax.scan(step, x,
                               (params["dec_layers"], cache["k"], cache["v"]))
    x = layers.rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)
    logits = layers.unembed(x, params["lm_head"]["table"])
    return logits, dict(cache, k=nk, v=nv, length=length + 1)
