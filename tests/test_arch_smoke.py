"""Per-architecture smoke tests: reduced same-family configs, one train step
and one prefill+decode step on CPU; asserts shapes + finite outputs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.data.synthetic import TokenGenConfig, batch_at
from repro.models import zoo
from repro.optim import AdamWConfig
from repro.train import init_train_state, make_decode_step, make_train_step

B, S = 2, 32


def _batch(cfg):
    gen = TokenGenConfig(vocab_size=cfg.vocab_size, batch=B, seq_len=S,
                         seed=3, n_frontend_tokens=cfg.n_frontend_tokens,
                         d_model=cfg.d_model)
    b = batch_at(gen, 0)
    return {k: jnp.asarray(v) for k, v in b.items()}


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_forward_and_train_step(arch):
    cfg = configs.smoke(arch)
    model = zoo.build(cfg)
    state = init_train_state(model, jax.random.key(0))
    batch = _batch(cfg)

    logits, aux = model.forward(state.params, batch["inputs"],
                                memory=batch.get("memory"))
    assert logits.shape == (B, S, cfg.vocab_size)
    assert jnp.isfinite(logits).all(), f"{arch}: non-finite logits"

    step = make_train_step(model, AdamWConfig(lr=1e-3, total_steps=10))
    state2, metrics = jax.jit(step)(state, batch)
    assert jnp.isfinite(metrics["loss"]), f"{arch}: loss {metrics['loss']}"
    assert float(metrics["grad_norm"]) > 0
    # params actually changed
    delta = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(lambda a, b: float(jnp.abs(a - b).sum()),
                     state.params, state2.params))
    assert delta > 0


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_prefill_then_decode(arch):
    cfg = configs.smoke(arch)
    model = zoo.build(cfg)
    params = model.init(jax.random.key(1))
    batch = _batch(cfg)
    max_len = S + 8

    cache = model.init_cache(B, max_len)
    logits, cache = model.prefill(params, batch["inputs"], cache,
                                  memory=batch.get("memory"))
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert jnp.isfinite(logits).all()
    assert int(cache["length"]) == S

    decode = make_decode_step(model)
    tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
    for _ in range(2):
        tok, logits2, cache = jax.jit(decode)(params, cache, tok)
        assert logits2.shape == (B, 1, cfg.vocab_size)
        assert jnp.isfinite(logits2).all()
    assert int(cache["length"]) == S + 2


def test_decode_matches_forward_dense():
    """Greedy decode logits must match teacher-forced forward logits
    (KV-cache correctness) for a dense arch."""
    cfg = configs.smoke("qwen2-1.5b")
    model = zoo.build(cfg)
    params = model.init(jax.random.key(2))
    toks = jax.random.randint(jax.random.key(3), (B, 8), 0, cfg.vocab_size)

    full_logits, _ = model.forward(params, toks)

    cache = model.init_cache(B, 16)
    pre_logits, cache = model.prefill(params, toks[:, :7], cache)
    step_logits, cache = model.decode_step(params, cache, toks[:, 7:8])

    np.testing.assert_allclose(np.asarray(pre_logits[:, 0]),
                               np.asarray(full_logits[:, 6]),
                               rtol=5e-2, atol=5e-2)
    np.testing.assert_allclose(np.asarray(step_logits[:, 0]),
                               np.asarray(full_logits[:, 7]),
                               rtol=5e-2, atol=5e-2)
    # the functional property: both paths pick the same next token
    np.testing.assert_array_equal(
        np.argmax(np.asarray(pre_logits[:, 0]), -1),
        np.argmax(np.asarray(full_logits[:, 6]), -1))
    np.testing.assert_array_equal(
        np.argmax(np.asarray(step_logits[:, 0]), -1),
        np.argmax(np.asarray(full_logits[:, 7]), -1))


def test_decode_matches_forward_ssm():
    """Recurrent decode must match the chunked SSD forward (state-space
    duality, the Mamba2 paper's core identity)."""
    cfg = configs.smoke("mamba2-370m")
    model = zoo.build(cfg)
    params = model.init(jax.random.key(4))
    toks = jax.random.randint(jax.random.key(5), (B, 9), 0, cfg.vocab_size)

    full_logits, _ = model.forward(params, toks)
    cache = model.init_cache(B, 16)
    pre_logits, cache = model.prefill(params, toks[:, :8], cache)
    step_logits, cache = model.decode_step(params, cache, toks[:, 8:9])

    np.testing.assert_allclose(np.asarray(pre_logits[:, 0]),
                               np.asarray(full_logits[:, 7]),
                               rtol=2e-2, atol=2e-2)
    np.testing.assert_allclose(np.asarray(step_logits[:, 0]),
                               np.asarray(full_logits[:, 8]),
                               rtol=2e-2, atol=2e-2)


def test_sliding_window_differs_from_full():
    """gemma3's local layers must actually mask: logits differ from a
    window-free clone."""
    import dataclasses
    cfg = configs.smoke("gemma3-1b")
    model = zoo.build(cfg)
    params = model.init(jax.random.key(6))
    toks = jax.random.randint(jax.random.key(7), (1, 24), 0, cfg.vocab_size)
    lg, _ = model.forward(params, toks)

    cfg_full = dataclasses.replace(cfg, sliding_window=0, local_pattern=0)
    model_full = zoo.build(cfg_full)
    lf, _ = model_full.forward(params, toks)
    assert not np.allclose(np.asarray(lg), np.asarray(lf))
