"""Hashing + partitioning invariants (property-based)."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from conftest import make_rel
from repro.core import hashing, partition
from repro.core.relation import Relation


def test_mix32_avalanche():
    """Flipping one input bit flips ~half the output bits on average."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.integers(0, 2**31 - 1, size=2000).astype(np.int32))
    h0 = hashing.mix32(x, 0xABCD)
    flips = []
    for bit in [0, 7, 16, 30]:
        h1 = hashing.mix32(x ^ (1 << bit), 0xABCD)
        diff = np.asarray(h0 ^ h1).view(np.uint32)
        pop = np.unpackbits(diff.view(np.uint8)).sum() / diff.size
        flips.append(pop)
    assert all(12 < f < 20 for f in flips), flips  # ideal = 16


def test_hash_bucket_uniformity():
    rng = np.random.default_rng(1)
    keys = jnp.asarray(rng.integers(0, 2**31 - 1, size=65536).astype(np.int32))
    for nb in (7, 16, 64, 100):
        ids = np.asarray(hashing.hash_bucket(keys, nb, "H"))
        assert ids.min() >= 0 and ids.max() < nb
        counts = np.bincount(ids, minlength=nb)
        mean = 65536 / nb
        assert counts.max() < mean * 1.3 and counts.min() > mean * 0.7


def test_hash_families_independent():
    keys = jnp.arange(10000, dtype=jnp.int32)
    a = np.asarray(hashing.hash_bucket(keys, 16, "H"))
    b = np.asarray(hashing.hash_bucket(keys, 16, "h"))
    # correlation between families should be near zero
    joint = np.zeros((16, 16))
    for x, y in zip(a, b):
        joint[x, y] += 1
    expected = 10000 / 256
    chi2 = ((joint - expected) ** 2 / expected).sum()
    assert chi2 < 400  # dof=225, mean 225, generous bound


def test_salt_changes_assignment():
    keys = jnp.arange(4096, dtype=jnp.int32)
    a = np.asarray(hashing.hash_bucket(keys, 32, "H", salt=0))
    b = np.asarray(hashing.hash_bucket(keys, 32, "H", salt=1))
    assert (a != b).mean() > 0.9


def test_trailing_zeros_distribution():
    rng = np.random.default_rng(3)
    keys = jnp.asarray(rng.integers(0, 2**31 - 1, size=1 << 16).astype(np.int32))
    rho = np.asarray(hashing.hash_trailing_zeros(keys, 0))
    assert rho.min() >= 1
    # P(rho = k) = 2^-k
    frac1 = (rho == 1).mean()
    assert 0.47 < frac1 < 0.53


@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 300), nb=st.integers(1, 32),
       seed=st.integers(0, 2**31 - 1))
def test_partition_sorted_invariants(n, nb, seed):
    rng = np.random.default_rng(seed)
    rel, data = make_rel(rng, n, ("k",), max(1, n // 2), cap_extra=seed % 7)
    sp = partition.partition_sorted(rel, "k", nb, fn="H")
    offs = np.asarray(sp.offsets)
    ids = np.asarray(sp.bucket_ids)
    keys = np.asarray(sp.rel.col("k"))
    valid = np.asarray(sp.rel.valid)
    # offsets are monotone and cover all valid rows
    assert (np.diff(offs) >= 0).all()
    assert offs[-1] == valid.sum()
    # rows within [offsets[i], offsets[i+1]) hash to bucket i
    for i in range(nb):
        seg = slice(offs[i], offs[i + 1])
        if offs[i + 1] > offs[i]:
            assert (ids[seg] == i).all()
            want = np.asarray(hashing.hash_bucket(
                jnp.asarray(keys[seg]), nb, "H"))
            assert (want == i).all()


@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 300), nb=st.integers(1, 16),
       seed=st.integers(0, 2**31 - 1))
def test_bucketize_preserves_multiset(n, nb, seed):
    rng = np.random.default_rng(seed)
    rel, data = make_rel(rng, n, ("k", "v"), max(1, n // 3))
    cap = partition.suggest_capacity(n, nb, slack=4.0)
    b = partition.bucketize(rel, "k", nb, cap, fn="h")
    if bool(b.overflowed):
        return  # dropped rows allowed only when flagged
    got_k = np.asarray(b.columns["k"])[np.asarray(b.valid)]
    assert sorted(got_k.tolist()) == sorted(data["k"].tolist())
    # every row is in the bucket its key hashes to
    ids = np.asarray(hashing.hash_bucket(jnp.asarray(b.columns["k"]), nb, "h"))
    rows = np.broadcast_to(np.arange(nb)[:, None], ids.shape)
    v = np.asarray(b.valid)
    assert (ids[v] == rows[v]).all()
    # counts match histogram
    want_counts = np.bincount(
        np.asarray(hashing.hash_bucket(jnp.asarray(data["k"]), nb, "h")),
        minlength=nb)
    np.testing.assert_array_equal(np.asarray(b.counts), want_counts)


def test_bucketize_overflow_detection(rng):
    rel, _ = make_rel(rng, 100, ("k",), 1)  # all-equal keys -> one bucket
    b = partition.bucketize(rel, "k", 8, capacity=16, fn="h")
    assert bool(b.overflowed)
    assert int(np.asarray(b.counts).max()) == 100


def test_composite_ids_lexicographic(rng):
    rel, data = make_rel(rng, 64, ("x", "y"), 20)
    ids, total = partition.composite_ids(
        rel, [("x", 4, "H"), ("y", 8, "g")])
    assert total == 32
    hx = np.asarray(hashing.hash_bucket(jnp.asarray(data["x"]), 4, "H"))
    gy = np.asarray(hashing.hash_bucket(jnp.asarray(data["y"]), 8, "g"))
    np.testing.assert_array_equal(np.asarray(ids)[:64], hx * 8 + gy)


def test_composite_ids_int32_guard(rng):
    """Deep/wide specs whose flat id space exceeds int32 must fail loudly —
    a silent wrap would scatter rows into wrong buckets."""
    import pytest

    rel, _ = make_rel(rng, 16, ("x", "y"), 10)
    # 70000 * 70000 = 4.9e9 > 2^31 - 1
    with pytest.raises(ValueError, match="int32"):
        partition.composite_ids(rel, [("x", 70000, "H"), ("y", 70000, "g")])
    # a capacity blowing the flat slot space is caught too
    with pytest.raises(ValueError, match="int32"):
        partition.bucketize_by_ids(
            rel, jnp.zeros(16, jnp.int32), 70000, 70000, (70000,))
    # the boundary itself is fine
    ids, total = partition.composite_ids(rel, [("x", 46341, "H"),
                                               ("y", 46340, "g")])
    assert total == 46341 * 46340 <= 2**31 - 1


def test_sentinel_constant_unified():
    """ONE padding sentinel everywhere, side sentinels derived and distinct:
    no sentinel can equal a live key (>= -2^30) or another side's."""
    import inspect

    from repro.core.relation import SENTINEL, sentinel_fill
    from repro.kernels import ops

    assert inspect.signature(partition.bucketize).parameters[
        "sentinel"].default == SENTINEL
    assert inspect.signature(partition.bucketize_by_ids).parameters[
        "sentinel"].default == SENTINEL
    assert inspect.signature(sentinel_fill).parameters[
        "sentinel"].default == SENTINEL
    sents = set(ops._SENT.values()) | {SENTINEL, ops.SENT_BASE}
    assert len(sents) == len(ops._SENT) + 2          # all distinct
    assert all(s < -(2**30) for s in sents)          # below the key floor


def test_sentinel_rows_never_false_match(rng):
    """Invalid rows carrying ADVERSARIAL key values — another side's probe
    sentinel, the padding sentinel itself — must never join with anything:
    counts equal the oracle over valid rows only."""
    from conftest import oracle_linear3_count
    from repro.core import linear3, engine
    from repro.core.relation import SENTINEL
    from repro.kernels import ops as kops_

    n, d = 120, 20
    adversarial = np.asarray(
        [SENTINEL, kops_.SENT_BASE] + list(kops_._SENT.values()),
        np.int32)

    def poisoned(cols):
        """Relation with 24 invalid tail rows holding sentinel-ish keys."""
        rel = Relation.from_arrays(capacity=n + 24, **cols)
        poison = {
            k: jnp.asarray(np.concatenate(
                [np.asarray(v, np.int32),
                 np.resize(adversarial, 24)]))
            for k, v in cols.items()}
        return Relation(poison, rel.valid)

    rd = {c: rng.integers(0, d, n).astype(np.int32) for c in ("a", "b")}
    sd = {c: rng.integers(0, d, n).astype(np.int32) for c in ("b", "c")}
    td = {c: rng.integers(0, d, n).astype(np.int32) for c in ("c", "d")}
    r, s, t = poisoned(rd), poisoned(sd), poisoned(td)
    want = oracle_linear3_count(rd["b"], sd["b"], sd["c"], td["c"])

    plan = linear3.default_plan(n, n, n, m_budget=48, u=4, slack=4.0)
    res = engine.linear3_count_fused(r, s, t, plan)
    assert int(res.count) == want
    # the bucketized layouts pad dead slots with the canonical sentinel
    rg, sg, tg = engine.linear3_layouts(r, s, t, plan)
    dead = np.asarray(rg.columns["b"])[~np.asarray(rg.valid)]
    assert (dead == SENTINEL).all()
