"""moonshot-v1-16b-a3b (Moonlight-16B-A3B) — MoE 64 experts top-6
[hf:moonshotai/Moonlight-16B-A3B].

48L d_model=2048 16H (MHA kv=16) per-expert d_ff=1408 vocab=163840.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, vocab_size=163840, head_dim=128,
    n_experts=64, top_k=6, moe_d_ff=1408, n_shared_experts=2,
    norm_topk=True, rope_theta=5e4, norm_eps=1e-5,
    scan_group=8, accum_steps=4,
)

SMOKE = ModelConfig(
    name="moonshot-v1-16b-a3b-smoke", family="moe",
    n_layers=2, d_model=96, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab_size=512, head_dim=24,
    n_experts=8, top_k=2, moe_d_ff=64, n_shared_experts=1,
    norm_topk=True, rope_theta=5e4, norm_eps=1e-5, remat=False,
)
