"""Pallas TPU kernels for the join inner loops (+ jnp references).

Layout: <name>.py holds the pl.pallas_call kernels with explicit BlockSpec
VMEM tiling; ops.py is the jit'd public wrapper layer; ref.py the pure-jnp
oracles every kernel is validated against (interpret=True on CPU).
"""
