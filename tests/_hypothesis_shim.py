"""Deterministic stand-in for `hypothesis` on hermetic images.

The real hypothesis is a dev dependency (``pip install -e .[dev]``, used in
CI); accelerator images are built offline and may not carry it.  Rather than
skip the property tests there, ``conftest.py`` installs this shim into
``sys.modules`` when the import fails.  It implements exactly the subset the
suite uses — ``@given`` with keyword strategies, ``@settings(max_examples,
deadline)``, ``st.integers`` / ``st.sampled_from`` / ``st.booleans`` — with
seeded, reproducible draws (no shrinking, no database).
"""

from __future__ import annotations

import random
import types
import zlib

DEFAULT_MAX_EXAMPLES = 20


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def draw(self, rnd: random.Random):
        return self._draw(rnd)


def _integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(lambda rnd: rnd.randint(min_value, max_value))


def _sampled_from(elements) -> _Strategy:
    elems = list(elements)
    return _Strategy(lambda rnd: elems[rnd.randrange(len(elems))])


def _booleans() -> _Strategy:
    return _Strategy(lambda rnd: bool(rnd.getrandbits(1)))


def _floats(min_value: float = 0.0, max_value: float = 1.0,
            **_ignored) -> _Strategy:
    return _Strategy(lambda rnd: rnd.uniform(min_value, max_value))


strategies = types.ModuleType("hypothesis.strategies")
strategies.integers = _integers
strategies.sampled_from = _sampled_from
strategies.booleans = _booleans
strategies.floats = _floats


def settings(max_examples: int | None = None, deadline=None, **_ignored):
    """Records max_examples on the decorated function (applies whether it
    sits above or below @given)."""
    def deco(fn):
        fn._shim_max_examples = max_examples
        return fn
    return deco


def given(**strats):
    def deco(fn):
        # NOTE: no functools.wraps — the wrapper must NOT expose the
        # wrapped signature, or pytest would resolve the strategy
        # parameters as fixtures.
        def wrapper():
            n = (getattr(wrapper, "_shim_max_examples", None)
                 or getattr(fn, "_shim_max_examples", None)
                 or DEFAULT_MAX_EXAMPLES)
            base = zlib.crc32(fn.__qualname__.encode())
            for i in range(n):
                rnd = random.Random(base * 1000003 + i)
                drawn = {k: s.draw(rnd) for k, s in sorted(strats.items())}
                fn(**drawn)
        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = fn.__qualname__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        return wrapper
    return deco


class HealthCheck:
    """Placeholder so `suppress_health_check=[...]` settings parse."""
    too_slow = data_too_large = filter_too_much = None
