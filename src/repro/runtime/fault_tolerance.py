"""Fleet-scale fault tolerance: restartable step loop, straggler
detection, elastic re-mesh restore.

On a real multi-pod fleet the failure modes are: host preemption (SIGTERM
→ checkpoint + exit), hardware loss (process dies → restart from latest
committed checkpoint), and stragglers (slow host stretches every
collective).  This module implements the control-plane logic in a
backend-agnostic way:

  * RestartableLoop — run(step_fn) with checkpoint cadence, SIGTERM-safe
    final save, crash-resume from the newest *committed* checkpoint, and a
    simulated-failure hook used by the integration tests.
  * StragglerMonitor — per-step wall-time EMA + z-score flagging; on a real
    fleet the flag feeds the scheduler's eviction hook (here: logged and
    surfaced in metrics; tests assert detection).
  * elastic_restore — restore a checkpoint written under any device count
    onto the current mesh (checkpoints are host-format; shardings are
    applied at restore).
"""

from __future__ import annotations

import dataclasses
import signal
import time
from typing import Any, Callable

from repro.checkpoint import CheckpointManager


@dataclasses.dataclass
class StragglerStats:
    mean: float
    std: float
    last: float
    z: float
    flagged: bool


class StragglerMonitor:
    """EMA-based step-time outlier detector (z > threshold ⇒ straggler)."""

    def __init__(self, alpha: float = 0.1, threshold: float = 4.0,
                 warmup: int = 5):
        self.alpha = alpha
        self.threshold = threshold
        self.warmup = warmup
        self._mean = 0.0
        self._var = 0.0
        self._n = 0
        self.flags: list[int] = []

    def observe(self, step: int, dt: float) -> StragglerStats:
        self._n += 1
        if self._n <= self.warmup:
            # prime the EMA on the warmup window
            w = 1.0 / self._n
            self._mean = (1 - w) * self._mean + w * dt
            self._var = (1 - w) * self._var + w * (dt - self._mean) ** 2
            return StragglerStats(self._mean, self._var ** 0.5, dt, 0.0,
                                  False)
        std = max(self._var ** 0.5, 1e-6, 0.05 * self._mean)
        z = (dt - self._mean) / std
        flagged = z > self.threshold
        if flagged:
            self.flags.append(step)
        else:
            # only adapt the EMA on non-outliers (don't learn the straggler)
            self._mean = (1 - self.alpha) * self._mean + self.alpha * dt
            self._var = ((1 - self.alpha) * self._var
                         + self.alpha * (dt - self._mean) ** 2)
        return StragglerStats(self._mean, std, dt, z, flagged)


def elastic_restore(template, directory, shardings=None, step=None):
    """Restore the newest committed checkpoint onto the *current* mesh —
    the device count at save time is irrelevant (host-format arrays)."""
    from repro.checkpoint import restore_pytree
    return restore_pytree(template, directory, step=step,
                          shardings=shardings)


class RestartableLoop:
    """Crash-safe training loop driver.

    state = loop.run(state, step_fn, data_iter, n_steps)
      * resumes from the newest committed checkpoint if one exists
      * checkpoints every `every` steps and on SIGTERM
      * `fail_at` (test hook) raises mid-run to simulate a node loss
    """

    def __init__(self, manager: CheckpointManager, *,
                 log: Callable[[str], None] = print,
                 monitor: StragglerMonitor | None = None):
        self.manager = manager
        self.log = log
        self.monitor = monitor or StragglerMonitor()
        self._stop = False

    def _install_sigterm(self):
        def handler(signum, frame):
            self._stop = True
        try:
            signal.signal(signal.SIGTERM, handler)
        except ValueError:
            pass  # not on main thread (tests)

    def resume_step(self, state_template, shardings=None):
        """(state, start_step): restored or (template-as-is, 0)."""
        last = self.manager.latest_step()
        if last is None:
            return None, 0
        state, manifest = self.manager.restore(state_template,
                                               shardings=shardings)
        self.log(f"[ft] resumed from committed step {last}")
        return state, int(manifest["step"])

    def run(self, state: Any, step_fn, batch_for_step, n_steps: int,
            start_step: int = 0, fail_at: int | None = None,
            metrics_cb=None):
        self._install_sigterm()
        step = start_step
        while step < n_steps and not self._stop:
            t0 = time.monotonic()
            batch = batch_for_step(step)
            state, metrics = step_fn(state, batch)
            if fail_at is not None and step == fail_at:
                raise RuntimeError(f"simulated node failure at step {step}")
            dt = time.monotonic() - t0
            stats = self.monitor.observe(step, dt)
            if stats.flagged:
                self.log(f"[ft] straggler step {step}: {dt:.3f}s "
                         f"(z={stats.z:.1f}) — would evict/requeue host")
            if metrics_cb:
                metrics_cb(step, metrics, stats)
            step += 1
            if self.manager.should_save(step):
                self.manager.save(state, step)
                self.log(f"[ft] checkpoint @ step {step}")
        if self._stop:
            self.manager.save(state, step)
            self.log(f"[ft] SIGTERM checkpoint @ step {step}")
        return state, step
