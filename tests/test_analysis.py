"""Tests for ``repro.analysis``: the plan verifier, the integer-width
dataflow analysis, the arena sanitizer, the repo invariant lint, and the
calibration persistence helpers.

Property test: the verifier accepts every plan the planner emits over
random 2–6-relation join trees (uniform and skewed keys) under every
strategy.  Mutation tests: corrupting a specific field of a valid plan
raises the matching typed diagnostic.  Width regressions pin the two
seeded hazards from the issue: an int32 composite-id overflow (error) and
a 2^24 exact-f32 accumulator ceiling (hazard), both caught at plan time.
"""

from __future__ import annotations

import dataclasses
import json
from pathlib import Path

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from conftest import make_rel, oracle_linear3_count, skewed_keys
from repro.analysis import arena_sanitizer, lint_invariants
from repro.analysis.arena_sanitizer import ArenaSanitizerError, ArenaShadow
from repro.analysis.errors import (PlanPerRError, PlanRefcountError,
                                   PlanSchemaError, PlanStructureError,
                                   PlanValidationError, PlanWidthError)
from repro.analysis.verify_plan import verify_plan
from repro.analysis.widths import analyze_widths, check_widths
from repro.core import planner
from repro.core.cyclic3 import Cyclic3Plan
from repro.core.linear3 import Linear3Plan
from repro.core.plan_ir import execute_plan
from repro.core.query import Query
from repro.core.relation import Relation
from repro.core.session import JoinSession
from repro.kernels.ops import EXACT_F32_MAX
from repro.perfmodel import calibrate


# --------------------------------------------------------------------------
# helpers
# --------------------------------------------------------------------------

def _cards(query: Query) -> dict[str, int]:
    return {name: int(rel.n) for name, rel in query.relations.items()}


def _schemas(query: Query) -> dict[str, frozenset]:
    return {name: frozenset(rel.columns)
            for name, rel in query.relations.items()}


def _linear_chain(rng, n=120, d=25):
    r, rd = make_rel(rng, n, ("a", "b"), d)
    s, sd = make_rel(rng, n + 30, ("b", "c"), d)
    t, td = make_rel(rng, n + 10, ("c", "d"), d)
    q = Query({"r": r, "s": s, "t": t},
              [("r.b", "s.b"), ("s.c", "t.c")])
    return q, {"r": rd, "s": sd, "t": td}


def _triangle(rng, n=120, d=25):
    r, _ = make_rel(rng, n, ("a", "b"), d)
    s, _ = make_rel(rng, n + 20, ("b", "c"), d)
    t, _ = make_rel(rng, n + 10, ("c", "a"), d)
    return Query({"r": r, "s": s, "t": t},
                 [("r.b", "s.b"), ("s.c", "t.c"), ("t.a", "r.a")])


def _random_tree_query(seed: int, n_rel: int, skew: bool) -> Query:
    """A random connected acyclic join tree: relation i joins an earlier
    relation on a shared column ``k<i>``; every relation also carries a
    payload column.  This is the full space of query graphs the planner's
    contraction path handles for N >= 2."""
    rng = np.random.default_rng(seed)
    parents = {i: int(rng.integers(1, i)) for i in range(2, n_rel + 1)}
    cols: dict[int, set[str]] = {i: {f"p{i}"} for i in range(1, n_rel + 1)}
    for i, p in parents.items():
        cols[i].add(f"k{i}")
        cols[p].add(f"k{i}")
    rels = {}
    for i in range(1, n_rel + 1):
        n = int(rng.integers(40, 200))
        d = int(rng.integers(8, 40))
        data = {}
        for c in sorted(cols[i]):
            if skew and c == f"k{i}":
                data[c] = skewed_keys(rng, n, d, 0.4)
            else:
                data[c] = rng.integers(0, d, size=n).astype(np.int32)
        rels[f"r{i}"] = Relation.from_arrays(**data)
    preds = [(f"r{i}.k{i}", f"r{p}.k{i}")
             for i, p in sorted(parents.items())]
    return Query(rels, preds)


# --------------------------------------------------------------------------
# verifier: every planner-emitted plan passes (property)
# --------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10**6),
       n_rel=st.integers(min_value=2, max_value=6),
       skew=st.booleans(),
       strategy=st.sampled_from(["default", "3way", "cascade"]))
def test_verifier_accepts_planner_plans(seed, n_rel, skew, strategy):
    query = _random_tree_query(seed, n_rel, skew)
    if n_rel == 2 and strategy == "3way":
        strategy = "default"
    cards = _cards(query)
    qp = planner.plan_query(query, cards, m_budget=64,
                            strategy=None if strategy == "default"
                            else strategy)
    # plan-time mode (schemas: schema propagation end to end) ...
    verify_plan(qp, schemas=_schemas(query))
    # ... and execute-time mode (external environment names)
    verify_plan(qp, external=set(cards))
    # width analysis never errors on a planner-emitted small plan
    for diag in check_widths(qp, cards):
        assert diag.severity == "hazard"


def test_verifier_accepts_per_r_plan(rng):
    query, _ = _linear_chain(rng)
    cards = _cards(query)
    r_name = dict(query.classify(cards).roles)["r"]
    qp = planner.plan_query(query, cards, m_budget=64, strategy="3way",
                            per_r_name=r_name)
    assert any(s.per_r_key is not None for s in qp.steps)
    verify_plan(qp, schemas=_schemas(query))


def test_verifier_accepts_triangle_plan(rng):
    query = _triangle(rng)
    qp = planner.plan_query(query, _cards(query), m_budget=64,
                            strategy="3way")
    assert qp.steps[-1].kind == "cyclic"
    verify_plan(qp, schemas=_schemas(query))


# --------------------------------------------------------------------------
# verifier: mutations raise the matching typed diagnostic
# --------------------------------------------------------------------------

@pytest.fixture
def lin_cascade(rng):
    query, _ = _linear_chain(rng)
    qp = planner.plan_query(query, _cards(query), m_budget=64,
                            strategy="cascade")
    assert len(qp.steps) == 2 and qp.steps[0].op == "binary"
    return query, qp


@pytest.fixture
def lin_fused(rng):
    query, _ = _linear_chain(rng)
    qp = planner.plan_query(query, _cards(query), m_budget=64,
                            strategy="3way")
    assert len(qp.steps) == 1 and qp.steps[0].op == "fused3"
    return query, qp


def test_verifier_rejects_reversed_steps(lin_cascade):
    query, qp = lin_cascade
    bad = dataclasses.replace(qp, steps=tuple(reversed(qp.steps)))
    with pytest.raises(PlanStructureError):
        verify_plan(bad, schemas=_schemas(query))


def test_verifier_rejects_duplicate_out(rng):
    query = Query({f"r{i + 1}": make_rel(rng, 80, cols, 20)[0]
                   for i, cols in enumerate((("a", "b"), ("b", "c"),
                                             ("c", "d"), ("d", "e")))},
                  [("r1.b", "r2.b"), ("r2.c", "r3.c"), ("r3.d", "r4.d")])
    qp = planner.plan_query(query, _cards(query), m_budget=64,
                            strategy="cascade")
    assert len(qp.steps) == 3
    steps = list(qp.steps)
    steps[1] = dataclasses.replace(steps[1], out=steps[0].out)
    with pytest.raises(PlanStructureError):
        verify_plan(dataclasses.replace(qp, steps=tuple(steps)),
                    schemas=_schemas(query))


def test_verifier_rejects_bad_column_binding(lin_fused):
    query, qp = lin_fused
    root = qp.steps[0]
    bad_cols = tuple((k, "zz" if k == "rb" else v) for k, v in root.cols)
    bad = dataclasses.replace(
        qp, steps=(dataclasses.replace(root, cols=bad_cols),))
    with pytest.raises(PlanSchemaError):
        verify_plan(bad, schemas=_schemas(query))


def test_verifier_rejects_bad_projection_source(lin_cascade):
    query, qp = lin_cascade
    step0 = qp.steps[0]
    assert step0.project
    proj_a = tuple(("zz", dst) for _src, dst in step0.project[0])
    bad0 = dataclasses.replace(step0,
                               project=(proj_a,) + step0.project[1:])
    with pytest.raises(PlanSchemaError):
        verify_plan(dataclasses.replace(qp, steps=(bad0,) + qp.steps[1:]),
                    schemas=_schemas(query))


def test_verifier_rejects_unconsumed_intermediate(lin_cascade, lin_fused):
    query, cascade = lin_cascade
    _, fused = lin_fused
    # a materialize step whose %i0 no later step reads: the refcounting
    # arena would hold the buffer for the whole walk
    bad = dataclasses.replace(
        cascade, steps=(cascade.steps[0], fused.steps[0]))
    with pytest.raises(PlanRefcountError):
        verify_plan(bad, schemas=_schemas(query))


def test_verifier_rejects_per_r_on_cyclic(rng):
    query = _triangle(rng)
    qp = planner.plan_query(query, _cards(query), m_budget=64,
                            strategy="3way")
    bad_root = dataclasses.replace(qp.steps[0], per_r_key="a")
    with pytest.raises(PlanPerRError):
        verify_plan(dataclasses.replace(qp, steps=(bad_root,)),
                    schemas=_schemas(query))


def test_verifier_rejects_unrecovered_fused(lin_fused):
    query, qp = lin_fused
    bad_root = dataclasses.replace(qp.steps[0], recovery=False)
    with pytest.raises(PlanStructureError):
        verify_plan(dataclasses.replace(qp, steps=(bad_root,)),
                    schemas=_schemas(query))


def test_verifier_rejects_orphan_relation(lin_fused, rng):
    query, qp = lin_fused
    schemas = dict(_schemas(query))
    schemas["zzz"] = frozenset({"a"})
    with pytest.raises(PlanStructureError, match="orphan"):
        verify_plan(qp, schemas=schemas)


def test_verifier_error_names_failing_step(lin_cascade):
    query, qp = lin_cascade
    bad = dataclasses.replace(qp, steps=tuple(reversed(qp.steps)))
    with pytest.raises(PlanStructureError) as exc:
        verify_plan(bad, schemas=_schemas(query))
    msg = str(exc.value)
    assert "at step[" in msg and "<-" in msg


# --------------------------------------------------------------------------
# width analysis: the two seeded regressions + clean plans
# --------------------------------------------------------------------------

def test_widths_composite_id_overflow_is_plan_time_error(rng):
    """A pinned cyclic shape whose role-r composite-id space
    (h_parts * g_parts * uh * ug = 2^34) cannot be hashed in int32 must be
    refused at plan time, before any device work."""
    query = _triangle(rng)
    qp = planner.plan_query(query, _cards(query), m_budget=64,
                            strategy="3way")
    shape = Cyclic3Plan(h_parts=2**13, g_parts=2**13, uh=16, ug=16,
                        f_parts=2, r_cap=8, s_cap=8, t_cap=8)
    bad_root = dataclasses.replace(qp.steps[0], shape_plan=shape)
    bad = dataclasses.replace(qp, steps=(bad_root,))
    with pytest.raises(PlanWidthError) as exc:
        check_widths(bad, _cards(query))
    errors = [d for d in exc.value.diagnostics if d.severity == "error"]
    assert any("composite-id" in d.quantity for d in errors)
    assert all(d.width_needed.startswith("int3") for d in errors)


def test_widths_f32_accumulator_ceiling_is_hazard(rng):
    """A linear shape whose per-cell accumulator ceiling
    (r_cap * g_parts * s_cap * t_cap) crosses 2^24 is flagged as a hazard
    (a compiled f32 kernel would lose counts) but does NOT fail the plan —
    the product is a total-skew ceiling, not a guarantee."""
    query, _ = _linear_chain(rng)
    qp = planner.plan_query(query, _cards(query), m_budget=64,
                            strategy="3way")
    shape = Linear3Plan(h_parts=4, u=8, g_parts=64,
                        r_cap=64, s_cap=64, t_cap=72)
    assert shape.r_cap * shape.g_parts * shape.s_cap * shape.t_cap \
        > EXACT_F32_MAX
    root = dataclasses.replace(qp.steps[0], shape_plan=shape)
    plan = dataclasses.replace(qp, steps=(root,))
    diags = check_widths(plan, _cards(query))   # must NOT raise
    hz = [d for d in diags if d.quantity == "accumulator cell ceiling"]
    assert len(hz) == 1 and hz[0].severity == "hazard"
    assert hz[0].limit == EXACT_F32_MAX
    assert hz[0].bound == 64 * 64 * 64 * 72


def test_widths_materialize_overflow_is_error(lin_cascade):
    query, qp = lin_cascade
    big0 = dataclasses.replace(qp.steps[0], est_out=2**31)
    bad = dataclasses.replace(qp, steps=(big0,) + qp.steps[1:])
    with pytest.raises(PlanWidthError, match="materialized rows"):
        check_widths(bad, _cards(query))


def test_widths_input_cardinality_overflow_is_error(lin_fused):
    query, qp = lin_fused
    cards = dict(_cards(query))
    cards["r"] = 2**31
    with pytest.raises(PlanWidthError, match="input cardinality"):
        check_widths(qp, cards)


def test_widths_clean_plan_has_no_errors(lin_cascade, lin_fused):
    for query, qp in (lin_cascade, lin_fused):
        for diag in analyze_widths(qp, _cards(query)):
            assert diag.severity == "hazard"


# --------------------------------------------------------------------------
# executor: typed errors, execute-time verification gate
# --------------------------------------------------------------------------

def test_plan_errors_subclass_value_error():
    for exc_type in (PlanStructureError, PlanSchemaError,
                     PlanRefcountError, PlanPerRError, PlanWidthError):
        assert issubclass(exc_type, PlanValidationError)
        assert issubclass(exc_type, ValueError)


def test_executor_unknown_op_is_typed(lin_cascade):
    query, qp = lin_cascade
    bad0 = dataclasses.replace(qp.steps[0], op="scan")
    bad = dataclasses.replace(qp, steps=(bad0,) + qp.steps[1:])
    with pytest.raises(PlanStructureError):
        execute_plan(bad, dict(query.relations))


def test_executor_per_r_on_cyclic_is_typed(rng):
    query = _triangle(rng)
    qp = planner.plan_query(query, _cards(query), m_budget=64,
                            strategy="3way")
    bad_root = dataclasses.replace(qp.steps[0], per_r_key="a")
    with pytest.raises(PlanPerRError):
        execute_plan(dataclasses.replace(qp, steps=(bad_root,)),
                     dict(query.relations))


def test_execute_time_verification_gate(monkeypatch, lin_cascade):
    query, qp = lin_cascade
    bad = dataclasses.replace(qp, steps=tuple(reversed(qp.steps)))
    monkeypatch.setenv("REPRO_VERIFY_PLANS", "1")
    with pytest.raises(PlanStructureError):
        execute_plan(bad, dict(query.relations))


# --------------------------------------------------------------------------
# arena sanitizer
# --------------------------------------------------------------------------

def test_sanitizer_shadow_clean_walk(lin_cascade):
    query, qp = lin_cascade
    inter = qp.steps[0].out
    shadow = ArenaShadow(qp, query.relations, keep_intermediates=False)
    shadow.on_produce(inter)
    for name in ("r", "s", inter, "t"):
        shadow.on_release(name)
    shadow.on_drop(inter)
    shadow.finish({})


def test_sanitizer_shadow_double_release(lin_cascade):
    query, qp = lin_cascade
    shadow = ArenaShadow(qp, query.relations, keep_intermediates=False)
    shadow.on_release("r")
    with pytest.raises(ArenaSanitizerError, match="double release"):
        shadow.on_release("r")
    with pytest.raises(ArenaSanitizerError, match="no step"):
        shadow.on_release("%i9")


def test_sanitizer_shadow_drop_before_last_consumer(lin_cascade):
    query, qp = lin_cascade
    inter = qp.steps[0].out
    shadow = ArenaShadow(qp, query.relations, keep_intermediates=False)
    shadow.on_produce(inter)
    with pytest.raises(ArenaSanitizerError, match="consumer"):
        shadow.on_drop(inter)


def test_sanitizer_shadow_leak_and_lost_consumer(lin_cascade):
    query, qp = lin_cascade
    inter = qp.steps[0].out
    shadow = ArenaShadow(qp, query.relations, keep_intermediates=False)
    shadow.on_produce(inter)
    with pytest.raises(ArenaSanitizerError, match="unconsumed"):
        shadow.finish({})       # nobody released anything
    for name in ("r", "s", inter, "t"):
        shadow.on_release(name)
    with pytest.raises(ArenaSanitizerError, match="leaked"):
        shadow.finish({inter: object()})


def test_sanitizer_shadow_produce_twice_and_keep_drop(lin_cascade):
    query, qp = lin_cascade
    inter = qp.steps[0].out
    shadow = ArenaShadow(qp, query.relations, keep_intermediates=True)
    shadow.on_produce(inter)
    with pytest.raises(ArenaSanitizerError, match="produced twice"):
        shadow.on_produce(inter)
    for name in ("r", "s", inter, "t"):
        shadow.on_release(name)
    with pytest.raises(ArenaSanitizerError, match="keep_intermediates"):
        shadow.on_drop(inter)


def test_sanitizer_activation_toggle(monkeypatch):
    monkeypatch.setenv("REPRO_SANITIZE_ARENA", "0")
    assert not arena_sanitizer.active()
    with arena_sanitizer.enabled():
        assert arena_sanitizer.active()
    assert not arena_sanitizer.active()
    monkeypatch.setenv("REPRO_SANITIZE_ARENA", "1")
    assert arena_sanitizer.active()


def test_sanitizer_check_residents(monkeypatch, lin_cascade):
    query, qp = lin_cascade
    inter = qp.steps[0].out
    with arena_sanitizer.enabled():
        arena_sanitizer.check_residents(qp, {inter: object()})
        with pytest.raises(ArenaSanitizerError, match="missing"):
            arena_sanitizer.check_residents(qp, {})
        with pytest.raises(ArenaSanitizerError, match="unexpected"):
            arena_sanitizer.check_residents(
                qp, {inter: object(), "%i9": object()})
    # inactive -> no-op even on divergent residents
    monkeypatch.setenv("REPRO_SANITIZE_ARENA", "0")
    arena_sanitizer.check_residents(qp, {})


def test_sanitizer_clean_execution(rng):
    query, data = _linear_chain(rng)
    want = oracle_linear3_count(data["r"]["b"], data["s"]["b"],
                                data["s"]["c"], data["t"]["c"])
    with arena_sanitizer.enabled():
        sess = JoinSession(m_budget=128)
        assert int(sess.execute(query, strategy="cascade").count) == want
        assert int(sess.execute(query, strategy="3way").count) == want


def test_sanitizer_streaming_ingest(rng):
    query, _ = _linear_chain(rng)
    d = 25
    with arena_sanitizer.enabled():
        sess = JoinSession(m_budget=128)
        sq = sess.watch(query)
        for _ in range(2):
            rel = query.relations["s"]
            rel.append(**{c: rng.integers(0, d, 40).astype(np.int32)
                          for c in rel.columns})
        want = int(JoinSession(m_budget=128).execute(query).count)
        assert sq.count == want
        sq.close()


# --------------------------------------------------------------------------
# invariant lint
# --------------------------------------------------------------------------

def test_lint_clean_on_repo_source():
    import repro
    src = Path(repro.__file__).resolve().parent
    assert lint_invariants.lint_paths([src]) == []


def test_lint_flags_each_rule(tmp_path):
    bad = tmp_path / "bad.py"
    bad.write_text(
        "import numpy as np\n"
        "def f(rel, x):\n"
        "    rel.columns['a'] = x\n"
        "    rel.valid = x\n"
        "    object.__setattr__(rel, 'columns', {})\n"
        "    u = np.unique(x)\n"
        "    s = -0x7FFFFFFF\n"
        "    tot = np.sum(x, dtype=np.float32)\n"
        "    tot2 = x.astype(np.float32).sum()\n"
        "    return u, s, tot, tot2\n")
    findings = lint_invariants.lint_file(bad)
    rules = [f.split("[")[1].split("]")[0] for f in findings]
    assert rules.count("relation-mutation") == 3
    assert rules.count("np-unique") == 1
    assert rules.count("sentinel-literal") == 1
    assert rules.count("float-count-accum") == 2


def test_lint_pallas_gate(tmp_path):
    f = tmp_path / "kern.py"
    f.write_text(
        "import jax.experimental.pallas as pl\n"
        "def g(k, o, _interpret):\n"
        "    a = pl.pallas_call(k, out_shape=o)\n"
        "    b = pl.pallas_call(k, out_shape=o, interpret=True)\n"
        "    if _interpret:\n"
        "        c = pl.pallas_call(k, out_shape=o, interpret=True)\n"
        "    return a, b, c\n")
    findings = lint_invariants.lint_file(f)
    assert len(findings) == 2
    assert all("pallas-gate" in x for x in findings)
    assert not any(":6:" in x for x in findings)   # the gated call is fine


def test_lint_allows_implementation_files(tmp_path):
    core = tmp_path / "core"
    core.mkdir()
    rel_py = core / "relation.py"
    rel_py.write_text("def f(rel, x):\n"
                      "    rel.columns['a'] = x\n"
                      "    s = -0x7FFFFFFF\n"
                      "    return s\n")
    assert lint_invariants.lint_file(rel_py) == []
    ref_py = core / "reference.py"
    ref_py.write_text("import numpy as np\n"
                      "def g(x):\n"
                      "    return np.unique(x)\n")
    assert lint_invariants.lint_file(ref_py) == []


# --------------------------------------------------------------------------
# calibration persistence
# --------------------------------------------------------------------------

def _bench_record():
    return {"shapes": {"cascade_4way": {
        "fused_root_s": 2.0, "binary_tail_s": 1.0,
        "model_t3_s": 0.5, "model_tc_s": 0.25}}}


def test_calibration_file_roundtrip(tmp_path):
    out = tmp_path / "cal.json"
    cal = calibrate.refresh_calibration_file(_bench_record(), out)
    assert cal.fused3_scale == pytest.approx(4.0)
    assert cal.cascade_scale == pytest.approx(4.0)
    loaded = calibrate.calibration_from_file(out)
    assert loaded.fused3_scale == pytest.approx(cal.fused3_scale)
    assert loaded.cascade_scale == pytest.approx(cal.cascade_scale)
    assert loaded.source == "bench:cascade_4way"


def test_calibration_file_never_guesses(tmp_path):
    assert calibrate.calibration_from_file(tmp_path / "nope.json") \
        == calibrate.IDENTITY
    bad = tmp_path / "bad.json"
    bad.write_text("{not json")
    assert calibrate.calibration_from_file(bad) == calibrate.IDENTITY
    out = tmp_path / "cal.json"
    calibrate.refresh_calibration_file({"shapes": {}}, out)
    assert out.exists()
    assert calibrate.calibration_from_file(out) == calibrate.IDENTITY


def test_session_refresh_calibration_adopts_and_clears_cache(tmp_path, rng):
    query, _ = _linear_chain(rng)
    sess = JoinSession(m_budget=128)
    sess.execute(query)
    assert sess.cache_info["size"] == 1
    bench = tmp_path / "bench.json"
    bench.write_text(json.dumps(_bench_record()))
    out = tmp_path / "cal.json"
    cal = sess.refresh_calibration(bench, out_path=out)
    assert sess.calibration is cal
    assert cal.source == "bench:cascade_4way"
    assert out.exists()
    assert sess.cache_info["size"] == 0
