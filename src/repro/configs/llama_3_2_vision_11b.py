"""llama-3.2-vision-11b — dense backbone with cross-attn image layers
[hf:meta-llama/Llama-3.2-11B-Vision; unverified].

40L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=128256; a vision
cross-attention layer after every 5 self-attention layers (8 total).  The
image frontend is stubbed: input_specs() provides patch embeddings
[B, n_patches, d_model].
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b", family="vlm",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab_size=128256, head_dim=128,
    cross_attn_every=5, n_frontend_tokens=1601,
    rope_theta=5e5, norm_eps=1e-5,
    accum_steps=4,
)

SMOKE = ModelConfig(
    name="llama-3.2-vision-11b-smoke", family="vlm",
    n_layers=4, d_model=96, n_heads=4, n_kv_heads=2,
    d_ff=256, vocab_size=512, head_dim=24,
    cross_attn_every=2, n_frontend_tokens=16,
    rope_theta=5e5, norm_eps=1e-5, remat=False,
)
