"""Scan-based reference baselines: whole-query retry drivers and the host
join-count oracle.

The paper assumes near-uniform keys (§1.2) and notes that skew must be
handled by "leaving some components to handle overflow" or re-partitioning.
These drivers implement the naive whole-query version of that loop: on
overflow, grow the per-bucket capacities geometrically and re-run the whole
join.  Capacities are static shapes, so each retry re-jits; the fused
engine's surgical per-cell recovery (``core.recovery``) replaces this in
the production path, and these functions remain ONLY as the scan-based
baselines the engine is benchmarked and property-tested against.

This module is also the one place host ``np.unique`` is allowed (the
``analysis.lint_invariants`` np-unique rule): :func:`host_join_count` is
the host-histogram parity oracle the device-side ``exact_join_count`` is
tested against — nothing on the execution hot path calls it.

(Historical note: these lived in ``core.driver`` next to the
``engine_count``/``engine_per_r_counts`` deprecation shims; the shims are
gone — build a ``core.query.Query`` and execute it through
``core.session.JoinSession`` — and the baselines moved here.)
"""

from __future__ import annotations

from typing import Any

import numpy as np

from repro.core import cyclic3, linear3, recovery, star3
from repro.core.relation import Relation


class OverflowError_(RuntimeError):
    pass


def host_join_count(build: Relation, build_key: str,
                    probe: Relation, probe_key: str) -> int:
    """Exact ``|build ⋈ probe|`` via host-side key histograms (np.unique +
    intersect1d).  The former ``exact_join_count`` — kept as the parity
    oracle for the device-side path (re-exported from ``binary_join``)."""
    bv = np.asarray(build.col(build_key))[np.asarray(build.valid)]
    pv = np.asarray(probe.col(probe_key))[np.asarray(probe.valid)]
    bu, bc = np.unique(bv, return_counts=True)
    pu, pc = np.unique(pv, return_counts=True)
    _, bi, pi = np.intersect1d(bu, pu, return_indices=True)
    return int((bc[bi].astype(np.int64) * pc[pi].astype(np.int64)).sum())


def _grown(plan: Any, growth: float, align: int = 8) -> Any:
    return recovery.grown(plan, growth, align)


def linear3_count_auto(r, s, t, plan: linear3.Linear3Plan, *,
                       max_retries: int = 4, growth: float = 2.0, **kw):
    """linear3_count with geometric capacity growth on overflow."""
    for _ in range(max_retries + 1):
        res = linear3.linear3_count(r, s, t, plan, **kw)
        if not bool(res.overflowed):
            return res, plan
        plan = _grown(plan, growth)
    raise OverflowError_(f"linear3 overflow persisted; final plan {plan}")


def linear3_per_r_counts_auto(r, s, t, plan: linear3.Linear3Plan, *,
                              max_retries: int = 4, growth: float = 2.0, **kw):
    for _ in range(max_retries + 1):
        keys, counts, valid, ovf = linear3.linear3_per_r_counts(
            r, s, t, plan, **kw)
        if not bool(ovf):
            return (keys, counts, valid), plan
        plan = _grown(plan, growth)
    raise OverflowError_(f"linear3 per-r overflow persisted; final plan {plan}")


def cyclic3_count_auto(r, s, t, plan: cyclic3.Cyclic3Plan, *,
                       max_retries: int = 4, growth: float = 2.0, **kw):
    for _ in range(max_retries + 1):
        res = cyclic3.cyclic3_count(r, s, t, plan, **kw)
        if not bool(res.overflowed):
            return res, plan
        plan = _grown(plan, growth)
    raise OverflowError_(f"cyclic3 overflow persisted; final plan {plan}")


def star3_count_auto(r, s, t, plan: star3.Star3Plan, *,
                     max_retries: int = 4, growth: float = 2.0, **kw):
    for _ in range(max_retries + 1):
        res = star3.star3_count(r, s, t, plan, **kw)
        if not bool(res.overflowed):
            return res, plan
        plan = _grown(plan, growth)
    raise OverflowError_(f"star3 overflow persisted; final plan {plan}")
