"""Standing queries under continuous ingest, end to end.

    PYTHONPATH=src python examples/streaming_counts.py

Registers a standing 3-way join count, streams delta batches into each
relation, and shows the delta plans keeping the count exact (verified
against a from-scratch execution at the end) without ever re-reading the
full inputs.
"""

import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent
                       / "src"))

import numpy as np

from repro.core import JoinSession, Query, Relation

rng = np.random.default_rng(0)
N, D = 20_000, 2_048


def fresh(n, *cols):
    return Relation.from_arrays(
        **{c: rng.integers(0, D, n).astype(np.int32) for c in cols})


# orders ⋈ users ⋈ items: count qualifying (order, user, item) triples
orders = fresh(N, "user", "item")
users = fresh(N // 4, "user", "region")
items = fresh(N // 8, "item", "vendor")

q = Query({"orders": orders, "users": users, "items": items},
          [("orders.user", "users.user"), ("orders.item", "items.item")])

sess = JoinSession(m_budget=1024)
sq = sess.watch(q)
print(f"standing count at registration: {sq.count:,}")

# stream ingest: small delta batches, rotating over the relations
for step in range(6):
    k = 200
    if step % 3 == 0:
        orders.append(user=rng.integers(0, D, k),
                      item=rng.integers(0, D, k))
    elif step % 3 == 1:
        users.append(user=rng.integers(0, D, k),
                     region=rng.integers(0, D, k))
    else:
        items.append(item=rng.integers(0, D, k),
                     vendor=rng.integers(0, D, k))
    rec = sq.delta_rounds[-1]
    print(f"  +{rec.delta_rows} rows into {rec.relation:<6} → "
          f"Δcount={rec.count_delta:+,}  ({rec.exec_s * 1e3:.1f} ms, "
          f"rounds={rec.rounds}, overflowed={rec.overflowed})")

snap = sq.snapshot()
oracle = JoinSession(m_budget=1024).execute(q)
print(f"standing count: {int(snap.count):,}")
print(f"from scratch:   {int(oracle.count):,}  "
      f"(match={int(snap.count) == int(oracle.count)})")
assert int(snap.count) == int(oracle.count)
assert not bool(snap.overflowed)
sq.close()
