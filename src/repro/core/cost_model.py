"""The paper's cost analysis (§4.2, §5.2, §6.3) as executable formulas.

Cost metric: number of tuples read onto the accelerator chip.  These are the
closed forms the algorithms' realized ``tuples_read`` are validated against,
and the inputs to the planner's 3-way vs cascaded-binary decision.

All counts are float (they model 1e11-scale relations); M is the on-chip
memory budget in tuples; d is the max distinct values over join columns.
"""

from __future__ import annotations

import math
from typing import NamedTuple


def linear3_tuples(n_r: float, n_s: float, n_t: float, m: float) -> float:
    """|R| + |S| + |R||T|/M  (§4.2).  R should be the smaller of R, T."""
    return n_r + n_s + (n_r * n_t) / m


def cyclic3_optimal_h(n_r: float, n_s: float, n_t: float, m: float) -> float:
    """H* = √(|R||T| / (M|S|))  (§5.2)."""
    return math.sqrt((n_r * n_t) / (m * n_s))


def cyclic3_tuples(n_r: float, n_s: float, n_t: float, m: float,
                   h: float | None = None) -> float:
    """|R| + H|S| + G|T| with GH = |R|/M;  at H* this is
    |R| + 2√(|R||S||T|/M)  (§5.2)."""
    if h is None:
        return n_r + 2.0 * math.sqrt(n_r * n_s * n_t / m)
    g = n_r / (m * h)
    return n_r + h * n_s + g * n_t


def intermediate_size(n_r: float, n_s: float, d: float) -> float:
    """|R ⋈ S| ≤ |R||S|/d under the uniform assumption (Swami–Schiefer)."""
    return n_r * n_s / d


def cascaded_binary_tuples(n_r: float, n_s: float, n_t: float, m: float,
                           d: float) -> float:
    """Tuples moved on/off chip for the cascade: read R,S; write intermediate
    I; read I back; read T once per I-partition batch (T partition-resident
    like Algorithm 1 with I streamed — the paper streams I and loads T
    partitions; tuple traffic: |R|+|S| + 2|I| + |T|)."""
    i = intermediate_size(n_r, n_s, d)
    return n_r + n_s + 2.0 * i + n_t


class PlanChoice(NamedTuple):
    strategy: str          # "linear3" | "cascade"
    tuples_3way: float
    tuples_cascade: float
    speed_ratio: float     # cascade / 3way traffic ratio (>1 favors 3-way)


def choose_linear_strategy(n_r: float, n_s: float, n_t: float, m: float,
                           d: float) -> PlanChoice:
    """§4.2 / Example 3 decision: 3-way wins iff its total tuple traffic is
    below the cascade's (which includes the intermediate round-trip)."""
    t3 = linear3_tuples(n_r, n_s, n_t, m)
    tc = cascaded_binary_tuples(n_r, n_s, n_t, m, d)
    return PlanChoice("linear3" if t3 < tc else "cascade", t3, tc, tc / t3)


def choose_cyclic_strategy(n_r: float, n_s: float, n_t: float, m: float,
                           d: float) -> PlanChoice:
    t3 = cyclic3_tuples(n_r, n_s, n_t, m)
    tc = cascaded_binary_tuples(n_r, n_s, n_t, m, d)
    return PlanChoice("cyclic3" if t3 < tc else "cascade", t3, tc, tc / t3)


def example3_threshold_m(n: float = 6e11) -> float:
    """Example 3: the M above which the 3-way self-join reads fewer tuples
    than the cascade's intermediate for the Facebook relation."""
    # n + n + n²/M < 3.6e14  =>  M > n² / (3.6e14 - 2n)
    rhs = 3.6e14 - 2.0 * n
    return (n * n) / rhs


def example4_threshold_m(n: float = 6e11,
                         intermediate: float = 1.8e14) -> float:
    """Example 4: minimal M for the cyclic 3-way to beat the intermediate.

    Follows the paper's in-text expression n(1 + √(n/M)) — which drops the
    factor 2 of the §5.2 closed form (a paper-internal inconsistency we
    reproduce as written; see EXPERIMENTS.md §Paper-claims).
    """
    # n(1 + sqrt(n/M)) < intermediate  =>  M > n / (intermediate/n - 1)^2
    return n / (intermediate / n - 1.0) ** 2
