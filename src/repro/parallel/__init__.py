"""Parallelism substrate: logical-axis sharding rules + mesh context."""

from repro.parallel.sharding import (  # noqa: F401
    MeshContext, current_context, set_context, shard, sharding_for,
    DEFAULT_RULES, spec_for)
