"""End-to-end analytics driver: the paper's Example 1 (friends-of-friends-
of-friends) and Example 2 (triangles) on a synthetic social graph.

    PYTHONPATH=src python examples/analytics_3way.py [--users 2000] \
        [--friends 40]

Pipeline (all on the join engine, aggregates only — nothing materialized):
  1. generate a friends relation F (n = users·friends edges),
  2. declare the self 3-way F ⋈ F ⋈ F as a query graph (three aliases of
     one relation) and execute it with per-user COUNT through ONE
     JoinSession, plus the Flajolet-Martin DISTINCT sketch (the paper's
     footnote-4 aggregation),
  3. declare the triangle query (a 3-cycle in the predicate graph) —
     community cohesion metric — on the same session,
  4. planner report: what the cost model would pick at Facebook scale.
"""

import argparse
import pathlib
import sys
import time

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent
                       / "src"))

import numpy as np  # noqa: E402

from repro.core import (JoinSession, Query, cost_model,  # noqa: E402
                        linear3, sketches)
from repro.core.relation import Relation  # noqa: E402


def friends_graph(users: int, friends: int, seed: int = 0):
    """Symmetric friendship edges, ~friends per user."""
    rng = np.random.default_rng(seed)
    n_edges = users * friends // 2
    a = rng.integers(0, users, size=n_edges).astype(np.int32)
    b = rng.integers(0, users, size=n_edges).astype(np.int32)
    keep = a != b
    a, b = a[keep], b[keep]
    src = np.concatenate([a, b])
    dst = np.concatenate([b, a])
    return src, dst


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--users", type=int, default=2000)
    ap.add_argument("--friends", type=int, default=40)
    args = ap.parse_args()

    src, dst = friends_graph(args.users, args.friends)
    n = len(src)
    print(f"friends relation: {n} edges over {args.users} users "
          f"(f ≈ {n / args.users:.0f})")

    friends = Relation.from_arrays(src=src, dst=dst)
    sess = JoinSession(m_budget=max(n // 4, 2048))

    # --- Example 1: friends-of-friends-of-friends ------------------------
    # the self 3-way as a declarative query graph: one relation, three
    # aliases, a path of equality predicates — the session classifies it
    # as the linear chain and plans/executes/recovers in one call
    fofof = Query(
        relations={"f1": friends, "f2": friends, "f3": friends},
        predicates=[("f1.dst", "f2.src"), ("f2.dst", "f3.src")])
    t0 = time.time()
    res = sess.execute(fofof, per_r=True, key_col="src")
    print(f"\nFoFoF paths (COUNT, with duplicates): {int(res.count):,} "
          f"in {time.time() - t0:.2f}s; classified {res.kind}, strategy "
          f"{res.strategy}; tuples read on-chip = {int(res.tuples_read):,}")

    k = np.asarray(res.per_r.keys)[np.asarray(res.per_r.valid)]
    c = np.asarray(res.per_r.counts)[np.asarray(res.per_r.valid)]
    top = np.argsort(c)[-5:][::-1]
    print("top-5 users by FoFoF reach (edge-endpoint aggregation):")
    for i in top:
        print(f"   user-edge src={k[i]}: {c[i]:,} paths")

    # FM sketch: approximate DISTINCT d-endpoints over the whole join
    # (sketch aggregates ride the scan driver until the fused path grows
    # them; same relations, legacy column names)
    r = Relation.from_arrays(a=src, b=dst)
    s = Relation.from_arrays(b=src, c=dst)
    t = Relation.from_arrays(c=src, d=dst)
    plan = linear3.default_plan(n, n, n, m_budget=max(n // 4, 2048))
    regs, _fm_ovf = linear3.linear3_fm_distinct(r, s, t, plan,
                                                n_registers=64)
    est = sketches.fm_estimate(regs)
    exact_d = len(np.unique(dst))
    print(f"FM-sketch distinct d-endpoints ≈ {est:,.0f} "
          f"(exact {exact_d}; sketch bytes = {64 * 4})")

    # --- Example 2: triangles -------------------------------------------
    # the 3-cycle predicate graph IS the triangle query
    triangles = Query(
        relations={"f1": friends, "f2": friends, "f3": friends},
        predicates=[("f1.dst", "f2.src"), ("f2.dst", "f3.src"),
                    ("f3.dst", "f1.src")])
    t0 = time.time()
    cres = sess.execute(triangles)
    tri = int(cres.count) // 6        # each triangle counted 6x (3! orders)
    print(f"\ntriangles: {tri:,} (raw oriented count {int(cres.count):,}; "
          f"classified {cres.kind}) in {time.time() - t0:.2f}s")

    # --- planner at Facebook scale (paper Examples 3/4) ------------------
    print("\nplanner at paper scale (N=6e11, M=16MB-chip -> 1e6 tuples):")
    lin = cost_model.choose_linear_strategy(6e11, 6e11, 6e11, 1e6, 2e9)
    cyc = cost_model.choose_cyclic_strategy(6e11, 6e11, 6e11, 1e6, 2e9)
    print(f"   linear: {lin.strategy} (3way traffic {lin.tuples_3way:.2e} "
          f"vs cascade {lin.tuples_cascade:.2e})")
    print(f"   cyclic: {cyc.strategy} (3way traffic {cyc.tuples_3way:.2e} "
          f"vs cascade {cyc.tuples_cascade:.2e})")
    print("\nanalytics_3way OK")


if __name__ == "__main__":
    main()
