"""§Roofline table generator: reads artifacts/dryrun/*.json and emits the
per-(arch × shape × mesh) roofline table as markdown (for EXPERIMENTS.md)
and CSV.  Single-pod rows are the roofline table proper; multi-pod rows
prove the "pod" axis shards (dry-run requirement)."""

from __future__ import annotations

import json
import pathlib

from benchmarks.common import claim, write_csv

DRYRUN_DIR = pathlib.Path("artifacts/dryrun")


def load(tag: str = ""):
    arts = []
    for p in sorted(DRYRUN_DIR.glob("*.json")):
        parts = p.stem.split("__")
        if tag and (len(parts) < 4 or parts[3] != tag):
            continue
        if not tag and len(parts) > 3:
            continue
        arts.append(json.loads(p.read_text()))
    return arts


def fmt_s(x):
    if x is None:
        return "-"
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.2f}ms"
    return f"{x * 1e6:.1f}us"


def markdown_table(arts, pod="pod1") -> str:
    rows = []
    hdr = ("| arch | shape | kind | t_comp | t_mem | t_coll | bottleneck "
           "| useful_flops | roofline_frac | fits 16G |")
    sep = "|" + "---|" * 10
    for a in arts:
        if not a.get("ok"):
            rows.append(f"| {a['arch']} | {a['shape']} | - | FAILED: "
                        f"{a.get('error', '?')[:60]} | | | | | | |")
            continue
        mesh_is_pod1 = a["mesh"] == "16x16"
        if (pod == "pod1") != mesh_is_pod1:
            continue
        r = a["roofline"]
        rows.append(
            f"| {a['arch']} | {a['shape']} | {a['kind']} "
            f"| {fmt_s(r['t_compute_s'])} | {fmt_s(r['t_memory_s'])} "
            f"| {fmt_s(r['t_collective_s'])} | {r['bottleneck']} "
            f"| {r['useful_flops_fraction']:.3f} "
            f"| {r['roofline_fraction']:.4f} "
            f"| {'Y' if a.get('fits_16gb') else 'N'} |")
    return "\n".join([hdr, sep] + rows)


def main(results: dict | None = None):
    results = results if results is not None else {}
    print("roofline: aggregate dry-run artifacts")
    arts = load()
    ok = [a for a in arts if a.get("ok")]
    pod1 = [a for a in ok if a["mesh"] == "16x16"]
    pod2 = [a for a in ok if a["mesh"] != "16x16"]
    n_fail = len(arts) - len(ok)

    rows = []
    for a in ok:
        r = a["roofline"]
        rows.append([a["arch"], a["shape"], a["mesh"], a["kind"],
                     r["t_compute_s"], r["t_memory_s"], r["t_collective_s"],
                     r["bottleneck"], r["useful_flops_fraction"],
                     r["roofline_fraction"], a.get("fits_16gb"),
                     a.get("compile_s")])
    write_csv("roofline",
              ["arch", "shape", "mesh", "kind", "t_compute_s", "t_memory_s",
               "t_collective_s", "bottleneck", "useful_flops_frac",
               "roofline_frac", "fits_16gb", "compile_s"], rows)

    claim(results, "dryrun_all_cells_compile", n_fail == 0,
          f"{len(ok)}/{len(arts)} cells compiled "
          f"({len(pod1)} single-pod + {len(pod2)} multi-pod)")
    claim(results, "dryrun_multipod_present", len(pod2) >= 30,
          f"{len(pod2)} multi-pod (2x16x16) cells lowered+compiled")
    return results


if __name__ == "__main__":
    main()
    print()
    print(markdown_table(load(), "pod1"))
