"""Fig 4 (e,f): linear 3-way vs cascaded binary self-join speedup across
relation size N, friends-per-person f = N/d, and DRAM bandwidth.

Paper claims validated:
  * speedup up to ~45x for N=2e8, d=7e5 with the SSD spill (we also report
    the exact-N 45x crossing),
  * step increase when the intermediate exceeds DRAM (the vertical dashed
    lines in the figure),
  * with more friends per person the cliff happens at smaller N,
  * binary join wins (speedup < 1) for small N / large d.
"""

from __future__ import annotations

import dataclasses

from benchmarks.common import claim, write_csv
from repro.perfmodel import PLASTICINE, binary_cascade_time, linear3_time


def speedup(n, d, hw):
    t3 = linear3_time(n, n, n, d, hw)
    tc = binary_cascade_time(n, n, n, d, hw)
    return tc.total / t3.total, t3, tc


def main(results: dict | None = None):
    results = results if results is not None else {}
    print("fig4ef: 3-way vs cascaded binary")
    rows = []
    cliff_n = {}
    for f in (25, 100, 286):                  # avg friends per person
        prev_sp = None
        for n in (1e6, 3e6, 1e7, 3e7, 1e8, 2e8, 5e8, 1e9, 3e9):
            d = n / f
            sp, t3, tc = speedup(n, d, PLASTICINE)
            spilled = (n * n / d) * 8 > PLASTICINE.dram_cap
            if spilled and f not in cliff_n:
                cliff_n[f] = n
            rows.append([f, n, d, sp, t3.total, tc.total, spilled,
                         t3.bottleneck, tc.bottleneck])
            prev_sp = sp
        del prev_sp
    write_csv("fig4e_speedup_vs_n",
              ["f", "n", "d", "speedup", "t3_s", "tc_s", "spilled",
               "bn_3way", "bn_cascade"], rows)

    sp_paper, _, _ = speedup(2e8, 7e5, PLASTICINE)
    claim(results, "fig4e_selfjoin_45x_at_200M_700k",
          20 <= sp_paper <= 120,
          f"N=2e8, d=7e5 -> {sp_paper:.0f}x (paper: 45x; "
          "cliff position depends on DRAM capacity)")
    def _fmt(x):
        return f"{x:.0e}" if x else "none<=3e9"
    claim(results, "fig4e_cliff_earlier_with_more_friends",
          cliff_n.get(286, 1e18) <= cliff_n.get(100, 1e18)
          <= cliff_n.get(25, 1e18),
          f"spill N: f=286 @ {_fmt(cliff_n.get(286))}, f=100 @ "
          f"{_fmt(cliff_n.get(100))}, f=25 @ {_fmt(cliff_n.get(25))}")
    # cascade wins when the intermediate is small (high d / low f) AND R
    # overflows on-chip memory so the 3-way re-reads T per H partition:
    # H·|T| > 2·|I|  ⇔  N > 2·f·M
    sp_small, _, _ = speedup(3e7, 3e7 / 5, PLASTICINE)
    claim(results, "fig4e_binary_wins_high_d_regime", sp_small < 1.0,
          f"N=3e7, f=5 -> {sp_small:.2f}x (<1: cascade wins; paper "
          "conclusion: binary wins when I fits and d is high)")

    rows_f = []
    sps = {}
    for bw in (12.25e9, 24.5e9, 49e9, 98e9):
        hw = dataclasses.replace(PLASTICINE, dram_bw=bw)
        # pre-cliff point (DRAM-resident intermediate)
        sp_pre, _, _ = speedup(1e8, 1e8 / 286, hw)
        # post-cliff point (spilled intermediate)
        sp_post, _, _ = speedup(2e8, 7e5, hw)
        sps[bw] = (sp_pre, sp_post)
        rows_f.append([bw, sp_pre, sp_post])
    write_csv("fig4f_speedup_vs_dram_bw",
              ["dram_bw", "speedup_pre_cliff", "speedup_post_cliff"],
              rows_f)
    claim(results, "fig4f_smaller_bw_favors_3way_pre_cliff",
          sps[12.25e9][0] >= sps[98e9][0],
          f"pre-cliff speedup {sps[12.25e9][0]:.1f}x @ 12GB/s >= "
          f"{sps[98e9][0]:.1f}x @ 98GB/s (paper: binary more "
          "DRAM-bound on smaller DRAM)")
    return results


if __name__ == "__main__":
    main()
