"""Binary hash join and the cascaded-binary baseline (paper §6.3).

Two execution paths:

* **sorted path** (`join_count`, `join_materialize`, `probe_weight_sum`) —
  exact joins via sort + searchsorted range probes.  O((n+m) log n), static
  shapes, used as the in-framework oracle and for fast aggregates.

* **bucketed path** (`bucketed_join_count`) — the accelerator-shaped
  execution: hash-partition both sides into `[n_buckets, capacity]` grids
  (PMU layout) and run the per-bucket compare kernel from
  ``repro.kernels.ops``.  This is the structure Algorithm 1 builds on and is
  exact as long as no bucket overflows (overflow is returned, never hidden).

The cascade (first join materialized, second join aggregated) reproduces the
paper's binary baseline, including the bounded intermediate buffer whose
overflow models the DRAM/SSD spill cliff.
"""

from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

from repro.core import partition
from repro.core.relation import Relation


def exact_join_count(build: Relation, build_key: str,
                     probe: Relation, probe_key: str) -> int:
    """Exact ``|build ⋈ probe|`` via host-side key histograms (int64 —
    immune to the int32 device counters).  The plan IR uses this both to
    size materialized intermediates exactly (a materialize step cannot
    overflow) and as the root aggregate of an all-binary cascade."""
    bv = np.asarray(build.col(build_key))[np.asarray(build.valid)]
    pv = np.asarray(probe.col(probe_key))[np.asarray(probe.valid)]
    bu, bc = np.unique(bv, return_counts=True)
    pu, pc = np.unique(pv, return_counts=True)
    _, bi, pi = np.intersect1d(bu, pu, return_indices=True)
    return int((bc[bi].astype(np.int64) * pc[pi].astype(np.int64)).sum())


# --------------------------------------------------------------------------
# sorted-path primitives
# --------------------------------------------------------------------------

def match_ranges(sorted_keys: jnp.ndarray, probe_keys: jnp.ndarray):
    """For each probe key, the [lo, hi) range of equal keys in sorted_keys."""
    lo = jnp.searchsorted(sorted_keys, probe_keys, side="left")
    hi = jnp.searchsorted(sorted_keys, probe_keys, side="right")
    return lo.astype(jnp.int32), hi.astype(jnp.int32)


def join_count(build: Relation, build_key: str,
               probe: Relation, probe_key: str) -> jnp.ndarray:
    """Exact number of matching (build, probe) pairs."""
    _, skeys = partition.sort_by_key(build, build_key)
    lo, hi = match_ranges(skeys, probe.col(probe_key))
    cnt = jnp.where(probe.valid, hi - lo, 0)
    return jnp.sum(cnt.astype(jnp.int64) if cnt.dtype == jnp.int64
                   else cnt.astype(jnp.int32)).astype(jnp.int32)


def probe_weight_sum(build: Relation, build_key: str, build_weights: jnp.ndarray,
                     probe_keys: jnp.ndarray, probe_valid: jnp.ndarray) -> jnp.ndarray:
    """For each probe row: sum of weights over matching build rows.

    The workhorse for per-key multiway aggregates: weights flow backwards
    through each join stage (T -> S -> R) without materializing anything.
    """
    srel, skeys = partition.sort_by_key(build, build_key)
    # weights must be permuted identically to the sort; recompute the order.
    keys = jnp.where(build.valid, build.col(build_key), jnp.int32(0x7FFFFFFF))
    order = jnp.argsort(keys, stable=True)
    w = jnp.where(build.valid, build_weights, 0)[order]
    cw = jnp.concatenate([jnp.zeros((1,), w.dtype), jnp.cumsum(w)])
    lo, hi = match_ranges(skeys, probe_keys)
    out = cw[hi] - cw[lo]
    return jnp.where(probe_valid, out, 0)


class JoinResult(NamedTuple):
    rel: Relation            # materialized join, fixed capacity, masked
    total: jnp.ndarray       # true (unclipped) number of result tuples
    overflowed: jnp.ndarray  # () bool — result exceeded out_capacity


def join_materialize(build: Relation, build_key: str,
                     probe: Relation, probe_key: str,
                     out_capacity: int,
                     build_prefix: str = "", probe_prefix: str = "") -> JoinResult:
    """Materialize the equi-join into a fixed-capacity Relation.

    Used for the cascaded-binary intermediate I = R ⋈ S (paper §6.3): the
    intermediate is written out (to DRAM in the paper) before the second
    join; ``overflowed`` models the spill condition.
    """
    sbuild, skeys = partition.sort_by_key(build, build_key)
    lo, hi = match_ranges(skeys, probe.col(probe_key))
    cnt = jnp.where(probe.valid, hi - lo, 0).astype(jnp.int32)
    off = jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(cnt)])
    total = off[-1]

    slots = jnp.arange(out_capacity, dtype=jnp.int32)
    # probe row owning output slot p: last i with off[i] <= p
    owner = jnp.searchsorted(off, slots, side="right").astype(jnp.int32) - 1
    owner = jnp.clip(owner, 0, probe.capacity - 1)
    rank = slots - off[owner]
    bidx = jnp.clip(lo[owner] + rank, 0, build.capacity - 1)
    ok = slots < total

    cols = {}
    for name, col in sbuild.columns.items():
        cols[build_prefix + name] = jnp.where(ok, col[bidx], jnp.int32(-0x7FFFFFFF))
    for name, col in probe.columns.items():
        key = probe_prefix + name
        if key in cols:  # join column appears once
            continue
        cols[key] = jnp.where(ok, col[owner], jnp.int32(-0x7FFFFFFF))
    return JoinResult(Relation(cols, ok), total, total > out_capacity)


# --------------------------------------------------------------------------
# cascaded binary baseline:  (R ⋈ S) materialized, then ⋈ T aggregated
# --------------------------------------------------------------------------

class CascadeResult(NamedTuple):
    count: jnp.ndarray          # total 3-way join cardinality (aggregated)
    intermediate_total: jnp.ndarray
    intermediate_overflowed: jnp.ndarray


def cascaded_binary_count(r: Relation, s: Relation, t: Relation,
                          intermediate_capacity: int,
                          rb: str = "b", sb: str = "b", sc: str = "c",
                          tc: str = "c") -> CascadeResult:
    """COUNT(R(AB) ⋈ S(BC) ⋈ T(CD)) as two cascaded binary joins with a
    bounded, materialized intermediate (the paper's baseline plan)."""
    inter = join_materialize(r, rb, s, sb, intermediate_capacity,
                             build_prefix="r_", probe_prefix="s_")
    # second join: aggregate only (final output never materialized, §6)
    w = probe_weight_sum(t, tc, jnp.ones((t.capacity,), jnp.int32),
                         inter.rel.col("s_" + sc), inter.rel.valid)
    return CascadeResult(jnp.sum(w).astype(jnp.int32), inter.total,
                         inter.overflowed)


def cascaded_binary_per_r_counts(r: Relation, s: Relation, t: Relation,
                                 rb: str = "b", sb: str = "b", sc: str = "c",
                                 tc: str = "c") -> jnp.ndarray:
    """Per-R-row 3-way join counts via weight backflow (no materialization).

    w_s = |{t : t.c == s.c}| ;  count_r = Σ_{s : s.b == r.b} w_s.
    Exact; used as the oracle for the per-key (Example 1) aggregate.
    """
    w_s = probe_weight_sum(t, tc, jnp.ones((t.capacity,), jnp.int32),
                           s.col(sc), s.valid)
    c_r = probe_weight_sum(s, sb, w_s, r.col(rb), r.valid)
    return c_r


# --------------------------------------------------------------------------
# bucketed path (accelerator-shaped)
# --------------------------------------------------------------------------

def bucketed_join_count(build: Relation, build_key: str,
                        probe: Relation, probe_key: str,
                        n_buckets: int, build_cap: int, probe_cap: int,
                        use_kernel: bool = False):
    """Hash-partition both sides and count matches per bucket pair.

    Returns (count, overflowed).  Matching keys hash identically, so
    bucket-local exact compares lose nothing (completeness), and cross-bucket
    pairs can never match (soundness) — exactness holds unless a bucket
    overflows, which is reported.
    """
    from repro.kernels import ops as kops

    b = partition.bucketize(build, build_key, n_buckets, build_cap, fn="h")
    p = partition.bucketize(probe, probe_key, n_buckets, probe_cap, fn="h")
    counts = kops.bucket_pair_count(
        b.columns[build_key], b.valid, p.columns[probe_key], p.valid,
        use_kernel=use_kernel)
    return jnp.sum(counts), b.overflowed | p.overflowed
