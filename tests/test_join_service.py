"""JoinService: admission/backpressure, wave batching, tenancy, metrics.

The service is the async front end over ``JoinSession`` — requests go
through a bounded queue (full → ``ServiceOverloaded``), waves group plain
executes per tenant through ``execute_many`` (shared plan cache), ingest
requests drive standing-query delta plans synchronously, and per-tenant
power-of-two histograms export latency/rounds/tuples_read.
"""

import numpy as np
import pytest

from repro.core.query import Query
from repro.core.relation import Relation
from repro.core.session import JoinSession
from repro.launch.join_service import JoinService, ServiceOverloaded, _Hist


def _mk(rng, n, d, cols):
    return Relation.from_arrays(
        **{c: rng.integers(0, d, n).astype(np.int32) for c in cols})


def _linear_query(rng, n=400, d=80):
    r = _mk(rng, n, d, ("a", "b"))
    s = _mk(rng, n, d, ("b", "c"))
    t = _mk(rng, n, d, ("c", "e"))
    return Query({"R": r, "S": s, "T": t},
                 [("R.b", "S.b"), ("S.c", "T.c")]), (r, s, t)


# --------------------------------------------------------------------------
# histogram format
# --------------------------------------------------------------------------

def test_hist_pow2_buckets():
    h = _Hist()
    for v in (0, 1, 2, 3, 4, 1000):
        h.record(v)
    out = h.export()
    assert out["count"] == 6 and out["sum"] == 1010
    # 0 → "0"; 1 → 2^0; 2 → 2^1; 3,4 → 2^2; 1000 → 2^10
    assert out["buckets"] == {"0": 1, "2^0": 1, "2^1": 1, "2^2": 2,
                              "2^10": 1}


# --------------------------------------------------------------------------
# admission + backpressure
# --------------------------------------------------------------------------

def test_bounded_queue_backpressure(rng):
    q, _ = _linear_query(rng, n=120, d=30)
    svc = JoinService(max_queue=2, wave_size=4, m_budget=64)
    svc.submit("a", q)
    svc.submit("a", q)
    with pytest.raises(ServiceOverloaded):
        svc.submit("a", q)
    assert svc.rejected == 1
    # draining the queue restores admission
    assert svc.run_until_idle() == 2
    fut = svc.submit("a", q)
    svc.run_until_idle()
    assert int(fut.result().count) >= 0


def test_wave_batches_and_plan_cache_share(rng):
    q, _ = _linear_query(rng, n=200, d=40)
    svc = JoinService(max_queue=16, wave_size=4, m_budget=64)
    futs = [svc.submit("a", q) for _ in range(6)]
    served = svc.run_until_idle()
    assert served == 6
    assert svc.waves == 2          # 4 + 2
    counts = {int(f.result().count) for f in futs}
    assert len(counts) == 1        # identical query, identical answer
    m = svc.metrics()
    # repeated identical queries hit the tenant session's plan cache
    assert m["tenants"]["a"]["plan_cache"]["hits"] >= 4
    assert m["tenants"]["a"]["latency_us"]["count"] == 6


def test_per_tenant_sessions_and_metrics(rng):
    qa, _ = _linear_query(rng, n=150, d=30)
    qb, _ = _linear_query(rng, n=150, d=30)
    svc = JoinService(max_queue=8, wave_size=8, m_budget=64)
    fa = svc.submit("alice", qa)
    fb = svc.submit("bob", qb)
    svc.run_until_idle()
    fa.result(), fb.result()
    m = svc.metrics()
    assert set(m["tenants"]) == {"alice", "bob"}
    for t in m["tenants"].values():
        assert t["latency_us"]["count"] == 1
        assert t["rounds"]["count"] == 1
        assert t["tuples_read"]["count"] == 1


# --------------------------------------------------------------------------
# standing queries through the service
# --------------------------------------------------------------------------

def test_service_watch_ingest_snapshot_roundtrip(rng):
    q, (r, s, t) = _linear_query(rng, n=300, d=60)
    svc = JoinService(max_queue=16, wave_size=4, m_budget=128)
    hf = svc.watch("a", q)
    svc.run_until_idle()
    sq = hf.result()
    for i in range(3):
        fut = svc.ingest("a", s, {
            "b": rng.integers(0, 60, 20).astype(np.int32),
            "c": rng.integers(0, 60, 20).astype(np.int32)})
        svc.run_until_idle()
        assert fut.result() == 20
        assert not sq.delta_rounds[-1].overflowed
    sf = svc.snapshot("a", sq)
    svc.run_until_idle()
    snap = sf.result()
    assert int(snap.count) == int(JoinSession(m_budget=128).execute(q).count)
    sq.close()


def test_service_errors_propagate_to_future(rng):
    svc = JoinService(max_queue=4, wave_size=4, m_budget=64)
    bad = _mk(rng, 50, 10, ("a", "b"))
    fut = svc.ingest("a", bad, {"wrong": np.arange(3, dtype=np.int32)})
    svc.run_until_idle()
    with pytest.raises(ValueError, match="schema"):
        fut.result()


def test_background_thread_start_stop(rng):
    q, _ = _linear_query(rng, n=120, d=30)
    svc = JoinService(max_queue=8, wave_size=4, m_budget=64)
    svc.start()
    try:
        fut = svc.submit("a", q)
        res = fut.result(timeout=300)
        assert not bool(res.overflowed)
    finally:
        svc.stop()
