"""Model zoo: one uniform functional interface over all families.

  model = zoo.build(cfg)
  params = model.init(key)
  logits, aux = model.forward(params, tokens, memory=...)
  cache = model.init_cache(batch, max_len)
  logits, cache = model.prefill(params, tokens, cache, memory=...)
  logits, cache = model.decode_step(params, cache, tokens)

`memory` is the stubbed modality frontend output ([B, T_frontend, d_model])
for the audio/vlm families; None elsewhere.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax.numpy as jnp

from repro.models import encdec, hybrid, transformer
from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class Model:
    config: ModelConfig
    init: Callable
    forward: Callable          # (params, tokens, memory=None) -> (logits, aux)
    init_cache: Callable       # (batch, max_len, dtype=...) -> cache
    prefill: Callable          # (params, tokens, cache, memory=None)
    decode_step: Callable      # (params, cache, tokens) -> (logits, cache)
    needs_memory: bool = False


def build(cfg: ModelConfig) -> Model:
    if cfg.family in ("dense", "moe", "vlm"):
        return Model(
            config=cfg,
            init=lambda key: transformer.init_lm(key, cfg),
            forward=lambda p, t, memory=None: transformer.forward(
                p, cfg, t, memory=memory),
            init_cache=lambda b, ml, dtype=jnp.bfloat16: transformer.init_cache(
                cfg, b, ml, dtype),
            prefill=lambda p, t, c, memory=None: transformer.prefill(
                p, cfg, t, c, memory=memory),
            decode_step=lambda p, c, t: transformer.decode_step(p, cfg, c, t),
            needs_memory=cfg.family == "vlm")
    if cfg.family in ("ssm", "hybrid"):
        return Model(
            config=cfg,
            init=lambda key: hybrid.init_lm(key, cfg),
            forward=lambda p, t, memory=None: hybrid.forward(p, cfg, t),
            init_cache=lambda b, ml, dtype=jnp.bfloat16: hybrid.init_cache(
                cfg, b, ml, dtype),
            prefill=lambda p, t, c, memory=None: hybrid.prefill(p, cfg, t, c),
            decode_step=lambda p, c, t: hybrid.decode_step(p, cfg, c, t))
    if cfg.family in ("encdec", "audio"):
        return Model(
            config=cfg,
            init=lambda key: encdec.init_encdec(key, cfg),
            forward=lambda p, t, memory=None: encdec.forward(
                p, cfg, t, memory=memory),
            init_cache=lambda b, ml, dtype=jnp.bfloat16: encdec.init_cache(
                cfg, b, ml, dtype),
            prefill=lambda p, t, c, memory=None: encdec.prefill(
                p, cfg, t, c, memory=memory),
            decode_step=lambda p, c, t: encdec.decode_step(p, cfg, c, t),
            needs_memory=True)
    raise ValueError(f"unknown family {cfg.family!r}")
