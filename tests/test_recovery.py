"""Property test for the shared recovery-round core (core/recovery.py):
engine counts equal the kernels/ref.py single-bucket oracle for randomly
skewed relations across all three kinds and arbitrary base salts.

Runs under real hypothesis in CI and under tests/_hypothesis_shim.py on
hermetic accelerator images (conftest installs the shim when the import
fails) — either way the draws are seeded and reproducible.

The sharded path is covered by the same adversarial-skew construction in
tests/dist_runner.py (subprocess, 8 fake devices).
"""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from conftest import skewed_keys as _skew_mix
from repro.core import cyclic3, engine, linear3, star3
from repro.core.relation import Relation
from repro.kernels import ops as kops


def _ref_linear(rb, sb, sc, tc) -> int:
    c = kops.bucket_count3_linear(
        jnp.asarray(rb)[None], jnp.ones((1, len(rb)), bool),
        jnp.asarray(sb)[None], jnp.asarray(sc)[None],
        jnp.ones((1, len(sb)), bool),
        jnp.asarray(tc)[None], jnp.ones((1, len(tc)), bool))
    return int(c[0])


def _ref_cyclic(ra, rb, sb, sc, tc, ta) -> int:
    c = kops.bucket_count3_cyclic(
        jnp.asarray(ra)[None], jnp.asarray(rb)[None],
        jnp.ones((1, len(ra)), bool),
        jnp.asarray(sb)[None], jnp.asarray(sc)[None],
        jnp.ones((1, len(sb)), bool),
        jnp.asarray(tc)[None], jnp.asarray(ta)[None],
        jnp.ones((1, len(tc)), bool))
    return int(c[0])


@settings(max_examples=9, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       kind=st.sampled_from(["linear", "cyclic", "star"]),
       base_salt=st.integers(0, 7),
       frac=st.sampled_from([0.0, 0.35, 0.65]),
       d=st.integers(6, 50))
def test_engine_matches_ref_under_random_skew(seed, kind, base_salt, frac, d):
    rng = np.random.default_rng(seed)
    nr, ns, nt = 170, 190, 180
    ra = _skew_mix(rng, nr, d, frac, 1)
    rb = _skew_mix(rng, nr, d, frac, 2)
    sb = _skew_mix(rng, ns, d, frac, 2)
    sc = _skew_mix(rng, ns, d, frac, 3)
    tc = _skew_mix(rng, nt, d, frac, 3)
    t2 = _skew_mix(rng, nt, d, frac, 1)    # "a" for cyclic, "d" otherwise
    r = Relation.from_arrays(a=ra, b=rb)
    s = Relation.from_arrays(b=sb, c=sc)
    t = Relation.from_arrays(**({"c": tc, "a": t2} if kind == "cyclic"
                                else {"c": tc, "d": t2}))
    if kind == "linear":
        want = _ref_linear(rb, sb, sc, tc)
        plan = linear3.default_plan(nr, ns, nt, m_budget=64, u=4, slack=1.3)
    elif kind == "cyclic":
        want = _ref_cyclic(ra, rb, sb, sc, tc, t2)
        plan = cyclic3.default_plan(nr, ns, nt, m_budget=48, uh=2, ug=2,
                                    slack=1.3)
    else:
        want = _ref_linear(rb, sb, sc, tc)
        plan = star3.default_plan(nr, ns, nt, uh=4, ug=4, chunks=2,
                                  slack=1.3)
    res = engine.MultiwayJoinEngine(kind, base_salt=base_salt).count(
        r, s, t, plan)
    assert int(res.count) == want, (kind, base_salt, frac)
    assert not bool(res.overflowed)
    assert res.rounds >= 1
