"""Abstract shapes + shardings for every (architecture × input shape) cell.

`input_specs(arch, shape)` returns ShapeDtypeStruct stand-ins for every
model input (weak-type-correct, shardable, zero allocation); the companion
`*_shardings` builders give the jit-boundary NamedShardings.

Parameter sharding is path-rule based (see `param_logical`): TP on the
"model" axis for head/ffn/vocab/expert dims, FSDP over "data" on the
d_model dim, with divisibility-aware fallback (a rule is dropped when the
dim does not divide — probe-verified that jit *boundary* shardings must
divide exactly, while internal constraints may be uneven).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.models import zoo
from repro.models.config import ModelConfig
from repro.optim import AdamWConfig
from repro.parallel.sharding import MeshContext, spec_for
from repro.train import init_train_state, make_decode_step, make_train_step


# --------------------------------------------------------------------------
# parameter logical axes by path
# --------------------------------------------------------------------------

_COL_PARALLEL = {"wq", "wk", "wv", "gate", "up"}      # out-dim on "model"
_ROW_PARALLEL = {"wo", "down"}                        # in-dim on "model"
_REPLICATED_LEAVES = {"scale", "a_log", "dt_bias", "d_skip"}


def param_logical(path: tuple[str, ...], ndim: int) -> tuple:
    """Logical axes for one parameter leaf, padded with leading None for
    stacked-layer / group dims."""
    names = [p for p in path]
    leaf = names[-1]
    parent = names[-2] if len(names) >= 2 else ""
    in_moe = "moe" in names and "shared" not in names

    if leaf == "table":                       # [vocab, d_model]
        base = ("p_vocab", "p_embed")
    elif in_moe and leaf in ("gate", "up"):   # [E, d, ff]
        base = ("p_experts", "p_embed", None)
    elif in_moe and leaf == "down":           # [E, ff, d]
        base = ("p_experts", None, "p_embed")
    elif in_moe and parent == "router":       # [d, E]
        base = ("p_embed", None)
    elif parent == "in_proj":                 # ssm fused in [d, X]
        base = ("p_embed", None)
    elif parent == "out_proj":                # ssm out [di, d]
        base = (None, "p_embed")
    elif parent == "conv":                    # depthwise conv [W, C] / [C]
        base = (None,) * min(ndim, 2)
    elif parent in _COL_PARALLEL and leaf == "w":
        kind = "p_mlp" if parent in ("gate", "up") else "p_heads"
        base = ("p_embed", kind)
    elif parent in _COL_PARALLEL and leaf == "b":
        base = ("p_mlp" if parent in ("gate", "up") else "p_heads",)
    elif parent in _ROW_PARALLEL and leaf == "w":
        kind = "p_mlp" if parent == "down" else "p_heads"
        base = (kind, "p_embed")
    elif parent in _ROW_PARALLEL and leaf == "b":
        base = (None,)
    elif leaf in _REPLICATED_LEAVES or leaf == "b":
        base = (None,) * min(ndim, 1)
    else:
        base = ()

    pad = ndim - len(base)
    if pad < 0:        # leaf has fewer dims than the rule (e.g. scalar)
        return (None,) * ndim
    return (None,) * pad + tuple(base)


def _key_name(k) -> str:
    for attr in ("key", "name", "idx"):
        if hasattr(k, attr):
            return str(getattr(k, attr))
    return str(k)


def _tree_shardings(tree, ctx: MeshContext, logical_fn):
    """Map a pytree of ShapeDtypeStructs to NamedShardings."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        names = tuple(_key_name(k) for k in path)
        logical = logical_fn(names, len(leaf.shape))
        out.append(NamedSharding(ctx.mesh,
                                 spec_for(leaf.shape, logical, ctx)))
    return jax.tree_util.tree_unflatten(treedef, out)


def param_shardings(params_abs, ctx: MeshContext):
    return _tree_shardings(params_abs, ctx, param_logical)


def state_shardings(state_abs, ctx: MeshContext):
    """TrainState(params, opt{m,v,step}, step): m/v mirror the params."""
    def logical(names, ndim):
        names = tuple(n for n in names if n not in ("params", "opt",
                                                    "m", "v"))
        if not names or names[-1] == "step":
            return (None,) * ndim
        return param_logical(names, ndim)
    return _tree_shardings(state_abs, ctx, logical)


# --------------------------------------------------------------------------
# activation / batch / cache shardings
# --------------------------------------------------------------------------

def _div_axes(dim: int, candidates: tuple[str, ...], ctx: MeshContext,
              used: set) -> tuple[str, ...]:
    """Longest prefix of unused mesh axes whose product divides `dim`."""
    got: tuple[str, ...] = ()
    acc = 1
    for a in candidates:
        if a not in ctx.mesh.shape or a in used:
            continue
        if dim % (acc * ctx.mesh.shape[a]) == 0:
            acc *= ctx.mesh.shape[a]
            got = got + (a,)
    return got


def _one(axes: tuple[str, ...]):
    return None if not axes else (axes if len(axes) > 1 else axes[0])


def batch_shardings(batch_abs: dict, ctx: MeshContext):
    """tokens/targets [B, S] over ("pod","data"); memory [B, F, d] same."""
    out = {}
    for k, v in batch_abs.items():
        used: set = set()
        baxes = _div_axes(v.shape[0], ("pod", "data"), ctx, used)
        used.update(baxes)
        parts = [_one(baxes)] + [None] * (len(v.shape) - 1)
        out[k] = NamedSharding(ctx.mesh, P(*parts))
    return out


def cache_shardings(cache_abs: dict, ctx: MeshContext):
    """KV cache [L,B,T,KVH,D]; SSM state [L,B,nh,st,hd]; conv
    [L,B,W-1,C]; memory [B,F,d]; length scalar.

    Batch gets ("pod","data") when divisible; heads get "model"; when the
    batch cannot shard (long_500k B=1) the cache *sequence* dim takes the
    leftover axes (flash-decoding style sequence sharding)."""
    out = {}
    for key, v in cache_abs.items():
        shape = v.shape
        if key == "length" or len(shape) == 0:
            out[key] = NamedSharding(ctx.mesh, P())
            continue
        if key == "memory":                     # [B, F, d]
            b = _div_axes(shape[0], ("pod", "data"), ctx, set())
            out[key] = NamedSharding(ctx.mesh, P(_one(b), None, None))
            continue
        used: set = set()
        parts: list = [None] * len(shape)
        if key in ("k", "v"):                   # [L, B, T, KVH, D]
            b = _div_axes(shape[1], ("pod", "data"), ctx, used)
            used.update(b)
            h = _div_axes(shape[3], ("model",), ctx, used)
            used.update(h)
            t = _div_axes(shape[2], ("pod", "data", "model"), ctx, used)
            parts[1], parts[2], parts[3] = _one(b), _one(t), _one(h)
        elif key == "state":                    # [L, B, nh, st, hd]
            b = _div_axes(shape[1], ("pod", "data"), ctx, used)
            used.update(b)
            h = _div_axes(shape[2], ("model",), ctx, used)
            parts[1], parts[2] = _one(b), _one(h)
        elif key == "conv":                     # [L, B, W-1, C]
            b = _div_axes(shape[1], ("pod", "data"), ctx, used)
            used.update(b)
            c = _div_axes(shape[3], ("model",), ctx, used)
            parts[1], parts[3] = _one(b), _one(c)
        out[key] = NamedSharding(ctx.mesh, P(*parts))
    return out


# --------------------------------------------------------------------------
# abstract inputs per cell
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Cell:
    arch: str
    shape: str
    cfg: ModelConfig
    model: Any
    kind: str                  # train | prefill | decode
    seq_len: int
    global_batch: int


def build_cell(arch: str, shape: str, *, overrides: dict | None = None
               ) -> Cell:
    cfg = configs.get(arch)
    sh = configs.SHAPES[shape]
    if not configs.shape_applicable(cfg, shape):
        raise ValueError(f"{arch} × {shape}: skipped per DESIGN.md "
                         "§Arch-applicability (full-attention at 500k)")
    upd: dict = {}
    if sh["kind"] in ("decode", "prefill"):
        upd["max_cache_len"] = sh["seq_len"]
    if overrides:
        upd.update(overrides)
    if upd:
        cfg = dataclasses.replace(cfg, **upd)
    model = zoo.build(cfg)
    return Cell(arch, shape, cfg, model, sh["kind"], sh["seq_len"],
                sh["global_batch"])


def train_batch_abs(cell: Cell):
    b, s = cell.global_batch, cell.seq_len
    batch = {
        "inputs": jax.ShapeDtypeStruct((b, s), jnp.int32),
        "targets": jax.ShapeDtypeStruct((b, s), jnp.int32),
    }
    if cell.cfg.n_frontend_tokens:
        batch["memory"] = jax.ShapeDtypeStruct(
            (b, cell.cfg.n_frontend_tokens, cell.cfg.d_model), jnp.float32)
    return batch


def abstract_state(cell: Cell):
    return jax.eval_shape(
        lambda k: init_train_state(cell.model, k), jax.random.key(0))


def abstract_cache(cell: Cell, batch: int, max_len: int):
    return jax.eval_shape(
        lambda: cell.model.init_cache(batch, max_len))


def input_specs(arch: str, shape: str = "train_4k",
                overrides: dict | None = None):
    """ShapeDtypeStruct stand-ins for every input of the cell's step
    function, in the order the step takes them.  Returns (cell, args)."""
    cell = build_cell(arch, shape, overrides=overrides)
    if cell.kind == "train":
        return cell, (abstract_state(cell), train_batch_abs(cell))
    if cell.kind == "prefill":
        params = jax.eval_shape(
            lambda k: cell.model.init(k), jax.random.key(0))
        cache = abstract_cache(cell, cell.global_batch, cell.seq_len)
        args = [params,
                jax.ShapeDtypeStruct((cell.global_batch, cell.seq_len),
                                     jnp.int32), cache]
        if cell.cfg.n_frontend_tokens:
            args.append(jax.ShapeDtypeStruct(
                (cell.global_batch, cell.cfg.n_frontend_tokens,
                 cell.cfg.d_model), jnp.float32))
        return cell, tuple(args)
    # decode: serve_step(params, cache, tokens) with a full cache of seq_len
    params = jax.eval_shape(lambda k: cell.model.init(k), jax.random.key(0))
    cache = abstract_cache(cell, cell.global_batch, cell.seq_len)
    tokens = jax.ShapeDtypeStruct((cell.global_batch, 1), jnp.int32)
    return cell, (params, cache, tokens)


# --------------------------------------------------------------------------
# step functions + jit shardings per cell
# --------------------------------------------------------------------------

def step_and_shardings(cell: Cell, ctx: MeshContext, args):
    """Returns (step_fn, in_shardings, out_shardings, donate_argnums)."""
    repl = NamedSharding(ctx.mesh, P())
    if cell.kind == "train":
        state_abs, batch_abs = args
        st_sh = state_shardings(state_abs, ctx)
        bt_sh = batch_shardings(batch_abs, ctx)
        step = make_train_step(cell.model, AdamWConfig())
        # metrics: replicated scalars
        metrics_sh = jax.tree.map(
            lambda _: repl,
            jax.eval_shape(step, state_abs, batch_abs)[1])
        return step, (st_sh, bt_sh), (st_sh, metrics_sh), (0,)

    if cell.kind == "prefill":
        params_abs, tokens_abs, cache_abs = args[0], args[1], args[2]
        p_sh = param_shardings(params_abs, ctx)
        c_sh = cache_shardings(cache_abs, ctx)
        t_sh = batch_shardings({"inputs": tokens_abs}, ctx)["inputs"]
        if len(args) == 4:
            m_sh = batch_shardings({"memory": args[3]}, ctx)["memory"]

            def step(params, tokens, cache, memory):
                return cell.model.prefill(params, tokens, cache,
                                          memory=memory)
            in_sh = (p_sh, t_sh, c_sh, m_sh)
        else:
            def step(params, tokens, cache):
                return cell.model.prefill(params, tokens, cache)
            in_sh = (p_sh, t_sh, c_sh)
        logits_sh = NamedSharding(
            ctx.mesh, P(t_sh.spec[0], None,
                        "model" if cell.cfg.vocab_size
                        % ctx.mesh.shape["model"] == 0 else None))
        return step, in_sh, (logits_sh, c_sh), (2,)

    # decode
    params_abs, cache_abs, tokens_abs = args
    p_sh = param_shardings(params_abs, ctx)
    c_sh = cache_shardings(cache_abs, ctx)
    t_sh = batch_shardings({"inputs": tokens_abs}, ctx)["inputs"]
    decode = make_decode_step(cell.model)
    logits_sh = NamedSharding(
        ctx.mesh, P(t_sh.spec[0], None,
                    "model" if cell.cfg.vocab_size
                    % ctx.mesh.shape["model"] == 0 else None))
    return (decode, (p_sh, c_sh, t_sh),
            (t_sh, logits_sh, c_sh), (1,))
