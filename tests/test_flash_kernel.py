"""Pallas flash-attention kernel vs jnp references (interpret mode):
shape/dtype/causal/window/GQA sweeps for the forward, and VJP agreement
against jax.grad of the dense reference for the backward."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import (flash_attention_kernel,
                                           flash_fwd)

jax.config.update("jax_enable_x64", False)


def dense_reference(q, k, v, *, causal=True, window=0):
    """O(S·T) reference attention (f32, GQA via repeat)."""
    b, s, nq, d = q.shape
    t, nkv = k.shape[1], k.shape[2]
    g = nq // nkv
    kf = jnp.repeat(k.astype(jnp.float32), g, axis=2)
    vf = jnp.repeat(v.astype(jnp.float32), g, axis=2)
    s_ = jnp.einsum("bshd,bthd->bhst", q.astype(jnp.float32), kf) / d ** 0.5
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(t)[None, :]
    mask = jnp.ones((s, t), bool)
    if causal:
        mask = mask & (kpos <= qpos)
    if window:
        mask = mask & (kpos > qpos - window)
    s_ = jnp.where(mask[None, None], s_, -2e38)
    p = jax.nn.softmax(s_, axis=-1)
    out = jnp.einsum("bhst,bthd->bshd", p, vf)
    return out


CASES = [
    # (B, S, T, nq, nkv, D, causal, window, dtype)
    (1, 128, 128, 4, 4, 32, True, 0, jnp.float32),
    (2, 128, 128, 4, 2, 32, True, 0, jnp.float32),     # GQA g=2
    (1, 256, 256, 8, 1, 16, True, 0, jnp.float32),     # MQA
    (1, 128, 128, 4, 4, 32, False, 0, jnp.float32),    # bidirectional
    (1, 256, 256, 2, 2, 32, True, 64, jnp.float32),    # sliding window
    (1, 128, 128, 4, 2, 32, True, 0, jnp.bfloat16),    # bf16 inputs
]


@pytest.mark.parametrize(
    "b,s,t,nq,nkv,d,causal,window,dtype", CASES,
    ids=[f"c{i}" for i in range(len(CASES))])
def test_flash_fwd_matches_dense(b, s, t, nq, nkv, d, causal, window,
                                 dtype):
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (b, s, nq, d), dtype)
    k = jax.random.normal(ks[1], (b, t, nkv, d), dtype)
    v = jax.random.normal(ks[2], (b, t, nkv, d), dtype)
    o, m, l = flash_fwd(q, k, v, causal=causal, window=window,
                        q_chunk=64, kv_chunk=64, interpret=True)
    want = dense_reference(q, k, v, causal=causal, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("g", [1, 2, 4])
def test_flash_vjp_matches_dense(g):
    b, s, nkv, d = 1, 128, 2, 16
    nq = nkv * g
    ks = jax.random.split(jax.random.key(1), 4)
    q = jax.random.normal(ks[0], (b, s, nq, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, nkv, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, nkv, d), jnp.float32)
    co = jax.random.normal(ks[3], (b, s, nq, d), jnp.float32)

    def loss_kernel(q, k, v):
        o = flash_attention_kernel(q, k, v, True, 0, 64, 64, True)
        return jnp.sum(o * co)

    def loss_dense(q, k, v):
        return jnp.sum(dense_reference(q, k, v, causal=True) * co)

    gk = jax.grad(loss_kernel, argnums=(0, 1, 2))(q, k, v)
    gd = jax.grad(loss_dense, argnums=(0, 1, 2))(q, k, v)
    for a, bb, name in zip(gk, gd, "q k v".split()):
        np.testing.assert_allclose(np.asarray(a), np.asarray(bb),
                                   rtol=2e-4, atol=2e-4,
                                   err_msg=f"d{name} mismatch (g={g})")


def test_flash_kernel_vs_jnp_flash():
    """The kernel and the model's jnp flash implement the same math."""
    from repro.models.attention import flash_attention
    b, s, nq, nkv, d = 2, 256, 4, 2, 32
    ks = jax.random.split(jax.random.key(2), 3)
    q = jax.random.normal(ks[0], (b, s, nq, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, nkv, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, nkv, d), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    o_jnp = flash_attention(q, k, v, pos, pos, causal=True, window=0,
                            q_chunk=64, kv_chunk=64)
    o_ker, _, _ = flash_fwd(q, k, v, causal=True, q_chunk=64, kv_chunk=64,
                            interpret=True)
    np.testing.assert_allclose(np.asarray(o_ker), np.asarray(o_jnp),
                               rtol=2e-3, atol=2e-3)
