"""qwen3-moe-30b-a3b — MoE 128 experts top-8 [hf:Qwen/Qwen3-30B-A3B].

48L d_model=2048 32H (GQA kv=4) per-expert d_ff=768 vocab=151936, QK-norm.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4,
    d_ff=768, vocab_size=151936, head_dim=128,
    n_experts=128, top_k=8, moe_d_ff=768,
    qk_norm=True, norm_topk=True, rope_theta=1e6, norm_eps=1e-6,
    scan_group=8, accum_steps=4,
)

SMOKE = ModelConfig(
    name="qwen3-moe-30b-a3b-smoke", family="moe",
    n_layers=2, d_model=96, n_heads=8, n_kv_heads=2,
    d_ff=96, vocab_size=512, head_dim=16,
    n_experts=8, top_k=2, moe_d_ff=48,
    qk_norm=True, norm_topk=True, rope_theta=1e6, norm_eps=1e-6,
    remat=False,
)
