"""Join planner: 3-way vs cascaded-binary decision (§6 logic).

Two decision layers:
  * traffic  — the paper's closed-form tuple-traffic comparison
    (re-exported from cost_model: Examples 3/4 thresholds),
  * time     — the Appendix-A cycle model on a concrete hardware profile
    (captures the compute/DRAM/SSD terms traffic alone misses, e.g. the
    v5e case where fast host DMA shrinks the 3-way win to 2.1×).
"""

from __future__ import annotations

import dataclasses

from repro.core.cost_model import (  # noqa: F401  (traffic layer)
    PlanChoice, choose_cyclic_strategy, choose_linear_strategy,
    cascaded_binary_tuples, cyclic3_tuples, linear3_tuples)
from repro.perfmodel import HW, PLASTICINE, binary_cascade_time, \
    linear3_time, star3_time, star3_binary_time


@dataclasses.dataclass(frozen=True)
class TimedChoice:
    strategy: str            # "3way" | "cascade"
    t_3way_s: float
    t_cascade_s: float
    speedup: float           # cascade / 3way (>1 favors the 3-way)
    bottleneck_3way: str
    bottleneck_cascade: str


def choose_linear_timed(n_r: float, n_s: float, n_t: float, d: float,
                        hw: HW = PLASTICINE) -> TimedChoice:
    """Self/linear 3-way vs cascade on a hardware profile (Fig 4 e/f)."""
    t3 = linear3_time(n_r, n_s, n_t, d, hw)
    tc = binary_cascade_time(n_r, n_s, n_t, d, hw)
    return TimedChoice(
        "3way" if t3.total < tc.total else "cascade",
        t3.total, tc.total, tc.total / t3.total,
        t3.bottleneck, tc.bottleneck)


def choose_star_timed(n_r: float, n_s: float, n_t: float, d: float,
                      hw: HW = PLASTICINE) -> TimedChoice:
    """Star 3-way vs cascade (Fig 4 g/h/i)."""
    t3 = star3_time(n_r, n_s, n_t, d, hw)
    tc = star3_binary_time(n_r, n_s, n_t, d, hw)
    return TimedChoice(
        "3way" if t3.total < tc.total else "cascade",
        t3.total, tc.total, tc.total / t3.total,
        t3.bottleneck, tc.bottleneck)
