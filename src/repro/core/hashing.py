"""Hash families for radix partitioning.

The paper partitions relations with "robust hash functions" [25] at two
levels: a coarse level (H, G) that sizes partitions to on-chip memory, and a
fine level (h, g, f) that routes tuples to PMUs / streaming buckets.  We use
a Murmur3-style finalizer (full avalanche) seeded per hash function, followed
by either a modulo or a top-bits multiply-shift reduction to the bucket count.

All functions are vectorized jnp, int32-in / int32-out, and safe under jit,
vmap, shard_map and inside Pallas kernels (pure arithmetic, no gathers).
"""

from __future__ import annotations

import jax.numpy as jnp

# Distinct odd constants per hash-function "name" so H, h, g, f, G are
# independent, mirroring the paper's notation.
_SEEDS = {
    "H": 0x9E3779B1,
    "G": 0x85EBCA77,
    "h": 0xC2B2AE3D,
    "g": 0x27D4EB2F,
    "f": 0x165667B1,
    "salt": 0xB5297A4D,
}


def _as_u32(x: jnp.ndarray) -> jnp.ndarray:
    return x.astype(jnp.uint32)


def mix32(x: jnp.ndarray, seed: int) -> jnp.ndarray:
    """Murmur3 fmix32 with a seed xor — full-avalanche 32-bit mixer."""
    h = _as_u32(x) ^ jnp.uint32(seed & 0xFFFFFFFF)
    h = h ^ (h >> 16)
    h = h * jnp.uint32(0x85EBCA6B)
    h = h ^ (h >> 13)
    h = h * jnp.uint32(0xC2B2AE35)
    h = h ^ (h >> 16)
    return h


def hash_bucket(keys: jnp.ndarray, n_buckets: int, fn: str = "H",
                salt: int = 0) -> jnp.ndarray:
    """Map int keys -> bucket ids in [0, n_buckets) with hash family `fn`.

    `salt` re-randomizes the family (used for skew-overflow re-partitioning).
    Returns int32.
    """
    if fn not in _SEEDS:
        raise ValueError(f"unknown hash fn {fn!r}; choose from {sorted(_SEEDS)}")
    seed = (_SEEDS[fn] + 0x9E3779B9 * salt) & 0xFFFFFFFF
    h = mix32(keys, seed)
    # Modulo reduction on the avalanche-mixed hash.  (Lemire multiply-shift
    # needs 64-bit arithmetic, which we avoid so the whole engine runs with
    # jax_enable_x64 off — the default everywhere in this framework.)
    return (h % jnp.uint32(n_buckets)).astype(jnp.int32)


def hash_trailing_zeros(keys: jnp.ndarray, reg: int) -> jnp.ndarray:
    """rho(hash(key)) for Flajolet-Martin: index of lowest set bit + 1 of a
    mixed hash, per register `reg` (independent family per register).

    Returns int32 in [1, 33]; 33 means hash == 0 (probability 2^-32).
    """
    h = mix32(keys, (0x5851F42D + 0x9E3779B9 * reg) & 0xFFFFFFFF)
    # lowest set bit: h & -h ; its position via population count of (x-1)
    low = h & (jnp.uint32(0) - h)
    rho = _popcount32(low - jnp.uint32(1)) + 1
    return jnp.where(h == 0, jnp.int32(33), rho.astype(jnp.int32))


def _popcount32(x: jnp.ndarray) -> jnp.ndarray:
    x = x - ((x >> 1) & jnp.uint32(0x55555555))
    x = (x & jnp.uint32(0x33333333)) + ((x >> 2) & jnp.uint32(0x33333333))
    x = (x + (x >> 4)) & jnp.uint32(0x0F0F0F0F)
    return ((x * jnp.uint32(0x01010101)) >> 24).astype(jnp.int32)
