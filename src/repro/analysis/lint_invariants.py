"""AST lint enforcing the repo-wide exactness invariants over ``src/repro``.

The engine's correctness argument leans on conventions no type checker
sees: ``Relation`` is immutable except through ``append``; the device
pipelines never fall back to host ``np.unique`` (only the oracle baselines
in ``core/reference.py`` may); invalid-slot sentinels derive from
``relation.SENTINEL`` instead of re-typed magic numbers; join counts
accumulate in integers (one f32 ``sum`` caps every total at 2^24); and the
interpret-only Pallas kernels are dispatched only where
``kernels.ops._interpret()`` says interpret mode is on.  Each rule here is
one of those conventions, machine-checked:

=================  =====================================================
rule               fires on
=================  =====================================================
relation-mutation  ``object.__setattr__(x, <field>, ...)`` for a
                   ``Relation`` field (columns/valid/_version/
                   _sketch_cache) outside ``core/relation.py``, or any
                   ``.columns``/``.valid`` attribute or ``.columns[...]``
                   subscript store
np-unique          ``np.unique``/``numpy.unique`` calls outside
                   ``core/reference.py`` (host oracles live there)
sentinel-literal   a literal ``-0x7FFFFFFF`` outside ``core/relation.py``
                   — spell it ``relation.SENTINEL``
float-count-accum  ``sum``/``cumsum``/``bincount`` with a float ``dtype``
                   kwarg, or ``.astype(<float>)`` directly feeding
                   ``.sum()`` — counts must accumulate in int32/int64
pallas-gate        ``pallas_call`` without an explicit ``interpret=``
                   kwarg, or a call passing literal ``interpret=True``
                   outside an ``if`` guarded by ``_interpret``
=================  =====================================================

Run via ``python tools/check_invariants.py`` (the CI gate next to ruff) or
``python -m repro.analysis.lint_invariants [paths...]``.
"""

from __future__ import annotations

import ast
import sys
from pathlib import Path

_RELATION_FIELDS = frozenset(
    {"columns", "valid", "_version", "_sketch_cache"})
_SENTINEL_MAGNITUDE = 0x7FFFFFFF
_FLOAT_NAMES = ("float", "float16", "float32", "float64", "bfloat16")

# rule -> path suffixes (posix) where the construct is the implementation
_ALLOWED = {
    "relation-mutation": ("core/relation.py",),
    "np-unique": ("core/reference.py",),
    "sentinel-literal": ("core/relation.py",),
}


def _attr_chain(node) -> str:
    """Dotted-name text of a Name/Attribute chain, '' if not one."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def _is_float_dtype(node) -> bool:
    chain = _attr_chain(node)
    if chain:
        return chain.split(".")[-1] in _FLOAT_NAMES
    return isinstance(node, ast.Constant) and node.value in (float,)


class _Visitor(ast.NodeVisitor):
    def __init__(self, rel_path: str):
        self.rel_path = rel_path
        self.findings: list[tuple[int, str, str]] = []
        self._interpret_gate = 0

    def _emit(self, node, rule: str, message: str) -> None:
        if any(self.rel_path.endswith(sfx)
               for sfx in _ALLOWED.get(rule, ())):
            return
        self.findings.append((node.lineno, rule, message))

    # -- relation-mutation ---------------------------------------------

    def visit_Assign(self, node: ast.Assign) -> None:
        for tgt in node.targets:
            self._check_store(tgt)
        self.generic_visit(node)

    def visit_AugAssign(self, node: ast.AugAssign) -> None:
        self._check_store(node.target)
        self.generic_visit(node)

    def _check_store(self, tgt) -> None:
        if isinstance(tgt, ast.Attribute) and tgt.attr in ("columns",
                                                           "valid"):
            self._emit(tgt, "relation-mutation",
                       f"direct store to .{tgt.attr} — Relation mutates "
                       "only through append()")
        if (isinstance(tgt, ast.Subscript)
                and isinstance(tgt.value, ast.Attribute)
                and tgt.value.attr == "columns"):
            self._emit(tgt, "relation-mutation",
                       "store into .columns[...] — Relation columns are "
                       "immutable; build a new Relation or use append()")

    # -- calls: object.__setattr__, np.unique, dtype kwargs, pallas ----

    def visit_Call(self, node: ast.Call) -> None:
        chain = _attr_chain(node.func)

        if chain == "object.__setattr__" and len(node.args) >= 2:
            field = node.args[1]
            if (isinstance(field, ast.Constant)
                    and field.value in _RELATION_FIELDS):
                self._emit(node, "relation-mutation",
                           f"object.__setattr__(..., {field.value!r}, ...)"
                           " — Relation internals mutate only inside "
                           "core/relation.py")

        if chain.endswith(".unique") and chain.split(".")[0] in ("np",
                                                                 "numpy"):
            self._emit(node, "np-unique",
                       "host np.unique outside core/reference.py — the "
                       "device pipelines must not fall back to host "
                       "dedup; oracles belong in reference.py")

        # the called name even when the receiver is itself a call
        # (``x.astype(f).sum()`` has no Name-rooted chain)
        if isinstance(node.func, ast.Attribute):
            func_name = node.func.attr
        elif isinstance(node.func, ast.Name):
            func_name = node.func.id
        else:
            func_name = ""
        if func_name in ("sum", "cumsum", "bincount"):
            for kw in node.keywords:
                if kw.arg == "dtype" and _is_float_dtype(kw.value):
                    self._emit(node, "float-count-accum",
                               f"{func_name}(dtype=<float>) — count "
                               "totals accumulate in int32/int64; one "
                               "f32 sum caps exact totals at 2^24")
            # .astype(<float>).sum() — float accumulation by another name
            recv = node.func.value if isinstance(node.func,
                                                 ast.Attribute) else None
            if (func_name == "sum" and isinstance(recv, ast.Call)
                    and isinstance(recv.func, ast.Attribute)
                    and recv.func.attr == "astype"
                    and any(_is_float_dtype(a) for a in recv.args)):
                self._emit(node, "float-count-accum",
                           ".astype(<float>).sum() — count totals must "
                           "not round-trip through floats")

        if func_name == "pallas_call":
            if not any(kw.arg == "interpret" for kw in node.keywords):
                self._emit(node, "pallas-gate",
                           "pallas_call without an explicit interpret= "
                           "kwarg — kernels must thread the dispatch "
                           "gate, not rely on the Pallas default")
        for kw in node.keywords:
            if (kw.arg == "interpret"
                    and isinstance(kw.value, ast.Constant)
                    and kw.value.value is True
                    and self._interpret_gate == 0):
                self._emit(node, "pallas-gate",
                           "literal interpret=True outside an "
                           "_interpret() dispatch gate — interpret-only "
                           "kernels must be gated so compiled mode never "
                           "silently falls back")
        self.generic_visit(node)

    def visit_If(self, node: ast.If) -> None:
        gated = any(isinstance(n, (ast.Name, ast.Attribute))
                    and _attr_chain(n).split(".")[-1] == "_interpret"
                    for n in ast.walk(node.test))
        self.visit(node.test)
        if gated:
            self._interpret_gate += 1
        for child in node.body:
            self.visit(child)
        if gated:
            self._interpret_gate -= 1
        for child in node.orelse:
            self.visit(child)

    # -- sentinel-literal ----------------------------------------------

    def visit_UnaryOp(self, node: ast.UnaryOp) -> None:
        if (isinstance(node.op, ast.USub)
                and isinstance(node.operand, ast.Constant)
                and node.operand.value == _SENTINEL_MAGNITUDE):
            self._emit(node, "sentinel-literal",
                       "literal -0x7FFFFFFF — derive sentinels from "
                       "relation.SENTINEL so they stay in one place")
        self.generic_visit(node)


def lint_file(path: Path, root: Path | None = None) -> list[str]:
    """Lint one file; findings as ``path:line: [rule] message``."""
    rel = path.as_posix()
    if root is not None:
        try:
            rel = path.relative_to(root).as_posix()
        except ValueError:
            pass
    tree = ast.parse(path.read_text(), filename=str(path))
    v = _Visitor(path.as_posix())
    v.visit(tree)
    return [f"{rel}:{line}: [{rule}] {msg}"
            for line, rule, msg in sorted(v.findings)]


def lint_paths(paths) -> list[str]:
    """Lint every ``.py`` file under each path (file or directory)."""
    findings: list[str] = []
    for p in paths:
        p = Path(p)
        files = sorted(p.rglob("*.py")) if p.is_dir() else [p]
        for f in files:
            findings.extend(lint_file(f, root=Path.cwd()))
    return findings


def main(argv=None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    if not argv:
        argv = ["src/repro"]
    findings = lint_paths(argv)
    for f in findings:
        print(f)
    print(f"invariant lint: {len(findings)} finding(s) over {argv}")
    return 1 if findings else 0


if __name__ == "__main__":
    raise SystemExit(main())
