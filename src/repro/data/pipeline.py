"""Join-enriched data pipeline: the paper's hash-join engine as a
first-class framework feature (DESIGN.md §3).

Training examples carry a document id; a metadata relation maps doc_id →
quality tier.  The enrichment stage hash-joins the example stream against
the metadata (build once, probe per batch — the classic build/probe split)
and emits per-example weights used by the loss/sampler.  This is the same
``core.binary_join.probe_weight_sum`` primitive the 3-way joins use.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.core import binary_join
from repro.core.relation import Relation


@dataclasses.dataclass
class JoinEnrichedPipeline:
    """Wraps a token-batch iterator, attaching join-derived example weights.

    metadata: Relation with columns (doc, tier); examples with no metadata
    row get weight `default_tier`.
    """

    metadata: Relation
    tier_weights: tuple = (0.25, 0.5, 1.0, 2.0)
    default_tier: int = 1

    def weights_for(self, doc_ids: jnp.ndarray) -> jnp.ndarray:
        """Probe the metadata build side for each example's doc id.

        Weight = mean tier weight over matching metadata rows (documents can
        have several annotations), default when unmatched.
        """
        doc_ids = jnp.asarray(doc_ids, jnp.int32)
        valid = jnp.ones(doc_ids.shape, bool)
        tiers = jnp.clip(self.metadata.col("tier"), 0,
                         len(self.tier_weights) - 1)
        tw = jnp.asarray(self.tier_weights, jnp.float32)
        wsum = binary_join.probe_weight_sum(
            self.metadata, "doc", tiers, doc_ids, valid)
        cnt = binary_join.probe_weight_sum(
            self.metadata, "doc", jnp.ones((self.metadata.capacity,),
                                           jnp.int32), doc_ids, valid)
        mean_tier = jnp.where(cnt > 0, wsum / jnp.maximum(cnt, 1),
                              self.default_tier)
        return jnp.take(tw, jnp.clip(mean_tier.astype(jnp.int32), 0,
                                     len(self.tier_weights) - 1))

    def enrich(self, batch: dict, doc_ids) -> dict:
        out = dict(batch)
        out["example_weight"] = self.weights_for(doc_ids)
        return out
