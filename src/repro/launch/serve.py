"""Serving launcher: prefill + batched greedy decode.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --smoke \
        --batch 4 --prompt-len 32 --gen 16 --requests 8

Serving structure (the same code path the decode_32k / long_500k dry-run
cells lower):
  * prefill fills the KV cache for the whole batch,
  * decode_step emits one token per sequence per step (greedy),
  * requests are served in batch waves (batch-synchronous continuous
    batching): when a wave finishes, the next wave's prompts are prefetched
    and prefilled into the (donated) cache with zero recompilation.

Per-slot continuous batching needs a per-row cache clock ([B] lengths);
the cache layout reserves that extension (see DESIGN.md §5 serving).
"""

from __future__ import annotations

import argparse
import time


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro import configs
    from repro.models import zoo
    from repro.train import make_decode_step

    cfg = configs.smoke(args.arch) if args.smoke else configs.get(args.arch)
    model = zoo.build(cfg)
    params = model.init(jax.random.key(args.seed))
    rng = np.random.default_rng(args.seed)
    max_len = args.prompt_len + args.gen
    needs_mem = model.needs_memory

    decode = jax.jit(make_decode_step(model), donate_argnums=1)
    prefill = jax.jit(lambda p, t, c, m=None: model.prefill(p, t, c,
                                                            memory=m),
                      donate_argnums=2)

    n_waves = -(-args.requests // args.batch)
    served = 0
    total_steps = 0
    t0 = time.time()
    for wave in range(n_waves):
        prompts = rng.integers(
            0, cfg.vocab_size,
            size=(args.batch, args.prompt_len)).astype(np.int32)
        memory = (rng.normal(0, 1, size=(args.batch,
                                         cfg.n_frontend_tokens,
                                         cfg.d_model)).astype(np.float32)
                  if needs_mem and cfg.n_frontend_tokens else None)
        cache = model.init_cache(args.batch, max_len)
        if memory is not None:
            logits, cache = prefill(params, jnp.asarray(prompts), cache,
                                    jnp.asarray(memory))
        else:
            logits, cache = prefill(params, jnp.asarray(prompts), cache)
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        outs = [[] for _ in range(args.batch)]
        for _ in range(args.gen):
            tok, logits, cache = decode(params, cache, tok)
            total_steps += 1
            for i in range(args.batch):
                outs[i].append(int(tok[i, 0]))
        served += args.batch
        print(f"wave {wave}: served {args.batch} requests "
              f"({args.gen} tokens each); sample: {outs[0][:8]}")
    dt = time.time() - t0
    print(f"served {min(served, args.requests)} requests, "
          f"{total_steps} decode steps in {dt:.2f}s "
          f"({args.batch * total_steps / dt:.1f} tok/s)")


if __name__ == "__main__":
    main()
