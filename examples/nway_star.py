"""N-way query graphs: a star-schema fact table joined to four dimensions.

    PYTHONPATH=src python examples/nway_star.py

PR 4's front door rejected anything but exactly three relations.  The
plan IR (``core/plan_ir.py``) lifts that: this example declares a
5-relation acyclic query (fact + 4 dims), lets ``planner.plan_query``
decompose it into binary materialize steps feeding a fused,
recovery-wrapped 3-way root, prints the plan, and checks the count
against a brute-force oracle.  It then demonstrates the two operational
satellites: ``execute_many`` amortizing planning over the plan cache,
and the log-bucketed cache keys surviving a ±5% data refresh.
"""

import pathlib
import sys
from collections import defaultdict

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent
                       / "src"))

import numpy as np  # noqa: E402

from repro.core import JoinSession, Query, Relation  # noqa: E402


def _rel(rng, n, cols, d):
    return Relation.from_arrays(
        **{c: rng.integers(0, d, size=n).astype(np.int32) for c in cols})


def main():
    rng = np.random.default_rng(29)
    n_fact, n_dim, d = 40000, 1500, 600
    fact = _rel(rng, n_fact, ("k1", "k2", "k3", "k4"), d)
    dims = {f"d{i}": _rel(rng, n_dim, (f"k{i}", "x"), d)
            for i in (1, 2, 3, 4)}

    q = Query(relations={"fact": fact, **dims},
              predicates=[(f"fact.k{i}", f"d{i}.k{i}")
                          for i in (1, 2, 3, 4)])
    sess = JoinSession(m_budget=4096)
    res = sess.execute(q)

    # oracle: per-fact-row product of dimension match counts
    want = np.ones(n_fact, np.int64)
    for i in (1, 2, 3, 4):
        cnt = defaultdict(int)
        for v in np.asarray(dims[f"d{i}"].col(f"k{i}")).tolist():
            cnt[v] += 1
        want *= np.array([cnt.get(v, 0) for v in
                          np.asarray(fact.col(f"k{i}")).tolist()], np.int64)
    oracle = int(want.sum())

    print(res.plan.describe())
    print(f"\n5-way star COUNT = {int(res.count)}  (oracle {oracle})  "
          f"strategy={res.strategy}  rounds={res.rounds}  "
          f"tuples read = {int(res.tuples_read)}")
    for st in res.step_stats:
        print(f"  step {st.out}: {st.op}, {st.rows} rows, "
              f"{st.tuples_read} tuples, {st.exec_s * 1e3:.1f} ms")
    assert int(res.count) == oracle and not res.overflowed

    # batched execution over the plan cache: plans once, hits thereafter
    batch = sess.execute_many([q] * 4)
    print(f"\nexecute_many(4): cache hits = "
          f"{[r.cache_hit for r in batch]}, "
          f"plan ms = {[f'{r.plan_s * 1e3:.2f}' for r in batch]}")
    assert all(int(r.count) == oracle for r in batch)

    # log-bucketed cache keys: a ±5% refresh of the fact table still hits
    fact2 = _rel(rng, int(n_fact * 1.05), ("k1", "k2", "k3", "k4"), d)
    q2 = Query(relations={"fact": fact2, **dims},
               predicates=[(f"fact.k{i}", f"d{i}.k{i}")
                           for i in (1, 2, 3, 4)])
    drifted = sess.execute(q2)
    print(f"+5% fact refresh: cache_hit={drifted.cache_hit} "
          f"(exact count {int(drifted.count)}, overflowed="
          f"{drifted.overflowed})")
    assert drifted.cache_hit and not drifted.overflowed
    print("\nnway_star OK")


if __name__ == "__main__":
    main()
