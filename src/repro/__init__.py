"""Efficient Multiway Hash Join on Reconfigurable Hardware — JAX/Pallas
reproduction.  See README.md for the package map."""

__version__ = "0.2.0"
