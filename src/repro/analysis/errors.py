"""Typed plan-validation errors shared by the static verifier and the
executor.

``plan_ir.execute_plan`` used to raise bare ``ValueError``s for malformed
plans (unknown op, materializing fused3 step, per-R pin on a non-linear
root, an intermediate too large for int32 indexing).  Those conditions are
exactly what ``analysis.verify_plan`` / ``analysis.widths`` check *before*
dispatch, so both layers now raise the same typed hierarchy: a test (or a
caller) that guards against "this plan is structurally broken" catches one
exception family regardless of whether the verifier or the executor found
it first.

Every class subclasses ``ValueError`` so pre-existing ``except ValueError``
call sites keep working.  This module imports nothing from ``repro`` — it
sits below ``core.plan_ir`` in the import graph on purpose.
"""

from __future__ import annotations


class PlanValidationError(ValueError):
    """A :class:`~repro.core.plan_ir.QueryPlan` violates a plan invariant.

    ``rule`` names the invariant family (mirrored by the subclasses),
    ``step`` / ``index`` locate the offending :class:`PlanStep` when one is
    identifiable — the message embeds the step's ``describe()`` output so
    the failing step is readable without re-walking the plan.
    """

    rule = "plan"

    def __init__(self, message: str, *, step=None, index: int | None = None):
        self.step = step
        self.index = index
        if step is not None:
            try:
                where = step.describe()
            except Exception:
                where = repr(step)
            at = f"step[{index}]" if index is not None else "step"
            message = f"{message}\n  at {at}: {where}"
        super().__init__(message)


class PlanStructureError(PlanValidationError):
    """Topology / def-use violations: steps out of topological order,
    duplicate or malformed ``%i<k>`` definitions, unknown ops, wrong input
    arity, predicates naming relations the step does not read, a fused3
    step that tries to materialize, or an orphan relation no step reads."""

    rule = "structure"


class PlanSchemaError(PlanValidationError):
    """Schema / projection propagation broke: a projection or predicate
    references a column its input does not carry, or two projections
    collide on a destination column name."""

    rule = "schema"


class PlanRefcountError(PlanValidationError):
    """Arena refcount invariants: a materialized ``%i<k>`` intermediate
    with no consumer (the executor would leak it), or consumption that
    cannot match the refcounting arena's bookkeeping."""

    rule = "refcount"


class PlanPerRError(PlanValidationError):
    """Per-R pin violations: ``per_r_key`` on a non-root or non-linear
    step, a pinned key column the role-r input does not carry, or a pin
    the classification cannot host (path centre / cyclic kind)."""

    rule = "per_r"


class PlanWidthError(PlanValidationError):
    """Integer-width violations found by ``analysis.widths``: a composite
    bucket-id space or flat slot range past int32, an intermediate too
    large to materialize, or a Traffic64 multiplier out of range.  Carries
    the diagnostics that crossed the line on ``diagnostics``."""

    rule = "width"

    def __init__(self, message: str, *, step=None, index: int | None = None,
                 diagnostics: tuple = ()):
        super().__init__(message, step=step, index=index)
        self.diagnostics = tuple(diagnostics)
