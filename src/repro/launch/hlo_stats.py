"""Static analyzer for optimized (post-SPMD) HLO text.

Why not ``compiled.cost_analysis()``: XLA's HloCostAnalysis counts a
``while`` body ONCE — a scanned-layer transformer reports ~1/L of its real
flops/bytes, and collectives inside the layer loop (e.g. MoE all-to-alls)
vanish from the totals.  This module re-derives per-device, per-step:

  * flops           — every dot (2·|out|·k, batch-aware) and convolution,
                      recursively through fusions/calls, × while trip
                      counts (from ``backend_config known_trip_count``).
  * traffic bytes   — an HBM model: every non-view top-level op reads its
                      operands and writes its result once; fusion internals
                      are free (that is what fusion means); while-loop
                      bodies multiply by trip count.
  * collective wire bytes — ring-model per-device traffic by kind and by
                      replica-group size (16 = one mesh axis, 512 = world),
                      × trip counts.

The analyzer is intentionally text-level (no jaxlib private APIs) so it
also runs on HLO dumps from other toolchains.
"""

from __future__ import annotations

import dataclasses
import json
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*"
    r"(\([^()]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s+"
    r"([\w\-]+)\(")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS_RE = re.compile(r"(?:calls|to_apply)=%([\w\.\-]+)")
_BODY_RE = re.compile(r"body=%([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%([\w\.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_OPERANDS_RE = re.compile(r"%([\w\.\-]+)")
_LHS_C_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_GROUPS_EXPL_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_WINDOW_SIZE_RE = re.compile(r"window=\{[^}]*size=([0-9x]+)")

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
# view/control ops: no HBM traffic of their own
_NO_TRAFFIC = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "while", "conditional", "call", "after-all", "partition-id",
    "replica-id", "iota", "rng-bit-generator",
}


def _dtype_bytes(dt: str) -> int:
    return _DTYPE_BYTES.get(dt, 4)


def _shape_dims(shape_str: str) -> tuple[list[int], int]:
    """First array shape in the string -> (dims, elem_bytes)."""
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return [], 0
    dt, dims = m.group(1), m.group(2)
    d = [int(x) for x in dims.split(",") if x]
    return d, _dtype_bytes(dt)


def _all_shapes_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        n = 1
        for x in dims.split(","):
            if x:
                n *= int(x)
        total += n * _dtype_bytes(dt)
    return total


@dataclasses.dataclass
class Op:
    name: str
    result: str
    opcode: str
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    ops: list
    is_entry: bool = False


def parse_module(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for line in text.splitlines():
        if cur is None:
            if line and not line[0].isspace() and line.rstrip().endswith("{"):
                m = _COMP_HDR.match(line)
                if m:
                    cur = Computation(m.group(2), [],
                                      is_entry=bool(m.group(1)))
            continue
        if line.startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        m = _OP_RE.match(line)
        if m:
            cur.ops.append(Op(m.group(1), m.group(2), m.group(3), line))
    if cur is not None:
        comps[cur.name] = cur
    return comps


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    traffic: float = 0.0
    coll_wire: float = 0.0
    coll_by_kind: dict = dataclasses.field(
        default_factory=lambda: defaultdict(float))
    coll_by_group: dict = dataclasses.field(
        default_factory=lambda: defaultdict(float))
    n_coll: int = 0

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.traffic += other.traffic * mult
        self.coll_wire += other.coll_wire * mult
        for k, v in other.coll_by_kind.items():
            self.coll_by_kind[k] += v * mult
        for k, v in other.coll_by_group.items():
            self.coll_by_group[k] += v * mult
        self.n_coll += int(other.n_coll * mult)


def _group_size(line: str, world: int) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_EXPL_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip()])
    return world


def _collective_wire(kind: str, result_bytes: int, n: int) -> float:
    if n <= 1:
        return 0.0
    if kind == "all-reduce":
        return 2.0 * (n - 1) / n * result_bytes
    if kind == "all-gather":
        return (n - 1) / n * result_bytes
    if kind == "reduce-scatter":
        return float((n - 1) * result_bytes)      # operand = result × n
    if kind == "all-to-all":
        return (n - 1) / n * result_bytes
    return float(result_bytes)                    # collective-permute


class HloAnalyzer:
    def __init__(self, text: str, world: int, trace: bool = False):
        self.comps = parse_module(text)
        self.world = world
        self._memo: dict[str, Cost] = {}
        self.trace = trace
        self.contrib: list = []        # (traffic, mult, comp, op) if trace
        self._mult = 1.0
        # symbol tables: comp name -> {op name -> result shape str}
        self._sym = {c.name: {op.name: op.result for op in c.ops}
                     for c in self.comps.values()}

    def entry_cost(self) -> Cost:
        entry = next((c for c in self.comps.values() if c.is_entry), None)
        if entry is None:   # fall back: biggest computation
            entry = max(self.comps.values(), key=lambda c: len(c.ops))
        return self._cost(entry.name, traffic_on=True)

    # -- per-computation cost ------------------------------------------
    def _cost(self, name: str, traffic_on: bool) -> Cost:
        key = f"{name}|{traffic_on}"
        if key in self._memo:
            return self._memo[key]
        comp = self.comps.get(name)
        cost = Cost()
        if comp is None:
            self._memo[key] = cost
            return cost
        self._memo[key] = cost      # break cycles defensively
        sym = self._sym[name]
        for op in comp.ops:
            oc = op.opcode
            base_kind = oc[:-6] if oc.endswith("-start") else oc
            if oc == "while":
                m = _TRIP_RE.search(op.line)
                trip = int(m.group(1)) if m else 1
                b = _BODY_RE.search(op.line)
                c = _COND_RE.search(op.line)
                if b:
                    cost.add(self._cost(b.group(1), traffic_on), trip)
                if c:
                    cost.add(self._cost(c.group(1), traffic_on), trip)
                continue
            if oc == "conditional":
                m = _BRANCHES_RE.search(op.line)
                if m:
                    subs = [self._cost(s.strip().lstrip("%"), traffic_on)
                            for s in m.group(1).split(",")]
                    if subs:
                        big = max(subs, key=lambda s: (s.flops, s.traffic))
                        cost.add(big)
                continue
            if oc in ("fusion", "call", "async-start"):
                m = _CALLS_RE.search(op.line)
                if m:
                    # flops + collectives inside; NO internal traffic
                    cost.add(self._cost(m.group(1), traffic_on=False))
                if traffic_on and oc != "async-start":
                    cost.traffic += self._fusion_traffic(
                        op, sym, m.group(1) if m else None)
                continue
            if base_kind in _COLLECTIVES:
                rb = _all_shapes_bytes(op.result)
                n = _group_size(op.line, self.world)
                w = _collective_wire(base_kind, rb, n)
                cost.coll_wire += w
                cost.coll_by_kind[base_kind] += w
                cost.coll_by_group[n] += w
                cost.n_coll += 1
                if traffic_on:
                    cost.traffic += self._op_traffic(op, sym)
                continue
            if oc == "dot":
                cost.flops += self._dot_flops(op, sym)
            elif oc == "convolution":
                cost.flops += self._conv_flops(op, sym)
            if traffic_on and oc not in _NO_TRAFFIC:
                cost.traffic += self._op_traffic(op, sym)
        self._memo[key] = cost
        return cost

    # -- op-level helpers ----------------------------------------------
    def _operand_names(self, op: Op) -> list[str]:
        call = op.line.split(op.opcode + "(", 1)[1]
        depth = 1
        args = []
        for i, ch in enumerate(call):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    args = _OPERANDS_RE.findall(call[:i])
                    break
        return args

    def _op_traffic(self, op: Op, sym: dict) -> float:
        """HBM traffic of one top-level op: read operands + write result,
        with slicing ops charged only for the data they touch."""
        res = _all_shapes_bytes(op.result)
        oc = op.opcode
        if oc in ("dynamic-slice", "slice"):
            return 2.0 * res                       # read slice + write
        if oc == "gather":
            idx = sym.get((self._operand_names(op) + [None, None])[1], "")
            return 2.0 * res + _all_shapes_bytes(idx)
        if oc == "dynamic-update-slice":
            upd = sym.get((self._operand_names(op) + [None, None])[1], "")
            return 2.0 * _all_shapes_bytes(upd)    # in-place slice write
        if oc == "scatter":
            names = self._operand_names(op)
            upd = sym.get(names[2], "") if len(names) > 2 else ""
            idx = sym.get(names[1], "") if len(names) > 1 else ""
            return (2.0 * _all_shapes_bytes(upd)
                    + _all_shapes_bytes(idx))
        t = float(res)
        for nm in self._operand_names(op):
            shp = sym.get(nm)
            if shp:
                t += _all_shapes_bytes(shp)
        return t

    def _fusion_traffic(self, op: Op, sym: dict,
                        callee: str | None) -> float:
        """Fusion site traffic: result + effective operand bytes.  An
        operand whose in-fusion consumers are all slicing ops is charged at
        the sliced size; a DUS-rooted fusion writes only its update."""
        comp = self.comps.get(callee) if callee else None
        names = self._operand_names(op)
        if comp is None:
            return self._op_traffic(op, sym)
        fsym = self._sym[comp.name]
        # map parameter index -> in-fusion param op name
        param_of: dict[int, str] = {}
        for fop in comp.ops:
            if fop.opcode == "parameter":
                m = re.search(r"parameter\((\d+)\)", fop.line)
                if m:
                    param_of[int(m.group(1))] = fop.name
        # consumers of each in-fusion op
        consumers: dict[str, list[Op]] = defaultdict(list)
        for fop in comp.ops:
            for nm in self._operand_names(fop):
                consumers[nm].append(fop)

        total = 0.0
        # in-place pattern: an internal DUS whose buffer operand resolves
        # (through convert/bitcast/copy/reshape chains — XLA:CPU wraps
        # bf16 buffers in f32 converts that a TPU lowering does not emit)
        # to a fusion parameter of ~the fusion result's element count: the
        # update is written through; the big buffer is never re-read.
        view_like = {"convert", "bitcast", "copy", "reshape", "transpose"}
        op_by_name = {f.name: f for f in comp.ops}

        def resolve(nm: str, depth: int = 0) -> str:
            f = op_by_name.get(nm)
            if f is None or depth > 8:
                return nm
            if f.opcode == "parameter":
                return nm
            if f.opcode in view_like:
                ops_ = self._operand_names(f)
                if ops_:
                    return resolve(ops_[0], depth + 1)
            return nm

        dus_ops = [f for f in comp.ops
                   if f.opcode == "dynamic-update-slice"]
        inplace_param = None
        dus_update_bytes = 0.0
        res_bytes = _all_shapes_bytes(op.result)

        def _numel(shape_str):
            d, eb = _shape_dims(shape_str)
            n = 1
            for x in d:
                n *= x
            return n, eb

        res_numel, _ = _numel(op.result)
        for dus in dus_ops:
            dnames = self._operand_names(dus)
            if not dnames:
                continue
            buf = resolve(dnames[0])
            if buf in set(param_of.values()):
                buf_numel, _ = _numel(fsym.get(buf, ""))
                if buf_numel == res_numel:
                    inplace_param = buf
                    upd = fsym.get(dnames[1], "") \
                        if len(dnames) > 1 else ""
                    dus_update_bytes = _all_shapes_bytes(upd)
                    break
        if inplace_param is not None:
            total += 2.0 * dus_update_bytes
        else:
            total += res_bytes

        for i, nm in enumerate(names):
            shp = sym.get(nm)
            if not shp:
                continue
            full = _all_shapes_bytes(shp)
            pname = param_of.get(i)
            if pname is not None and pname == inplace_param:
                continue          # the in-place buffer: not re-read
            cons = consumers.get(pname, []) if pname else []
            # look through view/convert chains to the real consumers
            seen = set()
            frontier = list(cons)
            real = []
            while frontier:
                c = frontier.pop()
                if c.name in seen:
                    continue
                seen.add(c.name)
                if c.opcode in view_like:
                    frontier.extend(consumers.get(c.name, []))
                else:
                    real.append(c)
            if real and all(c.opcode in ("dynamic-slice", "slice",
                                         "gather") for c in real):
                eff = sum(_all_shapes_bytes(c.result) for c in real)
                total += min(full, eff)
            else:
                total += full
        return total

    def _dot_flops(self, op: Op, sym: dict) -> float:
        out_dims, _ = _shape_dims(op.result)
        out_numel = 1
        for d in out_dims:
            out_numel *= d
        m = _LHS_C_RE.search(op.line)
        contract = 1
        if m:
            idxs = [int(x) for x in m.group(1).split(",") if x]
            lhs_name = (self._operand_names(op) or [None])[0]
            lhs_shape = sym.get(lhs_name, "")
            ldims, _ = _shape_dims(lhs_shape)
            for i in idxs:
                if i < len(ldims):
                    contract *= ldims[i]
        return 2.0 * out_numel * contract

    def _conv_flops(self, op: Op, sym: dict) -> float:
        out_dims, _ = _shape_dims(op.result)
        out_numel = 1
        for d in out_dims:
            out_numel *= d
        m = _WINDOW_SIZE_RE.search(op.line)
        ksize = 1
        if m:
            for x in m.group(1).split("x"):
                ksize *= int(x)
        names = self._operand_names(op)
        cin = 1
        if len(names) >= 2:
            kdims, _ = _shape_dims(sym.get(names[1], ""))
            if kdims:
                cin = kdims[-2] if len(kdims) >= 2 else 1
        return 2.0 * out_numel * ksize * cin


def score_traffic(text: str, world: int, qc: int, kc: int) -> float:
    """Traffic (bytes/device/step) of attention score-shaped tensors: any
    op whose RESULT dims include both the q-chunk and kv-chunk sizes.
    Used by the dry-run's Pallas-flash substitution — these are exactly
    the tensors a fused kernel keeps in VMEM."""
    total = 0.0
    for row in trace_contributors(text, world, top=None):
        tot, _per, _mult, kind, _comp, _opc, _name, res = row
        if kind != "traffic":
            continue
        m = _SHAPE_RE.search(res)
        if not m:
            continue
        dims = [int(x) for x in m.group(2).split(",") if x]
        if qc in dims and kc in dims:
            total += tot
    return total


def trace_contributors(text: str, world: int, top: int | None = 25):
    """Non-memoized walk listing the largest traffic/flops/collective
    contributors with their loop multipliers — the dry-run 'profiler'."""
    an = HloAnalyzer(text, world)
    out = []

    def walk(name: str, mult: float, traffic_on: bool):
        comp = an.comps.get(name)
        if comp is None:
            return
        sym = an._sym[name]
        for op in comp.ops:
            oc = op.opcode
            base_kind = oc[:-6] if oc.endswith("-start") else oc
            if oc == "while":
                m = _TRIP_RE.search(op.line)
                trip = int(m.group(1)) if m else 1
                b = _BODY_RE.search(op.line)
                if b:
                    walk(b.group(1), mult * trip, traffic_on)
                continue
            if oc in ("fusion", "call", "async-start"):
                m = _CALLS_RE.search(op.line)
                if m:
                    walk(m.group(1), mult, False)
                if traffic_on and oc != "async-start":
                    t = an._fusion_traffic(op, sym,
                                           m.group(1) if m else None)
                    out.append((t * mult, t, mult, "traffic", name,
                                op.opcode, op.name, op.result[:60]))
                continue
            if base_kind in _COLLECTIVES:
                rb = _all_shapes_bytes(op.result)
                n = _group_size(op.line, world)
                w = _collective_wire(base_kind, rb, n)
                out.append((w * mult, w, mult, f"coll[{n}]", name,
                            base_kind, op.name, op.result[:60]))
                continue
            if oc == "dot":
                f = an._dot_flops(op, sym)
                out.append((f * mult / 1e3, f, mult, "flops", name,
                            op.opcode, op.name, op.result[:60]))
            if traffic_on and oc not in _NO_TRAFFIC:
                t = an._op_traffic(op, sym)
                out.append((t * mult, t, mult, "traffic", name, op.opcode,
                            op.name, op.result[:60]))

    entry = next((c for c in an.comps.values() if c.is_entry), None)
    if entry:
        walk(entry.name, 1.0, True)
    out.sort(reverse=True)
    return out if top is None else out[:top]


def analyze(text: str, world: int) -> dict:
    cost = HloAnalyzer(text, world).entry_cost()
    return {
        "flops": cost.flops,
        "traffic_bytes": cost.traffic,
        "collective_wire_bytes": cost.coll_wire,
        "wire_by_kind": dict(cost.coll_by_kind),
        "wire_by_group_size": {str(k): v
                               for k, v in cost.coll_by_group.items()},
        "n_collectives": cost.n_coll,
    }


if __name__ == "__main__":
    import sys
    text = open(sys.argv[1]).read()
    world = int(sys.argv[2]) if len(sys.argv) > 2 else 256
    print(json.dumps(analyze(text, world), indent=2))
    if len(sys.argv) > 3 and sys.argv[3] == "--trace":
        print("\ntop contributors (total, per-visit, mult, kind, comp, "
              "opcode, name, result):")
        for row in trace_contributors(text, world):
            tot, per, mult, kind, comp, opc, name, res = row
            print(f"  {tot:.3e}  per={per:.3e} x{mult:<6.0f} {kind:10s} "
                  f"{opc:22s} {name[:28]:28s} {res}  [{comp[:40]}]")
