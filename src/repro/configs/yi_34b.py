"""yi-34b — dense llama-arch GQA [arXiv:2403.04652; hf].

60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="yi-34b", family="dense",
    n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8,
    d_ff=20480, vocab_size=64000, head_dim=128,
    rope_theta=5e6, norm_eps=1e-5,
    scan_group=10, accum_steps=4,
)

SMOKE = ModelConfig(
    name="yi-34b-smoke", family="dense",
    n_layers=3, d_model=128, n_heads=8, n_kv_heads=2,
    d_ff=352, vocab_size=512, head_dim=16,
    rope_theta=5e6, norm_eps=1e-5, remat=False,
)
