"""Multi-step query-plan IR: cascades of fused 3-way and binary joins.

The paper's central result is a *choice* — one fused 3-way join versus a
cascade of binary hash joins — and this module is the representation that
makes the choice first-class for any connected acyclic equality-join graph
over N >= 2 named relations (cyclic graphs stay supported at N = 3, the
triangle query):

  * :class:`PlanStep` — one physical step.  ``op == "binary"`` is a
    sorted-path hash join (materialized into a fixed-capacity intermediate
    ``Relation``, or host-aggregated when it is the root); ``op ==
    "fused3"`` is the fused 3-way engine, recovery-wrapped: skew rounds +
    the exact-histogram final round make ``overflowed == False`` a
    per-step postcondition.
  * :class:`QueryPlan` — a DAG of steps in topological order.  Steps name
    their inputs (base relations by query name, intermediates as
    ``%i<k>``); intermediate schemas (``project``) and plan-time
    cardinality estimates (``est_rows``/``est_out``) flow between steps;
    the root step writes :data:`COUNT`.
  * :func:`execute_plan` — the ONE executor.  It walks the DAG,
    materializes intermediates exactly (capacities sized from exact
    host-side key histograms, so a materialize step *cannot* overflow),
    threads ``base_salt``/``max_rounds``/``growth`` through every fused
    step, and aggregates count / tuples_read / recovery rounds across
    steps into a single result.

``planner.plan_query`` is the decomposer that produces these plans;
``session.JoinSession.execute`` walks them.  The legacy
``planner.EnginePlan.run`` cascade branch now routes through this
executor too — there is no second cascade implementation.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Mapping, NamedTuple

import numpy as np

from repro.core import binary_join, engine
from repro.core.query import Predicate
from repro.core.relation import Relation

# The root step's output name: the aggregated COUNT of the whole query.
COUNT = "%count"


def _align8(n: int) -> int:
    return max(8, ((int(n) + 7) // 8) * 8)


@dataclasses.dataclass(frozen=True)
class PlanStep:
    """One physical step of a :class:`QueryPlan`.

    ``inputs`` are environment names: base relations keep their query
    names, intermediates are ``%i<k>``.  ``preds`` reference columns in
    the *post-projection* key space of each input (base relations keep
    their original column names; intermediate columns are
    ``"<relation>.<column>"``, stamped by the materialize step that
    produced them).
    """

    op: str                              # "binary" | "fused3"
    out: str                             # "%i<k>" or COUNT
    inputs: tuple[str, ...]              # 2 (binary) or 3 (fused3) names
    preds: tuple[Predicate, ...]         # equality predicates among inputs
    aggregate: bool                      # root COUNT step vs materialize
    # binary materialize: per-input projection ((src col, dst col), ...) —
    # only the columns later steps read survive into the intermediate
    project: tuple = ()
    # fused3 bookkeeping: the classified kind, engine role -> input name,
    # engine col kwarg -> column key, and (optionally) a pre-sized shape
    # plan.  ``shape_plan is None`` means "size at execute time from the
    # live cardinalities" — the rule for steps that read intermediates.
    kind: str | None = None
    roles: tuple[tuple[str, str], ...] = ()
    cols: tuple[tuple[str, str], ...] = ()
    shape_plan: object | None = None
    recovery: bool = True                # fused3 steps run skew recovery
    choice: object | None = None         # planner.TimedChoice, if one ran
    est_rows: tuple[int, ...] = ()       # plan-time input-card estimates
    est_out: int | None = None           # plan-time output-rows estimate

    def describe(self) -> str:
        if self.op == "fused3":
            ins = ", ".join(self.inputs)
            return (f"{self.out} <- fused3[{self.kind}"
                    f"{', recovery' if self.recovery else ''}]({ins})")
        (p,) = self.preds
        verb = "count" if self.aggregate else "join"
        est = "" if self.est_out is None else f"  [~{self.est_out} rows]"
        return (f"{self.out} <- binary-{verb}({self.inputs[0]} ⋈ "
                f"{self.inputs[1]} on {p.left[1]} = {p.right[1]}){est}")


@dataclasses.dataclass(frozen=True)
class QueryPlan:
    """A DAG of :class:`PlanStep` in topological order, plus the engine
    configuration every step shares.  This object is what the session's
    plan cache stores: it references relations by NAME only, so a cached
    plan re-executes against refreshed data of similar size."""

    steps: tuple[PlanStep, ...]
    n_relations: int
    kind: str                # classified kind of the (root) frontier
    strategy: str            # "3way" | "cascade" | "hybrid"
    m_budget: int | None = None
    use_kernel: bool = False
    max_rounds: int = 3
    growth: float = 2.0
    base_salt: int = 0

    @property
    def fused3_steps(self) -> tuple[PlanStep, ...]:
        return tuple(s for s in self.steps if s.op == "fused3")

    @property
    def root(self) -> PlanStep:
        return self.steps[-1]

    def describe(self) -> str:
        head = (f"QueryPlan[{self.n_relations} relations, kind={self.kind}, "
                f"strategy={self.strategy}]")
        return "\n".join([head] + ["  " + s.describe() for s in self.steps])


class StepStats(NamedTuple):
    """Per-step execution record (aggregated onto the QueryResult)."""

    op: str
    out: str
    rows: int                # materialized rows, or the aggregated count
    rounds: int              # recovery rounds (0 for binary steps)
    tuples_read: int
    exec_s: float


class PlanExecResult(NamedTuple):
    count: int
    overflowed: bool         # False by construction (see execute_plan)
    tuples_read: int         # summed over steps (intermediates counted as
    rounds: int              # written once + read once, like §6.3)
    step_stats: tuple


def _step_keys(step: PlanStep) -> tuple[str, str]:
    """The (left-input, right-input) join column keys of a binary step."""
    (pred,) = step.preds
    if pred.left[0] == step.inputs[0]:
        return pred.left[1], pred.right[1]
    return pred.right[1], pred.left[1]


def _project(rel: Relation, mapping) -> Relation:
    if not mapping:
        return rel
    return Relation({dst: rel.columns[src] for src, dst in mapping},
                    rel.valid)


def _materialize(step: PlanStep, env) -> tuple[Relation, int, int]:
    """Execute a binary materialize step: exact-size the intermediate from
    host-side key histograms (it cannot overflow), then expand."""
    a, b = env[step.inputs[0]], env[step.inputs[1]]
    proj_a, proj_b = step.project if step.project else ((), ())
    a2, b2 = _project(a, proj_a), _project(b, proj_b)
    ka, kb = _step_keys(step)
    total = binary_join.exact_join_count(a2, ka, b2, kb)
    if total >= 2**31:
        raise ValueError(
            f"intermediate {step.out} has {total} rows — too large to "
            "materialize; re-plan with strategy='3way' (the fused 3-way "
            "engine never materializes the join output)")
    jres = binary_join.join_materialize(a2, ka, b2, kb,
                                        _align8(max(64, total + 8)))
    assert not bool(jres.overflowed)      # exact-sized above
    tuples = int(a.n) + int(b.n) + total  # read both inputs, write I once
    return jres.rel, total, tuples


def _run_fused3(step: PlanStep, plan: QueryPlan, env) -> engine.EngineResult:
    """Execute a fused 3-way step through the recovery-wrapped engine.
    ``shape_plan is None`` sizes the partition shape here, from the LIVE
    input cardinalities (the inputs may be just-materialized
    intermediates whose sizes no plan-time estimate pinned down)."""
    rels = {role: env[name] for role, name in step.roles}
    r, s, t = rels["r"], rels["s"], rels["t"]
    eng = engine.MultiwayJoinEngine(
        step.kind, use_kernel=plan.use_kernel, max_rounds=plan.max_rounds,
        growth=plan.growth, base_salt=plan.base_salt)
    shape = step.shape_plan
    if shape is None:
        shape = eng.default_plan(int(r.n), int(s.n), int(t.n),
                                 m_budget=plan.m_budget)
    return eng.count(r, s, t, shape, **dict(step.cols))


def execute_plan(plan: QueryPlan,
                 relations: Mapping[str, Relation]) -> PlanExecResult:
    """Walk the DAG: materialize intermediates, aggregate at the root.

    ``overflowed == False`` is a postcondition of the whole walk: binary
    materialize steps are exact-sized host-side, binary aggregates are
    exact int64 host histograms, and fused steps inherit the recovery
    engine's exact-histogram final round.
    """
    env: dict[str, Relation] = dict(relations)
    total_tuples = 0
    rounds = 0
    count = 0
    stats: list[StepStats] = []
    for step in plan.steps:
        t0 = time.perf_counter()
        if step.op == "binary" and not step.aggregate:
            rel, rows, tuples = _materialize(step, env)
            env[step.out] = rel
            total_tuples += tuples
            stats.append(StepStats("binary", step.out, rows, 0, tuples,
                                   time.perf_counter() - t0))
        elif step.op == "binary":
            a, b = env[step.inputs[0]], env[step.inputs[1]]
            ka, kb = _step_keys(step)
            count = binary_join.exact_join_count(a, ka, b, kb)
            tuples = int(a.n) + int(b.n)
            total_tuples += tuples
            stats.append(StepStats("binary", step.out, count, 0, tuples,
                                   time.perf_counter() - t0))
        elif step.op == "fused3":
            if not step.aggregate:
                raise ValueError(
                    "fused3 steps aggregate (the engine never materializes "
                    f"its output); step {step.out!r} tries to materialize")
            res = _run_fused3(step, plan, env)
            count = int(res.count)
            total_tuples += int(res.tuples_read)
            rounds += int(res.rounds)
            stats.append(StepStats("fused3", step.out, count,
                                   int(res.rounds), int(res.tuples_read),
                                   time.perf_counter() - t0))
        else:
            raise ValueError(f"unknown plan-step op {step.op!r}")
    return PlanExecResult(int(count), False, int(total_tuples),
                          max(rounds, 1), tuple(stats))


def result_as_engine(res: PlanExecResult) -> engine.EngineResult:
    """Repackage a plan walk as the legacy EngineResult contract."""
    import jax.numpy as jnp
    return engine.EngineResult(np.int64(res.count), jnp.asarray(False),
                               np.int64(res.tuples_read), res.rounds)
