"""Fig 4 (d): linear 3-way self-join hyperparameter selection — execution
time vs H_bkt and g_bkt.  Paper behaviours validated: compute-bound at
small g_bkt, shifting to stream_T; dramatic degradation at very large
g_bkt (tiny S_ij buckets: DRAM response-time cliff + per-bucket sync);
larger R partitions (small H_bkt) are better."""

from __future__ import annotations

from benchmarks.common import claim, write_csv
from repro.perfmodel import PLASTICINE, linear3_time

N, D = 2e8, 7e5


def main(results: dict | None = None):
    results = results if results is not None else {}
    print("fig4d: linear 3-way hyperparameters")
    rows = []
    by_g = {}
    for g in (16, 64, 256, 1024, 4096, 65536, 1048576, 16777216):
        b = linear3_time(N, N, N, D, PLASTICINE, g_bkt=g)
        comp = b.stages["comp"]
        stream = b.stages["stream_T"] + b.stages["load_S"]
        bn = "comp" if comp > stream else "stream_T"
        by_g[g] = (b.total, bn)
        rows.append([g, b.total, comp, b.stages["stream_T"],
                     b.stages["load_S"], b.stages["sync"], bn])
    write_csv("fig4d_linear3_gbkt",
              ["g_bkt", "total_s", "comp_s", "stream_T_s", "load_S_s",
               "sync_s", "bottleneck"], rows)

    claim(results, "fig4d_comp_to_stream_shift",
          by_g[16][1] == "comp" and by_g[16777216][1] == "stream_T",
          f"bottleneck g=16: {by_g[16][1]} -> g=1.7e7: {by_g[16777216][1]}")
    claim(results, "fig4d_large_gbkt_cliff",
          by_g[16777216][0] > 3 * by_g[4096][0],
          f"t(g=1.7e7)={by_g[16777216][0]:.1f}s >> "
          f"t(g=4096)={by_g[4096][0]:.1f}s (tiny-bucket DRAM cliff)")

    rows_h = []
    hs = {}
    for h in (200, 400, 800, 1600, 6400):   # min H = |R|/M = 200
        b = linear3_time(N, N, N, D, PLASTICINE, h_bkt=h)
        hs[h] = b.total
        rows_h.append([h, b.total, b.bottleneck])
    write_csv("fig4d_linear3_hbkt", ["h_bkt", "total_s", "bottleneck"],
              rows_h)
    claim(results, "fig4d_small_hbkt_better", hs[200] <= hs[6400],
          f"t(H=200)={hs[200]:.1f}s <= t(H=6400)={hs[6400]:.1f}s "
          "(paper: larger R partition + prefetch wins)")
    return results


if __name__ == "__main__":
    main()
