"""Run the full dry-run matrix: every (arch × applicable shape × mesh).

Each cell runs in its own subprocess (jax locks the forced 512-device count
at first init) and writes artifacts/dryrun/<arch>__<shape>__<pod>.json.
Already-present artifacts are skipped (delete to re-run), so this driver is
resumable and can be re-invoked after perf iterations with --tag.

    PYTHONPATH=src python benchmarks/dryrun_all.py [--only-pod1] [--arch A]
"""

from __future__ import annotations

import argparse
import json
import pathlib
import subprocess
import sys
import time

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent
                       / "src"))

from repro import configs  # noqa: E402


def cells():
    for arch in configs.ARCH_IDS:
        cfg = configs.get(arch)
        for shape in configs.SHAPES:
            if configs.shape_applicable(cfg, shape):
                yield arch, shape


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--only-pod1", action="store_true")
    ap.add_argument("--only-pod2", action="store_true")
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--tag", default="")
    ap.add_argument("--set", nargs="*", dest="overrides", default=None)
    ap.add_argument("--timeout", type=int, default=2400)
    args = ap.parse_args()

    outdir = pathlib.Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)
    pods = [False, True]
    if args.only_pod1:
        pods = [False]
    if args.only_pod2:
        pods = [True]

    todo = []
    for arch, shape in cells():
        if args.arch and arch != args.arch:
            continue
        if args.shape and shape != args.shape:
            continue
        for mp in pods:
            name = f"{arch}__{shape}__{'pod2' if mp else 'pod1'}"
            if args.tag:
                name += f"__{args.tag}"
            path = outdir / f"{name}.json"
            if path.exists():
                if json.loads(path.read_text()).get("ok"):
                    print(f"skip (cached): {name}")
                    continue
                path.unlink()          # retry failures
            todo.append((arch, shape, mp, name))

    print(f"{len(todo)} cells to run")
    t_all = time.time()
    failures = []
    for i, (arch, shape, mp, name) in enumerate(todo):
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", arch, "--shape", shape, "--out", str(outdir)]
        if mp:
            cmd.append("--multi-pod")
        if args.tag:
            cmd += ["--tag", args.tag]
        if args.overrides:
            cmd += ["--set", *args.overrides]
        t0 = time.time()
        print(f"[{i + 1}/{len(todo)}] {name} ...", flush=True)
        try:
            r = subprocess.run(cmd, capture_output=True, text=True,
                               timeout=args.timeout,
                               env={"PYTHONPATH": "src",
                                    "PATH": "/usr/bin:/bin:/usr/local/bin"})
            ok = r.returncode == 0
        except subprocess.TimeoutExpired:
            ok = False
            r = None
        dt = time.time() - t0
        if not ok:
            failures.append(name)
            tail = (r.stdout + r.stderr)[-2000:] if r else "TIMEOUT"
            print(f"  FAILED in {dt:.0f}s\n{tail}", flush=True)
        else:
            art = json.loads((outdir / f"{name}.json").read_text())
            rf = art.get("roofline", {})
            print(f"  ok in {dt:.0f}s  bottleneck={rf.get('bottleneck')}  "
                  f"roofline_frac={rf.get('roofline_fraction', 0):.4f}  "
                  f"fits16g={art.get('fits_16gb')}", flush=True)

    print(f"\ndone in {(time.time() - t_all) / 60:.1f} min; "
          f"{len(failures)} failures: {failures}")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
