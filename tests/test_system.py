"""End-to-end system behaviour: fault tolerance (checkpoint/restart,
simulated node failure, straggler detection), elastic re-mesh restore,
gradient-compression error feedback, and the train/serve launchers."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.checkpoint import CheckpointManager, latest_step
from repro.data.synthetic import TokenGenConfig, batch_at
from repro.models import zoo
from repro.optim import AdamWConfig
from repro.optim.compression import ef_init, simulate_roundtrip
from repro.runtime import RestartableLoop, StragglerMonitor
from repro.train import init_train_state, make_train_step


def _setup(tmp_path, arch="qwen2-1.5b", every=2):
    cfg = configs.smoke(arch)
    model = zoo.build(cfg)
    gen = TokenGenConfig(vocab_size=cfg.vocab_size, batch=2, seq_len=16,
                         seed=7, n_frontend_tokens=cfg.n_frontend_tokens,
                         d_model=cfg.d_model)
    step_fn = jax.jit(make_train_step(model, AdamWConfig(lr=1e-3,
                                                         total_steps=20)))
    batch = lambda s: {k: jnp.asarray(v)            # noqa: E731
                       for k, v in batch_at(gen, s).items()}
    manager = CheckpointManager(tmp_path / "ckpt", every=every, keep=2)
    return model, step_fn, batch, manager


def test_restart_resumes_identically(tmp_path):
    """Crash at step 5 -> resume -> final state identical to an
    uninterrupted run (pure data pipeline + committed checkpoints)."""
    model, step_fn, batch, manager = _setup(tmp_path)
    state0 = init_train_state(model, jax.random.key(0))

    # uninterrupted reference run
    ref = state0
    for s in range(8):
        ref, _ = step_fn(ref, batch(s))

    # crashing run
    loop = RestartableLoop(manager, log=lambda *_: None)
    with pytest.raises(RuntimeError, match="simulated node failure"):
        loop.run(state0, step_fn, batch, 8, fail_at=5)

    # restart: resume from newest committed checkpoint
    last = manager.latest_step()
    assert last is not None and last <= 5
    loop2 = RestartableLoop(manager, log=lambda *_: None)
    resumed, start = loop2.resume_step(jax.eval_shape(lambda: state0))
    assert start == last
    final, end = loop2.run(resumed, step_fn, batch, 8, start_step=start)
    assert end == 8
    for a, b in zip(jax.tree.leaves(ref.params),
                    jax.tree.leaves(final.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-6)


def test_checkpoint_atomicity_ignores_torn_write(tmp_path):
    model, step_fn, batch, manager = _setup(tmp_path)
    state = init_train_state(model, jax.random.key(0))
    manager.save(state, 2)
    # simulate a torn write: step_4 exists but has no COMMITTED marker
    torn = manager.dir / "step_00000004"
    torn.mkdir(parents=True)
    (torn / "manifest.json").write_text("{}")
    assert latest_step(manager.dir) == 2


def test_elastic_restore_across_shardings(tmp_path):
    """A checkpoint restores regardless of the saving process's sharding
    (host-format arrays + shardings applied at restore)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.mesh import make_host_mesh
    model, step_fn, batch, manager = _setup(tmp_path)
    state = init_train_state(model, jax.random.key(0))
    manager.save(state, 1)

    mesh = make_host_mesh()          # 1-device "fleet" on this container
    shardings = jax.tree.map(lambda _: NamedSharding(mesh, P()), state)
    restored, manifest = manager.restore(state, shardings=shardings)
    assert manifest["step"] == 1
    leaf = jax.tree.leaves(restored.params)[0]
    assert isinstance(leaf.sharding, NamedSharding)


def test_straggler_monitor_flags_outlier():
    mon = StragglerMonitor(threshold=4.0, warmup=3)
    for s in range(10):
        mon.observe(s, 0.10 + 0.001 * (s % 2))
    st = mon.observe(10, 1.5)       # 15x the EMA
    assert st.flagged and 10 in mon.flags
    # EMA did not learn the outlier
    st2 = mon.observe(11, 0.10)
    assert not st2.flagged


def test_gradient_compression_error_feedback():
    """Error feedback keeps compressed-SGD unbiased over steps: the
    accumulated applied update converges to the true gradient sum."""
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.normal(0, 1, (64, 64)).astype(np.float32))}
    residual = ef_init(g)
    applied = jnp.zeros_like(g["w"])
    for _ in range(20):
        out, residual = simulate_roundtrip(g, residual)
        applied = applied + out["w"]
    true = 20.0 * g["w"]
    rel = float(jnp.linalg.norm(applied - true) / jnp.linalg.norm(true))
    assert rel < 0.01, f"error feedback drifted: rel={rel}"
    # while a single step has visible quantization error:
    one, _ = simulate_roundtrip(g, ef_init(g))
    rel1 = float(jnp.linalg.norm(one["w"] - g["w"])
                 / jnp.linalg.norm(g["w"]))
    assert rel1 > 1e-4


def test_train_launcher_smoke(tmp_path):
    from repro.launch.train import main as train_main
    state, losses = train_main([
        "--arch", "qwen2-1.5b", "--smoke", "--steps", "6",
        "--batch", "2", "--seq", "16",
        "--ckpt-dir", str(tmp_path / "ck"), "--ckpt-every", "3",
        "--log-every", "100"])
    assert len(losses) == 6
    assert all(np.isfinite(x) for x in losses)
    assert latest_step(tmp_path / "ck") is not None


def test_train_loss_decreases():
    """Training on a FIXED batch must memorize it (loss drops >1 nat)."""
    cfg = configs.smoke("qwen2-1.5b")
    model = zoo.build(cfg)
    gen = TokenGenConfig(vocab_size=cfg.vocab_size, batch=4, seq_len=32,
                         seed=11)
    step_fn = jax.jit(make_train_step(model, AdamWConfig(
        lr=3e-3, total_steps=60, warmup_steps=10)))
    state = init_train_state(model, jax.random.key(1))
    first = last = None
    batch = {k: jnp.asarray(v) for k, v in batch_at(gen, 0).items()}
    for s in range(60):
        state, m = step_fn(state, batch)
        if first is None:
            first = float(m["loss"])
        last = float(m["loss"])
    assert last < first - 1.0, (first, last)


def test_serve_launcher_smoke(capsys):
    from repro.launch.serve import main as serve_main
    serve_main(["--arch", "qwen2-1.5b", "--smoke", "--batch", "2",
                "--prompt-len", "8", "--gen", "4", "--requests", "4"])
    out = capsys.readouterr().out
    assert "served 4 requests" in out
