"""Attention: GQA with chunked (flash-style) softmax, sliding windows,
cross-attention, and KV-cache decode.

Memory discipline: training/prefill never materializes the full [S, T] score
matrix — a double scan over (q chunks × kv chunks) carries the online
softmax state (m, l, acc), bounding live intermediates to
[B, H, q_chunk, kv_chunk].  This is the jnp analogue of a Pallas flash
kernel; XLA fuses the inner body.  (The paper's compute hot-spot is the join
kernels — attention stays pure JAX per DESIGN.md §3.)

Decode attends one query position against the cache: [B, H, 1, T] scores are
linear in T and cheap even at T = 524288, batch 1 (long_500k).
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models import layers
from repro.parallel import shard

NEG_INF = -2.0e38


def init_attention(key, cfg, d_model=None):
    d = d_model or cfg.d_model
    hd, nq, nkv = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {
        "wq": layers.init_linear(k1, d, nq * hd, bias=cfg.qkv_bias,
                                 logical=("p_embed", "p_heads")),
        "wk": layers.init_linear(k2, d, nkv * hd, bias=cfg.qkv_bias,
                                 logical=("p_embed", "p_heads")),
        "wv": layers.init_linear(k3, d, nkv * hd, bias=cfg.qkv_bias,
                                 logical=("p_embed", "p_heads")),
        "wo": layers.init_linear(k4, nq * hd, d,
                                 logical=("p_heads", "p_embed")),
    }
    if cfg.qk_norm:
        p["q_norm"] = layers.init_rms_norm(hd)
        p["k_norm"] = layers.init_rms_norm(hd)
    return p


def _project_qkv(p, cfg, x, positions, theta):
    b, s, _ = x.shape
    hd, nq, nkv = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    q = layers.linear(x, p["wq"]["w"], p["wq"].get("b")).reshape(b, s, nq, hd)
    k = layers.linear(x, p["wk"]["w"], p["wk"].get("b")).reshape(b, s, nkv, hd)
    v = layers.linear(x, p["wv"]["w"], p["wv"].get("b")).reshape(b, s, nkv, hd)
    if cfg.qk_norm:
        q = layers.rms_norm(q, p["q_norm"]["scale"], cfg.norm_eps)
        k = layers.rms_norm(k, p["k_norm"]["scale"], cfg.norm_eps)
    if theta is not None:
        q = layers.rope(q, positions, theta)
        k = layers.rope(k, positions, theta)
    q = shard(q, ("batch", "seq", "heads", None))
    k = shard(k, ("batch", "seq", "kv_heads", None))
    v = shard(v, ("batch", "seq", "kv_heads", None))
    return q, k, v


def flash_attention(q, k, v, qpos, kpos, *, causal=True, window=0,
                    q_chunk=512, kv_chunk=1024):
    """Memory-bounded attention.  q: [B,S,H,D], k/v: [B,T,KVH,D].
    Returns [B,S,H,D] in q.dtype.

    Perf-iteration notes (EXPERIMENTS.md §Perf, dense-train cells):
      * chunks are sliced out of the NATURAL [B,S,...] layout inside the
        scan (dynamic_slice) — the previous pre-transposed chunk stacking
        materialized two full [B,S,KVH,D]-sized layout copies per layer;
      * all dots keep bf16 operands with f32 accumulation
        (``preferred_element_type``) — no f32 copies of q/k/v;
      * probabilities are cast to the value dtype for the PV matmul
        (halves the second dot's input traffic; standard TPU flash);
      * einsum orders are dot_general-natural ([b,h,q,g,k]) so no
        transpose fusions appear between the mask/exp chain and the dots.
    """
    b, s_len, nq, d = q.shape
    t_len, nkv = k.shape[1], k.shape[2]
    g = nq // nkv
    scale = 1.0 / (d ** 0.5)

    q_chunk = min(q_chunk, s_len)
    kv_chunk = min(kv_chunk, t_len)
    nqc = -(-s_len // q_chunk)
    nkc = -(-t_len // kv_chunk)

    def pad_to(x, axis, size):
        pad = size - x.shape[axis]
        if pad == 0:
            return x
        widths = [(0, 0)] * x.ndim
        widths[axis] = (0, pad)
        return jnp.pad(x, widths)

    qg = pad_to(q.reshape(b, s_len, nkv, g, d), 1, nqc * q_chunk)
    qpos_p = pad_to(qpos, 1, nqc * q_chunk)
    kp = pad_to(k, 1, nkc * kv_chunk)
    vp = pad_to(v, 1, nkc * kv_chunk)
    kpos_p = pad_to(kpos + 1, 1, nkc * kv_chunk) - 1   # pad -> pos -1

    w = jnp.asarray(window, jnp.int32)   # traced per-layer window; 0 = full

    def q_step(_, i):
        qi = jax.lax.dynamic_slice_in_dim(qg, i * q_chunk, q_chunk, 1)
        qp = jax.lax.dynamic_slice_in_dim(qpos_p, i * q_chunk, q_chunk, 1)
        dq = qp[:, None, :, None, None]                 # [B,1,qc,1,1]

        def kv_step(carry, j):
            m, l, acc = carry
            kj = jax.lax.dynamic_slice_in_dim(kp, j * kv_chunk, kv_chunk, 1)
            vj = jax.lax.dynamic_slice_in_dim(vp, j * kv_chunk, kv_chunk, 1)
            kpj = jax.lax.dynamic_slice_in_dim(kpos_p, j * kv_chunk,
                                               kv_chunk, 1)
            # scores [B,KVH,qc,G,kc]: bf16 dot, f32 accumulate
            s = jnp.einsum("bqhgd,bkhd->bhqgk", qi, kj,
                           preferred_element_type=jnp.float32) * scale
            dk = kpj[:, None, None, None, :]            # [B,1,1,1,kc]
            mask = dk >= 0
            if causal:
                mask = mask & (dk <= dq)
            mask = mask & ((w <= 0) | (dk > dq - w))
            s = jnp.where(mask, s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            # all-masked guard: keep exp() arguments at -inf, not nan
            safe = jnp.where(m_new <= NEG_INF / 2, 0.0, m_new)
            p = jnp.exp(s - safe[..., None])            # [B,KVH,qc,G,kc]
            corr = jnp.exp(m - safe)
            l_new = l * corr + p.sum(axis=-1)
            pv = jnp.einsum("bhqgk,bkhd->bhqgd", p.astype(vj.dtype), vj,
                            preferred_element_type=jnp.float32)
            acc_new = acc * corr[..., None] + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, nkv, q_chunk, g), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, nkv, q_chunk, g), jnp.float32)
        a0 = jnp.zeros((b, nkv, q_chunk, g, d), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0),
                                      jnp.arange(nkc))
        out = acc / jnp.maximum(l[..., None], 1e-30)    # [B,KVH,qc,G,D]
        return None, out.astype(q.dtype)

    _, outs = jax.lax.scan(q_step, None, jnp.arange(nqc))
    # [nqc,B,KVH,qc,G,D] -> [B,S,H,D] (single layout fix-up at the end)
    out = outs.transpose(1, 0, 3, 2, 4, 5).reshape(
        b, nqc * q_chunk, nq, d)[:, :s_len]
    return out


def self_attention(p, cfg, x, positions, *, causal=True, window=0,
                   theta=None, return_kv=False):
    """Full self-attention sub-layer (projections + flash + output).

    §Perf note: checkpointing the flash core (it-1b) was REFUTED — with
    per-block remat already on, recompute-in-backward at the HLO level
    only adds another pass over the score tensors.  Score traffic is
    irreducible without kernel fusion; see kernels/flash_attention.py."""
    b, s, _ = x.shape
    theta = cfg.rope_theta if theta is None else theta
    q, k, v = _project_qkv(p, cfg, x, positions, theta)
    out = flash_attention(q, k, v, positions, positions, causal=causal,
                          window=window)
    out = out.reshape(b, s, cfg.n_heads * cfg.head_dim)
    out = layers.linear(out, p["wo"]["w"])
    if return_kv:
        return out, k, v
    return out


def cross_attention(p, cfg, x, memory, positions):
    """Decoder→encoder / text→vision cross-attention (no mask, no rope on
    memory side beyond its own precomputed embedding)."""
    b, s, _ = x.shape
    t = memory.shape[1]
    hd, nq, nkv = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    q = layers.linear(x, p["wq"]["w"], p["wq"].get("b")).reshape(b, s, nq, hd)
    k = layers.linear(memory, p["wk"]["w"],
                      p["wk"].get("b")).reshape(b, t, nkv, hd)
    v = layers.linear(memory, p["wv"]["w"],
                      p["wv"].get("b")).reshape(b, t, nkv, hd)
    if cfg.qk_norm:
        q = layers.rms_norm(q, p["q_norm"]["scale"], cfg.norm_eps)
        k = layers.rms_norm(k, p["k_norm"]["scale"], cfg.norm_eps)
    mpos = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32)[None], (b, t))
    out = flash_attention(q, k, v, positions, mpos, causal=False)
    out = out.reshape(b, s, nq * hd)
    return layers.linear(out, p["wo"]["w"])


# --------------------------------------------------------------------------
# KV cache (decode)
# --------------------------------------------------------------------------

def init_kv_cache(cfg, batch, max_len, n_layers=None, dtype=jnp.bfloat16):
    """[L, B, T, KVH, D] stacked cache (+ current length)."""
    nl = n_layers if n_layers is not None else cfg.n_layers
    shape = (nl, batch, max_len, cfg.n_kv_heads, cfg.head_dim)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
        "length": jnp.zeros((), jnp.int32),
    }


def decode_attention(p, cfg, x, layer_k, layer_v, length, *, window=0,
                     theta=None):
    """One-token self-attention against the cache.

    x: [B, 1, d]; layer_k/v: [B, T, KVH, D] (already containing this step's
    k/v at index `length`); returns [B, 1, d].
    """
    b = x.shape[0]
    t = layer_k.shape[1]
    hd, nq, nkv = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    g = nq // nkv
    theta = cfg.rope_theta if theta is None else theta

    pos = jnp.broadcast_to(length[None, None], (b, 1))
    q = layers.linear(x, p["wq"]["w"], p["wq"].get("b")).reshape(b, 1, nq, hd)
    if cfg.qk_norm:
        q = layers.rms_norm(q, p["q_norm"]["scale"], cfg.norm_eps)
    if theta is not None:
        q = layers.rope(q, pos, theta)
    qg = q.reshape(b, 1, nkv, g, hd)

    # bf16 operands, f32 accumulation: no f32 copy of the cache (the
    # baseline's operand upcasts made XLA hoist TWO full f32 cache-stack
    # conversions out of the layer loop — EXPERIMENTS.md §Perf decode)
    s = jnp.einsum("bqkgd,btkd->bkgqt", qg, layer_k.astype(qg.dtype),
                   preferred_element_type=jnp.float32) / (hd ** 0.5)
    kpos = jnp.arange(t, dtype=jnp.int32)[None, None, None, None, :]
    mask = kpos <= length
    w = jnp.asarray(window, jnp.int32)   # traced per-layer window; 0 = full
    mask = mask & ((w <= 0) | (kpos > length - w))
    s = jnp.where(mask, s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqt,btkd->bqkgd", w.astype(layer_v.dtype),
                     layer_v, preferred_element_type=jnp.float32)
    out = out.reshape(b, 1, nq * hd).astype(x.dtype)
    return layers.linear(out, p["wo"]["w"])


def project_kv_token(p, cfg, x, length, *, theta=None):
    """This step's k/v [B,1,KVH,D] WITHOUT writing the cache (§Perf
    decode-it-3: the scan emits these tiny tensors as ys and the caller
    does ONE in-place update on the stacked cache, instead of rewriting a
    full [B,T,KVH,D] buffer per layer)."""
    b = x.shape[0]
    hd, nkv = cfg.head_dim, cfg.n_kv_heads
    theta = cfg.rope_theta if theta is None else theta
    pos = jnp.broadcast_to(length[None, None], (b, 1))
    k = layers.linear(x, p["wk"]["w"], p["wk"].get("b")).reshape(b, 1, nkv, hd)
    v = layers.linear(x, p["wv"]["w"], p["wv"].get("b")).reshape(b, 1, nkv, hd)
    if cfg.qk_norm:
        k = layers.rms_norm(k, p["k_norm"]["scale"], cfg.norm_eps)
    if theta is not None:
        k = layers.rope(k, pos, theta)
    return k, v


def decode_attention_append(p, cfg, x, layer_k, layer_v, k_new, v_new,
                            length, *, window=0, theta=None):
    """One-token attention: cache scores (positions < length) + the new
    token's self-score computed separately — the cache tensors are READ
    ONLY (no per-layer write-back)."""
    b = x.shape[0]
    t = layer_k.shape[1]
    hd, nq, nkv = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    g = nq // nkv
    theta = cfg.rope_theta if theta is None else theta

    pos = jnp.broadcast_to(length[None, None], (b, 1))
    q = layers.linear(x, p["wq"]["w"], p["wq"].get("b")).reshape(b, 1, nq, hd)
    if cfg.qk_norm:
        q = layers.rms_norm(q, p["q_norm"]["scale"], cfg.norm_eps)
    if theta is not None:
        q = layers.rope(q, pos, theta)
    qg = q.reshape(b, 1, nkv, g, hd)

    s = jnp.einsum("bqkgd,btkd->bkgqt", qg, layer_k.astype(qg.dtype),
                   preferred_element_type=jnp.float32) / (hd ** 0.5)
    kpos = jnp.arange(t, dtype=jnp.int32)[None, None, None, None, :]
    mask = kpos < length                       # strictly-past cache slots
    w = jnp.asarray(window, jnp.int32)
    mask = mask & ((w <= 0) | (kpos > length - w))
    s = jnp.where(mask, s, NEG_INF)
    s_new = jnp.einsum("bqkgd,btkd->bkgqt", qg, k_new.astype(qg.dtype),
                       preferred_element_type=jnp.float32) / (hd ** 0.5)
    sc = jnp.concatenate([s, s_new], axis=-1)  # [B,KVH,G,1,T+1]
    wts = jax.nn.softmax(sc, axis=-1)
    out = jnp.einsum("bkgqt,btkd->bqkgd", wts[..., :t].astype(layer_v.dtype),
                     layer_v, preferred_element_type=jnp.float32) \
        + jnp.einsum("bkgqt,btkd->bqkgd", wts[..., t:].astype(v_new.dtype),
                     v_new, preferred_element_type=jnp.float32)
    out = out.reshape(b, 1, nq * hd).astype(x.dtype)
    return layers.linear(out, p["wo"]["w"])


def write_kv_stack(cache_k, cache_v, ks, vs, length):
    """One in-place update of the stacked [L,B,T,KVH,D] cache at position
    `length` with the scan-collected per-layer k/v [L,B,1,KVH,D]."""
    new_k = jax.lax.dynamic_update_slice(
        cache_k, ks.astype(cache_k.dtype),
        (0, 0, length, 0, 0))
    new_v = jax.lax.dynamic_update_slice(
        cache_v, vs.astype(cache_v.dtype),
        (0, 0, length, 0, 0))
    return new_k, new_v


def append_kv(p, cfg, x, layer_k, layer_v, length, *, theta=None):
    """Project this step's k/v and write them at `length`; returns updated
    (k, v) buffers."""
    b = x.shape[0]
    hd, nkv = cfg.head_dim, cfg.n_kv_heads
    theta = cfg.rope_theta if theta is None else theta
    pos = jnp.broadcast_to(length[None, None], (b, 1))
    k = layers.linear(x, p["wk"]["w"], p["wk"].get("b")).reshape(b, 1, nkv, hd)
    v = layers.linear(x, p["wv"]["w"], p["wv"].get("b")).reshape(b, 1, nkv, hd)
    if cfg.qk_norm:
        k = layers.rms_norm(k, p["k_norm"]["scale"], cfg.norm_eps)
    if theta is not None:
        k = layers.rope(k, pos, theta)
    layer_k = jax.lax.dynamic_update_slice_in_dim(
        layer_k, k.astype(layer_k.dtype), length, axis=1)
    layer_v = jax.lax.dynamic_update_slice_in_dim(
        layer_v, v.astype(layer_v.dtype), length, axis=1)
    return layer_k, layer_v
