"""Opt-in shadow of ``execute_plan``'s refcounting buffer arena.

The executor overlaps aggressively: ``stage_ready`` dispatches stage 1 of
later binary steps the moment their inputs are live and releases those
inputs *at capture time*, long before the step's total is synced.  The
refcount bookkeeping that makes this safe ("drop each ``%i<k>`` exactly
when its last consumer has captured it") is easy to break when the
dispatch order changes — and the failure mode is not a crash but a
KeyError three steps later, or a buffer silently held for the whole walk.

This module is a shadow arena that recomputes the expected consumer count
per environment name independently from the plan, then audits every
release/drop/produce event the executor emits:

* a release past zero is a **double release**;
* a drop (eviction from the environment) while consumers remain is a
  **release-before-last-consumer** — a later step would read a dead
  buffer;
* a ``%``-named buffer still resident at the end of the walk (without
  ``keep_intermediates``), or expected consumers that never arrived, is a
  **leak** / lost consumer.

Enablement is opt-in because the hooks sit on the executor's hot loop:
set ``REPRO_SANITIZE_ARENA=1`` (the CI pytest matrix does), or wrap a
block in :func:`enabled` — ``with arena_sanitizer.enabled(): ...``.
Violations raise :class:`ArenaSanitizerError` (a ``RuntimeError``: these
are executor bugs, not plan validation failures).

:func:`check_residents` is the streaming-side audit: a standing query's
resident intermediates must be exactly the plan's materialized outs.
"""

from __future__ import annotations

import contextlib
import os

_FORCED = 0      # nesting depth of enabled() context managers


def active() -> bool:
    """True when the sanitizer should shadow the next plan walk."""
    return _FORCED > 0 or os.environ.get("REPRO_SANITIZE_ARENA", "") not in (
        "", "0")


@contextlib.contextmanager
def enabled():
    """Force the sanitizer on for a block, regardless of the env var."""
    global _FORCED
    _FORCED += 1
    try:
        yield
    finally:
        _FORCED -= 1


class ArenaSanitizerError(RuntimeError):
    """The executor's arena bookkeeping diverged from the plan."""


class ArenaShadow:
    """Shadow arena for one ``execute_plan`` walk.  The executor calls
    ``on_release`` / ``on_drop`` / ``on_produce`` as events happen and
    ``finish`` before returning."""

    def __init__(self, plan, relations, keep_intermediates: bool):
        self._keep = keep_intermediates
        # independent recomputation of the executor's `readers` map
        self._left: dict[str, int] = {}
        for step in plan.steps:
            for name in step.inputs:
                self._left[name] = self._left.get(name, 0) + 1
        self._produced: set[str] = set()
        self._base: set[str] = set(relations)
        self._dropped: set[str] = set()

    def on_produce(self, name: str) -> None:
        if name in self._produced:
            raise ArenaSanitizerError(
                f"arena shadow: {name!r} produced twice — a step "
                "overwrote a live intermediate")
        if name in self._dropped:
            raise ArenaSanitizerError(
                f"arena shadow: {name!r} produced after it was dropped")
        self._produced.add(name)

    def on_release(self, name: str) -> None:
        left = self._left.get(name)
        if left is None:
            raise ArenaSanitizerError(
                f"arena shadow: release of {name!r}, which no step "
                "consumes")
        if left <= 0:
            raise ArenaSanitizerError(
                f"arena shadow: double release of {name!r} — every "
                "consumer already released it")
        self._left[name] = left - 1

    def on_drop(self, name: str) -> None:
        """The executor evicted ``name`` from the environment."""
        if self._left.get(name, 0) > 0:
            raise ArenaSanitizerError(
                f"arena shadow: {name!r} dropped while "
                f"{self._left[name]} consumer(s) have not captured it — "
                "release-before-last-consumer")
        if self._keep and name.startswith("%"):
            raise ArenaSanitizerError(
                f"arena shadow: {name!r} dropped under "
                "keep_intermediates=True — standing queries need it "
                "resident")
        self._dropped.add(name)

    def finish(self, env) -> None:
        pending = {n: c for n, c in self._left.items() if c > 0}
        if pending:
            raise ArenaSanitizerError(
                "arena shadow: walk finished with unconsumed inputs "
                f"{sorted(pending)} — a consumer never released them")
        if not self._keep:
            leaked = sorted(n for n in env
                            if n.startswith("%") and n in self._produced)
            if leaked:
                raise ArenaSanitizerError(
                    f"arena shadow: intermediates {leaked} leaked — still "
                    "resident after their last consumer released them")


def begin(plan, relations, keep_intermediates: bool) -> ArenaShadow | None:
    """Start a shadow for one plan walk, or ``None`` when inactive."""
    if not active():
        return None
    return ArenaShadow(plan, relations, keep_intermediates)


def check_residents(plan, residents) -> None:
    """Streaming audit: a standing query's resident intermediates must be
    exactly the plan's materialized (non-aggregate binary) outs."""
    if not active():
        return
    expected = {s.out for s in plan.steps
                if s.op == "binary" and not s.aggregate}
    got = set(residents)
    missing = sorted(expected - got)
    extra = sorted(n for n in got - expected if n.startswith("%"))
    if missing or extra:
        raise ArenaSanitizerError(
            "arena shadow: standing-query residents diverge from the "
            f"plan: missing {missing}, unexpected {extra}")
