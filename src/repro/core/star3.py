"""Star 3-way join — paper §6.5: small dimension relations R(AB), T(CD)
pinned on-chip, large fact relation S(BC) streamed through once.

One level of hashing on both join columns: the PMU at grid position
(h(b), g(c)) holds the R bucket h(b) and the T bucket g(c); each streamed
s(b,c) tuple is routed to exactly that one PMU (hash-pair routing), where the
inner join happens.  For the 3-way variant hg = U constrains the bucket
counts (the paper's noted restriction vs. h = g = U for binary joins).

Cost: |R| + |T| + |S| — every tuple is read exactly once (this is why the
star case is the best case for the 3-way plan: 11× over cascaded binary in
the paper's Fig 4(h,i)).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import partition
from repro.core.relation import Relation
from repro.kernels import ops as kops


class Star3Plan(NamedTuple):
    uh: int        # R-side grid rows, h(B)
    ug: int        # T-side grid cols, g(C)
    chunks: int    # S streaming chunks (arrival-order tiles)
    r_cap: int
    s_cap: int
    t_cap: int


class Star3Result(NamedTuple):
    count: jnp.ndarray
    overflowed: jnp.ndarray
    tuples_read: object      # int32 (scan) | engine.Traffic64 (fused)


def default_plan(n_r: int, n_s: int, n_t: int, *, uh: int = 8, ug: int = 8,
                 chunks: int = 1, slack: float = 2.5) -> Star3Plan:
    r_cap = partition.suggest_capacity(n_r, uh, slack)
    s_cap = partition.suggest_capacity(n_s, chunks * uh * ug, slack)
    t_cap = partition.suggest_capacity(n_t, ug, slack)
    return Star3Plan(uh, ug, chunks, r_cap, s_cap, t_cap)


def star3_count(r: Relation, s: Relation, t: Relation, plan: Star3Plan, *,
                use_kernel: bool = False, rb: str = "b", sb: str = "b",
                sc: str = "c", tc: str = "c") -> Star3Result:
    uh, ug, ch = plan.uh, plan.ug, plan.chunks

    # dimensions pinned on-chip: one level of hashing each
    rg = partition.bucketize(r, rb, uh, plan.r_cap, fn="h")
    tg = partition.bucketize(t, tc, ug, plan.t_cap, fn="g")
    # fact relation: streamed chunk × (h(B), g(C)) routing
    chunk_ids = jnp.where(
        s.valid,
        (jnp.arange(s.capacity, dtype=jnp.int32) * ch) // s.capacity, 0)
    hb = partition.bucket_ids_for(s, sb, uh, "h")
    gc = partition.bucket_ids_for(s, sc, ug, "g")
    flat = jnp.where(s.valid, (chunk_ids * uh + hb) * ug + gc,
                     jnp.int32(ch * uh * ug))
    sgrid = partition.bucketize_by_ids(s, flat, ch * uh * ug, plan.s_cap,
                                       (ch, uh, ug))

    rb_g = jnp.broadcast_to(rg.columns[rb][:, None], (uh, ug, plan.r_cap))
    rv_g = jnp.broadcast_to(rg.valid[:, None], (uh, ug, plan.r_cap))
    tc_g = jnp.broadcast_to(tg.columns[tc][None, :], (uh, ug, plan.t_cap))
    tv_g = jnp.broadcast_to(tg.valid[None, :], (uh, ug, plan.t_cap))

    def fl(x):
        return x.reshape((uh * ug,) + x.shape[2:])

    def chunk_step(acc, ys):
        sb_c, sc_c, sv_c = ys   # [uh, ug, s_cap]
        c = kops.bucket_count3_linear(fl(rb_g), fl(rv_g), fl(sb_c), fl(sc_c),
                                      fl(sv_c), fl(tc_g), fl(tv_g),
                                      use_kernel=use_kernel)
        return acc + jnp.sum(c), None

    total, _ = jax.lax.scan(chunk_step, jnp.int32(0),
                            (sgrid.columns[sb], sgrid.columns[sc], sgrid.valid))
    overflow = rg.overflowed | sgrid.overflowed | tg.overflowed
    tuples = r.n + s.n + t.n
    return Star3Result(total, overflow, tuples.astype(jnp.int32))
