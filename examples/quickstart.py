"""Quickstart: the multiway-join engine in five minutes.

    PYTHONPATH=src python examples/quickstart.py

Builds the paper's three join shapes on small relations, checks them
against a brute-force oracle, shows the planner's 3-way vs cascaded-binary
decision on the paper's own workloads (Examples 3/4), and runs one Pallas
kernel in interpret mode.
"""

import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent
                       / "src"))

import numpy as np  # noqa: E402
import jax  # noqa: E402

from repro.core import (cost_model, cyclic3, linear3, star3,  # noqa: E402
                        driver)
from repro.data.relations import RelGenConfig, gen_relation  # noqa: E402


def main():
    rng_n, d = 4000, 300
    r = gen_relation(RelGenConfig(n=rng_n, d=d, columns=("a", "b"), seed=1))
    s = gen_relation(RelGenConfig(n=rng_n, d=d, columns=("b", "c"), seed=2))
    t = gen_relation(RelGenConfig(n=rng_n, d=d, columns=("c", "d"), seed=3))

    # --- linear 3-way: R(AB) ⋈ S(BC) ⋈ T(CD), COUNT aggregated ---------
    plan = linear3.default_plan(rng_n, rng_n, rng_n, m_budget=1024)
    res, plan = driver.linear3_count_auto(r, s, t, plan)
    rb = np.asarray(r.col("b")); sb = np.asarray(s.col("b"))
    sc = np.asarray(s.col("c")); tc = np.asarray(t.col("c"))
    oracle = int(((rb[:, None] == sb[None, :]).sum(0).astype(np.int64)
                  * (sc[:, None] == tc[None, :]).sum(1)).sum())
    print(f"linear 3-way COUNT = {int(res.count)}  (oracle {oracle})  "
          f"tuples read on-chip = {int(res.tuples_read)}")
    assert int(res.count) == oracle

    # --- cyclic 3-way (triangles): R(AB) ⋈ S(BC) ⋈ T(CA) ---------------
    t_cyc = gen_relation(RelGenConfig(n=rng_n, d=d, columns=("c", "a"),
                                      seed=3))
    cplan = cyclic3.default_plan(rng_n, rng_n, rng_n, m_budget=2048)
    cres, _ = driver.cyclic3_count_auto(r, s, t_cyc, cplan)
    ra = np.asarray(r.col("a"))
    ta_c = np.asarray(t_cyc.col("c")); ta_a = np.asarray(t_cyc.col("a"))
    m1 = (sb[:, None] == rb[None, :]).astype(np.int64)
    m2 = (sc[:, None] == ta_c[None, :]).astype(np.int64)
    m3 = (ra[:, None] == ta_a[None, :]).astype(np.int64)
    tri = int(np.einsum("sr,st,rt->", m1, m2, m3, optimize=True))
    print(f"cyclic 3-way (triangle) COUNT = {int(cres.count)}  "
          f"(oracle {tri})")
    assert int(cres.count) == tri

    # --- star 3-way (fact S, dims R and T) -------------------------------
    splan = star3.default_plan(rng_n, rng_n, rng_n, m_budget=8192)
    sres, _ = driver.star3_count_auto(r, s, t, splan)
    print(f"star 3-way COUNT = {int(sres.count)}  (oracle {oracle})")
    assert int(sres.count) == oracle

    # --- the paper's planner decisions (Examples 3 and 4) ----------------
    m3_thresh = cost_model.example3_threshold_m()
    m4_thresh = cost_model.example4_threshold_m()
    print(f"\nExample 3 (Facebook linear self-join): 3-way wins iff "
          f"M > {m3_thresh:.3e} tuples (paper: 1.003e9)")
    print(f"Example 4 (cyclic/triangles): M threshold ≈ {m4_thresh:.2e} "
          "tuples (paper: ~7e6)")
    pick = cost_model.choose_linear_strategy(2e8, 2e8, 2e8, m=1e6, d=7e5)
    print(f"planner @ N=2e8,d=7e5,M=1e6: {pick.strategy} "
          f"(traffic ratio {pick.speed_ratio:.1f}x)")

    # --- one Pallas kernel, interpret mode ------------------------------
    from repro.kernels import ops as kops
    from repro.core import partition
    b = partition.bucketize(r, "b", 8, 1024, fn="h")
    p2 = partition.bucketize(s, "b", 8, 1024, fn="h")
    counts = kops.bucket_pair_count(b.columns["b"], b.valid,
                                    p2.columns["b"], p2.valid,
                                    use_kernel=True)
    print(f"\nPallas bucket_pair_count (interpret): "
          f"R⋈S pairs = {int(jax.numpy.sum(counts))}")
    print("\nquickstart OK")


if __name__ == "__main__":
    main()
