"""Public jit'd wrappers around the Pallas kernels (with jnp fallback).

Responsibilities kept out of the kernels so they stay branch-free:
  * sentinel-mask invalid slots with per-side sentinels (so invalid slots can
    never equal anything on the other side),
  * pad capacities to 128-lane multiples (MXU/VPU alignment),
  * dispatch kernel vs. pure-jnp reference (``use_kernel=False`` is the CPU
    default — interpret-mode Pallas is for validation, not speed),
  * cast/clip results back to caller shapes.

Keys must be > SENT_BASE (= -2^31 + 16); the data generators and the
relational layer guarantee int32 keys ≥ -2^30.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import bucket_join, radix_hist, ref

SENT_BASE = -0x7FFFFFF0
_SENT = {"r": SENT_BASE + 1, "s": SENT_BASE + 2, "t": SENT_BASE + 3,
         "a": SENT_BASE + 4, "b": SENT_BASE + 5}


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _mask(keys: jnp.ndarray, valid: jnp.ndarray, side: str) -> jnp.ndarray:
    return jnp.where(valid, keys, jnp.int32(_SENT[side]))


def _pad_lanes(x: jnp.ndarray, side: str, align: int = 128) -> jnp.ndarray:
    c = x.shape[-1]
    rem = (-c) % align
    if rem == 0:
        return x
    pad = [(0, 0)] * (x.ndim - 1) + [(0, rem)]
    return jnp.pad(x, pad, constant_values=_SENT[side])


def bucket_pair_count(ka, va, kb, vb, *, use_kernel: bool = False):
    ka = _mask(ka, va, "a")
    kb = _mask(kb, vb, "b")
    if use_kernel:
        return bucket_join.pair_count(_pad_lanes(ka, "a"), _pad_lanes(kb, "b"),
                                      interpret=_interpret())
    return ref.bucket_pair_count(ka, kb)


def bucket_count3_linear(rb, rv, sb, sc, sv, tc, tv, *,
                         use_kernel: bool = False):
    rb = _mask(rb, rv, "r")
    sb = _mask(sb, sv, "s")
    sc = _mask(sc, sv, "s")
    tc = _mask(tc, tv, "t")
    if use_kernel:
        return bucket_join.count3_linear(
            _pad_lanes(rb, "r"), _pad_lanes(sb, "s"), _pad_lanes(sc, "s"),
            _pad_lanes(tc, "t"), interpret=_interpret())
    return ref.bucket_count3_linear(rb, sb, sc, tc)


def bucket_per_r_counts(rb, rv, sb, sc, sv, tc, tv, *,
                        use_kernel: bool = False):
    cr = rb.shape[-1]
    rb = _mask(rb, rv, "r")
    sb = _mask(sb, sv, "s")
    sc = _mask(sc, sv, "s")
    tc = _mask(tc, tv, "t")
    if use_kernel:
        out = bucket_join.per_r_counts(
            _pad_lanes(rb, "r"), _pad_lanes(sb, "s"), _pad_lanes(sc, "s"),
            _pad_lanes(tc, "t"), interpret=_interpret())
        return out[:, :cr]
    return ref.bucket_per_r_counts(rb, sb, sc, tc)


def bucket_count3_cyclic(ra, rb, rv, sb, sc, sv, tc, ta, tv, *,
                         use_kernel: bool = False):
    ra = _mask(ra, rv, "r")
    rb = _mask(rb, rv, "r")
    sb = _mask(sb, sv, "s")
    sc = _mask(sc, sv, "s")
    tc = _mask(tc, tv, "t")
    ta = _mask(ta, tv, "t")
    if use_kernel:
        return bucket_join.count3_cyclic(
            _pad_lanes(ra, "r"), _pad_lanes(rb, "r"), _pad_lanes(sb, "s"),
            _pad_lanes(sc, "s"), _pad_lanes(tc, "t"), _pad_lanes(ta, "t"),
            interpret=_interpret())
    return ref.bucket_count3_cyclic(ra, rb, sb, sc, tc, ta)


@functools.partial(jax.jit, static_argnames=("n_buckets", "use_kernel"))
def radix_histogram(keys, valid, *, n_buckets: int, use_kernel: bool = False):
    """Histogram of hash_bucket(keys) over live rows."""
    from repro.core import hashing

    if use_kernel:
        # pad the stream to the tile size with a sentinel whose bucket we
        # compute and subtract afterwards.
        tile = 1024
        n = keys.shape[0]
        padded = jnp.where(valid, keys, jnp.int32(_SENT["s"]))
        rem = (-n) % tile
        if rem:
            padded = jnp.pad(padded, (0, rem), constant_values=_SENT["s"])
        hist = radix_hist.radix_histogram(padded, n_buckets=n_buckets,
                                          interpret=_interpret())
        n_invalid = (padded.shape[0] - jnp.sum(valid)).astype(jnp.int32)
        sent_bucket = hashing.hash_bucket(
            jnp.full((1,), _SENT["s"], jnp.int32), n_buckets, "H")[0]
        return hist.at[sent_bucket].add(-n_invalid)
    ids = jnp.where(valid, hashing.hash_bucket(keys, n_buckets, "H"),
                    jnp.int32(n_buckets))
    return ref.radix_histogram(keys, ids, n_buckets)


def fm_registers(ra, rv, rb, sb, sc, sv, tc, td, tv, *, n_registers: int = 32,
                 use_kernel: bool = False):
    """FM sketch registers over implicit joined (a, d) pairs (ref path only;
    the matmul inside dominates and is already MXU-shaped under jit)."""
    del use_kernel
    ra = _mask(ra, rv, "r")
    rb = _mask(rb, rv, "r")
    sb = _mask(sb, sv, "s")
    sc = _mask(sc, sv, "s")
    tc = _mask(tc, tv, "t")
    td = _mask(td, tv, "t")
    return ref.fm_registers(ra, rb, sb, sc, tc, td, n_registers)
