"""Linear 3-way join  R(AB) ⋈ S(BC) ⋈ T(CD)  — paper §4, Algorithm 1.

Partitioning scheme (Fig 2):
  * coarse ``H(B)`` → `h_parts` partitions of R and S; one R partition is
    sized to fit on-chip memory (here: one scan step's working set),
  * fine ``h(B)`` → `u` PMU buckets within a partition (here: the Pallas
    kernel's bucket grid),
  * fine ``g(C)`` → `g_parts` streaming buckets of S and T; the T bucket with
    the same g(C) is *broadcast to every PMU* (Algorithm 1 line 15).

Execution = scan over H(B) partitions, inner scan over g(C) buckets; inside a
step the bucket-triple join runs on the `u`-way grid (kernels/bucket_join).
The scan carry holds only the running aggregate — S and T buckets are
discarded after each step (Algorithm 1 lines 17, 20) and R's partition lives
exactly one outer iteration (the paper's "R partition pinned on-chip").

Cost (tuples touched): |R| + |S| + h_parts·|T|  ==  |R| + |S| + |R||T|/M.
``tuples_read`` on the result reports the realized value for validation
against ``cost_model``.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import partition
from repro.core.relation import Relation
from repro.kernels import ops as kops


class Linear3Plan(NamedTuple):
    h_parts: int   # coarse H(B) partitions of R and S
    u: int         # PMU buckets per partition, h(B)
    g_parts: int   # streaming g(C) buckets of S and T
    r_cap: int     # per-(H,h) bucket capacity for R
    s_cap: int     # per-(H,g,h) bucket capacity for S
    t_cap: int     # per-g bucket capacity for T


class Linear3Result(NamedTuple):
    count: jnp.ndarray           # () int32 total join cardinality
    overflowed: jnp.ndarray      # () bool — any bucket overflow (skew signal)
    tuples_read: object          # tuples streamed on-chip (cost metric):
    #   () int32 on the scan driver, engine.Traffic64 (int64-exact limb
    #   pair, int() to read) on the fused path — h_parts * |T| wraps int32


def default_plan(n_r: int, n_s: int, n_t: int, *, m_budget: int,
                 u: int = 64, g_parts: int | None = None,
                 slack: float = 2.5) -> Linear3Plan:
    """Size partition counts from the paper's rules: h_parts = ceil(|R|/M) so
    one R partition fits the memory budget; g_parts so a T bucket does."""
    import math

    h_parts = max(1, math.ceil(n_r / m_budget))
    if g_parts is None:
        g_parts = max(1, math.ceil(n_t / m_budget))
    r_cap = partition.suggest_capacity(n_r, h_parts * u, slack)
    s_cap = partition.suggest_capacity(n_s, h_parts * g_parts * u, slack)
    t_cap = partition.suggest_capacity(n_t, g_parts, slack)
    return Linear3Plan(h_parts, u, g_parts, r_cap, s_cap, t_cap)


def _layouts(r, s, t, plan, rb, sb, sc, tc):
    """The Fig 2 data reorganization: R → [hp,u,cap], S → [hp,gp,u,cap],
    T → [gp,cap]."""
    hp, u, gp = plan.h_parts, plan.u, plan.g_parts
    r_ids, r_nb = partition.composite_ids(r, [(rb, hp, "H"), (rb, u, "h")])
    rg = partition.bucketize_by_ids(r, r_ids, r_nb, plan.r_cap, (hp, u))
    s_ids, s_nb = partition.composite_ids(
        s, [(sb, hp, "H"), (sc, gp, "g"), (sb, u, "h")])
    sg = partition.bucketize_by_ids(s, s_ids, s_nb, plan.s_cap, (hp, gp, u))
    tg = partition.bucketize(t, tc, gp, plan.t_cap, fn="g")
    return rg, sg, tg


def linear3_count(r: Relation, s: Relation, t: Relation,
                  plan: Linear3Plan, *, use_kernel: bool = False,
                  rb: str = "b", sb: str = "b", sc: str = "c",
                  tc: str = "c") -> Linear3Result:
    """COUNT of the linear 3-way join per Algorithm 1."""
    u = plan.u
    rg, sg, tg = _layouts(r, s, t, plan, rb, sb, sc, tc)
    tc_g, tv_g = tg.columns[tc], tg.valid     # [gp, t_cap]

    def h_step(total, xs):
        ri, rvi, sbi, sci, svi = xs           # one H(B) partition

        def g_step(acc, ys):
            sb_j, sc_j, sv_j, tc_j, tv_j = ys
            # broadcast T_j to every PMU bucket (Algorithm 1 line 15)
            tcb = jnp.broadcast_to(tc_j[None, :], (u,) + tc_j.shape)
            tvb = jnp.broadcast_to(tv_j[None, :], (u,) + tv_j.shape)
            c = kops.bucket_count3_linear(ri, rvi, sb_j, sc_j, sv_j, tcb, tvb,
                                          use_kernel=use_kernel)
            return acc + jnp.sum(c), None

        acc, _ = jax.lax.scan(g_step, jnp.int32(0),
                              (sbi, sci, svi, tc_g, tv_g))
        return total + acc, None

    total, _ = jax.lax.scan(
        h_step, jnp.int32(0),
        (rg.columns[rb], rg.valid, sg.columns[sb], sg.columns[sc], sg.valid))
    overflow = rg.overflowed | sg.overflowed | tg.overflowed
    tuples = r.n + s.n + plan.h_parts * t.n
    return Linear3Result(total, overflow, tuples.astype(jnp.int32))


def linear3_per_r_counts(r: Relation, s: Relation, t: Relation,
                         plan: Linear3Plan, *, use_kernel: bool = False,
                         rb: str = "b", sb: str = "b", sc: str = "c",
                         tc: str = "c", key_col: str = "a"):
    """Per-R-tuple counts (Example 1: friends-of-friends-of-friends per user).

    Returns (keys [hp,u,r_cap], counts [hp,u,r_cap], valid, overflowed):
    counts aligned with the bucketized R layout so callers can group-by the
    carried key column.
    """
    u = plan.u
    rg, sg, tg = _layouts(r, s, t, plan, rb, sb, sc, tc)
    tc_g, tv_g = tg.columns[tc], tg.valid

    def h_step(_, xs):
        ri, rvi, sbi, sci, svi = xs

        def g_step(acc, ys):
            sb_j, sc_j, sv_j, tc_j, tv_j = ys
            tcb = jnp.broadcast_to(tc_j[None, :], (u,) + tc_j.shape)
            tvb = jnp.broadcast_to(tv_j[None, :], (u,) + tv_j.shape)
            c = kops.bucket_per_r_counts(ri, rvi, sb_j, sc_j, sv_j, tcb, tvb,
                                         use_kernel=use_kernel)
            return acc + c, None

        acc, _ = jax.lax.scan(g_step, jnp.zeros(ri.shape, jnp.int32),
                              (sbi, sci, svi, tc_g, tv_g))
        return None, acc

    _, counts = jax.lax.scan(
        h_step, None,
        (rg.columns[rb], rg.valid, sg.columns[sb], sg.columns[sc], sg.valid))
    overflow = rg.overflowed | sg.overflowed | tg.overflowed
    key = key_col if key_col in rg.columns else rb
    return rg.columns[key], counts, rg.valid, overflow


def linear3_fm_distinct(r: Relation, s: Relation, t: Relation,
                        plan: Linear3Plan, *, n_registers: int = 32,
                        rb: str = "b", sb: str = "b", sc: str = "c",
                        tc: str = "c", ra_col: str = "a", td_col: str = "d"):
    """Flajolet–Martin estimate of |distinct (a, d)| over the join output,
    folded on the fly (Example 1's aggregation) — never materializes joins.

    Returns (registers [n_registers], overflowed).  Combine across shards
    with elementwise max; estimate via sketches.fm_estimate.
    """
    u = plan.u
    rg, sg, tg = _layouts(r, s, t, plan, rb, sb, sc, tc)
    tc_g, tv_g = tg.columns[tc], tg.valid
    td_g = tg.columns[td_col]

    def h_step(regs, xs):
        ri_a, ri_b, rvi, sbi, sci, svi = xs

        def g_step(acc, ys):
            sb_j, sc_j, sv_j, tc_j, tv_j, td_j = ys
            tcb = jnp.broadcast_to(tc_j[None, :], (u,) + tc_j.shape)
            tvb = jnp.broadcast_to(tv_j[None, :], (u,) + tv_j.shape)
            tdb = jnp.broadcast_to(td_j[None, :], (u,) + td_j.shape)
            regs_b = kops.fm_registers(ri_a, rvi, ri_b, sb_j, sc_j, sv_j,
                                       tcb, tdb, tvb, n_registers=n_registers)
            merged = jax.lax.reduce(regs_b, jnp.int32(0), jax.lax.bitwise_or,
                                    (0,))
            return acc | merged, None

        acc, _ = jax.lax.scan(g_step, regs,
                              (sbi, sci, svi, tc_g, tv_g, td_g))
        return acc, None

    regs0 = jnp.zeros((n_registers,), jnp.int32)
    regs, _ = jax.lax.scan(
        h_step, regs0,
        (rg.columns[ra_col], rg.columns[rb], rg.valid,
         sg.columns[sb], sg.columns[sc], sg.valid))
    overflow = rg.overflowed | sg.overflowed | tg.overflowed
    return regs, overflow
