"""Divisibility-aware logical-axis sharding (MaxText-style rules).

Model code annotates tensors with *logical* axis names
(``shard(x, ("batch", "seq", "embed"))``); the rules map logical names to
mesh axes; a rule is dropped per-tensor when the dimension is not divisible
by the mesh-axis size (e.g. yi-34b's 56 query heads on a 16-way "model"
axis), in which case XLA's SPMD partitioner inserts the reshard at the
nearest divisible boundary instead of us forcing a bad constraint.

The mesh context is process-global and set by the launcher (or a test); all
model code degrades to no-ops without one, so single-device smoke tests see
plain jnp.
"""

from __future__ import annotations

import dataclasses
from typing import Mapping, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axis -> mesh axes (tried in order; tuple entries shard together)
DEFAULT_RULES: dict[str, tuple[str, ...]] = {
    # activations
    "batch": ("pod", "data"),
    "seq": (),                 # sequence kept unsharded by default
    "seq_res": (),             # residual-stream seq dim; launcher remaps to
                               # ("model",) for Megatron-style seq parallelism
    "seq_sp": ("model",),      # sequence-parallel variant (long-context)
    "embed": (),               # activation d_model unsharded
    "heads": ("model",),
    "kv_heads": ("model",),
    "head_dim": (),
    "vocab": ("model",),
    "mlp": ("model",),
    "experts": ("model",),
    "kv_seq": ("model",),      # sequence-sharded KV cache (decode SP)
    # parameters (2-D sharded: TP on one dim, FSDP on the other)
    "p_embed": ("data",),      # FSDP axis for weights' d_model dim
    "p_vocab": ("model",),
    "p_mlp": ("model",),
    "p_heads": ("model",),
    "p_experts": ("model",),
    "p_state": (),
}


@dataclasses.dataclass(frozen=True)
class MeshContext:
    mesh: Mesh
    rules: Mapping[str, tuple[str, ...]]

    def axis_size(self, names: tuple[str, ...]) -> int:
        n = 1
        for a in names:
            n *= self.mesh.shape[a]
        return n


_CTX: list[MeshContext | None] = [None]
_MANUAL: list[bool] = [False]


class manual_mode:
    """Context manager: inside shard_map bodies, mesh axes are manual and
    with_sharding_constraint is illegal — `shard()` becomes a no-op."""

    def __enter__(self):
        self._old = _MANUAL[0]
        _MANUAL[0] = True

    def __exit__(self, *exc):
        _MANUAL[0] = self._old
        return False


def set_context(mesh: Mesh | None,
                rules: Mapping[str, tuple[str, ...]] | None = None) -> None:
    _CTX[0] = None if mesh is None else MeshContext(
        mesh, dict(rules or DEFAULT_RULES))


def current_context() -> MeshContext | None:
    return _CTX[0]


def spec_for(shape: Sequence[int], logical: Sequence[str | None],
             ctx: MeshContext) -> P:
    """PartitionSpec from logical axes, dropping non-divisible rules."""
    assert len(shape) == len(logical), (shape, logical)
    parts = []
    used: set[str] = set()
    for dim, name in zip(shape, logical):
        if name is None:
            parts.append(None)
            continue
        axes = tuple(a for a in ctx.rules.get(name, ())
                     if a in ctx.mesh.shape and a not in used)
        size = 1
        for a in axes:
            size *= ctx.mesh.shape[a]
        if not axes or size == 1 or dim % size != 0:
            # try a prefix that divides (e.g. ("pod","data") -> ("pod",))
            ok: tuple[str, ...] = ()
            acc = 1
            for a in axes:
                if dim % (acc * ctx.mesh.shape[a]) == 0:
                    acc *= ctx.mesh.shape[a]
                    ok = ok + (a,)
                else:
                    break
            axes = ok
        if not axes:
            parts.append(None)
        else:
            used.update(axes)
            parts.append(axes if len(axes) > 1 else axes[0])
    return P(*parts)


def sharding_for(shape: Sequence[int], logical: Sequence[str | None],
                 ctx: MeshContext | None = None) -> NamedSharding | None:
    ctx = ctx or current_context()
    if ctx is None:
        return None
    return NamedSharding(ctx.mesh, spec_for(shape, logical, ctx))


def shard(x: jax.Array, logical: Sequence[str | None]) -> jax.Array:
    """with_sharding_constraint by logical axes (no-op without a mesh or
    inside a shard_map body)."""
    ctx = current_context()
    if ctx is None or _MANUAL[0]:
        return x
    s = sharding_for(x.shape, logical, ctx)
    return jax.lax.with_sharding_constraint(x, s)
