"""Static verifier for ``core.plan_ir.QueryPlan`` DAGs.

``execute_plan`` trusts its input: a plan with steps out of topological
order, a projection that drops a column a later predicate reads, or a
per-R pin on a cyclic root would fail deep inside a kernel (or worse,
answer wrong).  :func:`verify_plan` checks the whole contract as pure
bookkeeping — no device work, microseconds per plan — and raises a typed
:class:`~repro.analysis.errors.PlanValidationError` naming the failing
step via its ``describe()``.

Checked invariants (one exception class per family):

  structure  — ops are known; the root (and only the root) aggregates to
               ``%count``; fused3 steps are aggregate roots; binary steps
               have 2 inputs + 1 predicate, fused3 have 3 inputs with a
               role permutation and kind-complete column bindings; every
               ``%i<k>`` is defined exactly once, before first use; every
               relation the caller names is read by some step
  schema     — projections and predicates only reference columns their
               (post-projection) inputs carry; destination columns never
               collide
  refcount   — every materialized intermediate has at least one consumer
               (mirrors the executor's refcounting arena: a consumer
               count of zero means the buffer would leak)
  per_r      — a ``per_r_key`` pin sits on the linear fused root and the
               key is a column of the role-r input

Two call modes:

* **Plan time** (``session.JoinSession._plan``, always on): ``schemas``
  maps each base relation to its column set, so schema propagation is
  checked end to end, and every ``%``-named input must be defined by an
  earlier step.
* **Execute time** (``REPRO_VERIFY_PLANS=1`` in ``execute_plan``):
  ``external`` is the execution environment's name set.  Streaming delta
  plans legitimately read resident ``%i<k>`` intermediates and ``%d·``
  delta relations straight from the environment, so any external name is
  an allowed input there.
"""

from __future__ import annotations

import re
from typing import Iterable, Mapping

from repro.analysis.errors import (PlanPerRError, PlanRefcountError,
                                   PlanSchemaError, PlanStructureError)
from repro.core import plan_ir

_INTERMEDIATE = re.compile(r"^%i\d+$")

# engine column kwarg -> fused role its column must live on, per kind
_KIND_COLS = {
    "linear": {"rb": "r", "sb": "s", "sc": "s", "tc": "t"},
    "star": {"rb": "r", "sb": "s", "sc": "s", "tc": "t"},
    "cyclic": {"ra": "r", "rb": "r", "sb": "s", "sc": "s",
               "tc": "t", "ta": "t"},
}


def _schema_of(step: plan_ir.PlanStep, in_schemas) -> frozenset | None:
    """Output schema of a binary materialize step: the destination columns
    of both projections, or the union of input schemas when a side is
    unprojected.  ``None`` when an unprojected side's schema is unknown."""
    proj_a, proj_b = step.project if step.project else ((), ())
    out: set[str] = set()
    for proj, schema, name in ((proj_a, in_schemas[0], step.inputs[0]),
                               (proj_b, in_schemas[1], step.inputs[1])):
        if proj:
            cols = [dst for _src, dst in proj]
        elif schema is not None:
            cols = sorted(schema)
        else:
            return None
        for c in cols:
            if c in out:
                raise PlanSchemaError(
                    f"projection destination column {c!r} (from input "
                    f"{name!r}) collides with the other side's output",
                    step=step)
            out.add(c)
    return frozenset(out)


def _check_pred_cols(step, index, schemas_by_input) -> None:
    """Predicates reference the post-projection key space of each input."""
    proj = dict(zip(step.inputs, step.project)) if step.project else {}
    for pred in step.preds:
        for name, col in (pred.left, pred.right):
            if name not in step.inputs:
                raise PlanStructureError(
                    f"predicate endpoint {name!r} is not one of the "
                    f"step's inputs {step.inputs}", step=step, index=index)
            mapping = proj.get(name, ())
            if mapping:
                space = {dst for _src, dst in mapping}
            else:
                space = schemas_by_input.get(name)
                if space is None:
                    continue
            if col not in space:
                raise PlanSchemaError(
                    f"predicate column {col!r} is not in the "
                    f"post-projection key space of input {name!r} "
                    f"({sorted(space)})", step=step, index=index)


def _check_binary(step, index, schemas_by_input) -> None:
    if len(step.inputs) != 2:
        raise PlanStructureError(
            f"binary steps take 2 inputs, got {len(step.inputs)}",
            step=step, index=index)
    if len(step.preds) != 1:
        raise PlanStructureError(
            f"binary steps join on exactly 1 predicate, got "
            f"{len(step.preds)}", step=step, index=index)
    if step.per_r_key is not None:
        raise PlanPerRError(
            "per-R pins live on the fused linear root, not on binary "
            "steps", step=step, index=index)
    if step.project:
        if len(step.project) != 2:
            raise PlanStructureError(
                "binary projections are one (src, dst) tuple per input",
                step=step, index=index)
        for proj, name in zip(step.project, step.inputs):
            schema = schemas_by_input.get(name)
            if schema is None:
                continue
            for src, _dst in proj:
                if src not in schema:
                    raise PlanSchemaError(
                        f"projection source column {src!r} is not a "
                        f"column of input {name!r} ({sorted(schema)})",
                        step=step, index=index)
    _check_pred_cols(step, index, schemas_by_input)


def _check_fused3(step, index, is_root, schemas_by_input) -> None:
    if not step.aggregate:
        raise PlanStructureError(
            "fused3 steps aggregate (the engine never materializes its "
            f"output); step {step.out!r} tries to materialize",
            step=step, index=index)
    if not is_root:
        raise PlanStructureError(
            "fused3 steps are aggregate-only, so they can only be the "
            "plan root — no later step could read this one's output",
            step=step, index=index)
    if len(step.inputs) != 3:
        raise PlanStructureError(
            f"fused3 steps take 3 inputs, got {len(step.inputs)}",
            step=step, index=index)
    if step.kind not in _KIND_COLS:
        raise PlanStructureError(
            f"unknown fused kind {step.kind!r}; choose from "
            f"{sorted(_KIND_COLS)}", step=step, index=index)
    if not step.recovery:
        raise PlanStructureError(
            "fused3 steps must be recovery-wrapped (recovery=False breaks "
            "the overflowed == False postcondition)", step=step,
            index=index)
    roles = dict(step.roles)
    if sorted(roles) != ["r", "s", "t"]:
        raise PlanStructureError(
            f"fused3 roles must bind exactly r/s/t, got "
            f"{sorted(roles)}", step=step, index=index)
    if sorted(roles.values()) != sorted(step.inputs):
        raise PlanStructureError(
            f"fused3 roles {roles} are not a permutation of the step's "
            f"inputs {step.inputs}", step=step, index=index)
    cols = dict(step.cols)
    expected = _KIND_COLS[step.kind]
    if set(cols) != set(expected):
        raise PlanStructureError(
            f"{step.kind} fused steps bind columns {sorted(expected)}, "
            f"got {sorted(cols)}", step=step, index=index)
    for kwarg, col in cols.items():
        schema = schemas_by_input.get(roles[expected[kwarg]])
        if schema is not None and col not in schema:
            raise PlanSchemaError(
                f"column binding {kwarg}={col!r} is not a column of the "
                f"role-{expected[kwarg]} input "
                f"{roles[expected[kwarg]]!r} ({sorted(schema)})",
                step=step, index=index)
    _check_pred_cols(step, index, schemas_by_input)
    if step.per_r_key is not None:
        if step.kind != "linear":
            raise PlanPerRError(
                "per-R fused steps must be linear; planner emitted kind "
                f"{step.kind!r}", step=step, index=index)
        schema = schemas_by_input.get(roles["r"])
        if schema is not None and step.per_r_key not in schema:
            raise PlanPerRError(
                f"per-R key column {step.per_r_key!r} is not a column of "
                f"the role-r input {roles['r']!r} ({sorted(schema)})",
                step=step, index=index)


def verify_plan(plan: plan_ir.QueryPlan, schemas: Mapping[str, Iterable[str]]
                | None = None, *, external: Iterable[str] | None = None,
                require_all_inputs: bool | None = None) -> None:
    """Statically verify ``plan``; raise ``PlanValidationError`` on the
    first violation.

    ``schemas`` maps base-relation (or environment) names to their column
    names; when provided, schema/projection propagation is checked step by
    step.  ``external`` is the set of environment names available at
    execution (defaults to ``schemas``' keys) — inputs must be external or
    defined by an earlier step.  With no ``external`` and no ``schemas``,
    any non-``%`` name passes as an implicit base relation, but
    ``%``-names must still be step-defined (the planner never emits free
    ``%`` inputs; the streaming delta path passes ``external`` instead).
    ``require_all_inputs=True`` (the default whenever ``schemas`` is
    given) additionally rejects orphan relations no step reads.
    """
    steps = plan.steps
    if not steps:
        raise PlanStructureError("plan has no steps")
    known: set[str] | None = None
    if external is not None:
        known = set(external)
    elif schemas is not None:
        known = set(schemas)
    if require_all_inputs is None:
        require_all_inputs = schemas is not None and external is None

    # name -> column set (None = unknown); intermediates fill in as steps
    # define them
    schema_env: dict[str, frozenset | None] = {}
    if schemas is not None:
        for name, cols in schemas.items():
            schema_env[name] = frozenset(cols)

    defined: dict[str, int] = {}
    consumers: dict[str, int] = {}
    last = len(steps) - 1
    for index, step in enumerate(steps):
        if step.op not in ("binary", "fused3"):
            raise PlanStructureError(
                f"unknown plan-step op {step.op!r}", step=step, index=index)
        # -- def-use / topological order ------------------------------
        for name in step.inputs:
            if name in defined:
                consumers[name] = consumers.get(name, 0) + 1
                continue
            if known is not None:
                if name not in known:
                    raise PlanStructureError(
                        f"input {name!r} is neither defined by an earlier "
                        "step nor provided by the environment "
                        f"(topological-order or unknown-relation error)",
                        step=step, index=index)
            elif _INTERMEDIATE.match(name) or name.startswith("%"):
                raise PlanStructureError(
                    f"intermediate input {name!r} is read before any step "
                    "defines it (topological-order violation)",
                    step=step, index=index)
        # -- output naming / single definition ------------------------
        if step.out in defined:
            raise PlanStructureError(
                f"output {step.out!r} is defined more than once (first at "
                f"step[{defined[step.out]}])", step=step, index=index)
        if known is not None and step.out in known:
            raise PlanStructureError(
                f"output {step.out!r} shadows an environment relation",
                step=step, index=index)
        if index == last:
            if not step.aggregate or step.out != plan_ir.COUNT:
                raise PlanStructureError(
                    f"the root step must aggregate to {plan_ir.COUNT!r}; "
                    f"got out={step.out!r} aggregate={step.aggregate}",
                    step=step, index=index)
        else:
            if step.aggregate or step.out == plan_ir.COUNT:
                raise PlanStructureError(
                    "only the root step aggregates; an earlier aggregate "
                    "would be overwritten and its inputs wasted",
                    step=step, index=index)
            if not _INTERMEDIATE.match(step.out) and not (
                    step.out.startswith("%d·")):
                raise PlanStructureError(
                    f"materialized outputs are named %i<k> (or %d·… on "
                    f"delta plans); got {step.out!r}", step=step,
                    index=index)

        in_schemas = [schema_env.get(n) for n in step.inputs]
        schemas_by_input = dict(zip(step.inputs, in_schemas))
        if step.op == "binary":
            _check_binary(step, index, schemas_by_input)
            if not step.aggregate:
                schema_env[step.out] = _schema_of(step, in_schemas)
        else:
            _check_fused3(step, index, index == last, schemas_by_input)
        defined[step.out] = index

    # -- refcounts: every materialized intermediate is consumed --------
    for name, index in defined.items():
        if name == plan_ir.COUNT:
            continue
        if consumers.get(name, 0) == 0:
            raise PlanRefcountError(
                f"intermediate {name!r} is materialized but never "
                "consumed — the refcounting arena would hold it for the "
                "whole walk (leak) and the work is dead",
                step=steps[index], index=index)

    # -- orphan relations ---------------------------------------------
    if require_all_inputs and schemas is not None:
        read = {n for s in steps for n in s.inputs}
        orphans = sorted(set(schemas) - read)
        if orphans:
            raise PlanStructureError(
                f"relation(s) {orphans} are provided but no step reads "
                "them (orphan relations)")
