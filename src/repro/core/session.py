"""JoinSession: one front door for plan → classify → execute → recover.

The session owns everything between a declarative :class:`~repro.core.query.
Query` and an exact answer:

  * **classify** — the predicate-graph analysis (`Query.classify`): linear
    chain vs triangle cycle vs star hub, no ``kind`` strings,
  * **plan** — the traffic/time strategy decision and shape sizing from
    ``core.planner`` (3-way vs cascaded binary on the hardware profile),
  * **cache** — executable plans are cached by (query structure, live
    cardinalities, m_budget, hardware, kernel flag), so repeated queries
    skip classification and sizing entirely (the hot path for serving the
    same parametrized query over refreshed data),
  * **execute / recover** — the fused ``MultiwayJoinEngine`` with the
    shared skew-recovery rounds; ``overflowed == False`` is a
    postcondition, and every result is a uniform :class:`QueryResult`.

``execute_sharded`` runs the same query on a device mesh through
``distributed.engine_count_sharded`` — the binding's canonical column
re-keying is what lets one Query serve both the local and the mesh path.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any

import numpy as np

from repro.core import engine, planner, recovery
from repro.core.query import STAR_FACT_RATIO, Binding, Classification, Query
from repro.perfmodel import HW, PLASTICINE


@dataclasses.dataclass(frozen=True)
class QueryResult:
    """Uniform result for every kind and strategy."""

    count: np.int64                       # exact cardinality (int64)
    overflowed: bool                      # False by construction
    tuples_read: np.int64 | None          # traffic, summed over rounds
    rounds: int                           # recovery rounds (1 = no skew)
    kind: str                             # inferred: linear | cyclic | star
    strategy: str                         # "3way" | "cascade"
    cache_hit: bool                       # plan came from the session cache
    plan_s: float                         # classification + sizing seconds
    exec_s: float                         # execution seconds
    plan: planner.EnginePlan | None = None
    per_r: recovery.PerRResult | None = None   # per-R aggregates (linear)


def _estimate_d(binding: Binding) -> int:
    """Distinct-value estimate for the planner's traffic/time models: the
    hub relation's R-side join column (host-side exact unique count — one
    pass, amortized by the plan cache)."""
    s = binding.rels["s"]
    col = np.asarray(s.columns[binding.col_kwargs()["sb"]])
    valid = np.asarray(s.valid)
    return max(1, int(np.unique(col[valid]).size)) if valid.any() else 1


class JoinSession:
    """Declarative query executor with a plan cache.

    >>> sess = JoinSession(m_budget=4096)
    >>> res = sess.execute(Query(relations={...}, predicates=[...]))
    >>> res.count, res.kind, res.strategy, res.cache_hit

    Parameters mirror the engine: ``use_kernel`` dispatches the fused
    Pallas kernels, ``max_rounds``/``growth`` shape skew recovery,
    ``base_salt`` seeds every round's hash salt (plumbed all the way into
    the recovery rounds — a plan-level salt is never silently dropped),
    ``hw`` is the profile the 3-way vs cascade time decision runs on, and
    ``star_fact_ratio`` tunes the star/linear hub disambiguation.
    """

    def __init__(self, *, m_budget: int | None = None, hw: HW = PLASTICINE,
                 use_kernel: bool = False, max_rounds: int = 3,
                 growth: float = 2.0, base_salt: int = 0,
                 star_fact_ratio: float | None = None):
        self.m_budget = m_budget
        self.hw = hw
        self.use_kernel = use_kernel
        self.max_rounds = max_rounds
        self.growth = growth
        self.base_salt = base_salt
        self.star_fact_ratio = (STAR_FACT_RATIO if star_fact_ratio is None
                                else star_fact_ratio)
        self._plan_cache: dict[Any, tuple[Classification,
                                          planner.EnginePlan]] = {}
        self._hits = 0
        self._misses = 0

    # -- cache -------------------------------------------------------------

    @property
    def cache_info(self) -> dict[str, int]:
        return {"size": len(self._plan_cache), "hits": self._hits,
                "misses": self._misses}

    def clear_plan_cache(self) -> None:
        self._plan_cache.clear()

    def _cache_key(self, query: Query, cards: dict[str, int],
                   m_budget: int | None, strategy: str | None,
                   forced: Classification | None):
        return (query.schema(), tuple(sorted(cards.items())), m_budget,
                self.hw, self.use_kernel, strategy,
                None if forced is None else (forced.kind, forced.roles,
                                             forced.cols))

    # -- planning ----------------------------------------------------------

    def _plan(self, query: Query, cards: dict[str, int],
              m_budget: int | None, strategy: str | None,
              forced: Classification | None
              ) -> tuple[Classification, planner.EnginePlan, bool]:
        """Classify + size, through the plan cache.  A hit skips BOTH the
        predicate-graph analysis and the shape/strategy sizing."""
        key = self._cache_key(query, cards, m_budget, strategy, forced)
        hit = self._plan_cache.get(key)
        if hit is not None:
            self._hits += 1
            return hit[0], hit[1], True
        self._misses += 1
        cls_ = forced or query.classify(
            cards, star_fact_ratio=self.star_fact_ratio)
        binding = query.bind(cls_)
        n_r, n_s, n_t = binding.cardinalities()
        if strategy == "3way":
            # forced 3-way (the legacy engine_count contract): size the
            # shape plan, skip the time model
            eng = engine.MultiwayJoinEngine(
                cls_.kind, use_kernel=self.use_kernel,
                max_rounds=self.max_rounds, growth=self.growth,
                base_salt=self.base_salt)
            if cls_.kind != "star" and m_budget is None:
                raise ValueError(f"{cls_.kind} plans need m_budget")
            shape = eng.default_plan(n_r, n_s, n_t, m_budget=m_budget)
            ep = planner.forced_3way_plan(
                cls_.kind, shape, m_budget=m_budget,
                use_kernel=self.use_kernel, max_rounds=self.max_rounds,
                growth=self.growth, base_salt=self.base_salt)
        else:
            ep = planner.plan_query(
                cls_.kind, n_r, n_s, n_t, _estimate_d(binding),
                m_budget=m_budget, hw=self.hw, use_kernel=self.use_kernel,
                max_rounds=self.max_rounds, growth=self.growth,
                base_salt=self.base_salt)
        self._plan_cache[key] = (cls_, ep)
        return cls_, ep, False

    # -- execution ---------------------------------------------------------

    def execute(self, query: Query, *, m_budget: int | None = None,
                per_r: bool = False, key_col: str = "a",
                plan=None, strategy: str | None = None,
                classification: Classification | None = None) -> QueryResult:
        """Classify, plan (or reuse a cached plan), execute, recover.

        ``plan`` overrides sizing with an explicit shape plan (skipping the
        planner and the cache); ``strategy="3way"`` skips the time model
        and always runs the fused multiway engine; ``classification``
        bypasses inference (the deprecation shims use it — new code should
        let the graph speak).
        """
        if strategy not in (None, "3way"):
            raise ValueError(f"unknown strategy {strategy!r}: pass None "
                             "(planner decides) or '3way' (force the "
                             "fused multiway engine)")
        t0 = time.perf_counter()
        m_budget = self.m_budget if m_budget is None else m_budget
        cards = {name: int(rel.n) for name, rel in query.relations.items()}
        if plan is not None:
            cls_ = classification or query.classify(
                cards, star_fact_ratio=self.star_fact_ratio)
            ep = planner.forced_3way_plan(
                cls_.kind, plan, m_budget=m_budget,
                use_kernel=self.use_kernel, max_rounds=self.max_rounds,
                growth=self.growth, base_salt=self.base_salt)
            cache_hit = False
        else:
            cls_, ep, cache_hit = self._plan(query, cards, m_budget,
                                             strategy, classification)
        binding = query.bind(cls_)
        plan_s = time.perf_counter() - t0

        t1 = time.perf_counter()
        r, s, t = binding.relations()
        if per_r:
            # the per-R aggregate pass owns every output tuple exactly
            # once, so COUNT is its valid-slot sum — one engine execution,
            # not two (legacy engine_per_r_counts parity)
            if binding.kind != "linear":
                raise ValueError(
                    f"per-R aggregates need a linear-classified query; "
                    f"this one classified as {binding.kind!r}")
            per_r_res = recovery.run_per_r_rounds(
                binding.kind_ops(), r, s, t, ep.shape_plan,
                max_rounds=self.max_rounds, growth=self.growth,
                use_kernel=self.use_kernel, base_salt=self.base_salt,
                key_col=key_col)
            count = int(per_r_res.counts[np.asarray(per_r_res.valid)].sum())
            exec_s = time.perf_counter() - t1
            return QueryResult(
                count=np.int64(count),
                overflowed=bool(per_r_res.overflowed),
                tuples_read=per_r_res.tuples_read,
                rounds=int(per_r_res.rounds), kind=binding.kind,
                strategy="3way", cache_hit=cache_hit, plan_s=plan_s,
                exec_s=exec_s, plan=ep, per_r=per_r_res)
        res = ep.run(r, s, t, binding=binding)
        exec_s = time.perf_counter() - t1
        return QueryResult(
            count=np.int64(int(res.count)),
            overflowed=bool(res.overflowed),
            tuples_read=np.int64(int(res.tuples_read)),
            rounds=int(res.rounds), kind=binding.kind,
            strategy=ep.strategy, cache_hit=cache_hit, plan_s=plan_s,
            exec_s=exec_s, plan=ep, per_r=None)

    # -- distributed -------------------------------------------------------

    def execute_sharded(self, query: Query, mesh, row: str, col: str, *,
                        max_rounds: int = 2,
                        classification: Classification | None = None,
                        **kw) -> QueryResult:
        """The same declarative query on a device mesh: classify + bind,
        re-key the relations to the canonical routing columns, and run the
        cross-device recovery rounds of ``distributed.engine_count_sharded``
        (``overflowed == False`` on the mesh too).  Relations should enter
        sharded in arrival order (``distributed.shard_relation``)."""
        from repro.core import distributed
        t0 = time.perf_counter()
        cards = {name: int(rel.n) for name, rel in query.relations.items()}
        cls_ = classification or query.classify(
            cards, star_fact_ratio=self.star_fact_ratio)
        binding = query.bind(cls_)
        r, s, t = binding.canonical()
        plan_s = time.perf_counter() - t0
        t1 = time.perf_counter()
        fn = distributed.engine_count_sharded(
            mesh, row, col, binding.kind, max_rounds=max_rounds,
            growth=self.growth, use_kernel=self.use_kernel, **kw)
        res = fn(r, s, t)
        exec_s = time.perf_counter() - t1
        return QueryResult(
            count=np.int64(int(res.count)),
            overflowed=bool(res.overflowed), tuples_read=None,
            rounds=int(res.rounds), kind=binding.kind, strategy="3way",
            cache_hit=False, plan_s=plan_s, exec_s=exec_s)
