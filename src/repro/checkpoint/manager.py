"""Fault-tolerant checkpointing: atomic, elastic, resumable.

Format: one ``.npz`` per checkpoint step holding the flattened pytree
(keyed by '/'-joined tree paths) + a JSON manifest with step metadata and a
content checksum.  Writes go to a temp directory and are atomically
renamed; a checkpoint without its ``COMMITTED`` marker is ignored by
restore (torn writes from a killed process can never be resumed into).

Elasticity: arrays are saved *unsharded* (host-gathered).  Restore places
them onto whatever mesh/sharding the new process provides — a checkpoint
written on N devices restores on M (the elastic re-mesh path, exercised in
tests).  At real fleet scale you'd write per-host shards; the manifest
format reserves a ``shards`` field for that extension.
"""

from __future__ import annotations

import hashlib
import json
import os
import pathlib
import shutil
import time

import jax
import numpy as np

_SEP = "/"


def _key_name(k) -> str:
    for attr in ("key", "name", "idx"):
        if hasattr(k, attr):
            return str(getattr(k, attr))
    return str(k)


def _flatten(tree):
    flat = {}
    for path, leaf in jax.tree_util.tree_leaves_with_path(
            tree, is_leaf=lambda x: x is None):
        if leaf is None:
            continue
        key = _SEP.join(_key_name(k) for k in path)
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def save_pytree(tree, directory: str | os.PathLike, step: int,
                extra_meta: dict | None = None) -> pathlib.Path:
    """Atomic checkpoint write; returns the committed directory."""
    root = pathlib.Path(directory)
    root.mkdir(parents=True, exist_ok=True)
    final = root / f"step_{step:08d}"
    tmp = root / f".tmp_step_{step:08d}_{os.getpid()}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    flat = _flatten(tree)
    arrays_path = tmp / "arrays.npz"
    np.savez(arrays_path, **flat)
    digest = hashlib.sha256(arrays_path.read_bytes()).hexdigest()
    manifest = {
        "step": int(step),
        "time": time.time(),
        "keys": sorted(flat.keys()),
        "sha256": digest,
        "shards": None,           # reserved: per-host shard layout
        **(extra_meta or {}),
    }
    (tmp / "manifest.json").write_text(json.dumps(manifest, indent=2))
    (tmp / "COMMITTED").write_text(digest)
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)             # atomic on POSIX
    return final


def _is_committed(path: pathlib.Path) -> bool:
    return (path / "COMMITTED").exists() and (path / "manifest.json").exists()


def latest_step(directory: str | os.PathLike) -> int | None:
    root = pathlib.Path(directory)
    if not root.exists():
        return None
    steps = []
    for p in root.iterdir():
        if p.name.startswith("step_") and _is_committed(p):
            steps.append(int(p.name.split("_")[1]))
    return max(steps) if steps else None


def restore_pytree(template, directory: str | os.PathLike,
                   step: int | None = None, shardings=None,
                   verify: bool = True):
    """Restore into the structure of `template` (a pytree of arrays or
    ShapeDtypeStructs).  `shardings`: optional matching pytree of
    NamedShardings — arrays are device_put onto them (elastic re-mesh)."""
    root = pathlib.Path(directory)
    if step is None:
        step = latest_step(root)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {root}")
    path = root / f"step_{step:08d}"
    if not _is_committed(path):
        raise FileNotFoundError(f"checkpoint {path} not committed")
    manifest = json.loads((path / "manifest.json").read_text())
    if verify:
        digest = hashlib.sha256((path / "arrays.npz").read_bytes()).hexdigest()
        if digest != manifest["sha256"]:
            raise IOError(f"checkpoint {path} corrupt (checksum mismatch)")
    data = np.load(path / "arrays.npz")

    flat_t, treedef = jax.tree_util.tree_flatten_with_path(template)
    flat_s = (jax.tree_util.tree_leaves(shardings)
              if shardings is not None else [None] * len(flat_t))
    leaves = []
    for (kpath, leaf), sh in zip(flat_t, flat_s):
        key = _SEP.join(_key_name(k) for k in kpath)
        if key not in data:
            raise KeyError(f"checkpoint missing key {key!r}")
        arr = data[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"{key}: shape {arr.shape} != template "
                             f"{leaf.shape}")
        leaves.append(jax.device_put(arr, sh) if sh is not None
                      else jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, leaves), manifest


class CheckpointManager:
    """Retention + cadence policy around save/restore."""

    def __init__(self, directory: str | os.PathLike, *, every: int = 100,
                 keep: int = 3):
        self.dir = pathlib.Path(directory)
        self.every = every
        self.keep = keep

    def should_save(self, step: int) -> bool:
        return self.every > 0 and step > 0 and step % self.every == 0

    def save(self, tree, step: int, extra_meta: dict | None = None):
        path = save_pytree(tree, self.dir, step, extra_meta)
        self._gc()
        return path

    def restore(self, template, step: int | None = None, shardings=None):
        return restore_pytree(template, self.dir, step, shardings)

    def latest_step(self):
        return latest_step(self.dir)

    def _gc(self):
        steps = sorted(
            int(p.name.split("_")[1]) for p in self.dir.iterdir()
            if p.name.startswith("step_") and _is_committed(p))
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)
