"""Cost model (paper §4.2/§5.2 closed forms + Examples 3/4) and FM sketch."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cost_model, sketches


# --------------------------------------------------------------------------
# cost model: the paper's own numbers
# --------------------------------------------------------------------------

def test_example3_threshold():
    """Example 3: linear 3-way beats the cascade's intermediate for the
    Facebook relation when M > ~1.003e9 tuples."""
    m = cost_model.example3_threshold_m(6e11)
    assert 1.0e9 < m < 1.01e9
    # at that M the traffic matches the cascade's intermediate bound
    t3 = cost_model.linear3_tuples(6e11, 6e11, 6e11, m)
    assert abs(t3 - 3.6e14) / 3.6e14 < 1e-6


def test_example4_threshold():
    """Example 4: cyclic 3-way needs only ~7e6 tuples of on-chip memory.

    Note: the paper's Example 4 uses n(1 + √(n/M)) — dropping the factor 2
    from its own §5.2 closed form |R| + 2√(|R||S||T|/M).  We validate the
    example's threshold with the example's expression (reproducing the
    "seven million tuples" claim) and separately check that the §5.2 form
    at that M is exactly 2× the example's second term.
    """
    m = cost_model.example4_threshold_m(6e11, 1.8e14)
    assert 6e6 < m < 8e6
    n = 6e11
    example_form = n * (1.0 + (n / m) ** 0.5)
    assert abs(example_form - 1.8e14) / 1.8e14 < 1e-6
    closed = cost_model.cyclic3_tuples(n, n, n, m)
    assert abs((closed - n) - 2.0 * (example_form - n)) / closed < 1e-6


def test_cyclic_optimal_h_minimizes():
    n_r, n_s, n_t, m = 1e8, 3e8, 2e8, 1e6
    h_star = cost_model.cyclic3_optimal_h(n_r, n_s, n_t, m)
    best = cost_model.cyclic3_tuples(n_r, n_s, n_t, m, h=h_star)
    for h in (h_star * 0.5, h_star * 0.9, h_star * 1.1, h_star * 2.0):
        assert cost_model.cyclic3_tuples(n_r, n_s, n_t, m, h=h) >= best - 1e-6
    # closed form at the optimum
    closed = cost_model.cyclic3_tuples(n_r, n_s, n_t, m)
    assert abs(best - closed) / closed < 1e-9


def test_linear_strategy_flips_with_d():
    """Low d (big intermediate) favors 3-way; high d favors the cascade."""
    n, m = 2e8, 16e6 / 8  # 16MB scratchpad, 8B tuples
    lo = cost_model.choose_linear_strategy(n, n, n, m, d=7e5)
    hi = cost_model.choose_linear_strategy(n, n, n, m, d=1e9)
    assert lo.strategy == "linear3"
    assert hi.strategy == "cascade"
    assert lo.speed_ratio > 1 > hi.speed_ratio


def test_symmetry_prefers_small_r():
    """§4.2: reading R once means the smaller of R,T should be R."""
    small, big, m = 1e6, 1e9, 1e6
    a = cost_model.linear3_tuples(small, 1e7, big, m)
    b = cost_model.linear3_tuples(big, 1e7, small, m)
    assert a < b


# --------------------------------------------------------------------------
# FM sketch
# --------------------------------------------------------------------------

@pytest.mark.parametrize("true_distinct", [100, 5000, 200_000])
def test_fm_estimate_accuracy(true_distinct):
    keys = jnp.arange(true_distinct, dtype=jnp.int32) * 7919 + 13
    regs = sketches.add(sketches.empty(64), keys,
                        jnp.ones((true_distinct,), bool))
    est = float(sketches.fm_estimate(regs))
    assert 0.5 * true_distinct < est < 2.0 * true_distinct


def test_fm_merge_equals_union():
    a_keys = jnp.arange(0, 3000, dtype=jnp.int32)
    b_keys = jnp.arange(1500, 4000, dtype=jnp.int32)
    ra = sketches.add(sketches.empty(32), a_keys, jnp.ones((3000,), bool))
    rb = sketches.add(sketches.empty(32), b_keys, jnp.ones((2500,), bool))
    merged = sketches.merge(ra, rb)
    union = sketches.add(sketches.empty(32), jnp.arange(0, 4000, dtype=jnp.int32),
                         jnp.ones((4000,), bool))
    np.testing.assert_array_equal(np.asarray(merged), np.asarray(union))


def test_fm_invalid_rows_ignored():
    keys = jnp.arange(1000, dtype=jnp.int32)
    none = sketches.add(sketches.empty(16), keys, jnp.zeros((1000,), bool))
    np.testing.assert_array_equal(np.asarray(none), 0)


def test_linear3_fm_distinct_close_to_truth(rng):
    from conftest import make_rel, oracle_distinct_join_pairs
    from repro.core import linear3
    r, rd = make_rel(rng, 150, ("a", "b"), 60)
    s, sd = make_rel(rng, 160, ("b", "c"), 60)
    t, td = make_rel(rng, 140, ("c", "d"), 60)
    truth = oracle_distinct_join_pairs(rd["b"], rd["a"], sd["b"], sd["c"],
                                       td["c"], td["d"])
    plan = linear3.default_plan(150, 160, 140, m_budget=64, u=4, slack=6.0)
    regs, ovf = linear3.linear3_fm_distinct(r, s, t, plan, n_registers=64)
    assert not bool(ovf)
    est = float(sketches.fm_estimate(regs))
    assert 0.4 * truth < est < 2.5 * truth, (est, truth)


# --------------------------------------------------------------------------
# planner: time-based decisions on hardware profiles
# --------------------------------------------------------------------------

def test_planner_timed_decisions():
    from repro.core import planner
    from repro.perfmodel import PLASTICINE, TPU_V5E
    # the paper's flagship point: 3-way wins big on Plasticine (SSD cliff)
    c = planner.choose_linear_timed(2e8, 2e8, 2e8, 7e5, PLASTICINE)
    assert c.strategy == "3way" and c.speedup > 20
    # on v5e the fast host link narrows the win but keeps the 3-way ahead
    v = planner.choose_linear_timed(2e8, 2e8, 2e8, 7e5, TPU_V5E)
    assert v.strategy == "3way" and 1.0 < v.speedup < c.speedup
    # high-d small-N regime: the cascade wins (paper's conclusion)
    w = planner.choose_linear_timed(3e7, 3e7, 3e7, 3e7 / 5, PLASTICINE)
    assert w.strategy == "cascade"
    # star join at duplicate factor 5: ~11x (Fig 4h)
    s = planner.choose_star_timed(1e6, 1e9, 1e6, 2e5, PLASTICINE)
    assert s.strategy == "3way" and 8 < s.speedup < 15
