"""Radix hash partitioning (the paper's Fig 2 / Fig 3 data reorganization).

Two static-shape-friendly layouts are provided:

* ``partition_sorted`` — relation sorted by bucket id plus a CSR-style offsets
  array.  This mirrors the paper's partition files ("S_ij partitions are
  ordered first on H(B) and then on g(C)"): composite partitioning is just a
  lexicographic sort on (outer, inner) bucket ids.

* ``bucketize`` — fixed-capacity `[n_buckets, capacity]` grid with per-bucket
  counts and an overflow indicator.  This is the on-chip layout: bucket i is
  the contents of PMU i (or one VMEM tile in the Pallas kernels).  Overflow
  (a bucket exceeding its capacity) is the skew signal; callers either size
  capacity with slack (uniform assumption, §1.2) or re-partition with a salt.
"""

from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

from repro.core import hashing
from repro.core.relation import SENTINEL, Relation, sentinel_fill

_INT32_MAX = 2**31 - 1


def _check_flat_range(n_slots: int, what: str) -> None:
    """Flat bucket/slot ids are int32 throughout; a silent wrap would scatter
    rows into the wrong buckets.  Fail loudly instead."""
    if n_slots > _INT32_MAX:
        raise ValueError(
            f"{what} = {n_slots} exceeds the int32 id range ({_INT32_MAX}); "
            "use fewer/coarser bucket levels or smaller capacities")


class SortedPartition(NamedTuple):
    rel: Relation            # rows sorted by bucket id (invalid rows last)
    bucket_ids: jnp.ndarray  # (capacity,) int32, n_buckets for invalid rows
    offsets: jnp.ndarray     # (n_buckets + 1,) int32 CSR offsets


class Buckets(NamedTuple):
    columns: dict            # name -> (n_buckets, capacity) int32, sentinel-padded
    valid: jnp.ndarray       # (n_buckets, capacity) bool
    counts: jnp.ndarray      # (n_buckets,) int32 true per-bucket count (pre-clip)
    overflowed: jnp.ndarray  # () bool — any bucket exceeded capacity


def bucket_ids_for(rel: Relation, key_col: str, n_buckets: int, fn: str,
                   salt: int = 0) -> jnp.ndarray:
    """Bucket id per row; invalid rows get id == n_buckets (sorts last)."""
    ids = hashing.hash_bucket(rel.col(key_col), n_buckets, fn, salt)
    return jnp.where(rel.valid, ids, jnp.int32(n_buckets))


def partition_sorted(rel: Relation, key_col: str, n_buckets: int, fn: str = "H",
                     salt: int = 0) -> SortedPartition:
    ids = bucket_ids_for(rel, key_col, n_buckets, fn, salt)
    order = jnp.argsort(ids, stable=True)
    sorted_rel = rel.select(order, jnp.ones_like(order, dtype=bool))
    sorted_ids = ids[order]
    offsets = jnp.searchsorted(sorted_ids, jnp.arange(n_buckets + 1), side="left")
    return SortedPartition(sorted_rel, sorted_ids, offsets.astype(jnp.int32))


def partition_sorted2(rel: Relation, outer_col: str, inner_col: str,
                      n_outer: int, n_inner: int, outer_fn: str = "H",
                      inner_fn: str = "g") -> SortedPartition:
    """Composite two-level partitioning: sort by (outer, inner) bucket pair.

    Bucket id = outer * n_inner + inner, matching the paper's S layout
    (ordered by H(B), then by g(C) within each H(B) partition).
    """
    outer = bucket_ids_for(rel, outer_col, n_outer, outer_fn)
    inner = bucket_ids_for(rel, inner_col, n_inner, inner_fn)
    flat = jnp.where(rel.valid, outer * n_inner + inner,
                     jnp.int32(n_outer * n_inner))
    order = jnp.argsort(flat, stable=True)
    sorted_rel = rel.select(order, jnp.ones_like(order, dtype=bool))
    sorted_ids = flat[order]
    offsets = jnp.searchsorted(
        sorted_ids, jnp.arange(n_outer * n_inner + 1), side="left")
    return SortedPartition(sorted_rel, sorted_ids, offsets.astype(jnp.int32))


def bucketize(rel: Relation, key_col: str, n_buckets: int, capacity: int,
              fn: str = "h", salt: int = 0,
              sentinel: int = SENTINEL) -> Buckets:
    """Scatter rows into a fixed [n_buckets, capacity] grid.

    Rows beyond a bucket's capacity are dropped and flagged via
    ``overflowed`` — the caller must re-partition (bigger capacity or new
    salt).  Implementation: rank-within-bucket via a stable sort, then a
    single scatter; O(n log n), no dynamic shapes.
    """
    _check_flat_range(n_buckets * capacity + 1, "n_buckets * capacity")
    ids = bucket_ids_for(rel, key_col, n_buckets, fn, salt)
    order = jnp.argsort(ids, stable=True)
    sorted_ids = ids[order]
    # position of each sorted row within its bucket
    starts = jnp.searchsorted(sorted_ids, jnp.arange(n_buckets + 1), side="left")
    within = jnp.arange(sorted_ids.shape[0]) - starts[jnp.clip(sorted_ids, 0, n_buckets)]
    counts = (starts[1:] - starts[:-1]).astype(jnp.int32)
    overflowed = jnp.any(counts > capacity)

    keep = (sorted_ids < n_buckets) & (within < capacity)
    dest = jnp.where(keep, sorted_ids * capacity + within, n_buckets * capacity)

    filled = sentinel_fill(rel, sentinel)
    out_cols = {}
    for name, col in filled.columns.items():
        flat = jnp.full((n_buckets * capacity + 1,), sentinel, dtype=jnp.int32)
        flat = flat.at[dest].set(col[order], mode="drop")
        out_cols[name] = flat[:-1].reshape(n_buckets, capacity)
    vflat = jnp.zeros((n_buckets * capacity + 1,), dtype=bool)
    vflat = vflat.at[dest].set(rel.valid[order], mode="drop")
    valid = vflat[:-1].reshape(n_buckets, capacity)
    return Buckets(out_cols, valid, counts, overflowed)


def bucketize_by_ids(rel: Relation, flat_ids: jnp.ndarray, n_buckets: int,
                     capacity: int, out_shape: tuple,
                     sentinel: int = SENTINEL) -> Buckets:
    """Scatter rows into `[*out_shape, capacity]` by precomputed flat bucket
    ids (invalid rows must carry id == n_buckets).  Generic engine behind the
    composite two/three-level layouts of Fig 2/3."""
    _check_flat_range(n_buckets * capacity + 1, "n_buckets * capacity")
    order = jnp.argsort(flat_ids, stable=True)
    sorted_ids = flat_ids[order]
    starts = jnp.searchsorted(sorted_ids, jnp.arange(n_buckets + 1), side="left")
    within = jnp.arange(sorted_ids.shape[0]) - starts[
        jnp.clip(sorted_ids, 0, n_buckets)]
    counts = (starts[1:] - starts[:-1]).astype(jnp.int32)
    overflowed = jnp.any(counts > capacity)
    keep = (sorted_ids < n_buckets) & (within < capacity)
    dest = jnp.where(keep, sorted_ids * capacity + within, n_buckets * capacity)
    cols = {}
    for name, col in rel.columns.items():
        flat = jnp.full((n_buckets * capacity + 1,), sentinel, dtype=jnp.int32)
        flat = flat.at[dest].set(jnp.where(rel.valid, col,
                                           jnp.int32(sentinel))[order],
                                 mode="drop")
        cols[name] = flat[:-1].reshape(*out_shape, capacity)
    vflat = jnp.zeros((n_buckets * capacity + 1,), dtype=bool)
    vflat = vflat.at[dest].set(rel.valid[order], mode="drop")
    valid = vflat[:-1].reshape(*out_shape, capacity)
    return Buckets(cols, valid, counts.reshape(out_shape), overflowed)


def composite_ids(rel: Relation, specs: list[tuple[str, int, str]],
                  salt: int = 0) -> tuple[jnp.ndarray, int]:
    """Flat composite bucket id from [(column, n_buckets, hash_fn), ...],
    most-significant first.  Invalid rows get id == prod(n_buckets).
    ``salt`` re-randomizes every level (skew-recovery re-partitioning).

    Raises ``ValueError`` when ``prod(n_buckets)`` exceeds the int32 id
    range: ``flat`` accumulates in int32, so a deeper/wider spec (e.g. the
    cyclic four-level layout on a huge plan) would otherwise wrap silently
    and scatter rows into wrong buckets.
    """
    total = 1
    for _col, nb, _fn in specs:
        total *= nb
    _check_flat_range(total, f"prod(n_buckets) for specs {specs!r}")
    flat = jnp.zeros((rel.capacity,), jnp.int32)
    for col, nb, fn in specs:
        ids = bucket_ids_for(rel, col, nb, fn, salt)
        flat = flat * nb + jnp.clip(ids, 0, nb - 1)
    return jnp.where(rel.valid, flat, jnp.int32(total)), total


def suggest_capacity(n_rows: int, n_buckets: int, slack: float = 2.0,
                     align: int = 8) -> int:
    """Uniform-hash bucket capacity with slack, aligned for TPU lanes."""
    import math

    mean = max(1, math.ceil(n_rows / n_buckets))
    # Poisson tail headroom: mean + slack * sqrt(mean) at minimum.
    cap = max(int(mean * slack), mean + int(slack * math.sqrt(mean)) + 1)
    return int(math.ceil(cap / align) * align)


def sort_by_key(rel: Relation, key_col: str,
                big: int = 0x7FFFFFFF) -> tuple[Relation, jnp.ndarray]:
    """Sort rows by the *actual* key (invalid rows last).  Returns the sorted
    relation and the sorted key array (invalid = big sentinel) for
    searchsorted probes — the exact-join building block."""
    keys = jnp.where(rel.valid, rel.col(key_col), jnp.int32(big))
    order = jnp.argsort(keys, stable=True)
    return rel.select(order, jnp.ones_like(order, dtype=bool)), keys[order]
