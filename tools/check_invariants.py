#!/usr/bin/env python
"""CI gate: run the repo invariant lint (``repro.analysis.lint_invariants``)
over ``src/repro``.  Exits nonzero on any finding — the rules it enforces
(one Relation mutation point, oracle-only np.unique, SENTINEL-derived
sentinels, integer count accumulation, dispatch-gated interpret-only
kernels) are the conventions the engine's exactness argument rests on.

    python tools/check_invariants.py [paths...]
"""

from __future__ import annotations

import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent
                       / "src"))

from repro.analysis import lint_invariants  # noqa: E402

if __name__ == "__main__":
    raise SystemExit(lint_invariants.main())
