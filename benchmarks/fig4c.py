"""Fig 4 (c): cascaded binary self-join speedup, accelerator vs CPU, over
relation size and distinct-value fraction d%.  Paper claim: 200-600x,
growing as d% drops (larger intermediates).  CPU probe cost is calibrated
(hw.CPU_XEON.cpu_probe_s) — the validated claims are the BAND and the
TREND, per DESIGN.md §7."""

from __future__ import annotations

from benchmarks.common import claim, write_csv
from repro.perfmodel import (CPU_XEON, PLASTICINE, binary_cascade_time,
                             cpu_cascade_time)


def main(results: dict | None = None):
    results = results if results is not None else {}
    print("fig4c: accelerated cascade vs CPU")
    rows = []
    curves = {}
    for n in (1e7, 5e7, 1e8, 2e8):
        for dpct in (0.1, 0.5, 1.0, 5.0, 10.0, 25.0):
            d = n * dpct / 100.0
            acc = binary_cascade_time(n, n, n, d, PLASTICINE)
            cpu = cpu_cascade_time(n, n, n, d, CPU_XEON)
            sp = cpu.total / acc.total
            rows.append([n, dpct, acc.total, cpu.total, sp, acc.bottleneck])
            curves.setdefault(n, {})[dpct] = sp
    write_csv("fig4c_cpu_speedup",
              ["n", "d_pct", "accel_s", "cpu_s", "speedup", "accel_bn"],
              rows)

    sps = [sp for c in curves.values() for sp in c.values()]
    in_band = [sp for sp in sps if 100 <= sp <= 1000]
    claim(results, "fig4c_speedup_band",
          max(sps) >= 200 and len(in_band) >= len(sps) * 0.4,
          f"speedups {min(sps):.0f}x..{max(sps):.0f}x "
          "(paper band 200-600x; calibrated CPU probe cost)")
    n = 1e8
    trend = curves[n][1.0] > curves[n][10.0] > curves[n][25.0]
    claim(results, "fig4c_speedup_grows_as_d_drops", trend,
          f"N=1e8: d%=1: {curves[n][1.0]:.0f}x > d%=10: "
          f"{curves[n][10.0]:.0f}x > d%=25: {curves[n][25.0]:.0f}x")
    return results


if __name__ == "__main__":
    main()
