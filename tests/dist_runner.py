"""Distributed-join correctness runner (executed in a subprocess so the
fake-device XLA flag never leaks into other tests).

Usage: python dist_runner.py  — exits nonzero on any mismatch.
"""

import os
import pathlib
import sys

os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=8")

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import jax  # noqa: E402
import numpy as np  # noqa: E402

from conftest import (make_rel, oracle_cyclic3_count,  # noqa: E402
                      oracle_linear3_count)
from repro.core import distributed  # noqa: E402
from repro.core.relation import Relation  # noqa: E402


def main():
    assert len(jax.devices()) == 8, jax.devices()
    mesh = jax.make_mesh((4, 2), ("row", "col"))
    rng = np.random.default_rng(42)
    failures = []

    def place(rel):
        return distributed.shard_relation(
            distributed.pad_to_multiple(rel, 8), mesh, "row", "col")

    # ---- cyclic (triangles) --------------------------------------------
    r, rd = make_rel(rng, 160, ("a", "b"), 30)
    s, sd = make_rel(rng, 176, ("b", "c"), 30)
    t, td = make_rel(rng, 168, ("c", "a"), 30)
    want = oracle_cyclic3_count(rd["a"], rd["b"], sd["b"], sd["c"],
                                td["c"], td["a"])
    fn = distributed.cyclic3_count_sharded(mesh, "row", "col",
                                           shuffle_slack=4.0,
                                           local_slack=5.0)
    res = jax.jit(fn)(place(r), place(s), place(t))
    got, ovf = int(res.count), bool(res.overflowed)
    if ovf or got != want:
        failures.append(f"cyclic3: got {got} want {want} ovf {ovf}")

    # ---- cyclic with the Pallas kernel ---------------------------------
    fnk = distributed.cyclic3_count_sharded(mesh, "row", "col",
                                            shuffle_slack=4.0,
                                            local_slack=5.0, use_kernel=True)
    resk = jax.jit(fnk)(place(r), place(s), place(t))
    if bool(resk.overflowed) or int(resk.count) != want:
        failures.append(f"cyclic3+kernel: got {int(resk.count)} want {want}")

    # ---- linear ---------------------------------------------------------
    r2, rd2 = make_rel(rng, 144, ("a", "b"), 40)
    s2, sd2 = make_rel(rng, 160, ("b", "c"), 40)
    t2, td2 = make_rel(rng, 152, ("c", "d"), 40)
    want2 = oracle_linear3_count(rd2["b"], sd2["b"], sd2["c"], td2["c"])
    fn2 = distributed.linear3_count_sharded(mesh, "row", "col",
                                            shuffle_slack=4.0, local_u=4,
                                            local_g=2, local_slack=5.0)
    res2 = jax.jit(fn2)(place(r2), place(s2), place(t2))
    if bool(res2.overflowed) or int(res2.count) != want2:
        failures.append(f"linear3: got {int(res2.count)} want {want2} "
                        f"ovf {bool(res2.overflowed)}")

    # ---- star -----------------------------------------------------------
    r3, rd3 = make_rel(rng, 64, ("a", "b"), 25)
    s3, sd3 = make_rel(rng, 320, ("b", "c"), 25)
    t3, td3 = make_rel(rng, 72, ("c", "d"), 25)
    want3 = oracle_linear3_count(rd3["b"], sd3["b"], sd3["c"], td3["c"])
    fn3 = distributed.star3_count_sharded(mesh, "row", "col",
                                          shuffle_slack=4.0, local_slack=5.0)
    res3 = jax.jit(fn3)(place(r3), place(s3), place(t3))
    if bool(res3.overflowed) or int(res3.count) != want3:
        failures.append(f"star3: got {int(res3.count)} want {want3} "
                        f"ovf {bool(res3.overflowed)}")

    # ---- fused engine locals + cross-device recovery --------------------
    # (host-driven: each round is one shard_map; not wrapped in jit)
    for kind, rel3, want_k, kw in (
            ("linear", (r2, s2, t2), want2,
             dict(local_u=4, local_g=2)),
            ("cyclic", (r, s, t), want, {}),
            ("star", (r3, s3, t3), want3, {})):
        fne = distributed.engine_count_sharded(
            mesh, "row", "col", kind, shuffle_slack=4.0, local_slack=5.0,
            **kw)
        rese = fne(*map(place, rel3))
        if bool(rese.overflowed) or int(rese.count) != want_k:
            failures.append(f"engine {kind}: got {int(rese.count)} "
                            f"want {want_k} ovf {bool(rese.overflowed)}")

    # ---- declarative sharded path: JoinSession.execute_sharded ----------
    # same queries through the front door: classification + canonical
    # column re-keying must reproduce the kind-keyed engine results (the
    # session re-keys the ALREADY-SHARDED relations — pure dict re-keying,
    # no data movement)
    from repro.core.query import Query
    from repro.core.session import JoinSession
    sess = JoinSession()
    q_lin = Query({"r": place(r2), "s": place(s2), "t": place(t2)},
                  [("r.b", "s.b"), ("s.c", "t.c")])
    qres = sess.execute_sharded(q_lin, mesh, "row", "col",
                                shuffle_slack=4.0, local_slack=5.0,
                                local_u=4, local_g=2)
    if qres.overflowed or int(qres.count) != want2 or qres.kind != "linear":
        failures.append(f"session linear sharded: got {int(qres.count)} "
                        f"want {want2} kind {qres.kind}")
    q_cyc = Query({"r": place(r), "s": place(s), "t": place(t)},
                  [("r.b", "s.b"), ("s.c", "t.c"), ("t.a", "r.a")])
    qres2 = sess.execute_sharded(q_cyc, mesh, "row", "col",
                                 shuffle_slack=4.0, local_slack=5.0)
    if qres2.overflowed or int(qres2.count) != want or qres2.kind != "cyclic":
        failures.append(f"session cyclic sharded: got {int(qres2.count)} "
                        f"want {want} kind {qres2.kind}")
    q_star = Query({"dim1": place(r3), "fact": place(s3), "dim2": place(t3)},
                   [("dim1.b", "fact.b"), ("fact.c", "dim2.c")])
    qres3 = sess.execute_sharded(q_star, mesh, "row", "col",
                                 shuffle_slack=4.0, local_slack=5.0)
    if qres3.overflowed or int(qres3.count) != want3 or qres3.kind != "star":
        failures.append(f"session star sharded: got {int(qres3.count)} "
                        f"want {want3} kind {qres3.kind}")

    # ---- cross-device skew recovery: adversarial heavy hitters ----------
    # A heavy-hitter key owns a large fraction of every relation: one
    # device (and one bucket on it) must absorb all of it, so tight slacks
    # guarantee overflow in round 0.  engine_count_sharded must still
    # return the exact oracle count with overflowed == False — the §5 skew
    # guarantee, now across devices.
    from conftest import skewed_keys

    for seed in (0, 1):
        srng = np.random.default_rng(1000 + seed)

        def skewed(n, d, frac, heavy=1):
            return skewed_keys(srng, n, d, frac, heavy)

        ra5, rb5 = skewed(160, 25, 0.5), skewed(160, 25, 0.5, 3)
        sb5, sc5 = skewed(176, 25, 0.5, 3), skewed(176, 25, 0.5, 5)
        tc5, ta5 = skewed(168, 25, 0.5, 5), skewed(168, 25, 0.5)
        r5 = Relation.from_arrays(a=ra5, b=rb5)
        s5 = Relation.from_arrays(b=sb5, c=sc5)
        t5 = Relation.from_arrays(c=tc5, a=ta5)
        want5 = oracle_cyclic3_count(ra5, rb5, sb5, sc5, tc5, ta5)
        fn5 = distributed.engine_count_sharded(
            mesh, "row", "col", "cyclic", shuffle_slack=1.2,
            local_slack=1.0, max_rounds=2)
        res5 = fn5(place(r5), place(s5), place(t5))
        if bool(res5.overflowed) or int(res5.count) != want5:
            failures.append(f"engine cyclic skew[{seed}]: got "
                            f"{int(res5.count)} want {want5} "
                            f"ovf {bool(res5.overflowed)}")

        rb6 = skewed(144, 30, 0.6)
        sb6, sc6 = skewed(160, 30, 0.6), skewed(160, 30, 0.4, 7)
        tc6 = skewed(152, 30, 0.4, 7)
        r6 = Relation.from_arrays(
            a=rng.integers(0, 99, 144).astype(np.int32), b=rb6)
        s6 = Relation.from_arrays(b=sb6, c=sc6)
        t6 = Relation.from_arrays(
            c=tc6, d=rng.integers(0, 99, 152).astype(np.int32))
        want6 = oracle_linear3_count(rb6, sb6, sc6, tc6)
        fn6 = distributed.engine_count_sharded(
            mesh, "row", "col", "linear", shuffle_slack=1.2,
            local_slack=1.0, local_u=4, local_g=2, max_rounds=2)
        res6 = fn6(place(r6), place(s6), place(t6))
        if bool(res6.overflowed) or int(res6.count) != want6:
            failures.append(f"engine linear skew[{seed}]: got "
                            f"{int(res6.count)} want {want6} "
                            f"ovf {bool(res6.overflowed)}")

    # star: skewed fact keys route most of S to one device
    sb7 = skewed_keys(rng, 320, 25, 0.6, 9)
    sc7 = skewed_keys(rng, 320, 25, 0.6, 11)
    s7 = Relation.from_arrays(b=sb7, c=sc7)
    want7 = oracle_linear3_count(rd3["b"], sb7, sc7, td3["c"])
    fn7 = distributed.engine_count_sharded(
        mesh, "row", "col", "star", shuffle_slack=1.2, local_slack=1.0,
        max_rounds=2)
    res7 = fn7(place(r3), place(s7), place(t3))
    if bool(res7.overflowed) or int(res7.count) != want7:
        failures.append(f"engine star skew: got {int(res7.count)} "
                        f"want {want7} ovf {bool(res7.overflowed)}")

    # ---- skew: zipf keys, bigger slack must stay exact ------------------
    r4, rd4 = make_rel(rng, 160, ("a", "b"), 30, zipf=1.5)
    s4, sd4 = make_rel(rng, 160, ("b", "c"), 30, zipf=1.5)
    t4, td4 = make_rel(rng, 160, ("c", "d"), 30, zipf=1.5)
    want4 = oracle_linear3_count(rd4["b"], sd4["b"], sd4["c"], td4["c"])
    fn4 = distributed.linear3_count_sharded(mesh, "row", "col",
                                            shuffle_slack=8.0, local_u=2,
                                            local_g=2, local_slack=8.0)
    res4 = jax.jit(fn4)(place(r4), place(s4), place(t4))
    if bool(res4.overflowed):
        # overflow signalled -> acceptable (driver would re-plan); but the
        # count must then NOT silently equal a wrong value check
        print("note: zipf case overflowed (signalled correctly)")
    elif int(res4.count) != want4:
        failures.append(f"zipf linear3: got {int(res4.count)} want {want4}")

    # ---- MoE shard_map dispatch == single-device reference --------------
    import jax.numpy as jnp
    from repro import configs
    from repro.models import moe as moe_lib
    from repro.parallel import sharding as shd

    cfg = configs.smoke("qwen3-moe-30b-a3b")   # 8 experts, top-2
    key = jax.random.key(0)
    p_moe = moe_lib.init_moe(key, cfg)
    x = jax.random.normal(jax.random.key(1), (8, 16, cfg.d_model),
                          jnp.float32)
    # capacity_factor high enough that nothing drops: the sharded path
    # must then agree exactly (at tight capacity the DROP SETS differ —
    # per-shard vs global ranking, standard per-shard GShard semantics)
    ref_out, ref_aux = moe_lib.moe_mlp(x, p_moe, cfg, capacity_factor=8.0)

    mesh2 = jax.make_mesh((4, 2), ("data", "model"))
    shd.set_context(mesh2)
    try:
        out, aux = jax.jit(
            lambda x, p: moe_lib.moe_mlp_sharded(
                x, p, cfg, capacity_factor=8.0))(x, p_moe)
        err = float(jnp.max(jnp.abs(out - ref_out)))
        scale = float(jnp.max(jnp.abs(ref_out))) + 1e-9
        if err / scale > 1e-4:
            failures.append(f"moe shard_map: rel err {err / scale:.3e}")
        if abs(float(aux["aux_loss"]) - float(ref_aux["aux_loss"])) > 0.3:
            failures.append(
                f"moe aux: {float(aux['aux_loss'])} vs "
                f"{float(ref_aux['aux_loss'])}")
    finally:
        shd.set_context(None)

    if failures:
        print("\n".join(failures))
        sys.exit(1)
    print("distributed joins: all exact")


if __name__ == "__main__":
    main()
