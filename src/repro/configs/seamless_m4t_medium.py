"""seamless-m4t-medium — enc-dec multimodal backbone [arXiv:2308.11596; hf].

12L (enc) + 12L (dec), d_model=1024 16H (MHA kv=16) d_ff=4096 vocab=256206.
Modality frontend is a stub: input_specs() provides precomputed frame
embeddings [B, T_frames, d_model].
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium", family="audio",
    n_layers=12, n_enc_layers=12, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=4096, vocab_size=256206, head_dim=64,
    n_frontend_tokens=4096, norm_eps=1e-5,
    accum_steps=2,
)

SMOKE = ModelConfig(
    name="seamless-m4t-medium-smoke", family="audio",
    n_layers=2, n_enc_layers=2, d_model=96, n_heads=4, n_kv_heads=4,
    d_ff=256, vocab_size=512, head_dim=24,
    n_frontend_tokens=32, norm_eps=1e-5, remat=False,
)
