"""Assigned-architecture configs (full + reduced smoke variants) + shapes.

Every architecture is selectable by id:  ``configs.get("yi-34b")``.
``configs.smoke(id)`` returns the reduced same-family config used by the
CPU smoke tests; the full configs are only ever lowered via the dry-run
(ShapeDtypeStruct, no allocation).
"""

from __future__ import annotations

import importlib

from repro.models.config import ModelConfig

ARCH_IDS = (
    "yi-34b", "gemma3-1b", "qwen2-1.5b", "qwen2.5-14b",
    "seamless-m4t-medium", "moonshot-v1-16b-a3b", "qwen3-moe-30b-a3b",
    "llama-3.2-vision-11b", "zamba2-1.2b", "mamba2-370m",
)

# (seq_len, global_batch, kind)
SHAPES = {
    "train_4k": dict(seq_len=4096, global_batch=256, kind="train"),
    "prefill_32k": dict(seq_len=32768, global_batch=32, kind="prefill"),
    "decode_32k": dict(seq_len=32768, global_batch=128, kind="decode"),
    "long_500k": dict(seq_len=524288, global_batch=1, kind="decode"),
}


def _module(arch_id: str):
    return importlib.import_module(
        "repro.configs." + arch_id.replace("-", "_").replace(".", "_"))


def get(arch_id: str) -> ModelConfig:
    if arch_id not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch_id!r}; choose from {ARCH_IDS}")
    return _module(arch_id).CONFIG


def smoke(arch_id: str) -> ModelConfig:
    return _module(arch_id).SMOKE


def shape_applicable(cfg: ModelConfig, shape: str) -> bool:
    """long_500k only for sub-quadratic archs (skips noted in DESIGN.md)."""
    if shape == "long_500k":
        return cfg.sub_quadratic
    return True
