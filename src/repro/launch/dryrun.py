import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × input shape)
cell on the production mesh, prove it shards and fits, and extract the
roofline terms from the compiled artifact.

    PYTHONPATH=src python -m repro.launch.dryrun --arch yi-34b \
        --shape train_4k [--multi-pod] [--out artifacts/dryrun] \
        [--set scan_group=8 seq_shard=1 ...]

Writes one JSON artifact per cell:
  memory_analysis    per-device argument/output/temp/peak bytes
  cost_analysis      per-device FLOPs + bytes accessed
  collectives        operand/wire bytes by kind and replica-group size
  roofline           three terms (s), bottleneck, MODEL_FLOPS ratio

The FIRST TWO LINES of this file force 512 host-platform devices — they
must run before ANY other import (jax locks the device count on first
init).  Never set that flag globally: smoke tests and benches see 1 device.
"""

import argparse
import json
import pathlib
import time
import traceback


def _parse_overrides(items):
    out = {}
    for it in items or ():
        k, v = it.split("=", 1)
        if v.lower() in ("true", "false"):
            out[k] = v.lower() == "true"
        else:
            try:
                out[k] = int(v)
            except ValueError:
                try:
                    out[k] = float(v)
                except ValueError:
                    out[k] = v
        if k in ("seq_shard", "remat") and isinstance(out[k], int):
            out[k] = bool(out[k])
    return out


def run_cell(arch: str, shape: str, multi_pod: bool,
             overrides: dict | None = None, save_hlo: str | None = None):
    import jax
    from repro import configs  # noqa: F401
    from repro.launch import hlo_analysis, hlo_stats, mesh as mesh_lib, specs
    from repro.parallel import sharding as shd

    t0 = time.monotonic()
    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    overrides = dict(overrides or {})
    # Pallas flash-attention substitution (§Perf): the model lowers with
    # the numerically-identical jnp flash; the roofline then swaps the
    # measured score-tensor traffic for the validated kernel's HBM
    # contract (kernels/flash_attention.py — interpret-mode Pallas cannot
    # appear in a CPU-compiled HLO module).
    attn_substitute = bool(overrides.pop("attn_substitute", False))
    # serve-time deployment mode: bf16 weights, no FSDP (replicated over
    # "data") — kills the per-step f32 parameter all-gather at decode
    serve_bf16 = bool(overrides.pop("serve_bf16", False))
    rules = {}
    if overrides.pop("seq_shard_rule", None) or overrides.get("seq_shard"):
        rules["seq_res"] = ("model",)
    n_chips = mesh.devices.size

    cell, args = specs.input_specs(arch, shape, overrides=overrides or None)
    serve_bf16 = serve_bf16 and cell.kind in ("prefill", "decode")
    if serve_bf16:
        rules["p_embed"] = ()      # no FSDP at serve: replicate over data
    mesh_lib.activate(mesh, rules)
    ctx = shd.current_context()
    if serve_bf16:
        import jax.numpy as jnp

        def _bf16(s):
            if hasattr(s, "dtype") and s.dtype == jnp.float32:
                return jax.ShapeDtypeStruct(s.shape, jnp.bfloat16)
            return s
        args = (jax.tree.map(_bf16, args[0]),) + args[1:]
    step, in_sh, out_sh, donate = specs.step_and_shardings(cell, ctx, args)

    with mesh:
        jitted = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                         donate_argnums=donate)
        lowered = jitted.lower(*args)
        t_lower = time.monotonic() - t0
        compiled = lowered.compile()
        t_compile = time.monotonic() - t0 - t_lower

    ma = compiled.memory_analysis()
    mem = {
        "argument_bytes": getattr(ma, "argument_size_in_bytes", None),
        "output_bytes": getattr(ma, "output_size_in_bytes", None),
        "temp_bytes": getattr(ma, "temp_size_in_bytes", None),
        "generated_code_bytes": getattr(ma, "generated_code_size_in_bytes",
                                        None),
        "alias_bytes": getattr(ma, "alias_size_in_bytes", None),
    }
    print("memory_analysis:", mem)

    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    flops = float(ca.get("flops", 0.0))
    bytes_accessed = float(ca.get("bytes accessed", 0.0))
    print("cost_analysis: flops=%.4g bytes=%.4g" % (flops, bytes_accessed))

    hlo = compiled.as_text()
    if save_hlo:
        pathlib.Path(save_hlo).write_text(hlo)
    # trip-count-aware static analysis (XLA cost_analysis counts while
    # bodies once — useless for scanned-layer models; see hlo_stats)
    stats = hlo_stats.analyze(hlo, world=n_chips)

    mflops = hlo_analysis.model_flops_per_device(
        cell.cfg, cell.kind, cell.global_batch, cell.seq_len, n_chips)

    substitution = None
    traffic = stats["traffic_bytes"]
    if attn_substitute and cell.kind in ("train", "prefill") \
            and cell.cfg.family != "ssm":
        from repro.kernels import flash_attention as fa
        qc, kc = 512, 1024          # the jnp flash chunk sizes
        score = hlo_stats.score_traffic(hlo, n_chips, qc, kc)
        n_attn_layers = cell.cfg.n_layers
        if cell.cfg.is_hybrid:
            n_attn_layers = cell.cfg.n_layers // cell.cfg.hybrid_every
        contract = n_attn_layers * fa.hbm_bytes(
            cell.cfg, cell.global_batch, cell.seq_len,
            train=(cell.kind == "train")) / n_chips
        traffic = stats["traffic_bytes"] - score + contract
        substitution = {
            "score_traffic_bytes": score,
            "kernel_contract_bytes": contract,
            "traffic_before": stats["traffic_bytes"],
            "traffic_after": traffic,
        }
        print(f"pallas substitution: score={score:.3e} B  "
              f"contract={contract:.3e} B")

    roof = hlo_analysis.Roofline(
        flops=stats["flops"], hbm_bytes=traffic,
        wire_bytes=stats["collective_wire_bytes"], model_flops=mflops)

    peak = 0.0
    for k in ("temp_bytes", "argument_bytes", "output_bytes"):
        peak += mem.get(k) or 0.0
    # donated buffers alias input/output — don't double count
    peak -= mem.get("alias_bytes") or 0.0

    art = {
        "arch": arch, "shape": shape, "kind": cell.kind,
        "mesh": ("pod2x16x16" if multi_pod else "16x16"),
        "n_chips": n_chips,
        "overrides": {k: v for k, v in (overrides or {}).items()},
        "ok": True,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": mem,
        "per_device_peak_bytes_est": peak,
        "fits_16gb": bool(peak < 16e9),
        "xla_cost": {"flops": flops, "bytes_accessed": bytes_accessed,
                     "note": "while bodies counted once by XLA"},
        "hlo_stats": stats,
        "attn_substitution": substitution,
        "roofline": roof.to_json(),
        "param_count": cell.cfg.param_count(),
        "active_param_count": cell.cfg.active_param_count(),
    }
    return art


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k",
                    choices=list(__import__("repro.configs",
                                            fromlist=["SHAPES"]).SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--save-hlo", default=None,
                    help="also dump the optimized HLO text to this path")
    ap.add_argument("--set", nargs="*", dest="overrides", default=None,
                    metavar="K=V", help="ModelConfig overrides "
                    "(e.g. scan_group=8 seq_shard=1)")
    ap.add_argument("--tag", default="", help="artifact filename suffix "
                    "(perf-iteration id)")
    args = ap.parse_args()

    overrides = _parse_overrides(args.overrides)
    outdir = pathlib.Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)
    name = f"{args.arch}__{args.shape}__" \
           f"{'pod2' if args.multi_pod else 'pod1'}"
    if args.tag:
        name += f"__{args.tag}"

    try:
        art = run_cell(args.arch, args.shape, args.multi_pod,
                       overrides=overrides, save_hlo=args.save_hlo)
    except Exception as e:  # record failures as artifacts too
        art = {"arch": args.arch, "shape": args.shape,
               "mesh": "pod2x16x16" if args.multi_pod else "16x16",
               "ok": False, "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()}
        (outdir / f"{name}.json").write_text(json.dumps(art, indent=2))
        print(json.dumps({k: art[k] for k in ("arch", "shape", "ok",
                                              "error")}, indent=2))
        raise SystemExit(1)

    (outdir / f"{name}.json").write_text(json.dumps(art, indent=2))
    summary = {k: art[k] for k in ("arch", "shape", "mesh", "kind", "ok",
                                   "compile_s", "fits_16gb")}
    summary["bottleneck"] = art["roofline"]["bottleneck"]
    summary["roofline_fraction"] = round(
        art["roofline"]["roofline_fraction"], 4)
    print(json.dumps(summary, indent=2))


if __name__ == "__main__":
    main()
