from repro.runtime.fault_tolerance import (  # noqa: F401
    StragglerMonitor, RestartableLoop, elastic_restore)
