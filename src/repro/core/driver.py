"""Overflow-handling drivers around the join algorithms.

The paper assumes near-uniform keys (§1.2) and notes that skew must be
handled by "leaving some components to handle overflow" or re-partitioning.
Our bucketized layouts are fixed-capacity, so skew (including plain key
multiplicity, |rel|/d copies per value) surfaces as an ``overflowed`` flag —
never as silent wrong answers.

These drivers implement the re-partition loop: on overflow, grow the
per-bucket capacities geometrically (and optionally re-salt the hash
functions) and re-run.  Capacities are static shapes, so each retry re-jits;
retries are rare under the plan defaults and the cost is off the hot path.

``engine_count`` is the preferred entry point: it dispatches to the fused
``core.engine.MultiwayJoinEngine``, which keeps the exact partitions from
the first pass and re-runs only the skewed shards (one fused kernel launch
per round instead of h_parts × g_parts of them).  The ``*_auto`` whole-query
retry drivers remain as the scan-based baseline.
"""

from __future__ import annotations

from typing import Any

from repro.core import cyclic3, engine, linear3, recovery, star3


class OverflowError_(RuntimeError):
    pass


def engine_count(kind: str, r, s, t, plan=None, *, m_budget: int | None = None,
                 use_kernel: bool = False, max_rounds: int = 3,
                 growth: float = 2.0, base_salt: int = 0,
                 **cols) -> engine.EngineResult:
    """Fused-engine count with surgical skew recovery (exact by
    construction; ``overflowed`` is always False on return)."""
    eng = engine.MultiwayJoinEngine(kind, use_kernel=use_kernel,
                                    max_rounds=max_rounds, growth=growth,
                                    base_salt=base_salt)
    return eng.count(r, s, t, plan, m_budget=m_budget, **cols)


def engine_per_r_counts(r, s, t, plan, *, use_kernel: bool = False,
                        max_rounds: int = 3, growth: float = 2.0,
                        base_salt: int = 0, **cols) -> engine.PerRResult:
    """Fused-engine per-R-tuple counts (Example 1) with skew recovery."""
    eng = engine.MultiwayJoinEngine("linear", use_kernel=use_kernel,
                                    max_rounds=max_rounds, growth=growth,
                                    base_salt=base_salt)
    return eng.per_r_counts(r, s, t, plan, **cols)


def _grown(plan: Any, growth: float, align: int = 8) -> Any:
    return recovery.grown(plan, growth, align)


def linear3_count_auto(r, s, t, plan: linear3.Linear3Plan, *,
                       max_retries: int = 4, growth: float = 2.0, **kw):
    """linear3_count with geometric capacity growth on overflow."""
    for _ in range(max_retries + 1):
        res = linear3.linear3_count(r, s, t, plan, **kw)
        if not bool(res.overflowed):
            return res, plan
        plan = _grown(plan, growth)
    raise OverflowError_(f"linear3 overflow persisted; final plan {plan}")


def linear3_per_r_counts_auto(r, s, t, plan: linear3.Linear3Plan, *,
                              max_retries: int = 4, growth: float = 2.0, **kw):
    for _ in range(max_retries + 1):
        keys, counts, valid, ovf = linear3.linear3_per_r_counts(
            r, s, t, plan, **kw)
        if not bool(ovf):
            return (keys, counts, valid), plan
        plan = _grown(plan, growth)
    raise OverflowError_(f"linear3 per-r overflow persisted; final plan {plan}")


def cyclic3_count_auto(r, s, t, plan: cyclic3.Cyclic3Plan, *,
                       max_retries: int = 4, growth: float = 2.0, **kw):
    for _ in range(max_retries + 1):
        res = cyclic3.cyclic3_count(r, s, t, plan, **kw)
        if not bool(res.overflowed):
            return res, plan
        plan = _grown(plan, growth)
    raise OverflowError_(f"cyclic3 overflow persisted; final plan {plan}")


def star3_count_auto(r, s, t, plan: star3.Star3Plan, *,
                     max_retries: int = 4, growth: float = 2.0, **kw):
    for _ in range(max_retries + 1):
        res = star3.star3_count(r, s, t, plan, **kw)
        if not bool(res.overflowed):
            return res, plan
        plan = _grown(plan, growth)
    raise OverflowError_(f"star3 overflow persisted; final plan {plan}")
