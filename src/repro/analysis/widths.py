"""Integer-width dataflow analysis over :class:`~repro.core.plan_ir.QueryPlan`.

Everything index-shaped in the engine is int32: composite bucket ids
(``kernels/ops.composite_ids``), flat slot indexes (``partition.bucketize``,
``bucket * capacity + slot``), per-cell fused accumulators, materialized
intermediate row indexes, and the static multipliers feeding
``engine.traffic64``.  Today a mis-sized plan dies in a scattered runtime
``ValueError`` deep inside ``partition._check_flat_range`` — after the
planner has committed, and only on the code paths that still check.  On
compiled TPU kernels and a device mesh (ROADMAP items 2 and 4) the same
mistake is a silently wrapped int32, i.e. a wrong join count.

This pass walks the DAG once with whatever cardinalities it has — planner
estimates at plan time (``est_rows``/``est_out``), live ``Relation.n``
values at execute under ``REPRO_VERIFY_PLANS=1`` — sizes each fused step's
partition shape exactly the way ``_run_fused3`` will (``shape_plan`` if
pinned, else ``MultiwayJoinEngine.default_plan`` from the cards), and
bounds every width-sensitive quantity.  Each diagnostic names the step,
the quantity, the computed bound, and the width the value would need.

Severities:

``error``
    A bound the engine *guarantees* to exceed: a composite-id space or
    flat slot range past int32 (``composite_ids`` / ``bucketize`` would
    raise, or a compiled kernel would wrap), an intermediate estimated at
    >= 2^31 rows (``execute_plan`` refuses to materialize it), a Traffic64
    static multiplier outside ``0 < k < 2^31``.  :func:`check_widths`
    raises :class:`PlanWidthError` carrying these.

``hazard``
    A data-dependent worst case worth surfacing but not failing on: the
    skew-recovery growth rounds pushing flat slot ranges toward int32, a
    per-cell accumulator whose capacity-product ceiling crosses the 2^24
    exact-f32 range (``kernels.ops.EXACT_F32_MAX`` — relevant the moment a
    compiled kernel accumulates in f32) or int32.  These products are
    *ceilings* (every bucket full, every pair matching), so treating them
    as errors would flag every healthy plan.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Mapping

from repro.analysis.errors import PlanWidthError
from repro.core import engine, plan_ir, recovery
from repro.kernels.ops import EXACT_F32_MAX

_INT32_MAX = 2**31 - 1
_INT32_ROWS = 2**31          # materialize / cardinality ceiling
_TRAFFIC_MAX = 2**61         # Traffic64 two-limb total ceiling


@dataclasses.dataclass(frozen=True)
class WidthDiagnostic:
    """One width finding: ``quantity`` at ``step_out`` needs
    ``width_needed`` but the engine gives it ``limit``."""

    step_index: int
    step_out: str
    quantity: str            # e.g. "composite-id space (role r)"
    bound: int               # the computed bound
    limit: int               # the width ceiling it is judged against
    width_needed: str        # e.g. "int35" — bits the bound requires
    severity: str            # "error" | "hazard"
    detail: str

    def __str__(self) -> str:
        return (f"[{self.severity}] step[{self.step_index}] "
                f"{self.step_out}: {self.quantity} = {self.bound} "
                f"exceeds {self.limit} (needs {self.width_needed}) — "
                f"{self.detail}")


def _width(bound: int) -> str:
    """Signed integer width a positive bound requires."""
    return f"int{max(8, int(bound).bit_length() + 1)}"


def _diag(out, index, quantity, bound, limit, severity, detail):
    return WidthDiagnostic(index, out, quantity, int(bound), int(limit),
                           _width(bound), severity, detail)


def _grown_caps(shape, growth: float, rounds: int):
    """Worst-round capacities: ``recovery.grown`` applied ``rounds`` times."""
    for _ in range(max(0, rounds)):
        shape = recovery.grown(shape, growth)
    return shape


def _fused_spaces(kind: str, cols: dict, shape):
    """(role, composite-id space, bucket capacity) per hashed relation,
    exactly as ``recovery`` lays them out."""
    ops = recovery.OPS[kind](**cols)
    caps = {"r": shape.r_cap, "s": shape.s_cap, "t": shape.t_cap}
    out = []
    for role, (_specs, out_shape) in ops.specs(shape).items():
        out.append((role, math.prod(out_shape), caps[role]))
    if kind == "star":
        # S is bucketed by s_pass: chunks x uh x ug (see StarOps.s_pass)
        out.append(("s", shape.chunks * shape.uh * shape.ug, caps["s"]))
    return out


def _accum_cell_bound(kind: str, shape) -> int:
    """Capacity-product ceiling of one fused accumulator cell.

    Each cell counts matches driven by one bucket of the driving relation:
    every driving row can match at most ``cap`` rows per joined bucket,
    summed over the streamed dimension (g_parts / f_parts / chunks)."""
    if kind == "linear":     # cell [hp, u]: r_cap rows x Σ_g s_cap·t_cap
        return shape.r_cap * shape.g_parts * shape.s_cap * shape.t_cap
    if kind == "cyclic":     # cell [hp, gp, uh, ug]: r_cap x Σ_f s·t
        return shape.r_cap * shape.f_parts * shape.s_cap * shape.t_cap
    # star, cell [uh, ug]: Σ_chunks s_cap fact rows x r_cap x t_cap
    return shape.chunks * shape.s_cap * shape.r_cap * shape.t_cap


def _traffic_terms(kind: str, shape, in_rows: dict):
    """(static multiplier, estimated rows) per ``engine.traffic64`` term —
    mirrors each kind's ``tuples_read``."""
    r, s, t = (in_rows.get(k) for k in ("r", "s", "t"))
    if kind == "linear":
        return [(1, r), (1, s), (shape.h_parts, t)]
    if kind == "cyclic":
        return [(1, r), (shape.h_parts, s), (shape.g_parts, t)]
    return [(1, r), (1, s), (1, t)]


def _check_fused(step, index, shape, in_rows, plan, diags) -> None:
    cols = dict(step.cols)
    kind = step.kind
    for role, space, cap in _fused_spaces(kind, cols, shape):
        if space > _INT32_MAX:
            diags.append(_diag(
                step.out, index, f"composite-id space (role {role})",
                space, _INT32_MAX, "error",
                "partition.composite_ids flat bucket ids are int32; this "
                "shape cannot be hashed — shrink the partition grid or "
                "raise m_budget"))
            continue                      # slots are hopeless too
        slots = space * cap + 1           # bucketize: bucket*cap + slot
        if slots > _INT32_MAX:
            diags.append(_diag(
                step.out, index, f"flat slot range (role {role})",
                slots, _INT32_MAX, "error",
                "partition.bucketize scatters into bucket*capacity+slot "
                "int32 ids; shrink capacities or the partition grid"))
        else:
            worst = _grown_caps(shape, plan.growth, plan.max_rounds)
            wcap = {"r": worst.r_cap, "s": worst.s_cap,
                    "t": worst.t_cap}[role]
            wslots = space * wcap + 1
            if wslots > _INT32_MAX:
                diags.append(_diag(
                    step.out, index,
                    f"grown flat slot range (role {role}, "
                    f"round {plan.max_rounds})", wslots, _INT32_MAX,
                    "hazard",
                    "skew-recovery capacity growth could push the flat "
                    "slot range past int32 on the worst round; recovery "
                    "would fail late instead of at plan time"))
    cell = _accum_cell_bound(kind, shape)
    if cell > _INT32_MAX:
        diags.append(_diag(
            step.out, index, "accumulator cell ceiling", cell,
            _INT32_MAX, "hazard",
            "fused per-cell partials are int32; the capacity-product "
            "ceiling of one cell crosses 2^31 — only reachable under "
            "total skew, but a compiled kernel would wrap silently"))
    elif cell > EXACT_F32_MAX:
        diags.append(_diag(
            step.out, index, "accumulator cell ceiling", cell,
            EXACT_F32_MAX, "hazard",
            "one fused accumulator cell could exceed the 2^24 exact-f32 "
            "range; any compiled kernel lowering these partials to f32 "
            "would lose counts — keep int32 accumulation"))
    # Traffic64: static multipliers must satisfy 0 < k < 2^31, and the
    # two-limb total holds up to 2^61.
    roles = dict(step.roles)
    rows = {role: in_rows.get(roles[role]) for role in ("r", "s", "t")}
    total = 0
    for k, n in _traffic_terms(kind, shape, rows):
        if not 0 < k < 2**31:
            diags.append(_diag(
                step.out, index, "Traffic64 static multiplier", k,
                _INT32_MAX, "error",
                "engine.traffic64 requires 0 < k < 2^31 for its 15-bit "
                "limb split; this partition count cannot be metered"))
        elif n is not None:
            total += k * n
    if total > _TRAFFIC_MAX:
        diags.append(_diag(
            step.out, index, "Traffic64 total", total, _TRAFFIC_MAX,
            "hazard",
            "estimated tuples_read exceeds the two-limb 2^61 ceiling; "
            "the traffic meter would wrap"))


def analyze_widths(plan: plan_ir.QueryPlan,
                   cards: Mapping[str, int] | None = None,
                   ) -> tuple[WidthDiagnostic, ...]:
    """Bound every width-sensitive quantity in ``plan``.

    ``cards`` maps input names to row counts — live ``Relation.n`` values
    at execute time, or planner estimates; step-level ``est_rows`` /
    ``est_out`` fill the gaps.  Quantities whose cardinalities are unknown
    are skipped (never guessed), so an estimate-free plan only gets the
    purely static checks (pinned shape plans, traffic multipliers).
    """
    diags: list[WidthDiagnostic] = []
    rows: dict[str, int] = {k: int(v) for k, v in (cards or {}).items()}
    for index, step in enumerate(plan.steps):
        in_rows: dict[str, int] = {}
        for pos, name in enumerate(step.inputs):
            n = rows.get(name)
            if n is None and pos < len(step.est_rows):
                n = int(step.est_rows[pos])
            if n is not None:
                in_rows[name] = n
        for name, n in in_rows.items():
            if n >= _INT32_ROWS:
                diags.append(_diag(
                    step.out, index, f"input cardinality ({name})", n,
                    _INT32_ROWS - 1, "error",
                    "row indexes, sort permutations and bucket ids are "
                    "int32; a relation this large cannot be processed"))
        if step.op == "binary":
            out_rows = step.est_out
            if out_rows is not None and not step.aggregate:
                if out_rows >= _INT32_ROWS:
                    diags.append(_diag(
                        step.out, index, "materialized rows", out_rows,
                        _INT32_ROWS - 1, "error",
                        "execute_plan refuses to materialize >= 2^31 "
                        "rows; re-plan with strategy='3way' (the fused "
                        "engine never materializes the join output)"))
                rows.setdefault(step.out, int(out_rows))
        elif step.op == "fused3" and step.kind in recovery.OPS:
            shape = step.shape_plan
            if shape is None and len(in_rows) == 3 and plan.m_budget:
                roles = dict(step.roles)
                eng = engine.MultiwayJoinEngine(step.kind)
                shape = eng.default_plan(
                    in_rows[roles["r"]], in_rows[roles["s"]],
                    in_rows[roles["t"]], m_budget=plan.m_budget)
            if shape is not None:
                _check_fused(step, index, shape, in_rows, plan, diags)
    return tuple(diags)


def check_widths(plan: plan_ir.QueryPlan,
                 cards: Mapping[str, int] | None = None,
                 ) -> tuple[WidthDiagnostic, ...]:
    """Run :func:`analyze_widths`; raise :class:`PlanWidthError` if any
    diagnostic is an error.  Returns the full diagnostic tuple (hazards
    included) so callers can log them."""
    diags = analyze_widths(plan, cards)
    errors = [d for d in diags if d.severity == "error"]
    if errors:
        lines = "\n".join(f"  {d}" for d in errors)
        raise PlanWidthError(
            f"plan fails integer-width analysis "
            f"({len(errors)} error(s)):\n{lines}", diagnostics=diags)
    return diags
