"""SSM (mamba2) and hybrid (zamba2) language models.

mamba2-370m: a pure stack of SSD blocks (attention-free).
zamba2-1.2b: a Mamba2 backbone with ONE shared transformer block (attention
+ MLP, single parameter set) invoked after every `hybrid_every` SSM layers —
the Zamba2 weight-sharing trick (arXiv:2411.15242).  Simplifications vs. the
released model (documented in DESIGN.md): no per-invocation LoRA on the
shared block and the shared block consumes the running hidden state directly
(no concat with the original embedding).

Both families carry O(1)-per-token state, so they own the long_500k shape.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention, layers, ssm, transformer
from repro.models.config import ModelConfig
from repro.parallel import shard


def init_ssm_block(key, cfg):
    return {
        "ln": layers.init_rms_norm(cfg.d_model),
        "ssm": ssm.init_ssm(key, cfg),
    }


def _ssm_block_forward(p, cfg, x):
    h = layers.rms_norm(x, p["ln"]["scale"], cfg.norm_eps)
    x = x + ssm.ssd_forward(h, p["ssm"], cfg)
    return shard(x, ("batch", "seq_res", "embed"))


def _ssm_block_decode(p, cfg, x, state, conv):
    h = layers.rms_norm(x, p["ln"]["scale"], cfg.norm_eps)
    y, state, conv = ssm.ssd_decode_step(h, p["ssm"], cfg, state, conv)
    return x + y, state, conv


def init_lm(key, cfg: ModelConfig):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    params = {
        "embed": layers.init_embed(k1, cfg.vocab_size, cfg.d_model),
        "layers": transformer._stack_init(
            lambda k: init_ssm_block(k, cfg), k2, cfg.n_layers),
        "final_norm": layers.init_rms_norm(cfg.d_model),
    }
    if cfg.is_hybrid:
        params["shared_block"] = transformer.init_block(k3, cfg)
    if not cfg.tie_embeddings:
        params["lm_head"] = layers.init_embed(k4, cfg.vocab_size, cfg.d_model)
    return params


def _n_shared_invocations(cfg) -> int:
    return cfg.n_layers // cfg.hybrid_every if cfg.is_hybrid else 0


def _split_groups(cfg, stacked):
    """[L, ...] ssm stack -> ([G, every, ...] grouped, [tail, ...])."""
    n_inv = _n_shared_invocations(cfg)
    main = n_inv * cfg.hybrid_every
    grouped = jax.tree.map(
        lambda a: a[:main].reshape((n_inv, cfg.hybrid_every) + a.shape[1:]),
        stacked)
    tail = jax.tree.map(lambda a: a[main:], stacked)
    return grouped, tail


def forward(params, cfg: ModelConfig, tokens, memory=None):
    del memory
    b, s = tokens.shape
    dt = layers.dtype_of(cfg.dtype)
    x = layers.embed(tokens, params["embed"]["table"], dt)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    block = _ssm_block_forward
    if cfg.remat:
        block = jax.checkpoint(block, static_argnums=(1,))

    def ssm_scan(x, stacked):
        def step(x, p):
            return block(p, cfg, x), None
        x, _ = jax.lax.scan(step, x, stacked)
        return x

    if not cfg.is_hybrid:
        x = ssm_scan(x, params["layers"])
    else:
        grouped, tail = _split_groups(cfg, params["layers"])

        def shared(x):
            y, _ = transformer.block_forward(
                params["shared_block"], cfg, x, positions,
                jnp.int32(0), jnp.float32(cfg.rope_theta))
            return y

        if cfg.remat:
            shared = jax.checkpoint(shared)

        def group_step(x, ps):
            x = ssm_scan(x, ps)
            return shared(x), None

        x, _ = jax.lax.scan(group_step, x, grouped)
        x = ssm_scan(x, tail)

    x = layers.rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)
    table = (params["embed"]["table"] if cfg.tie_embeddings
             else params["lm_head"]["table"])
    return layers.unembed(x, table), {}


# --------------------------------------------------------------------------
# serving
# --------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16):
    cache = ssm.init_ssm_cache(cfg, batch, cfg.n_layers)
    cache["length"] = jnp.zeros((), jnp.int32)
    if cfg.is_hybrid:
        n_inv = _n_shared_invocations(cfg)
        kv = attention.init_kv_cache(cfg, batch, max_len, n_layers=n_inv,
                                     dtype=dtype)
        cache["k"], cache["v"] = kv["k"], kv["v"]
    return cache


def decode_step(params, cfg: ModelConfig, cache, tokens):
    dt = layers.dtype_of(cfg.dtype)
    x = layers.embed(tokens, params["embed"]["table"], dt)
    length = cache["length"]

    def ssm_scan(x, stacked, states, convs):
        def step(x, xs):
            p, st, cv = xs
            x, st, cv = _ssm_block_decode(p, cfg, x, st, cv)
            return x, (st, cv)
        x, (new_st, new_cv) = jax.lax.scan(step, x, (stacked, states, convs))
        return x, new_st, new_cv

    if not cfg.is_hybrid:
        x, new_state, new_conv = ssm_scan(x, params["layers"],
                                          cache["state"], cache["conv"])
        new_cache = dict(cache, state=new_state, conv=new_conv,
                         length=length + 1)
    else:
        n_inv = _n_shared_invocations(cfg)
        main = n_inv * cfg.hybrid_every
        grouped, tail = _split_groups(cfg, params["layers"])
        st_g = jax.tree.map(
            lambda a: a[:main].reshape((n_inv, cfg.hybrid_every)
                                       + a.shape[1:]), cache["state"])
        cv_g = jax.tree.map(
            lambda a: a[:main].reshape((n_inv, cfg.hybrid_every)
                                       + a.shape[1:]), cache["conv"])
        sb = params["shared_block"]

        def shared_decode(x, lk, lv):
            h = layers.rms_norm(x, sb["ln_attn"]["scale"], cfg.norm_eps)
            lk, lv = attention.append_kv(sb["attn"], cfg, h, lk, lv, length)
            x = x + attention.decode_attention(sb["attn"], cfg, h, lk, lv,
                                               length)
            h = layers.rms_norm(x, sb["ln_mlp"]["scale"], cfg.norm_eps)
            x = x + layers.glu_mlp(h, sb["mlp"], cfg.act)
            return x, lk, lv

        def group_step(x, xs):
            ps, sts, cvs, lk, lv = xs
            x, new_st, new_cv = ssm_scan(x, ps, sts, cvs)
            x, lk, lv = shared_decode(x, lk, lv)
            return x, (new_st, new_cv, lk, lv)

        x, (st_new, cv_new, k_new, v_new) = jax.lax.scan(
            group_step, x, (grouped, st_g, cv_g, cache["k"], cache["v"]))
        x, st_tail, cv_tail = ssm_scan(
            x, tail, jax.tree.map(lambda a: a[main:], cache["state"]),
            jax.tree.map(lambda a: a[main:], cache["conv"]))
        new_state = jnp.concatenate(
            [st_new.reshape((main,) + st_new.shape[2:]), st_tail], axis=0)
        new_conv = jnp.concatenate(
            [cv_new.reshape((main,) + cv_new.shape[2:]), cv_tail], axis=0)
        new_cache = dict(cache, state=new_state, conv=new_conv, k=k_new,
                         v=v_new, length=length + 1)

    x = layers.rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)
    table = (params["embed"]["table"] if cfg.tie_embeddings
             else params["lm_head"]["table"])
    return layers.unembed(x, table), new_cache


def prefill(params, cfg: ModelConfig, tokens, cache, memory=None):
    """Full-sequence prefill: chunked SSD per layer, capturing the final
    recurrent state + conv window of every layer (and the shared block's
    K/V for the hybrid) — all under layer scans."""
    del memory
    b, s = tokens.shape
    dt = layers.dtype_of(cfg.dtype)
    x = layers.embed(tokens, params["embed"]["table"], dt)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    length = jnp.asarray(s, jnp.int32)

    def one_layer(x, p):
        h = layers.rms_norm(x, p["ln"]["scale"], cfg.norm_eps)
        y, st, cv = ssm.ssd_prefill(h, p["ssm"], cfg)
        return x + y, st, cv

    if cfg.remat:
        one_layer = jax.checkpoint(one_layer)

    def ssm_scan(x, stacked):
        def step(x, p):
            x, st, cv = one_layer(x, p)
            return x, (st, cv)
        return jax.lax.scan(step, x, stacked)

    if not cfg.is_hybrid:
        x, (states, convs) = ssm_scan(x, params["layers"])
        new_cache = dict(cache, state=states, conv=convs, length=length)
    else:
        grouped, tail = _split_groups(cfg, params["layers"])
        sb = params["shared_block"]

        def shared_prefill(x):
            h = layers.rms_norm(x, sb["ln_attn"]["scale"], cfg.norm_eps)
            out, kk, vv = attention.self_attention(
                sb["attn"], cfg, h, positions, causal=True, return_kv=True)
            x = x + out
            h = layers.rms_norm(x, sb["ln_mlp"]["scale"], cfg.norm_eps)
            return x + layers.glu_mlp(h, sb["mlp"], cfg.act), kk, vv

        if cfg.remat:
            shared_prefill = jax.checkpoint(shared_prefill)

        def group_step(x, ps):
            x, (sts, cvs) = ssm_scan(x, ps)
            x, kk, vv = shared_prefill(x)
            return x, (sts, cvs, kk, vv)

        x, (st_g, cv_g, ks, vs) = jax.lax.scan(group_step, x, grouped)
        x, (st_t, cv_t) = ssm_scan(x, tail)
        main = st_g.shape[0] * st_g.shape[1]
        states = jnp.concatenate(
            [st_g.reshape((main,) + st_g.shape[2:]), st_t], axis=0)
        convs = jnp.concatenate(
            [cv_g.reshape((main,) + cv_g.shape[2:]), cv_t], axis=0)
        # write shared-block K/V ([n_inv, B, S, KVH, D]) into cache prefix
        new_k = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], ks.astype(cache["k"].dtype), 0, axis=2)
        new_v = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], vs.astype(cache["v"].dtype), 0, axis=2)
        new_cache = dict(cache, state=states, conv=convs, k=new_k, v=new_v,
                         length=length)

    x = layers.rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)
    table = (params["embed"]["table"] if cfg.tie_embeddings
             else params["lm_head"]["table"])
    logits = layers.unembed(x[:, -1:], table)
    return logits, new_cache
