"""Unified model configuration covering the 10 assigned architectures."""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | encdec | vlm | audio
    n_layers: int               # decoder layers
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0           # 0 -> d_model // n_heads

    # attention
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 1e4
    rope_local_theta: float = 0.0   # gemma3 local layers (0 = use rope_theta)
    sliding_window: int = 0         # 0 = full attention
    local_pattern: int = 0          # N local layers per 1 global (gemma3: 5)

    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0               # per-expert hidden dim
    n_shared_experts: int = 0
    norm_topk: bool = True

    # SSM (Mamba2 / SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_conv: int = 4
    ssm_ngroups: int = 1

    # hybrid (Zamba2): shared attn block applied every k SSM layers
    hybrid_every: int = 0

    # enc-dec (seamless backbone): encoder depth (0 = decoder-only)
    n_enc_layers: int = 0
    # vision (llama-3.2-vision): cross-attn layer every k self-attn layers
    cross_attn_every: int = 0
    n_frontend_tokens: int = 0      # stubbed modality frontend sequence length

    act: str = "silu"               # silu (swiglu) | gelu (geglu)
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    remat: bool = True
    # outer remat group size (0 = flat layer scan).  k>0 nests the layer
    # scan: an outer checkpointed scan over L/k groups × an inner scan of k
    # (individually rematted) blocks — sqrt-L remat: live saved residuals
    # drop from L·|x| to (L/k + k)·|x| for one extra recompute.
    scan_group: int = 0
    # shard the residual-stream sequence dim over "model" (Megatron-style
    # sequence parallelism).  Trades two extra collectives per block for a
    # model-axis-wide reduction in activation memory.
    seq_shard: bool = False
    # microbatch gradient accumulation: the train step scans over
    # `accum_steps` microbatches, accumulating f32 grads — live activation
    # memory drops ~accum_steps× for one extra f32 grad buffer.
    accum_steps: int = 1
    # MoE dispatch implementation: "shard_map" (local partition + expert
    # routing — the paper's partition phase; §Perf) or "gspmd" (naive
    # global dispatch, kept as the reproducible baseline).
    moe_impl: str = "shard_map"

    # serving
    max_cache_len: int = 0          # set per shape at lowering time

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def is_ssm(self) -> bool:
        return self.family == "ssm"

    @property
    def is_hybrid(self) -> bool:
        return self.family == "hybrid"

    @property
    def d_inner_ssm(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner_ssm // self.ssm_headdim

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch serve 500k+ contexts (bounded state)?"""
        return self.family in ("ssm", "hybrid")

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks)."""
        d, ff, v = self.d_model, self.d_ff, self.vocab_size
        hd = self.head_dim
        attn = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd) \
            + (self.n_heads * hd) * d
        dense_mlp = 3 * d * ff
        if self.is_moe:
            mlp = self.n_experts * 3 * d * self.moe_d_ff \
                + self.n_shared_experts * 3 * d * self.moe_d_ff \
                + d * self.n_experts
        else:
            mlp = dense_mlp
        if self.family == "ssm":
            block = self._ssm_block_params()
            core = self.n_layers * block
        elif self.family == "hybrid":
            n_shared = 1
            core = self.n_layers * self._ssm_block_params() \
                + n_shared * (attn + dense_mlp)
        else:
            core = self.n_layers * (attn + mlp)
            if self.cross_attn_every:
                core += (self.n_layers // self.cross_attn_every) * attn
            if self.n_enc_layers:
                core += self.n_enc_layers * (attn + dense_mlp) \
                    + self.n_layers * attn  # decoder cross-attn
        embed = v * d * (1 if self.tie_embeddings else 2)
        return int(core + embed)

    def _ssm_block_params(self) -> int:
        d, di, st = self.d_model, self.d_inner_ssm, self.ssm_state
        g = self.ssm_ngroups
        in_proj = d * (2 * di + 2 * g * st + self.n_ssm_heads)
        out_proj = di * d
        return in_proj + out_proj + self.ssm_conv * (di + 2 * g * st)

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed experts count)."""
        if not self.is_moe:
            return self.param_count()
        d = self.d_model
        total = self.param_count()
        all_experts = self.n_layers * self.n_experts * 3 * d * self.moe_d_ff
        active = self.n_layers * (self.top_k + self.n_shared_experts) \
            * 3 * d * self.moe_d_ff
        return int(total - all_experts + active)
