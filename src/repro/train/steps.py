"""train_step / serve_step builders — the functions the launcher jits and
the dry-run lowers.

Batch format:
  {"inputs": [B, S] int32, "targets": [B, S] int32,
   optional "memory": [B, T_frontend, d_model] (stubbed modality frontend)}

The backward pass is overlapped with the gradient cross-replica reduction by
XLA (donated buffers + standard SPMD latency hiding); optional int8
error-feedback compression for the cross-pod axis lives in
``repro.optim.compression`` and is applied by the launcher when enabled.
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.models.zoo import Model
from repro.optim import AdamWConfig, adamw_init, adamw_update


class TrainState(NamedTuple):
    params: Any
    opt: Any
    step: jnp.ndarray


def init_train_state(model: Model, key) -> TrainState:
    params = model.init(key)
    return TrainState(params, adamw_init(params), jnp.zeros((), jnp.int32))


def cross_entropy_loss(logits, targets, z_loss: float = 1e-4):
    """Mean token NLL (+ z-loss for logit drift control).  logits f32."""
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None],
                               axis=-1)[..., 0]
    nll = lse - gold
    loss = jnp.mean(nll)
    if z_loss:
        loss = loss + z_loss * jnp.mean(jnp.square(lse))
    return loss


def make_train_step(model: Model, opt_cfg: AdamWConfig,
                    moe_aux_weight: float = 1e-2,
                    accum_steps: int | None = None):
    """Returns train_step(state, batch) -> (state, metrics).

    accum_steps > 1 (default: model.config.accum_steps): the step scans
    over microbatches accumulating f32 gradients — peak activation memory
    drops ~accum_steps× at the cost of one params-sized f32 buffer, and
    the data-parallel gradient reduction overlaps microbatch compute.
    """
    accum = accum_steps if accum_steps is not None \
        else getattr(model.config, "accum_steps", 1) or 1

    def loss_fn(params, batch):
        logits, aux = model.forward(params, batch["inputs"],
                                    memory=batch.get("memory"))
        loss = cross_entropy_loss(logits, batch["targets"])
        if aux and "aux_loss" in aux:
            loss = loss + moe_aux_weight * aux["aux_loss"]
        return loss, aux

    def train_step(state: TrainState, batch):
        if accum <= 1:
            (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                state.params, batch)
        else:
            b = batch["inputs"].shape[0]
            assert b % accum == 0, (b, accum)

            def split(x):
                return x.reshape((accum, b // accum) + x.shape[1:])

            micro = {k: split(v) for k, v in batch.items()}
            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)

            def mb_step(carry, mbatch):
                gacc, lacc = carry
                (l, aux_i), g = jax.value_and_grad(
                    loss_fn, has_aux=True)(state.params, mbatch)
                gacc = jax.tree.map(
                    lambda a, x: a + x.astype(jnp.float32), gacc, g)
                return (gacc, lacc + l), aux_i

            (grads, loss_sum), auxes = jax.lax.scan(
                mb_step, (g0, jnp.zeros((), jnp.float32)), micro)
            grads = jax.tree.map(lambda g: g / accum, grads)
            loss = loss_sum / accum
            aux = jax.tree.map(lambda a: jnp.mean(a, axis=0), auxes) \
                if auxes else {}
        params, opt, om = adamw_update(state.params, grads, state.opt,
                                       opt_cfg)
        metrics = {"loss": loss, **om}
        if aux:
            metrics.update({k: v for k, v in aux.items()})
        return TrainState(params, opt, state.step + 1), metrics

    return train_step


def make_prefill_step(model: Model):
    def prefill_step(params, tokens, cache, memory=None):
        return model.prefill(params, tokens, cache, memory=memory)
    return prefill_step


def make_decode_step(model: Model, sample_greedy: bool = True):
    """serve_step: one token for every sequence in the batch."""

    def decode_step(params, cache, tokens):
        logits, cache = model.decode_step(params, cache, tokens)
        if sample_greedy:
            nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        else:
            nxt = tokens[:, -1]
        return nxt[:, None], logits, cache

    return decode_step
