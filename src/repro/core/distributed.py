"""Distributed multiway joins on the device mesh (shard_map).

The paper's on-chip network routing maps 1:1 onto mesh collectives:

  Plasticine                          TPU mesh ("row" × "col")
  ---------------------------------   --------------------------------------
  route r(a,b) → PMU[h(a), g(b)]      two-phase all_to_all (rows, then cols)
  broadcast s(b,c) down column g(b)   all_to_all to column + all_gather rows
  broadcast t(c,a) across row h(a)    all_to_all to row + all_gather cols
  per-PMU bucket join                 per-device core join (Pallas kernels)
  merge partial aggregates            psum (counts) / OR-reduce (FM sketches)

Relations enter sharded in arrival order over all devices (the "DRAM-
resident, evenly striped" state); the shuffle phases above are the
partitioning the paper configures the accelerator to perform first (§4).

Everything is static-shape: the shuffles use fixed-capacity per-destination
send buffers, and overflow is psum-reduced and reported, never hidden.

The same functions compile on the 2-pod production mesh: the "pod" axis is
folded into "row" (joins scale out along rows; the extra hop is the paper's
multi-chip case, and the collective-term roofline in EXPERIMENTS.md
quantifies it).
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import compat
from repro.core import cyclic3, engine, hashing, linear3, partition, star3
from repro.core.relation import Relation
from repro.kernels import ops as kops


class DistJoinResult(NamedTuple):
    count: jnp.ndarray       # () int32, global
    overflowed: jnp.ndarray  # () bool, any shuffle/bucket overflow anywhere


# --------------------------------------------------------------------------
# shuffle primitives (inside shard_map)
# --------------------------------------------------------------------------

def _to_buckets(cols: dict, valid: jnp.ndarray, dest: jnp.ndarray,
                n_dest: int, cap: int):
    """Pack local rows into [n_dest, cap] send buffers (+ overflow flag)."""
    rel = Relation(cols, valid)
    ids = jnp.where(valid, dest, jnp.int32(n_dest))
    b = partition.bucketize_by_ids(rel, ids, n_dest, cap, (n_dest,))
    return b.columns, b.valid, b.overflowed


def _all_to_all(cols: dict, valid: jnp.ndarray, axis: str):
    """Exchange [n_dest, cap] buffers along a mesh axis → received rows,
    flattened back to a local [n_src * cap] relation."""
    def xc(x):
        out = jax.lax.all_to_all(x, axis, split_axis=0, concat_axis=0,
                                 tiled=True)
        return out.reshape((-1,))
    return {k: xc(v) for k, v in cols.items()}, xc(valid)


def _shuffle(cols: dict, valid: jnp.ndarray, key_col: str, axis: str,
             n_dest: int, cap: int, fn: str):
    """Route rows to the device at position hash(key) along `axis`."""
    dest = hashing.hash_bucket(cols[key_col], n_dest, fn)
    bcols, bvalid, ovf = _to_buckets(cols, valid, dest, n_dest, cap)
    cols2, valid2 = _all_to_all(bcols, bvalid, axis)
    return cols2, valid2, ovf


def _replicate(cols: dict, valid: jnp.ndarray, axis: str):
    """all_gather along `axis` (the paper's broadcast) → concatenated rows."""
    def g(x):
        return jax.lax.all_gather(x, axis, axis=0, tiled=True)
    return {k: g(v) for k, v in cols.items()}, g(valid)


def _or_all(x: jnp.ndarray, axes) -> jnp.ndarray:
    """Global bitwise-OR via all_gather + local reduce (for FM bitmaps)."""
    for ax in axes:
        g = jax.lax.all_gather(x, ax, axis=0)
        x = jax.lax.reduce(g, jnp.int32(0), jax.lax.bitwise_or, (0,))
    return x


def _psum_bool(x: jnp.ndarray, axes) -> jnp.ndarray:
    return jax.lax.psum(x.astype(jnp.int32), axes) > 0


# --------------------------------------------------------------------------
# distributed cyclic 3-way join (the paper's grid algorithm, §5.1)
# --------------------------------------------------------------------------

def cyclic3_count_sharded(mesh: Mesh, row: str, col: str,
                          *, shuffle_slack: float = 3.0,
                          local_uh: int = 4, local_ug: int = 4,
                          local_f: int = 2, local_slack: float = 3.0,
                          use_kernel: bool = False, fused: bool = False):
    """Build a jit-able distributed triangle-count:  f(R, S, T) -> result.

    R(a,b), S(b,c), T(c,a) arrive sharded in arrival order over the whole
    mesh (PartitionSpec((row, col)) on every column).  Device (i, j) ends up
    owning R tuples with (H(a), G(b)) == (i, j), the full S_j column
    partition and the full T_i row partition — exactly Fig 3.
    """
    nrow = mesh.shape[row]
    ncol = mesh.shape[col]

    def local(r_cols, r_valid, s_cols, s_valid, t_cols, t_valid):
        # --- R → cell (H(a), G(b)): two-phase all_to_all ----------------
        cap_r = partition.suggest_capacity(
            r_valid.shape[0], nrow, shuffle_slack)
        r1, rv1, ovf_r1 = _shuffle(r_cols, r_valid, "a", row, nrow, cap_r, "H")
        cap_r2 = partition.suggest_capacity(rv1.shape[0], ncol, shuffle_slack)
        r2, rv2, ovf_r2 = _shuffle(r1, rv1, "b", col, ncol, cap_r2, "G")

        # --- S → column G(b), replicated down the column ----------------
        cap_s = partition.suggest_capacity(
            s_valid.shape[0], ncol, shuffle_slack)
        s1, sv1, ovf_s = _shuffle(s_cols, s_valid, "b", col, ncol, cap_s, "G")
        s2, sv2 = _replicate(s1, sv1, row)

        # --- T → row H(a), replicated across the row --------------------
        cap_t = partition.suggest_capacity(
            t_valid.shape[0], nrow, shuffle_slack)
        t1, tv1, ovf_t = _shuffle(t_cols, t_valid, "a", row, nrow, cap_t, "H")
        t2, tv2 = _replicate(t1, tv1, col)

        # --- local grid join (coarse level done; fine level = VMEM) -----
        rl = Relation(r2, rv2)
        sl = Relation(s2, sv2)
        tl = Relation(t2, tv2)
        plan = cyclic3.Cyclic3Plan(
            h_parts=1, g_parts=1, uh=local_uh, ug=local_ug, f_parts=local_f,
            r_cap=partition.suggest_capacity(
                rl.capacity, local_uh * local_ug, local_slack),
            s_cap=partition.suggest_capacity(
                sl.capacity, local_f * local_ug, local_slack),
            t_cap=partition.suggest_capacity(
                tl.capacity, local_f * local_uh, local_slack))
        if fused:
            res = engine.cyclic3_count_fused(rl, sl, tl, plan,
                                             use_kernel=use_kernel)
        else:
            res = cyclic3.cyclic3_count(rl, sl, tl, plan,
                                        use_kernel=use_kernel)

        count = jax.lax.psum(res.count, (row, col))
        ovf = _psum_bool(ovf_r1 | ovf_r2 | ovf_s | ovf_t | res.overflowed,
                         (row, col))
        return count, ovf

    spec = P((row, col))

    def fn(r: Relation, s: Relation, t: Relation) -> DistJoinResult:
        sm = compat.shard_map(
            lambda rc, rv, sc, sv, tc, tv: local(rc, rv, sc, sv, tc, tv),
            mesh=mesh,
            in_specs=(spec,) * 6,
            out_specs=(P(), P()))
        count, ovf = sm(dict(r.columns), r.valid, dict(s.columns), s.valid,
                        dict(t.columns), t.valid)
        return DistJoinResult(count, ovf)

    return fn


# --------------------------------------------------------------------------
# distributed linear 3-way join (§4, Algorithm 1 on the mesh)
# --------------------------------------------------------------------------

def linear3_count_sharded(mesh: Mesh, row: str, col: str,
                          *, shuffle_slack: float = 3.0,
                          local_u: int = 8, local_g: int = 4,
                          local_slack: float = 3.0,
                          use_kernel: bool = False, fused: bool = False):
    """Distributed Algorithm 1: the whole mesh is the flat U-way PMU grid.

    R and S shuffle to device h(B) (two-phase: row then col hash of B);
    T is broadcast to every device (all_gather over both axes) — the
    |R||T|/M term of the cost model becomes the T all-gather bytes, which
    the roofline's collective term measures.  Call once per coarse H(B)
    partition when R exceeds aggregate device memory.
    """
    nrow = mesh.shape[row]
    ncol = mesh.shape[col]

    def local(r_cols, r_valid, s_cols, s_valid, t_cols, t_valid):
        cap_r = partition.suggest_capacity(r_valid.shape[0], nrow,
                                           shuffle_slack)
        r1, rv1, ovf_r1 = _shuffle(r_cols, r_valid, "b", row, nrow, cap_r, "H")
        cap_r2 = partition.suggest_capacity(rv1.shape[0], ncol, shuffle_slack)
        r2, rv2, ovf_r2 = _shuffle(r1, rv1, "b", col, ncol, cap_r2, "G")

        cap_s = partition.suggest_capacity(s_valid.shape[0], nrow,
                                           shuffle_slack)
        s1, sv1, ovf_s1 = _shuffle(s_cols, s_valid, "b", row, nrow, cap_s, "H")
        cap_s2 = partition.suggest_capacity(sv1.shape[0], ncol, shuffle_slack)
        s2, sv2, ovf_s2 = _shuffle(s1, sv1, "b", col, ncol, cap_s2, "G")

        # T broadcast to all devices (streamed bucket-by-bucket locally)
        t1, tv1 = _replicate(t_cols, t_valid, row)
        t2, tv2 = _replicate(t1, tv1, col)

        rl = Relation(r2, rv2)
        sl = Relation(s2, sv2)
        tl = Relation(t2, tv2)
        plan = linear3.Linear3Plan(
            h_parts=1, u=local_u, g_parts=local_g,
            r_cap=partition.suggest_capacity(rl.capacity, local_u,
                                             local_slack),
            s_cap=partition.suggest_capacity(sl.capacity,
                                             local_g * local_u, local_slack),
            t_cap=partition.suggest_capacity(tl.capacity, local_g,
                                             local_slack))
        if fused:
            res = engine.linear3_count_fused(rl, sl, tl, plan,
                                             use_kernel=use_kernel)
        else:
            res = linear3.linear3_count(rl, sl, tl, plan,
                                        use_kernel=use_kernel)
        count = jax.lax.psum(res.count, (row, col))
        ovf = _psum_bool(ovf_r1 | ovf_r2 | ovf_s1 | ovf_s2 | res.overflowed,
                         (row, col))
        return count, ovf

    spec = P((row, col))

    def fn(r: Relation, s: Relation, t: Relation) -> DistJoinResult:
        sm = compat.shard_map(
            local, mesh=mesh, in_specs=(spec,) * 6, out_specs=(P(), P()))
        count, ovf = sm(dict(r.columns), r.valid, dict(s.columns), s.valid,
                        dict(t.columns), t.valid)
        return DistJoinResult(count, ovf)

    return fn


# --------------------------------------------------------------------------
# distributed star 3-way join (§6.5)
# --------------------------------------------------------------------------

def star3_count_sharded(mesh: Mesh, row: str, col: str,
                        *, shuffle_slack: float = 3.0,
                        local_chunks: int = 1, local_slack: float = 3.0,
                        use_kernel: bool = False, fused: bool = False):
    """Distributed star join: R pinned by h(B) on rows (replicated along
    cols), T pinned by g(C) on cols (replicated along rows); each fact tuple
    s(b,c) is routed to exactly the one device (h(b), g(c)) — S crosses the
    network once, R and T are the only replicated (small) relations."""
    nrow = mesh.shape[row]
    ncol = mesh.shape[col]

    def local(r_cols, r_valid, s_cols, s_valid, t_cols, t_valid):
        # dimensions: shuffle to their axis position, replicate along other
        cap_r = partition.suggest_capacity(r_valid.shape[0], nrow,
                                           shuffle_slack)
        r1, rv1, ovf_r = _shuffle(r_cols, r_valid, "b", row, nrow, cap_r, "h")
        r2, rv2 = _replicate(r1, rv1, col)

        cap_t = partition.suggest_capacity(t_valid.shape[0], ncol,
                                           shuffle_slack)
        t1, tv1, ovf_t = _shuffle(t_cols, t_valid, "c", col, ncol, cap_t, "g")
        t2, tv2 = _replicate(t1, tv1, row)

        # fact: two-phase point routing (h(b) row, then g(c) col)
        cap_s = partition.suggest_capacity(s_valid.shape[0], nrow,
                                           shuffle_slack)
        s1, sv1, ovf_s1 = _shuffle(s_cols, s_valid, "b", row, nrow, cap_s, "h")
        cap_s2 = partition.suggest_capacity(sv1.shape[0], ncol, shuffle_slack)
        s2, sv2, ovf_s2 = _shuffle(s1, sv1, "c", col, ncol, cap_s2, "g")

        rl = Relation(r2, rv2)
        sl = Relation(s2, sv2)
        tl = Relation(t2, tv2)
        # local PMU grid: 1×1 coarse, uh×ug fine handled by star3 itself
        plan = star3.Star3Plan(
            uh=4, ug=4, chunks=local_chunks,
            r_cap=partition.suggest_capacity(rl.capacity, 4, local_slack),
            s_cap=partition.suggest_capacity(sl.capacity,
                                             local_chunks * 16, local_slack),
            t_cap=partition.suggest_capacity(tl.capacity, 4, local_slack))
        if fused:
            res = engine.star3_count_fused(rl, sl, tl, plan,
                                           use_kernel=use_kernel)
        else:
            res = star3.star3_count(rl, sl, tl, plan, use_kernel=use_kernel)
        count = jax.lax.psum(res.count, (row, col))
        ovf = _psum_bool(ovf_r | ovf_t | ovf_s1 | ovf_s2 | res.overflowed,
                         (row, col))
        return count, ovf

    spec = P((row, col))

    def fn(r: Relation, s: Relation, t: Relation) -> DistJoinResult:
        sm = compat.shard_map(
            local, mesh=mesh, in_specs=(spec,) * 6, out_specs=(P(), P()))
        count, ovf = sm(dict(r.columns), r.valid, dict(s.columns), s.valid,
                        dict(t.columns), t.valid)
        return DistJoinResult(count, ovf)

    return fn


# --------------------------------------------------------------------------
# engine entry point: fused local joins on the mesh
# --------------------------------------------------------------------------

def engine_count_sharded(mesh: Mesh, row: str, col: str,
                         kind: str = "linear", **kw):
    """Distributed fused-engine join: the coarse H(B) (resp. H(A)×G(B),
    h(B)×g(C)) partitions shard across devices exactly as in the scan-based
    builders, but each device's local sweep is ONE fused kernel launch
    (``engine.*_count_fused``) instead of a nested lax.scan — the mesh is
    the coarse grid, the fused Pallas grid is the fine one.

    Overflow anywhere is psum-reduced and reported; the host-side engine
    (``MultiwayJoinEngine``) is the recovery layer — re-invoke on the
    flagged shards with a salted plan, as ``core.driver.engine_count`` does
    on a single host.
    """
    builders = {"linear": linear3_count_sharded,
                "cyclic": cyclic3_count_sharded,
                "star": star3_count_sharded}
    if kind not in builders:
        raise ValueError(f"unknown kind {kind!r}; choose from "
                         f"{sorted(builders)}")
    return builders[kind](mesh, row, col, fused=True, **kw)


# --------------------------------------------------------------------------
# helpers for drivers/tests
# --------------------------------------------------------------------------

def shard_relation(rel: Relation, mesh: Mesh, row: str, col: str) -> Relation:
    """Place a host relation onto the mesh, striped in arrival order."""
    spec = P((row, col))
    sharding = NamedSharding(mesh, spec)
    cols = {k: jax.device_put(v, sharding) for k, v in rel.columns.items()}
    valid = jax.device_put(rel.valid, sharding)
    return Relation(cols, valid)


def pad_to_multiple(rel: Relation, multiple: int) -> Relation:
    """Pad capacity so it divides evenly over the mesh."""
    cap = rel.capacity
    rem = (-cap) % multiple
    if rem == 0:
        return rel
    cols = {k: jnp.pad(v, (0, rem)) for k, v in rel.columns.items()}
    valid = jnp.pad(rel.valid, (0, rem))
    return Relation(cols, valid)
